//! Figure 3 reproduction (DESIGN.md E3): Non-IID-{4,6,8} × attenuation
//! factor α ∈ {0.2, 0.5, 0.8}; contenders per the paper's legend:
//!
//!   solid      — FedAvg (dense)
//!   "- spark"      — conventional flat Top-k
//!   "- layerspares" — THGS (this paper, Alg. 1)
//!
//! Paper's expectation: THGS beats flat sparsification at every α, and
//! approaches the dense curve as α → 0.8.
//!
//!     cargo run --release --example fig3_thgs_beta [--quick]
//! → results/fig3.csv

use fedsparse::config::Partition;
use fedsparse::experiments::{base_config, fig3_contenders, results_dir, run_labeled, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_args();
    let csv = results_dir().join("fig3.csv");
    let _ = std::fs::remove_file(&csv);

    let noniid: &[usize] = match scale {
        Scale::Quick => &[4],
        Scale::Full => &[4, 6, 8],
    };
    let alphas: &[f64] = match scale {
        Scale::Quick => &[0.2, 0.8],
        Scale::Full => &[0.2, 0.5, 0.8],
    };

    let mut rows = Vec::new();
    for &n in noniid {
        for &alpha in alphas {
            for (head, alg) in fig3_contenders(alpha) {
                // fedavg is α-independent: run once per partition
                if head == "fedavg" && alpha != alphas[0] {
                    continue;
                }
                let mut cfg = base_config("mnist_mlp", scale);
                cfg.partition = Partition::NonIid(n);
                cfg.algorithm = alg;
                let label = format!("{head}-noniid{n}");
                let s = run_labeled(cfg, &label, &csv)?;
                rows.push((label, s.final_accuracy));
            }
        }
    }

    println!("=== Fig.3 summary ===");
    for (l, a) in rows {
        println!("{l:<28} final acc {a:.4}");
    }
    println!("curves → {}", csv.display());
    Ok(())
}
