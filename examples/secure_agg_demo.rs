//! Secure-aggregation walkthrough + §4 safety analysis (DESIGN.md E7):
//!
//! 1. full DH → pairwise masks → mask-sparsified updates → server sum,
//!    with the §4 case census (grad-only / mask-only / both / silent);
//! 2. the gradient-inversion probe showing reconstruction quality
//!    collapsing as sparsity increases (§3.1's security claim);
//! 3. the mask-exposure sweep (case-1 rate vs mask ratio k, Eq. 4).
//!
//!     cargo run --release --example secure_agg_demo

use std::collections::HashMap;

use fedsparse::attack::inversion::InversionReport;
use fedsparse::secagg::protocol::{full_setup, SecAggConfig};
use fedsparse::sparse::topk::threshold_for_topk_abs;
use fedsparse::util::rng::Rng;
use fedsparse::util::timer::fmt_bytes;

fn main() -> anyhow::Result<()> {
    let x = 6usize;
    let n = 100_000usize;
    let grad_rate = 0.01;

    println!("=== 1. mask-sparsified secure aggregation ({x} participants, n={n}) ===\n");
    let cfg = SecAggConfig { mask_ratio_k: 0.5, share_keys: false, ..Default::default() };
    let (clients, server) = full_setup(x as u32, 42, &cfg);
    let mut rng = Rng::new(1);

    let mut payloads = Vec::new();
    let mut expect = vec![0f64; n];
    let mut total_sparse = 0u64;
    for c in &clients {
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.05)).collect();
        let k = ((n as f64 * grad_rate).ceil()) as usize;
        let delta = threshold_for_topk_abs(&g, k);
        let keep: Vec<bool> = g.iter().map(|v| v.abs() > delta).collect();
        let u = c.build_update(&g, &keep, 0, x);
        let cen = u.census;
        println!(
            "client {}: sent {:>6}/{n} ({:.2}%)  grad-only {:>5}  mask-only {:>5}  both {:>4}  exposure {:.1}%",
            c.id,
            cen.transmitted(),
            100.0 * cen.transmitted() as f64 / n as f64,
            cen.case1_grad_only,
            cen.case2_mask_only,
            cen.case3_both,
            100.0 * cen.exposure_rate()
        );
        for j in 0..n {
            expect[j] += (g[j] - u.residual[j]) as f64;
        }
        total_sparse += u.payload.paper_cost_bytes();
        payloads.push((c.id, u.payload));
    }
    let agg = server.aggregate(n, 0, &payloads, &[], &HashMap::new());
    let max_err = (0..n).map(|j| (agg[j] as f64 - expect[j]).abs()).fold(0.0, f64::max);
    let dense = fedsparse::sparse::codec::dense_cost_bytes(n) * x as u64;
    println!("\nserver aggregate max|err| = {max_err:.2e} (pairwise masks cancelled exactly)");
    println!(
        "upload: {} masked-sparse vs {} dense secagg → {:.1}%",
        fmt_bytes(total_sparse),
        fmt_bytes(dense),
        100.0 * total_sparse as f64 / dense as f64
    );

    println!("\n=== 2. gradient-inversion probe (§3.1/§4) ===\n");
    let input: Vec<f32> = {
        let mut r = Rng::new(7);
        (0..784).map(|_| r.next_f32()).collect()
    };
    let delta: Vec<f32> = {
        let mut r = Rng::new(8);
        (0..10).map(|_| r.normal_f32(0.3)).collect()
    };
    let report = InversionReport::sweep(&input, &delta, &[1.0, 0.1, 0.01, 0.001]);
    println!("{:>10} {:>22}", "sparsity", "reconstruction cosine");
    for (s, q) in report.rates.iter().zip(&report.quality) {
        println!("{s:>10} {q:>22.4}");
    }
    println!("(1.0 = dense gradient leaks the sample exactly; sparsified uploads degrade the attack)");

    println!("\n=== 3. exposure vs mask ratio k (Eq. 4) ===\n");
    println!("{:>6} {:>12} {:>14}", "k", "exposure %", "sent % of n");
    let g: Vec<f32> = {
        let mut r = Rng::new(9);
        (0..n).map(|_| r.normal_f32(1.0)).collect()
    };
    let kk = (n as f64 * grad_rate).ceil() as usize;
    let d = threshold_for_topk_abs(&g, kk);
    let keep: Vec<bool> = g.iter().map(|v| v.abs() > d).collect();
    for k in [0.1f64, 0.25, 0.5, 1.0, 2.0] {
        let c2 = SecAggConfig { mask_ratio_k: k, share_keys: false, ..Default::default() };
        let (cl, _) = full_setup(x as u32, 50, &c2);
        let u = cl[0].build_update(&g, &keep, 0, x);
        println!(
            "{k:>6} {:>12.2} {:>14.2}",
            100.0 * u.census.exposure_rate(),
            100.0 * u.census.transmitted() as f64 / n as f64
        );
    }
    println!("\nhigher k → fewer exposed grad-only positions but more transmitted mask noise:");
    println!("the paper's condition-2 tradeoff (§3.2), tunable per deployment.");
    Ok(())
}
