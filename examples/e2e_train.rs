//! End-to-end validation driver (DESIGN.md E8, the brief's required
//! workload): federated training of the MNIST-MLP across 100 simulated
//! clients for a few hundred rounds, logging the full loss curve and
//! communication ledger. Proves all three layers compose: Pallas
//! kernels → JAX grad graph → AOT HLO → rust PJRT runtime → coordinator.
//!
//!     cargo run --release --example e2e_train [--quick] [--secure]
//!
//! Results land in results/e2e_loss.csv and EXPERIMENTS.md quotes them.

use fedsparse::coordinator::Algorithm;
use fedsparse::experiments::{base_config, results_dir, run_labeled, Scale};
use fedsparse::sparse::thgs::ThgsConfig;

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_args();
    let secure = std::env::args().any(|a| a == "--secure");
    let mut cfg = base_config("mnist_mlp", scale);
    cfg.rounds = match scale {
        Scale::Quick => 60,
        Scale::Full => 300,
    };
    cfg.eval_every = 5;
    cfg.algorithm = Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 });
    cfg.secure = secure;
    cfg.dynamic_rate = true;

    let csv = results_dir().join("e2e_loss.csv");
    let label = if secure { "e2e-thgs-secure" } else { "e2e-thgs" };
    let summary = run_labeled(cfg, label, &csv)?;

    println!("=== E2E summary ===");
    println!("rounds:            {}", summary.rounds);
    println!("final accuracy:    {:.4}", summary.final_accuracy);
    println!("best accuracy:     {:.4}", summary.best_accuracy);
    println!("upload (paper):    {:.2} MB", summary.total_up_bytes as f64 / 1e6);
    println!("upload (wire):     {:.2} MB", summary.total_wire_bytes as f64 / 1e6);
    println!("sim round time Σ:  {:.1} s", summary.total_sim_time_s);
    println!("loss curve → {}", csv.display());
    Ok(())
}
