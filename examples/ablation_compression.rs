//! Ablation over the compression design space (DESIGN.md "ablation
//! benches for the design choices"): the paper's THGS against the
//! §2.1-cited alternatives and the §6 future-work extensions, at equal
//! data/partition settings:
//!
//!   fedavg            dense baseline
//!   flat              Dryden'16 global Top-k
//!   thgs              the paper (Alg. 1)
//!   thgs+dyn          + Eq. 2 dynamic rate
//!   thgs+mom          + DGC momentum correction + warm-up (§6)
//!   flat+quant4       Top-k + QSGD 4-bit stochastic quantization
//!   stc               Sattler'19 sparse ternary compression
//!
//!     cargo run --release --example ablation_compression [--quick]
//! → results/ablation.csv

use fedsparse::config::Partition;
use fedsparse::coordinator::Algorithm;
use fedsparse::experiments::{base_config, results_dir, run_labeled, Scale};
use fedsparse::sparse::thgs::ThgsConfig;

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_args();
    let csv = results_dir().join("ablation.csv");
    let _ = std::fs::remove_file(&csv);

    let thgs = Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 });
    let mut rows = Vec::new();

    type Mutator = fn(&mut fedsparse::config::RunConfig);
    let variants: Vec<(&str, Algorithm, Mutator)> = vec![
        ("fedavg", Algorithm::FedAvg, |_| {}),
        ("flat", Algorithm::FlatSparse { s: 0.05 }, |_| {}),
        ("thgs", thgs, |_| {}),
        ("thgs+dyn", thgs, |c| c.dynamic_rate = true),
        ("thgs+mom", thgs, |c| {
            c.momentum = 0.9;
            c.warmup_rounds = 5;
        }),
        ("flat+quant4", Algorithm::FlatSparse { s: 0.05 }, |c| {
            c.quant_bits = Some(4)
        }),
        ("stc", Algorithm::Stc { s: 0.05 }, |_| {}),
    ];

    for (label, alg, mutate) in variants {
        let mut cfg = base_config("mnist_mlp", scale);
        cfg.partition = Partition::NonIid(4);
        cfg.algorithm = alg;
        mutate(&mut cfg);
        let s = run_labeled(cfg, label, &csv)?;
        rows.push((label, s.final_accuracy, s.total_up_bytes));
    }

    println!("=== compression ablation (Non-IID-4, mnist_mlp) ===");
    println!("{:<14} {:>10} {:>14}", "variant", "final acc", "upload bytes");
    for (l, a, b) in &rows {
        println!("{l:<14} {a:>10.4} {b:>14}");
    }
    println!("rows → {}", csv.display());
    Ok(())
}
