//! Figure 1 reproduction (DESIGN.md E1): accuracy of the aggregated
//! model vs rounds under flat gradient sparsification with
//! s ∈ {dense, 0.1, 0.01, 0.001}, IID MNIST-MLP.
//!
//! Paper's expectation: s=0.1 indistinguishable from dense; s=0.01 and
//! 0.001 slow early rounds but converge to nearly the same accuracy.
//!
//!     cargo run --release --example fig1_sparsity_sweep [--quick]
//! → results/fig1.csv (series keyed by label)

use fedsparse::coordinator::Algorithm;
use fedsparse::experiments::{base_config, results_dir, run_labeled, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_args();
    let csv = results_dir().join("fig1.csv");
    let _ = std::fs::remove_file(&csv);

    let series: Vec<(String, Algorithm)> = vec![
        ("dense".into(), Algorithm::FedAvg),
        ("s0.1".into(), Algorithm::FlatSparse { s: 0.1 }),
        ("s0.01".into(), Algorithm::FlatSparse { s: 0.01 }),
        ("s0.001".into(), Algorithm::FlatSparse { s: 0.001 }),
    ];

    let mut finals = Vec::new();
    for (label, alg) in series {
        let mut cfg = base_config("mnist_mlp", scale);
        cfg.algorithm = alg;
        let s = run_labeled(cfg, &label, &csv)?;
        finals.push((label, s.final_accuracy, s.total_up_bytes));
    }

    println!("=== Fig.1 summary (accuracy vs sparsity) ===");
    println!("{:<10} {:>10} {:>14}", "series", "final acc", "upload bytes");
    for (l, a, b) in &finals {
        println!("{l:<10} {a:>10.4} {b:>14}");
    }
    println!("curves → {}", csv.display());
    Ok(())
}
