//! Quickstart: train a small federated MNIST-MLP job with THGS
//! sparsification through the public API, in under a minute — from a
//! clean checkout, with no Python step.
//!
//!     cargo run --release --example quickstart
//!
//! Backend selection (`cfg.backend`, default `Auto`):
//!
//! * `BackendKind::Native` — the pure-Rust compute path. Always
//!   available: when `artifacts/manifest.json` is absent the trainer
//!   uses the built-in `mnist_mlp` manifest (159,010 params), so this
//!   example needs nothing beyond `cargo run`.
//! * `BackendKind::Pjrt` — the AOT-artifact path (build with
//!   `--features pjrt` after `make artifacts`); required for the conv
//!   models.
//! * `BackendKind::Auto` — PJRT when available, native otherwise.
//!
//! Failure injection (off by default): real federations lose clients
//! mid-round, and the round engine simulates that deterministically.
//! Three `RunConfig` knobs control it:
//!
//! * `dropout_prob` — per-round probability each selected client
//!   crashes before its upload arrives. In secure mode this also turns
//!   on Shamir key-sharing at setup so the server can recover and
//!   cancel dead clients' masks.
//! * `straggler_timeout_s` — collect deadline in *simulated* seconds;
//!   uploads that land later are excluded from the round
//!   (`f64::INFINITY` = no deadline).
//! * `min_survivors` — below this many delivered uploads the round
//!   aborts: the global model and every client roll back, residuals
//!   carry forward to the clients' next participating round.

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::sparse::thgs::ThgsConfig;
use fedsparse::util::timer::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // A CI-sized configuration: 20 clients over a synthetic MNIST-shaped
    // corpus (drop real IDX files under data/mnist/ to use real MNIST).
    let mut cfg = RunConfig::default();
    cfg.model = "mnist_mlp".into();
    cfg.clients = 20;
    cfg.clients_per_round = 5;
    cfg.train_samples = Some(4_000); // synthetic corpus cap
    cfg.eval_samples = 1_000;
    cfg.rounds = 30;
    cfg.eval_every = 5;
    cfg.algorithm = Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 });
    // cfg.backend = fedsparse::BackendKind::Native; // force pure-Rust

    // Failure injection (see the module docs above). Uncomment to watch
    // the engine drop clients and keep training on the survivors:
    // cfg.dropout_prob = 0.1;          // 10% of selected clients crash per round
    // cfg.straggler_timeout_s = 2.0;   // uploads later than 2 simulated seconds miss
    // cfg.min_survivors = 2;           // abort (and roll back) below 2 uploads

    let mut trainer = Trainer::new(cfg)?;
    println!(
        "training mnist_mlp ({} params) with THGS on the {} backend…",
        trainer.model_params(),
        trainer.backend_name()
    );
    for round in 0..trainer.cfg.rounds {
        let out = trainer.run_round(round)?;
        if let Some((eval_loss, acc)) = out.eval {
            println!(
                "round {:>3}  train_loss {:.4}  eval_loss {:.4}  acc {:.3}",
                round, out.mean_train_loss, eval_loss, acc
            );
        }
    }
    let s = trainer.recorder.summary();
    println!(
        "\nfinal accuracy {:.3} | total upload {} (vs dense {})",
        s.final_accuracy,
        fmt_bytes(s.total_up_bytes),
        fmt_bytes(
            s.rounds
                * trainer.cfg.clients_per_round as u64
                * fedsparse::sparse::codec::dense_cost_bytes(trainer.model_params())
        ),
    );
    Ok(())
}
