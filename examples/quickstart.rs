//! Quickstart: train a small federated MNIST-MLP job with THGS
//! sparsification through the public API, in under a minute — from a
//! clean checkout, with no Python step.
//!
//!     cargo run --release --example quickstart
//!
//! Backend selection (`cfg.backend`, default `Auto`):
//!
//! * `BackendKind::Native` — the pure-Rust compute path. Always
//!   available: when `artifacts/manifest.json` is absent the trainer
//!   uses the built-in `mnist_mlp` manifest (159,010 params), so this
//!   example needs nothing beyond `cargo run`.
//! * `BackendKind::Pjrt` — the AOT-artifact path (build with
//!   `--features pjrt` after `make artifacts`); required for the conv
//!   models.
//! * `BackendKind::Auto` — PJRT when available, native otherwise.

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::sparse::thgs::ThgsConfig;
use fedsparse::util::timer::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // A CI-sized configuration: 20 clients over a synthetic MNIST-shaped
    // corpus (drop real IDX files under data/mnist/ to use real MNIST).
    let mut cfg = RunConfig::default();
    cfg.model = "mnist_mlp".into();
    cfg.clients = 20;
    cfg.clients_per_round = 5;
    cfg.train_samples = Some(4_000); // synthetic corpus cap
    cfg.eval_samples = 1_000;
    cfg.rounds = 30;
    cfg.eval_every = 5;
    cfg.algorithm = Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 });
    // cfg.backend = fedsparse::BackendKind::Native; // force pure-Rust

    let mut trainer = Trainer::new(cfg)?;
    println!(
        "training mnist_mlp ({} params) with THGS on the {} backend…",
        trainer.model_params(),
        trainer.backend_name()
    );
    for round in 0..trainer.cfg.rounds {
        let out = trainer.run_round(round)?;
        if let Some((eval_loss, acc)) = out.eval {
            println!(
                "round {:>3}  train_loss {:.4}  eval_loss {:.4}  acc {:.3}",
                round, out.mean_train_loss, eval_loss, acc
            );
        }
    }
    let s = trainer.recorder.summary();
    println!(
        "\nfinal accuracy {:.3} | total upload {} (vs dense {})",
        s.final_accuracy,
        fmt_bytes(s.total_up_bytes),
        fmt_bytes(
            s.rounds
                * trainer.cfg.clients_per_round as u64
                * fedsparse::sparse::codec::dense_cost_bytes(trainer.model_params())
        ),
    );
    Ok(())
}
