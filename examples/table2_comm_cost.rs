//! Table 2 reproduction (DESIGN.md E5/E6): upload communication cost
//! required to reach 95% of the final (converged) accuracy, under
//! Non-IID data, for FedAvg / FedProx / Ours (THGS + mask-sparsified
//! secure aggregation), plus the compression factor ×.
//!
//! The paper's headline (E6): at sparsity 0.01 the upload cost is
//! 2.9%-18.9% of conventional FL (5.3×-34× compression). We reproduce
//! the *shape* (who wins, roughly what factor) — the absolute bytes
//! differ because rounds-to-converge differ on the synthetic corpus.
//!
//!     cargo run --release --example table2_comm_cost [--quick]
//! → results/table2.csv + printed table

use std::io::Write;

use fedsparse::config::Partition;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::experiments::{base_config, results_dir, Scale};
use fedsparse::sparse::thgs::ThgsConfig;
use fedsparse::util::timer::fmt_bytes;

struct Row {
    model: String,
    alg: String,
    upload: Option<u64>,
    rounds: Option<u64>,
    converged_acc: f64,
}

fn run_one(model: &str, alg_label: &str, alg: Algorithm, secure: bool, scale: Scale) -> anyhow::Result<Row> {
    let mut cfg = base_config(model, scale);
    cfg.partition = Partition::NonIid(6);
    cfg.algorithm = alg;
    cfg.secure = secure;
    if secure {
        // paper regime: the union of pair masks ≈ k of all positions;
        // keep it at the gradient rate's scale so condition 2 holds
        cfg.mask_ratio_k = 0.02;
        cfg.dynamic_rate = true;
    }
    cfg.eval_every = 2;
    println!("── {model} / {alg_label} ──");
    let mut t = Trainer::new(cfg)?;
    for round in 0..t.cfg.rounds {
        t.run_round(round)?;
    }
    let converged = t.ledger.converged_accuracy(5);
    let target = 0.95 * converged;
    Ok(Row {
        model: model.into(),
        alg: alg_label.into(),
        upload: t.ledger.upload_to_reach(target),
        rounds: t.ledger.rounds_to_reach(target),
        converged_acc: converged,
    })
}

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_args();
    let models: &[&str] = match scale {
        Scale::Quick => &["mnist_mlp"],
        Scale::Full => &["mnist_mlp", "mnist_cnn", "cifar_cnn"],
    };
    let ours = Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.5, s_min: 0.01 });

    let mut rows = Vec::new();
    for model in models {
        rows.push(run_one(model, "fedavg", Algorithm::FedAvg, false, scale)?);
        rows.push(run_one(model, "fedprox", Algorithm::FedProx { mu: 0.01 }, false, scale)?);
        rows.push(run_one(model, "ours", ours, true, scale)?);
    }

    println!("\n=== Table 2: upload cost to reach 95% of converged accuracy (Non-IID-6) ===\n");
    println!(
        "{:<12} {:<10} {:>12} {:>8} {:>10} {:>8}",
        "model", "algorithm", "upload", "rounds", "conv acc", "×compr"
    );
    let csv_path = results_dir().join("table2.csv");
    let mut csv = std::fs::File::create(&csv_path)?;
    writeln!(csv, "model,algorithm,upload_bytes,rounds,converged_acc,compression")?;

    for model in models {
        let fedavg_up = rows
            .iter()
            .find(|r| &r.model == model && r.alg == "fedavg")
            .and_then(|r| r.upload);
        for r in rows.iter().filter(|r| &r.model == model) {
            let up_s = r.upload.map(fmt_bytes).unwrap_or_else(|| "n/r".into());
            let rounds_s = r.rounds.map(|x| x.to_string()).unwrap_or_else(|| "n/r".into());
            let compr = match (fedavg_up, r.upload) {
                (Some(f), Some(u)) if u > 0 => format!("{:.1}", f as f64 / u as f64),
                _ => "—".into(),
            };
            println!(
                "{:<12} {:<10} {:>12} {:>8} {:>10.4} {:>8}",
                r.model, r.alg, up_s, rounds_s, r.converged_acc, compr
            );
            writeln!(
                csv,
                "{},{},{},{},{:.4},{}",
                r.model,
                r.alg,
                r.upload.map(|x| x.to_string()).unwrap_or_default(),
                r.rounds.map(|x| x.to_string()).unwrap_or_default(),
                r.converged_acc,
                compr
            )?;
        }
    }
    println!(
        "\npaper Table 2 (for shape comparison): FedAvg→Ours compression\n\
         MNIST-MLP ×13.6, MNIST-CNN ×6.11, FMNIST-MLP ×7, FMNIST-CNN ×19.8,\n\
         CIFAR-MLP ×34, CIFAR-VGG16 ×24.6  (i.e. ours = 2.9%–18.9% of FedAvg)"
    );
    println!("rows → {}", csv_path.display());
    Ok(())
}
