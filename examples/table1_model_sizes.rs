//! Table 1 reproduction (DESIGN.md E4): model parameter sizes and
//! dense update volumes, straight from the AOT manifest — plus the
//! paper's reported numbers for comparison.
//!
//!     cargo run --release --example table1_model_sizes

use fedsparse::models::manifest::Manifest;
use fedsparse::sparse::codec::dense_cost_bytes;

fn human(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.1}G", b as f64 / (1u64 << 30) as f64)
    } else {
        format!("{:.2}M", b as f64 / (1u64 << 20) as f64)
    }
}

fn main() -> anyhow::Result<()> {
    // falls back to the builtin manifest (mnist_mlp only) pre-export
    let manifest = Manifest::load_or_builtin(std::path::Path::new("artifacts"))?;
    // (model, paper's reported parameter size; None = not reported)
    let paper: &[(&str, Option<u64>)] = &[
        ("mnist_mlp", Some(159_010)),
        ("mnist_cnn", Some(582_026)),
        ("cifar_mlp", Some(5_852_170)),
        ("cifar_vgg16", Some(14_728_266)),
        ("cifar_cnn", None),
    ];

    println!("=== Table 1: model parameter sizes and update volumes ===\n");
    println!(
        "{:<14} {:>12} {:>12} {:>10} {:>10}",
        "model", "params", "paper", "update", "Δ%"
    );
    for (name, paper_count) in paper {
        let Some(meta) = manifest.model(name) else {
            println!("{name:<14} {:>12}", "(not exported)");
            continue;
        };
        let ours = meta.param_count as u64;
        let update = dense_cost_bytes(meta.param_count); // m · 64 bit (Eq. 8)
        match paper_count {
            Some(p) => {
                let delta = 100.0 * (ours as f64 - *p as f64) / *p as f64;
                println!(
                    "{name:<14} {ours:>12} {p:>12} {:>10} {delta:>9.2}%",
                    human(update)
                );
            }
            None => println!(
                "{name:<14} {ours:>12} {:>12} {:>10} {:>10}",
                "—",
                human(update),
                "—"
            ),
        }
    }
    println!(
        "\nupdate volume = m·64bit (paper Eq. 8; double-precision accounting).\n\
         mnist_mlp / mnist_cnn / cifar_vgg16 match the paper EXACTLY\n\
         (VGG16+BN: 14,714,688 conv + 8,448 BN γβ + 5,130 fc = 14,728,266).\n\
         cifar_mlp layout is unspecified in the paper; ours is within 1%."
    );
    Ok(())
}
