//! Durable runs: kill a checkpointed training job mid-way, resume it,
//! and verify the result is bitwise-identical to an uninterrupted twin
//! — then seal the run's outputs in a validated manifest.
//!
//!     cargo run --release --example durable_run
//!
//! Three `RunConfig` knobs make a run durable:
//!
//! * `checkpoint_dir` — where end-of-round snapshots land
//!   (`ckpt_<round>.fsckpt`, atomic write → fsync → rename commits, so
//!   a crash mid-save never corrupts the committed set);
//! * `checkpoint_every` — commit cadence in applied rounds (aborted
//!   rounds roll back and never commit);
//! * `resume` — load the newest valid snapshot and continue from it.
//!
//! Resume is exact, not approximate: every RNG stream in the round
//! loop is a pure function of (seed, round, client id), so restoring
//! the cross-round state (model, residuals, rate controllers, momentum
//! velocities, metrics) replays the remaining rounds bit-for-bit.

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::io::manifest::{build_manifest, validate_manifest_file, write_manifest};
use fedsparse::util::json::num;

fn cfg() -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.data_dir = None; // synthetic corpus: runs from a clean checkout
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.rounds = 6;
    cfg.eval_every = 2;
    cfg.dynamic_rate = true;
    cfg.momentum = 0.5;
    cfg
}

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("fedsparse-durable-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // The uninterrupted twin: the reference answer.
    let mut twin = Trainer::new(cfg())?;
    twin.run()?;

    // The same run, checkpointed and "killed" after round 3.
    let mut killed_cfg = cfg();
    killed_cfg.checkpoint_dir = Some(dir.join("ckpt"));
    let mut killed = Trainer::new(killed_cfg.clone())?;
    for round in 0..3 {
        killed.run_round(round)?;
    }
    drop(killed); // stand-in for SIGKILL: no teardown path runs
    println!("killed after 3 of 6 rounds; checkpoints in {:?}", dir.join("ckpt"));

    // Resume: picks up at the newest snapshot and finishes the run.
    let mut resumed_cfg = killed_cfg;
    resumed_cfg.resume = true;
    let mut resumed = Trainer::new(resumed_cfg)?;
    println!("resumed at round {} of {}", resumed.start_round(), resumed.cfg.rounds);
    resumed.run()?;

    let twin_bits: Vec<u32> = twin.global.data.iter().map(|v| v.to_bits()).collect();
    let resumed_bits: Vec<u32> = resumed.global.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(twin_bits, resumed_bits, "resumed model diverged from the twin");
    println!("resumed model is bitwise-identical to the uninterrupted twin ✓");

    // Seal the run's outputs in a self-describing manifest and
    // validate it — the same contract `manifest_check` enforces in CI.
    let csv = dir.join("resumed.csv");
    resumed.recorder.write_csv(&csv)?;
    let built = build_manifest(
        "example-run",
        "durable-run-example",
        vec![
            ("rounds".to_string(), num(resumed.cfg.rounds as f64)),
            ("resumed_at_round".to_string(), num(resumed.start_round() as f64)),
        ],
        &[(csv.clone(), "resumed.csv".to_string())],
    );
    let mpath = dir.join("MANIFEST.json");
    write_manifest(&mpath, &built.manifest)?;
    let issues = validate_manifest_file(&mpath);
    assert!(issues.is_empty(), "manifest failed validation: {issues:?}");
    println!("run manifest written + validated: {}", mpath.display());
    Ok(())
}
