//! Figure 2 reproduction (DESIGN.md E2): learning curves under Non-IID
//! distribution with aggressive sparsity (paper: s = 0.001), sparse vs
//! dense, MNIST-MLP + MNIST-CNN.
//!
//! Paper's expectation: sparsity still converges under Non-IID; the
//! sparse loss curve can even be smoother (implicit regularization).
//!
//!     cargo run --release --example fig2_noniid [--quick]
//! → results/fig2.csv

use fedsparse::config::Partition;
use fedsparse::coordinator::Algorithm;
use fedsparse::experiments::{base_config, results_dir, run_labeled, Scale};

fn main() -> anyhow::Result<()> {
    let scale = Scale::from_args();
    let csv = results_dir().join("fig2.csv");
    let _ = std::fs::remove_file(&csv);

    // quick scale uses s=0.01 (0.001 needs paper-scale rounds to move)
    let s = match scale {
        Scale::Quick => 0.01,
        Scale::Full => 0.001,
    };
    let models: &[&str] = match scale {
        Scale::Quick => &["mnist_mlp"],
        Scale::Full => &["mnist_mlp", "mnist_cnn"],
    };

    for model in models {
        for noniid_n in [4usize, 8] {
            for (label_head, alg) in [
                ("dense", Algorithm::FedAvg),
                ("sparse", Algorithm::FlatSparse { s }),
            ] {
                let mut cfg = base_config(model, scale);
                cfg.partition = Partition::NonIid(noniid_n);
                cfg.algorithm = alg;
                let label = format!("{model}-{label_head}-noniid{noniid_n}");
                run_labeled(cfg, &label, &csv)?;
            }
        }
    }
    println!("curves → {}", csv.display());
    Ok(())
}
