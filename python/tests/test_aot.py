"""AOT exporter tests: HLO text validity, manifest schema, kernel
artifacts — the build-time half of the interchange contract the rust
runtime depends on (rust/tests/integration.rs covers the load half)."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as model_mod, zoo


@pytest.fixture(scope="module")
def outdir(tmp_path_factory):
    d = tmp_path_factory.mktemp("artifacts")
    return str(d)


def test_to_hlo_text_produces_parseable_module():
    fn, _ = model_mod.make_grad_fn("mnist_mlp")
    lowered = jax.jit(fn).lower(*model_mod.arg_specs("mnist_mlp", 4))
    text = aot.to_hlo_text(lowered)
    # HLO text invariants the rust-side parser relies on
    assert text.startswith("HloModule")
    assert "ENTRY" in text
    assert "f32[" in text
    # return_tuple=True → root is a tuple of (loss, grads…)
    assert "tuple(" in text or ") tuple" in text or "(f32[]" in text


def test_export_model_writes_artifacts_and_entry(outdir):
    entry = aot.export_model("mnist_mlp", outdir)
    assert entry["param_count"] == 159_010
    assert os.path.exists(os.path.join(outdir, entry["grad"]))
    assert os.path.exists(os.path.join(outdir, entry["eval"]))
    # layer table covers all params
    covered = [i for ly in entry["layers"] for i in ly["params"]]
    assert sorted(covered) == list(range(len(entry["params"])))
    # init specs carry everything rust init needs
    for p in entry["params"]:
        assert p["init"]["kind"] in ("normal", "zeros", "ones")
        assert all(d > 0 for d in p["shape"])


def test_export_kernels_all_sizes(outdir):
    index = aot.export_kernels(outdir)
    assert index["block"] == 1024
    for n in aot.KERNEL_SIZES:
        assert os.path.exists(os.path.join(outdir, index["sparsify"][str(n)]))
        assert os.path.exists(os.path.join(outdir, index["masked_agg"][str(n)]))


def test_manifest_json_schema(outdir):
    # emulate main() for one quick model
    manifest = {
        "version": 1,
        "train_batch": aot.TRAIN_BATCH,
        "eval_batch": aot.EVAL_BATCH,
        "models": {"mnist_mlp": aot.export_model("mnist_mlp", outdir)},
        "kernels": aot.export_kernels(outdir),
    }
    path = os.path.join(outdir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f)
    loaded = json.load(open(path))
    assert loaded["train_batch"] == 50  # paper batch size
    assert loaded["models"]["mnist_mlp"]["classes"] == 10


def test_grad_eval_batch_sizes_fixed():
    # the rust runtime relies on these exact shapes
    specs = model_mod.arg_specs("mnist_mlp", aot.TRAIN_BATCH)
    assert specs[-2].shape == (50, 28, 28, 1)
    specs = model_mod.arg_specs("mnist_mlp", aot.EVAL_BATCH)
    assert specs[-2].shape == (250, 28, 28, 1)


def test_exported_grad_matches_direct_execution(outdir):
    """The lowered artifact computes the same numbers as direct jax."""
    fn, n_params = model_mod.make_grad_fn("mnist_mlp")
    params = model_mod.init_params("mnist_mlp", seed=0)
    x = jax.random.normal(jax.random.PRNGKey(1), (aot.TRAIN_BATCH, 28, 28, 1))
    y = jax.random.randint(jax.random.PRNGKey(2), (aot.TRAIN_BATCH,), 0, 10)

    direct = fn(*params, x, y)
    compiled = jax.jit(fn)(*params, x, y)
    assert jnp.allclose(direct[0], compiled[0], rtol=1e-5, atol=1e-5)
    for d, c in zip(direct[1:], compiled[1:]):
        assert jnp.allclose(d, c, rtol=1e-4, atol=1e-4)


def test_default_zoo_covers_paper_models():
    for name in ["mnist_mlp", "mnist_cnn", "cifar_mlp", "cifar_vgg16"]:
        assert name in aot.DEFAULT_MODELS
    for name in aot.DEFAULT_MODELS:
        assert zoo.resolve(name) in zoo.MODELS
