"""L2 model graph tests: Table 1 parameter parity, shapes, learnability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as model_mod
from compile import zoo


# ------------------------------------------------- Table 1 parity (E4)

def test_mnist_mlp_param_count_exact():
    assert zoo.param_count("mnist_mlp") == 159_010  # paper Table 1


def test_mnist_cnn_param_count_exact():
    assert zoo.param_count("mnist_cnn") == 582_026  # paper Table 1


def test_cifar_vgg16_param_count_exact():
    assert zoo.param_count("cifar_vgg16") == 14_728_266  # paper Table 1


def test_cifar_mlp_param_count_close():
    # paper reports 5,852,170 with unspecified layout; ours is within 1%
    ours = zoo.param_count("cifar_mlp")
    assert abs(ours - 5_852_170) / 5_852_170 < 0.01


def test_fmnist_aliases_share_architecture():
    assert zoo.param_count("fmnist_mlp") == zoo.param_count("mnist_mlp")
    assert zoo.param_count("fmnist_cnn") == zoo.param_count("mnist_cnn")


def test_layer_table_covers_all_params():
    for name in zoo.MODELS:
        specs = zoo.param_specs(name)
        covered = [i for ly in zoo.layer_table(name) for i in ly["params"]]
        assert sorted(covered) == list(range(len(specs))), name


# ----------------------------------------------------- forward shapes

@pytest.mark.parametrize("name", ["mnist_mlp", "mnist_cnn", "cifar_cnn", "cifar_mlp"])
def test_forward_logits_shape(name):
    spec = zoo.MODELS[zoo.resolve(name)]
    params = model_mod.init_params(name, seed=0)
    x = jnp.zeros((4, *spec["input"]))
    logits = model_mod.forward(name, params, x)
    assert logits.shape == (4, spec["classes"])


def test_vgg_forward_shape():
    params = model_mod.init_params("cifar_vgg16", seed=0)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32, 3))
    logits = model_mod.forward("cifar_vgg16", params, x)
    assert logits.shape == (2, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


# -------------------------------------------------------- grad + eval

@pytest.mark.parametrize("name", ["mnist_mlp", "cifar_cnn"])
def test_grad_fn_signature_and_descent(name):
    grad_fn, n_params = model_mod.make_grad_fn(name)
    spec = zoo.MODELS[zoo.resolve(name)]
    params = model_mod.init_params(name, seed=1)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (8, *spec["input"]))
    y = jax.random.randint(jax.random.PRNGKey(3), (8,), 0, 10)

    out = grad_fn(*params, x, y)
    loss0, grads = out[0], out[1:]
    assert len(grads) == n_params
    for g, p in zip(grads, params):
        assert g.shape == p.shape

    # a few SGD steps on the same batch must reduce the loss
    lr = 0.01
    for _ in range(4):
        out = grad_fn(*params, x, y)
        params = [p - lr * g for p, g in zip(params, out[1:])]
    loss1 = grad_fn(*params, x, y)[0]
    assert float(loss1) < float(loss0)


def test_eval_fn_counts():
    eval_fn, _ = model_mod.make_eval_fn("mnist_mlp")
    params = model_mod.init_params("mnist_mlp", seed=4)
    x = jax.random.normal(jax.random.PRNGKey(5), (16, 28, 28, 1))
    y = jax.random.randint(jax.random.PRNGKey(6), (16,), 0, 10)
    loss_sum, correct = eval_fn(*params, x, y)
    assert float(loss_sum) > 0.0
    assert 0.0 <= float(correct) <= 16.0
    assert float(correct) == int(float(correct))  # integral count


def test_eval_correct_matches_argmax():
    eval_fn, _ = model_mod.make_eval_fn("mnist_mlp")
    params = model_mod.init_params("mnist_mlp", seed=7)
    x = jax.random.normal(jax.random.PRNGKey(8), (32, 28, 28, 1))
    logits = model_mod.forward("mnist_mlp", params, x)
    y = jnp.argmax(logits, axis=-1)  # labels = predictions → all correct
    _, correct = eval_fn(*params, x, y.astype(jnp.int32))
    assert float(correct) == 32.0


def test_arg_specs_order():
    specs = model_mod.arg_specs("mnist_mlp", 50)
    # 4 params + x + y
    assert len(specs) == 6
    assert specs[0].shape == (784, 200)
    assert specs[-2].shape == (50, 28, 28, 1)
    assert specs[-1].shape == (50,)
    assert specs[-1].dtype == jnp.int32


def test_init_matches_manifest_spec():
    params = model_mod.init_params("mnist_mlp", seed=0)
    specs = zoo.param_specs("mnist_mlp")
    for p, s in zip(params, specs):
        assert p.shape == tuple(s["shape"])
        if s["init"]["kind"] == "zeros":
            np.testing.assert_array_equal(np.asarray(p), 0.0)
        elif s["init"]["kind"] == "ones":
            np.testing.assert_array_equal(np.asarray(p), 1.0)


def test_batchnorm_normalizes():
    x = jax.random.normal(jax.random.PRNGKey(9), (8, 4, 4, 3)) * 5.0 + 2.0
    out = model_mod._batchnorm(x, jnp.ones((3,)), jnp.zeros((3,)))
    np.testing.assert_allclose(np.mean(np.asarray(out), axis=(0, 1, 2)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.std(np.asarray(out), axis=(0, 1, 2)), 1.0, atol=1e-3)
