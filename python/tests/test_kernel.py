"""L1 kernel correctness: pallas vs pure-jnp oracle (ref.py).

This is the core correctness signal for the compute layer. Includes
hypothesis sweeps over shapes/values per the repro brief.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense as dense_k
from compile.kernels import masked_agg as magg_k
from compile.kernels import ref
from compile.kernels import sparsify as sp_k


def _rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- matmul

@pytest.mark.parametrize(
    "m,k,n",
    [
        (50, 784, 200),   # mnist_mlp layer 1 fwd
        (50, 200, 10),    # mnist_mlp layer 2 fwd
        (200, 50, 784),   # its dw transpose shapes
        (50, 1024, 512),  # mnist_cnn fc1
        (50, 3072, 1800), # cifar_mlp fc1
        (1, 128, 128),
        (7, 11, 13),      # primes: exercises block fallback to full dim
    ],
)
def test_matmul_matches_ref(m, k, n):
    x = _rand(1, (m, k))
    w = _rand(2, (k, n))
    got = dense_k.matmul(x, w)
    want = ref.matmul_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


def test_pick_block_divides_and_caps():
    for dim in [1, 2, 10, 50, 128, 200, 250, 512, 784, 1024, 1800, 3072]:
        b = dense_k.pick_block(dim)
        assert dim % b == 0
        assert b <= max(dense_k.MXU_TILE, dim if dim <= dense_k.MXU_TILE else 0) or b <= dense_k.MXU_TILE


@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=25, deadline=None)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    x = _rand(seed, (m, k))
    w = _rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        dense_k.matmul(x, w), ref.matmul_ref(x, w), rtol=2e-5, atol=2e-4
    )


# ----------------------------------------------------------------- dense

@pytest.mark.parametrize("act", ["relu", "none"])
def test_dense_fwd_matches_ref(act):
    x = _rand(3, (50, 784))
    w = _rand(4, (784, 200), 0.05)
    b = _rand(5, (200,), 0.1)
    got = dense_k.dense(x, w, b, act)
    want = ref.dense_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-4)


@pytest.mark.parametrize("act", ["relu", "none"])
def test_dense_grad_matches_autodiff_of_ref(act):
    x = _rand(6, (20, 64))
    w = _rand(7, (64, 32), 0.1)
    b = _rand(8, (32,), 0.1)

    def loss_pallas(w, b):
        return jnp.sum(dense_k.dense(x, w, b, act) ** 2)

    def loss_ref(w, b):
        return jnp.sum(ref.dense_ref(x, w, b, act) ** 2)

    gw, gb = jax.grad(loss_pallas, argnums=(0, 1))(w, b)
    gw_r, gb_r = jax.grad(loss_ref, argnums=(0, 1))(w, b)
    np.testing.assert_allclose(gw, gw_r, rtol=2e-4, atol=2e-3)
    np.testing.assert_allclose(gb, gb_r, rtol=2e-4, atol=2e-3)


def test_dense_grad_wrt_input():
    x = _rand(9, (8, 16))
    w = _rand(10, (16, 12), 0.2)
    b = jnp.zeros((12,))
    gx = jax.grad(lambda x: jnp.sum(dense_k.dense(x, w, b, "relu") ** 2))(x)
    gx_r = jax.grad(lambda x: jnp.sum(ref.dense_ref(x, w, b, "relu") ** 2))(x)
    np.testing.assert_allclose(gx, gx_r, rtol=2e-4, atol=2e-3)


def test_dense_jit_composes():
    x = _rand(11, (10, 32))
    w = _rand(12, (32, 10), 0.2)
    b = jnp.zeros((10,))
    f = jax.jit(lambda x: dense_k.dense(x, w, b, "relu"))
    np.testing.assert_allclose(f(x), ref.dense_ref(x, w, b, "relu"), rtol=2e-5, atol=2e-4)


# -------------------------------------------------------------- sparsify

def test_sparsify_matches_ref_exact():
    g = _rand(13, (4096,))
    thr = jnp.array([0.8], jnp.float32)
    s, r = sp_k.sparsify(g, thr)
    s_r, r_r = ref.sparsify_ref(g, thr[0])
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r_r))


def test_sparsify_exact_split_invariant():
    g = _rand(14, (2048,), 3.0)
    thr = jnp.array([1.5], jnp.float32)
    s, r = sp_k.sparsify(g, thr)
    # bitwise: sparse + residual reconstructs g, and supports are disjoint
    np.testing.assert_array_equal(np.asarray(s + r), np.asarray(g))
    assert not np.any((np.asarray(s) != 0) & (np.asarray(r) != 0))


def test_sparsify_threshold_zero_keeps_all_nonzero():
    g = _rand(15, (1024,))
    s, r = sp_k.sparsify(g, jnp.array([0.0], jnp.float32))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(g))
    np.testing.assert_array_equal(np.asarray(r), np.zeros_like(g))


def test_sparsify_threshold_inf_keeps_none():
    g = _rand(16, (1024,))
    s, r = sp_k.sparsify(g, jnp.array([np.inf], jnp.float32))
    np.testing.assert_array_equal(np.asarray(s), np.zeros_like(g))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(g))


def test_sparsify_rejects_unpadded():
    with pytest.raises(ValueError):
        sp_k.sparsify(jnp.zeros((1000,)), jnp.array([1.0]))


def test_sparsify_padded_wrapper():
    g = _rand(17, (1000,))
    thr = jnp.array([0.5], jnp.float32)
    s, r = sp_k.sparsify_padded(g, thr)
    s_r, r_r = ref.sparsify_ref(g, thr[0])
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r_r))


@given(
    n_blocks=st.integers(1, 8),
    thr=st.floats(0.0, 4.0),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=30, deadline=None)
def test_sparsify_hypothesis(n_blocks, thr, seed):
    g = _rand(seed, (n_blocks * sp_k.LANE_BLOCK,), 2.0)
    t = jnp.array([thr], jnp.float32)
    s, r = sp_k.sparsify(g, t)
    s_r, r_r = ref.sparsify_ref(g, t[0])
    np.testing.assert_array_equal(np.asarray(s), np.asarray(s_r))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r_r))


def test_topk_threshold_ref_selects_kth():
    g = jnp.array([0.1, -5.0, 2.0, -0.3, 4.0, 1.0, -2.5, 0.0])
    # |g| sorted desc: 5, 4, 2.5, 2, 1, .3, .1, 0
    assert float(ref.topk_threshold_ref(g, 1)) == 5.0
    assert float(ref.topk_threshold_ref(g, 3)) == 2.5
    assert float(ref.topk_threshold_ref(g, 8)) == 0.0


# ------------------------------------------------------------ masked_agg

def test_masked_agg_matches_ref():
    acc = _rand(18, (2048,))
    c = _rand(19, (2048,))
    m = (jax.random.uniform(jax.random.PRNGKey(20), (2048,)) > 0.5).astype(jnp.float32)
    got = magg_k.masked_agg(acc, c, m)
    want = ref.masked_agg_ref(acc, c, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_masked_agg_zero_mask_is_identity():
    acc = _rand(21, (1024,))
    c = _rand(22, (1024,))
    got = magg_k.masked_agg(acc, c, jnp.zeros((1024,)))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(acc))


def test_masked_agg_shape_mismatch_raises():
    with pytest.raises(ValueError):
        magg_k.masked_agg(jnp.zeros((1024,)), jnp.zeros((1024,)), jnp.zeros((2048,)))


@given(n_blocks=st.integers(1, 4), seed=st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_masked_agg_hypothesis(n_blocks, seed):
    n = n_blocks * magg_k.LANE_BLOCK
    acc = _rand(seed, (n,))
    c = _rand(seed + 1, (n,))
    m = (jax.random.uniform(jax.random.PRNGKey(seed + 2), (n,)) > 0.3).astype(jnp.float32)
    np.testing.assert_allclose(
        np.asarray(magg_k.masked_agg(acc, c, m)),
        np.asarray(ref.masked_agg_ref(acc, c, m)),
        rtol=1e-6, atol=1e-6,
    )
