"""AOT exporter — lowers L2 graphs (with inlined L1 pallas kernels) to
HLO *text* artifacts + a manifest the rust runtime consumes.

Interchange format is HLO text, NOT ``lowered.compile().serialize()``:
jax ≥ 0.5 emits HloModuleProtos with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Usage (from python/):
    python -m compile.aot --outdir ../artifacts [--models m1,m2] [--quick]

Artifacts:
    <model>_grad.hlo.txt   (params…, x[B], y[B]) → (loss, grads…)
    <model>_eval.hlo.txt   (params…, x[E], y[E]) → (loss_sum, correct)
    sparsify_<n>.hlo.txt   (g[n], thr[1]) → (sparse[n], residual[n])
    masked_agg_<n>.hlo.txt (acc[n], c[n], m[n]) → acc'[n]
    manifest.json          shapes / layer table / init / artifact index
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from . import zoo
from .kernels import masked_agg as magg_k
from .kernels import sparsify as sp_k

TRAIN_BATCH = 50   # paper §5: local batch size 50
EVAL_BATCH = 250   # divides the 10k test split evenly
KERNEL_SIZES = [1024, 16384, 131072]  # standalone L1 kernel exports

DEFAULT_MODELS = ["mnist_mlp", "mnist_cnn", "cifar_cnn", "cifar_mlp", "cifar_vgg16"]
QUICK_MODELS = ["mnist_mlp", "cifar_cnn"]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write(outdir: str, fname: str, text: str) -> None:
    path = os.path.join(outdir, fname)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {fname}  ({len(text) / 1e6:.2f} MB)", flush=True)


def export_model(name: str, outdir: str) -> dict:
    """Lower grad+eval for one model; return its manifest entry."""
    t0 = time.time()
    spec = zoo.MODELS[zoo.resolve(name)]

    grad_fn, _ = model_mod.make_grad_fn(name)
    lowered = jax.jit(grad_fn).lower(*model_mod.arg_specs(name, TRAIN_BATCH))
    _write(outdir, f"{name}_grad.hlo.txt", to_hlo_text(lowered))

    eval_fn, _ = model_mod.make_eval_fn(name)
    lowered = jax.jit(eval_fn).lower(*model_mod.arg_specs(name, EVAL_BATCH))
    _write(outdir, f"{name}_eval.hlo.txt", to_hlo_text(lowered))

    entry = {
        "input": spec["input"],
        "classes": spec["classes"],
        "params": zoo.param_specs(name),
        "layers": zoo.layer_table(name),
        "param_count": zoo.param_count(name),
        "grad": f"{name}_grad.hlo.txt",
        "eval": f"{name}_eval.hlo.txt",
    }
    print(f"  {name}: {entry['param_count']} params, {time.time() - t0:.1f}s")
    return entry


def export_kernels(outdir: str) -> dict:
    """Standalone L1 kernel artifacts (rust↔pallas parity tests)."""
    index = {"sparsify": {}, "masked_agg": {}, "block": sp_k.LANE_BLOCK}
    for n in KERNEL_SIZES:
        g = jax.ShapeDtypeStruct((n,), jnp.float32)
        thr = jax.ShapeDtypeStruct((1,), jnp.float32)
        lowered = jax.jit(lambda g, t: sp_k.sparsify(g, t)).lower(g, thr)
        fname = f"sparsify_{n}.hlo.txt"
        _write(outdir, fname, to_hlo_text(lowered))
        index["sparsify"][str(n)] = fname

        lowered = jax.jit(lambda a, c, m: magg_k.masked_agg(a, c, m)).lower(g, g, g)
        fname = f"masked_agg_{n}.hlo.txt"
        _write(outdir, fname, to_hlo_text(lowered))
        index["masked_agg"][str(n)] = fname
    return index


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--models", default=None,
                    help="comma list; default exports the full zoo")
    ap.add_argument("--quick", action="store_true",
                    help="only the small models (CI-speed)")
    args = ap.parse_args()

    os.makedirs(args.outdir, exist_ok=True)
    if args.models:
        names = [m.strip() for m in args.models.split(",") if m.strip()]
    elif args.quick:
        names = QUICK_MODELS
    else:
        names = DEFAULT_MODELS

    manifest = {
        "version": 1,
        "train_batch": TRAIN_BATCH,
        "eval_batch": EVAL_BATCH,
        "models": {},
        "kernels": export_kernels(args.outdir),
    }
    for name in names:
        print(f"exporting {name} …", flush=True)
        manifest["models"][name] = export_model(name, args.outdir)

    with open(os.path.join(args.outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['models'])} models → {args.outdir}/manifest.json")
    return 0


if __name__ == "__main__":
    sys.exit(main())
