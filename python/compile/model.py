"""L2 — the paper's models as JAX fwd/bwd graphs, calling L1 kernels.

Every dense layer goes through the pallas ``kernels.dense`` kernel
(matmul fwd + matmul-based custom-vjp bwd), so the exported HLO contains
the L1 kernel lowering inline. Convolutions use ``lax.conv`` at L2 (the
paper's hot spots are the dense layers and the sparsify sweep; see
DESIGN.md §Hardware-Adaptation).

The two graphs exported per model:

  grad_fn(params…, x, y) → (loss, grads…)     — one local SGD step's work
  eval_fn(params…, x, y) → (loss_sum, correct) — test-set shard metrics

``params…`` is the flat, manifest-ordered tuple of tensors so the rust
runtime can feed positional PJRT arguments without pytree logic.
"""

import functools
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from . import zoo
from .kernels import dense as dense_k

BN_EPS = 1e-5


def _batchnorm(x, gamma, beta):
    """Training-mode batch norm over N,H,W (per-channel statistics).

    No running averages: federated rounds re-estimate batch statistics
    locally, and eval reuses batch stats (standard simplification for
    FL reproductions; affine γ/β are the trained parameters, matching
    the paper's 14,728,266 VGG16 count).
    """
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    xhat = (x - mean) * lax.rsqrt(var + BN_EPS)
    return xhat * gamma + beta


def forward(name: str, params: Sequence[jnp.ndarray], x: jnp.ndarray) -> jnp.ndarray:
    """Run the model named ``name`` over NHWC batch ``x`` → logits."""
    spec = zoo.MODELS[zoo.resolve(name)]
    p = 0
    h = x
    for ly in spec["layers"]:
        kind = ly["kind"]
        if kind == "flatten":
            h = h.reshape(h.shape[0], -1)
        elif kind == "maxpool":
            s = ly["size"]
            h = lax.reduce_window(
                h, -jnp.inf, lax.max, (1, s, s, 1), (1, s, s, 1), "VALID"
            )
        elif kind == "dense":
            w, b = params[p], params[p + 1]
            p += 2
            h = dense_k.dense(h, w, b, ly["act"])
        elif kind == "conv":
            w, b = params[p], params[p + 1]
            p += 2
            h = lax.conv_general_dilated(
                h, w, window_strides=(1, 1), padding=ly["pad"],
                dimension_numbers=("NHWC", "HWIO", "NHWC"),
            ) + b
            if ly.get("bn"):
                gamma, beta = params[p], params[p + 1]
                p += 2
                h = _batchnorm(h, gamma, beta)
            if ly["act"] == "relu":
                h = jnp.maximum(h, 0.0)
        else:
            raise ValueError(f"unknown layer kind {kind!r}")
    assert p == len(params), f"{name}: used {p} of {len(params)} params"
    return h


def _ce_loss(logits: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Mean softmax cross-entropy with integer labels."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def make_grad_fn(name: str):
    """(params…, x, y) → (loss, *grads) — flat signature for AOT export."""
    n_params = len(zoo.param_specs(name))

    def loss_of(params: Tuple, x, y):
        return _ce_loss(forward(name, params, x), y)

    def grad_fn(*args):
        params, x, y = args[:n_params], args[n_params], args[n_params + 1]
        loss, grads = jax.value_and_grad(loss_of)(tuple(params), x, y)
        return (loss, *grads)

    return grad_fn, n_params


def make_eval_fn(name: str):
    """(params…, x, y) → (loss_sum, correct_count) over an eval shard."""
    n_params = len(zoo.param_specs(name))

    def eval_fn(*args):
        params, x, y = args[:n_params], args[n_params], args[n_params + 1]
        logits = forward(name, tuple(params), x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
        correct = jnp.sum(
            (jnp.argmax(logits, axis=-1) == y).astype(jnp.float32)
        )
        return jnp.sum(nll), correct

    return eval_fn, n_params


def init_params(name: str, seed: int = 0) -> List[jnp.ndarray]:
    """Reference initializer (tests only — rust owns init at runtime)."""
    key = jax.random.PRNGKey(seed)
    out = []
    for p in zoo.param_specs(name):
        kind = p["init"]["kind"]
        shape = tuple(p["shape"])
        if kind == "normal":
            key, sub = jax.random.split(key)
            out.append(jax.random.normal(sub, shape) * p["init"]["std"])
        elif kind == "zeros":
            out.append(jnp.zeros(shape))
        elif kind == "ones":
            out.append(jnp.ones(shape))
        else:
            raise ValueError(f"unknown init {kind!r}")
    return out


def arg_specs(name: str, batch: int):
    """ShapeDtypeStructs for (params…, x, y) at the given batch size."""
    spec = zoo.MODELS[zoo.resolve(name)]
    specs = [
        jax.ShapeDtypeStruct(tuple(p["shape"]), jnp.float32)
        for p in zoo.param_specs(name)
    ]
    specs.append(jax.ShapeDtypeStruct((batch, *spec["input"]), jnp.float32))
    specs.append(jax.ShapeDtypeStruct((batch,), jnp.int32))
    return specs
