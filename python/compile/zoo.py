"""Model zoo — architecture specs shared by model.py and the manifest.

Parameter counts match the paper's Table 1 where the architecture can be
inferred exactly:

  mnist_mlp   784-200-10                                  → 159,010  ✓ exact
  mnist_cnn   conv5×5×32(valid)-pool-conv5×5×64(valid)-
              pool-fc1024→512-fc512→10                    → 582,026  ✓ exact
  cifar_vgg16 VGG16+BN conv stack + fc512→10              → 14,728,266 ✓ exact
              (the +8,448 over plain VGG16 features is the BN γ/β set,
               which pins down that the paper used the BN variant)
  cifar_mlp   3072-1800-200-10                            → 5,893,610
              (paper: 5,852,170; layout unspecified, ~0.7% off)
  cifar_cnn   small CIFAR convnet — scaled stand-in for CI-speed runs
              (not in the paper; documented in DESIGN.md)

A model is a list of layer dicts. Layer kinds:
  {"kind": "dense",   "in": I, "out": O, "act": "relu"|"none"}
  {"kind": "conv",    "kh":, "kw":, "cin":, "cout":, "pad": "SAME"|"VALID",
                      "act": "relu"|"none", "bn": bool}
  {"kind": "maxpool", "size": 2}
  {"kind": "flatten"}
Dense/conv layers carry trainable params; THGS treats each such layer as
one sparsification group (manifest "layers" table).
"""

from typing import Dict, List


def _dense(i, o, act):
    return {"kind": "dense", "in": i, "out": o, "act": act}


def _conv(cin, cout, k=3, pad="SAME", act="relu", bn=False):
    return {
        "kind": "conv", "kh": k, "kw": k, "cin": cin, "cout": cout,
        "pad": pad, "act": act, "bn": bn,
    }


def _pool():
    return {"kind": "maxpool", "size": 2}


def _flat():
    return {"kind": "flatten"}


def _vgg16_layers() -> List[dict]:
    cfg = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
           512, 512, 512, "M", 512, 512, 512, "M"]
    layers: List[dict] = []
    cin = 3
    for v in cfg:
        if v == "M":
            layers.append(_pool())
        else:
            layers.append(_conv(cin, v, k=3, pad="SAME", act="relu", bn=True))
            cin = v
    layers.append(_flat())          # 1×1×512 after five pools on 32×32
    layers.append(_dense(512, 10, "none"))
    return layers


MODELS: Dict[str, dict] = {
    "mnist_mlp": {
        "input": [28, 28, 1],
        "classes": 10,
        "layers": [
            _flat(),
            _dense(784, 200, "relu"),
            _dense(200, 10, "none"),
        ],
    },
    "mnist_cnn": {
        "input": [28, 28, 1],
        "classes": 10,
        "layers": [
            _conv(1, 32, k=5, pad="VALID", act="relu"),
            _pool(),
            _conv(32, 64, k=5, pad="VALID", act="relu"),
            _pool(),
            _flat(),
            _dense(1024, 512, "relu"),
            _dense(512, 10, "none"),
        ],
    },
    "cifar_mlp": {
        "input": [32, 32, 3],
        "classes": 10,
        "layers": [
            _flat(),
            _dense(3072, 1800, "relu"),
            _dense(1800, 200, "relu"),
            _dense(200, 10, "none"),
        ],
    },
    "cifar_cnn": {
        "input": [32, 32, 3],
        "classes": 10,
        "layers": [
            _conv(3, 16, k=3, pad="SAME", act="relu"),
            _pool(),
            _conv(16, 32, k=3, pad="SAME", act="relu"),
            _pool(),
            _flat(),
            _dense(2048, 64, "relu"),
            _dense(64, 10, "none"),
        ],
    },
    "cifar_vgg16": {
        "input": [32, 32, 3],
        "classes": 10,
        "layers": _vgg16_layers(),
    },
}

# fashion-MNIST uses the MNIST architectures verbatim (paper Table 1
# lists identical parameter sizes); only the dataset differs, which is a
# rust-side concern. The aliases keep experiment configs readable.
MODEL_ALIASES = {"fmnist_mlp": "mnist_mlp", "fmnist_cnn": "mnist_cnn"}


def resolve(name: str) -> str:
    return MODEL_ALIASES.get(name, name)


def param_specs(name: str) -> List[dict]:
    """Flat list of parameter tensors for a model, in execution order.

    Each entry: name, shape, init spec ({kind, std}) and the index of
    the network layer it belongs to (THGS grouping).
    """
    spec = MODELS[resolve(name)]
    out: List[dict] = []
    layer_idx = 0
    for ly in spec["layers"]:
        if ly["kind"] == "dense":
            fan_in = ly["in"]
            std = (2.0 / fan_in) ** 0.5 if ly["act"] == "relu" else (1.0 / fan_in) ** 0.5
            out.append({
                "name": f"layer{layer_idx}/w", "shape": [ly["in"], ly["out"]],
                "init": {"kind": "normal", "std": std}, "layer": layer_idx,
            })
            out.append({
                "name": f"layer{layer_idx}/b", "shape": [ly["out"]],
                "init": {"kind": "zeros", "std": 0.0}, "layer": layer_idx,
            })
            layer_idx += 1
        elif ly["kind"] == "conv":
            fan_in = ly["kh"] * ly["kw"] * ly["cin"]
            std = (2.0 / fan_in) ** 0.5
            out.append({
                "name": f"layer{layer_idx}/w",
                "shape": [ly["kh"], ly["kw"], ly["cin"], ly["cout"]],
                "init": {"kind": "normal", "std": std}, "layer": layer_idx,
            })
            out.append({
                "name": f"layer{layer_idx}/b", "shape": [ly["cout"]],
                "init": {"kind": "zeros", "std": 0.0}, "layer": layer_idx,
            })
            if ly.get("bn"):
                out.append({
                    "name": f"layer{layer_idx}/gamma", "shape": [ly["cout"]],
                    "init": {"kind": "ones", "std": 0.0}, "layer": layer_idx,
                })
                out.append({
                    "name": f"layer{layer_idx}/beta", "shape": [ly["cout"]],
                    "init": {"kind": "zeros", "std": 0.0}, "layer": layer_idx,
                })
            layer_idx += 1
    return out


def param_count(name: str) -> int:
    total = 0
    for p in param_specs(name):
        n = 1
        for d in p["shape"]:
            n *= d
        total += n
    return total


def layer_table(name: str) -> List[dict]:
    """THGS layer groups: for each network layer, the param indices."""
    specs = param_specs(name)
    groups: Dict[int, List[int]] = {}
    for i, p in enumerate(specs):
        groups.setdefault(p["layer"], []).append(i)
    return [
        {"name": f"layer{k}", "params": v}
        for k, v in sorted(groups.items())
    ]
