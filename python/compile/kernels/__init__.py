"""L1 Pallas kernels (build-time only).

Every kernel here is lowered with ``interpret=True`` so the emitted HLO
contains plain XLA ops runnable on the CPU PJRT plugin (real-TPU pallas
lowering emits a Mosaic custom-call the CPU client cannot execute; see
DESIGN.md §Hardware-Adaptation for the TPU mapping).

Kernels:
  dense       — MXU-tiled matmul + fused bias/activation (custom_vjp so
                jax.grad differentiates through the pallas calls).
  sparsify    — threshold-apply half of Top-k sparsification
                (Alg. 1 lines 7-12 of the paper).
  masked_agg  — fused masked accumulate used by the secure-aggregation
                server sum (Eq. 5 application).
  ref         — pure-jnp oracles for all of the above.
"""

from . import dense, masked_agg, ref, sparsify  # noqa: F401
