"""Pallas masked-accumulate kernel (secure-aggregation server sum).

Implements the hot inner loop of the server's Eq. 5 aggregation:

    acc' = acc + contrib ⊙ mask

where ``contrib`` is a client's decoded (masked) update and ``mask`` is
the transmission mask ``mask_t`` (1 where the client actually sent a
value). Fused multiply-add, bandwidth-bound; tiled like ``sparsify``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_BLOCK = 1024


def _masked_agg_kernel(a_ref, c_ref, m_ref, o_ref):
    o_ref[...] = a_ref[...] + c_ref[...] * m_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def masked_agg(acc, contrib, mask, interpret: bool = True, block: int = LANE_BLOCK):
    """Fused ``acc + contrib * mask`` over flat f32 arrays of equal length.

    Length must be a multiple of ``block`` (AOT pads; rust mirrors).
    """
    (n,) = acc.shape
    if contrib.shape != (n,) or mask.shape != (n,):
        raise ValueError("masked_agg: shape mismatch")
    if n % block != 0:
        raise ValueError(f"masked_agg: n={n} not a multiple of block={block}")
    spec = pl.BlockSpec((block,), lambda i: (i,))
    return pl.pallas_call(
        _masked_agg_kernel,
        grid=(n // block,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=interpret,
    )(acc, contrib, mask)
