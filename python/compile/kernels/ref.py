"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These define the *semantics*; the pallas kernels in ``dense.py``,
``sparsify.py`` and ``masked_agg.py`` must agree with them to float32
tolerance. pytest (``python/tests/test_kernel.py``) asserts the
agreement, including hypothesis-driven shape/value sweeps.
"""

import jax.numpy as jnp


def matmul_ref(x, w):
    """Plain f32 matmul: ``x @ w`` with f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def dense_ref(x, w, b, act="relu"):
    """Dense layer oracle: ``act(x @ w + b)``."""
    z = matmul_ref(x, w) + b
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "none":
        return z
    raise ValueError(f"unknown activation {act!r}")


def sparsify_ref(g, thr):
    """Threshold-apply oracle (Alg. 1 lines 7-12).

    Keeps entries with ``|g| > thr`` and splits the rest into the
    residual so that ``sparse + residual == g`` exactly.
    Returns ``(sparse, residual)``.
    """
    keep = jnp.abs(g) > thr
    sparse = jnp.where(keep, g, 0.0)
    return sparse, g - sparse


def masked_agg_ref(acc, contrib, mask):
    """Masked accumulate oracle: ``acc + contrib * mask`` (Eq. 5 apply)."""
    return acc + contrib * mask


def topk_threshold_ref(g, k):
    """Top-k threshold selection oracle: the k-th largest ``|g|``.

    This is the L2 half of sparsification (the sort/partition half that
    stays out of the pallas kernel — see DESIGN.md §Hardware-Adaptation).
    ``k`` is clamped to ``[1, g.size]``.
    """
    flat = jnp.abs(jnp.ravel(g))
    k = max(1, min(int(k), flat.shape[0]))
    return jnp.sort(flat)[flat.shape[0] - k]
