"""Pallas dense-layer kernel (the model's matmul hot-spot).

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel tiles the
output into ``bm × bn`` blocks sized for the MXU systolic array
(≤128 per side), streaming the full-K slabs of ``x`` and ``w`` through
VMEM via BlockSpec. fp32 accumulation (``preferred_element_type``).
Lowered with ``interpret=True`` so the exported HLO runs on CPU PJRT.

``jax.grad`` cannot differentiate through ``pallas_call`` on its own, so
``dense`` carries a ``custom_vjp`` whose backward pass is *also* built
from the same pallas matmul kernel:

    dz = dy * act'(z)        (elementwise, at L2)
    dx = dz @ wᵀ             (pallas matmul)
    dw = xᵀ @ dz             (pallas matmul)
    db = Σ_batch dz          (reduction, at L2)
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Hardware tile cap: one MXU side. Blocks are the largest divisor of the
# dim ≤ this cap so every grid cell is full (no masking needed).
MXU_TILE = 128


def pick_block(dim: int, cap: int = MXU_TILE) -> int:
    """Largest divisor of ``dim`` that is ≤ ``cap``.

    Keeps every pallas grid cell full-sized. All dims in the model zoo
    are composite enough that this stays ≥ dim/8 in practice.
    """
    if dim <= cap:
        return dim
    for b in range(cap, 0, -1):
        if dim % b == 0:
            return b
    return 1  # unreachable: 1 divides everything


def _mm_kernel(x_ref, w_ref, o_ref):
    # One (bm, K) × (K, bn) MXU slab per grid cell, f32 accumulate.
    o_ref[...] = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul(x, w, interpret: bool = True):
    """Tiled pallas matmul ``x[M,K] @ w[K,N] -> [M,N]`` (f32)."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {w.shape}"
    bm, bn = pick_block(m), pick_block(n)
    return pl.pallas_call(
        _mm_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, w)


def _act_fwd(z, act: str):
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "none":
        return z
    raise ValueError(f"unknown activation {act!r}")


def _act_bwd(z, dy, act: str):
    if act == "relu":
        return jnp.where(z > 0.0, dy, 0.0)
    if act == "none":
        return dy
    raise ValueError(f"unknown activation {act!r}")


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, act: str = "relu"):
    """Fused dense layer ``act(x @ w + b)`` with a pallas matmul core."""
    return _act_fwd(matmul(x, w) + b, act)


def _dense_fwd(x, w, b, act):
    z = matmul(x, w) + b
    return _act_fwd(z, act), (x, w, z)


def _dense_bwd(act, res, dy):
    x, w, z = res
    dz = _act_bwd(z, dy, act)
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
