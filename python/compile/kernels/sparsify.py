"""Pallas threshold-sparsify kernel (Alg. 1 lines 7-12).

The paper's THGS sparsification has two halves:

  1. *threshold selection* — find the k-th largest ``|g|`` in a layer.
     Sort/partition is not a TPU-friendly primitive, so this stays at
     L2/L3 (``ref.topk_threshold_ref`` in jax; ``sparse::topk`` in rust).
  2. *threshold application* — the O(N) sweep producing the sparse
     update and the residual. This is bandwidth-bound elementwise work
     and is the pallas kernel below: 1-D lanes tiled in VPU-register
     multiples (8×128 = 1024 elements per block).

Exact-split invariant: ``sparse + residual == g`` bitwise, because the
residual is computed as ``g - sparse`` with sparse ∈ {g, 0}.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 sublanes × 128 lanes — one VPU register tile of f32.
LANE_BLOCK = 1024


def _sparsify_kernel(g_ref, t_ref, s_ref, r_ref):
    g = g_ref[...]
    thr = t_ref[0]
    s = jnp.where(jnp.abs(g) > thr, g, 0.0)
    s_ref[...] = s
    r_ref[...] = g - s


@functools.partial(jax.jit, static_argnames=("interpret", "block"))
def sparsify(g, thr, interpret: bool = True, block: int = LANE_BLOCK):
    """Apply threshold ``thr`` to flat ``g[n]``.

    ``n`` must be a multiple of ``block`` (the AOT exporter pads layer
    tails; rust mirrors the padding). ``thr`` is a shape-``[1]`` f32
    array (a scalar operand would need SMEM prefetch on real TPU; a
    [1]-ref works on both paths).

    Returns ``(sparse[n], residual[n])``.
    """
    (n,) = g.shape
    if n % block != 0:
        raise ValueError(f"sparsify: n={n} not a multiple of block={block}")
    return pl.pallas_call(
        _sparsify_kernel,
        grid=(n // block,),
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_shape=[jax.ShapeDtypeStruct((n,), jnp.float32)] * 2,
        interpret=interpret,
    )(g, thr)


def sparsify_padded(g, thr, block: int = LANE_BLOCK):
    """Pad-to-block wrapper for arbitrary-length ``g`` (test helper)."""
    (n,) = g.shape
    pad = (-n) % block
    gp = jnp.pad(g, (0, pad))
    s, r = sparsify(gp, thr, block=block)
    return s[:n], r[:n]
