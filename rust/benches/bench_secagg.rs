//! Secure-aggregation benchmarks (§3.2 costs): DH setup, mask
//! expansion, sparse-mask build, masked-update construction and server
//! aggregation, at the paper's MNIST-MLP size.

use std::collections::HashMap;

use fedsparse::secagg::mask::MaskRange;
use fedsparse::secagg::neighborhood::Neighborhood;
use fedsparse::secagg::protocol::{full_setup, SecAggConfig};
use fedsparse::secagg::rekey::RekeyRegistry;
use fedsparse::sparse::topk::threshold_for_topk_abs;
use fedsparse::util::bench::{black_box, Bench};
use fedsparse::util::pool::ThreadPool;
use fedsparse::util::rng::Rng;

fn main() {
    let mut b = Bench::new("secagg");
    let n = 159_010usize; // mnist_mlp
    let x = 10usize; // paper: 10 clients per round

    // one-time setup cost (toy group; full RFC group = `full_dh_setup`)
    b.bench("setup/toy_dh/10clients", || {
        let cfg = SecAggConfig { share_keys: false, ..Default::default() };
        black_box(full_setup(10, 1, &cfg));
    });
    b.bench("setup/rfc3526_dh/3clients", || {
        let cfg = SecAggConfig { full_dh: true, share_keys: false, ..Default::default() };
        black_box(full_setup(3, 1, &cfg));
    });

    let cfg = SecAggConfig { mask_ratio_k: 0.5, share_keys: false, ..Default::default() };
    let (clients, server) = full_setup(x as u32, 2, &cfg);

    // dense mask expansion (the Bonawitz baseline per-round cost)
    let masker = clients[0].masker_for(&(1..x as u32).collect::<Vec<_>>());
    b.bench_throughput("mask/dense_combined/159k", n as u64, || {
        black_box(masker.combined_mask(3, n));
    });

    // sparse mask expansion (the paper's Alg. 2 path)
    let sigma = MaskRange::default().sigma(0.5, x);
    b.bench_throughput("mask/sparse_combined/159k", n as u64, || {
        black_box(masker.sparse_combined_mask(3, n, sigma));
    });

    // same sweep through caller-owned scratch (the round engine's
    // zero-allocation path — no dense stream, no fresh accumulators)
    let mut acc = Vec::new();
    let mut nz = Vec::new();
    b.bench_throughput("mask/sparse_combined_into/159k", n as u64, || {
        masker.sparse_combined_mask_into(3, n, sigma, &mut acc, &mut nz);
        black_box((&acc, &nz));
    });

    // per-pair fan-out over a worker pool (bitwise-identical reduction
    // order — see PERF.md); same sweep, generation parallelized
    let pool = ThreadPool::new(3);
    b.bench_throughput("mask/sparse_combined_pooled/159k", n as u64, || {
        masker.sparse_combined_mask_pooled_into(&pool, 3, n, sigma, &mut acc, &mut nz);
        black_box((&acc, &nz));
    });

    // full client-side masked update
    let mut rng = Rng::new(3);
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.05)).collect();
    let k = n / 100;
    let d = threshold_for_topk_abs(&g, k);
    let keep: Vec<bool> = g.iter().map(|v| v.abs() > d).collect();
    b.bench_throughput("client/build_update/159k", n as u64, || {
        black_box(clients[0].build_update(&g, &keep, 5, x));
    });

    // per-round neighborhood-local re-keying at 10k clients, degree 16
    // (O(n·k): 160k shares/round). The old all-pairs setup walk is
    // O(n³) field evaluations — infeasible at 10k — so the honest
    // contrast runs both paths at n = 64 and lets the asymptotics
    // speak; round advances per iteration so every owner re-shares.
    {
        let big = 10_000u32;
        let cfg = SecAggConfig { share_keys: false, ..Default::default() };
        let (clients10k, _server) = full_setup(big, 4, &cfg);
        let sel: Vec<u32> = (0..big).collect();
        let mut reg = RekeyRegistry::new(3);
        let mut round = 0u64;
        b.bench("rekey10k/per_round_10k_deg16", || {
            round += 1;
            let topo = Neighborhood::build(&sel, 16, 5, round);
            black_box(reg.rekey_for(&clients10k, &topo, round, 5));
        });

        let (clients64, _s) = full_setup(64, 4, &cfg);
        let sel64: Vec<u32> = (0..64u32).collect();
        let mut reg64 = RekeyRegistry::new(3);
        let mut round64 = 0u64;
        b.bench("rekey10k/per_round_64_deg16", || {
            round64 += 1;
            let topo = Neighborhood::build(&sel64, 16, 5, round64);
            black_box(reg64.rekey_for(&clients64, &topo, round64, 5));
        });
        b.bench("rekey10k/allpairs_setup_64", || {
            let cfg = SecAggConfig { share_keys: true, ..Default::default() };
            black_box(full_setup(64, 4, &cfg));
        });
    }

    // server aggregation of x masked payloads
    let payloads: Vec<_> = clients
        .iter()
        .map(|c| (c.id, c.build_update(&g, &keep, 7, x).payload))
        .collect();
    b.bench_throughput("server/aggregate/10x159k", (n * x) as u64, || {
        black_box(server.aggregate(n, 7, &payloads, &[], &HashMap::new()));
    });

    b.finish();
}
