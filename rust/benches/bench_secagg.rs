//! Secure-aggregation benchmarks (§3.2 costs): DH setup, mask
//! expansion, sparse-mask build, masked-update construction and server
//! aggregation, at the paper's MNIST-MLP size.

use std::collections::HashMap;

use fedsparse::secagg::mask::MaskRange;
use fedsparse::secagg::protocol::{full_setup, SecAggConfig};
use fedsparse::sparse::topk::threshold_for_topk_abs;
use fedsparse::util::bench::{black_box, Bench};
use fedsparse::util::pool::ThreadPool;
use fedsparse::util::rng::Rng;

fn main() {
    let mut b = Bench::new("secagg");
    let n = 159_010usize; // mnist_mlp
    let x = 10usize; // paper: 10 clients per round

    // one-time setup cost (toy group; full RFC group = `full_dh_setup`)
    b.bench("setup/toy_dh/10clients", || {
        let cfg = SecAggConfig { share_keys: false, ..Default::default() };
        black_box(full_setup(10, 1, &cfg));
    });
    b.bench("setup/rfc3526_dh/3clients", || {
        let cfg = SecAggConfig { full_dh: true, share_keys: false, ..Default::default() };
        black_box(full_setup(3, 1, &cfg));
    });

    let cfg = SecAggConfig { mask_ratio_k: 0.5, share_keys: false, ..Default::default() };
    let (clients, server) = full_setup(x as u32, 2, &cfg);

    // dense mask expansion (the Bonawitz baseline per-round cost)
    let masker = clients[0].masker_for(&(1..x as u32).collect::<Vec<_>>());
    b.bench_throughput("mask/dense_combined/159k", n as u64, || {
        black_box(masker.combined_mask(3, n));
    });

    // sparse mask expansion (the paper's Alg. 2 path)
    let sigma = MaskRange::default().sigma(0.5, x);
    b.bench_throughput("mask/sparse_combined/159k", n as u64, || {
        black_box(masker.sparse_combined_mask(3, n, sigma));
    });

    // same sweep through caller-owned scratch (the round engine's
    // zero-allocation path — no dense stream, no fresh accumulators)
    let mut acc = Vec::new();
    let mut nz = Vec::new();
    b.bench_throughput("mask/sparse_combined_into/159k", n as u64, || {
        masker.sparse_combined_mask_into(3, n, sigma, &mut acc, &mut nz);
        black_box((&acc, &nz));
    });

    // per-pair fan-out over a worker pool (bitwise-identical reduction
    // order — see PERF.md); same sweep, generation parallelized
    let pool = ThreadPool::new(3);
    b.bench_throughput("mask/sparse_combined_pooled/159k", n as u64, || {
        masker.sparse_combined_mask_pooled_into(&pool, 3, n, sigma, &mut acc, &mut nz);
        black_box((&acc, &nz));
    });

    // full client-side masked update
    let mut rng = Rng::new(3);
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.05)).collect();
    let k = n / 100;
    let d = threshold_for_topk_abs(&g, k);
    let keep: Vec<bool> = g.iter().map(|v| v.abs() > d).collect();
    b.bench_throughput("client/build_update/159k", n as u64, || {
        black_box(clients[0].build_update(&g, &keep, 5, x));
    });

    // server aggregation of x masked payloads
    let payloads: Vec<_> = clients
        .iter()
        .map(|c| (c.id, c.build_update(&g, &keep, 7, x).payload))
        .collect();
    b.bench_throughput("server/aggregate/10x159k", (n * x) as u64, || {
        black_box(server.aggregate(n, 7, &payloads, &[], &HashMap::new()));
    });

    b.finish();
}
