//! End-to-end round latency per algorithm (paper Table 2's time
//! dimension): one full federated round — local training through the
//! resolved backend (native by default; PJRT grad artifacts when built
//! with `--features pjrt` after `make artifacts`), sparsify, (secure)
//! encode, transport collect, aggregate — for each contender.
//!
//! Besides the human-readable summary, this bench writes
//! `BENCH_round.json` (cwd): per-contender latency stats plus the
//! round engine's mean per-phase timings, so the perf trajectory of
//! every phase is machine-trackable across PRs.

use std::path::PathBuf;

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::metrics::PhaseTimings;
use fedsparse::sparse::thgs::ThgsConfig;
use fedsparse::util::bench::{black_box, Bench};
use fedsparse::util::json::{arr, num, obj, s, Value};

fn cfg_for(alg: Algorithm, secure: bool) -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    // resolves to pjrt when built+exported, native otherwise
    cfg.artifacts_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.data_dir = None;
    cfg.rounds = 1_000_000; // bench drives rounds manually
    cfg.eval_every = u64::MAX; // no eval inside the measured round
    cfg.clients = 20;
    cfg.clients_per_round = 10; // paper: 10 clients per round
    cfg.local_iters = 5;
    // single-core testbed: extra workers only add scheduling overhead
    cfg.exec_workers = 2;
    cfg.client_workers = 2;
    cfg.algorithm = alg;
    cfg.secure = secure;
    cfg
}

fn main() {
    let mut b = Bench::new("round");
    {
        let probe = Trainer::new(cfg_for(Algorithm::FedAvg, false)).unwrap();
        eprintln!("bench_round: backend = {}", probe.backend_name());
    }

    let contenders: Vec<(&str, Algorithm, bool)> = vec![
        ("fedavg", Algorithm::FedAvg, false),
        ("fedprox", Algorithm::FedProx { mu: 0.01 }, false),
        ("flat_s0.01", Algorithm::FlatSparse { s: 0.01 }, false),
        (
            "thgs",
            Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 }),
            false,
        ),
        (
            "thgs_secure",
            Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 }),
            true,
        ),
    ];

    let mut cases: Vec<Value> = Vec::new();
    for (label, alg, secure) in contenders {
        let mut trainer = Trainer::new(cfg_for(alg, secure)).unwrap();
        let n = trainer.model_params();
        let mut round = 0u64;
        // warm the executable cache before measuring
        trainer.run_round(round).unwrap();
        round += 1;
        let mut phase_sum = PhaseTimings::default();
        let mut phase_n = 0u64;
        let stats = b.bench(&format!("mnist_mlp/{label}"), || {
            let out = trainer.run_round(round).unwrap();
            phase_sum.accumulate(&out.timings);
            phase_n += 1;
            round += 1;
            black_box(out);
        });
        let phases = phase_sum.scaled(1.0 / phase_n.max(1) as f64);
        cases.push(obj(vec![
            ("name", s(&stats.name)),
            ("n", num(n as f64)),
            ("iters", num(stats.iters as f64)),
            ("mean_s", num(stats.mean.as_secs_f64())),
            ("std_dev_s", num(stats.std_dev.as_secs_f64())),
            ("p50_s", num(stats.p50.as_secs_f64())),
            ("p95_s", num(stats.p95.as_secs_f64())),
            ("min_s", num(stats.min.as_secs_f64())),
            ("phases", phases.to_json()),
        ]));
    }

    // Bench::finish writes the generic schema; overwrite with the
    // phase-annotated report (same base fields + `phases`, including
    // the new mask_gen_s column the streaming σ-filter is judged on).
    b.finish();

    let report = obj(vec![("bench", s("round")), ("cases", arr(cases))]);
    let path = PathBuf::from("BENCH_round.json");
    std::fs::write(&path, report.to_string()).expect("write BENCH_round.json");
    println!("\nmachine-readable report: {}", path.display());
}
