//! End-to-end round latency per algorithm (paper Table 2's time
//! dimension): one full federated round — local training through the
//! resolved backend (native by default; PJRT grad artifacts when built
//! with `--features pjrt` after `make artifacts`), sparsify, (secure)
//! encode, transport collect, aggregate — for each contender.
//!
//! Besides the human-readable summary, this bench writes
//! `BENCH_round.json` (cwd): per-contender latency stats plus the
//! round engine's mean per-phase timings, so the perf trajectory of
//! every phase is machine-trackable across PRs.

use std::path::PathBuf;

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::metrics::PhaseTimings;
use fedsparse::sparse::thgs::ThgsConfig;
use fedsparse::util::bench::{black_box, Bench};
use fedsparse::util::json::{arr, num, obj, s, Value};

fn cfg_for(alg: Algorithm, secure: bool) -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    // resolves to pjrt when built+exported, native otherwise
    cfg.artifacts_dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    cfg.data_dir = None;
    cfg.rounds = 1_000_000; // bench drives rounds manually
    cfg.eval_every = u64::MAX; // no eval inside the measured round
    cfg.clients = 20;
    cfg.clients_per_round = 10; // paper: 10 clients per round
    cfg.local_iters = 5;
    // single-core testbed: extra workers only add scheduling overhead
    cfg.exec_workers = 2;
    cfg.client_workers = 2;
    cfg.algorithm = alg;
    cfg.secure = secure;
    cfg
}

fn main() {
    let mut b = Bench::new("round");
    {
        let probe = Trainer::new(cfg_for(Algorithm::FedAvg, false)).unwrap();
        eprintln!("bench_round: backend = {}", probe.backend_name());
    }

    let contenders: Vec<(&str, Algorithm, bool)> = vec![
        ("fedavg", Algorithm::FedAvg, false),
        ("fedprox", Algorithm::FedProx { mu: 0.01 }, false),
        ("flat_s0.01", Algorithm::FlatSparse { s: 0.01 }, false),
        (
            "thgs",
            Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 }),
            false,
        ),
        (
            "thgs_secure",
            Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 }),
            true,
        ),
    ];

    let mut cases: Vec<Value> = Vec::new();
    for (label, alg, secure) in contenders {
        let mut trainer = Trainer::new(cfg_for(alg, secure)).unwrap();
        let n = trainer.model_params();
        let mut round = 0u64;
        // warm the executable cache before measuring
        trainer.run_round(round).unwrap();
        round += 1;
        let mut phase_sum = PhaseTimings::default();
        let mut phase_n = 0u64;
        let stats = b.bench(&format!("mnist_mlp/{label}"), || {
            let out = trainer.run_round(round).unwrap();
            phase_sum.accumulate(&out.timings);
            phase_n += 1;
            round += 1;
            black_box(out);
        });
        let phases = phase_sum.scaled(1.0 / phase_n.max(1) as f64);
        cases.push(obj(vec![
            ("name", s(&stats.name)),
            ("n", num(n as f64)),
            ("iters", num(stats.iters as f64)),
            ("mean_s", num(stats.mean.as_secs_f64())),
            ("std_dev_s", num(stats.std_dev.as_secs_f64())),
            ("p50_s", num(stats.p50.as_secs_f64())),
            ("p95_s", num(stats.p95.as_secs_f64())),
            ("min_s", num(stats.min.as_secs_f64())),
            ("phases", phases.to_json()),
        ]));
    }

    // --- headline: one secure round at 10,000 simulated clients -----
    // Local SGD at this scale is not the subject, so the case drives
    // the protocol + coordinator layers directly: every client builds
    // its masked uplink once (setup; the shared stream cache generates
    // each k-regular pair stream a single time), then the timed legs
    // are (1) the coordinator's streaming Collect — decode + fold all
    // 10k uplinks into a 4-shard accumulator whose footprint is
    // O(model), not O(cohort) — and (2) dead-client mask recovery,
    // which under the k-regular topology touches one neighborhood
    // (degree 16), not 9,999 survivor pairs.
    {
        use std::collections::HashMap;

        use fedsparse::coordinator::ShardedAccumulator;
        use fedsparse::secagg::neighborhood::Neighborhood;
        use fedsparse::secagg::protocol::{full_setup, SecAggConfig};
        use fedsparse::sparse::codec::SparseVec;
        use fedsparse::sparse::topk::threshold_for_topk_abs;
        use fedsparse::util::pool::ThreadPool;
        use fedsparse::util::rng::Rng;

        const COHORT: usize = 10_000;
        const DIM: usize = 4_096;
        const SHARDS: usize = 4;
        let round = 1u64;
        let sc = SecAggConfig { share_keys: false, mask_ratio_k: 0.2, ..Default::default() };
        let (mut clients, server) = full_setup(COHORT as u32, 42, &sc);
        let cache: fedsparse::secagg::mask::MaskCache = Default::default();
        for c in clients.iter_mut() {
            c.attach_cache(cache.clone());
        }
        let selected: Vec<u32> = (0..COHORT as u32).collect();
        let topo = Neighborhood::build(&selected, 16, 42, round);
        assert!(!topo.is_complete(), "10k cohort must get a k-regular graph");
        eprintln!(
            "bench_round: secure10k — cohort {COHORT}, degree {}, dim {DIM}, {SHARDS} shards",
            topo.degree()
        );

        let mut rng = Rng::new(7);
        let mut peers: Vec<u32> = Vec::new();
        let payloads: Vec<Vec<u8>> = clients
            .iter()
            .map(|c| {
                let g: Vec<f32> = (0..DIM).map(|_| rng.normal_f32(0.1)).collect();
                let d = threshold_for_topk_abs(&g, DIM / 100);
                let keep: Vec<bool> = g.iter().map(|v| v.abs() > d).collect();
                topo.neighbors_into(c.id, &mut peers);
                c.build_update_among(&g, &keep, round, &peers).payload.encode()
            })
            .collect();

        let mut acc = ShardedAccumulator::default();
        let mut decode = SparseVec::default();
        let mut agg: Vec<f32> = Vec::new();
        let stats = b.bench("secure10k/collect_stream", || {
            acc.reset(DIM, SHARDS);
            for p in &payloads {
                SparseVec::decode_into(p, &mut decode).unwrap();
                acc.fold(&decode);
            }
            acc.merge_into(&mut agg);
            black_box(agg.len());
        });
        cases.push(obj(vec![
            ("name", s(&stats.name)),
            ("n", num(DIM as f64)),
            ("clients", num(COHORT as f64)),
            ("iters", num(stats.iters as f64)),
            ("mean_s", num(stats.mean.as_secs_f64())),
            ("std_dev_s", num(stats.std_dev.as_secs_f64())),
            ("p50_s", num(stats.p50.as_secs_f64())),
            ("p95_s", num(stats.p95.as_secs_f64())),
            ("min_s", num(stats.min.as_secs_f64())),
        ]));

        // recovery leg: the reconstructable pair keys are handed in
        // (Shamir re-sharing at 10k is out of scope for the bench) and
        // the cache is None so stream regeneration — the actual
        // recovery work — is what gets measured
        let pool = ThreadPool::new(2);
        let dead = [clients[0].id];
        topo.neighbors_into(dead[0], &mut peers);
        let survivors: Vec<u32> =
            selected.iter().copied().filter(|&v| v != dead[0]).collect();
        let mut recovered: HashMap<(u32, u32), [u8; 32]> = HashMap::new();
        for &v in &peers {
            let (lo, hi) = if v < dead[0] { (v, dead[0]) } else { (dead[0], v) };
            recovered.insert((lo, hi), clients[v as usize].pair_key_with(dead[0]));
        }
        let stats = b.bench("secure10k/recover_one_dead", || {
            server.cancel_dead_masks_pooled_sink(
                &pool,
                None,
                DIM,
                round,
                &survivors,
                &dead,
                &recovered,
                topo.participants(),
                Some(&topo),
                |i, x| acc.sub_at(i, x),
            );
            black_box(acc.len());
        });
        cases.push(obj(vec![
            ("name", s(&stats.name)),
            ("n", num(DIM as f64)),
            ("clients", num(COHORT as f64)),
            ("iters", num(stats.iters as f64)),
            ("mean_s", num(stats.mean.as_secs_f64())),
            ("std_dev_s", num(stats.std_dev.as_secs_f64())),
            ("p50_s", num(stats.p50.as_secs_f64())),
            ("p95_s", num(stats.p95.as_secs_f64())),
            ("min_s", num(stats.min.as_secs_f64())),
        ]));
    }

    // Bench::finish writes the generic schema; overwrite with the
    // phase-annotated report (same base fields + `phases`, including
    // the new mask_gen_s column the streaming σ-filter is judged on).
    b.finish();

    let report = obj(vec![("bench", s("round")), ("cases", arr(cases))]);
    let path = PathBuf::from("BENCH_round.json");
    std::fs::write(&path, report.to_string()).expect("write BENCH_round.json");
    println!("\nmachine-readable report: {}", path.display());
}
