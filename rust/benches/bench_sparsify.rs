//! Sparsification hot-path benchmarks (per paper Table 1/2 model
//! sizes): flat Top-k, THGS, threshold application, and the Pallas
//! kernel offload path for comparison.
//!
//!     cargo bench --bench bench_sparsify
//!     FEDSPARSE_BENCH_QUICK=1 cargo bench …   (CI-speed)

use fedsparse::sparse::flat::{apply_threshold, flat_topk_sparsify};
use fedsparse::sparse::thgs::{thgs_sparsify, thgs_sparsify_into, ThgsConfig};
use fedsparse::sparse::topk::threshold_for_topk_abs;
use fedsparse::util::bench::{black_box, Bench};
use fedsparse::util::rng::Rng;

fn grad(seed: u64, n: usize) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| rng.normal_f32(0.05)).collect()
}

/// mnist_mlp layer spans (784×200+200, 200×10+10).
fn mlp_spans() -> Vec<(usize, usize)> {
    vec![(0, 157_000), (157_000, 2_010)]
}

fn main() {
    let mut b = Bench::new("sparsify");

    // paper model sizes: MLP 159k, CNN 582k, CIFAR-MLP 5.85M
    for (label, n) in [("mlp159k", 159_010usize), ("cnn582k", 582_026), ("cifar5.9M", 5_893_610)] {
        let g = grad(1, n);
        b.bench_throughput(&format!("flat_topk/s0.01/{label}"), n as u64, || {
            black_box(flat_topk_sparsify(&g, 0.01));
        });
    }

    // THGS vs flat at the same model (the paper's contribution vs baseline)
    let g = grad(2, 159_010);
    let spans = mlp_spans();
    let cfg = ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 };
    b.bench_throughput("thgs/mlp159k", 159_010, || {
        black_box(thgs_sparsify(&g, &spans, &cfg));
    });

    // same split through caller-owned scratch (the round engine's
    // zero-allocation path)
    let mut scratch = Vec::new();
    let mut out = fedsparse::sparse::flat::SparsifyOut::default();
    b.bench_throughput("thgs_into/mlp159k", 159_010, || {
        thgs_sparsify_into(&g, &spans, &cfg, &mut scratch, &mut out);
        black_box(&out);
    });

    // split the two halves: selection vs application
    b.bench_throughput("topk_select/mlp159k", 159_010, || {
        black_box(threshold_for_topk_abs(&g, 1_590));
    });
    let thr = threshold_for_topk_abs(&g, 1_590);
    b.bench_throughput("apply_threshold/mlp159k", 159_010, || {
        black_box(apply_threshold(&g, thr));
    });

    // sparsity-rate sweep (Fig. 1 rates)
    let g = grad(3, 582_026);
    for s in [0.1f64, 0.01, 0.001] {
        b.bench_throughput(&format!("flat_topk/cnn582k/s{s}"), 582_026, || {
            black_box(flat_topk_sparsify(&g, s));
        });
    }

    b.finish();
}
