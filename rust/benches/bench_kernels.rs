//! Kernel-layer microbenchmarks (PERF.md §SIMD layer): the vectorized
//! blocked-matmul grad, the quad-block ChaCha dispatch, and the
//! σ-filter compress — each with its forced-scalar twin where the
//! toggle is public, so `bench_diff` tracks the SIMD win per kernel
//! instead of only through the aggregate round benches.

use fedsparse::models::manifest::Manifest;
use fedsparse::models::params::ParamVector;
use fedsparse::runtime::{Backend, NativeBackend, Workspace};
use fedsparse::secagg::mask::{MaskRange, PairwiseMasker};
use fedsparse::util::bench::{black_box, Bench};
use fedsparse::util::chacha::ChaCha20;
use fedsparse::util::rng::Rng;
use fedsparse::util::simd;

fn main() {
    let mut b = Bench::new("kernels");
    let n = 159_010usize; // mnist_mlp
    eprintln!("bench_kernels: simd enabled = {}", simd::enabled());

    // -- blocked matmul: full grad at the paper's model size ---------
    let manifest = Manifest::builtin();
    let meta = manifest.model("mnist_mlp").expect("builtin mnist_mlp");
    let params = ParamVector::init(meta, 7);
    let mut rng = Rng::new(9);
    let batch = 32usize;
    let d: usize = meta.input.iter().product();
    let x: Vec<f32> = (0..batch * d).map(|_| rng.normal_f32(1.0).max(0.0)).collect();
    let y: Vec<i32> = (0..batch).map(|_| rng.below(meta.classes as u64) as i32).collect();
    let mut ws = Workspace::new();
    let mut grads = Vec::new();
    for (label, use_simd) in [("simd", true), ("scalar", false)] {
        let mut be = NativeBackend::new(meta).unwrap();
        be.set_simd(use_simd);
        b.bench_throughput(&format!("matmul/grad159k_b32/{label}"), n as u64, || {
            black_box(be.grad_into(&params, &x, &y, &mut ws, &mut grads).unwrap());
        });
    }

    // -- backward-input kernel: gather vs scalar sweep ---------------
    // (the mnist_mlp hidden layer, the only backward-input site in the
    // 159k model: dprev = delta · Wᵀ at d_in=200, d_out=10, batch 32 —
    // the stride-d_out gather branch vs the scalar per-cell dots)
    {
        let (d_in, d_out) = (200usize, 10usize);
        let mut rng = Rng::new(0xb1);
        let a_prev: Vec<f32> = (0..batch * d_in)
            .map(|_| rng.normal_f32(1.0).max(0.0)) // ~half dead, ReLU-like
            .collect();
        let delta: Vec<f32> = (0..batch * d_out).map(|_| rng.normal_f32(0.1)).collect();
        let w: Vec<f32> = (0..d_in * d_out).map(|_| rng.normal_f32(0.05)).collect();
        let mut dprev = vec![0f32; batch * d_in];
        for (label, use_simd) in [("simd", true), ("scalar", false)] {
            b.bench_throughput(
                &format!("backward_input/grad159k/{label}"),
                (batch * d_in * d_out) as u64,
                || {
                    fedsparse::runtime::bench_dense_backward_input(
                        &a_prev, &delta, &w, &mut dprev, batch, d_in, d_out, use_simd,
                    );
                    black_box(&dprev);
                },
            );
        }
    }

    // -- ChaCha keystream: quad-block vs single-block dispatch -------
    let key = [0x42u8; 32];
    for (label, quad) in [("quad", true), ("scalar", false)] {
        b.bench_throughput(&format!("chacha_blocks/159k_lanes/{label}"), n as u64, || {
            let mut prg = ChaCha20::from_seed(&key, 3);
            prg.set_quad_blocks(quad);
            let mut acc = 0u32;
            prg.for_each_uniform_f32(n, |_, lane| acc = acc.wrapping_add(lane));
            black_box(acc);
        });
    }

    // -- σ-filter compress: one pair stream at round keep-ratios -----
    // (the SIMD/scalar filter branch follows FEDSPARSE_NO_SIMD; run
    // the bench under both env settings to compare)
    let peers = vec![(1, b"bench-pair-secret".to_vec())];
    let masker = PairwiseMasker::new(0, peers, MaskRange::default());
    let mut acc = Vec::new();
    let mut nz = Vec::new();
    for (label, k) in [("k1.0", 1.0f64), ("k0.2", 0.2)] {
        let sigma = masker.range.sigma(k, 10);
        b.bench_throughput(&format!("sigma_filter/pair159k/{label}"), n as u64, || {
            masker.sparse_combined_mask_into(5, n, sigma, &mut acc, &mut nz);
            black_box((&acc, &nz));
        });
    }

    b.finish();
}
