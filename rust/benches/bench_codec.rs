//! Sparse codec benchmarks (Eq. 6 wire path): encode / decode /
//! scatter-add / deflate, at Fig.1 sparsity rates over the MNIST-MLP
//! update size.

use fedsparse::sparse::codec::SparseVec;
use fedsparse::util::bench::{black_box, Bench};
use fedsparse::util::rng::Rng;

fn sparse_update(seed: u64, n: usize, s: f64) -> SparseVec {
    let mut rng = Rng::new(seed);
    let mut dense = vec![0f32; n];
    let k = (n as f64 * s) as usize;
    for _ in 0..k {
        let i = rng.below(n as u64) as usize;
        dense[i] = rng.normal_f32(0.05);
    }
    SparseVec::from_dense(&dense)
}

fn main() {
    let mut b = Bench::new("codec");
    let n = 159_010usize;

    for s in [0.1f64, 0.01, 0.001] {
        let sv = sparse_update(1, n, s);
        let nnz = sv.nnz() as u64;
        b.bench_throughput(&format!("encode/s{s}"), nnz, || {
            black_box(sv.encode());
        });
        let bytes = sv.encode();
        b.bench_throughput(&format!("decode/s{s}"), nnz, || {
            black_box(SparseVec::decode(&bytes).unwrap());
        });
        b.bench_throughput(&format!("encode_deflate/s{s}"), nnz, || {
            black_box(sv.encode_compressed());
        });
        let mut acc = vec![0f32; n];
        b.bench_throughput(&format!("scatter_add/s{s}"), nnz, || {
            sv.add_into(&mut acc);
            black_box(&acc);
        });
        println!(
            "codec/s{s}: nnz={} wire={}B paper={}B deflate={}B",
            sv.nnz(),
            bytes.len(),
            sv.paper_cost_bytes(),
            sv.encode_compressed().len()
        );
    }

    // dense baseline scatter for contrast
    let dense = sparse_update(2, n, 1.0);
    b.bench_throughput("from_dense/full", n as u64, || {
        black_box(SparseVec::from_dense(&dense.to_dense()));
    });

    b.finish();
}
