//! Sparse codec benchmarks (Eq. 6 wire path): encode / decode /
//! scatter-add / deflate, at Fig.1 sparsity rates over the MNIST-MLP
//! update size — plus the quantized-wire fast path (bitpacked v1
//! frame: SIMD vs scalar pack/unpack, pool-parallel decode+fold).

use std::sync::{Arc, Mutex};

use fedsparse::sparse::codec::{fold_f32_range, SparseVec};
use fedsparse::sparse::quant::{pack_codes_with, quantize, unpack_codes_with, QuantConfig};
use fedsparse::util::bench::{black_box, Bench};
use fedsparse::util::pool::ThreadPool;
use fedsparse::util::rng::Rng;

fn sparse_update(seed: u64, n: usize, s: f64) -> SparseVec {
    let mut rng = Rng::new(seed);
    let mut dense = vec![0f32; n];
    let k = (n as f64 * s) as usize;
    for _ in 0..k {
        let i = rng.below(n as u64) as usize;
        dense[i] = rng.normal_f32(0.05);
    }
    SparseVec::from_dense(&dense)
}

fn main() {
    let mut b = Bench::new("codec");
    let n = 159_010usize;

    for s in [0.1f64, 0.01, 0.001] {
        let sv = sparse_update(1, n, s);
        let nnz = sv.nnz() as u64;
        b.bench_throughput(&format!("encode/s{s}"), nnz, || {
            black_box(sv.encode());
        });
        let bytes = sv.encode();
        b.bench_throughput(&format!("decode/s{s}"), nnz, || {
            black_box(SparseVec::decode(&bytes).unwrap());
        });
        b.bench_throughput(&format!("encode_deflate/s{s}"), nnz, || {
            black_box(sv.encode_compressed());
        });
        let mut acc = vec![0f32; n];
        b.bench_throughput(&format!("scatter_add/s{s}"), nnz, || {
            sv.add_into(&mut acc);
            black_box(&acc);
        });
        println!(
            "codec/s{s}: nnz={} wire={}B paper={}B deflate={}B",
            sv.nnz(),
            bytes.len(),
            sv.paper_cost_bytes(),
            sv.encode_compressed().len()
        );
    }

    // dense baseline scatter for contrast
    let dense = sparse_update(2, n, 1.0);
    b.bench_throughput("from_dense/full", n as u64, || {
        black_box(SparseVec::from_dense(&dense.to_dense()));
    });

    // --- quantized wire fast path (ISSUE 8) -------------------------
    // 4-bit codes over a 10%-dense 159k-dim update: the SIMD bitpack
    // kernels vs their bitwise-identical scalar references
    let sv = sparse_update(3, n, 0.1);
    let mut qrng = Rng::new(4);
    let q = quantize(&sv, QuantConfig { bits: 4 }, &mut qrng);
    let nnz = q.nnz() as u64;
    let mut packed = Vec::new();
    for (name, simd) in [("pack_simd", true), ("pack_scalar", false)] {
        b.bench_throughput(&format!("quant159k/{name}"), nnz, || {
            pack_codes_with(&q.codes, q.bits, &mut packed, simd);
            black_box(&packed);
        });
    }
    pack_codes_with(&q.codes, q.bits, &mut packed, false);
    let mut codes = Vec::new();
    for (name, simd) in [("unpack_simd", true), ("unpack_scalar", false)] {
        b.bench_throughput(&format!("quant159k/{name}"), nnz, || {
            unpack_codes_with(&packed, nnz as usize, q.bits, &mut codes, simd).unwrap();
            black_box(&codes);
        });
    }
    let qframe = q.encode();
    println!(
        "codec/quant159k: nnz={} bits={} wire={}B f32_wire={}B",
        q.nnz(),
        q.bits,
        qframe.len(),
        sv.encode().len()
    );

    // pool-parallel fused decode+fold: 10 f32 payloads × 4 range
    // shards on a 4-worker pool, the Collect-phase hot loop
    let payloads: Arc<Vec<Vec<u8>>> =
        Arc::new((0..10).map(|i| sparse_update(10 + i, n, 0.1).encode()).collect());
    let pool = ThreadPool::new(4);
    let shards = 4usize;
    let starts: Vec<usize> = (0..=shards).map(|s| s * n / shards).collect();
    b.bench_throughput("decode_fold_parallel", nnz * 10, || {
        let tasks: Vec<Mutex<(u32, u32, Vec<f32>)>> = (0..shards)
            .map(|s| {
                Mutex::new((starts[s] as u32, starts[s + 1] as u32, vec![
                    0f32;
                    starts[s + 1] - starts[s]
                ]))
            })
            .collect();
        let p = Arc::clone(&payloads);
        let out = pool.map_shared(tasks, move |t: &Mutex<(u32, u32, Vec<f32>)>| {
            let t = &mut *t.lock().unwrap();
            let (start, end) = (t.0, t.1);
            for bytes in p.iter() {
                fold_f32_range(bytes, start, end, &mut t.2).unwrap();
            }
            std::mem::take(&mut t.2)
        });
        black_box(out);
    });

    b.finish();
}
