//! Native-backend correctness: finite-difference gradient checks
//! against the analytic backward pass, plus a fixed-seed golden run of
//! the full `mnist_mlp` round loop asserting the paper's headline
//! claims (train loss decreases; THGS upload lands inside the
//! 2.9%–18.9% band of the abstract, i.e. under 20% of dense FedAvg).

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::models::manifest::{InitKind, LayerGroup, ModelMeta, ParamSpec};
use fedsparse::models::params::ParamVector;
use fedsparse::runtime::{Backend, BackendKind, NativeBackend};
use fedsparse::sparse::thgs::ThgsConfig;
use fedsparse::util::rng::Rng;

/// A small 8→10→4 MLP whose full parameter vector is cheap to
/// finite-difference.
fn small_meta() -> ModelMeta {
    let w = |name: &str, shape: Vec<usize>, layer: usize| ParamSpec {
        name: name.into(),
        shape,
        init: InitKind::Normal { std: 0.35 },
        layer,
    };
    let b = |name: &str, d: usize, layer: usize| ParamSpec {
        name: name.into(),
        shape: vec![d],
        init: InitKind::Zeros,
        layer,
    };
    ModelMeta {
        name: "small_mlp".into(),
        input: vec![8],
        classes: 4,
        params: vec![
            w("l0/w", vec![8, 10], 0),
            b("l0/b", 10, 0),
            w("l1/w", vec![10, 4], 1),
            b("l1/b", 4, 1),
        ],
        layers: vec![
            LayerGroup { name: "l0".into(), params: vec![0, 1] },
            LayerGroup { name: "l1".into(), params: vec![2, 3] },
        ],
        param_count: 8 * 10 + 10 + 10 * 4 + 4,
        grad_artifact: String::new(),
        eval_artifact: String::new(),
    }
}

fn random_batch(d: usize, classes: usize, b: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal_f32(1.0)).collect();
    let y: Vec<i32> = (0..b).map(|_| rng.below(classes as u64) as i32).collect();
    (x, y)
}

#[test]
fn analytic_gradient_matches_finite_difference() {
    let meta = small_meta();
    let be = NativeBackend::new(&meta).unwrap();
    let mut params = ParamVector::init(&meta, 17);
    let (x, y) = random_batch(8, 4, 16, 23);

    let (_, analytic) = be.grad(&params, &x, &y).unwrap();
    assert_eq!(analytic.len(), meta.total_params());

    // central differences over EVERY parameter; loss is O(1) and f32,
    // so eps must stay well above the f32 noise floor. Individual
    // coordinates can wobble when a ReLU pre-activation straddles the
    // kink inside ±eps, so the per-coordinate bound is loose and the
    // sharp assertion is the global relative error (which any
    // systematic backward-pass bug — transposition, sign, off-by-one
    // layer — blows up by orders of magnitude).
    let eps = 5e-3f32;
    let mut err2 = 0f64;
    let mut norm2 = 0f64;
    for i in 0..params.len() {
        let orig = params.data[i];
        params.data[i] = orig + eps;
        let (lp, _) = be.grad(&params, &x, &y).unwrap();
        params.data[i] = orig - eps;
        let (lm, _) = be.grad(&params, &x, &y).unwrap();
        params.data[i] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        let an = analytic[i];
        err2 += ((fd - an) as f64).powi(2);
        norm2 += (an as f64).powi(2);
        assert!(
            (fd - an).abs() < 1e-2 + 0.1 * an.abs(),
            "param {i}: finite-diff {fd} vs analytic {an}"
        );
    }
    let rel = (err2 / norm2.max(1e-30)).sqrt();
    assert!(rel < 0.05, "global finite-diff relative error {rel}");
}

#[test]
fn gradient_check_holds_after_training() {
    // re-check at a non-random point: gradcheck at init can pass by
    // luck when everything is near-symmetric
    let meta = small_meta();
    let be = NativeBackend::new(&meta).unwrap();
    let mut params = ParamVector::init(&meta, 29);
    let (x, y) = random_batch(8, 4, 16, 31);
    for _ in 0..25 {
        let (_, g) = be.grad(&params, &x, &y).unwrap();
        params.sgd_step(&g, 0.3);
    }
    let (_, analytic) = be.grad(&params, &x, &y).unwrap();
    let eps = 5e-3f32;
    let mut rng = Rng::new(7);
    for _ in 0..40 {
        let i = rng.below(params.len() as u64) as usize;
        let orig = params.data[i];
        params.data[i] = orig + eps;
        let (lp, _) = be.grad(&params, &x, &y).unwrap();
        params.data[i] = orig - eps;
        let (lm, _) = be.grad(&params, &x, &y).unwrap();
        params.data[i] = orig;
        let fd = (lp - lm) / (2.0 * eps);
        assert!(
            (fd - analytic[i]).abs() < 1e-2 + 0.1 * analytic[i].abs(),
            "param {i}: finite-diff {fd} vs analytic {}",
            analytic[i]
        );
    }
}

#[test]
fn mnist_mlp_untrained_accuracy_is_chance() {
    let cfg = {
        let mut c = RunConfig::smoke("mnist_mlp");
        c.backend = BackendKind::Native;
        c.data_dir = None;
        c
    };
    let trainer = Trainer::new(cfg).unwrap();
    let (loss, acc) = trainer.evaluate().unwrap();
    assert!(loss > 0.0);
    // 10 classes, random init ⇒ ≈ 10% ± noise
    assert!((0.0..=0.35).contains(&acc), "untrained acc {acc}");
}

/// The golden e2e test: fixed seed, 3 THGS rounds on `mnist_mlp`
/// (159,010 params from the builtin manifest), native backend only.
#[test]
fn golden_three_rounds_thgs_loss_and_upload() {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.seed = 42;
    cfg.rounds = 3;
    cfg.eval_every = 3;
    cfg.local_iters = 3;
    cfg.algorithm = Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 });
    let mut trainer = Trainer::new(cfg).unwrap();
    assert_eq!(trainer.backend_name(), "native");
    assert_eq!(trainer.model_params(), 159_010);

    let mut losses = Vec::new();
    for round in 0..3 {
        let out = trainer.run_round(round).unwrap();
        assert!(out.mean_train_loss.is_finite());
        losses.push(out.mean_train_loss);
    }
    // train loss strictly decreases over the first rounds
    assert!(
        losses[1] < losses[0] && losses[2] < losses[1],
        "loss not strictly decreasing: {losses:?}"
    );

    // THGS upload (paper Eq. 6 cost model) under 20% of the dense
    // FedAvg baseline — the band the abstract claims (2.9%–18.9%)
    let summary = trainer.recorder.summary();
    let m = trainer.model_params();
    let dense_baseline: u64 = summary.rounds
        * trainer.cfg.clients_per_round as u64
        * fedsparse::sparse::codec::dense_cost_bytes(m);
    let ratio = summary.total_up_bytes as f64 / dense_baseline as f64;
    assert!(
        ratio < 0.20,
        "THGS upload {} of dense {} = {ratio:.3}, outside the paper band",
        summary.total_up_bytes,
        dense_baseline
    );
    assert!(ratio > 0.0, "no upload recorded");
}

#[test]
fn golden_run_reproduces_bitwise_per_seed() {
    let run = || {
        let mut cfg = RunConfig::smoke("mnist_mlp");
        cfg.backend = BackendKind::Native;
        cfg.data_dir = None;
        cfg.seed = 1234;
        cfg.rounds = 2;
        cfg.eval_every = 99;
        let mut t = Trainer::new(cfg).unwrap();
        t.run().unwrap();
        t.global.data.clone()
    };
    let a = run();
    let b = run();
    assert!(
        a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()),
        "native runs diverged for the same seed"
    );
}
