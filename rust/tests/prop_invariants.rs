//! Property-based invariants over the coordinator's pure substrates
//! (DESIGN.md deliverable (c): proptest-style coverage of routing,
//! batching and state invariants via the in-repo prop framework).

use fedsparse::secagg::mask::{MaskRange, PairwiseMasker};
use fedsparse::sparse::codec::SparseVec;
use fedsparse::sparse::dynamic::DynamicRate;
use fedsparse::sparse::flat::flat_topk_sparsify;
use fedsparse::sparse::thgs::{thgs_sparsify, ThgsConfig};
use fedsparse::sparse::topk::threshold_for_topk_abs;
use fedsparse::util::prop::{f32_in, forall, usize_in, vec_f32, Pair};
use fedsparse::util::rng::Rng;

#[test]
fn prop_sparse_plus_residual_reconstructs() {
    forall(
        "sparse+residual == g (flat)",
        300,
        Pair(vec_f32(1..=4096, 5.0), f32_in(0.001, 1.0)),
        |(g, s)| {
            let out = flat_topk_sparsify(g, *s as f64);
            g.iter()
                .enumerate()
                .all(|(i, &x)| out.sparse[i] + out.residual[i] == x
                    && (out.sparse[i] == 0.0 || out.residual[i] == 0.0))
        },
    );
}

#[test]
fn prop_flat_nnz_at_most_k() {
    forall(
        "flat nnz ≤ ⌈s·n⌉",
        300,
        Pair(vec_f32(1..=2048, 3.0), f32_in(0.001, 1.0)),
        |(g, s)| {
            let out = flat_topk_sparsify(g, *s as f64);
            out.nnz <= ((g.len() as f64 * *s as f64).ceil() as usize).max(1)
        },
    );
}

#[test]
fn prop_topk_threshold_partitions() {
    forall(
        "∣{|g| > δ}∣ ≤ k ≤ ∣{|g| ≥ δ}∣",
        300,
        Pair(vec_f32(1..=1024, 2.0), usize_in(1..=1024)),
        |(g, k)| {
            let k = (*k).min(g.len()).max(1);
            let d = threshold_for_topk_abs(g, k);
            let gt = g.iter().filter(|x| x.abs() > d).count();
            let ge = g.iter().filter(|x| x.abs() >= d).count();
            gt <= k && k <= ge
        },
    );
}

#[test]
fn prop_thgs_respects_span_boundaries() {
    forall(
        "thgs residual split per layer",
        150,
        Pair(vec_f32(64..=2048, 2.0), usize_in(1..=6)),
        |(g, n_layers)| {
            // build spans: n_layers ~equal chunks
            let n = g.len();
            let nl = (*n_layers).min(n);
            let base = n / nl;
            let mut spans = Vec::new();
            let mut start = 0;
            for i in 0..nl {
                let len = if i == nl - 1 { n - start } else { base };
                spans.push((start, len));
                start += len;
            }
            let cfg = ThgsConfig { s0: 0.1, alpha: 0.7, s_min: 0.02 };
            let out = thgs_sparsify(g, &spans, &cfg);
            // exact split + every span sends ≥1 entry when it has a
            // strict-max element (ties may drop all; allow ≥0 but check
            // totals)
            g.iter()
                .enumerate()
                .all(|(i, &x)| out.sparse[i] + out.residual[i] == x)
                && out.thresholds.len() == nl
        },
    );
}

#[test]
fn prop_codec_roundtrip() {
    forall(
        "SparseVec encode/decode identity",
        200,
        Pair(vec_f32(1..=4096, 1.0), f32_in(0.0, 0.9)),
        |(dense, zero_frac)| {
            // zero out a fraction to get realistic sparsity
            let mut v = dense.clone();
            let cut = (v.len() as f32 * zero_frac) as usize;
            for x in v.iter_mut().take(cut) {
                *x = 0.0;
            }
            let sv = SparseVec::from_dense(&v);
            let plain = SparseVec::decode(&sv.encode()) == Ok(sv.clone());
            let compressed = SparseVec::decode_compressed(&sv.encode_compressed()) == Ok(sv.clone());
            let dense_rt = sv.to_dense() == v;
            plain && compressed && dense_rt
        },
    );
}

#[test]
fn prop_codec_wire_cheaper_than_paper_model() {
    forall(
        "wire bytes < paper 96-bit model (nnz > 8)",
        100,
        vec_f32(64..=8192, 1.0),
        |dense| {
            let sv = SparseVec::from_dense(dense);
            sv.nnz() <= 8 || (sv.encode().len() as u64) <= sv.paper_cost_bytes()
        },
    );
}

#[test]
fn prop_dynamic_rate_always_clamped() {
    forall(
        "Eq.2 rate ∈ [R_min, 1]",
        200,
        Pair(vec_f32(2..=40, 3.0), f32_in(0.05, 1.5)),
        |(losses, alpha)| {
            let mut c = DynamicRate::new(0.5, *alpha as f64, 100, 0.01);
            losses.iter().enumerate().all(|(t, &l)| {
                let r = c.observe(t as u64, (l.abs() + 0.01) as f64);
                (0.01..=1.0).contains(&r)
            })
        },
    );
}

#[test]
fn prop_pairwise_masks_cancel() {
    forall(
        "Σ signed pair masks == 0",
        40,
        Pair(usize_in(2..=6), usize_in(64..=1024)),
        |(fleet_size, n)| {
            let secret = |a: u32, b: u32| {
                let (lo, hi) = if a < b { (a, b) } else { (b, a) };
                format!("p{lo}:{hi}").into_bytes()
            };
            let fleet: Vec<PairwiseMasker> = (0..*fleet_size as u32)
                .map(|id| {
                    let peers = (0..*fleet_size as u32)
                        .filter(|&p| p != id)
                        .map(|p| (p, secret(id, p)))
                        .collect();
                    PairwiseMasker::new(id, peers, MaskRange::default())
                })
                .collect();
            let mut sum = vec![0f64; *n];
            for c in &fleet {
                for (i, v) in c.combined_mask(3, *n).iter().enumerate() {
                    sum[i] += *v as f64;
                }
            }
            sum.iter().all(|s| s.abs() < 1e-3)
        },
    );
}

#[test]
fn prop_residual_mass_conservation() {
    // multi-round residual accumulation never loses update mass
    forall(
        "Σ shipped + residual == Σ raw",
        30,
        Pair(usize_in(16..=512), usize_in(2..=10)),
        |(n, rounds)| {
            let mut rng = Rng::new((*n * 31 + *rounds) as u64);
            let mut store = fedsparse::sparse::residual::ResidualStore::new(*n);
            let mut shipped = vec![0f64; *n];
            let mut raw = vec![0f64; *n];
            for _ in 0..*rounds {
                let mut u: Vec<f32> = (0..*n).map(|_| rng.normal_f32(1.0)).collect();
                for i in 0..*n {
                    raw[i] += u[i] as f64;
                }
                store.fold_into(&mut u);
                let out = flat_topk_sparsify(&u, 0.1);
                for i in 0..*n {
                    shipped[i] += out.sparse[i] as f64;
                }
                store.store(&out.residual);
            }
            (0..*n).all(|i| {
                (shipped[i] + store.as_slice()[i] as f64 - raw[i]).abs() < 1e-3
            })
        },
    );
}

#[test]
fn prop_selection_valid() {
    forall(
        "selection distinct, sorted, in range",
        200,
        Pair(usize_in(2..=200), usize_in(0..=10_000)),
        |(n, round)| {
            let k = (*n / 2).max(1);
            let sel = fedsparse::coordinator::selection::select_clients(*n, k, 7, *round as u64);
            sel.len() == k
                && sel.windows(2).all(|w| w[0] < w[1])
                && sel.iter().all(|&c| (c as usize) < *n)
        },
    );
}

#[test]
fn prop_shamir_roundtrip() {
    forall(
        "shamir reconstruct == secret",
        100,
        Pair(usize_in(1..=6), usize_in(0..=1_000_000)),
        |(t, secret)| {
            let n = t + 2;
            let mut rng = Rng::new((*secret + 7) as u64);
            let shares =
                fedsparse::secagg::shamir::split(*secret as u64, n, *t, &mut rng);
            fedsparse::secagg::shamir::reconstruct(&shares[..*t]) == *secret as u64
        },
    );
}
