//! Secure-aggregation protocol end-to-end tests, including the §4
//! safety-analysis case census, the dropout-recovery extension, a
//! full-size (RFC 3526) DH exchange, and full `Trainer` runs over the
//! native backend with mask-sparsified secure aggregation enabled.

use std::collections::HashMap;

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::runtime::BackendKind;
use fedsparse::secagg::mask::MaskRange;
use fedsparse::secagg::protocol::{full_setup, SecAggConfig};
use fedsparse::secagg::shamir::Share;
use fedsparse::sparse::topk::threshold_for_topk_abs;
use fedsparse::util::rng::Rng;

fn keep_top(g: &[f32], frac: f64) -> Vec<bool> {
    let k = ((g.len() as f64 * frac).ceil() as usize).max(1);
    let d = threshold_for_topk_abs(g, k);
    g.iter().map(|v| v.abs() > d).collect()
}

/// Multi-round secure training traffic: masks must cancel every round
/// and the per-round mask streams must differ (no mask reuse).
#[test]
fn masks_cancel_across_rounds_without_reuse() {
    let cfg = SecAggConfig { share_keys: false, ..Default::default() };
    let (clients, server) = full_setup(5, 3, &cfg);
    let n = 5000;
    let mut rng = Rng::new(4);
    let mut prev_payload: Option<Vec<f32>> = None;

    for round in 0..4u64 {
        let mut payloads = Vec::new();
        let mut expect = vec![0f64; n];
        for c in &clients {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.05)).collect();
            let keep = keep_top(&g, 0.02);
            let u = c.build_update(&g, &keep, round, clients.len());
            for j in 0..n {
                expect[j] += (g[j] - u.residual[j]) as f64;
            }
            payloads.push((c.id, u.payload));
        }
        let agg = server.aggregate(n, round, &payloads, &[], &HashMap::new());
        for j in 0..n {
            assert!((agg[j] as f64 - expect[j]).abs() < 3e-3, "round {round} pos {j}");
        }
        // same client's masked payload must change across rounds
        let dense0 = payloads[0].1.to_dense();
        if let Some(prev) = prev_payload.replace(dense0.clone()) {
            assert_ne!(prev, dense0, "mask stream reused across rounds");
        }
    }
}

/// §4 case census: with mask ratio k, the expected fraction of pure
/// mask positions matches Eq. 4, and exposure (case 1) shrinks as the
/// mask ratio grows.
#[test]
fn case_census_matches_eq4() {
    let n = 60_000;
    let x = 4usize;
    for k in [0.4f64, 1.0, 2.0] {
        let cfg = SecAggConfig { mask_ratio_k: k, share_keys: false, ..Default::default() };
        let (clients, _) = full_setup(x as u32, 5, &cfg);
        let g: Vec<f32> = {
            let mut rng = Rng::new(6);
            (0..n).map(|_| rng.normal_f32(1.0)).collect()
        };
        let keep = vec![false; n]; // isolate the mask channel
        let u = clients[0].build_update(&g, &keep, 1, x);
        // P(any of 3 pair masks nonzero) = 1 − (1 − k/x)^3
        let p = 1.0 - (1.0 - k / x as f64).powi(3);
        let got = u.census.case2_mask_only as f64 / n as f64;
        assert!(
            (got - p).abs() < 0.02,
            "k={k}: mask fraction {got:.3} vs expected {p:.3}"
        );
    }
}

#[test]
fn exposure_shrinks_with_mask_ratio() {
    let n = 40_000;
    let g: Vec<f32> = {
        let mut rng = Rng::new(7);
        (0..n).map(|_| rng.normal_f32(1.0)).collect()
    };
    let keep = keep_top(&g, 0.01);
    let mut exposures = Vec::new();
    for k in [0.25f64, 1.0, 3.0] {
        let cfg = SecAggConfig { mask_ratio_k: k, share_keys: false, ..Default::default() };
        let (clients, _) = full_setup(4, 8, &cfg);
        let u = clients[0].build_update(&g, &keep, 0, 4);
        exposures.push(u.census.exposure_rate());
    }
    assert!(exposures[0] > exposures[1] && exposures[1] > exposures[2], "{exposures:?}");
}

/// Dropout mid-round: Shamir recovery de-orphans the masks.
#[test]
fn dropout_recovery_full_protocol() {
    let cfg = SecAggConfig { share_threshold: 3, ..Default::default() };
    let (clients, server) = full_setup(5, 9, &cfg);
    let n = 3000;
    let mut rng = Rng::new(10);

    let mut updates = Vec::new();
    for c in &clients {
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.05)).collect();
        let keep = keep_top(&g, 0.02);
        let u = c.build_update(&g, &keep, 1, clients.len());
        updates.push((c.id, g, u));
    }
    let dropped = 4u32;
    let mut payloads = Vec::new();
    let mut expect = vec![0f64; n];
    for (id, g, u) in &updates {
        if *id == dropped {
            continue;
        }
        for j in 0..n {
            expect[j] += (g[j] - u.residual[j]) as f64;
        }
        payloads.push((*id, u.payload.clone()));
    }

    let mut recovered = HashMap::new();
    for (v, _, _) in updates.iter().filter(|(id, _, _)| *id != dropped) {
        let pair = if *v < dropped { (*v, dropped) } else { (dropped, *v) };
        let share_sets: Vec<Vec<Share>> = clients
            .iter()
            .filter(|c| c.id != dropped)
            .filter_map(|c| c.shares_for(pair.0, pair.1).cloned())
            .take(cfg.share_threshold)
            .collect();
        assert!(share_sets.len() >= cfg.share_threshold);
        recovered.insert((*v, dropped), server.reconstruct_pair_key(&share_sets));
    }
    let agg = server.aggregate(n, 1, &payloads, &[dropped], &recovered);
    for j in 0..n {
        assert!((agg[j] as f64 - expect[j]).abs() < 3e-3, "pos {j}");
    }
}

/// The real 1536-bit MODP group works end-to-end (slower; small fleet).
#[test]
fn full_dh_group_small_fleet() {
    let cfg = SecAggConfig { full_dh: true, share_keys: false, ..Default::default() };
    let (clients, server) = full_setup(3, 11, &cfg);
    let n = 1000;
    let mut rng = Rng::new(12);
    let mut payloads = Vec::new();
    let mut expect = vec![0f64; n];
    for c in &clients {
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.05)).collect();
        let keep = keep_top(&g, 0.05);
        let u = c.build_update(&g, &keep, 0, 3);
        for j in 0..n {
            expect[j] += (g[j] - u.residual[j]) as f64;
        }
        payloads.push((c.id, u.payload));
    }
    let agg = server.aggregate(n, 0, &payloads, &[], &HashMap::new());
    for j in 0..n {
        assert!((agg[j] as f64 - expect[j]).abs() < 2e-3);
    }
}

/// Paper §3.2 condition 2: masked-sparse upload is far below the dense
/// secure-aggregation baseline.
#[test]
fn masked_sparse_beats_dense_secagg_cost() {
    let cfg = SecAggConfig { mask_ratio_k: 1.0, share_keys: false, ..Default::default() };
    let (clients, _) = full_setup(10, 13, &cfg);
    let n = 159_010; // mnist_mlp size
    let mut rng = Rng::new(14);
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.02)).collect();
    let keep = keep_top(&g, 0.01);
    let u = clients[0].build_update(&g, &keep, 0, 10);
    let sparse_cost = u.payload.paper_cost_bytes();
    let dense_cost = fedsparse::sparse::codec::dense_cost_bytes(n);
    let ratio = sparse_cost as f64 / dense_cost as f64;
    // grad 1% + mask ~1/10 per pair over 9 pairs ≈ up to ~60% worst
    // case; with k=1, x=10 → pair keep 0.1, union over 9 pairs ≈ 0.61.
    // The paper's regime uses smaller k/x; just assert strictly below dense.
    assert!(ratio < 1.0, "ratio {ratio}");

    // and with the paper-ish k=0.2 the ratio drops well below
    let cfg2 = SecAggConfig { mask_ratio_k: 0.2, share_keys: false, ..Default::default() };
    let (clients2, _) = full_setup(10, 15, &cfg2);
    let u2 = clients2[0].build_update(&g, &keep, 0, 10);
    let ratio2 = u2.payload.paper_cost_bytes() as f64 / dense_cost as f64;
    assert!(ratio2 < 0.4, "ratio2 {ratio2}");
    assert!(ratio2 < ratio);
}

fn secure_trainer_cfg() -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.secure = true;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg
}

/// Full `Trainer` run, secure aggregation on, native backend:
/// with `mask_ratio_k = 0` the σ filter keeps no mask positions
/// (Eq. 4: σ = p), so every transmitted value is the plaintext sparse
/// gradient — and the secure aggregate must equal the plaintext
/// aggregate of an identical non-secure run **bit for bit** (same
/// payload values, same summation order). This is the exact-equality
/// anchor; active masks can only cancel to f32 rounding (next test),
/// since `(g₁+m) + (g₂−m)` rounds at each f32 add.
#[test]
fn secure_trainer_aggregate_equals_plaintext_bitwise() {
    let run = |secure: bool| {
        let mut cfg = secure_trainer_cfg();
        cfg.secure = secure;
        cfg.mask_ratio_k = 0.0;
        cfg.rounds = 2;
        cfg.eval_every = 99;
        cfg.expose_aggregate = true; // this test asserts on the sums
        let mut t = Trainer::new(cfg).unwrap();
        let mut aggs = Vec::new();
        for r in 0..2 {
            aggs.push(t.run_round(r).unwrap().aggregate);
        }
        (aggs, t.global.data.clone())
    };
    let (agg_plain, global_plain) = run(false);
    let (agg_sec, global_sec) = run(true);
    for (round, (a, b)) in agg_plain.iter().zip(&agg_sec).enumerate() {
        assert_eq!(a.len(), b.len());
        let diff = a
            .iter()
            .zip(b)
            .filter(|(x, y)| x.to_bits() != y.to_bits())
            .count();
        assert_eq!(diff, 0, "round {round}: {diff} positions differ bitwise");
    }
    assert!(
        global_plain
            .iter()
            .zip(&global_sec)
            .all(|(x, y)| x.to_bits() == y.to_bits()),
        "global models diverged"
    );
}

/// Full multi-round `Trainer` run with ACTIVE pair masks (k = 0.5):
/// the audited plaintext sum and the masked aggregate must agree to
/// f32 mask-cancellation rounding at every position, every round —
/// i.e. the server learns the sum and nothing else survives.
#[test]
fn secure_trainer_masks_cancel_every_round() {
    let mut cfg = secure_trainer_cfg();
    cfg.mask_ratio_k = 0.5;
    cfg.audit_secure_sum = true;
    cfg.expose_aggregate = true;
    cfg.rounds = 3;
    cfg.eval_every = 99;
    let mut trainer = Trainer::new(cfg).unwrap();
    let mut losses = Vec::new();
    for round in 0..3 {
        let out = trainer.run_round(round).unwrap();
        let plain = out.plain_sum.as_ref().expect("audit enabled");
        let max_err = out
            .aggregate
            .iter()
            .zip(plain)
            .map(|(&a, &p)| (a as f64 - p).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err < 5e-3, "round {round}: mask residue {max_err}");
        // the masks are not degenerate: some mask-only positions ship
        let m = trainer.model_params();
        assert!(out.nnz.iter().all(|&n| n > 0 && n < m), "nnz {:?}", out.nnz);
        losses.push(out.mean_train_loss);
    }
    // and the secure path still trains
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "secure training made no progress: {losses:?}"
    );
}

/// Tentpole e2e: full secure `Trainer` run with transport failure
/// injection. Every round of this seeded configuration loses 1–3 of
/// the 6 selected clients mid-round (after they built their pair
/// masks), so the engine's Unmask/Recover phase must Shamir-
/// reconstruct the dead clients' pair keys and cancel their orphaned
/// masks — and the recovered aggregate must still match the
/// *survivors'* audited plaintext sum at every position.
#[test]
fn secure_trainer_recovers_dropped_clients() {
    let mut cfg = secure_trainer_cfg();
    cfg.clients = 8;
    cfg.clients_per_round = 6;
    cfg.mask_ratio_k = 0.5;
    cfg.audit_secure_sum = true;
    cfg.expose_aggregate = true;
    cfg.dropout_prob = 0.25;
    cfg.min_survivors = 2;
    cfg.rounds = 4;
    cfg.eval_every = 99;
    let mut trainer = Trainer::new(cfg).unwrap();
    let mut saw_dropout = false;
    let mut losses = Vec::new();
    for round in 0..4 {
        let out = trainer.run_round(round).unwrap();
        assert!(!out.aborted, "round {round} aborted unexpectedly");
        let dead = out.dropped.len() + out.stragglers.len();
        assert_eq!(
            out.survivors.len() + dead,
            out.selected.len(),
            "round {round}: selected set must partition into survivors + dead"
        );
        assert_eq!(out.nnz.len(), out.survivors.len());
        if dead > 0 {
            saw_dropout = true;
            // one recovered pair key per (survivor, dead) pair
            assert_eq!(out.recovered_pairs, dead * out.survivors.len(), "round {round}");
        }
        let plain = out.plain_sum.as_ref().expect("audit enabled");
        let max_err = out
            .aggregate
            .iter()
            .zip(plain)
            .map(|(&a, &p)| (a as f64 - p).abs())
            .fold(0.0f64, f64::max);
        assert!(
            max_err < 5e-3,
            "round {round}: mask residue {max_err} with {dead} dead clients"
        );
        losses.push(out.mean_train_loss);
    }
    // this seed drops clients in every round (verified against the
    // deterministic FailurePlan draws) — the assertion guards against
    // silently testing the failure-free path
    assert!(saw_dropout, "seed 42 must produce dropouts");
    // and training still makes progress on the survivor cohorts
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "dropout-recovered training made no progress: {losses:?}"
    );
    // only delivered rounds count toward participation
    let total_participation: u64 = trainer.clients.iter().map(|c| c.participation).sum();
    let total_survivors: u64 = trainer.recorder.rows.iter().map(|r| r.survivors as u64).sum();
    assert_eq!(total_participation, total_survivors);
}

/// Negative test: when dropout leaves fewer than `min_survivors`
/// uploads, the round aborts cleanly — global model untouched, every
/// client rolled back, no aggregate — instead of applying a
/// mask-corrupted or under-represented update.
#[test]
fn round_aborts_below_min_survivors() {
    let mut cfg = secure_trainer_cfg();
    cfg.expose_aggregate = true; // aborted rounds must still yield none
    cfg.dropout_prob = 0.95; // this seed: all 4 selected clients crash
    cfg.min_survivors = cfg.clients_per_round;
    cfg.rounds = 1;
    cfg.eval_every = 99;
    let mut trainer = Trainer::new(cfg).unwrap();
    let global_before = trainer.global.data.clone();

    let out = trainer.run_round(0).unwrap();
    assert!(out.aborted, "expected an aborted round");
    assert!(out.survivors.len() < trainer.cfg.min_survivors);
    assert!(out.aggregate.is_empty(), "aborted rounds produce no aggregate");
    assert!(out.eval.is_none());
    assert!(
        trainer
            .global
            .data
            .iter()
            .zip(&global_before)
            .all(|(a, b)| a.to_bits() == b.to_bits()),
        "aborted round must not touch the global model"
    );
    assert!(
        trainer.clients.iter().all(|c| c.participation == 0),
        "aborted round must not count as participation"
    );
    // the round is still recorded (one row per round, accuracy NaN)
    assert_eq!(trainer.recorder.rows.len(), 1);
    assert!(trainer.recorder.rows[0].eval_accuracy.is_nan());
    assert_eq!(trainer.recorder.rows[0].survivors, out.survivors.len());
}

/// Mask range sigma arithmetic (Eq. 4) at protocol level.
#[test]
fn sigma_boundaries() {
    let r = MaskRange { p: -10.0, q: 20.0 };
    assert_eq!(r.sigma(0.0, 10), -10.0); // keep nothing
    assert_eq!(r.sigma(10.0, 10), 10.0); // keep everything
    let mid = r.sigma(5.0, 10.0 as usize);
    assert!((mid - 0.0).abs() < 1e-6);
}
