//! End-to-end integration: the full federated round loop — local SGD,
//! sparsification, (secure) aggregation, eval — on the **native**
//! backend, unconditionally. No Python, JAX, or PJRT artifacts are
//! needed; `Trainer::new` falls back to the builtin manifest when
//! `artifacts/manifest.json` is absent.
//!
//! The artifact-dependent checks (manifest parity with the AOT export,
//! grad/eval HLO behavior, conv models) live in [`pjrt`] and only
//! compile under the `pjrt` feature.

use fedsparse::config::{Partition, RunConfig};
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::runtime::BackendKind;
use fedsparse::sparse::thgs::ThgsConfig;

/// Small native-backend run config: synthetic corpus, builtin manifest
/// fallback, deterministic.
fn native_cfg(model: &str) -> RunConfig {
    let mut cfg = RunConfig::smoke(model);
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg
}

#[test]
fn trainer_builds_without_artifacts() {
    // the round loop must come up on a machine that never ran
    // `make artifacts` — this is the PR's core acceptance criterion
    let mut cfg = native_cfg("mnist_mlp");
    cfg.backend = BackendKind::Auto;
    cfg.artifacts_dir = "/definitely/no/artifacts/here".into();
    let trainer = Trainer::new(cfg).unwrap();
    assert_eq!(trainer.backend_name(), "native");
    assert_eq!(trainer.model_params(), 159_010);
}

#[test]
fn federated_training_learns_thgs() {
    let mut cfg = native_cfg("mnist_mlp");
    cfg.rounds = 20;
    cfg.eval_every = 20;
    cfg.algorithm = Algorithm::Thgs(ThgsConfig { s0: 0.2, alpha: 0.8, s_min: 0.05 });
    let mut trainer = Trainer::new(cfg).unwrap();
    let (_, acc0) = trainer.evaluate().unwrap();
    let summary = trainer.run().unwrap();
    assert!(
        summary.final_accuracy > acc0 + 0.15,
        "no learning: {acc0} → {}",
        summary.final_accuracy
    );
    // sparse upload must be far below dense
    let m = trainer.model_params() as u64;
    let dense_total = summary.rounds * 4 * m * 8; // 4 clients/round × 64bit
    assert!(summary.total_up_bytes < dense_total / 2);
}

#[test]
fn federated_training_learns_secure() {
    let mut cfg = native_cfg("mnist_mlp");
    cfg.rounds = 12;
    cfg.eval_every = 12;
    cfg.secure = true;
    cfg.mask_ratio_k = 0.5;
    cfg.algorithm = Algorithm::Thgs(ThgsConfig { s0: 0.2, alpha: 0.8, s_min: 0.05 });
    let mut trainer = Trainer::new(cfg).unwrap();
    let (_, acc0) = trainer.evaluate().unwrap();
    let summary = trainer.run().unwrap();
    assert!(
        summary.final_accuracy > acc0 + 0.15,
        "secure path broke learning: {acc0} → {}",
        summary.final_accuracy
    );
}

#[test]
fn secure_equals_plain_aggregation_in_expectation() {
    // One round, same seed: the secure aggregate must equal the plain
    // sparse aggregate PLUS the mask-rider positions — so the global
    // models stay close (not identical: mask-only positions ship their
    // gradient component too, which plain sparsification residualizes).
    let mk = |secure: bool| {
        let mut cfg = native_cfg("mnist_mlp");
        cfg.rounds = 1;
        cfg.eval_every = 1;
        cfg.secure = secure;
        cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
        let mut t = Trainer::new(cfg).unwrap();
        t.run_round(0).unwrap();
        t.global.data.clone()
    };
    let plain = mk(false);
    let secure = mk(true);
    let dot: f64 = plain.iter().zip(&secure).map(|(&a, &b)| a as f64 * b as f64).sum();
    let na: f64 = plain.iter().map(|&a| (a as f64).powi(2)).sum::<f64>().sqrt();
    let nb: f64 = secure.iter().map(|&b| (b as f64).powi(2)).sum::<f64>().sqrt();
    let cos = dot / (na * nb);
    assert!(cos > 0.99, "secure/plain cosine {cos}");
}

#[test]
fn quantized_wire_shrinks_uplink_at_equal_nnz() {
    // ISSUE 8 acceptance: at --quant-bits 4 the per-round uplink wire
    // bytes must be ≤ 45% of the f32 encoding at identical nnz. Round
    // 0 starts from the same seeded global model in both runs, so the
    // sparsification (and hence the nnz vector) is identical and only
    // the wire format differs.
    let run = |bits: Option<u8>| {
        let mut cfg = native_cfg("mnist_mlp");
        cfg.rounds = 1;
        cfg.eval_every = 99;
        cfg.quant_bits = bits;
        cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
        let mut t = Trainer::new(cfg).unwrap();
        let out = t.run_round(0).unwrap();
        assert!(!out.aborted);
        (out.nnz.clone(), t.ledger.rounds[0].up_wire)
    };
    let (nnz_f32, wire_f32) = run(None);
    let (nnz_q4, wire_q4) = run(Some(4));
    assert_eq!(nnz_f32, nnz_q4, "quantization must not change the transmitted support");
    assert!(nnz_f32.iter().all(|&n| n > 0));
    assert!(
        wire_q4 * 100 <= wire_f32 * 45,
        "4-bit wire {wire_q4} > 45% of f32 wire {wire_f32}"
    );
}

#[test]
fn quantized_training_learns_and_is_deterministic() {
    // codes ship on the wire and dequantize on fold — the run must
    // still learn, and replay bit-for-bit per seed
    let run = || {
        let mut cfg = native_cfg("mnist_mlp");
        cfg.rounds = 15;
        cfg.eval_every = 15;
        cfg.quant_bits = Some(4);
        cfg.algorithm = Algorithm::FlatSparse { s: 0.1 };
        let mut t = Trainer::new(cfg).unwrap();
        let summary = t.run().unwrap();
        (t.global.data.clone(), summary.final_accuracy)
    };
    let (a, acc) = run();
    let (b, _) = run();
    assert_eq!(a, b, "quantized run must replay exactly");
    assert!(acc > 0.3, "quantized path broke learning: acc {acc}");
}

#[test]
fn parallel_collect_is_bitwise_equal_to_serial() {
    // the pool-parallel sharded fold (shards > 1, workers > 1) must be
    // bit-for-bit the serial streaming fold, f32 and quantized alike
    let run = |shards: usize, workers: usize, bits: Option<u8>| {
        let mut cfg = native_cfg("mnist_mlp");
        cfg.rounds = 3;
        cfg.eval_every = 99;
        cfg.shards = shards;
        cfg.client_workers = workers;
        cfg.quant_bits = bits;
        cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
        let mut t = Trainer::new(cfg).unwrap();
        t.run().unwrap();
        t.global.data.clone()
    };
    for bits in [None, Some(4)] {
        let want = run(1, 1, bits);
        for (shards, workers) in [(2, 4), (4, 4), (4, 1), (1, 4)] {
            let got = run(shards, workers, bits);
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "bits {bits:?}, shards {shards} × workers {workers}: \
                 parallel Collect diverged from serial"
            );
        }
    }
}

#[test]
fn fedavg_baseline_runs_dense() {
    let mut cfg = native_cfg("mnist_mlp");
    cfg.rounds = 2;
    cfg.eval_every = 2;
    cfg.algorithm = Algorithm::FedAvg;
    let mut trainer = Trainer::new(cfg).unwrap();
    let out = trainer.run_round(0).unwrap();
    let m = trainer.model_params();
    // dense: every entry ships
    assert!(out.nnz.iter().all(|&n| n == m), "{:?}", out.nnz);
}

#[test]
fn fedprox_differs_from_fedavg() {
    let run = |alg: Algorithm| {
        let mut cfg = native_cfg("mnist_mlp");
        cfg.rounds = 3;
        cfg.eval_every = 3;
        cfg.algorithm = alg;
        let mut t = Trainer::new(cfg).unwrap();
        t.run().unwrap();
        t.global.data.clone()
    };
    let a = run(Algorithm::FedAvg);
    let b = run(Algorithm::FedProx { mu: 0.5 });
    let diff: f64 = a.iter().zip(&b).map(|(&x, &y)| ((x - y) as f64).abs()).sum();
    assert!(diff > 1e-3, "prox term had no effect");
}

#[test]
fn noniid_partition_trains() {
    let mut cfg = native_cfg("mnist_mlp");
    cfg.partition = Partition::NonIid(4);
    cfg.rounds = 15;
    cfg.eval_every = 15;
    let mut trainer = Trainer::new(cfg).unwrap();
    let summary = trainer.run().unwrap();
    // non-IID converges slower; just require clearly above chance
    assert!(summary.final_accuracy > 0.15, "noniid acc {}", summary.final_accuracy);
}

#[test]
fn run_is_deterministic_per_seed() {
    let run = || {
        let mut cfg = native_cfg("mnist_mlp");
        cfg.rounds = 3;
        cfg.eval_every = 3;
        let mut t = Trainer::new(cfg).unwrap();
        t.run().unwrap();
        t.global.data.clone()
    };
    let a = run();
    let b = run();
    // thread scheduling does not affect results: the native backend is
    // pure sequential f32 math per client, aggregation is collected in
    // selection order, and client RNG streams are seed-derived.
    assert_eq!(a, b);
}

#[test]
fn residuals_accumulate_across_rounds() {
    let mut cfg = native_cfg("mnist_mlp");
    cfg.rounds = 4;
    cfg.eval_every = 99;
    cfg.clients = 4;
    cfg.clients_per_round = 4; // everyone participates → residuals live
    cfg.algorithm = Algorithm::FlatSparse { s: 0.01 };
    let mut trainer = Trainer::new(cfg).unwrap();
    trainer.run().unwrap();
    let with_residual = trainer
        .clients
        .iter()
        .filter(|c| c.residual.norm() > 0.0)
        .count();
    assert!(with_residual >= 3, "only {with_residual} clients hold residual");
    assert!(trainer.clients.iter().all(|c| c.participation == 4));
}

#[test]
fn dropout_aggregates_survivors_only() {
    let mut cfg = native_cfg("mnist_mlp");
    cfg.rounds = 3;
    cfg.eval_every = 99;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.dropout_prob = 0.3;
    let mut trainer = Trainer::new(cfg).unwrap();
    let mut saw_dropout = false;
    let mut survivor_total = 0u64;
    for round in 0..3 {
        let out = trainer.run_round(round).unwrap();
        assert!(!out.aborted, "round {round}: enough survivors for min_survivors=1");
        assert_eq!(
            out.survivors.len() + out.dropped.len() + out.stragglers.len(),
            out.selected.len()
        );
        // per-survivor rows stay aligned
        assert_eq!(out.nnz.len(), out.survivors.len());
        assert_eq!(out.wire_bytes.len(), out.survivors.len());
        assert!(out.mean_train_loss.is_finite());
        assert!(out.timings.train_s > 0.0, "phase timings must be measured");
        saw_dropout |= !out.dropped.is_empty();
        survivor_total += out.survivors.len() as u64;
    }
    // seed 42 drops clients in rounds 0 and 2 (deterministic plan)
    assert!(saw_dropout, "seeded failure plan must produce dropouts");
    // participation counts only delivered rounds — single owner check
    let participation: u64 = trainer.clients.iter().map(|c| c.participation).sum();
    assert_eq!(participation, survivor_total);
}

#[test]
fn impossible_deadline_strands_everyone_and_aborts() {
    // every delivery needs at least rtt/2 + download time, so a
    // microsecond deadline times out all uploads regardless of seed
    let mut cfg = native_cfg("mnist_mlp");
    cfg.rounds = 1;
    cfg.eval_every = 99;
    cfg.straggler_timeout_s = 1e-6;
    let mut trainer = Trainer::new(cfg).unwrap();
    let global_before = trainer.global.data.clone();
    let out = trainer.run_round(0).unwrap();
    assert!(out.aborted);
    assert!(out.survivors.is_empty());
    assert_eq!(out.stragglers.len(), out.selected.len());
    assert!(out.dropped.is_empty());
    assert_eq!(trainer.global.data, global_before);
}

#[test]
fn generous_deadline_is_bitwise_identical_to_no_injection() {
    // a finite-but-unreachable deadline turns the snapshot/rollback
    // machinery on without ever killing a client: the trained model
    // must be bit-for-bit the same as the failure-free path (the
    // straggler jitter only shifts simulated time, never payloads)
    let run = |timeout: f64| {
        let mut cfg = native_cfg("mnist_mlp");
        cfg.rounds = 2;
        cfg.eval_every = 99;
        cfg.straggler_timeout_s = timeout;
        let mut t = Trainer::new(cfg).unwrap();
        t.run().unwrap();
        (t.global.data.clone(), t.clients.iter().map(|c| c.participation).sum::<u64>())
    };
    let (plain, part_plain) = run(f64::INFINITY);
    let (injected, part_injected) = run(1e6);
    assert!(
        plain.iter().zip(&injected).all(|(a, b)| a.to_bits() == b.to_bits()),
        "failure-injection plumbing must not perturb the failure-free path"
    );
    assert_eq!(part_plain, part_injected);
}

/// Artifact-dependent checks: only meaningful when the PJRT path is
/// compiled in, and still skipped at runtime pre-`make artifacts`.
#[cfg(feature = "pjrt")]
mod pjrt {
    use std::path::PathBuf;

    use fedsparse::config::RunConfig;
    use fedsparse::coordinator::Trainer;
    use fedsparse::models::manifest::Manifest;
    use fedsparse::models::params::ParamVector;
    use fedsparse::runtime::BackendKind;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    fn pjrt_cfg(model: &str) -> RunConfig {
        let mut cfg = RunConfig::smoke(model);
        cfg.backend = BackendKind::Pjrt;
        cfg.artifacts_dir = artifacts_dir().unwrap();
        cfg.data_dir = None;
        cfg
    }

    fn runner_for(model: &str) -> fedsparse::runtime::ModelRunner {
        let manifest = Manifest::load(&artifacts_dir().unwrap()).unwrap();
        fedsparse::runtime::ModelRunner::for_config(&manifest, &pjrt_cfg(model)).unwrap()
    }

    #[test]
    fn manifest_param_counts_match_table1() {
        let Some(dir) = artifacts_dir() else { return };
        let m = Manifest::load(&dir).unwrap();
        // paper Table 1 parity (see DESIGN.md model zoo)
        assert_eq!(m.model("mnist_mlp").unwrap().param_count, 159_010);
        if let Some(cnn) = m.model("mnist_cnn") {
            assert_eq!(cnn.param_count, 582_026);
        }
        if let Some(vgg) = m.model("cifar_vgg16") {
            assert_eq!(vgg.param_count, 14_728_266);
        }
    }

    #[test]
    fn grad_artifact_descends_loss() {
        let Some(_) = artifacts_dir() else { return };
        let runner = runner_for("mnist_mlp");
        let mut params = ParamVector::init(&runner.meta, 7);

        // fixed synthetic batch
        use fedsparse::data::{Dataset, DatasetKind, Split};
        let data = Dataset::synthetic_small(DatasetKind::Mnist, Split::Train, 200, 3);
        let idx: Vec<usize> = (0..runner.train_batch).collect();
        let (x, y) = data.batch(&idx);

        let (loss0, grads) = runner.grad(&params, &x, &y).unwrap();
        assert!(loss0.is_finite() && loss0 > 0.0);
        assert_eq!(grads.len(), params.len());
        // loss at init should be ~ln(10) for 10 classes
        assert!((1.0..4.0).contains(&loss0), "init loss {loss0}");

        for _ in 0..5 {
            let (_, g) = runner.grad(&params, &x, &y).unwrap();
            params.sgd_step(&g, 0.1);
        }
        let (loss1, _) = runner.grad(&params, &x, &y).unwrap();
        assert!(loss1 < loss0, "no descent: {loss0} → {loss1}");
    }

    #[test]
    fn eval_artifact_counts_correct() {
        let Some(_) = artifacts_dir() else { return };
        let runner = runner_for("mnist_mlp");
        let params = ParamVector::init(&runner.meta, 11);

        use fedsparse::data::{Dataset, DatasetKind, Split};
        let data = Dataset::synthetic_small(DatasetKind::Mnist, Split::Test, 500, 5);
        let (loss, acc) = runner.evaluate(&params, &data, 500).unwrap();
        assert!(loss > 0.0);
        // untrained model ≈ chance
        assert!((0.0..=0.35).contains(&acc), "untrained acc {acc}");
    }

    #[test]
    fn cifar_cnn_one_round() {
        let Some(_) = artifacts_dir() else { return };
        let mut cfg = pjrt_cfg("cifar_cnn");
        cfg.rounds = 1;
        cfg.eval_every = 1;
        let mut trainer = Trainer::new(cfg).unwrap();
        let out = trainer.run_round(0).unwrap();
        assert!(out.mean_train_loss.is_finite());
        assert!(out.eval.unwrap().1 >= 0.0);
    }
}
