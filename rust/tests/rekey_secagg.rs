//! Per-round neighborhood-local Shamir re-keying property tests.
//!
//! The PR's share-placement contract, pinned here:
//!
//! * after `rekey_for` at round r, shares of client u's secret exist
//!   at **exactly** `N_r(u)` — no other client holds material for u,
//!   and the total share count is Σ_u |N_r(u)| = n·k, not n·(n−1);
//! * churn (a member leaving between re-key calls at the same round)
//!   re-shares exactly the affected neighborhoods — the consistent-hash
//!   ring keeps that to the departed member's ring window — and
//!   everyone else's shares are carried;
//! * dead-client recovery against the registry reconstructs the same
//!   pair-key bytes both endpoints derive
//!   ([`SecAggClient::pair_key_with`]), for exactly the (survivor,
//!   dead) neighborhood edges;
//! * a dead client with fewer than `t` surviving neighbors is
//!   unrecoverable (`None`) — the quorum is neighborhood-scoped now.

use fedsparse::secagg::neighborhood::Neighborhood;
use fedsparse::secagg::protocol::{full_setup, SecAggClient, SecAggConfig, SecAggServer};
use fedsparse::secagg::rekey::{recover_pair_keys_rekeyed, RekeyRegistry};

fn setup(n: u32) -> (Vec<SecAggClient>, SecAggServer) {
    // no setup-time all-pairs share walk: the registry is the only
    // share distribution in this test
    let sc = SecAggConfig { share_keys: false, ..Default::default() };
    full_setup(n, 0x5eed, &sc)
}

/// ISSUE acceptance: at n = 64, k = 8, one full re-key distributes
/// exactly 64·8 = 512 shares (not n·(n−1) = 4032), and every owner's
/// holder set is exactly its round neighborhood.
#[test]
fn shares_exist_only_at_round_neighbors_and_count_nk() {
    let n = 64u32;
    let (clients, _server) = setup(n);
    let selected: Vec<u32> = (0..n).collect();
    let topo = Neighborhood::build(&selected, 8, 9, 5);
    assert!(!topo.is_complete());
    assert_eq!(topo.degree(), 8);

    let mut reg = RekeyRegistry::new(2);
    let stats = reg.rekey_for(&clients, &topo, 5, 9);
    assert_eq!(stats.reshared_owners, 64);
    assert_eq!(stats.shares_distributed, 512, "Σ_u |N_r(u)| = n·k, not n·(n−1) = 4032");
    assert_eq!(stats.dropped_owners, 0);
    assert_eq!(stats.carried_owners, 0);

    assert_eq!(reg.owners(), selected, "every cohort member's secret is shared");
    for &c in &selected {
        let holders = reg.holders_of(c).expect("owner has an entry");
        assert_eq!(
            holders,
            topo.neighbors_of(c).as_slice(),
            "client {c}: shares must sit at exactly N_r({c})"
        );
        assert!(!holders.contains(&c), "client {c} must not hold its own secret");
    }
}

/// A member leaving between re-key calls (same round, same seed)
/// re-shares exactly the neighborhoods whose holder set changed — the
/// departed member's ring window, at most `degree` owners — and
/// carries everyone else's shares untouched.
#[test]
fn churn_reshares_exactly_the_affected_neighborhoods() {
    let n = 64u32;
    let (clients, _server) = setup(n);
    let selected: Vec<u32> = (0..n).collect();
    let round = 3u64;
    let seed = 11u64;
    let topo = Neighborhood::build(&selected, 8, seed, round);
    let mut reg = RekeyRegistry::new(2);
    reg.rekey_for(&clients, &topo, round, seed);

    // client 20 leaves; same round ⇒ surviving members keep their ring
    // ranks, so only the window around 20's old slot changes
    let leaver = 20u32;
    let reduced: Vec<u32> = selected.iter().copied().filter(|&c| c != leaver).collect();
    let topo2 = Neighborhood::build(&reduced, 8, seed, round);
    let changed: Vec<u32> = reduced
        .iter()
        .copied()
        .filter(|&c| topo2.neighbors_of(c) != topo.neighbors_of(c))
        .collect();
    assert!(!changed.is_empty(), "the leaver's ring window must shift");
    assert!(
        changed.len() <= topo2.degree(),
        "churn must stay local: {} neighborhoods changed, degree is {}",
        changed.len(),
        topo2.degree()
    );

    let stats = reg.rekey_for(&clients, &topo2, round, seed);
    assert_eq!(stats.dropped_owners, 1, "exactly the leaver's entry is dropped");
    assert_eq!(
        stats.reshared_owners,
        changed.len(),
        "re-share exactly the owners whose neighbor set changed"
    );
    assert_eq!(stats.carried_owners, reduced.len() - changed.len());
    assert_eq!(
        stats.shares_distributed,
        changed.len() * topo2.degree(),
        "churn share traffic is one ring window, not a cohort re-key"
    );

    assert!(reg.holders_of(leaver).is_none(), "no live material for a departed member");
    for &c in &reduced {
        assert_eq!(
            reg.holders_of(c).expect("owner has an entry"),
            topo2.neighbors_of(c).as_slice(),
            "client {c}: holders must track the current topology"
        );
    }
}

/// Dead-client recovery still reconstructs under churn: re-key, lose a
/// member, re-key at the next round, then kill clients — the recovered
/// pair keys byte-equal what the live endpoints derive, for exactly
/// the (survivor, dead) neighborhood edges.
#[test]
fn dead_client_recovery_reconstructs_under_churn() {
    let n = 32u32;
    let (clients, server) = setup(n);
    let selected: Vec<u32> = (0..n).collect();
    let seed = 7u64;
    let mut reg = RekeyRegistry::new(2);

    // round 1 over the full cohort, then client 9 churns out and
    // round 2 re-keys over the reduced cohort (new ring, new
    // polynomials where holder sets moved)
    let topo1 = Neighborhood::build(&selected, 6, seed, 1);
    reg.rekey_for(&clients, &topo1, 1, seed);
    let reduced: Vec<u32> = selected.iter().copied().filter(|&c| c != 9).collect();
    let topo2 = Neighborhood::build(&reduced, 6, seed, 2);
    reg.rekey_for(&clients, &topo2, 2, seed);

    // two mid-round deaths; everyone else survives
    let dead = vec![4u32, 17];
    let survivors: Vec<u32> = reduced.iter().copied().filter(|c| !dead.contains(c)).collect();
    let recovered = recover_pair_keys_rekeyed(&reg, &server, &survivors, &dead, &topo2)
        .expect("degree-6 neighborhoods with 2 deaths must meet a t=2 quorum");

    let expected_edges: usize = dead
        .iter()
        .map(|&u| topo2.neighbors_of(u).iter().filter(|v| survivors.contains(v)).count())
        .sum();
    assert_eq!(recovered.len(), expected_edges, "recovery walks the dead neighborhoods only");
    assert!(expected_edges < dead.len() * survivors.len());
    for (&(v, u), key) in &recovered {
        assert!(dead.contains(&u) && survivors.contains(&v));
        assert!(topo2.are_neighbors(u, v));
        assert_eq!(
            *key,
            clients[v as usize].pair_key_with(u),
            "recovered pair key ({v},{u}) must byte-equal the live endpoint's"
        );
    }
}

/// The quorum is over |N_r(u) ∩ survivors| now, not all survivors:
/// when a dead client's neighborhood is wiped out below `t`, recovery
/// must refuse (the round aborts) rather than fabricate keys.
#[test]
fn recovery_refuses_below_neighborhood_quorum() {
    let n = 32u32;
    let (clients, server) = setup(n);
    let selected: Vec<u32> = (0..n).collect();
    let topo = Neighborhood::build(&selected, 6, 13, 4);
    let mut reg = RekeyRegistry::new(3);
    reg.rekey_for(&clients, &topo, 4, 13);

    // kill u; exclude all but 2 of its 6 neighbors from the survivor
    // set — plenty of survivors overall, but < t = 3 holders of u's
    // shares remain
    let u = 5u32;
    let neighbors = topo.neighbors_of(u);
    let keep: Vec<u32> = neighbors.iter().copied().take(2).collect();
    let survivors: Vec<u32> = selected
        .iter()
        .copied()
        .filter(|&c| c != u && (!neighbors.contains(&c) || keep.contains(&c)))
        .collect();
    assert!(survivors.len() > reg.threshold(), "cohort-wide quorum would have passed");
    assert!(
        recover_pair_keys_rekeyed(&reg, &server, &survivors, &[u], &topo).is_none(),
        "2 surviving holders < t = 3 must abort recovery"
    );
}
