//! Chaos soak: socket-transport end-to-end runs across a seeded chaos
//! matrix. CI drives this with `FEDSPARSE_CHAOS_*` env knobs (see
//! `.github/workflows/ci.yml`); locally it runs one moderate mix with
//! the default seed list. Every run must either complete with the
//! correct aggregate (bitwise-equal to the in-process twin under the
//! same seeds) or abort cleanly at quorum (global model untouched).
//! Failure messages reprint the exact replay line.

mod common;

use common::{assert_conformant, drive, secure_chaos_cfg};
use fedsparse::config::TransportKind;
use fedsparse::coordinator::Trainer;

fn env_f64(name: &str, default: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(default)
}

fn soak_seeds() -> Vec<u64> {
    std::env::var("FEDSPARSE_CHAOS_SEEDS")
        .ok()
        .map(|v| v.split(',').filter_map(|s| s.trim().parse().ok()).collect::<Vec<u64>>())
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![11, 23, 47])
}

/// The soak proper: for every seed in the matrix, a secure 4-round
/// TCP run must match its in-process twin observable-for-observable,
/// and every round must either apply an aggregate or abort cleanly.
#[test]
fn chaos_soak_tcp_matches_inproc_twin() {
    let loss = env_f64("FEDSPARSE_CHAOS_LOSS", 0.3);
    let dup = env_f64("FEDSPARSE_CHAOS_DUP", 0.0);
    let reorder = env_f64("FEDSPARSE_CHAOS_REORDER", 0.5);
    let slow = env_f64("FEDSPARSE_CHAOS_SLOW", 0.0);

    for seed in soak_seeds() {
        let replay = format!(
            "replay: FEDSPARSE_CHAOS_SEEDS={seed} FEDSPARSE_CHAOS_LOSS={loss} \
             FEDSPARSE_CHAOS_DUP={dup} FEDSPARSE_CHAOS_REORDER={reorder} \
             FEDSPARSE_CHAOS_SLOW={slow} \
             cargo test --release --test chaos_soak -- --nocapture"
        );
        let mut cfg = secure_chaos_cfg(seed);
        cfg.chaos_loss = loss;
        cfg.chaos_dup = dup;
        cfg.chaos_reorder = reorder;
        cfg.chaos_slow = slow;

        let inproc = drive(cfg.clone(), TransportKind::InProc);
        let tcp = drive(cfg, TransportKind::Tcp);
        assert_conformant(&replay, &inproc, &tcp);

        let mut aborted = 0usize;
        for s in &inproc.0 {
            if s.aborted {
                aborted += 1;
                assert!(
                    s.agg_bits.is_empty(),
                    "round {} aborted but still exposed an aggregate — {replay}",
                    s.round
                );
            } else {
                assert!(
                    !s.agg_bits.is_empty(),
                    "round {} completed without an aggregate — {replay}",
                    s.round
                );
            }
        }
        println!(
            "chaos soak seed {seed}: {} rounds ({aborted} aborted at quorum) \
             conformant across inproc/tcp",
            inproc.0.len()
        );
    }
}

/// Quorum-abort path over a real socket: with crash + loss rates so
/// hostile that a full cohort essentially never survives, every round
/// must abort cleanly — no error, no partial apply, global model
/// bitwise-untouched — and the socket run must still match the twin.
#[test]
fn chaos_soak_high_loss_aborts_cleanly_at_quorum() {
    let mut cfg = secure_chaos_cfg(5);
    cfg.chaos_loss = 0.8;
    cfg.dropout_prob = 0.85;
    // require the full cohort: any crash/exhausted-retry loss aborts
    cfg.min_survivors = cfg.clients_per_round;
    let replay = "replay: seed 5, chaos_loss 0.8, dropout 0.85, min_survivors = cohort";

    let inproc = drive(cfg.clone(), TransportKind::InProc);
    let tcp_cfg = {
        let mut c = cfg.clone();
        c.transport = TransportKind::Tcp;
        c
    };
    let mut t = Trainer::new(tcp_cfg).unwrap();
    let init: Vec<u32> = t.global.data.iter().map(|v| v.to_bits()).collect();
    let mut snaps = Vec::new();
    for r in 0..cfg.rounds {
        let out = t.run_round(r).unwrap_or_else(|e| {
            panic!("quorum abort must be clean, round {r} errored: {e} — {replay}")
        });
        assert!(
            out.aborted,
            "round {r} kept a full cohort under a near-certain-failure plan — {replay}"
        );
        assert!(out.aggregate.is_empty(), "aborted round {r} exposed an aggregate");
        let cost = *t.ledger.rounds.last().unwrap();
        snaps.push(common::RoundSnapshot {
            round: r,
            aborted: out.aborted,
            survivors: out.survivors.clone(),
            dropped: out.dropped.clone(),
            stragglers: out.stragglers.clone(),
            agg_bits: Vec::new(),
            up_wire: cost.up_wire,
            up_framed: cost.up_framed,
        });
    }
    let final_bits: Vec<u32> = t.global.data.iter().map(|v| v.to_bits()).collect();
    assert_eq!(init, final_bits, "aborted rounds must leave the global model untouched");
    let tcp = (snaps, final_bits);
    assert_conformant(replay, &inproc, &tcp);
}
