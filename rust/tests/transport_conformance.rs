//! Transport conformance suite: one shared harness drives identical
//! runs over the in-process twin, TCP, and (unix) UDS transports and
//! pins that every observable — payload bytes metered, framed bytes
//! metered, survivor/dropped/straggler sets per FailurePlan seed, and
//! the applied aggregate down to the f32 bit — is identical.
//!
//! This is the PR's acceptance criterion: a secure 4-round run over
//! TCP localhost with k-regular neighborhoods, seeded dropouts, and
//! chaos loss + reordering must produce an aggregate bitwise-equal to
//! the in-process run with the same seeds. Conformance holds by
//! construction (both transports evaluate the same pure
//! `effective_fate`, and the socket consumer resequences to the same
//! ascending-cid fold order) — these tests keep it that way.

mod common;

use common::{assert_conformant, drive, quantized_chaos_cfg, secure_chaos_cfg};
use fedsparse::config::TransportKind;

/// The acceptance scenario: secure, neighbors_k = 3, dropout 0.25,
/// chaos loss 0.3 + reorder 0.5, sharded fold, 4 rounds.
#[test]
fn secure_chaos_run_is_bitwise_identical_across_transports() {
    let cfg = secure_chaos_cfg(2024);
    let inproc = drive(cfg.clone(), TransportKind::InProc);
    let tcp = drive(cfg.clone(), TransportKind::Tcp);
    assert_conformant("secure inproc vs tcp", &inproc, &tcp);
    #[cfg(unix)]
    {
        let uds = drive(cfg, TransportKind::Uds);
        assert_conformant("secure inproc vs uds", &inproc, &uds);
    }

    // the scenario must actually exercise the interesting paths: at
    // least one applied aggregate and at least one removed client
    assert!(
        inproc.0.iter().any(|s| !s.aborted && !s.agg_bits.is_empty()),
        "no round applied an aggregate — scenario too hostile, retune seeds"
    );
    assert!(
        inproc.0.iter().any(|s| !s.dropped.is_empty() || !s.stragglers.is_empty()),
        "no client was ever removed — scenario too gentle, retune seeds"
    );
}

/// The quantized bitpacked wire path under duplication, slow links,
/// and reordering: dup frames must be deduped (first copy wins, bytes
/// not double-metered), slow links only shift simulated time.
#[test]
fn quantized_chaos_run_is_bitwise_identical_across_transports() {
    let cfg = quantized_chaos_cfg(7);
    let inproc = drive(cfg.clone(), TransportKind::InProc);
    let tcp = drive(cfg.clone(), TransportKind::Tcp);
    assert_conformant("quantized inproc vs tcp", &inproc, &tcp);
    #[cfg(unix)]
    {
        let uds = drive(cfg, TransportKind::Uds);
        assert_conformant("quantized inproc vs uds", &inproc, &uds);
    }
    assert!(
        inproc.0.iter().any(|s| !s.aborted && !s.agg_bits.is_empty()),
        "no round applied an aggregate — scenario too hostile, retune seeds"
    );
}

/// With failure injection and chaos off, every transport delivers the
/// full cohort and the framed meter is exactly the payload meter plus
/// one frame header per survivor.
#[test]
fn clean_run_framed_meter_is_payload_plus_headers() {
    let mut cfg = secure_chaos_cfg(11);
    cfg.dropout_prob = 0.0;
    cfg.chaos_loss = 0.0;
    cfg.chaos_reorder = 0.0;
    cfg.rounds = 2;
    let header = fedsparse::comm::frame::HEADER_LEN as u64;

    for kind in [TransportKind::InProc, TransportKind::Tcp] {
        let (snaps, _) = drive(cfg.clone(), kind);
        for s in &snaps {
            assert!(!s.aborted, "{kind:?}: clean round {} aborted", s.round);
            assert_eq!(
                s.survivors.len(),
                cfg.clients_per_round,
                "{kind:?}: clean round {} lost clients",
                s.round
            );
            assert_eq!(
                s.up_framed,
                s.up_wire + header * s.survivors.len() as u64,
                "{kind:?}: round {} framed meter is not payload + headers",
                s.round
            );
        }
    }
}

/// The straggler deadline boundary, end to end: a frame landing
/// exactly AT the deadline is delivered, one ulp past straggles — and
/// both transports classify it the same way. Uses a deadline placed
/// exactly on a client's simulated arrival time, discovered by
/// probing the deadline-free run.
#[test]
fn deadline_boundary_classifies_identically_across_transports() {
    use fedsparse::comm::chaos::ChaosPlan;
    use fedsparse::comm::transport::{effective_fate, FailurePlan, Fate};

    // reconstruct the trainer's plan for round 0 and find a client's
    // exact simulated arrival time
    let cfg = {
        let mut c = secure_chaos_cfg(2024);
        c.dropout_prob = 0.0;
        c.chaos_loss = 0.0;
        c.chaos_reorder = 0.0;
        c
    };
    let probe_plan = FailurePlan {
        dropout_prob: 0.0,
        straggler_timeout_s: 1.0,
        straggler_scale: fedsparse::comm::transport::DEFAULT_STRAGGLER_SCALE,
        seed: cfg.seed ^ 0xfa11,
    };
    let at = probe_plan
        .raw_time(0, 3, 0.25)
        .expect("dropout is off, the client cannot crash");
    assert!(at.is_finite() && at >= 0.25);

    // AT the deadline: delivered on both the pure classifier and thus
    // (by construction) on every transport
    let mut plan = probe_plan;
    plan.straggler_timeout_s = at;
    let fate = effective_fate(&plan, &ChaosPlan::none(), 0, 3, 0.25);
    assert!(
        matches!(fate.fate, Fate::Deliver { at_s } if at_s == at),
        "arrival exactly at the deadline must be delivered, got {:?}",
        fate.fate
    );

    // one ulp before the arrival time: straggles
    plan.straggler_timeout_s = f64::from_bits(at.to_bits() - 1);
    let fate = effective_fate(&plan, &ChaosPlan::none(), 0, 3, 0.25);
    assert!(
        matches!(fate.fate, Fate::Timeout { .. }),
        "arrival past the deadline must straggle, got {:?}",
        fate.fate
    );

    // end-to-end: run with the deadline pinned to the boundary on both
    // transports and require identical straggler sets
    let mut run_cfg = cfg;
    run_cfg.straggler_timeout_s = at;
    run_cfg.rounds = 1;
    let inproc = drive(run_cfg.clone(), TransportKind::InProc);
    let tcp = drive(run_cfg, TransportKind::Tcp);
    assert_conformant("deadline boundary inproc vs tcp", &inproc, &tcp);
}
