//! Counting-allocator audit of the steady-state round path — BOTH
//! sides of the engine.
//!
//! The kernel-layer contract (PERF.md): once the per-worker client
//! workspaces and the trainer's `ServerWorkspace` are warm, a
//! steady-state round performs **zero heap allocations of model-sized
//! buffers** — on the per-client path (local SGD → sparsify → mask →
//! encode) *and* on the coordinator path (Collect → Unmask/Recover →
//! Apply). Everything model-sized lives in trainer-owned scratch: the
//! client `WorkspacePool` (local params, grads, update, activations,
//! Top-k scratch, sparse/residual split, keep map, mask accumulators,
//! masked residual) and the `ServerWorkspace` (aggregate accumulator,
//! audit sum); the global model is `Arc`'d so the per-round pipeline
//! snapshot is a refcount bump and Apply mutates copy-on-write in
//! place. Per-round allocations are bounded by the *kept* entries
//! (~k/x of n) — wire payloads, σ-filtered pair streams, decoded
//! survivor payloads — never the model size.
//!
//! This test wraps the global allocator with a counter of "large"
//! allocations (≥ 3/4 of the model's f32 footprint — every
//! model-sized buffer is ≥ 4·m bytes, every legitimate
//! kept-entry-scaled buffer is well under), warms the workspaces up,
//! then drives (a) the isolated client phases
//! (`Trainer::run_client_phases`) and (b) the full engine
//! (`Trainer::run_round`), asserting **zero** large allocations in
//! steady state for plain and secure modes alike.
//!
//! The encode path is part of the audited loop: clients encode into
//! recycled `WorkspacePool` wire buffers (`SparseVec::encode_into` /
//! `QuantizedSparse::encode_into`), the buffers travel by move through
//! the transport, and the Collect fold releases them back to the pool
//! — so after warm-up the wire path allocates nothing at all on clean
//! rounds. Scenario (d) drives the quantized (`--quant-bits 4`) frame
//! through the same audit.
//!
//! This file is its own test binary (one test), so no parallel test
//! pollutes the counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::sparse::thgs::ThgsConfig;

static TRACKING: AtomicBool = AtomicBool::new(false);
static THRESHOLD_BYTES: AtomicUsize = AtomicUsize::new(usize::MAX);
static LARGE_ALLOCS: AtomicUsize = AtomicUsize::new(0);

struct CountingAllocator;

fn note(size: usize) {
    if TRACKING.load(Ordering::Relaxed) && size >= THRESHOLD_BYTES.load(Ordering::Relaxed) {
        LARGE_ALLOCS.fetch_add(1, Ordering::Relaxed);
    }
}

unsafe impl GlobalAlloc for CountingAllocator {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        note(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        note(new_size);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// The acceptance configuration: 20 clients, 10 per round, THGS down
/// to sparse rate 0.01, mnist_mlp (159,010 params). The mask
/// keep-ratio is dialed to k = 0.2 so the *union* of the 9 pair
/// streams (1 − (1 − k/x)^9 ≈ 17% of positions) keeps the per-client
/// wire payload — a legitimate, kept-entry-scaled allocation — well
/// below the model-sized threshold. `expose_aggregate` /
/// `audit_secure_sum` keep their zero-copy defaults. Failure injection
/// is exercised by its own scenario below: rollback snapshots are
/// copy-on-write (`Arc`-shared residuals + a recycled spare write
/// target — see coordinator/client.rs), so injected rounds must be as
/// allocation-free as clean ones.
fn cfg(secure: bool) -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.data_dir = None;
    cfg.rounds = 1_000_000; // rounds driven manually
    cfg.eval_every = u64::MAX;
    cfg.clients = 20;
    cfg.clients_per_round = 10;
    cfg.local_iters = 2;
    cfg.algorithm = Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.8, s_min: 0.01 });
    cfg.secure = secure;
    cfg.mask_ratio_k = 0.2;
    cfg
}

/// Track `rounds` steady-state iterations of `step` and return the
/// number of model-sized allocations observed.
fn count_large<F: FnMut(u64)>(m: usize, rounds: u64, mut step: F) -> usize {
    // "model-sized" = at least 3/4 of the model's f32 footprint
    // (4·m bytes). Every model-sized buffer (local params, grads,
    // update, Top-k scratch, sparse/residual split, mask accumulator,
    // server aggregate) is 4·m bytes = 636 KB ≥ this; every
    // legitimate kept-entry-scaled buffer (σ-filtered streams
    // ~25 KB/pair, the ~0.25n-entry wire payload ~240 KB, batch
    // pixels 157 KB) sits well below it.
    THRESHOLD_BYTES.store(m * 3, Ordering::SeqCst);
    LARGE_ALLOCS.store(0, Ordering::SeqCst);
    TRACKING.store(true, Ordering::SeqCst);
    for round in 2..2 + rounds {
        step(round);
    }
    TRACKING.store(false, Ordering::SeqCst);
    LARGE_ALLOCS.load(Ordering::SeqCst)
}

#[test]
fn steady_state_round_allocates_nothing_model_sized() {
    let rounds = 3u64;
    for secure in [false, true] {
        // --- (a) isolated client phases -----------------------------
        let mut trainer = Trainer::new(cfg(secure)).unwrap();
        let m = trainer.model_params();
        // warm-up: workspaces and payload buffers size themselves
        for round in 0..2u64 {
            trainer.run_client_phases(round).unwrap();
        }
        let count = count_large(m, rounds, |round| {
            trainer.run_client_phases(round).unwrap();
        });
        assert_eq!(
            count, 0,
            "secure={secure}: {count} model-sized (≥{} B) allocations across {rounds} \
             steady-state client-phase rounds of 10 clients each — the per-client path \
             must not allocate model-sized buffers (the global snapshot is an Arc bump)",
            m * 3
        );

        // --- (b) the full engine, coordinator side included ---------
        let mut trainer = Trainer::new(cfg(secure)).unwrap();
        for round in 0..2u64 {
            trainer.run_round(round).unwrap();
        }
        let count = count_large(m, rounds, |round| {
            let out = trainer.run_round(round).unwrap();
            assert!(!out.aborted);
            assert!(out.aggregate.is_empty(), "expose_aggregate off ⇒ no copy");
        });
        assert_eq!(
            count, 0,
            "secure={secure}: {count} model-sized (≥{} B) allocations across {rounds} \
             steady-state full rounds — the coordinator path (Collect → Unmask/Recover \
             → Apply) must run entirely on the ServerWorkspace + copy-on-write global",
            m * 3
        );

        // --- (c) injected-failure rounds: CoW rollback snapshots ----
        // dropout injection forces per-cohort snapshots, rollbacks,
        // and (secure) Shamir dead-mask recovery every round; with the
        // Arc-shared residual + recycled spare write target none of
        // that may copy or allocate anything model-sized either
        // momentum on: the DGC velocity is model-sized state that the
        // snapshot/rollback cycle used to deep-copy — with the Arc +
        // spare/retired double buffer it must be a refcount bump
        let mut icfg = cfg(secure);
        icfg.dropout_prob = 0.25;
        icfg.min_survivors = 2;
        icfg.momentum = 0.9;
        let mut trainer = Trainer::new(icfg).unwrap();
        let mut failures = 0usize;
        // two warm-up rounds, like (a)/(b): the double-buffer
        // spare/retired cycle reaches steady state after the first
        // committed round, and count_large tracks rounds 2.. — fresh,
        // non-replayed round numbers
        for round in 0..2u64 {
            trainer.run_round(round).unwrap();
        }
        let count = count_large(m, rounds, |round| {
            let out = trainer.run_round(round).unwrap();
            failures += out.dropped.len() + out.stragglers.len();
        });
        assert_eq!(
            count, 0,
            "secure={secure}: {count} model-sized (≥{} B) allocations across {rounds} \
             injected-failure rounds — rollback snapshots must be copy-on-write \
             (Arc'd residuals + recycled spares), not per-round deep copies",
            m * 3
        );
        assert!(
            failures > 0,
            "secure={secure}: dropout injection produced no failures — the scenario \
             no longer exercises the rollback path (adjust seed/dropout_prob)"
        );
    }

    // --- (d) quantized wire fast path ------------------------------
    // the bitpacked frame rides the same recycled wire buffers and the
    // server dequantizes on fold into the warm qdecode scratch, so the
    // quantized engine must be exactly as allocation-free as the f32
    // one (quantize itself is kept-entry-scaled: codes are nnz bytes)
    let mut qcfg = cfg(false);
    qcfg.quant_bits = Some(4);
    let mut trainer = Trainer::new(qcfg).unwrap();
    let m = trainer.model_params();
    for round in 0..2u64 {
        trainer.run_round(round).unwrap();
    }
    let rounds = 3u64;
    let count = count_large(m, rounds, |round| {
        let out = trainer.run_round(round).unwrap();
        assert!(!out.aborted);
    });
    assert_eq!(
        count, 0,
        "quant: {count} model-sized (≥{} B) allocations across {rounds} steady-state \
         quantized rounds — the bitpacked encode/decode-fold path must run entirely \
         on recycled wire buffers and the warm qdecode scratch",
        m * 3
    );
}
