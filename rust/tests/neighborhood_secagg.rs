//! k-regular neighborhood + sharded streaming-Collect property tests.
//!
//! The PR's bitwise contract, pinned here at every tested (cohort,
//! shard count, σ-filter) combination:
//!
//! * folding encoded uplinks into a [`ShardedAccumulator`] at ANY
//!   shard count reproduces the serial all-pairs reference
//!   (`SecAggServer::aggregate`-style in-order scatter-add) **bit for
//!   bit** — sharding partitions coordinate space, never one
//!   coordinate's op stream, and the merge is a copy in ascending
//!   shard id;
//! * with the σ filter keeping no mask entries, the masked sum IS the
//!   survivors' plain f32 sum, bitwise;
//! * where the k-regular graph degenerates to the complete graph
//!   (small cohorts), the neighborhood path produces bitwise-identical
//!   payloads to the all-pairs path;
//! * dead-client recovery under a k-regular topology reconstructs
//!   keys for exactly the (survivor, dead) *edges* — work proportional
//!   to one neighborhood, not the cohort — and still cancels the
//!   orphaned masks to f32 rounding.

use std::collections::HashMap;

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, ShardedAccumulator, Trainer};
use fedsparse::runtime::BackendKind;
use fedsparse::secagg::neighborhood::Neighborhood;
use fedsparse::secagg::protocol::{full_setup, recover_pair_keys_in, SecAggConfig};
use fedsparse::sparse::codec::SparseVec;
use fedsparse::sparse::topk::threshold_for_topk_abs;
use fedsparse::util::pool::ThreadPool;
use fedsparse::util::rng::Rng;

const SHARD_COUNTS: [usize; 3] = [1, 2, 4];

fn keep_top(g: &[f32], frac: f64) -> Vec<bool> {
    let k = ((g.len() as f64 * frac).ceil() as usize).max(1);
    let d = threshold_for_topk_abs(g, k);
    g.iter().map(|v| v.abs() > d).collect()
}

/// Build every client's masked uplink against its neighborhood.
/// Returns (payloads in id order, plain f64 sum, plain f32 serial sum
/// of the kept gradient entries).
fn build_cohort_payloads(
    clients: &[fedsparse::secagg::protocol::SecAggClient],
    topo: &Neighborhood,
    dim: usize,
    round: u64,
    data_seed: u64,
) -> (Vec<(u32, SparseVec)>, Vec<f64>, Vec<f32>) {
    let mut rng = Rng::new(data_seed);
    let mut payloads = Vec::with_capacity(clients.len());
    let mut expect = vec![0f64; dim];
    let mut plain_f32 = vec![0f32; dim];
    for c in clients {
        let g: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.05)).collect();
        let keep = keep_top(&g, 0.1);
        let peers = topo.neighbors_of(c.id);
        let u = c.build_update_among(&g, &keep, round, &peers);
        for j in 0..dim {
            expect[j] += (g[j] - u.residual[j]) as f64;
            if g[j] - u.residual[j] != 0.0 {
                // kept gradient entry: same per-position client order
                // as the server's fold
                plain_f32[j] += g[j];
            }
        }
        payloads.push((c.id, u.payload));
    }
    (payloads, expect, plain_f32)
}

/// Decode + fold `payloads` in order through a `shards`-way
/// accumulator and return the merged aggregate.
fn sharded_fold(payloads: &[(u32, SparseVec)], dim: usize, shards: usize) -> Vec<f32> {
    let mut acc = ShardedAccumulator::default();
    acc.reset(dim, shards);
    let mut decode = SparseVec::default();
    for (_, p) in payloads {
        SparseVec::decode_into(&p.encode(), &mut decode).unwrap();
        acc.fold(&decode);
    }
    let mut out = Vec::new();
    acc.merge_into(&mut out);
    out
}

/// Cohorts {2, 3, 8, 17, 64} × shards {1, 2, 4} × σ modes
/// {no-mask-entries, fractional, dense}: the streamed sharded sum must
/// be bitwise equal to the serial reference at every combination, and
/// bitwise equal to the survivors' plain f32 sum when the σ filter
/// keeps nothing.
#[test]
fn sharded_streaming_sum_is_bitwise_pinned_to_serial_reference() {
    let dim = 600usize;
    let round = 3u64;
    for &n in &[2usize, 3, 8, 17, 64] {
        let selected: Vec<u32> = (0..n as u32).collect();
        let topo = Neighborhood::build(&selected, 4, 42, round);
        let x = topo.participants();
        // σ modes: keep no mask entries / a fraction / every entry
        for (mode, ratio) in [("none", 0.0f64), ("frac", 0.5), ("dense", x as f64)] {
            let sc = SecAggConfig { mask_ratio_k: ratio, share_keys: false, ..Default::default() };
            let (clients, server) = full_setup(n as u32, 7 + n as u64, &sc);
            let (payloads, expect, plain_f32) =
                build_cohort_payloads(&clients, &topo, dim, round, 100 + n as u64);

            // serial all-pairs-order reference: in-order scatter-add,
            // no dead clients to cancel
            let serial = server.aggregate(dim, round, &payloads, &[], &HashMap::new());

            for &shards in &SHARD_COUNTS {
                let agg = sharded_fold(&payloads, dim, shards);
                assert_eq!(agg.len(), serial.len());
                let diff = agg
                    .iter()
                    .zip(&serial)
                    .filter(|(a, b)| a.to_bits() != b.to_bits())
                    .count();
                assert_eq!(
                    diff, 0,
                    "n={n} mode={mode} shards={shards}: {diff} positions differ \
                     from the serial reference bitwise"
                );
            }

            // the masked sum is the survivors' plain sum...
            let max_err = serial
                .iter()
                .zip(&expect)
                .map(|(&a, &e)| (a as f64 - e).abs())
                .fold(0.0f64, f64::max);
            assert!(max_err < 3e-3, "n={n} mode={mode}: mask residue {max_err}");
            // ...bitwise so when no mask entries survive the σ filter
            if mode == "none" {
                assert!(
                    serial.iter().zip(&plain_f32).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "n={n}: σ keeps nothing ⇒ masked sum must BE the plain f32 sum"
                );
            }
        }
    }
}

/// Where the k-regular build degenerates to the complete graph, the
/// neighborhood path and the explicit all-pairs path must produce
/// bitwise-identical payloads (this is what keeps the pre-PR golden
/// tests pinned without re-goldening).
#[test]
fn complete_bypass_matches_all_pairs_path_bitwise() {
    let dim = 400usize;
    let round = 1u64;
    // n=2,3 collapse under k=4 (2·⌈k/2⌉ ≥ n−1); n=8 collapses under
    // k=7; and k=0 is the complete graph at any size
    for (n, k) in [(2usize, 4usize), (3, 4), (8, 7), (17, 0)] {
        let selected: Vec<u32> = (0..n as u32).collect();
        let topo = Neighborhood::build(&selected, k, 42, round);
        assert!(topo.is_complete(), "n={n} k={k} must collapse to complete");
        assert_eq!(topo.participants(), n);
        let sc = SecAggConfig { mask_ratio_k: 0.5, share_keys: false, ..Default::default() };
        let (clients, _) = full_setup(n as u32, 19, &sc);
        let mut rng = Rng::new(23);
        for c in &clients {
            let g: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.05)).collect();
            let keep = keep_top(&g, 0.1);
            let via_topo = c.build_update_among(&g, &keep, round, &topo.neighbors_of(c.id));
            let all_pairs = c.build_update_among(&g, &keep, round, &selected);
            assert_eq!(via_topo.payload, all_pairs.payload, "n={n} k={k} client {}", c.id);
        }
    }
}

/// Seeded mid-round deaths under a genuinely sparse topology:
/// recovery reconstructs keys for exactly the (survivor, dead) edges
/// (Shamir path included), cancellation is bitwise identical at every
/// shard count, and the recovered aggregate matches the survivors'
/// plain sum.
#[test]
fn dead_client_recovery_is_neighborhood_local() {
    let n = 17u32;
    let dim = 500usize;
    let round = 2u64;
    let selected: Vec<u32> = (0..n).collect();
    let topo = Neighborhood::build(&selected, 4, 21, round);
    assert!(!topo.is_complete());
    assert_eq!(topo.degree(), 4);

    let sc = SecAggConfig { mask_ratio_k: 0.5, share_keys: true, ..Default::default() };
    let (clients, server) = full_setup(n, 21, &sc);
    let (payloads, _, _) = build_cohort_payloads(&clients, &topo, dim, round, 77);

    // seeded deaths: walk seeds deterministically until the draw kills
    // 2–4 of the 17 (so the scenario has several dead neighborhoods
    // and a healthy survivor majority)
    let (dead, survivors) = {
        let mut salt = 0u64;
        loop {
            let mut rng = Rng::new(0xdead ^ salt);
            let dead: Vec<u32> =
                selected.iter().copied().filter(|_| rng.next_f64() < 0.2).collect();
            if (2..=4).contains(&dead.len()) {
                let survivors: Vec<u32> =
                    selected.iter().copied().filter(|v| !dead.contains(v)).collect();
                break (dead, survivors);
            }
            salt += 1;
        }
    };

    // recovery work = the dead clients' edges, not |dead|·|survivors|
    let expected_edges: usize = dead
        .iter()
        .map(|&u| topo.neighbors_of(u).iter().filter(|v| survivors.contains(v)).count())
        .sum();
    let recovered =
        recover_pair_keys_in(&clients, &server, &survivors, &dead, Some(&topo)).unwrap();
    assert_eq!(recovered.len(), expected_edges);
    assert!(
        expected_edges < dead.len() * survivors.len(),
        "topology restriction did not reduce the pair walk"
    );
    // Shamir reconstruction recovered the true DH pair keys
    for (&(a, b), key) in &recovered {
        assert_eq!(*key, clients[a as usize].pair_key_with(b), "pair ({a},{b})");
    }

    // survivors' plain sum + serial cancelled reference
    let mut expect = vec![0f64; dim];
    let mut serial = vec![0f32; dim];
    let mut rng = Rng::new(77);
    for c in &clients {
        let g: Vec<f32> = (0..dim).map(|_| rng.normal_f32(0.05)).collect();
        let keep = keep_top(&g, 0.1);
        let peers = topo.neighbors_of(c.id);
        let u = c.build_update_among(&g, &keep, round, &peers);
        if survivors.contains(&c.id) {
            for j in 0..dim {
                expect[j] += (g[j] - u.residual[j]) as f64;
            }
        }
    }
    let pool = ThreadPool::new(2);
    for (id, p) in &payloads {
        if survivors.contains(id) {
            p.add_into(&mut serial);
        }
    }
    server.cancel_dead_masks_pooled_sink(
        &pool,
        None,
        dim,
        round,
        &survivors,
        &dead,
        &recovered,
        topo.participants(),
        Some(&topo),
        |i, x| serial[i as usize] -= x,
    );

    // sharded streaming path: fold survivors, cancel through the
    // shard-routing sink, merge — bitwise equal at every shard count
    for &shards in &SHARD_COUNTS {
        let mut acc = ShardedAccumulator::default();
        acc.reset(dim, shards);
        let mut decode = SparseVec::default();
        for (id, p) in &payloads {
            if survivors.contains(id) {
                SparseVec::decode_into(&p.encode(), &mut decode).unwrap();
                acc.fold(&decode);
            }
        }
        server.cancel_dead_masks_pooled_sink(
            &pool,
            None,
            dim,
            round,
            &survivors,
            &dead,
            &recovered,
            topo.participants(),
            Some(&topo),
            |i, x| acc.sub_at(i, x),
        );
        let mut agg = Vec::new();
        acc.merge_into(&mut agg);
        let diff = agg.iter().zip(&serial).filter(|(a, b)| a.to_bits() != b.to_bits()).count();
        assert_eq!(diff, 0, "shards={shards}: {diff} positions differ from serial");
    }

    let max_err = serial
        .iter()
        .zip(&expect)
        .map(|(&a, &e)| (a as f64 - e).abs())
        .fold(0.0f64, f64::max);
    assert!(max_err < 3e-3, "recovered aggregate residue {max_err} (dead {dead:?})");
}

fn trainer_cfg() -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.secure = true;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.clients = 12;
    cfg.clients_per_round = 8;
    cfg.neighbors_k = 4;
    cfg.mask_ratio_k = 0.5;
    cfg.eval_every = 99;
    cfg
}

/// Full secure `Trainer` run on a k-regular topology with failure
/// injection: masks still cancel every completed round, and each dead
/// client costs one neighborhood of recovered pairs, not one cohort.
///
/// With per-round re-keying the Shamir quorum is neighborhood-scoped
/// (shares of a dead client's secret live only at its round
/// neighbors), so a round where every dead client keeps < t surviving
/// neighbors legitimately aborts — those rounds are skipped, not
/// failures.
#[test]
fn trainer_k_regular_run_recovers_neighborhood_local() {
    let mut cfg = trainer_cfg();
    cfg.shards = 3;
    cfg.audit_secure_sum = true;
    cfg.expose_aggregate = true;
    cfg.dropout_prob = 0.25;
    cfg.min_survivors = 2;
    cfg.rounds = 6;
    let seed = cfg.seed;
    let k = cfg.neighbors_k;
    let mut trainer = Trainer::new(cfg).unwrap();
    let mut saw_dropout = false;
    let mut completed = 0usize;
    for round in 0..6 {
        let out = trainer.run_round(round).unwrap();
        if out.aborted {
            // legitimate under neighborhood-scoped quorum; the trainer
            // records and skips it (see Trainer::run)
            continue;
        }
        completed += 1;
        let topo = Neighborhood::build(&out.selected, k, seed, round);
        assert!(!topo.is_complete(), "8-client cohort with k=4 must stay sparse");
        let dead: Vec<u32> = out
            .selected
            .iter()
            .copied()
            .filter(|v| !out.survivors.contains(v))
            .collect();
        if !dead.is_empty() {
            saw_dropout = true;
            let expected: usize = dead
                .iter()
                .map(|&u| {
                    topo.neighbors_of(u).iter().filter(|v| out.survivors.contains(v)).count()
                })
                .sum();
            assert_eq!(
                out.recovered_pairs, expected,
                "round {round}: recovery must walk the dead neighborhoods only"
            );
            assert!(
                out.recovered_pairs < dead.len() * out.survivors.len()
                    || out.survivors.len() <= topo.degree(),
                "round {round}: neighborhood recovery did not beat the all-pairs walk"
            );
        }
        let plain = out.plain_sum.as_ref().expect("audit enabled");
        let max_err = out
            .aggregate
            .iter()
            .zip(plain)
            .map(|(&a, &p)| (a as f64 - p).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err < 5e-3, "round {round}: mask residue {max_err}");
    }
    assert!(completed >= 2, "too many aborted rounds for the properties to bite");
    assert!(saw_dropout, "this seed must produce dropouts on some completed round");
}

/// The shard count is an execution detail: identical runs at shards=1
/// and shards=3 produce bitwise-identical aggregates and globals.
#[test]
fn shard_count_does_not_change_the_run_bitwise() {
    let run = |shards: usize| {
        let mut cfg = trainer_cfg();
        cfg.shards = shards;
        cfg.expose_aggregate = true;
        cfg.rounds = 2;
        let mut t = Trainer::new(cfg).unwrap();
        let mut aggs = Vec::new();
        for r in 0..2 {
            aggs.push(t.run_round(r).unwrap().aggregate);
        }
        (aggs, t.global.data.clone())
    };
    let (agg1, global1) = run(1);
    let (agg3, global3) = run(3);
    for (round, (a, b)) in agg1.iter().zip(&agg3).enumerate() {
        assert!(!a.is_empty());
        let diff = a.iter().zip(b).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
        assert_eq!(diff, 0, "round {round}: {diff} aggregate positions differ across shards");
    }
    assert!(
        global1.iter().zip(&global3).all(|(x, y)| x.to_bits() == y.to_bits()),
        "global models diverged across shard counts"
    );
}

/// ISSUE 8 acceptance: the pool-parallel Collect fold (one range-walk
/// task per shard on `ThreadPool::map_shared`) is an execution detail
/// too — at every (shards, pool size) combination the secure run with
/// failure injection is bitwise identical to the serial fold
/// (shards=1, workers=1). The parallel path only engages when both
/// shards > 1 and workers > 1; the grid covers both gate sides.
#[test]
fn parallel_collect_is_bitwise_equal_to_serial_at_any_pool_size() {
    let run = |shards: usize, workers: usize| {
        let mut cfg = trainer_cfg();
        cfg.shards = shards;
        cfg.client_workers = workers;
        cfg.expose_aggregate = true;
        cfg.dropout_prob = 0.25;
        cfg.min_survivors = 2;
        cfg.rounds = 3;
        let mut t = Trainer::new(cfg).unwrap();
        let mut aggs = Vec::new();
        for r in 0..3 {
            aggs.push(t.run_round(r).unwrap().aggregate);
        }
        (aggs, t.global.data.clone())
    };
    let (want_aggs, want_global) = run(1, 1);
    for shards in [1usize, 2, 4] {
        for workers in [1usize, 4] {
            let (aggs, global) = run(shards, workers);
            for (round, (a, b)) in want_aggs.iter().zip(&aggs).enumerate() {
                let diff =
                    a.iter().zip(b).filter(|(x, y)| x.to_bits() != y.to_bits()).count();
                assert_eq!(
                    diff, 0,
                    "shards={shards} workers={workers} round {round}: \
                     {diff} aggregate positions differ from the serial fold"
                );
            }
            assert!(
                want_global.iter().zip(&global).all(|(x, y)| x.to_bits() == y.to_bits()),
                "shards={shards} workers={workers}: global diverged from serial"
            );
        }
    }
}
