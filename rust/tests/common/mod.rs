//! Shared transport-conformance harness (used by the
//! `transport_conformance` and `chaos_soak` integration tests).
//!
//! One `drive()` runs a full multi-round `Trainer` over a chosen
//! `--transport` and snapshots everything the conformance contract
//! pins: per-round survivor/dropped/straggler sets, abort flags, the
//! aggregate's exact f32 bits, and the ledger's payload + framed byte
//! meters. Two transports conform iff their snapshot vectors are
//! equal element-for-element.
#![allow(dead_code)] // each test crate uses a subset of the harness

use fedsparse::config::{RunConfig, TransportKind};
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::runtime::BackendKind;

/// Everything a round exposes that must be identical across
/// transports under the same (seed, plan, chaos) triple.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundSnapshot {
    pub round: u64,
    pub aborted: bool,
    pub survivors: Vec<u32>,
    pub dropped: Vec<u32>,
    pub stragglers: Vec<u32>,
    /// Exact bits of the applied aggregate (empty on aborted rounds
    /// or when `expose_aggregate` is off).
    pub agg_bits: Vec<u32>,
    /// Payload bytes metered this round (the golden `up_wire` meter).
    pub up_wire: u64,
    /// Payload + frame-header bytes (`up_framed`) — must match across
    /// transports because the in-process twin charges the same header
    /// a real socket writes.
    pub up_framed: u64,
}

/// Run `cfg` over `kind` for `cfg.rounds` rounds and snapshot each
/// round, plus the final global model bits.
pub fn drive(mut cfg: RunConfig, kind: TransportKind) -> (Vec<RoundSnapshot>, Vec<u32>) {
    cfg.transport = kind;
    let rounds = cfg.rounds;
    let mut t = Trainer::new(cfg).unwrap_or_else(|e| panic!("trainer({kind:?}): {e}"));
    let mut snaps = Vec::with_capacity(rounds as usize);
    for r in 0..rounds {
        let out = t
            .run_round(r)
            .unwrap_or_else(|e| panic!("round {r} over {kind:?}: {e}"));
        let cost = *t.ledger.rounds.last().expect("round recorded a cost row");
        snaps.push(RoundSnapshot {
            round: r,
            aborted: out.aborted,
            survivors: out.survivors.clone(),
            dropped: out.dropped.clone(),
            stragglers: out.stragglers.clone(),
            agg_bits: out.aggregate.iter().map(|v| v.to_bits()).collect(),
            up_wire: cost.up_wire,
            up_framed: cost.up_framed,
        });
    }
    let global_bits = t.global.data.iter().map(|v| v.to_bits()).collect();
    (snaps, global_bits)
}

/// Assert two transport runs produced identical snapshots, with a
/// failure message that names the divergent round and field.
pub fn assert_conformant(
    label: &str,
    (a, ga): &(Vec<RoundSnapshot>, Vec<u32>),
    (b, gb): &(Vec<RoundSnapshot>, Vec<u32>),
) {
    assert_eq!(a.len(), b.len(), "{label}: round counts differ");
    for (x, y) in a.iter().zip(b.iter()) {
        assert_eq!(
            x.aborted, y.aborted,
            "{label}: round {} abort flags differ",
            x.round
        );
        assert_eq!(
            x.survivors, y.survivors,
            "{label}: round {} survivor sets differ",
            x.round
        );
        assert_eq!(
            x.dropped, y.dropped,
            "{label}: round {} dropped sets differ",
            x.round
        );
        assert_eq!(
            x.stragglers, y.stragglers,
            "{label}: round {} straggler sets differ",
            x.round
        );
        assert_eq!(
            x.agg_bits, y.agg_bits,
            "{label}: round {} aggregates differ bitwise",
            x.round
        );
        assert_eq!(
            x.up_wire, y.up_wire,
            "{label}: round {} up_wire meters differ",
            x.round
        );
        assert_eq!(
            x.up_framed, y.up_framed,
            "{label}: round {} up_framed meters differ",
            x.round
        );
    }
    assert_eq!(ga, gb, "{label}: final global models differ bitwise");
}

/// Secure chaos config: the acceptance-criterion scenario. 4 secure
/// rounds, k-regular mask neighborhoods, seeded crashes + packet loss
/// + reordering, sharded fold, small native-backend model.
pub fn secure_chaos_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.secure = true;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.seed = seed;
    cfg.clients = 12;
    cfg.clients_per_round = 6;
    cfg.rounds = 4;
    cfg.eval_every = 99;
    cfg.expose_aggregate = true;
    cfg.neighbors_k = 3;
    cfg.mask_ratio_k = 0.5;
    cfg.dropout_prob = 0.25;
    cfg.min_survivors = 2;
    cfg.shards = 2;
    cfg.chaos_loss = 0.3;
    cfg.chaos_reorder = 0.5;
    cfg
}

/// Plain (non-secure) quantized-wire chaos config: exercises the
/// bitpacked codec path plus duplication and slow links.
pub fn quantized_chaos_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.seed = seed;
    cfg.rounds = 3;
    cfg.eval_every = 99;
    cfg.expose_aggregate = true;
    cfg.quant_bits = Some(4);
    cfg.dropout_prob = 0.2;
    cfg.min_survivors = 1;
    cfg.chaos_dup = 0.4;
    cfg.chaos_slow = 0.3;
    cfg.chaos_reorder = 0.3;
    cfg
}
