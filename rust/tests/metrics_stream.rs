//! Crash-safe metrics: a Trainer with a live CSV stream leaves a
//! parseable prefix on disk after every completed round, without any
//! end-of-run finalization — the ledger of a killed run survives.

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::runtime::BackendKind;

/// Every line of a metrics CSV must carry the full column set, with
/// the numeric columns actually numeric.
fn assert_parseable(text: &str, expect_rows: usize, label: &str) {
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 1 + expect_rows, "header + {expect_rows} rows");
    let cols = lines[0].split(',').count();
    assert!(lines[0].starts_with("label,round,"), "header: {}", lines[0]);
    for (i, line) in lines.iter().enumerate().skip(1) {
        let fields: Vec<&str> = line.split(',').collect();
        assert_eq!(fields.len(), cols, "row {i} field count: {line}");
        assert_eq!(fields[0], label, "row {i} label");
        assert_eq!(fields[1].parse::<u64>().unwrap(), (i - 1) as u64, "row {i} round");
        for (j, f) in fields.iter().enumerate().skip(2) {
            assert!(f.parse::<f64>().is_ok(), "row {i} col {j} not numeric: {f:?}");
        }
    }
}

#[test]
fn partially_driven_trainer_leaves_parseable_csv_prefix() {
    let dir = std::env::temp_dir().join(format!("fedsparse-stream-e2e-{}", std::process::id()));
    let path = dir.join("partial.csv");
    let _ = std::fs::remove_file(&path);

    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.rounds = 6; // the run "dies" after 3 of them
    cfg.eval_every = 2;
    let mut trainer = Trainer::new(cfg).unwrap();
    let label = trainer.cfg.run_label();
    trainer.recorder.stream_to(&path).unwrap();

    for round in 0..3u64 {
        trainer.run_round(round).unwrap();
        // the trainer is still live and holds the open sink — exactly
        // the state a crash would interrupt. The on-disk prefix must
        // already contain every completed round, fully parseable.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_parseable(&text, round as usize + 1, &label);
    }

    // the in-memory recorder and the streamed file agree row-for-row
    assert_eq!(trainer.recorder.rows.len(), 3);
    let text = std::fs::read_to_string(&path).unwrap();
    for (line, row) in text.lines().skip(1).zip(&trainer.recorder.rows) {
        let round: u64 = line.split(',').nth(1).unwrap().parse().unwrap();
        assert_eq!(round, row.round);
    }
}

/// The `--resume` half of crash-safe metrics: `resume_stream_to`
/// reconciles a killed run's CSV (possibly ending in a torn row, or
/// holding rows from rounds the checkpoint rolled back) with the
/// restored recorder — keeping the header, truncating the divergent
/// tail, and appending the missing rows — so the resumed file ends up
/// identical in shape to the uninterrupted twin's.
#[test]
fn kill_then_resume_csv_round_trip() {
    use fedsparse::metrics::recorder::{Recorder, RoundRecord};
    use std::io::Write;

    let dir = std::env::temp_dir().join(format!("fedsparse-stream-resume-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("resume.csv");
    let _ = std::fs::remove_file(&path);

    let row = |round: u64| RoundRecord { round, survivors: 4, ..Default::default() };

    // "killed" run: streams rounds 0..4, then dies mid-write of round 4
    let mut first = Recorder::new("unit");
    first.stream_to(&path).unwrap();
    for r in 0..4 {
        first.push(row(r));
    }
    drop(first);
    {
        let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
        f.write_all(b"unit,4,0.12").unwrap(); // torn row: no newline
    }

    // resume from a checkpoint taken after round 3: the recorder is
    // restored with rows 0..3 — round 3 was recorded on disk but rolled
    // back, and the torn round-4 fragment must go too
    let mut resumed = Recorder::new("unit");
    for r in 0..3 {
        resumed.rows.push(row(r));
    }
    resumed.resume_stream_to(&path).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    assert_parseable(&text, 3, "unit");

    // rounds 3..6 now re-run and append; no duplicate header, no
    // duplicate rows
    for r in 3..6 {
        resumed.push(row(r));
    }
    let text = std::fs::read_to_string(&path).unwrap();
    assert_parseable(&text, 6, "unit");
    assert_eq!(text.matches("label,round").count(), 1, "exactly one header");
}

#[test]
fn resume_stream_to_handles_missing_and_headerless_files() {
    let dir = std::env::temp_dir().join(format!("fedsparse-stream-edge-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    use fedsparse::metrics::recorder::{Recorder, RoundRecord};

    // missing file: behaves like stream_to (backlog written, header once)
    let missing = dir.join("missing.csv");
    let _ = std::fs::remove_file(&missing);
    let mut rec = Recorder::new("unit");
    rec.rows.push(RoundRecord { round: 0, ..Default::default() });
    rec.resume_stream_to(&missing).unwrap();
    assert_parseable(&std::fs::read_to_string(&missing).unwrap(), 1, "unit");

    // a file killed mid-header (no newline at all): started over
    let torn = dir.join("torn-header.csv");
    std::fs::write(&torn, "label,rou").unwrap();
    let mut rec = Recorder::new("unit");
    rec.rows.push(RoundRecord { round: 0, ..Default::default() });
    rec.resume_stream_to(&torn).unwrap();
    assert_parseable(&std::fs::read_to_string(&torn).unwrap(), 1, "unit");

    // a complete but foreign header: refused, not silently rewritten
    let foreign = dir.join("foreign.csv");
    std::fs::write(&foreign, "time,value\n1,2\n").unwrap();
    let mut rec = Recorder::new("unit");
    let err = rec.resume_stream_to(&foreign).unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
}
