//! Crash-safe metrics: a Trainer with a live CSV stream leaves a
//! parseable prefix on disk after every completed round, without any
//! end-of-run finalization — the ledger of a killed run survives.

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::runtime::BackendKind;

/// Every line of a metrics CSV must carry the full column set, with
/// the numeric columns actually numeric.
fn assert_parseable(text: &str, expect_rows: usize, label: &str) {
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 1 + expect_rows, "header + {expect_rows} rows");
    let cols = lines[0].split(',').count();
    assert!(lines[0].starts_with("label,round,"), "header: {}", lines[0]);
    for (i, line) in lines.iter().enumerate().skip(1) {
        let fields: Vec<&str> = line.split(',').collect();
        assert_eq!(fields.len(), cols, "row {i} field count: {line}");
        assert_eq!(fields[0], label, "row {i} label");
        assert_eq!(fields[1].parse::<u64>().unwrap(), (i - 1) as u64, "row {i} round");
        for (j, f) in fields.iter().enumerate().skip(2) {
            assert!(f.parse::<f64>().is_ok(), "row {i} col {j} not numeric: {f:?}");
        }
    }
}

#[test]
fn partially_driven_trainer_leaves_parseable_csv_prefix() {
    let dir = std::env::temp_dir().join(format!("fedsparse-stream-e2e-{}", std::process::id()));
    let path = dir.join("partial.csv");
    let _ = std::fs::remove_file(&path);

    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.rounds = 6; // the run "dies" after 3 of them
    cfg.eval_every = 2;
    let mut trainer = Trainer::new(cfg).unwrap();
    let label = trainer.cfg.run_label();
    trainer.recorder.stream_to(&path).unwrap();

    for round in 0..3u64 {
        trainer.run_round(round).unwrap();
        // the trainer is still live and holds the open sink — exactly
        // the state a crash would interrupt. The on-disk prefix must
        // already contain every completed round, fully parseable.
        let text = std::fs::read_to_string(&path).unwrap();
        assert_parseable(&text, round as usize + 1, &label);
    }

    // the in-memory recorder and the streamed file agree row-for-row
    assert_eq!(trainer.recorder.rows.len(), 3);
    let text = std::fs::read_to_string(&path).unwrap();
    for (line, row) in text.lines().skip(1).zip(&trainer.recorder.rows) {
        let round: u64 = line.split(',').nth(1).unwrap().parse().unwrap();
        assert_eq!(round, row.round);
    }
}
