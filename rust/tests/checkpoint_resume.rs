//! Resume determinism: a run killed mid-way and resumed from its
//! newest checkpoint must finish bitwise-identical to the
//! uninterrupted twin — in plain mode and in secure
//! (k-regular, dropout + Shamir recovery) mode. This is the
//! load-bearing contract of `io/checkpoint.rs`: every RNG stream is
//! pure in (seed, round, cid), so restoring the cross-round mutable
//! state is sufficient.

use std::fs;
use std::path::PathBuf;

use fedsparse::config::RunConfig;
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::metrics::recorder::RoundRecord;
use fedsparse::runtime::BackendKind;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fedsparse-resume-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// Plain-mode config exercising every piece of checkpointed client
/// state: residuals (sparse algorithm), Eq. 2 rate controller, DGC
/// momentum velocity.
fn plain_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.seed = seed;
    cfg.rounds = 6;
    cfg.eval_every = 2;
    cfg.dynamic_rate = true;
    cfg.momentum = 0.5;
    cfg
}

/// Secure k-regular config with failure injection: dropout, Shamir
/// mask recovery, per-round re-keying, sharded fold. The re-keying
/// registry is deliberately NOT checkpointed — this test is what pins
/// that the reconstructed secrets are byte-identical anyway.
fn secure_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::smoke("mnist_mlp");
    cfg.backend = BackendKind::Native;
    cfg.data_dir = None;
    cfg.algorithm = Algorithm::FlatSparse { s: 0.05 };
    cfg.seed = seed;
    cfg.rounds = 6;
    cfg.eval_every = 2;
    cfg.secure = true;
    cfg.clients = 12;
    cfg.clients_per_round = 6;
    cfg.neighbors_k = 3;
    cfg.mask_ratio_k = 0.5;
    cfg.dropout_prob = 0.25;
    cfg.min_survivors = 2;
    cfg.shards = 2;
    cfg
}

fn global_bits(t: &Trainer) -> Vec<u32> {
    t.global.data.iter().map(|v| v.to_bits()).collect()
}

/// Deterministic row fields only — the `timings` block is wall-clock
/// and legitimately differs between twins. Floats compare by bits so
/// NaN (non-eval rounds) compares equal.
fn assert_rows_eq(label: &str, a: &[RoundRecord], b: &[RoundRecord]) {
    assert_eq!(a.len(), b.len(), "{label}: row counts differ");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.round, y.round, "{label}: row order");
        let r = x.round;
        assert_eq!(x.train_loss.to_bits(), y.train_loss.to_bits(), "{label} r{r}: train_loss");
        assert_eq!(x.eval_loss.to_bits(), y.eval_loss.to_bits(), "{label} r{r}: eval_loss");
        assert_eq!(
            x.eval_accuracy.to_bits(),
            y.eval_accuracy.to_bits(),
            "{label} r{r}: eval_accuracy"
        );
        assert_eq!(x.up_bytes, y.up_bytes, "{label} r{r}: up_bytes");
        assert_eq!(x.wire_bytes, y.wire_bytes, "{label} r{r}: wire_bytes");
        assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{label} r{r}: sim_time_s");
        assert_eq!(x.mean_rate.to_bits(), y.mean_rate.to_bits(), "{label} r{r}: mean_rate");
        assert_eq!(x.survivors, y.survivors, "{label} r{r}: survivors");
        assert_eq!(x.recovered, y.recovered, "{label} r{r}: recovered");
    }
}

fn assert_costs_eq(label: &str, a: &Trainer, b: &Trainer) {
    assert_eq!(a.ledger.rounds.len(), b.ledger.rounds.len(), "{label}: cost row counts");
    for (x, y) in a.ledger.rounds.iter().zip(&b.ledger.rounds) {
        let r = x.round;
        assert_eq!(x.round, y.round, "{label}: cost order");
        assert_eq!(x.up_paper, y.up_paper, "{label} r{r}: up_paper");
        assert_eq!(x.up_wire, y.up_wire, "{label} r{r}: up_wire");
        assert_eq!(x.up_framed, y.up_framed, "{label} r{r}: up_framed");
        assert_eq!(x.down_paper, y.down_paper, "{label} r{r}: down_paper");
        assert_eq!(x.accuracy.to_bits(), y.accuracy.to_bits(), "{label} r{r}: accuracy");
    }
}

/// Kill-then-resume twin comparison: run `cfg` uninterrupted, run it
/// again but drop the trainer after `kill_after` rounds, resume from
/// the checkpoint directory, and require bitwise-equal outcomes.
fn twin_check(label: &str, cfg: RunConfig, kill_after: u64, checkpoint_every: u64) {
    // the uninterrupted twin
    let mut twin = Trainer::new(cfg.clone()).unwrap();
    twin.run().unwrap();

    // the killed run
    let dir = tmp_dir(label);
    let mut killed_cfg = cfg.clone();
    killed_cfg.checkpoint_dir = Some(dir.clone());
    killed_cfg.checkpoint_every = checkpoint_every;
    let mut killed = Trainer::new(killed_cfg.clone()).unwrap();
    for round in 0..kill_after {
        killed.run_round(round).unwrap();
    }
    drop(killed); // SIGKILL stand-in: no graceful teardown path runs

    // the resumed run
    let mut resumed_cfg = killed_cfg;
    resumed_cfg.resume = true;
    let mut resumed = Trainer::new(resumed_cfg).unwrap();
    let start = resumed.start_round();
    assert!(
        start > 0 && start <= kill_after,
        "{label}: resumed at {start}, expected within (0, {kill_after}]"
    );
    resumed.run().unwrap();

    assert_eq!(global_bits(&twin), global_bits(&resumed), "{label}: final global model bits");
    assert_rows_eq(label, &twin.recorder.rows, &resumed.recorder.rows);
    assert_costs_eq(label, &twin, &resumed);
}

#[test]
fn plain_resume_is_bitwise_identical_to_twin() {
    // checkpoint_every = 1, no failure injection: the resume point is
    // exactly the kill point
    let cfg = plain_cfg(17);
    twin_check("plain", cfg.clone(), 3, 1);

    let dir = tmp_dir("plain-exact");
    let mut killed_cfg = cfg;
    killed_cfg.checkpoint_dir = Some(dir);
    let mut killed = Trainer::new(killed_cfg.clone()).unwrap();
    for round in 0..3 {
        killed.run_round(round).unwrap();
    }
    drop(killed);
    let mut resumed_cfg = killed_cfg;
    resumed_cfg.resume = true;
    let resumed = Trainer::new(resumed_cfg).unwrap();
    assert_eq!(resumed.start_round(), 3, "every round applied ⇒ resume at the kill point");
}

#[test]
fn secure_resume_is_bitwise_identical_to_twin() {
    // checkpoint_every = 2 and dropout: some rounds may abort (no
    // commit), so the resume point is the newest applied commit ≤ 4 —
    // the replayed rounds must land bit-identically too
    twin_check("secure", secure_cfg(23), 4, 2);
}

#[test]
fn resume_with_no_checkpoint_starts_fresh() {
    let dir = tmp_dir("fresh");
    let mut cfg = plain_cfg(5);
    cfg.rounds = 2;
    cfg.checkpoint_dir = Some(dir);
    cfg.resume = true;
    let t = Trainer::new(cfg).unwrap();
    assert_eq!(t.start_round(), 0, "empty checkpoint dir ⇒ fresh start, not an error");
}

#[test]
fn resume_rejects_mismatched_config() {
    let dir = tmp_dir("mismatch");
    let mut cfg = plain_cfg(7);
    cfg.rounds = 3;
    cfg.checkpoint_dir = Some(dir.clone());
    let mut t = Trainer::new(cfg.clone()).unwrap();
    t.run().unwrap();
    drop(t);

    // same label, different seed: must be refused, not silently resumed
    let mut other = cfg;
    other.seed = 8;
    other.resume = true;
    let err = Trainer::new(other).err().expect("seed mismatch accepted");
    assert!(format!("{err:#}").contains("seed"), "unhelpful error: {err:#}");
}

#[test]
fn aborted_rounds_do_not_commit_checkpoints() {
    let dir = tmp_dir("abort");
    let mut cfg = secure_cfg(31);
    cfg.rounds = 3;
    cfg.dropout_prob = 0.85;
    cfg.min_survivors = cfg.clients_per_round; // any death aborts
    cfg.checkpoint_dir = Some(dir.clone());
    let mut t = Trainer::new(cfg).unwrap();
    let mut aborted = 0;
    for round in 0..3 {
        if t.run_round(round).unwrap().aborted {
            aborted += 1;
        }
    }
    let snapshots = fs::read_dir(&dir)
        .unwrap()
        .flatten()
        .filter(|e| e.file_name().to_string_lossy().ends_with(".fsckpt"))
        .count();
    assert_eq!(
        snapshots as u64,
        3 - aborted,
        "exactly the applied rounds commit (aborted {aborted}/3)"
    );
}
