//! Wire-codec robustness properties: decoding attacker- or
//! line-noise-shaped bytes must never panic, never abort (no
//! unbounded allocation from a garbage header), and never leave
//! partial output in the reused scratch — every malformed input is a
//! clean `Err`. Covers the f32 frame, the deflate-compressed frame,
//! and the bitpacked quantized v1 frame, under seeded truncations,
//! bit flips, and pure-garbage buffers.
//!
//! The properties are deliberately asymmetric:
//! * **Truncation** of a valid frame is *always* an error (every
//!   suffix of the byte stream is load-bearing).
//! * **Bit flips** may legitimately still decode — flipping a bit
//!   inside an f32 value or the scale field yields a different but
//!   well-formed frame — so flips only assert no-panic and
//!   cleared-output-on-`Err`.

use fedsparse::sparse::codec::SparseVec;
use fedsparse::sparse::quant::{quantize, QuantConfig, QuantizedSparse};
use fedsparse::util::rng::Rng;

fn sample_sparse(seed: u64, n: u32, frac: f64) -> SparseVec {
    let mut rng = Rng::new(seed);
    let dense: Vec<f32> = (0..n)
        .map(|_| if rng.next_f64() < frac { rng.normal_f32(1.0) } else { 0.0 })
        .collect();
    SparseVec::from_dense(&dense)
}

/// Decode `bytes` as an f32 frame into a dirty scratch and check the
/// partial-output contract: `Err` ⇒ scratch fully cleared.
fn check_f32(bytes: &[u8]) -> bool {
    let mut out = SparseVec {
        n: 123,
        indices: vec![1, 2, 3],
        values: vec![0.5, 0.25, 0.125],
    };
    let ok = SparseVec::decode_into(bytes, &mut out).is_ok();
    if !ok {
        assert_eq!(out.n, 0, "partial n after f32 decode error");
        assert!(out.indices.is_empty(), "partial indices after f32 decode error");
        assert!(out.values.is_empty(), "partial values after f32 decode error");
    }
    ok
}

/// Same contract for the quantized v1 frame.
fn check_quant(bytes: &[u8]) -> bool {
    let mut out = QuantizedSparse {
        n: 123,
        indices: vec![1, 2, 3],
        codes: vec![1, -2, 3],
        scale: 7.0,
        bits: 4,
    };
    let ok = QuantizedSparse::decode_into(bytes, &mut out).is_ok();
    if !ok {
        assert_eq!(out.n, 0, "partial n after quant decode error");
        assert!(out.indices.is_empty(), "partial indices after quant decode error");
        assert!(out.codes.is_empty(), "partial codes after quant decode error");
    }
    ok
}

#[test]
fn every_truncation_of_a_valid_frame_errors() {
    let sv = sample_sparse(11, 4096, 0.03);
    let f32_frame = sv.encode();
    let mut qrng = Rng::new(12);
    let q = quantize(&sv, QuantConfig { bits: 4 }, &mut qrng);
    let quant_frame = q.encode();

    for cut in 0..f32_frame.len() {
        assert!(
            !check_f32(&f32_frame[..cut]),
            "f32 frame truncated to {cut}/{} bytes decoded",
            f32_frame.len()
        );
    }
    for cut in 0..quant_frame.len() {
        assert!(
            !check_quant(&quant_frame[..cut]),
            "quant frame truncated to {cut}/{} bytes decoded",
            quant_frame.len()
        );
    }
}

#[test]
fn seeded_bit_flips_never_panic_and_errors_leave_no_partial_output() {
    let sv = sample_sparse(21, 2048, 0.05);
    let f32_frame = sv.encode();
    let mut qrng = Rng::new(22);
    let q = quantize(&sv, QuantConfig { bits: 3 }, &mut qrng);
    let quant_frame = q.encode();

    let mut rng = Rng::new(0xf11b);
    for _ in 0..2000 {
        let mut mutant = f32_frame.clone();
        // 1-3 random bit flips
        for _ in 0..(1 + rng.below(3)) {
            let byte = rng.below(mutant.len() as u64) as usize;
            mutant[byte] ^= 1 << rng.below(8);
        }
        check_f32(&mutant);
        let mut mutant = quant_frame.clone();
        for _ in 0..(1 + rng.below(3)) {
            let byte = rng.below(mutant.len() as u64) as usize;
            mutant[byte] ^= 1 << rng.below(8);
        }
        check_quant(&mutant);
    }
}

#[test]
fn pure_garbage_never_panics() {
    let mut rng = Rng::new(0x6a5b);
    for _ in 0..2000 {
        let len = rng.below(257) as usize;
        let garbage: Vec<u8> = (0..len).map(|_| rng.next_u32() as u8).collect();
        check_f32(&garbage);
        check_quant(&garbage);
        // compressed path: garbage is both an invalid deflate stream
        // and, when it inflates, usually an invalid frame — either way
        // the contract is Err-or-valid, never a panic
        let _ = SparseVec::decode_compressed(&garbage);
    }
}

#[test]
fn garbage_headers_cannot_drive_huge_allocations() {
    // nnz = u32::MAX with a tiny body: the codec must bound nnz by the
    // remaining payload length *before* reserving, or a 16-byte frame
    // could request gigabytes.
    let mut frame = Vec::new();
    frame.extend_from_slice(&100u32.to_le_bytes());
    frame.extend_from_slice(&u32::MAX.to_le_bytes());
    frame.extend_from_slice(&[0u8; 8]);
    assert!(!check_f32(&frame));

    let mut qframe = vec![1u8, 4]; // version, bits
    qframe.extend_from_slice(&100u32.to_le_bytes());
    qframe.extend_from_slice(&u32::MAX.to_le_bytes());
    qframe.extend_from_slice(&1.0f32.to_le_bytes());
    qframe.extend_from_slice(&[0u8; 8]);
    assert!(!check_quant(&qframe));
}

#[test]
fn truncated_compressed_frames_error() {
    let sv = sample_sparse(31, 1024, 0.05);
    let comp = sv.encode_compressed();
    // decoded interior truncations: the inflated stream is a truncated
    // raw frame, which the inner decoder must reject
    for cut in [0, 1, comp.len() / 4, comp.len() / 2, comp.len() - 1] {
        assert!(
            SparseVec::decode_compressed(&comp[..cut]).is_err(),
            "compressed frame truncated to {cut}/{} bytes decoded",
            comp.len()
        );
    }
}
