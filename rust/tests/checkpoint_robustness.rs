//! Checkpoint codec + store robustness: no input — truncated, torn,
//! bit-flipped, or garbage — may panic the loader, and the store must
//! always fall back to the newest *valid* snapshot (quarantining, not
//! deleting, bad files).

use std::fs;
use std::path::PathBuf;

use fedsparse::io::atomic::Tear;
use fedsparse::io::checkpoint::{
    decode, encode, Checkpoint, CheckpointError, CheckpointStore, ClientCheckpoint,
};
use fedsparse::metrics::recorder::{PhaseTimings, RoundRecord};
use fedsparse::util::rng::Rng;

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("fedsparse-ckpt-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    fs::create_dir_all(&d).unwrap();
    d
}

/// A representative checkpoint exercising every optional branch of the
/// format. No NaN fields — `Checkpoint: PartialEq` is IEEE field-wise,
/// so the round-trip assertion needs comparable values.
fn sample_checkpoint(next_round: u64) -> Checkpoint {
    Checkpoint {
        label: format!("unit-run-{next_round}"),
        seed: 42,
        config_digest: "d".repeat(64),
        next_round,
        global_tensors: vec![(0, 6), (6, 2)],
        global_data: vec![0.5, -1.25, 3.75, 0.0, -0.0, 2.5e-3, 1.0, -7.0],
        clients: vec![
            ClientCheckpoint {
                last_loss: 0.75,
                participation: 3,
                residual_buf: vec![0.1, -0.2, 0.0, 4.5],
                residual_age: vec![0, 2, 7, 1],
                rate: Some((0.05, Some(1.5))),
                momentum_velocity: Some(vec![0.01, -0.02, 0.03, 0.0]),
            },
            ClientCheckpoint {
                last_loss: 1.25,
                participation: 0,
                residual_buf: vec![0.0; 4],
                residual_age: vec![0; 4],
                rate: Some((0.1, None)),
                momentum_velocity: None,
            },
            ClientCheckpoint {
                last_loss: 2.0,
                participation: 9,
                residual_buf: vec![1.0, 2.0, 3.0, 4.0],
                residual_age: vec![1, 1, 1, 1],
                rate: None,
                momentum_velocity: None,
            },
        ],
        rows: vec![RoundRecord {
            round: next_round.saturating_sub(1),
            train_loss: 0.9,
            eval_loss: 0.8,
            eval_accuracy: 0.65,
            up_bytes: 1234,
            wire_bytes: 999,
            sim_time_s: 0.25,
            mean_rate: 0.05,
            survivors: 5,
            recovered: 2,
            timings: PhaseTimings::default(),
        }],
        costs: vec![fedsparse::comm::cost::RoundCost {
            round: next_round.saturating_sub(1),
            up_paper: 1234,
            up_wire: 999,
            up_framed: 1031,
            down_paper: 4096,
            accuracy: 0.65,
        }],
    }
}

#[test]
fn round_trips_bitwise() {
    let ck = sample_checkpoint(4);
    let bytes = encode(&ck);
    let back = decode(&bytes).unwrap();
    assert_eq!(back, ck);
    // encoding is deterministic: same checkpoint, same bytes
    assert_eq!(encode(&back), bytes);
}

#[test]
fn every_strict_prefix_errors_cleanly() {
    let bytes = encode(&sample_checkpoint(2));
    for cut in 0..bytes.len() {
        let res = decode(&bytes[..cut]);
        assert!(res.is_err(), "prefix of {cut}/{} bytes decoded successfully", bytes.len());
    }
}

#[test]
fn seeded_bit_flips_never_panic_and_always_err() {
    let bytes = encode(&sample_checkpoint(3));
    let mut rng = Rng::new(0xc4ec);
    for _ in 0..2000 {
        let mut b = bytes.clone();
        let i = rng.below(b.len() as u64) as usize;
        b[i] ^= 1 << rng.below(8);
        // a single bit flip always lands in the magic, version,
        // length, hash, or hashed body — every case must be rejected
        assert!(decode(&b).is_err(), "bit flip at byte {i} went undetected");
    }
}

#[test]
fn garbage_never_panics() {
    let mut rng = Rng::new(0x6a4b);
    for len in [0usize, 1, 4, 47, 48, 49, 200, 4096] {
        let buf: Vec<u8> = (0..len).map(|_| rng.below(256) as u8).collect();
        let _ = decode(&buf);
        // garbage with a plausible header prefix
        let mut with_magic = buf.clone();
        if with_magic.len() >= 8 {
            with_magic[..4].copy_from_slice(b"FSCP");
            with_magic[4..8].copy_from_slice(&1u32.to_le_bytes());
        }
        let _ = decode(&with_magic);
    }
}

#[test]
fn trailing_bytes_rejected() {
    let mut bytes = encode(&sample_checkpoint(1));
    bytes.push(0);
    assert!(matches!(decode(&bytes), Err(CheckpointError::Malformed(_))));
}

#[test]
fn unsupported_version_named_in_error() {
    let mut bytes = encode(&sample_checkpoint(1));
    bytes[4..8].copy_from_slice(&9u32.to_le_bytes());
    assert!(matches!(decode(&bytes), Err(CheckpointError::UnsupportedVersion(9))));
}

#[test]
fn loader_falls_back_to_newest_valid_snapshot() {
    let dir = tmp_dir("fallback");
    let store = CheckpointStore::open(&dir).unwrap();
    assert!(store.save(&sample_checkpoint(1)).unwrap());
    assert!(store.save(&sample_checkpoint(2)).unwrap());
    // newest snapshot lands corrupted: flip a byte inside the sha256
    let mut bad = encode(&sample_checkpoint(3));
    bad[40] ^= 0xff;
    fs::write(store.path_for(3), &bad).unwrap();

    let (ck, path) = store.load_latest().expect("fallback snapshot");
    assert_eq!(ck.next_round, 2, "fell back past the corrupt newest snapshot");
    assert_eq!(path, store.path_for(2));
    // the corrupt file was quarantined, not deleted
    assert!(!store.path_for(3).exists());
    let quarantined = dir.join("ckpt_00000003.fsckpt.corrupt");
    assert!(quarantined.exists(), "corrupt snapshot preserved for forensics");
    assert_eq!(fs::read(quarantined).unwrap(), bad);
}

#[test]
fn loader_returns_none_on_empty_or_all_corrupt() {
    let dir = tmp_dir("none");
    let store = CheckpointStore::open(&dir).unwrap();
    assert!(store.load_latest().is_none());
    fs::write(store.path_for(1), b"not a checkpoint").unwrap();
    assert!(store.load_latest().is_none());
    assert!(dir.join("ckpt_00000001.fsckpt.corrupt").exists());
}

#[test]
fn torn_write_at_every_commit_step_leaves_loadable_state() {
    let dir = tmp_dir("torn");
    let store = CheckpointStore::open(&dir).unwrap();
    let a = sample_checkpoint(1);
    assert!(store.save(&a).unwrap());

    let b = sample_checkpoint(2);
    let len = encode(&b).len();
    let tears = [
        Tear::Partial { keep: 0 },
        Tear::Partial { keep: 1 },
        Tear::Partial { keep: 16 },
        Tear::Partial { keep: 47 },
        Tear::Partial { keep: 48 },
        Tear::Partial { keep: len / 2 },
        Tear::Partial { keep: len - 1 },
        Tear::BeforeRename,
    ];
    for tear in tears {
        assert!(!store.save_with(&b, Some(tear)).unwrap(), "{tear:?} reported a full commit");
        // the committed name was never touched; the newest valid
        // snapshot is still A
        let (ck, _) = store.load_latest().expect("prior snapshot survives the torn commit");
        assert_eq!(ck, a, "torn commit ({tear:?}) disturbed the committed snapshot set");
    }

    // the retried (un-torn) commit goes through
    assert!(store.save(&b).unwrap());
    let (ck, _) = store.load_latest().unwrap();
    assert_eq!(ck, b);
}
