//! Rust-hot-path ↔ Pallas-kernel parity.
//!
//! The round loop uses the rust implementations of the sparsify and
//! masked-aggregate sweeps for speed; the Pallas kernels (AOT-exported
//! standalone) define the reference semantics and are the TPU
//! deployment path. These tests prove the two produce **bitwise
//! identical** results, so the choice is purely an execution-placement
//! decision (DESIGN.md §Artifact set).
//!
//! The whole file needs the PJRT execution path, so it only compiles
//! under the `pjrt` feature (and still skips at runtime when `make
//! artifacts` has not produced the kernels).
#![cfg(feature = "pjrt")]

use std::path::PathBuf;

use fedsparse::models::manifest::Manifest;
use fedsparse::runtime::{ExecutorPool, KernelRunner};
use fedsparse::sparse::flat::apply_threshold;
use fedsparse::util::rng::Rng;

fn kernel_runner() -> Option<(KernelRunner, Vec<usize>)> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        return None;
    }
    let manifest = Manifest::load(&dir).unwrap();
    let pool = ExecutorPool::new(2);
    let runner = KernelRunner::new(&pool, &manifest);
    let sizes = runner.sparsify_sizes();
    // pool must outlive the runner's handle uses — leak it for the test
    std::mem::forget(pool);
    Some((runner, sizes))
}

#[test]
fn sparsify_bitwise_parity_all_sizes() {
    let Some((runner, sizes)) = kernel_runner() else { return };
    for &n in &sizes {
        let mut rng = Rng::new(n as u64);
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
        for thr in [0.0f32, 0.5, 1.5, 100.0] {
            let (pallas_s, pallas_r) = runner.sparsify(&g, thr).unwrap();
            let rust = apply_threshold(&g, thr);
            assert_eq!(pallas_s, rust.sparse, "sparse mismatch n={n} thr={thr}");
            assert_eq!(pallas_r, rust.residual, "residual mismatch n={n} thr={thr}");
        }
    }
}

#[test]
fn sparsify_parity_on_adversarial_values() {
    let Some((runner, sizes)) = kernel_runner() else { return };
    let n = sizes[0];
    // denormals, exact-threshold ties, infinities-free extremes
    let mut g = vec![0f32; n];
    g[0] = 1.0;
    g[1] = -1.0;
    g[2] = 1.0 + f32::EPSILON;
    g[3] = f32::MIN_POSITIVE;
    g[4] = -f32::MIN_POSITIVE;
    g[5] = 3.4e38;
    g[6] = -3.4e38;
    let (pallas_s, pallas_r) = runner.sparsify(&g, 1.0).unwrap();
    let rust = apply_threshold(&g, 1.0);
    assert_eq!(pallas_s, rust.sparse);
    assert_eq!(pallas_r, rust.residual);
    // ties (|g| == thr) go to the residual on BOTH paths
    assert_eq!(pallas_s[0], 0.0);
    assert_eq!(pallas_r[0], 1.0);
}

#[test]
fn masked_agg_bitwise_parity() {
    let Some((runner, _)) = kernel_runner() else { return };
    let n = 16_384;
    let mut rng = Rng::new(99);
    let acc: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
    let contrib: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
    let mask: Vec<f32> = (0..n).map(|_| (rng.next_u64() % 2) as f32).collect();

    let pallas = runner.masked_agg(&acc, &contrib, &mask).unwrap();
    let rust: Vec<f32> = (0..n).map(|i| acc[i] + contrib[i] * mask[i]).collect();
    assert_eq!(pallas, rust);
}

#[test]
fn topk_threshold_then_pallas_apply_equals_flat_sparsify() {
    // the full Alg.1 pipeline split across layers: rust top-k selection
    // feeding the pallas application must equal the rust flat sparsifier
    let Some((runner, sizes)) = kernel_runner() else { return };
    let n = sizes[0];
    let mut rng = Rng::new(123);
    let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(2.0)).collect();
    let s = 0.03;
    let k = ((n as f64 * s).ceil() as usize).max(1);
    let thr = fedsparse::sparse::topk::threshold_for_topk_abs(&g, k);

    let (pallas_s, _) = runner.sparsify(&g, thr).unwrap();
    let flat = fedsparse::sparse::flat::flat_topk_sparsify(&g, s);
    assert_eq!(pallas_s, flat.sparse);
}
