//! Conventional flat Top-k sparsification (Dryden et al. 2016) — the
//! baseline the paper's §5.1 calls "- spark": the whole update vector
//! is flattened and a single global Top-k is applied.
//!
//! This is exactly the failure mode §1 motivates THGS with: layers
//! whose parameters are orders of magnitude smaller are starved by a
//! global threshold.

use super::topk::threshold_for_topk_abs_with;

/// Result of a sparsification pass.
#[derive(Clone, Debug, Default)]
pub struct SparsifyOut {
    /// Dense vector with unkept entries zeroed (`g̃ ⊙ g` of Alg. 1).
    pub sparse: Vec<f32>,
    /// The complement, accumulated locally (`w_residual`).
    pub residual: Vec<f32>,
    /// Number of kept (non-zero) entries.
    pub nnz: usize,
    /// The threshold(s) used — one per layer group (flat = 1 entry).
    pub thresholds: Vec<f32>,
}

/// Flat Top-k: keep the `⌈s·n⌉` largest-magnitude entries of the whole
/// vector (strictly greater than the k-th magnitude; ties dropped to
/// the residual, matching Alg. 1's `torch.where(|g| > δ)` semantics).
pub fn flat_topk_sparsify(g: &[f32], s: f64) -> SparsifyOut {
    let mut out = SparsifyOut::default();
    flat_topk_sparsify_into(g, s, &mut Vec::new(), &mut out);
    out
}

/// [`flat_topk_sparsify`] into caller-owned scratch + output: the
/// selection magnitudes land in `scratch`, the split reuses `out`'s
/// buffers — the zero-allocation sparsify path.
pub fn flat_topk_sparsify_into(g: &[f32], s: f64, scratch: &mut Vec<f32>, out: &mut SparsifyOut) {
    let n = g.len();
    assert!(n > 0, "flat_topk_sparsify on empty update");
    assert!((0.0..=1.0).contains(&s), "sparsity rate {s} outside [0,1]");
    let k = ((n as f64 * s).ceil() as usize).clamp(1, n);
    let delta = threshold_for_topk_abs_with(g, k, scratch);
    apply_threshold_into(g, delta, out);
}

/// Threshold application sweep (the rust twin of the pallas
/// `sparsify` kernel; parity is asserted in `rust/tests/pallas_parity.rs`).
pub fn apply_threshold(g: &[f32], delta: f32) -> SparsifyOut {
    let mut out = SparsifyOut::default();
    apply_threshold_into(g, delta, &mut out);
    out
}

/// [`apply_threshold`] into a caller-owned [`SparsifyOut`] (buffers
/// resized + rewritten; identical results).
pub fn apply_threshold_into(g: &[f32], delta: f32, out: &mut SparsifyOut) {
    out.sparse.clear();
    out.sparse.resize(g.len(), 0.0);
    out.residual.clear();
    out.residual.resize(g.len(), 0.0);
    out.thresholds.clear();
    out.thresholds.push(delta);
    let mut nnz = 0usize;
    for i in 0..g.len() {
        let x = g[i];
        if x.abs() > delta {
            out.sparse[i] = x;
            nnz += 1;
        } else {
            out.residual[i] = x;
        }
    }
    out.nnz = nnz;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32(1.0)).collect()
    }

    #[test]
    fn split_reconstructs_exactly() {
        let g = rand_vec(1, 5000);
        let out = flat_topk_sparsify(&g, 0.01);
        for i in 0..g.len() {
            assert_eq!(out.sparse[i] + out.residual[i], g[i]);
            assert!(out.sparse[i] == 0.0 || out.residual[i] == 0.0);
        }
    }

    #[test]
    fn nnz_close_to_k() {
        let g = rand_vec(2, 10_000);
        let out = flat_topk_sparsify(&g, 0.01);
        // strict-> ties dropped, so nnz ≤ k; with continuous data nnz == k-ish
        assert!(out.nnz <= 100);
        assert!(out.nnz >= 95, "nnz={}", out.nnz);
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let g = vec![0.1f32, -3.0, 0.2, 2.0, -0.05, 1.0];
        // k=3 → δ = 1.0; strict > keeps the two entries above it
        let out = flat_topk_sparsify(&g, 0.5);
        assert_eq!(out.nnz, 2);
        assert_eq!(out.sparse[1], -3.0);
        assert_eq!(out.sparse[3], 2.0);
    }

    #[test]
    fn s_one_keeps_everything_nonzero_magnitude() {
        let g = vec![1.0f32, -2.0, 3.0];
        let out = flat_topk_sparsify(&g, 1.0);
        // delta = min |g| = 1.0; strict > drops the minimum into residual
        assert_eq!(out.nnz, 2);
        assert_eq!(out.residual[0], 1.0);
    }

    #[test]
    fn tiny_s_keeps_at_least_one() {
        let g = rand_vec(3, 1000);
        let out = flat_topk_sparsify(&g, 1e-9);
        assert!(out.nnz <= 1);
        assert_eq!(out.thresholds.len(), 1);
    }

    #[test]
    #[should_panic(expected = "outside [0,1]")]
    fn rejects_bad_rate() {
        flat_topk_sparsify(&[1.0], 1.5);
    }
}
