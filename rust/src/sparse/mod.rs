//! Gradient sparsification — the paper's first contribution (§3.1).
//!
//! * [`topk`] — O(N) quickselect threshold for Top-k selection
//! * [`flat`] — conventional whole-vector Top-k (Dryden'16 baseline)
//! * [`thgs`] — Time-varying Hierarchical Gradient Sparsification
//!   (Algorithm 1): per-layer Top-k with layer-decaying sparsity rate
//! * [`residual`] — local accumulation of unsent gradient mass
//! * [`codec`] — sparse index/value encoding + the paper's Eq. 6
//!   96-bit communication cost model
//! * [`dynamic`] — the Eq. 2 loss-driven dynamic sparsity-rate
//!   controller used by the secure path

pub mod codec;
pub mod dynamic;
pub mod flat;
pub mod momentum;
pub mod quant;
pub mod residual;
pub mod stc;
pub mod thgs;
pub mod topk;

pub use codec::SparseVec;
pub use momentum::{warmup_rate, MomentumCorrector};
pub use quant::{dequantize, quantize, QuantConfig, QuantizedSparse};
pub use stc::stc_sparsify;
pub use dynamic::DynamicRate;
pub use flat::{flat_topk_sparsify, flat_topk_sparsify_into};
pub use residual::ResidualStore;
pub use thgs::{layer_rates, thgs_sparsify, thgs_sparsify_into, ThgsConfig};
pub use topk::{threshold_for_topk, threshold_for_topk_abs, threshold_for_topk_abs_with};
