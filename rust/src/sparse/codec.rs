//! Sparse update codec + the paper's communication-cost model.
//!
//! §5.2 Eq. 6: a sparse update of `nnz` non-zeros costs
//! `nnz · (64 + 32)` bits — a 64-bit value plus a 32-bit position
//! index — while a dense update costs `m · 64` bits. We account both
//! this *paper model* (so Table 2 is comparable) and our *actual wire
//! bytes* (f32 values + u32 deltas, optionally deflate-compressed),
//! which is strictly smaller.

use std::io::{Read, Write};

use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;

/// Paper cost model constants (Eq. 6/8).
pub const PAPER_VALUE_BITS: u64 = 64;
pub const PAPER_INDEX_BITS: u64 = 32;

/// Sparse vector as (sorted indices, values).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct SparseVec {
    /// Dense length.
    pub n: u32,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVec {
    /// Gather the non-zeros of a dense vector.
    pub fn from_dense(dense: &[f32]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &x) in dense.iter().enumerate() {
            if x != 0.0 {
                indices.push(i as u32);
                values.push(x);
            }
        }
        Self { n: dense.len() as u32, indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Scatter back to a dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n as usize];
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v;
        }
        out
    }

    /// Scatter-add into an accumulator (server aggregation hot path).
    pub fn add_into(&self, acc: &mut [f32]) {
        assert_eq!(acc.len(), self.n as usize, "accumulator size mismatch");
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            acc[i as usize] += v;
        }
    }

    /// Paper cost model (Eq. 6): `nnz · 96 bit`, in bytes.
    pub fn paper_cost_bytes(&self) -> u64 {
        self.nnz() as u64 * (PAPER_VALUE_BITS + PAPER_INDEX_BITS) / 8
    }

    /// Paper cost of the dense equivalent: `m · 64 bit`, in bytes.
    pub fn paper_dense_cost_bytes(&self) -> u64 {
        self.n as u64 * PAPER_VALUE_BITS / 8
    }

    /// Actual wire encoding: header (n, nnz) + delta-encoded varint
    /// indices + raw f32 LE values.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.nnz() * 6);
        self.encode_into(&mut out);
        out
    }

    /// [`encode`](Self::encode) into a caller-owned buffer (cleared
    /// first) — the client pipeline's zero-alloc encode path, which
    /// reuses one warm [`crate::coordinator::ClientWorkspace`] buffer
    /// per worker instead of allocating a payload per client per round.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.extend_from_slice(&self.n.to_le_bytes());
        out.extend_from_slice(&(self.nnz() as u32).to_le_bytes());
        encode_indices(&self.indices, out);
        for &v in &self.values {
            out.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Decode [`encode`](Self::encode) output.
    pub fn decode(bytes: &[u8]) -> Result<Self, CodecError> {
        let mut out = Self::default();
        Self::decode_into(bytes, &mut out)?;
        Ok(out)
    }

    /// [`decode`](Self::decode) into a caller-owned vector, reusing its
    /// index/value buffers — the coordinator's streaming-Collect path,
    /// which decodes every uplink into one warm scratch `SparseVec`
    /// instead of allocating per payload. On error `out` is left
    /// cleared, never partially decoded.
    pub fn decode_into(bytes: &[u8], out: &mut SparseVec) -> Result<(), CodecError> {
        out.n = 0;
        out.indices.clear();
        out.values.clear();
        if bytes.len() < 8 {
            return Err(CodecError::Truncated);
        }
        let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        let nnz = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let pos = 8 + match decode_indices(&bytes[8..], nnz, n, &mut out.indices) {
            Ok(used) => used,
            Err(e) => {
                out.indices.clear();
                return Err(e);
            }
        };
        if bytes.len() < pos || bytes.len() - pos < nnz * 4 {
            out.indices.clear();
            return Err(CodecError::Truncated);
        }
        out.values.reserve(nnz);
        for i in 0..nnz {
            let off = pos + 4 * i;
            out.values
                .push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
        }
        out.n = n;
        Ok(())
    }

    /// Deflate-compressed wire encoding (the paper's "subsequent
    /// coding and compression" remark; golomb-style gains come free
    /// from delta+varint+deflate).
    pub fn encode_compressed(&self) -> Vec<u8> {
        let raw = self.encode();
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::default());
        enc.write_all(&raw).expect("deflate write");
        enc.finish().expect("deflate finish")
    }

    pub fn decode_compressed(bytes: &[u8]) -> Result<Self, CodecError> {
        let mut dec = DeflateDecoder::new(bytes);
        let mut raw = Vec::new();
        dec.read_to_end(&mut raw).map_err(|_| CodecError::Corrupt("deflate"))?;
        Self::decode(&raw)
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CodecError {
    #[error("truncated sparse payload")]
    Truncated,
    #[error("corrupt sparse payload: {0}")]
    Corrupt(&'static str),
}

/// Delta-encode sorted indices as varints — the index section shared
/// by the f32 ([`SparseVec::encode_into`]) and quantized
/// ([`crate::sparse::quant::QuantizedSparse::encode_into`]) frames.
pub(crate) fn encode_indices(indices: &[u32], out: &mut Vec<u8>) {
    let mut prev = 0u32;
    for &i in indices {
        let delta = i - prev; // indices sorted ascending
        write_varint(out, delta as u64);
        prev = i;
    }
}

/// Checked delta-varint index walk: calls `f(k, idx)` for each of the
/// `nnz` entries (all `idx < n`), returning the bytes consumed. The
/// non-materializing core shared by [`decode_indices`] and the fused
/// decode+fold range kernels ([`fold_f32_range`],
/// [`crate::sparse::quant::fold_quant_range`]).
pub(crate) fn walk_indices(
    bytes: &[u8],
    nnz: usize,
    n: u32,
    mut f: impl FnMut(usize, u32),
) -> Result<usize, CodecError> {
    // every index needs ≥ 1 varint byte, so an nnz larger than the
    // remaining payload is corrupt — checked up front, so a garbage
    // header fails fast (and callers can reserve safely)
    if nnz > bytes.len() {
        return Err(CodecError::Truncated);
    }
    let mut pos = 0usize;
    let mut prev = 0u32;
    for k in 0..nnz {
        let (delta, used) = match read_varint(&bytes[pos..]) {
            Some(x) => x,
            None => return Err(CodecError::Truncated),
        };
        pos += used;
        // reject before narrowing: `delta as u32` would silently wrap
        // a > u32::MAX varint into a small, in-range-looking delta
        if delta > u32::MAX as u64 {
            return Err(CodecError::Corrupt("delta overflow"));
        }
        let idx = match prev.checked_add(delta as u32) {
            Some(i) if i < n => i,
            Some(_) => return Err(CodecError::Corrupt("index out of range")),
            None => return Err(CodecError::Corrupt("index overflow")),
        };
        f(k, idx);
        prev = idx;
    }
    Ok(pos)
}

/// Decode `nnz` delta-varint indices (all `< n`) from `bytes` into
/// `out` (cleared first), returning the bytes consumed. On error `out`
/// may hold a partial prefix — callers clear it (the "no partial
/// output" contract lives at the frame level).
pub(crate) fn decode_indices(
    bytes: &[u8],
    nnz: usize,
    n: u32,
    out: &mut Vec<u32>,
) -> Result<usize, CodecError> {
    out.clear();
    if nnz > bytes.len() {
        return Err(CodecError::Truncated);
    }
    out.reserve(nnz);
    walk_indices(bytes, nnz, n, |_, idx| out.push(idx))
}

/// Fused decode+fold for the pool-parallel Collect: stream the f32
/// frame's entries whose index lies in `[start, end)` straight into
/// `acc` (`acc[idx - start] += v`), materializing nothing. Returns the
/// frame's dense dimension `n`. Index validation is identical to
/// [`SparseVec::decode_into`] (every index of the frame is checked, in
/// and out of range), and the in-range adds happen in frame order, so
/// a union of range folds over a partition of `[0, n)` applies exactly
/// the serial fold's per-position f32 op sequence — the bitwise
/// contract the parallel sharded Collect rests on (PERF.md).
pub fn fold_f32_range(
    bytes: &[u8],
    start: u32,
    end: u32,
    acc: &mut [f32],
) -> Result<u32, CodecError> {
    if bytes.len() < 8 {
        return Err(CodecError::Truncated);
    }
    let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
    let nnz = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
    let idx_bytes = &bytes[8..];
    // first walk finds (and validates) the index section so the value
    // section offset is known; the second fuses the range fold
    let used = walk_indices(idx_bytes, nnz, n, |_, _| {})?;
    let values = &idx_bytes[used..];
    if values.len() < nnz * 4 {
        return Err(CodecError::Truncated);
    }
    walk_indices(idx_bytes, nnz, n, |k, idx| {
        if idx >= start && idx < end {
            let off = 4 * k;
            let v = f32::from_le_bytes(values[off..off + 4].try_into().unwrap());
            acc[(idx - start) as usize] += v;
        }
    })?;
    Ok(n)
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn read_varint(bytes: &[u8]) -> Option<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &b) in bytes.iter().enumerate() {
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
        if shift >= 64 {
            return None;
        }
    }
    None
}

/// Dense update cost in the paper model: `m · 64 bit` → bytes (Eq. 8).
pub fn dense_cost_bytes(m: usize) -> u64 {
    m as u64 * PAPER_VALUE_BITS / 8
}

/// Sparse update cost in the paper model (Eq. 6) for `nnz` non-zeros.
pub fn sparse_cost_bytes(nnz: usize) -> u64 {
    nnz as u64 * (PAPER_VALUE_BITS + PAPER_INDEX_BITS) / 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sparse(seed: u64, n: usize, density: f64) -> SparseVec {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0f32; n];
        for v in dense.iter_mut() {
            if rng.next_f64() < density {
                *v = rng.normal_f32(1.0);
            }
        }
        SparseVec::from_dense(&dense)
    }

    #[test]
    fn dense_roundtrip() {
        let dense = vec![0.0f32, 1.5, 0.0, -2.0, 0.0, 3.25];
        let sv = SparseVec::from_dense(&dense);
        assert_eq!(sv.nnz(), 3);
        assert_eq!(sv.to_dense(), dense);
    }

    #[test]
    fn encode_roundtrip() {
        let sv = random_sparse(1, 10_000, 0.01);
        let bytes = sv.encode();
        assert_eq!(SparseVec::decode(&bytes).unwrap(), sv);
    }

    #[test]
    fn compressed_roundtrip_and_smaller_on_clustered() {
        let sv = random_sparse(2, 100_000, 0.01);
        let plain = sv.encode();
        let comp = sv.encode_compressed();
        assert_eq!(SparseVec::decode_compressed(&comp).unwrap(), sv);
        // f32 mantissas are high-entropy; deflate may not shrink much,
        // but must roundtrip. Clustered indices compress the index part.
        assert!(comp.len() < plain.len() + 64);
    }

    #[test]
    fn paper_cost_is_96_bits_per_nnz() {
        let sv = random_sparse(3, 1000, 0.1);
        assert_eq!(sv.paper_cost_bytes(), sv.nnz() as u64 * 12);
        assert_eq!(sv.paper_dense_cost_bytes(), 8000);
        assert_eq!(sparse_cost_bytes(100), 1200);
        assert_eq!(dense_cost_bytes(1000), 8000);
    }

    #[test]
    fn wire_encoding_beats_paper_model() {
        // u32-delta varints + f32 values < 96 bits/el of the paper model
        let sv = random_sparse(4, 100_000, 0.01);
        assert!((sv.encode().len() as u64) < sv.paper_cost_bytes());
    }

    #[test]
    fn add_into_accumulates() {
        let sv = SparseVec {
            n: 4,
            indices: vec![1, 3],
            values: vec![0.5, -1.0],
        };
        let mut acc = vec![1.0f32; 4];
        sv.add_into(&mut acc);
        assert_eq!(acc, vec![1.0, 1.5, 1.0, 0.0]);
    }

    #[test]
    fn decode_into_reuses_buffers_and_clears_on_error() {
        let a = random_sparse(11, 10_000, 0.02);
        let b = random_sparse(12, 10_000, 0.01);
        let mut scratch = SparseVec::default();
        SparseVec::decode_into(&a.encode(), &mut scratch).unwrap();
        assert_eq!(scratch, a);
        let cap = scratch.indices.capacity();
        // smaller payload into the same scratch: no regrowth
        SparseVec::decode_into(&b.encode(), &mut scratch).unwrap();
        assert_eq!(scratch, b);
        assert_eq!(scratch.indices.capacity(), cap);
        // a failed decode must not leave stale partial contents behind
        let bytes = a.encode();
        assert_eq!(
            SparseVec::decode_into(&bytes[..bytes.len() - 2], &mut scratch),
            Err(CodecError::Truncated)
        );
        assert_eq!(scratch.nnz(), 0);
        assert_eq!(scratch.n, 0);
    }

    #[test]
    fn decode_rejects_truncated() {
        let sv = random_sparse(5, 1000, 0.05);
        let bytes = sv.encode();
        assert_eq!(SparseVec::decode(&bytes[..4]), Err(CodecError::Truncated));
        assert_eq!(
            SparseVec::decode(&bytes[..bytes.len() - 2]),
            Err(CodecError::Truncated)
        );
    }

    #[test]
    fn decode_rejects_out_of_range_index() {
        let sv = SparseVec { n: 4, indices: vec![9], values: vec![1.0] };
        let bytes = sv.encode();
        assert!(matches!(
            SparseVec::decode(&bytes),
            Err(CodecError::Corrupt(_))
        ));
    }

    #[test]
    fn decode_rejects_wrapping_varint_delta() {
        // regression: a delta > u32::MAX used to be narrowed with `as
        // u32` BEFORE the overflow guard, so e.g. 1<<32 wrapped to 0
        // and decoded as a valid small index
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&10u32.to_le_bytes()); // n
        bytes.extend_from_slice(&1u32.to_le_bytes()); // nnz
        write_varint(&mut bytes, 1u64 << 32); // wraps to delta 0
        bytes.extend_from_slice(&1.0f32.to_le_bytes());
        assert_eq!(
            SparseVec::decode(&bytes),
            Err(CodecError::Corrupt("delta overflow"))
        );
        // u32::MAX itself still overflows prev+delta, not the varint
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&10u32.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        write_varint(&mut bytes, 5);
        write_varint(&mut bytes, u32::MAX as u64);
        bytes.extend_from_slice(&[0u8; 8]);
        assert_eq!(
            SparseVec::decode(&bytes),
            Err(CodecError::Corrupt("index overflow"))
        );
    }

    #[test]
    fn decode_bounds_nnz_by_payload_length() {
        // a garbage header claiming nnz = u32::MAX must fail fast
        // (Truncated) instead of reserving gigabytes
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&100u32.to_le_bytes());
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&[0x01; 16]);
        assert_eq!(SparseVec::decode(&bytes), Err(CodecError::Truncated));
    }

    #[test]
    fn encode_into_matches_encode_and_reuses_buffer() {
        let a = random_sparse(21, 10_000, 0.02);
        let b = random_sparse(22, 10_000, 0.01);
        let mut buf = Vec::new();
        a.encode_into(&mut buf);
        assert_eq!(buf, a.encode());
        let cap = buf.capacity();
        b.encode_into(&mut buf); // smaller payload: no regrowth
        assert_eq!(buf, b.encode());
        assert_eq!(buf.capacity(), cap);
    }

    #[test]
    fn f32_frame_bytes_are_pinned() {
        // golden: the quantized-wire fast path must leave the
        // `quant_bits = None` encoding byte-identical — this is the
        // exact frame layout from before the quantized frame existed
        let sv = SparseVec {
            n: 10,
            indices: vec![1, 3, 9],
            values: vec![1.0, -2.0, 0.5],
        };
        let golden: Vec<u8> = vec![
            10, 0, 0, 0, // n LE
            3, 0, 0, 0, // nnz LE
            1, 2, 6, // delta varints
            0, 0, 128, 63, // 1.0f32 LE
            0, 0, 0, 192, // -2.0f32 LE
            0, 0, 0, 63, // 0.5f32 LE
        ];
        assert_eq!(sv.encode(), golden);
    }

    #[test]
    fn fold_f32_range_partition_matches_add_into() {
        let sv = random_sparse(31, 4096, 0.05);
        let bytes = sv.encode();
        let mut want = vec![0f32; 4096];
        sv.add_into(&mut want);
        for cuts in [vec![0u32, 4096], vec![0, 1, 7, 100, 4095, 4096]] {
            let mut got = vec![0f32; 4096];
            for w in cuts.windows(2) {
                let (s, e) = (w[0], w[1]);
                let n =
                    fold_f32_range(&bytes, s, e, &mut got[s as usize..e as usize]).unwrap();
                assert_eq!(n, 4096);
            }
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "range-fold partition diverged at cuts {cuts:?}"
            );
        }
        // validation parity: truncated bytes fail in any range
        assert!(fold_f32_range(&bytes[..bytes.len() - 2], 0, 4096, &mut [0.0; 4096]).is_err());
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let (got, used) = read_varint(&buf).unwrap();
            assert_eq!(got, v);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn empty_vector_roundtrips() {
        let sv = SparseVec::from_dense(&[0.0; 10]);
        assert_eq!(sv.nnz(), 0);
        let bytes = sv.encode();
        assert_eq!(SparseVec::decode(&bytes).unwrap(), sv);
    }
}
