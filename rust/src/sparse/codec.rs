//! Sparse update codec + the paper's communication-cost model.
//!
//! §5.2 Eq. 6: a sparse update of `nnz` non-zeros costs
//! `nnz · (64 + 32)` bits — a 64-bit value plus a 32-bit position
//! index — while a dense update costs `m · 64` bits. We account both
//! this *paper model* (so Table 2 is comparable) and our *actual wire
//! bytes* (f32 values + u32 deltas, optionally deflate-compressed),
//! which is strictly smaller.

use std::io::{Read, Write};

use flate2::read::DeflateDecoder;
use flate2::write::DeflateEncoder;
use flate2::Compression;

/// Paper cost model constants (Eq. 6/8).
pub const PAPER_VALUE_BITS: u64 = 64;
pub const PAPER_INDEX_BITS: u64 = 32;

/// Sparse vector as (sorted indices, values).
#[derive(Clone, Debug, PartialEq, Default)]
pub struct SparseVec {
    /// Dense length.
    pub n: u32,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl SparseVec {
    /// Gather the non-zeros of a dense vector.
    pub fn from_dense(dense: &[f32]) -> Self {
        let mut indices = Vec::new();
        let mut values = Vec::new();
        for (i, &x) in dense.iter().enumerate() {
            if x != 0.0 {
                indices.push(i as u32);
                values.push(x);
            }
        }
        Self { n: dense.len() as u32, indices, values }
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Scatter back to a dense vector.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.n as usize];
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out[i as usize] = v;
        }
        out
    }

    /// Scatter-add into an accumulator (server aggregation hot path).
    pub fn add_into(&self, acc: &mut [f32]) {
        assert_eq!(acc.len(), self.n as usize, "accumulator size mismatch");
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            acc[i as usize] += v;
        }
    }

    /// Paper cost model (Eq. 6): `nnz · 96 bit`, in bytes.
    pub fn paper_cost_bytes(&self) -> u64 {
        self.nnz() as u64 * (PAPER_VALUE_BITS + PAPER_INDEX_BITS) / 8
    }

    /// Paper cost of the dense equivalent: `m · 64 bit`, in bytes.
    pub fn paper_dense_cost_bytes(&self) -> u64 {
        self.n as u64 * PAPER_VALUE_BITS / 8
    }

    /// Actual wire encoding: header (n, nnz) + delta-encoded varint
    /// indices + raw f32 LE values.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(8 + self.nnz() * 6);
        out.extend_from_slice(&self.n.to_le_bytes());
        out.extend_from_slice(&(self.nnz() as u32).to_le_bytes());
        let mut prev = 0u32;
        for &i in &self.indices {
            let delta = i - prev; // indices sorted ascending
            write_varint(&mut out, delta as u64);
            prev = i;
        }
        for &v in &self.values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    /// Decode [`encode`](Self::encode) output.
    pub fn decode(bytes: &[u8]) -> Result<Self, CodecError> {
        let mut out = Self::default();
        Self::decode_into(bytes, &mut out)?;
        Ok(out)
    }

    /// [`decode`](Self::decode) into a caller-owned vector, reusing its
    /// index/value buffers — the coordinator's streaming-Collect path,
    /// which decodes every uplink into one warm scratch `SparseVec`
    /// instead of allocating per payload. On error `out` is left
    /// cleared, never partially decoded.
    pub fn decode_into(bytes: &[u8], out: &mut SparseVec) -> Result<(), CodecError> {
        out.n = 0;
        out.indices.clear();
        out.values.clear();
        if bytes.len() < 8 {
            return Err(CodecError::Truncated);
        }
        let n = u32::from_le_bytes(bytes[0..4].try_into().unwrap());
        let nnz = u32::from_le_bytes(bytes[4..8].try_into().unwrap()) as usize;
        let mut pos = 8usize;
        out.indices.reserve(nnz);
        let mut prev = 0u32;
        for _ in 0..nnz {
            let (delta, used) = match read_varint(&bytes[pos..]) {
                Some(x) => x,
                None => {
                    out.indices.clear();
                    return Err(CodecError::Truncated);
                }
            };
            pos += used;
            let idx = match prev.checked_add(delta as u32) {
                Some(i) if i < n => i,
                Some(_) => {
                    out.indices.clear();
                    return Err(CodecError::Corrupt("index out of range"));
                }
                None => {
                    out.indices.clear();
                    return Err(CodecError::Corrupt("index overflow"));
                }
            };
            out.indices.push(idx);
            prev = idx;
        }
        if bytes.len() < pos + nnz * 4 {
            out.indices.clear();
            return Err(CodecError::Truncated);
        }
        out.values.reserve(nnz);
        for i in 0..nnz {
            let off = pos + 4 * i;
            out.values
                .push(f32::from_le_bytes(bytes[off..off + 4].try_into().unwrap()));
        }
        out.n = n;
        Ok(())
    }

    /// Deflate-compressed wire encoding (the paper's "subsequent
    /// coding and compression" remark; golomb-style gains come free
    /// from delta+varint+deflate).
    pub fn encode_compressed(&self) -> Vec<u8> {
        let raw = self.encode();
        let mut enc = DeflateEncoder::new(Vec::new(), Compression::default());
        enc.write_all(&raw).expect("deflate write");
        enc.finish().expect("deflate finish")
    }

    pub fn decode_compressed(bytes: &[u8]) -> Result<Self, CodecError> {
        let mut dec = DeflateDecoder::new(bytes);
        let mut raw = Vec::new();
        dec.read_to_end(&mut raw).map_err(|_| CodecError::Corrupt("deflate"))?;
        Self::decode(&raw)
    }
}

#[derive(Debug, thiserror::Error, PartialEq)]
pub enum CodecError {
    #[error("truncated sparse payload")]
    Truncated,
    #[error("corrupt sparse payload: {0}")]
    Corrupt(&'static str),
}

fn write_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn read_varint(bytes: &[u8]) -> Option<(u64, usize)> {
    let mut v = 0u64;
    let mut shift = 0u32;
    for (i, &b) in bytes.iter().enumerate() {
        v |= ((b & 0x7f) as u64) << shift;
        if b & 0x80 == 0 {
            return Some((v, i + 1));
        }
        shift += 7;
        if shift >= 64 {
            return None;
        }
    }
    None
}

/// Dense update cost in the paper model: `m · 64 bit` → bytes (Eq. 8).
pub fn dense_cost_bytes(m: usize) -> u64 {
    m as u64 * PAPER_VALUE_BITS / 8
}

/// Sparse update cost in the paper model (Eq. 6) for `nnz` non-zeros.
pub fn sparse_cost_bytes(nnz: usize) -> u64 {
    nnz as u64 * (PAPER_VALUE_BITS + PAPER_INDEX_BITS) / 8
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sparse(seed: u64, n: usize, density: f64) -> SparseVec {
        let mut rng = Rng::new(seed);
        let mut dense = vec![0f32; n];
        for v in dense.iter_mut() {
            if rng.next_f64() < density {
                *v = rng.normal_f32(1.0);
            }
        }
        SparseVec::from_dense(&dense)
    }

    #[test]
    fn dense_roundtrip() {
        let dense = vec![0.0f32, 1.5, 0.0, -2.0, 0.0, 3.25];
        let sv = SparseVec::from_dense(&dense);
        assert_eq!(sv.nnz(), 3);
        assert_eq!(sv.to_dense(), dense);
    }

    #[test]
    fn encode_roundtrip() {
        let sv = random_sparse(1, 10_000, 0.01);
        let bytes = sv.encode();
        assert_eq!(SparseVec::decode(&bytes).unwrap(), sv);
    }

    #[test]
    fn compressed_roundtrip_and_smaller_on_clustered() {
        let sv = random_sparse(2, 100_000, 0.01);
        let plain = sv.encode();
        let comp = sv.encode_compressed();
        assert_eq!(SparseVec::decode_compressed(&comp).unwrap(), sv);
        // f32 mantissas are high-entropy; deflate may not shrink much,
        // but must roundtrip. Clustered indices compress the index part.
        assert!(comp.len() < plain.len() + 64);
    }

    #[test]
    fn paper_cost_is_96_bits_per_nnz() {
        let sv = random_sparse(3, 1000, 0.1);
        assert_eq!(sv.paper_cost_bytes(), sv.nnz() as u64 * 12);
        assert_eq!(sv.paper_dense_cost_bytes(), 8000);
        assert_eq!(sparse_cost_bytes(100), 1200);
        assert_eq!(dense_cost_bytes(1000), 8000);
    }

    #[test]
    fn wire_encoding_beats_paper_model() {
        // u32-delta varints + f32 values < 96 bits/el of the paper model
        let sv = random_sparse(4, 100_000, 0.01);
        assert!((sv.encode().len() as u64) < sv.paper_cost_bytes());
    }

    #[test]
    fn add_into_accumulates() {
        let sv = SparseVec {
            n: 4,
            indices: vec![1, 3],
            values: vec![0.5, -1.0],
        };
        let mut acc = vec![1.0f32; 4];
        sv.add_into(&mut acc);
        assert_eq!(acc, vec![1.0, 1.5, 1.0, 0.0]);
    }

    #[test]
    fn decode_into_reuses_buffers_and_clears_on_error() {
        let a = random_sparse(11, 10_000, 0.02);
        let b = random_sparse(12, 10_000, 0.01);
        let mut scratch = SparseVec::default();
        SparseVec::decode_into(&a.encode(), &mut scratch).unwrap();
        assert_eq!(scratch, a);
        let cap = scratch.indices.capacity();
        // smaller payload into the same scratch: no regrowth
        SparseVec::decode_into(&b.encode(), &mut scratch).unwrap();
        assert_eq!(scratch, b);
        assert_eq!(scratch.indices.capacity(), cap);
        // a failed decode must not leave stale partial contents behind
        let bytes = a.encode();
        assert_eq!(
            SparseVec::decode_into(&bytes[..bytes.len() - 2], &mut scratch),
            Err(CodecError::Truncated)
        );
        assert_eq!(scratch.nnz(), 0);
        assert_eq!(scratch.n, 0);
    }

    #[test]
    fn decode_rejects_truncated() {
        let sv = random_sparse(5, 1000, 0.05);
        let bytes = sv.encode();
        assert_eq!(SparseVec::decode(&bytes[..4]), Err(CodecError::Truncated));
        assert_eq!(
            SparseVec::decode(&bytes[..bytes.len() - 2]),
            Err(CodecError::Truncated)
        );
    }

    #[test]
    fn decode_rejects_out_of_range_index() {
        let sv = SparseVec { n: 4, indices: vec![9], values: vec![1.0] };
        let bytes = sv.encode();
        assert!(matches!(
            SparseVec::decode(&bytes),
            Err(CodecError::Corrupt(_))
        ));
    }

    #[test]
    fn varint_boundaries() {
        for v in [0u64, 1, 127, 128, 16_383, 16_384, u32::MAX as u64] {
            let mut buf = Vec::new();
            write_varint(&mut buf, v);
            let (got, used) = read_varint(&buf).unwrap();
            assert_eq!(got, v);
            assert_eq!(used, buf.len());
        }
    }

    #[test]
    fn empty_vector_roundtrips() {
        let sv = SparseVec::from_dense(&[0.0; 10]);
        assert_eq!(sv.nnz(), 0);
        let bytes = sv.encode();
        assert_eq!(SparseVec::decode(&bytes).unwrap(), sv);
    }
}
