//! Time-varying Hierarchical Gradient Sparsification — Algorithm 1.
//!
//! The paper's first contribution: instead of one global Top-k over the
//! flattened update (which lets large-magnitude layers starve small
//! ones, §1), each network layer gets its own Top-k with a sparsity
//! rate that decays **geometrically with layer depth**:
//!
//! ```text
//! s_1 = s_0
//! s_i = max(s_{i-1} · α, s_min)        (Eq. 1)
//! ```
//!
//! and, per §3.1's "time-varying" part, the *starting* rate decays with
//! the round index (handled by [`crate::sparse::dynamic::DynamicRate`]
//! which implements the paper's Eq. 2 controller; `thgs_sparsify` takes
//! the already-resolved `s_0` for the round).
//!
//! The layer boundaries come from the model manifest (one group per
//! dense/conv layer, matching the paper's "each layer of a deep neural
//! network has its own characteristics").

use super::flat::SparsifyOut;
use super::topk::threshold_for_topk_abs_with;

/// THGS hyper-parameters (paper Eq. 1 symbols).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ThgsConfig {
    /// Initial (layer-1) sparsity rate `s_0`.
    pub s0: f64,
    /// Constant attenuation factor `α` applied per layer.
    pub alpha: f64,
    /// Lower bound `s_min`.
    pub s_min: f64,
}

impl Default for ThgsConfig {
    fn default() -> Self {
        // §5.1 experiments: s_min = 0.01, α sweeps {0.2, 0.5, 0.8}.
        Self { s0: 0.1, alpha: 0.8, s_min: 0.01 }
    }
}

impl ThgsConfig {
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0 < self.s0 && self.s0 <= 1.0) {
            return Err(format!("s0={} outside (0,1]", self.s0));
        }
        if !(0.0 < self.alpha && self.alpha <= 1.0) {
            return Err(format!("alpha={} outside (0,1]", self.alpha));
        }
        if !(0.0 < self.s_min && self.s_min <= self.s0) {
            return Err(format!("s_min={} outside (0, s0]", self.s_min));
        }
        Ok(())
    }
}

/// Per-layer sparsity rates `s_i` (Eq. 1) for `n_layers` layers.
pub fn layer_rates(cfg: &ThgsConfig, n_layers: usize) -> Vec<f64> {
    let mut rates = Vec::with_capacity(n_layers);
    let mut s = cfg.s0;
    for i in 0..n_layers {
        if i > 0 {
            let next = s * cfg.alpha;
            s = if next > cfg.s_min { next } else { cfg.s_min };
        }
        rates.push(s);
    }
    rates
}

/// Apply Algorithm 1 over a flat update vector `g` whose layer layout
/// is `layer_spans` (byte-offset-free: `(start, len)` in elements,
/// non-overlapping, covering `g`).
///
/// Returns the sparse/residual split (exact: `sparse + residual == g`)
/// plus the per-layer thresholds δ_i actually used.
pub fn thgs_sparsify(g: &[f32], layer_spans: &[(usize, usize)], cfg: &ThgsConfig) -> SparsifyOut {
    let mut out = SparsifyOut::default();
    thgs_sparsify_into(g, layer_spans, cfg, &mut Vec::new(), &mut out);
    out
}

/// [`thgs_sparsify`] into caller-owned scratch + output: one magnitude
/// scratch buffer serves every layer's Top-k selection and the split
/// reuses `out`'s buffers — the round engine's zero-allocation path.
pub fn thgs_sparsify_into(
    g: &[f32],
    layer_spans: &[(usize, usize)],
    cfg: &ThgsConfig,
    scratch: &mut Vec<f32>,
    out: &mut SparsifyOut,
) {
    cfg.validate().expect("invalid ThgsConfig");
    debug_assert_eq!(
        layer_spans.iter().map(|(_, l)| l).sum::<usize>(),
        g.len(),
        "layer spans must cover the update vector"
    );
    let rates = layer_rates(cfg, layer_spans.len());
    out.sparse.clear();
    out.sparse.resize(g.len(), 0.0);
    out.residual.clear();
    out.residual.resize(g.len(), 0.0);
    out.thresholds.clear();
    let mut nnz = 0usize;

    for (li, &(start, len)) in layer_spans.iter().enumerate() {
        let layer = &g[start..start + len];
        let k = ((len as f64 * rates[li]).ceil() as usize).clamp(1, len);
        let delta = threshold_for_topk_abs_with(layer, k, scratch);
        out.thresholds.push(delta);
        for (off, &x) in layer.iter().enumerate() {
            let i = start + off;
            if x.abs() > delta {
                out.sparse[i] = x;
                nnz += 1;
            } else {
                out.residual[i] = x;
            }
        }
    }
    out.nnz = nnz;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn spans_of(lens: &[usize]) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut start = 0;
        for &l in lens {
            out.push((start, l));
            start += l;
        }
        out
    }

    #[test]
    fn eq1_rates_decay_to_floor() {
        let cfg = ThgsConfig { s0: 0.1, alpha: 0.5, s_min: 0.02 };
        let r = layer_rates(&cfg, 5);
        assert_eq!(r.len(), 5);
        assert!((r[0] - 0.1).abs() < 1e-12);
        assert!((r[1] - 0.05).abs() < 1e-12);
        assert!((r[2] - 0.025).abs() < 1e-12);
        assert!((r[3] - 0.02).abs() < 1e-12); // 0.0125 < s_min → clamp
        assert!((r[4] - 0.02).abs() < 1e-12);
    }

    #[test]
    fn alpha_one_keeps_rate_constant() {
        let cfg = ThgsConfig { s0: 0.05, alpha: 1.0, s_min: 0.01 };
        let r = layer_rates(&cfg, 4);
        assert!(r.iter().all(|&x| (x - 0.05).abs() < 1e-12));
    }

    #[test]
    fn split_is_exact_per_layer() {
        let mut rng = Rng::new(3);
        let lens = [1000usize, 400, 2000, 50];
        let g: Vec<f32> = (0..lens.iter().sum::<usize>())
            .map(|_| rng.normal_f32(1.0))
            .collect();
        let out = thgs_sparsify(&g, &spans_of(&lens), &ThgsConfig::default());
        for i in 0..g.len() {
            assert_eq!(out.sparse[i] + out.residual[i], g[i]);
        }
        assert_eq!(out.thresholds.len(), 4);
    }

    #[test]
    fn each_layer_gets_representation() {
        // the THGS motivation: a layer with tiny magnitudes must still
        // send its top entries. Build layer A with huge values and
        // layer B with tiny ones; flat top-k would starve B.
        let mut g = vec![0f32; 2000];
        let mut rng = Rng::new(4);
        for v in g[..1000].iter_mut() {
            *v = rng.normal_f32(100.0);
        }
        for v in g[1000..].iter_mut() {
            *v = rng.normal_f32(0.001);
        }
        let cfg = ThgsConfig { s0: 0.01, alpha: 1.0, s_min: 0.01 };
        let out = thgs_sparsify(&g, &spans_of(&[1000, 1000]), &cfg);
        let nnz_b = out.sparse[1000..].iter().filter(|&&x| x != 0.0).count();
        assert!(nnz_b >= 9, "layer B starved: nnz_b={nnz_b}");

        // contrast: flat top-k at the same overall rate starves B
        let flat = crate::sparse::flat::flat_topk_sparsify(&g, 0.01);
        let flat_b = flat.sparse[1000..].iter().filter(|&&x| x != 0.0).count();
        assert_eq!(flat_b, 0, "flat top-k unexpectedly kept layer-B entries");
    }

    #[test]
    fn nnz_tracks_per_layer_rates() {
        let mut rng = Rng::new(5);
        let lens = [10_000usize, 10_000];
        let g: Vec<f32> = (0..20_000).map(|_| rng.normal_f32(1.0)).collect();
        let cfg = ThgsConfig { s0: 0.1, alpha: 0.5, s_min: 0.01 };
        let out = thgs_sparsify(&g, &spans_of(&lens), &cfg);
        // expected ~ 1000 + 500
        assert!(out.nnz > 1400 && out.nnz <= 1500, "nnz={}", out.nnz);
    }

    #[test]
    fn single_layer_equals_flat() {
        let mut rng = Rng::new(6);
        let g: Vec<f32> = (0..5000).map(|_| rng.normal_f32(1.0)).collect();
        let cfg = ThgsConfig { s0: 0.02, alpha: 0.9, s_min: 0.01 };
        let ours = thgs_sparsify(&g, &spans_of(&[5000]), &cfg);
        let flat = crate::sparse::flat::flat_topk_sparsify(&g, 0.02);
        assert_eq!(ours.sparse, flat.sparse);
        assert_eq!(ours.nnz, flat.nnz);
    }

    #[test]
    #[should_panic(expected = "invalid ThgsConfig")]
    fn invalid_config_rejected() {
        thgs_sparsify(&[1.0], &[(0, 1)], &ThgsConfig { s0: 0.0, alpha: 0.5, s_min: 0.01 });
    }
}
