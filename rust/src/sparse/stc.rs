//! Sparse Ternary Compression (Sattler et al. 2019) — the §2.1-cited
//! Non-IID-oriented contender: Top-k selection followed by
//! *ternarization* (all kept entries become ±μ, with μ the mean kept
//! magnitude). The wire format then only needs positions + signs + one
//! float, beating plain Top-k's 96 bits/element by ~3× at equal k.
//!
//! Implemented as an additional baseline for the ablation harness
//! (`examples/ablation_compression.rs`).

use super::flat::SparsifyOut;
use super::topk::threshold_for_topk_abs_with;

/// STC output: the ternarized sparse vector plus its codebook value μ.
#[derive(Clone, Debug)]
pub struct StcOut {
    pub sparsify: SparsifyOut,
    /// Mean magnitude of the kept entries (the ± codebook value).
    pub mu: f32,
}

/// Ternary-compress `g` at sparsity rate `s`.
///
/// Residual semantics follow STC: the residual keeps `g − sign(g)·μ`
/// at kept positions (the ternarization error feeds back) and the full
/// value elsewhere, so no mass is lost across rounds.
pub fn stc_sparsify(g: &[f32], s: f64) -> StcOut {
    let mut out = SparsifyOut::default();
    let mu = stc_sparsify_into(g, s, &mut Vec::new(), &mut out);
    StcOut { sparsify: out, mu }
}

/// [`stc_sparsify`] into caller-owned scratch + output — the
/// zero-allocation path (`scratch` feeds the Top-k magnitude
/// selection, `out`'s buffers are resized and rewritten). Returns the
/// ± codebook value μ; identical results to the allocating wrapper.
pub fn stc_sparsify_into(g: &[f32], s: f64, scratch: &mut Vec<f32>, out: &mut SparsifyOut) -> f32 {
    let n = g.len();
    assert!(n > 0, "stc on empty update");
    let k = ((n as f64 * s).ceil() as usize).clamp(1, n);
    let delta = threshold_for_topk_abs_with(g, k, scratch);

    // pass 1: μ over kept entries
    let mut sum = 0f64;
    let mut kept = 0usize;
    for &x in g {
        if x.abs() > delta {
            sum += x.abs() as f64;
            kept += 1;
        }
    }
    let mu = if kept == 0 { 0.0 } else { (sum / kept as f64) as f32 };

    // pass 2: ternarize + residual
    out.sparse.clear();
    out.sparse.resize(n, 0.0);
    out.residual.clear();
    out.residual.resize(n, 0.0);
    for i in 0..n {
        let x = g[i];
        if x.abs() > delta && mu > 0.0 {
            let t = mu * x.signum();
            out.sparse[i] = t;
            out.residual[i] = x - t; // ternarization error feeds back
        } else {
            out.residual[i] = x;
        }
    }
    out.nnz = kept;
    out.thresholds.clear();
    out.thresholds.push(delta);
    mu
}

/// Paper-model wire cost of an STC update: positions (32 bit) + signs
/// (1 bit) + one shared f32 — vs plain sparse 96 bits/entry (Eq. 6).
pub fn stc_cost_bytes(nnz: usize) -> u64 {
    // ceil(nnz/8) sign bytes + 4·nnz position bytes + 4 byte μ
    (nnz as u64 * 32).div_ceil(8) + (nnz as u64).div_ceil(8) + 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal_f32(1.0)).collect()
    }

    #[test]
    fn kept_entries_are_ternary() {
        let g = rand_vec(1, 5000);
        let out = stc_sparsify(&g, 0.02);
        let mu = out.mu;
        assert!(mu > 0.0);
        for (i, &v) in out.sparsify.sparse.iter().enumerate() {
            if v != 0.0 {
                assert!(v == mu || v == -mu, "entry {i} = {v}, mu = {mu}");
            }
        }
    }

    #[test]
    fn mass_conserved_including_ternary_error() {
        let g = rand_vec(2, 2000);
        let out = stc_sparsify(&g, 0.05);
        for i in 0..g.len() {
            let recon = out.sparsify.sparse[i] + out.sparsify.residual[i];
            assert!((recon - g[i]).abs() < 1e-6, "at {i}");
        }
    }

    #[test]
    fn mu_is_mean_kept_magnitude() {
        let g = vec![10.0f32, -20.0, 0.1, 0.2, -0.1, 30.0];
        // k=4 → δ = 0.2 (4th |g|); strict > keeps 10, -20, 30
        let out = stc_sparsify(&g, 4.0 / 6.0);
        assert!((out.mu - 20.0).abs() < 1e-5);
        assert_eq!(out.sparsify.nnz, 3);
    }

    #[test]
    fn signs_preserved() {
        let g = rand_vec(3, 1000);
        let out = stc_sparsify(&g, 0.1);
        for i in 0..g.len() {
            let v = out.sparsify.sparse[i];
            if v != 0.0 {
                assert_eq!(v.signum(), g[i].signum());
            }
        }
    }

    #[test]
    fn into_variant_matches_allocating_path() {
        let mut scratch = vec![99.0f32; 5]; // dirty, wrong-sized
        let mut out = SparsifyOut::default();
        for (seed, s) in [(7u64, 0.02), (8, 0.1), (9, 1.0)] {
            let g = rand_vec(seed, 3000);
            let reference = stc_sparsify(&g, s);
            let mu = stc_sparsify_into(&g, s, &mut scratch, &mut out);
            assert_eq!(mu, reference.mu);
            assert_eq!(out.sparse, reference.sparsify.sparse);
            assert_eq!(out.residual, reference.sparsify.residual);
            assert_eq!(out.nnz, reference.sparsify.nnz);
            assert_eq!(out.thresholds, reference.sparsify.thresholds);
        }
    }

    #[test]
    fn cost_beats_plain_sparse() {
        // 96 bits/el plain vs ~33 bits/el STC
        assert!(stc_cost_bytes(1000) < crate::sparse::codec::sparse_cost_bytes(1000) / 2);
    }

    #[test]
    fn all_below_threshold_keeps_nothing() {
        let g = vec![1.0f32; 100]; // all ties → strict > keeps none
        let out = stc_sparsify(&g, 0.1);
        assert_eq!(out.sparsify.nnz, 0);
        assert_eq!(out.mu, 0.0);
        assert_eq!(out.sparsify.residual, g);
    }
}
