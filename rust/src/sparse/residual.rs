//! Local residual accumulation (Alg. 1 line 12, Strom'15 style).
//!
//! Each client keeps the unsent gradient mass and folds it into the
//! next round's update *before* sparsification, so small-but-steady
//! directions eventually cross the threshold instead of being lost.

/// Per-client residual buffer for one model.
#[derive(Clone, Debug)]
pub struct ResidualStore {
    buf: Vec<f32>,
    /// Rounds since each element last shipped (staleness diagnostics,
    /// §1's "too many cumulative rounds" concern).
    age: Vec<u32>,
}

impl ResidualStore {
    pub fn new(n: usize) -> Self {
        Self { buf: vec![0.0; n], age: vec![0; n] }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// `update + residual` → the vector that gets sparsified this round.
    pub fn fold_into(&self, update: &mut [f32]) {
        assert_eq!(update.len(), self.buf.len(), "residual size mismatch");
        for (u, r) in update.iter_mut().zip(&self.buf) {
            *u += *r;
        }
    }

    /// Replace the residual with this round's unsent mass and advance
    /// staleness counters (sent positions reset to age 0).
    pub fn store(&mut self, residual: &[f32]) {
        assert_eq!(residual.len(), self.buf.len(), "residual size mismatch");
        for i in 0..residual.len() {
            self.buf[i] = residual[i];
            if residual[i] == 0.0 {
                self.age[i] = 0;
            } else {
                self.age[i] = self.age[i].saturating_add(1);
            }
        }
    }

    /// Double-buffered twin of [`Self::store`]: fill *this* store with
    /// the round's unsent mass while reading the staleness counters
    /// from the untouched pre-round store `prev` — exactly the state
    /// `prev.clone()` + `store(residual)` would produce, without
    /// mutating `prev`. This is what lets the round engine keep the
    /// pre-round store alive inside a copy-on-write rollback snapshot
    /// (an `Arc` bump) instead of deep-copying it: the evolved state is
    /// written into a recycled spare buffer (resized in place — no
    /// allocation once warm) and the two stores swap roles at commit.
    pub fn store_from(&mut self, prev: &ResidualStore, residual: &[f32]) {
        assert_eq!(residual.len(), prev.buf.len(), "residual size mismatch");
        self.buf.clear();
        self.buf.extend_from_slice(residual);
        self.age.clear();
        self.age.extend(residual.iter().zip(&prev.age).map(|(&v, &a)| {
            if v == 0.0 {
                0
            } else {
                a.saturating_add(1)
            }
        }));
    }

    /// L2 norm of the held-back mass (convergence diagnostics).
    pub fn norm(&self) -> f64 {
        self.buf.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Max rounds any position has gone unsent.
    pub fn max_age(&self) -> u32 {
        self.age.iter().copied().max().unwrap_or(0)
    }

    /// Mean age over currently-nonzero residual positions.
    pub fn mean_age_nonzero(&self) -> f64 {
        let mut sum = 0u64;
        let mut n = 0u64;
        for i in 0..self.buf.len() {
            if self.buf[i] != 0.0 {
                sum += self.age[i] as u64;
                n += 1;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum as f64 / n as f64
        }
    }

    pub fn reset(&mut self) {
        self.buf.iter_mut().for_each(|x| *x = 0.0);
        self.age.iter_mut().for_each(|x| *x = 0);
    }

    pub fn as_slice(&self) -> &[f32] {
        &self.buf
    }

    /// Staleness counters (checkpoint serialization).
    pub fn ages(&self) -> &[u32] {
        &self.age
    }

    /// Overwrite both buffers from a checkpoint snapshot.
    pub fn restore(&mut self, buf: &[f32], age: &[u32]) {
        assert_eq!(buf.len(), age.len(), "residual value/age length mismatch");
        self.buf.clear();
        self.buf.extend_from_slice(buf);
        self.age.clear();
        self.age.extend_from_slice(age);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::flat::flat_topk_sparsify;
    use crate::util::rng::Rng;

    #[test]
    fn fold_and_store_roundtrip() {
        let mut store = ResidualStore::new(4);
        store.store(&[0.0, 0.5, 0.0, -0.25]);
        let mut update = vec![1.0f32, 1.0, 1.0, 1.0];
        store.fold_into(&mut update);
        assert_eq!(update, vec![1.0, 1.5, 1.0, 0.75]);
    }

    #[test]
    fn no_mass_lost_over_rounds() {
        // Invariant: sum of everything ever shipped + current residual
        // == sum of all raw updates (exact split + exact fold).
        let mut rng = Rng::new(7);
        let n = 1000;
        let mut store = ResidualStore::new(n);
        let mut shipped_total = vec![0f64; n];
        let mut raw_total = vec![0f64; n];
        for _ in 0..20 {
            let mut update: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            for i in 0..n {
                raw_total[i] += update[i] as f64;
            }
            store.fold_into(&mut update);
            let out = flat_topk_sparsify(&update, 0.05);
            for i in 0..n {
                shipped_total[i] += out.sparse[i] as f64;
            }
            store.store(&out.residual);
        }
        for i in 0..n {
            let residual = store.as_slice()[i] as f64;
            // f32 round-off accumulates over 20 rounds; tolerance loose
            assert!(
                (shipped_total[i] + residual - raw_total[i]).abs() < 1e-3,
                "mass leak at {i}"
            );
        }
    }

    #[test]
    fn store_from_matches_clone_then_store() {
        let mut rng = Rng::new(11);
        let n = 200;
        let mut prev = ResidualStore::new(n);
        // evolve `prev` a few rounds so ages are non-trivial
        for _ in 0..3 {
            let vals: Vec<f32> =
                (0..n).map(|_| if rng.below(3) == 0 { 0.0 } else { rng.normal_f32(1.0) }).collect();
            prev.store(&vals);
        }
        let vals: Vec<f32> =
            (0..n).map(|_| if rng.below(3) == 0 { 0.0 } else { rng.normal_f32(1.0) }).collect();
        let mut reference = prev.clone();
        reference.store(&vals);
        // a dirty, wrong-sized spare must come out identical to the
        // clone-then-store reference, with `prev` untouched
        let mut fresh = ResidualStore::new(3);
        fresh.store(&[7.0, 0.0, 7.0]);
        let before = prev.as_slice().to_vec();
        fresh.store_from(&prev, &vals);
        assert_eq!(fresh.as_slice(), reference.as_slice());
        assert_eq!(fresh.age, reference.age);
        assert_eq!(prev.as_slice().to_vec(), before, "prev untouched");
    }

    #[test]
    fn age_tracks_staleness() {
        let mut store = ResidualStore::new(3);
        store.store(&[1.0, 0.0, 2.0]);
        store.store(&[1.0, 0.0, 0.0]);
        assert_eq!(store.max_age(), 2);
        assert!(store.mean_age_nonzero() >= 1.9);
        store.store(&[0.0, 0.0, 0.0]);
        assert_eq!(store.max_age(), 0);
    }

    #[test]
    fn norm_is_l2() {
        let mut store = ResidualStore::new(2);
        store.store(&[3.0, 4.0]);
        assert!((store.norm() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn reset_clears() {
        let mut store = ResidualStore::new(2);
        store.store(&[1.0, 2.0]);
        store.reset();
        assert_eq!(store.norm(), 0.0);
        assert_eq!(store.max_age(), 0);
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn size_mismatch_panics() {
        let store = ResidualStore::new(3);
        let mut update = vec![0f32; 4];
        store.fold_into(&mut update);
    }
}
