//! Dynamic sparsity-rate controller — the paper's Eq. 2:
//!
//! ```text
//! R ← (α + β − t/T) · R,   clipped to [R_min, 1]
//! ```
//!
//! where `β = (loss_prev − loss_now) / loss_now` is the client's loss
//! change rate (Alg. 2 line 8), `t` the round index and `T` the round
//! budget. Early in training (big loss swings, small t/T) the rate
//! stays high; as training settles the rate decays toward `R_min`.
//!
//! §4 also leans on this: each client's rate differs (loss-driven), so
//! the aggregator cannot infer the Top-k cardinality of any client.

/// Eq. 2 controller state for one client.
#[derive(Clone, Debug)]
pub struct DynamicRate {
    /// Constant attenuation factor α.
    pub alpha: f64,
    /// Round budget T.
    pub total_rounds: u64,
    /// Rate floor R_min.
    pub r_min: f64,
    /// Current rate R.
    rate: f64,
    /// Previous round's loss (None before the first observation).
    loss_prev: Option<f64>,
}

impl DynamicRate {
    pub fn new(r0: f64, alpha: f64, total_rounds: u64, r_min: f64) -> Self {
        assert!(r0 > 0.0 && r0 <= 1.0, "r0={r0} outside (0,1]");
        assert!(r_min > 0.0 && r_min <= r0, "r_min={r_min} outside (0,r0]");
        assert!(total_rounds > 0, "total_rounds=0");
        Self { alpha, total_rounds, r_min, rate: r0, loss_prev: None }
    }

    pub fn rate(&self) -> f64 {
        self.rate
    }

    /// Previous observed loss (checkpoint serialization).
    pub fn loss_prev(&self) -> Option<f64> {
        self.loss_prev
    }

    /// Overwrite the evolving state from a checkpoint snapshot
    /// (`alpha`/`total_rounds`/`r_min` are rebuilt from config).
    pub fn restore(&mut self, rate: f64, loss_prev: Option<f64>) {
        self.rate = rate;
        self.loss_prev = loss_prev;
    }

    /// β for a loss transition (Alg. 2 line 8). Positive when the loss
    /// dropped. Guards against division by ~0.
    pub fn beta(loss_prev: f64, loss_now: f64) -> f64 {
        if loss_now.abs() < 1e-12 {
            return 0.0;
        }
        (loss_prev - loss_now) / loss_now
    }

    /// Observe this round's loss and update R per Eq. 2.
    /// Returns the new rate.
    pub fn observe(&mut self, t: u64, loss_now: f64) -> f64 {
        let beta = match self.loss_prev {
            Some(prev) => Self::beta(prev, loss_now),
            None => 0.0, // first observation: no change signal yet
        };
        self.loss_prev = Some(loss_now);
        let frac = t as f64 / self.total_rounds as f64;
        let factor = self.alpha + beta - frac;
        self.rate = (self.rate * factor).clamp(self.r_min, 1.0);
        self.rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn beta_sign_matches_loss_direction() {
        assert!(DynamicRate::beta(2.0, 1.0) > 0.0); // improving → positive
        assert!(DynamicRate::beta(1.0, 2.0) < 0.0); // worsening → negative
        assert_eq!(DynamicRate::beta(1.0, 0.0), 0.0); // guard
    }

    #[test]
    fn decays_to_floor_when_stalled() {
        // constant loss → β=0; with α<1, R decays each round to R_min
        let mut c = DynamicRate::new(0.5, 0.8, 100, 0.01);
        for t in 0..100 {
            c.observe(t, 1.0);
        }
        assert!((c.rate() - 0.01).abs() < 1e-9, "rate={}", c.rate());
    }

    #[test]
    fn big_loss_drop_raises_rate() {
        let mut c = DynamicRate::new(0.1, 0.8, 1000, 0.01);
        c.observe(0, 10.0);
        // loss halves → β = (10-5)/5 = 1.0 → factor ≈ 1.8 → rate grows
        let r = c.observe(1, 5.0);
        assert!(r > 0.1, "rate={r}");
        assert!(r <= 1.0);
    }

    #[test]
    fn clipped_to_unit_interval() {
        let mut c = DynamicRate::new(0.9, 1.5, 10, 0.01);
        c.observe(0, 4.0);
        let r = c.observe(1, 1.0); // β=3, factor huge
        assert_eq!(r, 1.0);
    }

    #[test]
    fn late_rounds_push_down() {
        // identical loss trajectory, later t → smaller factor
        let mut early = DynamicRate::new(0.5, 1.0, 100, 0.01);
        early.observe(0, 2.0);
        let r_early = early.observe(1, 1.9);

        let mut late = DynamicRate::new(0.5, 1.0, 100, 0.01);
        late.observe(90, 2.0);
        let r_late = late.observe(95, 1.9);
        assert!(r_late < r_early, "late {r_late} !< early {r_early}");
    }

    #[test]
    fn first_observation_uses_zero_beta() {
        let mut c = DynamicRate::new(0.5, 1.0, 10, 0.01);
        // t=0 → factor = α − 0 = 1.0 → unchanged
        assert!((c.observe(0, 123.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "outside (0,1]")]
    fn rejects_bad_r0() {
        DynamicRate::new(0.0, 0.8, 10, 0.01);
    }
}
