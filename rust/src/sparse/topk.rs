//! Top-k threshold selection — the L3 half of sparsification.
//!
//! The paper's Alg. 1 uses `TopK(|g|, k)` to obtain the threshold δ,
//! then keeps entries with `|g| > δ`. We implement the selection with
//! `select_nth_unstable` (introselect, O(N) expected) over the
//! magnitudes; the *application* half lives in the pallas kernel /
//! [`crate::sparse::flat`] sweep.
//!
//! Tie semantics match the paper's `torch.where(g̃ > δ)`: strictly
//! greater than the k-th magnitude, so with ties fewer than k entries
//! may be kept — never more. (`keep_exact_k` resolves ties by index
//! order when an exact count is required, e.g. for the comm-cost
//! accounting benches.)

/// The k-th largest value of `vals` (1-based k), i.e. the threshold δ
/// such that exactly k entries are ≥ δ (modulo ties).
/// `k` is clamped to `[1, vals.len()]`. Panics on empty input.
pub fn threshold_for_topk(vals: &[f32], k: usize) -> f32 {
    assert!(!vals.is_empty(), "threshold_for_topk on empty slice");
    let k = k.clamp(1, vals.len());
    let mut buf = vals.to_vec();
    // k-th largest = (len-k)-th smallest (0-based)
    let idx = buf.len() - k;
    let (_, kth, _) = buf.select_nth_unstable_by(idx, |a, b| a.total_cmp(b));
    *kth
}

/// Threshold over magnitudes: k-th largest `|g|` (Alg. 1 line 6).
pub fn threshold_for_topk_abs(g: &[f32], k: usize) -> f32 {
    threshold_for_topk_abs_with(g, k, &mut Vec::new())
}

/// [`threshold_for_topk_abs`] with a caller-owned magnitude scratch
/// buffer: selection is the only allocation in the Top-k half, so the
/// per-worker workspace holds one model-sized `Vec<f32>` and the
/// steady-state sparsify path allocates nothing. The scratch contents
/// on return are the partially-ordered magnitudes (introselect
/// leftovers) — opaque, reuse freely.
///
/// The magnitude scan runs through the vectorized
/// [`crate::util::simd::abs_into`] (|x| is a sign-bit clear, so the
/// SIMD and scalar sweeps are bitwise identical and the selected
/// threshold cannot move).
pub fn threshold_for_topk_abs_with(g: &[f32], k: usize, scratch: &mut Vec<f32>) -> f32 {
    assert!(!g.is_empty(), "threshold_for_topk_abs on empty slice");
    let k = k.clamp(1, g.len());
    // no clear-first: resize is a steady-state no-op (same model size
    // every call) and abs_into overwrites every element anyway
    scratch.resize(g.len(), 0.0);
    crate::util::simd::abs_into(g, scratch);
    let idx = scratch.len() - k;
    let (_, kth, _) = scratch.select_nth_unstable_by(idx, |a, b| a.total_cmp(b));
    *kth
}

/// Indices of exactly `min(k, n)` kept entries: all with `|g| > δ`,
/// plus enough `|g| == δ` ties (in ascending index order) to reach k.
pub fn keep_exact_k(g: &[f32], k: usize) -> Vec<u32> {
    let k = k.clamp(1, g.len());
    let delta = threshold_for_topk_abs(g, k);
    let mut keep: Vec<u32> = Vec::with_capacity(k);
    let mut ties: Vec<u32> = Vec::new();
    for (i, &x) in g.iter().enumerate() {
        let a = x.abs();
        if a > delta {
            keep.push(i as u32);
        } else if a == delta {
            ties.push(i as u32);
        }
    }
    for t in ties {
        if keep.len() >= k {
            break;
        }
        keep.push(t);
    }
    keep.sort_unstable();
    keep
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn kth_largest_simple() {
        let v = [1.0, 5.0, 3.0, 2.0, 4.0];
        assert_eq!(threshold_for_topk(&v, 1), 5.0);
        assert_eq!(threshold_for_topk(&v, 3), 3.0);
        assert_eq!(threshold_for_topk(&v, 5), 1.0);
    }

    #[test]
    fn abs_variant_uses_magnitude() {
        let v = [0.1, -5.0, 2.0, -0.3, 4.0, 1.0, -2.5, 0.0];
        assert_eq!(threshold_for_topk_abs(&v, 1), 5.0);
        assert_eq!(threshold_for_topk_abs(&v, 3), 2.5);
        assert_eq!(threshold_for_topk_abs(&v, 8), 0.0);
    }

    #[test]
    fn scratch_variant_matches_and_reuses() {
        let mut rng = Rng::new(9);
        let mut scratch = vec![99.0f32; 7]; // dirty, wrong-sized
        for _ in 0..20 {
            let n = 1 + rng.below(500) as usize;
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            let k = 1 + rng.below(n as u64) as usize;
            assert_eq!(
                threshold_for_topk_abs_with(&g, k, &mut scratch),
                threshold_for_topk_abs(&g, k)
            );
        }
    }

    #[test]
    fn k_clamped() {
        let v = [3.0, 1.0];
        assert_eq!(threshold_for_topk(&v, 0), 3.0); // clamped to 1
        assert_eq!(threshold_for_topk(&v, 99), 1.0); // clamped to len
    }

    #[test]
    fn strict_gt_keeps_at_most_k() {
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let n = 1 + rng.below(2000) as usize;
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
            let k = 1 + rng.below(n as u64) as usize;
            let delta = threshold_for_topk_abs(&g, k);
            let kept = g.iter().filter(|x| x.abs() > delta).count();
            assert!(kept <= k, "kept {kept} > k {k}");
            let kept_ge = g.iter().filter(|x| x.abs() >= delta).count();
            assert!(kept_ge >= k, "kept_ge {kept_ge} < k {k}");
        }
    }

    #[test]
    fn exact_k_with_ties() {
        let g = [1.0f32, -1.0, 1.0, 1.0, 0.5];
        let keep = keep_exact_k(&g, 2);
        assert_eq!(keep.len(), 2);
        assert!(keep.iter().all(|&i| g[i as usize].abs() == 1.0));
    }

    #[test]
    fn exact_k_count_holds_on_random() {
        let mut rng = Rng::new(2);
        for _ in 0..30 {
            let n = 10 + rng.below(500) as usize;
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(2.0)).collect();
            let k = 1 + rng.below(n as u64) as usize;
            assert_eq!(keep_exact_k(&g, k).len(), k);
        }
    }

    #[test]
    fn handles_all_equal_values() {
        let g = [2.0f32; 100];
        let delta = threshold_for_topk_abs(&g, 10);
        assert_eq!(delta, 2.0);
        assert_eq!(g.iter().filter(|x| x.abs() > delta).count(), 0);
        assert_eq!(keep_exact_k(&g, 10).len(), 10);
    }

    #[test]
    fn handles_negatives_and_zeros() {
        let g = [0.0f32, -0.0, 0.0, -1.0];
        assert_eq!(threshold_for_topk_abs(&g, 1), 1.0);
        assert_eq!(threshold_for_topk_abs(&g, 2), 0.0);
    }
}
