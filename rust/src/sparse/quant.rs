//! QSGD-style stochastic quantization of sparse values (Alistarh et
//! al. 2016, cited in §2.1: "quantify the sparse gradient on the basis
//! of gradient sparsification … to further reduce the transmission
//! volume").
//!
//! Values are mapped to `b`-bit levels of a per-update absmax scale
//! with *stochastic rounding*, which keeps the quantizer unbiased
//! (E[Q(x)] = x) — the property QSGD's convergence proof needs.

//! ## The quantized wire frame (v1)
//!
//! [`QuantizedSparse::encode_into`] ships the codes themselves instead
//! of dequantized f32s, so `quant_bits` changes real wire bytes:
//!
//! ```text
//! [0]      frame version (1)
//! [1]      bits b (2..=8)
//! [2..6]   n    u32 LE
//! [6..10]  nnz  u32 LE
//! [10..14] scale f32 LE
//! [14..]   delta-varint indices (shared with the f32 frame)
//! then     bitpacked codes: biased unsigned (code + levels) fields of
//!          b bits, 32/b codes per u32 word LSB-first, words LE;
//!          ceil(nnz / (32/b)) words, padding bits zero
//! ```
//!
//! Codes pack word-aligned (`32/b` per u32, the last word's tail
//! zero-padded) so the pack/unpack kernels vectorize on
//! [`crate::util::simd::U32x8`] shifts: eight words per step, one
//! vector shl/shr+mask per field position. The scalar branch is the
//! `FEDSPARSE_NO_SIMD` fallback and the bitwise parity reference
//! (PERF.md) — both branches produce identical bytes/codes.
//!
//! The server dequantizes on fold (`code as f32 / levels * scale`,
//! [`crate::coordinator::ShardedAccumulator::fold_quant`]) — the exact
//! expression [`dequantize`] evaluates client-side, so shipping codes
//! is bitwise identical to yesterday's dequantize-then-encode-f32
//! path. Secure mode stays on f32 values: pair masks are f32 sums and
//! cancellation happens in f32 space (boundary documented in PERF.md).

use crate::sparse::codec::{self, CodecError, SparseVec};
use crate::util::rng::Rng;
use crate::util::simd::U32x8;

/// Quantization config: bits per value (2..=8 supported).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    pub bits: u8,
}

impl QuantConfig {
    pub fn levels(&self) -> u32 {
        assert!((2..=8).contains(&self.bits), "bits {} outside 2..=8", self.bits);
        (1u32 << (self.bits - 1)) - 1 // signed levels per side
    }
}

/// A quantized sparse update: indices + signed level codes + scale.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct QuantizedSparse {
    pub n: u32,
    pub indices: Vec<u32>,
    /// Signed level in `[-levels, levels]`, i8 storage.
    pub codes: Vec<i8>,
    pub scale: f32,
    pub bits: u8,
}

/// Version byte at the head of the quantized wire frame.
pub const QUANT_FRAME_VERSION: u8 = 1;

/// Codes per packed u32 word: `32 / b` (the word tail past
/// `cpw·b` bits stays zero).
#[inline]
fn codes_per_word(bits: u8) -> usize {
    32 / bits as usize
}

impl QuantizedSparse {
    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Packed-code section size in bytes for `nnz` codes at `bits`.
    pub fn packed_bytes(nnz: usize, bits: u8) -> usize {
        nnz.div_ceil(codes_per_word(bits)) * 4
    }

    /// Encode the v1 quantized wire frame (see the module doc for the
    /// layout) into a caller-owned buffer (cleared first) — the
    /// zero-alloc twin of [`SparseVec::encode_into`].
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        out.clear();
        out.push(QUANT_FRAME_VERSION);
        out.push(self.bits);
        out.extend_from_slice(&self.n.to_le_bytes());
        out.extend_from_slice(&(self.nnz() as u32).to_le_bytes());
        out.extend_from_slice(&self.scale.to_le_bytes());
        codec::encode_indices(&self.indices, out);
        pack_codes_with(&self.codes, self.bits, out, crate::util::simd::enabled());
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(14 + self.nnz() * 4);
        self.encode_into(&mut out);
        out
    }

    /// Decode [`encode`](Self::encode) output into a caller-owned
    /// frame, reusing its buffers (the coordinator's streaming-Collect
    /// scratch). On error `out` is left cleared, never partially
    /// decoded.
    pub fn decode_into(bytes: &[u8], out: &mut QuantizedSparse) -> Result<(), CodecError> {
        out.n = 0;
        out.scale = 0.0;
        out.bits = 0;
        out.indices.clear();
        out.codes.clear();
        if bytes.len() < 14 {
            return Err(CodecError::Truncated);
        }
        if bytes[0] != QUANT_FRAME_VERSION {
            return Err(CodecError::Corrupt("frame version"));
        }
        let bits = bytes[1];
        if !(2..=8).contains(&bits) {
            return Err(CodecError::Corrupt("bits out of range"));
        }
        let n = u32::from_le_bytes(bytes[2..6].try_into().unwrap());
        let nnz = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
        let scale = f32::from_le_bytes(bytes[10..14].try_into().unwrap());
        let pos = 14 + match codec::decode_indices(&bytes[14..], nnz, n, &mut out.indices) {
            Ok(used) => used,
            Err(e) => {
                out.indices.clear();
                return Err(e);
            }
        };
        if let Err(e) =
            unpack_codes_with(&bytes[pos..], nnz, bits, &mut out.codes, crate::util::simd::enabled())
        {
            out.indices.clear();
            out.codes.clear();
            return Err(e);
        }
        out.n = n;
        out.scale = scale;
        out.bits = bits;
        Ok(())
    }

    pub fn decode(bytes: &[u8]) -> Result<Self, CodecError> {
        let mut out = Self::default();
        Self::decode_into(bytes, &mut out)?;
        Ok(out)
    }
}

/// Fused decode+dequantize+fold for the pool-parallel Collect: stream
/// the quantized frame's entries whose index lies in `[start, end)`
/// into `acc` as `acc[idx - start] += code as f32 / levels * scale` —
/// the exact [`dequantize`] expression, evaluated server-side. Returns
/// the frame's dense dimension `n`. Every index of the frame is
/// validated ([`codec::walk_indices`] guards); each code is validated
/// by the one shard whose range contains its index, so a partition of
/// `[0, n)` validates every code exactly once.
pub fn fold_quant_range(
    bytes: &[u8],
    start: u32,
    end: u32,
    acc: &mut [f32],
) -> Result<u32, CodecError> {
    if bytes.len() < 14 {
        return Err(CodecError::Truncated);
    }
    if bytes[0] != QUANT_FRAME_VERSION {
        return Err(CodecError::Corrupt("frame version"));
    }
    let bits = bytes[1];
    if !(2..=8).contains(&bits) {
        return Err(CodecError::Corrupt("bits out of range"));
    }
    let n = u32::from_le_bytes(bytes[2..6].try_into().unwrap());
    let nnz = u32::from_le_bytes(bytes[6..10].try_into().unwrap()) as usize;
    let scale = f32::from_le_bytes(bytes[10..14].try_into().unwrap());
    let idx_bytes = &bytes[14..];
    let used = codec::walk_indices(idx_bytes, nnz, n, |_, _| {})?;
    let codes = &idx_bytes[used..];
    let cpw = codes_per_word(bits);
    if codes.len() < nnz.div_ceil(cpw) * 4 {
        return Err(CodecError::Truncated);
    }
    let b = bits as u32;
    let mask = (1u32 << b) - 1;
    let levels = QuantConfig { bits }.levels() as i32;
    let top = (2 * levels) as u32;
    let levels_f = levels as f32;
    let mut bad = false;
    codec::walk_indices(idx_bytes, nnz, n, |k, idx| {
        if idx >= start && idx < end {
            let word =
                u32::from_le_bytes(codes[(k / cpw) * 4..(k / cpw) * 4 + 4].try_into().unwrap());
            let raw = (word >> ((k % cpw) as u32 * b)) & mask;
            if raw > top {
                bad = true;
            } else {
                acc[(idx - start) as usize] += (raw as i32 - levels) as f32 / levels_f * scale;
            }
        }
    })?;
    if bad {
        return Err(CodecError::Corrupt("code out of range"));
    }
    Ok(n)
}

/// Bitpack signed codes (each in `[-levels, levels]`) as biased
/// unsigned `code + levels` fields, `32/bits` per u32 word LSB-first,
/// words appended LE. The SIMD branch fills eight words per step — one
/// [`U32x8`] shl+or per field position, lane `w` accumulating word
/// `w` — and is bitwise identical to the scalar branch (the
/// `FEDSPARSE_NO_SIMD` fallback and parity reference).
pub fn pack_codes_with(codes: &[i8], bits: u8, out: &mut Vec<u8>, use_simd: bool) {
    let levels = QuantConfig { bits }.levels() as i32;
    let cpw = codes_per_word(bits);
    let b = bits as u32;
    let mut i = 0usize;
    if use_simd {
        while i + 8 * cpw <= codes.len() {
            let mut acc = U32x8::splat(0);
            for j in 0..cpw {
                let lanes: [u32; 8] =
                    std::array::from_fn(|w| (codes[i + w * cpw + j] as i32 + levels) as u32);
                acc = acc.or(U32x8::from_array(lanes).shl(j as u32 * b));
            }
            for word in acc.to_array() {
                out.extend_from_slice(&word.to_le_bytes());
            }
            i += 8 * cpw;
        }
    }
    while i < codes.len() {
        let take = cpw.min(codes.len() - i);
        let mut word = 0u32;
        for j in 0..take {
            word |= ((codes[i + j] as i32 + levels) as u32) << (j as u32 * b);
        }
        out.extend_from_slice(&word.to_le_bytes());
        i += take;
    }
}

/// Unpack `nnz` bitpacked codes from `bytes` into `out` (cleared
/// first). Rejects fields outside the biased range `0..=2·levels` and
/// nonzero padding past the last code — a corrupt frame never yields
/// out-of-budget codes. SIMD branch mirrors [`pack_codes_with`]: eight
/// words per step, one [`U32x8`] shr+and per field position; identical
/// output and acceptance to the scalar branch.
pub fn unpack_codes_with(
    bytes: &[u8],
    nnz: usize,
    bits: u8,
    out: &mut Vec<i8>,
    use_simd: bool,
) -> Result<(), CodecError> {
    let levels = QuantConfig { bits }.levels() as i32;
    let cpw = codes_per_word(bits);
    let words = nnz.div_ceil(cpw);
    out.clear();
    if bytes.len() < words * 4 {
        return Err(CodecError::Truncated);
    }
    let b = bits as u32;
    let mask = (1u32 << b) - 1;
    let top = (2 * levels) as u32; // biased codes are 0..=2·levels
    out.reserve(nnz);
    let mut w = 0usize;
    if use_simd {
        while (w + 8) * cpw <= nnz {
            let v = U32x8::load_le(&bytes[w * 4..]);
            let base = out.len();
            out.resize(base + 8 * cpw, 0);
            let mut bad = false;
            for j in 0..cpw {
                let fields = v.shr(j as u32 * b).and(U32x8::splat(mask)).to_array();
                for (l, &raw) in fields.iter().enumerate() {
                    bad |= raw > top;
                    out[base + l * cpw + j] = (raw as i32 - levels) as i8;
                }
            }
            if bad {
                out.clear();
                return Err(CodecError::Corrupt("code out of range"));
            }
            w += 8;
        }
    }
    let mut i = w * cpw;
    while i < nnz {
        let word = u32::from_le_bytes(bytes[w * 4..w * 4 + 4].try_into().unwrap());
        let take = cpw.min(nnz - i);
        for j in 0..take {
            let raw = (word >> (j as u32 * b)) & mask;
            if raw > top {
                out.clear();
                return Err(CodecError::Corrupt("code out of range"));
            }
            out.push((raw as i32 - levels) as i8);
        }
        // bits past the last code of the final word must be zero —
        // one canonical encoding per payload
        if take < cpw && (word >> (take as u32 * b)) != 0 {
            out.clear();
            return Err(CodecError::Corrupt("nonzero padding"));
        }
        w += 1;
        i += take;
    }
    Ok(())
}

/// Stochastically quantize a sparse vector's values.
pub fn quantize(sv: &SparseVec, cfg: QuantConfig, rng: &mut Rng) -> QuantizedSparse {
    let levels = cfg.levels() as f32;
    let scale = sv
        .values
        .iter()
        .fold(0f32, |m, &v| m.max(v.abs()));
    let codes = sv
        .values
        .iter()
        .map(|&v| {
            if scale == 0.0 {
                return 0i8;
            }
            let x = v / scale * levels; // in [-levels, levels]
            let lo = x.floor();
            let frac = x - lo;
            // stochastic rounding: up with prob = frac → unbiased
            let q = lo + if (rng.next_f64() as f32) < frac { 1.0 } else { 0.0 };
            q.clamp(-levels, levels) as i8
        })
        .collect();
    QuantizedSparse { n: sv.n, indices: sv.indices.clone(), codes, scale, bits: cfg.bits }
}

/// Reconstruct the (lossy) sparse vector.
pub fn dequantize(q: &QuantizedSparse) -> SparseVec {
    let levels = QuantConfig { bits: q.bits }.levels() as f32;
    SparseVec {
        n: q.n,
        indices: q.indices.clone(),
        values: q
            .codes
            .iter()
            .map(|&c| c as f32 / levels * q.scale)
            .collect(),
    }
}

/// Paper-model wire cost: 32-bit index + `bits` per value + scale.
pub fn quant_cost_bytes(nnz: usize, bits: u8) -> u64 {
    (nnz as u64 * (32 + bits as u64)).div_ceil(8) + 4
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(values: Vec<f32>) -> SparseVec {
        SparseVec {
            n: values.len() as u32,
            indices: (0..values.len() as u32).collect(),
            values,
        }
    }

    #[test]
    fn roundtrip_preserves_support_and_scale() {
        let mut rng = Rng::new(1);
        let v = sv(vec![0.5, -1.0, 0.25, 0.75]);
        let q = quantize(&v, QuantConfig { bits: 8 }, &mut rng);
        let d = dequantize(&q);
        assert_eq!(d.indices, v.indices);
        // absmax element is exactly representable
        assert!((d.values[1] + 1.0).abs() < 1e-6);
        // others within one level
        let lsb = 1.0 / QuantConfig { bits: 8 }.levels() as f32;
        for (a, b) in d.values.iter().zip(&v.values) {
            assert!((a - b).abs() <= lsb + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut rng = Rng::new(2);
        let v = sv(vec![0.333, -0.777, 1.0]);
        let cfg = QuantConfig { bits: 4 };
        let trials = 5000;
        let mut sums = vec![0f64; 3];
        for _ in 0..trials {
            let d = dequantize(&quantize(&v, cfg, &mut rng));
            for (s, &x) in sums.iter_mut().zip(&d.values) {
                *s += x as f64;
            }
        }
        for (mean, &truth) in sums.iter().map(|s| s / trials as f64).zip(&v.values) {
            assert!(
                (mean - truth as f64).abs() < 0.02,
                "biased: {mean} vs {truth}"
            );
        }
    }

    #[test]
    fn zero_vector_safe() {
        let mut rng = Rng::new(3);
        let v = sv(vec![0.0, 0.0]);
        let q = quantize(&v, QuantConfig { bits: 4 }, &mut rng);
        assert_eq!(q.scale, 0.0);
        assert!(dequantize(&q).values.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn codes_within_bit_budget() {
        let mut rng = Rng::new(4);
        let vals: Vec<f32> = (0..1000).map(|i| ((i as f32) / 500.0) - 1.0).collect();
        let cfg = QuantConfig { bits: 3 };
        let q = quantize(&sv(vals), cfg, &mut rng);
        let lim = cfg.levels() as i8;
        assert!(q.codes.iter().all(|&c| (-lim..=lim).contains(&c)));
    }

    #[test]
    fn cost_below_plain_sparse() {
        assert!(quant_cost_bytes(1000, 4) < crate::sparse::codec::sparse_cost_bytes(1000) / 2);
    }

    #[test]
    #[should_panic(expected = "outside 2..=8")]
    fn bad_bits_rejected() {
        QuantConfig { bits: 1 }.levels();
    }

    /// A quantized update with exactly `nnz` entries at spread-out
    /// sorted indices.
    fn random_quant(seed: u64, nnz: usize, bits: u8) -> QuantizedSparse {
        let mut rng = Rng::new(seed);
        let n = (nnz as u32 * 7).max(16);
        let v = SparseVec {
            n,
            indices: (0..nnz as u32).map(|i| i * 7 + (seed as u32 % 7)).collect(),
            values: (0..nnz).map(|_| rng.normal_f32(1.0)).collect(),
        };
        quantize(&v, QuantConfig { bits }, &mut rng)
    }

    #[test]
    fn wire_roundtrip_matches_client_side_dequantize() {
        // encode → decode → server-side dequantize must be bitwise
        // equal to dequantizing the original client-side — the parity
        // that keeps the plain-path goldens pinned when quant_bits is
        // set. Lane-remainder nnz values per the PERF.md contract.
        for bits in [2u8, 4, 8] {
            for nnz in [0usize, 1, 7, 8, 9, 1590] {
                let q = random_quant(100 + nnz as u64, nnz, bits);
                let bytes = q.encode();
                let d = QuantizedSparse::decode(&bytes)
                    .unwrap_or_else(|e| panic!("bits={bits} nnz={nnz}: {e}"));
                assert_eq!(d, q, "bits={bits} nnz={nnz}");
                let (dv, qv) = (dequantize(&d), dequantize(&q));
                assert_eq!(dv.indices, qv.indices);
                assert!(
                    dv.values.iter().zip(&qv.values).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "bits={bits} nnz={nnz}: dequantized values diverged"
                );
            }
        }
    }

    #[test]
    fn pack_unpack_simd_bitwise_matches_scalar() {
        for bits in 2u8..=8 {
            for nnz in [0usize, 1, 7, 8, 9, 17, 64, 65, 1590] {
                let q = random_quant(7 * nnz as u64 + bits as u64, nnz, bits);
                let mut packed_simd = Vec::new();
                let mut packed_scalar = Vec::new();
                pack_codes_with(&q.codes, bits, &mut packed_simd, true);
                pack_codes_with(&q.codes, bits, &mut packed_scalar, false);
                assert_eq!(packed_simd, packed_scalar, "bits={bits} nnz={nnz}: pack");
                let mut up_simd = Vec::new();
                let mut up_scalar = Vec::new();
                unpack_codes_with(&packed_simd, nnz, bits, &mut up_simd, true).unwrap();
                unpack_codes_with(&packed_scalar, nnz, bits, &mut up_scalar, false).unwrap();
                assert_eq!(up_simd, up_scalar, "bits={bits} nnz={nnz}: unpack");
                assert_eq!(up_simd, q.codes, "bits={bits} nnz={nnz}: roundtrip");
            }
        }
    }

    #[test]
    fn decode_into_reuses_buffers_and_clears_on_error() {
        let a = random_quant(31, 1000, 4);
        let b = random_quant(32, 500, 4);
        let mut scratch = QuantizedSparse::default();
        QuantizedSparse::decode_into(&a.encode(), &mut scratch).unwrap();
        assert_eq!(scratch, a);
        let cap = scratch.indices.capacity();
        QuantizedSparse::decode_into(&b.encode(), &mut scratch).unwrap();
        assert_eq!(scratch, b);
        assert_eq!(scratch.indices.capacity(), cap);
        let bytes = a.encode();
        assert_eq!(
            QuantizedSparse::decode_into(&bytes[..bytes.len() - 2], &mut scratch),
            Err(CodecError::Truncated)
        );
        assert_eq!(scratch.nnz(), 0);
        assert!(scratch.codes.is_empty());
        assert_eq!(scratch.n, 0);
    }

    #[test]
    fn decode_rejects_bad_version_bits_and_out_of_budget_codes() {
        let q = random_quant(41, 64, 4);
        let good = q.encode();
        let mut bad = good.clone();
        bad[0] = 2; // unknown version
        assert_eq!(QuantizedSparse::decode(&bad), Err(CodecError::Corrupt("frame version")));
        let mut bad = good.clone();
        bad[1] = 9; // bits outside 2..=8
        assert_eq!(QuantizedSparse::decode(&bad), Err(CodecError::Corrupt("bits out of range")));
        // a packed field of all-ones (= 2·levels + 1) is out of budget
        let mut bad = good.clone();
        let last = bad.len() - 1;
        bad[last] = 0xff;
        assert!(matches!(QuantizedSparse::decode(&bad), Err(CodecError::Corrupt(_))));
    }

    #[test]
    fn quant_frame_at_4_bits_is_under_45_percent_of_f32_frame() {
        // the acceptance ratio, asserted at the codec level: same
        // support, 4-bit codes vs f32 values
        let mut rng = Rng::new(51);
        let mut dense = vec![0f32; 159_010];
        for v in dense.iter_mut() {
            if rng.next_f64() < 0.01 {
                *v = rng.normal_f32(1.0);
            }
        }
        let sv = SparseVec::from_dense(&dense);
        let q = quantize(&sv, QuantConfig { bits: 4 }, &mut rng);
        let f32_bytes = sv.encode().len();
        let q_bytes = q.encode().len();
        assert!(
            (q_bytes as f64) <= 0.45 * f32_bytes as f64,
            "quantized frame {q_bytes} vs f32 frame {f32_bytes}"
        );
    }
}
