//! QSGD-style stochastic quantization of sparse values (Alistarh et
//! al. 2016, cited in §2.1: "quantify the sparse gradient on the basis
//! of gradient sparsification … to further reduce the transmission
//! volume").
//!
//! Values are mapped to `b`-bit levels of a per-update absmax scale
//! with *stochastic rounding*, which keeps the quantizer unbiased
//! (E[Q(x)] = x) — the property QSGD's convergence proof needs.

use crate::sparse::codec::SparseVec;
use crate::util::rng::Rng;

/// Quantization config: bits per value (2..=8 supported).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct QuantConfig {
    pub bits: u8,
}

impl QuantConfig {
    pub fn levels(&self) -> u32 {
        assert!((2..=8).contains(&self.bits), "bits {} outside 2..=8", self.bits);
        (1u32 << (self.bits - 1)) - 1 // signed levels per side
    }
}

/// A quantized sparse update: indices + signed level codes + scale.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedSparse {
    pub n: u32,
    pub indices: Vec<u32>,
    /// Signed level in `[-levels, levels]`, i8 storage.
    pub codes: Vec<i8>,
    pub scale: f32,
    pub bits: u8,
}

/// Stochastically quantize a sparse vector's values.
pub fn quantize(sv: &SparseVec, cfg: QuantConfig, rng: &mut Rng) -> QuantizedSparse {
    let levels = cfg.levels() as f32;
    let scale = sv
        .values
        .iter()
        .fold(0f32, |m, &v| m.max(v.abs()));
    let codes = sv
        .values
        .iter()
        .map(|&v| {
            if scale == 0.0 {
                return 0i8;
            }
            let x = v / scale * levels; // in [-levels, levels]
            let lo = x.floor();
            let frac = x - lo;
            // stochastic rounding: up with prob = frac → unbiased
            let q = lo + if (rng.next_f64() as f32) < frac { 1.0 } else { 0.0 };
            q.clamp(-levels, levels) as i8
        })
        .collect();
    QuantizedSparse { n: sv.n, indices: sv.indices.clone(), codes, scale, bits: cfg.bits }
}

/// Reconstruct the (lossy) sparse vector.
pub fn dequantize(q: &QuantizedSparse) -> SparseVec {
    let levels = QuantConfig { bits: q.bits }.levels() as f32;
    SparseVec {
        n: q.n,
        indices: q.indices.clone(),
        values: q
            .codes
            .iter()
            .map(|&c| c as f32 / levels * q.scale)
            .collect(),
    }
}

/// Paper-model wire cost: 32-bit index + `bits` per value + scale.
pub fn quant_cost_bytes(nnz: usize, bits: u8) -> u64 {
    (nnz as u64 * (32 + bits as u64)).div_ceil(8) + 4
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(values: Vec<f32>) -> SparseVec {
        SparseVec {
            n: values.len() as u32,
            indices: (0..values.len() as u32).collect(),
            values,
        }
    }

    #[test]
    fn roundtrip_preserves_support_and_scale() {
        let mut rng = Rng::new(1);
        let v = sv(vec![0.5, -1.0, 0.25, 0.75]);
        let q = quantize(&v, QuantConfig { bits: 8 }, &mut rng);
        let d = dequantize(&q);
        assert_eq!(d.indices, v.indices);
        // absmax element is exactly representable
        assert!((d.values[1] + 1.0).abs() < 1e-6);
        // others within one level
        let lsb = 1.0 / QuantConfig { bits: 8 }.levels() as f32;
        for (a, b) in d.values.iter().zip(&v.values) {
            assert!((a - b).abs() <= lsb + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        let mut rng = Rng::new(2);
        let v = sv(vec![0.333, -0.777, 1.0]);
        let cfg = QuantConfig { bits: 4 };
        let trials = 5000;
        let mut sums = vec![0f64; 3];
        for _ in 0..trials {
            let d = dequantize(&quantize(&v, cfg, &mut rng));
            for (s, &x) in sums.iter_mut().zip(&d.values) {
                *s += x as f64;
            }
        }
        for (mean, &truth) in sums.iter().map(|s| s / trials as f64).zip(&v.values) {
            assert!(
                (mean - truth as f64).abs() < 0.02,
                "biased: {mean} vs {truth}"
            );
        }
    }

    #[test]
    fn zero_vector_safe() {
        let mut rng = Rng::new(3);
        let v = sv(vec![0.0, 0.0]);
        let q = quantize(&v, QuantConfig { bits: 4 }, &mut rng);
        assert_eq!(q.scale, 0.0);
        assert!(dequantize(&q).values.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn codes_within_bit_budget() {
        let mut rng = Rng::new(4);
        let vals: Vec<f32> = (0..1000).map(|i| ((i as f32) / 500.0) - 1.0).collect();
        let cfg = QuantConfig { bits: 3 };
        let q = quantize(&sv(vals), cfg, &mut rng);
        let lim = cfg.levels() as i8;
        assert!(q.codes.iter().all(|&c| (-lim..=lim).contains(&c)));
    }

    #[test]
    fn cost_below_plain_sparse() {
        assert!(quant_cost_bytes(1000, 4) < crate::sparse::codec::sparse_cost_bytes(1000) / 2);
    }

    #[test]
    #[should_panic(expected = "outside 2..=8")]
    fn bad_bits_rejected() {
        QuantConfig { bits: 1 }.levels();
    }
}
