//! DGC-style momentum correction + warm-up (Lin et al. 2018, §2.1;
//! also the paper's §6 future work: "adding gradient correction … to
//! the sparse gradient update process").
//!
//! Plain residual accumulation delays *velocity*, not just gradients;
//! DGC fixes this by accumulating momentum-corrected updates:
//!
//! ```text
//! u_t = m · u_{t-1} + g_t          (velocity)
//! v_t = v_{t-1} + u_t              (accumulated correction)
//! sparsify(v_t); v keeps the unsent mass
//! ```
//!
//! Warm-up: during the first `warmup_rounds` the sparsity rate is
//! relaxed exponentially from dense toward the target, which DGC found
//! necessary for aggressive (≤0.1%) rates.

/// Momentum-correction state for one client.
#[derive(Clone, Debug)]
pub struct MomentumCorrector {
    /// Momentum coefficient m.
    pub momentum: f32,
    velocity: Vec<f32>,
}

impl MomentumCorrector {
    pub fn new(n: usize, momentum: f32) -> Self {
        assert!((0.0..1.0).contains(&momentum), "momentum {momentum} outside [0,1)");
        Self { momentum, velocity: vec![0.0; n] }
    }

    /// Fold this round's raw update `g` through the velocity and
    /// return the corrected update to be accumulated + sparsified.
    pub fn correct(&mut self, g: &[f32]) -> Vec<f32> {
        let mut out = g.to_vec();
        self.correct_in_place(&mut out);
        out
    }

    /// [`Self::correct`] writing the corrected update back into `g` —
    /// the round engine's allocation-free path (identical math:
    /// velocity advances, then `g` becomes the velocity).
    pub fn correct_in_place(&mut self, g: &mut [f32]) {
        assert_eq!(g.len(), self.velocity.len(), "velocity size mismatch");
        for (u, x) in self.velocity.iter_mut().zip(g.iter_mut()) {
            *u = self.momentum * *u + *x;
            *x = *u;
        }
    }

    /// [`Self::correct_in_place`] in double-buffer form: advance
    /// `prev`'s velocity into `self` (leaving `prev` untouched — a
    /// rollback snapshot may share it) while writing the corrected
    /// update back into `g`. Bitwise-identical math to the in-place
    /// path (`m·u + x` per position, then `g` becomes the velocity);
    /// `self` adapts its size and coefficient to `prev`, so any
    /// recycled corrector works as the write target.
    pub fn correct_from(&mut self, prev: &MomentumCorrector, g: &mut [f32]) {
        assert_eq!(g.len(), prev.velocity.len(), "velocity size mismatch");
        self.momentum = prev.momentum;
        self.velocity.clear();
        self.velocity
            .extend(prev.velocity.iter().zip(g.iter()).map(|(&u, &x)| prev.momentum * u + x));
        g.copy_from_slice(&self.velocity);
    }

    /// DGC "momentum factor masking": zero the velocity at positions
    /// that shipped this round (they start fresh).
    pub fn mask_sent(&mut self, sparse: &[f32]) {
        assert_eq!(sparse.len(), self.velocity.len(), "mask size mismatch");
        for (u, &s) in self.velocity.iter_mut().zip(sparse) {
            if s != 0.0 {
                *u = 0.0;
            }
        }
    }

    pub fn velocity_norm(&self) -> f64 {
        self.velocity.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }

    /// Velocity buffer (checkpoint serialization).
    pub fn velocity(&self) -> &[f32] {
        &self.velocity
    }

    /// Overwrite the velocity from a checkpoint snapshot.
    pub fn restore_velocity(&mut self, v: &[f32]) {
        self.velocity.clear();
        self.velocity.extend_from_slice(v);
    }
}

/// Warm-up schedule: exponentially tighten the sparsity rate from 1.0
/// (dense) to `target` over `warmup_rounds` (DGC used 4 epochs:
/// 25% → 6.25% → 1.5625% → 0.4% → target).
pub fn warmup_rate(target: f64, warmup_rounds: u64, round: u64) -> f64 {
    if warmup_rounds == 0 || round >= warmup_rounds {
        return target;
    }
    // geometric interpolation 1.0 → target
    let frac = (round + 1) as f64 / (warmup_rounds + 1) as f64;
    target.powf(frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn velocity_accumulates_geometrically() {
        let mut mc = MomentumCorrector::new(1, 0.5);
        let c1 = mc.correct(&[1.0]);
        let c2 = mc.correct(&[1.0]);
        let c3 = mc.correct(&[1.0]);
        assert_eq!(c1[0], 1.0);
        assert_eq!(c2[0], 1.5);
        assert_eq!(c3[0], 1.75);
    }

    #[test]
    fn zero_momentum_is_identity() {
        let mut mc = MomentumCorrector::new(3, 0.0);
        let g = vec![0.1f32, -0.5, 2.0];
        assert_eq!(mc.correct(&g), g);
        assert_eq!(mc.correct(&g), g);
    }

    #[test]
    fn correct_from_matches_in_place_bitwise() {
        let mut rng_state = 0x9e3779b97f4a7c15u64;
        let mut next = move || {
            rng_state = rng_state.wrapping_mul(6364136223846793005).wrapping_add(1);
            ((rng_state >> 40) as f32 / (1u32 << 24) as f32) - 0.5
        };
        let mut serial = MomentumCorrector::new(64, 0.7);
        let mut prev = MomentumCorrector::new(64, 0.7);
        // the write target starts deliberately mis-sized: correct_from
        // must adapt it
        let mut fresh = MomentumCorrector::new(3, 0.1);
        for _ in 0..5 {
            let g: Vec<f32> = (0..64).map(|_| next()).collect();
            let mut a = g.clone();
            serial.correct_in_place(&mut a);
            let mut b = g.clone();
            fresh.correct_from(&prev, &mut b);
            assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
            // the double-buffer swap the round engine performs
            std::mem::swap(&mut prev, &mut fresh);
        }
        assert_eq!(prev.momentum, 0.7);
    }

    #[test]
    fn correct_from_leaves_prev_untouched() {
        let mut prev = MomentumCorrector::new(2, 0.5);
        prev.correct(&[1.0, 2.0]);
        let norm_before = prev.velocity_norm();
        let mut fresh = MomentumCorrector::new(2, 0.5);
        fresh.correct_from(&prev, &mut [3.0, 3.0]);
        assert_eq!(prev.velocity_norm(), norm_before);
        assert!(fresh.velocity_norm() > norm_before);
    }

    #[test]
    fn mask_sent_resets_velocity() {
        let mut mc = MomentumCorrector::new(2, 0.9);
        mc.correct(&[1.0, 1.0]);
        mc.mask_sent(&[1.0, 0.0]); // position 0 shipped
        let c = mc.correct(&[0.0, 0.0]);
        assert_eq!(c[0], 0.0);
        assert!(c[1] > 0.0);
    }

    #[test]
    fn warmup_monotone_to_target() {
        let target = 0.001;
        let mut prev = 1.0;
        for r in 0..10 {
            let rate = warmup_rate(target, 8, r);
            assert!(rate <= prev + 1e-12, "round {r}: {rate} > {prev}");
            assert!(rate >= target - 1e-12);
            prev = rate;
        }
        assert_eq!(warmup_rate(target, 8, 8), target);
        assert_eq!(warmup_rate(target, 0, 0), target);
    }

    #[test]
    #[should_panic(expected = "outside [0,1)")]
    fn bad_momentum_rejected() {
        MomentumCorrector::new(1, 1.0);
    }
}
