//! # fedsparse
//!
//! Reproduction of *"Efficient and Secure Federated Learning for
//! Financial Applications"* (cs.LG 2023).
//!
//! The crate is the **Layer-3 coordinator**: it owns the federated
//! round loop, the paper's two contributions — time-varying
//! hierarchical gradient sparsification ([`sparse::thgs`], Alg. 1) and
//! mask-sparsified secure aggregation ([`secagg`], Alg. 2) — plus every
//! substrate they need (datasets, partitioning, DH/PRG crypto, sparse
//! codecs, comm-cost accounting, transport, model compute backends,
//! metrics, config and CLI).
//!
//! ## The round engine
//!
//! Every federated round runs through the phased engine in
//! [`coordinator::round`]:
//!
//! ```text
//! Select           C·K of N clients, seeded
//! LocalTrain       parallel local SGD (E iterations) per client
//! Sparsify/Encode  residual fold + Eq.2 rate + Top-k (+ pairwise masks) + codec
//! Collect          the transport (in-process twin, TCP, or UDS — all
//!                  conformance-pinned) carries the framed uplinks; a
//!                  seeded FailurePlan injects crashes (dropout_prob)
//!                  and past-deadline stragglers (straggler_timeout_s),
//!                  a seeded ChaosPlan injects loss/dup/reorder/slow
//!                  links
//! Unmask/Recover   [secure] Shamir-reconstruct dead clients' pair keys,
//!                  cancel their orphaned masks (abort below min_survivors)
//! Apply            global ← global + Σ/|survivors|
//! Eval             test metrics + cost ledger + per-phase timings
//! ```
//!
//! With failure injection off (the default) the engine is byte-for-byte
//! the paper's §5 loop; with it on, the Bonawitz-style dropout recovery
//! in [`secagg::protocol`] runs end-to-end.
//!
//! ## Compute backends
//!
//! Model compute (forward/grad/eval) goes through the
//! [`runtime::Backend`] trait; [`config::RunConfig::backend`] selects
//! the implementation:
//!
//! * **native** (default, always available) — pure-Rust MLP compute on
//!   flat parameter vectors. No Python, JAX, or artifacts required: a
//!   built-in manifest describes `mnist_mlp` (159,010 params), so a
//!   clean checkout trains end-to-end, deterministically, with
//!   `cargo test` / `cargo run` alone.
//! * **pjrt** (cargo feature `pjrt`) — the AOT path: `make artifacts`
//!   lowers the JAX/Pallas graphs to `artifacts/*.hlo.txt` once, and
//!   the runtime executes them through the PJRT C API. Required for
//!   the conv models (`mnist_cnn`, `cifar_*`).
//! * **auto** (the default [`runtime::BackendKind`]) — pjrt when the
//!   feature is on and the model's artifacts exist, native otherwise.
//!
//! Python never runs on the round path in either mode.
//!
//! Quickstart — no artifacts, no Python, just cargo (see
//! `examples/quickstart.rs`):
//!
//! ```no_run
//! use fedsparse::config::RunConfig;
//! use fedsparse::coordinator::Trainer;
//!
//! let mut cfg = RunConfig::default();
//! cfg.model = "mnist_mlp".into();
//! cfg.rounds = 20;
//! let mut trainer = Trainer::new(cfg).unwrap();
//! println!("backend: {}", trainer.backend_name());
//! let summary = trainer.run().unwrap();
//! println!("final acc {:.3}", summary.final_accuracy);
//! ```

pub mod attack;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod io;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod secagg;
pub mod sparse;
pub mod util;

pub use config::RunConfig;
pub use coordinator::Trainer;
pub use runtime::BackendKind;
