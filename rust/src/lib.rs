//! # fedsparse
//!
//! Reproduction of *"Efficient and Secure Federated Learning for
//! Financial Applications"* (cs.LG 2023) as a three-layer
//! rust + JAX + Pallas system (AOT via PJRT).
//!
//! The crate is the **Layer-3 coordinator**: it owns the federated
//! round loop, the paper's two contributions — time-varying
//! hierarchical gradient sparsification ([`sparse::thgs`], Alg. 1) and
//! mask-sparsified secure aggregation ([`secagg`], Alg. 2) — plus every
//! substrate they need (datasets, partitioning, DH/PRG crypto, sparse
//! codecs, comm-cost accounting, a PJRT runtime for the AOT-compiled
//! JAX/Pallas compute graphs, metrics, config and CLI).
//!
//! Python never runs on the round path: `make artifacts` lowers the
//! L2/L1 graphs to `artifacts/*.hlo.txt` once, and [`runtime`] loads
//! them through the PJRT C API.
//!
//! Quickstart (see `examples/quickstart.rs`):
//!
//! ```no_run
//! use fedsparse::config::RunConfig;
//! use fedsparse::coordinator::Trainer;
//!
//! let mut cfg = RunConfig::default();
//! cfg.model = "mnist_mlp".into();
//! cfg.rounds = 20;
//! let mut trainer = Trainer::new(cfg).unwrap();
//! let summary = trainer.run().unwrap();
//! println!("final acc {:.3}", summary.final_accuracy);
//! ```

pub mod attack;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod metrics;
pub mod models;
pub mod runtime;
pub mod secagg;
pub mod sparse;
pub mod util;

pub use config::RunConfig;
pub use coordinator::Trainer;
