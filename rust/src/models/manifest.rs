//! Parse `artifacts/manifest.json` (written by `python -m compile.aot`).

use std::path::{Path, PathBuf};

use crate::util::json::{self, Value};

#[derive(Debug, thiserror::Error)]
pub enum ManifestError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("json: {0}")]
    Json(#[from] json::ParseError),
    #[error("manifest schema: {0}")]
    Schema(String),
}

/// Parameter initialization spec.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitKind {
    Normal { std: f32 },
    Zeros,
    Ones,
}

/// One parameter tensor.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: InitKind,
    /// Network-layer index (THGS grouping).
    pub layer: usize,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// THGS layer group: indices into the params list.
#[derive(Clone, Debug)]
pub struct LayerGroup {
    pub name: String,
    pub params: Vec<usize>,
}

/// One model's metadata.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    pub name: String,
    pub input: Vec<usize>,
    pub classes: usize,
    pub params: Vec<ParamSpec>,
    pub layers: Vec<LayerGroup>,
    pub param_count: usize,
    pub grad_artifact: String,
    pub eval_artifact: String,
}

impl ModelMeta {
    /// Flat-vector spans `(start, len)` per THGS layer group, in the
    /// concatenation order of `params`.
    pub fn layer_spans(&self) -> Vec<(usize, usize)> {
        // offsets of each param in the flat concat
        let mut offsets = Vec::with_capacity(self.params.len());
        let mut off = 0usize;
        for p in &self.params {
            offsets.push(off);
            off += p.numel();
        }
        self.layers
            .iter()
            .map(|g| {
                let start = offsets[g.params[0]];
                let len: usize = g.params.iter().map(|&i| self.params[i].numel()).sum();
                // groups are contiguous in manifest order
                debug_assert!(g
                    .params
                    .windows(2)
                    .all(|w| w[1] == w[0] + 1), "non-contiguous layer group");
                (start, len)
            })
            .collect()
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub models: Vec<ModelMeta>,
    /// (size → artifact) for the standalone pallas kernels.
    pub sparsify_kernels: Vec<(usize, String)>,
    pub masked_agg_kernels: Vec<(usize, String)>,
    pub kernel_block: usize,
}

/// The compiled-in default manifest: the `mnist_mlp` layout exactly as
/// `python -m compile.aot` exports it (784→200→10, 159,010 params).
/// Lets the native backend run the full round loop from a clean
/// checkout with no Python step; the artifact file names are kept so a
/// later `make artifacts` slots in without a schema change.
const BUILTIN_MANIFEST: &str = r#"{
  "version": 1, "train_batch": 50, "eval_batch": 250,
  "models": {
    "mnist_mlp": {
      "input": [28, 28, 1], "classes": 10,
      "params": [
        {"name": "layer0/w", "shape": [784, 200],
         "init": {"kind": "normal", "std": 0.0505}, "layer": 0},
        {"name": "layer0/b", "shape": [200],
         "init": {"kind": "zeros", "std": 0.0}, "layer": 0},
        {"name": "layer1/w", "shape": [200, 10],
         "init": {"kind": "normal", "std": 0.0707}, "layer": 1},
        {"name": "layer1/b", "shape": [10],
         "init": {"kind": "zeros", "std": 0.0}, "layer": 1}
      ],
      "layers": [
        {"name": "layer0", "params": [0, 1]},
        {"name": "layer1", "params": [2, 3]}
      ],
      "param_count": 159010,
      "grad": "mnist_mlp_grad.hlo.txt",
      "eval": "mnist_mlp_eval.hlo.txt"
    }
  },
  "kernels": {
    "sparsify": {},
    "masked_agg": {},
    "block": 1024
  }
}"#;

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self, ManifestError> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(dir, &text)
    }

    /// The compiled-in default manifest (`mnist_mlp` only). Its
    /// artifact paths still resolve under `dir` = `artifacts`, but the
    /// native backend never reads them.
    pub fn builtin() -> Self {
        Self::parse(Path::new("artifacts"), BUILTIN_MANIFEST).expect("builtin manifest parses")
    }

    /// Load `dir/manifest.json` when it exists; fall back to the
    /// builtin manifest when the file is absent (no Python/JAX export
    /// has run). A *present but malformed* manifest still errors —
    /// that is corruption, not a missing optional step. The fallback
    /// keeps `dir` as its artifact root (not the builtin default), so
    /// backend auto-detection never probes a directory the caller
    /// didn't ask for.
    pub fn load_or_builtin(dir: &Path) -> Result<Self, ManifestError> {
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::parse(dir, BUILTIN_MANIFEST).expect("builtin manifest parses"))
        }
    }

    pub fn model(&self, name: &str) -> Option<&ModelMeta> {
        self.models.iter().find(|m| m.name == name)
    }

    pub fn artifact_path(&self, file: &str) -> PathBuf {
        self.dir.join(file)
    }

    fn parse(dir: &Path, text: &str) -> Result<Self, ManifestError> {
        let v = json::parse(text)?;
        let err = |m: &str| ManifestError::Schema(m.to_string());

        let train_batch = v
            .get("train_batch")
            .and_then(Value::as_usize)
            .ok_or_else(|| err("train_batch"))?;
        let eval_batch = v
            .get("eval_batch")
            .and_then(Value::as_usize)
            .ok_or_else(|| err("eval_batch"))?;

        let mut models = Vec::new();
        let model_map = v
            .get("models")
            .and_then(Value::as_object)
            .ok_or_else(|| err("models"))?;
        for (name, mv) in model_map {
            let params = mv
                .get("params")
                .and_then(Value::as_array)
                .ok_or_else(|| err("params"))?
                .iter()
                .map(|p| parse_param(p))
                .collect::<Result<Vec<_>, _>>()?;
            let layers = mv
                .get("layers")
                .and_then(Value::as_array)
                .ok_or_else(|| err("layers"))?
                .iter()
                .map(|l| {
                    Ok(LayerGroup {
                        name: l
                            .get("name")
                            .and_then(Value::as_str)
                            .ok_or_else(|| err("layer name"))?
                            .to_string(),
                        params: l
                            .get("params")
                            .and_then(Value::as_array)
                            .ok_or_else(|| err("layer params"))?
                            .iter()
                            .map(|x| x.as_usize().ok_or_else(|| err("layer param idx")))
                            .collect::<Result<Vec<_>, _>>()?,
                    })
                })
                .collect::<Result<Vec<_>, ManifestError>>()?;
            models.push(ModelMeta {
                name: name.clone(),
                input: mv
                    .get("input")
                    .and_then(Value::as_array)
                    .ok_or_else(|| err("input"))?
                    .iter()
                    .filter_map(Value::as_usize)
                    .collect(),
                classes: mv
                    .get("classes")
                    .and_then(Value::as_usize)
                    .ok_or_else(|| err("classes"))?,
                params,
                layers,
                param_count: mv
                    .get("param_count")
                    .and_then(Value::as_usize)
                    .ok_or_else(|| err("param_count"))?,
                grad_artifact: mv
                    .get("grad")
                    .and_then(Value::as_str)
                    .ok_or_else(|| err("grad"))?
                    .to_string(),
                eval_artifact: mv
                    .get("eval")
                    .and_then(Value::as_str)
                    .ok_or_else(|| err("eval"))?
                    .to_string(),
            });
        }
        models.sort_by(|a, b| a.name.cmp(&b.name));

        let kernels = v.get("kernels").ok_or_else(|| err("kernels"))?;
        let parse_kmap = |key: &str| -> Result<Vec<(usize, String)>, ManifestError> {
            let mut out: Vec<(usize, String)> = kernels
                .get(key)
                .and_then(Value::as_object)
                .ok_or_else(|| err(key))?
                .iter()
                .map(|(k, f)| {
                    Ok((
                        k.parse::<usize>().map_err(|_| err("kernel size"))?,
                        f.as_str().ok_or_else(|| err("kernel file"))?.to_string(),
                    ))
                })
                .collect::<Result<Vec<_>, ManifestError>>()?;
            out.sort_by_key(|(n, _)| *n);
            Ok(out)
        };

        Ok(Self {
            dir: dir.to_path_buf(),
            train_batch,
            eval_batch,
            models,
            sparsify_kernels: parse_kmap("sparsify")?,
            masked_agg_kernels: parse_kmap("masked_agg")?,
            kernel_block: kernels
                .get("block")
                .and_then(Value::as_usize)
                .ok_or_else(|| err("kernel block"))?,
        })
    }
}

fn parse_param(p: &Value) -> Result<ParamSpec, ManifestError> {
    let err = |m: &str| ManifestError::Schema(m.to_string());
    let init_obj = p.get("init").ok_or_else(|| err("init"))?;
    let kind = init_obj
        .get("kind")
        .and_then(Value::as_str)
        .ok_or_else(|| err("init kind"))?;
    let init = match kind {
        "normal" => InitKind::Normal {
            std: init_obj
                .get("std")
                .and_then(Value::as_f64)
                .ok_or_else(|| err("init std"))? as f32,
        },
        "zeros" => InitKind::Zeros,
        "ones" => InitKind::Ones,
        other => return Err(err(&format!("unknown init kind {other}"))),
    };
    Ok(ParamSpec {
        name: p
            .get("name")
            .and_then(Value::as_str)
            .ok_or_else(|| err("param name"))?
            .to_string(),
        shape: p
            .get("shape")
            .and_then(Value::as_array)
            .ok_or_else(|| err("param shape"))?
            .iter()
            .filter_map(Value::as_usize)
            .collect(),
        init,
        layer: p.get("layer").and_then(Value::as_usize).ok_or_else(|| err("param layer"))?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1, "train_batch": 50, "eval_batch": 250,
      "models": {
        "mnist_mlp": {
          "input": [28, 28, 1], "classes": 10,
          "params": [
            {"name": "layer0/w", "shape": [784, 200],
             "init": {"kind": "normal", "std": 0.0505}, "layer": 0},
            {"name": "layer0/b", "shape": [200],
             "init": {"kind": "zeros", "std": 0.0}, "layer": 0},
            {"name": "layer1/w", "shape": [200, 10],
             "init": {"kind": "normal", "std": 0.0707}, "layer": 1},
            {"name": "layer1/b", "shape": [10],
             "init": {"kind": "zeros", "std": 0.0}, "layer": 1}
          ],
          "layers": [
            {"name": "layer0", "params": [0, 1]},
            {"name": "layer1", "params": [2, 3]}
          ],
          "param_count": 159010,
          "grad": "mnist_mlp_grad.hlo.txt",
          "eval": "mnist_mlp_eval.hlo.txt"
        }
      },
      "kernels": {
        "sparsify": {"1024": "sparsify_1024.hlo.txt"},
        "masked_agg": {"1024": "masked_agg_1024.hlo.txt"},
        "block": 1024
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        assert_eq!(m.train_batch, 50);
        let model = m.model("mnist_mlp").unwrap();
        assert_eq!(model.param_count, 159_010);
        assert_eq!(model.total_params(), 159_010);
        assert_eq!(model.params.len(), 4);
        assert_eq!(model.params[0].numel(), 156_800);
        assert!(matches!(model.params[0].init, InitKind::Normal { .. }));
        assert_eq!(m.sparsify_kernels, vec![(1024, "sparsify_1024.hlo.txt".to_string())]);
        assert_eq!(m.kernel_block, 1024);
    }

    #[test]
    fn layer_spans_contiguous() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        let spans = m.model("mnist_mlp").unwrap().layer_spans();
        assert_eq!(spans, vec![(0, 157_000), (157_000, 2_010)]);
    }

    #[test]
    fn builtin_matches_paper_layout() {
        let m = Manifest::builtin();
        assert_eq!(m.train_batch, 50);
        assert_eq!(m.eval_batch, 250);
        let model = m.model("mnist_mlp").unwrap();
        assert_eq!(model.total_params(), 159_010);
        assert_eq!(model.total_params(), model.param_count);
        assert_eq!(model.layer_spans(), vec![(0, 157_000), (157_000, 2_010)]);
        assert!(m.sparsify_kernels.is_empty());
    }

    #[test]
    fn load_or_builtin_falls_back() {
        let m = Manifest::load_or_builtin(Path::new("/definitely/not/a/dir")).unwrap();
        assert!(m.model("mnist_mlp").is_some());
    }

    #[test]
    fn rejects_missing_fields() {
        assert!(Manifest::parse(Path::new("/"), "{}").is_err());
        assert!(Manifest::parse(Path::new("/"), "not json").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration-ish: parse the actual exported manifest when built
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.train_batch > 0);
            for model in &m.models {
                assert_eq!(model.total_params(), model.param_count, "{}", model.name);
                let spans = model.layer_spans();
                assert_eq!(
                    spans.iter().map(|(_, l)| l).sum::<usize>(),
                    model.param_count
                );
            }
        }
    }
}
