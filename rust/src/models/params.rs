//! Flat parameter vector with per-tensor views + SGD/FedProx updates.
//!
//! The rust side owns model state as ONE contiguous `Vec<f32>` in
//! manifest order — sparsification, masking, codecs and aggregation all
//! operate on this flat layout; the runtime slices it into per-tensor
//! literals when invoking the PJRT executables.

use crate::util::rng::Rng;

use super::manifest::{InitKind, ModelMeta};

/// Flat model parameters + the tensor boundary table.
#[derive(Clone, Debug, Default)]
pub struct ParamVector {
    pub data: Vec<f32>,
    /// (offset, numel) per tensor, manifest order.
    pub tensors: Vec<(usize, usize)>,
}

impl ParamVector {
    /// Initialize per the manifest init specs, seeded (same seed ⇒ same
    /// global model for every run — the experiment reproducibility
    /// anchor).
    pub fn init(meta: &ModelMeta, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x9a9a_0001);
        let total = meta.total_params();
        let mut data = Vec::with_capacity(total);
        let mut tensors = Vec::with_capacity(meta.params.len());
        for p in &meta.params {
            let off = data.len();
            match p.init {
                InitKind::Normal { std } => {
                    data.extend((0..p.numel()).map(|_| rng.normal_f32(std)));
                }
                InitKind::Zeros => data.extend(std::iter::repeat(0f32).take(p.numel())),
                InitKind::Ones => data.extend(std::iter::repeat(1f32).take(p.numel())),
            }
            tensors.push((off, p.numel()));
        }
        Self { data, tensors }
    }

    pub fn zeros_like(&self) -> Vec<f32> {
        vec![0f32; self.data.len()]
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Slice of tensor `i`.
    pub fn tensor(&self, i: usize) -> &[f32] {
        let (off, len) = self.tensors[i];
        &self.data[off..off + len]
    }

    /// SGD step: `w ← w − lr·g` over the flat layout.
    pub fn sgd_step(&mut self, grads: &[f32], lr: f32) {
        assert_eq!(grads.len(), self.data.len(), "grad size mismatch");
        for (w, g) in self.data.iter_mut().zip(grads) {
            *w -= lr * g;
        }
    }

    /// FedProx gradient correction: `g ← g + μ(w − w_global)` (Li et
    /// al. 2020's proximal term, additive in the gradient).
    pub fn add_prox_term(&self, grads: &mut [f32], global: &ParamVector, mu: f32) {
        assert_eq!(grads.len(), self.data.len(), "grad size mismatch");
        assert_eq!(global.len(), self.data.len(), "global size mismatch");
        for i in 0..grads.len() {
            grads[i] += mu * (self.data[i] - global.data[i]);
        }
    }

    /// Become a copy of `other`, reusing this vector's allocations
    /// (the per-worker local-model buffer resets from the global
    /// snapshot this way every round — no model-sized clone).
    pub fn copy_from(&mut self, other: &ParamVector) {
        self.data.clear();
        self.data.extend_from_slice(&other.data);
        self.tensors.clear();
        self.tensors.extend_from_slice(&other.tensors);
    }

    /// `self − other` (the round update Δw a client uploads).
    pub fn delta_from(&self, other: &ParamVector) -> Vec<f32> {
        let mut out = Vec::new();
        self.delta_into(other, &mut out);
        out
    }

    /// [`Self::delta_from`] into a caller-owned buffer.
    pub fn delta_into(&self, other: &ParamVector, out: &mut Vec<f32>) {
        assert_eq!(self.len(), other.len(), "size mismatch");
        out.clear();
        out.extend(self.data.iter().zip(&other.data).map(|(a, b)| a - b));
    }

    /// Apply an aggregated update: `w ← w + scale·u`.
    pub fn apply_update(&mut self, update: &[f32], scale: f32) {
        assert_eq!(update.len(), self.data.len(), "update size mismatch");
        for (w, u) in self.data.iter_mut().zip(update) {
            *w += scale * u;
        }
    }

    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64).powi(2)).sum::<f64>().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::{LayerGroup, ParamSpec};

    fn meta() -> ModelMeta {
        ModelMeta {
            name: "toy".into(),
            input: vec![4],
            classes: 2,
            params: vec![
                ParamSpec {
                    name: "w".into(),
                    shape: vec![4, 3],
                    init: InitKind::Normal { std: 0.5 },
                    layer: 0,
                },
                ParamSpec {
                    name: "b".into(),
                    shape: vec![3],
                    init: InitKind::Zeros,
                    layer: 0,
                },
                ParamSpec {
                    name: "g".into(),
                    shape: vec![3],
                    init: InitKind::Ones,
                    layer: 1,
                },
            ],
            layers: vec![
                LayerGroup { name: "l0".into(), params: vec![0, 1] },
                LayerGroup { name: "l1".into(), params: vec![2] },
            ],
            param_count: 18,
            grad_artifact: String::new(),
            eval_artifact: String::new(),
        }
    }

    #[test]
    fn init_respects_kinds_and_layout() {
        let pv = ParamVector::init(&meta(), 1);
        assert_eq!(pv.len(), 18);
        assert_eq!(pv.tensors, vec![(0, 12), (12, 3), (15, 3)]);
        assert!(pv.tensor(0).iter().any(|&x| x != 0.0));
        assert!(pv.tensor(1).iter().all(|&x| x == 0.0));
        assert!(pv.tensor(2).iter().all(|&x| x == 1.0));
    }

    #[test]
    fn init_deterministic_per_seed() {
        let a = ParamVector::init(&meta(), 7);
        let b = ParamVector::init(&meta(), 7);
        let c = ParamVector::init(&meta(), 8);
        assert_eq!(a.data, b.data);
        assert_ne!(a.data, c.data);
    }

    #[test]
    fn sgd_and_delta_roundtrip() {
        let global = ParamVector::init(&meta(), 2);
        let mut local = global.clone();
        let grads = vec![0.1f32; 18];
        local.sgd_step(&grads, 0.5);
        let delta = local.delta_from(&global);
        assert!(delta.iter().all(|&d| (d + 0.05).abs() < 1e-6));
        // applying the delta back to global reproduces local
        let mut restored = global.clone();
        restored.apply_update(&delta, 1.0);
        for (a, b) in restored.data.iter().zip(&local.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn prox_term_pulls_toward_global() {
        let global = ParamVector::init(&meta(), 3);
        let mut local = global.clone();
        local.data[0] += 1.0; // drift
        let mut grads = vec![0f32; 18];
        local.add_prox_term(&mut grads, &global, 0.1);
        assert!((grads[0] - 0.1).abs() < 1e-6);
        assert!(grads[1..].iter().all(|&g| g.abs() < 1e-9));
    }

    #[test]
    fn l2_norm_sane() {
        let mut pv = ParamVector::init(&meta(), 4);
        pv.data.iter_mut().for_each(|x| *x = 0.0);
        pv.data[0] = 3.0;
        pv.data[1] = 4.0;
        assert!((pv.l2_norm() - 5.0).abs() < 1e-9);
    }
}
