//! Model manifest + parameter management.
//!
//! The python AOT exporter writes `artifacts/manifest.json` describing
//! every model's parameter tensors, layer grouping (THGS), init spec
//! and artifact filenames. [`manifest`] parses it; [`params`] owns the
//! flat parameter vector and its per-tensor/per-layer views.

pub mod manifest;
pub mod params;

pub use manifest::{InitKind, LayerGroup, Manifest, ModelMeta, ParamSpec};
pub use params::ParamVector;
