//! `fedsparse` CLI — the leader entrypoint.
//!
//! Subcommands:
//!   train    run a federated training job (the paper's §5 loop)
//!   info     print manifest / model zoo information
//!   secdemo  one secure-aggregation round with case census (§4)
//!
//! Examples:
//!   fedsparse train --model mnist_mlp --alg thgs:0.1,0.8,0.01 \
//!       --partition noniid-4 --rounds 200 --out results/run.csv
//!   fedsparse train --alg fedavg --secure --rounds 50
//!   fedsparse train --alg thgs --secure --dropout 0.1 --min-survivors 4 \
//!       --straggler-timeout 2.0   # failure injection + Shamir recovery
//!   fedsparse info

use std::path::PathBuf;
use std::process::ExitCode;

use fedsparse::config::{Partition, RunConfig, TransportKind};
use fedsparse::coordinator::{Algorithm, Trainer};
use fedsparse::io::manifest::{build_manifest, sha256_hex, write_manifest};
use fedsparse::models::manifest::Manifest;
use fedsparse::runtime::BackendKind;
use fedsparse::util::cli::{usage, ArgSpec, Args, CliError};
use fedsparse::util::json::{num, Value};
use fedsparse::util::timer::{fmt_bytes, Stopwatch};

const TRAIN_SPEC: &[ArgSpec] = &[
    ArgSpec::opt("model", "m", "mnist_mlp", "model from the zoo (see `fedsparse info`)"),
    ArgSpec::opt("dataset", "d", "", "mnist|fmnist|cifar10 (default: inferred from model)"),
    ArgSpec::opt("alg", "a", "thgs", "fedavg | fedprox[:mu] | flat[:s] | stc[:s] | thgs[:s0,alpha,s_min]"),
    ArgSpec::opt("partition", "p", "iid", "iid | noniid-N"),
    ArgSpec::opt("rounds", "r", "100", "federated rounds"),
    ArgSpec::opt("clients", "", "100", "total clients"),
    ArgSpec::opt("per-round", "k", "10", "clients selected per round"),
    ArgSpec::opt("local-iters", "e", "5", "local SGD iterations per round"),
    ArgSpec::opt("lr", "", "0.1", "local learning rate"),
    ArgSpec::opt("eval-every", "", "5", "evaluate every N rounds"),
    ArgSpec::opt("eval-samples", "", "2500", "test samples per evaluation"),
    ArgSpec::opt("train-samples", "", "0", "cap synthetic train split (0 = full size)"),
    ArgSpec::opt("seed", "", "42", "run seed"),
    ArgSpec::opt("mask-ratio", "", "1.0", "secure mode: Eq.4 mask keep-ratio k"),
    ArgSpec::opt("neighbors-k", "", "0", "secure mode: pair-mask neighborhood degree (0 = every pair)"),
    ArgSpec::opt("shards", "", "1", "server aggregation shards (any count is bitwise-equal)"),
    ArgSpec::opt("rate-alpha", "", "0.8", "Eq.2 attenuation factor (with --dynamic-rate)"),
    ArgSpec::opt("rate-min", "", "0.01", "Eq.2 rate floor"),
    ArgSpec::opt("quant-bits", "", "0", "QSGD stochastic quantization bits (0 = off)"),
    ArgSpec::opt("momentum", "", "0.0", "DGC momentum correction coefficient"),
    ArgSpec::opt("warmup", "", "0", "DGC warm-up rounds (sparsity relaxed dense→target)"),
    ArgSpec::opt("dropout", "", "0.0", "per-round client crash probability (failure injection)"),
    ArgSpec::opt("straggler-timeout", "", "0", "collect deadline in simulated seconds (0 = none)"),
    ArgSpec::opt("min-survivors", "", "1", "abort the round below this many delivered uploads"),
    ArgSpec::opt("transport", "", "inproc", "uplink: inproc | tcp | uds (framed sockets)"),
    ArgSpec::opt("chaos-loss", "", "0.0", "chaos: per-attempt packet-loss probability"),
    ArgSpec::opt("chaos-dup", "", "0.0", "chaos: frame duplication probability"),
    ArgSpec::opt("chaos-reorder", "", "0.0", "chaos: out-of-order arrival probability"),
    ArgSpec::opt("chaos-slow", "", "0.0", "chaos: slow-link probability (4x delivery time)"),
    ArgSpec::opt("backend", "b", "auto", "auto | native | pjrt (AOT artifacts)"),
    ArgSpec::opt("workers", "w", "4", "PJRT executor threads"),
    ArgSpec::opt("artifacts", "", "artifacts", "AOT artifacts directory"),
    ArgSpec::opt("data-dir", "", "data", "real-dataset directory (falls back to synthetic)"),
    ArgSpec::opt("out", "o", "", "CSV output path (append mode)"),
    ArgSpec::opt("checkpoint-dir", "", "", "directory for durable end-of-round checkpoints"),
    ArgSpec::opt("checkpoint-every", "", "1", "commit a checkpoint every N applied rounds"),
    ArgSpec::opt("manifest", "", "", "run-manifest output path (default: <out>.manifest.json)"),
    ArgSpec::flag("resume", "", "resume from the newest valid checkpoint in --checkpoint-dir"),
    ArgSpec::flag("secure", "s", "mask-sparsified secure aggregation (§3.2)"),
    ArgSpec::flag("dynamic-rate", "", "Eq.2 loss-driven sparsity rate"),
    ArgSpec::flag("quiet", "q", "suppress per-round lines"),
];

fn main() -> ExitCode {
    let mut argv = std::env::args().skip(1);
    let sub = argv.next().unwrap_or_else(|| "help".to_string());
    let result = match sub.as_str() {
        "train" => cmd_train(argv),
        "info" => cmd_info(argv),
        "secdemo" => cmd_secdemo(argv),
        "help" | "--help" | "-h" => {
            eprintln!("fedsparse — efficient and secure federated learning\n");
            eprintln!("subcommands: train | info | secdemo\n");
            eprintln!("{}", usage("fedsparse train", TRAIN_SPEC));
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?} (try `fedsparse help`)");
            return ExitCode::FAILURE;
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            if !matches!(e.downcast_ref::<CliError>(), Some(CliError::Help)) {
                eprintln!("error: {e:#}");
                return ExitCode::FAILURE;
            }
            ExitCode::SUCCESS
        }
    }
}

fn build_config(args: &Args) -> anyhow::Result<RunConfig> {
    let mut cfg = RunConfig::default();
    cfg.model = args.get("model").unwrap_or("mnist_mlp").to_string();
    let ds = args.get("dataset").unwrap_or("");
    cfg.dataset = if ds.is_empty() {
        if cfg.model.starts_with("cifar") {
            "cifar10".into()
        } else if cfg.model.starts_with("fmnist") {
            "fmnist".into()
        } else {
            "mnist".into()
        }
    } else {
        ds.to_string()
    };
    cfg.algorithm = Algorithm::parse(args.get("alg").unwrap_or("thgs"))
        .ok_or_else(|| anyhow::anyhow!("bad --alg (see --help)"))?;
    cfg.partition = Partition::parse(args.get("partition").unwrap_or("iid"))
        .ok_or_else(|| anyhow::anyhow!("bad --partition (iid | noniid-N)"))?;
    cfg.rounds = args.get_parsed("rounds")?;
    cfg.clients = args.get_parsed("clients")?;
    cfg.clients_per_round = args.get_parsed("per-round")?;
    cfg.local_iters = args.get_parsed("local-iters")?;
    cfg.lr = args.get_parsed("lr")?;
    cfg.eval_every = args.get_parsed("eval-every")?;
    cfg.eval_samples = args.get_parsed("eval-samples")?;
    let ts: usize = args.get_parsed("train-samples")?;
    cfg.train_samples = (ts > 0).then_some(ts);
    cfg.seed = args.get_parsed("seed")?;
    cfg.mask_ratio_k = args.get_parsed("mask-ratio")?;
    cfg.neighbors_k = args.get_parsed("neighbors-k")?;
    cfg.shards = args.get_parsed("shards")?;
    cfg.rate_alpha = args.get_parsed("rate-alpha")?;
    cfg.rate_min = args.get_parsed("rate-min")?;
    cfg.backend = BackendKind::parse(args.get("backend").unwrap_or("auto"))
        .ok_or_else(|| anyhow::anyhow!("bad --backend (auto | native | pjrt)"))?;
    cfg.exec_workers = args.get_parsed("workers")?;
    cfg.client_workers = cfg.exec_workers;
    cfg.artifacts_dir = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    cfg.data_dir = Some(PathBuf::from(args.get("data-dir").unwrap_or("data")));
    cfg.secure = args.get_flag("secure");
    cfg.dynamic_rate = args.get_flag("dynamic-rate");
    let qb: u8 = args.get_parsed("quant-bits")?;
    cfg.quant_bits = (qb > 0).then_some(qb);
    cfg.momentum = args.get_parsed("momentum")?;
    cfg.warmup_rounds = args.get_parsed("warmup")?;
    cfg.dropout_prob = args.get_parsed("dropout")?;
    let st: f64 = args.get_parsed("straggler-timeout")?;
    cfg.straggler_timeout_s = if st > 0.0 { st } else { f64::INFINITY };
    cfg.min_survivors = args.get_parsed("min-survivors")?;
    cfg.transport = TransportKind::parse(args.get("transport").unwrap_or("inproc"))
        .ok_or_else(|| anyhow::anyhow!("bad --transport (inproc | tcp | uds)"))?;
    cfg.chaos_loss = args.get_parsed("chaos-loss")?;
    cfg.chaos_dup = args.get_parsed("chaos-dup")?;
    cfg.chaos_reorder = args.get_parsed("chaos-reorder")?;
    cfg.chaos_slow = args.get_parsed("chaos-slow")?;
    let ckdir = args.get("checkpoint-dir").unwrap_or("");
    cfg.checkpoint_dir = (!ckdir.is_empty()).then(|| PathBuf::from(ckdir));
    cfg.checkpoint_every = args.get_parsed("checkpoint-every")?;
    cfg.resume = args.get_flag("resume");
    Ok(cfg)
}

fn cmd_train(argv: impl Iterator<Item = String>) -> anyhow::Result<()> {
    let args = Args::parse_spec("fedsparse train", TRAIN_SPEC, argv)?;
    let cfg = build_config(&args)?;
    let quiet = args.get_flag("quiet");
    let out = args.get("out").unwrap_or("").to_string();

    println!(
        "fedsparse train: {} on {} | {} | {} clients ({}/round, E={}) | {} rounds{}{}",
        cfg.model,
        cfg.dataset,
        cfg.algorithm.label(),
        cfg.clients,
        cfg.clients_per_round,
        cfg.local_iters,
        cfg.rounds,
        if cfg.secure { " | SECURE" } else { "" },
        if cfg.transport != TransportKind::InProc {
            format!(" | wire {}", cfg.transport.label())
        } else {
            String::new()
        },
    );
    let sw = Stopwatch::start();
    let mut trainer = Trainer::new(cfg)?;
    println!(
        "model: {} params | backend: {} | data: {}{}",
        trainer.model_params(),
        trainer.backend_name(),
        trainer.cfg.dataset,
        if trainer_is_synth(&trainer) { " (synthetic)" } else { " (real)" },
    );

    let start_round = trainer.start_round();
    if trainer.cfg.resume && start_round > 0 {
        println!(
            "resumed from checkpoint: continuing at round {start_round} of {}",
            trainer.cfg.rounds
        );
    }

    if !out.is_empty() {
        // stream rows as rounds complete (append + flush per row): a
        // crashed or killed run leaves a parseable CSV prefix behind
        // instead of nothing
        if trainer.cfg.resume {
            // reconcile the killed run's CSV with the restored rows
            // (truncate torn/rolled-back tail, keep the header) so the
            // resumed file matches the uninterrupted twin's
            trainer.recorder.resume_stream_to(PathBuf::from(&out))?;
        } else {
            trainer.recorder.stream_to(PathBuf::from(&out))?;
        }
    }

    for round in start_round..trainer.cfg.rounds {
        let out = trainer.run_round(round)?;
        if quiet {
            continue;
        }
        if out.aborted {
            println!(
                "round {:>4}  ABORTED: {} of {} uploads arrived (< {} required; {} crashed, {} straggled)",
                round,
                out.survivors.len(),
                out.selected.len(),
                trainer.cfg.min_survivors,
                out.dropped.len(),
                out.stragglers.len(),
            );
            continue;
        }
        let dead = out.dropped.len() + out.stragglers.len();
        let failures = if dead > 0 {
            format!("  [{} dead, {} masks recovered]", dead, out.recovered_pairs)
        } else {
            String::new()
        };
        match out.eval {
            Some((el, ea)) => println!(
                "round {:>4}  loss {:.4}  eval_loss {:.4}  acc {:.4}  up {}{}",
                round,
                out.mean_train_loss,
                el,
                ea,
                fmt_bytes(trainer.ledger.rounds.last().unwrap().up_paper),
                failures,
            ),
            None => println!(
                "round {:>4}  loss {:.4}  nnz/client ~{}{}",
                round,
                out.mean_train_loss,
                out.nnz.iter().sum::<usize>() / out.nnz.len().max(1),
                failures,
            ),
        }
    }

    let summary = trainer.recorder.summary();
    println!(
        "\ndone in {:.1}s: final acc {:.4} (best {:.4}) | upload {} (paper model) / {} (wire)",
        sw.elapsed_secs(),
        summary.final_accuracy,
        summary.best_accuracy,
        fmt_bytes(summary.total_up_bytes),
        fmt_bytes(summary.total_wire_bytes),
    );
    // grep-able determinism anchor: a resumed run and its
    // uninterrupted twin print identical hashes (CI's crash-resume
    // soak compares exactly this line)
    let mut param_bytes = Vec::with_capacity(trainer.global.data.len() * 4);
    for v in &trainer.global.data {
        param_bytes.extend_from_slice(&v.to_le_bytes());
    }
    let params_hash = sha256_hex(&param_bytes);
    println!("final_params_sha256: {params_hash}");
    if !out.is_empty() {
        println!("rows streamed to {out}");
    }

    // self-describing run manifest (--manifest, or <out>.manifest.json
    // next to the CSV)
    let mpath = match args.get("manifest").unwrap_or("") {
        "" if out.is_empty() => None,
        "" => Some(PathBuf::from(format!("{out}.manifest.json"))),
        explicit => Some(PathBuf::from(explicit)),
    };
    if let Some(mpath) = mpath {
        let created = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let label = trainer.cfg.run_label();
        let run_id = format!("{label}-seed{}-{created}", trainer.cfg.seed);
        let config_map: std::collections::BTreeMap<String, Value> =
            fedsparse::config::file::to_map(&trainer.cfg)
                .into_iter()
                .map(|(k, v)| (k, Value::Str(v)))
                .collect();
        let mut meta: Vec<(String, Value)> = vec![
            ("config".into(), Value::Object(config_map)),
            ("created_unix".into(), num(created as f64)),
            ("resumed_at_round".into(), num(start_round as f64)),
            ("final_params_sha256".into(), Value::Str(params_hash)),
            ("rounds_recorded".into(), num(trainer.recorder.rows.len() as f64)),
            ("total_wire_bytes".into(), num(trainer.ledger.total_up_wire() as f64)),
            ("total_framed_bytes".into(), num(trainer.ledger.total_up_framed() as f64)),
        ];
        if summary.final_accuracy.is_finite() {
            meta.push(("final_accuracy".into(), num(summary.final_accuracy)));
        }
        let mut artifacts: Vec<(PathBuf, String)> = Vec::new();
        if !out.is_empty() {
            let out_path = PathBuf::from(&out);
            // record the CSV relative to the manifest when they share a
            // directory (the relocatable common case), else absolute
            let recorded = if out_path.parent() == mpath.parent() {
                out_path.file_name().unwrap_or_default().to_string_lossy().into_owned()
            } else {
                std::fs::canonicalize(&out_path)
                    .unwrap_or_else(|_| out_path.clone())
                    .to_string_lossy()
                    .into_owned()
            };
            artifacts.push((out_path, recorded));
        }
        let built = build_manifest("train-run", &run_id, meta, &artifacts);
        for (p, why) in &built.invalid {
            eprintln!("warning: manifest skipped artifact {p}: {why}");
        }
        write_manifest(&mpath, &built.manifest)?;
        println!("run manifest: {}", mpath.display());
    }
    Ok(())
}

fn trainer_is_synth(t: &Trainer) -> bool {
    t.cfg.train_samples.is_some() || !t.cfg.data_dir.as_deref().map(|d| d.exists()).unwrap_or(false)
}

fn cmd_info(argv: impl Iterator<Item = String>) -> anyhow::Result<()> {
    const SPEC: &[ArgSpec] = &[ArgSpec::opt("artifacts", "", "artifacts", "artifacts dir")];
    let args = Args::parse_spec("fedsparse info", SPEC, argv)?;
    let dir = PathBuf::from(args.get("artifacts").unwrap());
    let exported = dir.join("manifest.json").exists();
    let m = Manifest::load_or_builtin(&dir)?;
    println!(
        "artifacts: {} | train batch {} | eval batch {}",
        if exported { format!("{}", dir.display()) } else { "(builtin manifest — no export yet)".into() },
        m.train_batch,
        m.eval_batch
    );
    println!("\n{:<14} {:>12} {:>8}  artifacts", "model", "params", "layers");
    for model in &m.models {
        println!(
            "{:<14} {:>12} {:>8}  {} / {}",
            model.name,
            model.param_count,
            model.layers.len(),
            model.grad_artifact,
            model.eval_artifact
        );
    }
    println!("\nkernels: sparsify {:?} | masked_agg {:?} | block {}",
        m.sparsify_kernels.iter().map(|(n, _)| *n).collect::<Vec<_>>(),
        m.masked_agg_kernels.iter().map(|(n, _)| *n).collect::<Vec<_>>(),
        m.kernel_block);
    Ok(())
}

fn cmd_secdemo(argv: impl Iterator<Item = String>) -> anyhow::Result<()> {
    const SPEC: &[ArgSpec] = &[
        ArgSpec::opt("participants", "x", "4", "number of participants"),
        ArgSpec::opt("size", "n", "10000", "update vector length"),
        ArgSpec::opt("grad-rate", "", "0.01", "gradient top-k rate"),
        ArgSpec::opt("mask-ratio", "k", "1.0", "Eq.4 mask keep-ratio k"),
    ];
    let args = Args::parse_spec("fedsparse secdemo", SPEC, argv)?;
    let x: usize = args.get_parsed("participants")?;
    let n: usize = args.get_parsed("size")?;
    let rate: f64 = args.get_parsed("grad-rate")?;
    let k: f64 = args.get_parsed("mask-ratio")?;

    use fedsparse::secagg::protocol::{full_setup, SecAggConfig};
    use fedsparse::sparse::topk::threshold_for_topk_abs;
    use fedsparse::util::rng::Rng;

    let cfg = SecAggConfig { mask_ratio_k: k, share_keys: false, ..Default::default() };
    let (clients, server) = full_setup(x as u32, 7, &cfg);
    let mut rng = Rng::new(1);
    let mut payloads = Vec::new();
    let mut expect = vec![0f64; n];
    println!("secure aggregation demo: {x} participants, n={n}, grad rate {rate}, mask k={k}\n");
    for c in &clients {
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.1)).collect();
        let kk = ((n as f64 * rate).ceil() as usize).max(1);
        let d = threshold_for_topk_abs(&g, kk);
        let keep: Vec<bool> = g.iter().map(|v| v.abs() > d).collect();
        let upd = c.build_update(&g, &keep, 0, x);
        let census = upd.census;
        println!(
            "client {}: sent {:>6} of {n} ({:.2}%) | case1 grad-only {} | case2 mask-only {} | case3 both {} | exposure {:.1}%",
            c.id,
            census.transmitted(),
            100.0 * census.transmitted() as f64 / n as f64,
            census.case1_grad_only,
            census.case2_mask_only,
            census.case3_both,
            100.0 * census.exposure_rate(),
        );
        for j in 0..n {
            expect[j] += (g[j] - upd.residual[j]) as f64;
        }
        payloads.push((c.id, upd.payload));
    }
    let agg = server.aggregate(n, 0, &payloads, &[], &Default::default());
    let max_err = (0..n).map(|j| (agg[j] as f64 - expect[j]).abs()).fold(0.0, f64::max);
    println!("\nserver aggregate: max |error| vs unmasked sum = {max_err:.2e} (masks cancelled)");
    let dense = fedsparse::sparse::codec::dense_cost_bytes(n) * x as u64;
    let sparse: u64 = payloads.iter().map(|(_, p)| p.paper_cost_bytes()).sum();
    println!(
        "upload: dense {} vs masked-sparse {} → {:.1}% of dense",
        fmt_bytes(dense),
        fmt_bytes(sparse),
        100.0 * sparse as f64 / dense as f64
    );
    Ok(())
}
