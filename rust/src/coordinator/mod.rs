//! Layer-3 coordinator — the federated round loop tying every
//! substrate together (DESIGN.md S1-S2).
//!
//! * [`algorithms`] — FedAvg / FedProx baselines, conventional flat
//!   Top-k, and the paper's THGS
//! * [`client`] — per-client persistent state (residuals, Eq. 2 rate
//!   controller, loss history) with the take/commit/restore protocol
//!   the round engine drives
//! * [`selection`] — seeded per-round client sampling (C·K of N)
//! * [`round`] — the phased round engine: `Select → LocalTrain →
//!   Sparsify/Encode → Collect → Unmask/Recover → Apply → Eval`, with
//!   the per-client path owned by [`round::ClientPipeline`]
//! * [`shard`] — the range-sharded aggregate accumulator Collect
//!   streams uplinks into (bitwise-exact at any shard count)
//! * [`trainer`] — construction and run-level state: backend, data
//!   partition, secure-aggregation setup, transport, metrics

pub mod algorithms;
pub mod client;
pub mod round;
pub mod selection;
pub mod shard;
pub mod trainer;

pub use algorithms::Algorithm;
pub use client::{ClientSnapshot, ClientState, RoundState};
pub use round::{
    ClientPipeline, ClientWorkspace, Cohort, RoundOutcome, ServerWorkspace, WorkspacePool,
};
pub use shard::ShardedAccumulator;
pub use trainer::Trainer;
