//! Layer-3 coordinator — the federated round loop tying every
//! substrate together (DESIGN.md S1-S2).
//!
//! * [`algorithms`] — FedAvg / FedProx baselines, conventional flat
//!   Top-k, and the paper's THGS
//! * [`client`] — per-client persistent state (residuals, Eq. 2 rate
//!   controller, local loss history)
//! * [`selection`] — seeded per-round client sampling (C·K of N)
//! * [`trainer`] — the orchestrator: local training via the PJRT
//!   runtime, sparsification, (secure) aggregation, eval, metrics

pub mod algorithms;
pub mod client;
pub mod selection;
pub mod trainer;

pub use algorithms::Algorithm;
pub use client::ClientState;
pub use trainer::{RoundOutcome, Trainer};
