//! The federated training orchestrator.
//!
//! One [`Trainer`] owns the global model, the client fleet, the
//! compute backend (native or PJRT, see [`crate::runtime`]), the
//! uplink transport (in-process twin, TCP, or UDS — `--transport`) and
//! (optionally) the secure-aggregation state. Rounds run through the
//! phased engine in
//! [`super::round`]:
//!
//! ```text
//! Select → LocalTrain → Sparsify/Encode → Collect → Unmask/Recover → Apply → Eval
//! ```
//!
//! * **Select** — C·K clients, seeded ([`super::selection`])
//! * **LocalTrain** — parallel local SGD (E iterations, batch B) via
//!   the backend's grad
//! * **Sparsify/Encode** — residual fold-in + Eq. 2 rate +
//!   FedAvg/FedProx/flat/THGS sparsifier, then [secure] pairwise
//!   mask-sparsified encoding (Alg. 2) + wire codec
//! * **Collect** — the transport carries the encoded uplinks; a seeded
//!   [`FailurePlan`](crate::comm::transport::FailurePlan) injects
//!   client crashes (`dropout_prob`) and past-deadline stragglers
//!   (`straggler_timeout_s`), and a seeded
//!   [`ChaosPlan`](crate::comm::chaos::ChaosPlan) injects packet loss,
//!   duplication, reordering, and slow links; survivors only from
//!   here on
//! * **Unmask/Recover** — server sum over survivors; in secure mode,
//!   Shamir-reconstruct dead clients' pair keys and cancel their
//!   orphaned masks (aborting below `min_survivors` / quorum)
//! * **Apply** — global ← global + Σ/|survivors|
//! * **Eval** — test split + cost ledger + metrics
//!
//! This module owns construction and run-level state; the per-round
//! data flow lives in [`super::round`].

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::comm::channel::NetworkModel;
use crate::comm::chaos::ChaosPlan;
use crate::comm::cost::CostLedger;
use crate::comm::socket::{SocketOptions, SocketTransport};
use crate::comm::transport::{FailurePlan, Transport, Uplink, DEFAULT_STRAGGLER_SCALE};
use crate::config::{Partition, RunConfig, TransportKind};
use crate::data::{iid_partition, noniid_partition, Dataset, DatasetKind, Split};
use crate::io::checkpoint::{config_digest, Checkpoint, CheckpointStore, ClientCheckpoint};
use crate::metrics::recorder::{Recorder, RunSummary};
use crate::models::manifest::Manifest;
use crate::models::params::ParamVector;
use crate::runtime::ModelRunner;
use crate::secagg::protocol::{full_setup, SecAggClient, SecAggConfig, SecAggServer};
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;

use super::algorithms::Algorithm;
use super::client::ClientState;

/// The coordinator.
pub struct Trainer {
    pub cfg: RunConfig,
    pub manifest: Manifest,
    pub(crate) runner: ModelRunner,
    pub(crate) train_data: Arc<Dataset>,
    pub(crate) test_data: Dataset,
    /// The global model. Arc'd so the per-round snapshot handed to the
    /// client pipeline is a refcount bump, not a model-sized copy;
    /// Apply mutates through [`Arc::make_mut`] (copy-on-write — by
    /// Apply time the round's pipeline clones are dropped, so the
    /// steady-state update is in-place).
    pub global: Arc<ParamVector>,
    pub clients: Vec<ClientState>,
    pub(crate) secagg: Option<Arc<(Vec<SecAggClient>, SecAggServer)>>,
    pub(crate) layer_spans: Vec<(usize, usize)>,
    /// Client-job worker pool. Arc'd so the pipeline's client jobs can
    /// fan pair-mask generation back out over the same pool
    /// (`ThreadPool::map_shared` is nesting-safe).
    pub(crate) client_pool: Arc<ThreadPool>,
    pub recorder: Recorder,
    pub ledger: CostLedger,
    /// The uplink carrying the Collect barrier: the in-process twin
    /// (default) or a real TCP/UDS socket, per `cfg.transport`. All
    /// implementations share the network model, failure plan, and
    /// chaos plan semantics (conformance-pinned).
    pub transport: Box<dyn Uplink>,
    pub(crate) base_rate: f64,
    pub(crate) mask_cache: crate::secagg::mask::MaskCache,
    /// Per-worker client scratch, reused across rounds (the warm
    /// buffers are what make the steady-state per-client path
    /// allocation-free; see [`super::round::WorkspacePool`]).
    pub(crate) client_workspaces: Arc<super::round::WorkspacePool>,
    /// Coordinator-side scratch, reused across rounds — the server
    /// twin of the client workspaces (see
    /// [`super::round::ServerWorkspace`]).
    pub(crate) server_ws: super::round::ServerWorkspace,
    /// Per-round Shamir re-keying registry
    /// ([`crate::secagg::rekey`]) — present for k-regular secure runs
    /// with failure injection; `neighbors_k = 0` runs keep the one-off
    /// all-pairs setup and leave this `None`.
    pub(crate) rekey: Option<crate::secagg::rekey::RekeyRegistry>,
    /// End-of-round durable snapshot store (`--checkpoint-dir`);
    /// `None` when checkpointing is off or was disabled after a save
    /// failure (recorder-sink precedent: warn once, keep training).
    pub(crate) ckpt: Option<CheckpointStore>,
    /// First round [`Self::run`] executes: 0 for fresh runs, the
    /// restored checkpoint's `next_round` under `--resume`.
    start_round: u64,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!("config: {e}"))?;
        // a missing manifest.json is not an error: the builtin
        // manifest + native backend cover the no-Python default path
        let manifest = Manifest::load_or_builtin(&cfg.artifacts_dir)
            .with_context(|| format!("load manifest from {:?}", cfg.artifacts_dir))?;
        let runner = ModelRunner::for_config(&manifest, &cfg)?;
        let meta = runner.meta.clone();

        let kind = DatasetKind::from_name(&cfg.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {:?}", cfg.dataset))?;
        let train_data = match cfg.train_samples {
            Some(n) => Dataset::synthetic_small(kind, Split::Train, n, cfg.seed),
            None => Dataset::load(kind, Split::Train, cfg.data_dir.as_deref(), cfg.seed),
        };
        let test_data = match cfg.train_samples {
            Some(n) => Dataset::synthetic_small(kind, Split::Test, (n / 4).max(manifest.eval_batch), cfg.seed),
            None => Dataset::load(kind, Split::Test, cfg.data_dir.as_deref(), cfg.seed),
        };

        // partition
        let mut rng = Rng::new(cfg.seed ^ 0xda7a);
        let parts = match cfg.partition {
            Partition::Iid => iid_partition(train_data.len(), cfg.clients, &mut rng),
            Partition::NonIid(n) => {
                noniid_partition(&train_data.labels(), cfg.clients, n, &mut rng)
            }
        };

        let m = meta.total_params();
        let clients: Vec<ClientState> = parts
            .into_iter()
            .enumerate()
            .map(|(i, data)| {
                let mut c = ClientState::new(i as u32, data, m);
                if cfg.dynamic_rate {
                    let r0 = base_rate_of(&cfg.algorithm);
                    c = c.with_dynamic_rate(r0, cfg.rate_alpha, cfg.rounds, cfg.rate_min);
                }
                if cfg.momentum > 0.0 {
                    c.enable_momentum(m, cfg.momentum);
                }
                c
            })
            .collect();

        let mask_cache: crate::secagg::mask::MaskCache = Default::default();
        let secagg = if cfg.secure {
            let sc = SecAggConfig {
                full_dh: false,
                mask_ratio_k: cfg.mask_ratio_k,
                // Shamir share material is only needed when clients can
                // vanish mid-round (dropout/straggler injection) — the
                // paper's §5 experiments assume full delivery. Even
                // then, the one-off O(n³) all-pairs distribution is
                // only for complete-graph (neighbors_k = 0) runs;
                // k-regular runs re-share per round through the rekey
                // registry instead.
                share_keys: cfg.failure_injection() && cfg.neighbors_k == 0,
                ..Default::default()
            };
            let (mut sec_clients, server) = full_setup(cfg.clients as u32, cfg.seed ^ 0x5eca, &sc);
            // shared per-round stream cache: each pair stream is used by
            // both endpoints within a round (§Perf L3 iteration 4)
            for c in sec_clients.iter_mut() {
                c.attach_cache(Arc::clone(&mask_cache));
            }
            Some(Arc::new((sec_clients, server)))
        } else {
            None
        };
        // k-regular secure runs with dropout re-share Shamir material
        // per round against the round's neighborhoods (Select phase)
        // instead of the one-off all-pairs walk above
        let rekey = match (&secagg, cfg.neighbors_k > 0 && cfg.failure_injection()) {
            (Some(sec), true) => {
                Some(crate::secagg::rekey::RekeyRegistry::new(sec.1.share_threshold))
            }
            _ => None,
        };

        let network = NetworkModel::default();
        let plan = FailurePlan {
            dropout_prob: cfg.dropout_prob,
            straggler_timeout_s: cfg.straggler_timeout_s,
            straggler_scale: if cfg.straggler_timeout_s.is_finite() {
                DEFAULT_STRAGGLER_SCALE
            } else {
                0.0
            },
            seed: cfg.seed ^ 0xfa11,
        };
        // chaos draws from its own seed stream so turning it on never
        // shifts the crash/straggle fates
        let chaos = ChaosPlan {
            loss_prob: cfg.chaos_loss,
            dup_prob: cfg.chaos_dup,
            reorder_prob: cfg.chaos_reorder,
            slow_prob: cfg.chaos_slow,
            slow_factor: cfg.chaos_slow_factor,
            max_retries: cfg.chaos_retries,
            seed: cfg.seed ^ 0xc4a05,
        };
        let sock_opts = SocketOptions {
            accept_deadline: std::time::Duration::from_millis(cfg.socket_deadline_ms),
            ..SocketOptions::default()
        };
        let transport: Box<dyn Uplink> = match cfg.transport {
            TransportKind::InProc => Box::new(Transport::with_chaos(network, plan, chaos)),
            TransportKind::Tcp => Box::new(
                SocketTransport::tcp_with(network, plan, chaos, sock_opts)
                    .context("open tcp uplink")?,
            ),
            #[cfg(unix)]
            TransportKind::Uds => Box::new(
                SocketTransport::uds_with(network, plan, chaos, sock_opts)
                    .context("open uds uplink")?,
            ),
            #[cfg(not(unix))]
            TransportKind::Uds => return Err(anyhow!("uds transport requires unix")),
        };

        let layer_spans = meta.layer_spans();
        let label = cfg.run_label();
        let base_rate = base_rate_of(&cfg.algorithm);

        let mut t = Self {
            client_pool: Arc::new(ThreadPool::new(cfg.client_workers)),
            recorder: Recorder::new(&label),
            ledger: CostLedger::new(m),
            transport,
            global: Arc::new(ParamVector::init(&meta, cfg.seed)),
            train_data: Arc::new(train_data),
            test_data,
            clients,
            secagg,
            layer_spans,
            runner,
            manifest,
            cfg,
            base_rate,
            mask_cache,
            client_workspaces: Default::default(),
            server_ws: Default::default(),
            rekey,
            ckpt: None,
            start_round: 0,
        };
        if let Some(dir) = t.cfg.checkpoint_dir.clone() {
            let store = CheckpointStore::open(&dir)
                .with_context(|| format!("open checkpoint dir {dir:?}"))?;
            if t.cfg.resume {
                match store.load_latest() {
                    Some((ck, path)) => {
                        t.restore_checkpoint(ck).with_context(|| format!("resume from {path:?}"))?;
                    }
                    None => eprintln!(
                        "warning: --resume found no valid checkpoint under {dir:?} — \
                         starting fresh"
                    ),
                }
            }
            t.ckpt = Some(store);
        }
        Ok(t)
    }

    /// The round [`Self::run`] starts from (non-zero exactly when a
    /// `--resume` restored a checkpoint).
    pub fn start_round(&self) -> u64 {
        self.start_round
    }

    /// Snapshot all cross-round mutable state as of `next_round` (the
    /// first round the restored run will execute). Everything else a
    /// round reads — RNG streams, mask neighborhoods, failure fates —
    /// is a pure function of (seed, round, client id) and is
    /// reconstructed, not stored; see [`crate::io::checkpoint`].
    pub fn build_checkpoint(&self, next_round: u64) -> Checkpoint {
        Checkpoint {
            label: self.cfg.run_label(),
            seed: self.cfg.seed,
            config_digest: config_digest(&self.cfg),
            next_round,
            global_tensors: self.global.tensors.clone(),
            global_data: self.global.data.clone(),
            clients: self
                .clients
                .iter()
                .map(|c| ClientCheckpoint {
                    last_loss: c.last_loss,
                    participation: c.participation,
                    residual_buf: c.residual.as_slice().to_vec(),
                    residual_age: c.residual.ages().to_vec(),
                    rate: c.rate.as_ref().map(|r| (r.rate(), r.loss_prev())),
                    momentum_velocity: c.momentum.as_ref().map(|m| m.velocity().to_vec()),
                })
                .collect(),
            rows: self.recorder.rows.clone(),
            costs: self.ledger.rounds.clone(),
        }
    }

    /// Overwrite the trainer's mutable state from a loaded checkpoint,
    /// after validating it belongs to *this* run configuration. The
    /// paranoid checks are cheap and the failure messages actionable —
    /// a checkpoint from a different seed/config silently producing a
    /// diverging continuation is the worst possible outcome.
    fn restore_checkpoint(&mut self, ck: Checkpoint) -> Result<()> {
        let label = self.cfg.run_label();
        if ck.label != label {
            return Err(anyhow!("checkpoint is for run {:?}, this run is {:?}", ck.label, label));
        }
        if ck.seed != self.cfg.seed {
            return Err(anyhow!(
                "checkpoint seed {} does not match --seed {}",
                ck.seed,
                self.cfg.seed
            ));
        }
        let digest = config_digest(&self.cfg);
        if ck.config_digest != digest {
            return Err(anyhow!(
                "checkpoint config digest {} does not match this run's {digest} \
                 (same label+seed but some knob differs)",
                ck.config_digest
            ));
        }
        if ck.next_round > self.cfg.rounds {
            return Err(anyhow!(
                "checkpoint next_round {} is past --rounds {}",
                ck.next_round,
                self.cfg.rounds
            ));
        }
        let m = self.global.data.len();
        if ck.global_data.len() != m || ck.global_tensors != self.global.tensors {
            return Err(anyhow!(
                "checkpoint model shape ({} params) does not match this model ({m} params)",
                ck.global_data.len()
            ));
        }
        if ck.clients.len() != self.clients.len() {
            return Err(anyhow!(
                "checkpoint has {} clients, this run has {}",
                ck.clients.len(),
                self.clients.len()
            ));
        }
        for (i, cc) in ck.clients.iter().enumerate() {
            if cc.residual_buf.len() != m {
                return Err(anyhow!(
                    "client {i}: checkpointed residual has {} entries, model has {m}",
                    cc.residual_buf.len()
                ));
            }
            if cc.rate.is_some() != self.clients[i].rate.is_some() {
                return Err(anyhow!(
                    "client {i}: dynamic-rate state presence mismatch (--dynamic-rate differs?)"
                ));
            }
            if cc.momentum_velocity.is_some() != self.clients[i].momentum.is_some() {
                return Err(anyhow!(
                    "client {i}: momentum state presence mismatch (--momentum differs?)"
                ));
            }
        }
        self.global = Arc::new(ParamVector { data: ck.global_data, tensors: ck.global_tensors });
        for (c, cc) in self.clients.iter_mut().zip(ck.clients) {
            c.last_loss = cc.last_loss;
            c.participation = cc.participation;
            Arc::make_mut(&mut c.residual).restore(&cc.residual_buf, &cc.residual_age);
            if let (Some(ctrl), Some((rate, loss_prev))) = (c.rate.as_mut(), cc.rate) {
                ctrl.restore(rate, loss_prev);
            }
            if let (Some(mc), Some(v)) = (c.momentum.as_mut(), cc.momentum_velocity) {
                Arc::make_mut(mc).restore_velocity(&v);
            }
        }
        self.recorder.rows = ck.rows;
        self.ledger.rounds = ck.costs;
        self.start_round = ck.next_round;
        Ok(())
    }

    /// Drive the full run; returns the summary. Aborted rounds (too
    /// many failures) are recorded and skipped, not fatal. Under
    /// `--resume` the loop picks up at the restored round — the
    /// remaining rounds are bitwise-identical to the uninterrupted
    /// twin's because every RNG stream is derived from
    /// (seed, round, client id), never from a live generator.
    pub fn run(&mut self) -> Result<RunSummary> {
        for round in self.start_round..self.cfg.rounds {
            self.run_round(round)?;
        }
        Ok(self.recorder.summary())
    }

    /// Evaluate the current global model on the test split.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        self.runner
            .evaluate(&self.global, &self.test_data, self.cfg.eval_samples)
    }

    pub fn model_params(&self) -> usize {
        self.global.len()
    }

    /// Which compute backend the run resolved to (`"native"`/`"pjrt"`).
    pub fn backend_name(&self) -> &'static str {
        self.runner.backend_name()
    }
}

/// The configured base sparsity rate (for Eq. 2 scaling).
pub(crate) fn base_rate_of(alg: &Algorithm) -> f64 {
    match alg {
        Algorithm::FedAvg | Algorithm::FedProx { .. } => 1.0,
        Algorithm::FlatSparse { s } => *s,
        Algorithm::Thgs(t) => t.s0,
        Algorithm::Stc { s } => *s,
    }
}
