//! The federated training orchestrator.
//!
//! One [`Trainer`] owns the global model, the client fleet, the
//! compute backend (native or PJRT, see [`crate::runtime`]) and
//! (optionally) the secure-aggregation state, and drives the §5 round
//! loop:
//!
//! ```text
//! select C·K clients
//!   → parallel local SGD (E iterations, batch B) via the backend's grad
//!   → residual fold-in + sparsify (FedAvg/FedProx/flat/THGS)
//!   → [secure] pairwise mask-sparsified encoding (Alg. 2)
//!   → server sum → global ← global + Σ/k
//!   → eval + ledger + metrics
//! ```

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::comm::channel::NetworkModel;
use crate::comm::cost::CostLedger;
use crate::config::{Partition, RunConfig};
use crate::data::{iid_partition, noniid_partition, Dataset, DatasetKind, Split};
use crate::metrics::recorder::{Recorder, RoundRecord, RunSummary};
use crate::models::manifest::Manifest;
use crate::models::params::ParamVector;
use crate::runtime::ModelRunner;
use crate::secagg::protocol::{full_setup, SecAggClient, SecAggConfig, SecAggServer};
use crate::sparse::codec::SparseVec;
use crate::sparse::residual::ResidualStore;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;

use super::algorithms::Algorithm;
use super::client::ClientState;
use super::selection::select_clients;

/// What one round produced (returned for tests/harnesses).
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub round: u64,
    pub selected: Vec<u32>,
    pub mean_train_loss: f64,
    /// Per-client transmitted non-zeros.
    pub nnz: Vec<usize>,
    /// Per-client actual wire bytes.
    pub wire_bytes: Vec<usize>,
    pub eval: Option<(f64, f64)>, // (loss, accuracy)
    /// The server-side aggregate (the summed payloads) before the
    /// `1/k` FedAvg scaling — what tests assert on.
    pub aggregate: Vec<f32>,
    /// [`RunConfig::audit_secure_sum`] only: the f64 sum of the
    /// clients' *unmasked* contributions, in the same client order as
    /// `aggregate` (so tests can assert the pair masks cancelled).
    pub plain_sum: Option<Vec<f64>>,
}

/// Per-client state moved into the parallel round pipeline.
struct ClientJob {
    cid: u32,
    indices: Vec<usize>,
    residual: ResidualStore,
    rate: Option<crate::sparse::dynamic::DynamicRate>,
    momentum: Option<crate::sparse::momentum::MomentumCorrector>,
}

/// What each client job hands back.
struct ClientResult {
    cid: u32,
    payload: SparseVec,
    /// Unmasked contribution (secure mode + audit only).
    plain: Option<Vec<f32>>,
    residual: ResidualStore,
    rate: Option<crate::sparse::dynamic::DynamicRate>,
    momentum: Option<crate::sparse::momentum::MomentumCorrector>,
    mean_loss: f64,
    nnz: usize,
    wire: usize,
    nnz_rate: f64,
}

/// The coordinator.
pub struct Trainer {
    pub cfg: RunConfig,
    pub manifest: Manifest,
    runner: ModelRunner,
    train_data: Arc<Dataset>,
    test_data: Dataset,
    pub global: ParamVector,
    pub clients: Vec<ClientState>,
    secagg: Option<Arc<(Vec<SecAggClient>, SecAggServer)>>,
    layer_spans: Vec<(usize, usize)>,
    client_pool: ThreadPool,
    pub recorder: Recorder,
    pub ledger: CostLedger,
    pub network: NetworkModel,
    base_rate: f64,
    mask_cache: crate::secagg::mask::MaskCache,
}

impl Trainer {
    pub fn new(cfg: RunConfig) -> Result<Self> {
        cfg.validate().map_err(|e| anyhow!("config: {e}"))?;
        // a missing manifest.json is not an error: the builtin
        // manifest + native backend cover the no-Python default path
        let manifest = Manifest::load_or_builtin(&cfg.artifacts_dir)
            .with_context(|| format!("load manifest from {:?}", cfg.artifacts_dir))?;
        let runner = ModelRunner::for_config(&manifest, &cfg)?;
        let meta = runner.meta.clone();

        let kind = DatasetKind::from_name(&cfg.dataset)
            .ok_or_else(|| anyhow!("unknown dataset {:?}", cfg.dataset))?;
        let train_data = match cfg.train_samples {
            Some(n) => Dataset::synthetic_small(kind, Split::Train, n, cfg.seed),
            None => Dataset::load(kind, Split::Train, cfg.data_dir.as_deref(), cfg.seed),
        };
        let test_data = match cfg.train_samples {
            Some(n) => Dataset::synthetic_small(kind, Split::Test, (n / 4).max(manifest.eval_batch), cfg.seed),
            None => Dataset::load(kind, Split::Test, cfg.data_dir.as_deref(), cfg.seed),
        };

        // partition
        let mut rng = Rng::new(cfg.seed ^ 0xda7a);
        let parts = match cfg.partition {
            Partition::Iid => iid_partition(train_data.len(), cfg.clients, &mut rng),
            Partition::NonIid(n) => {
                noniid_partition(&train_data.labels(), cfg.clients, n, &mut rng)
            }
        };

        let m = meta.total_params();
        let clients: Vec<ClientState> = parts
            .into_iter()
            .enumerate()
            .map(|(i, data)| {
                let mut c = ClientState::new(i as u32, data, m);
                if cfg.dynamic_rate {
                    let r0 = base_rate_of(&cfg.algorithm);
                    c = c.with_dynamic_rate(r0, cfg.rate_alpha, cfg.rounds, cfg.rate_min);
                }
                if cfg.momentum > 0.0 {
                    c.momentum = Some(crate::sparse::momentum::MomentumCorrector::new(
                        m,
                        cfg.momentum,
                    ));
                }
                c
            })
            .collect();

        let mask_cache: crate::secagg::mask::MaskCache = Default::default();
        let secagg = if cfg.secure {
            let sc = SecAggConfig {
                full_dh: false,
                mask_ratio_k: cfg.mask_ratio_k,
                share_keys: false, // no dropout in the §5 experiments
                ..Default::default()
            };
            let (mut sec_clients, server) = full_setup(cfg.clients as u32, cfg.seed ^ 0x5eca, &sc);
            // shared per-round stream cache: each pair stream is used by
            // both endpoints within a round (§Perf L3 iteration 4)
            for c in sec_clients.iter_mut() {
                c.attach_cache(Arc::clone(&mask_cache));
            }
            Some(Arc::new((sec_clients, server)))
        } else {
            None
        };

        let layer_spans = meta.layer_spans();
        let label = cfg.run_label();
        let base_rate = base_rate_of(&cfg.algorithm);

        Ok(Self {
            client_pool: ThreadPool::new(cfg.client_workers),
            recorder: Recorder::new(&label),
            ledger: CostLedger::new(m),
            network: NetworkModel::default(),
            global: ParamVector::init(&meta, cfg.seed),
            train_data: Arc::new(train_data),
            test_data,
            clients,
            secagg,
            layer_spans,
            runner,
            manifest,
            cfg,
            base_rate,
            mask_cache,
        })
    }

    /// Drive the full run; returns the summary.
    pub fn run(&mut self) -> Result<RunSummary> {
        for round in 0..self.cfg.rounds {
            self.run_round(round)?;
        }
        Ok(self.recorder.summary())
    }

    /// One federated round.
    pub fn run_round(&mut self, round: u64) -> Result<RoundOutcome> {
        let cfg = &self.cfg;
        let selected = select_clients(cfg.clients, cfg.clients_per_round, cfg.seed, round);
        // previous round's pair streams are dead weight — drop them
        self.mask_cache.lock().unwrap().clear();

        // ---- parallel per-client pipeline --------------------------
        // Each selected client's full path — local SGD (PJRT grads),
        // residual fold-in, Eq. 2 rate, sparsify, (secure) mask+encode
        // — runs as one pool job. Per-client mutable state (residual
        // store, rate controller) is moved in and handed back, so no
        // locking on the hot path (§Perf L3 iteration 3).
        let items: Vec<ClientJob> = selected
            .iter()
            .map(|&cid| {
                let cs = &mut self.clients[cid as usize];
                ClientJob {
                    cid,
                    indices: cs.data.clone(),
                    residual: std::mem::replace(&mut cs.residual, ResidualStore::new(0)),
                    rate: cs.rate.take(),
                    momentum: cs.momentum.take(),
                }
            })
            .collect();
        let runner = self.runner.clone();
        let global = Arc::new(self.global.clone());
        let data = Arc::clone(&self.train_data);
        let (seed, iters, lr, batch) =
            (cfg.seed, cfg.local_iters, cfg.lr, self.manifest.train_batch);
        let prox_mu = cfg.algorithm.is_fedprox();
        let algorithm = cfg.algorithm;
        let (dynamic, base_rate) = (cfg.dynamic_rate, self.base_rate);
        let quant_bits = cfg.quant_bits;
        let (momentum_coef, warmup_rounds, total_rounds) =
            (cfg.momentum, cfg.warmup_rounds, cfg.rounds);
        let _ = total_rounds;
        let layer_spans = Arc::new(self.layer_spans.clone());
        let secagg = self.secagg.clone();
        let selected_arc = Arc::new(selected.clone());
        let secure = cfg.secure;
        let audit = cfg.audit_secure_sum;
        let m = self.global.len();

        let results: Vec<Result<ClientResult>> = self.client_pool.map(
            items,
            move |job: ClientJob| -> Result<ClientResult> {
                let ClientJob { cid, indices, mut residual, mut rate, mut momentum } = job;
                // -- local SGD --
                let mut local = (*global).clone();
                let mut rng =
                    Rng::new(seed ^ (cid as u64) << 32 ^ round.wrapping_mul(0x2545_F491_4F6C_DD1D));
                let mut loss_sum = 0f64;
                for _ in 0..iters {
                    let batch_idx: Vec<usize> = (0..batch)
                        .map(|_| indices[rng.below(indices.len() as u64) as usize])
                        .collect();
                    let (x, y) = data.batch(&batch_idx);
                    let (loss, mut grads) = runner.grad(&local, &x, &y)?;
                    if let Some(mu) = prox_mu {
                        local.add_prox_term(&mut grads, &global, mu);
                    }
                    local.sgd_step(&grads, lr);
                    loss_sum += loss as f64;
                }
                let mean_loss = loss_sum / iters as f64;
                let mut update = local.delta_from(&global);

                // -- DGC momentum correction (before residual fold) --
                if let Some(mc) = &mut momentum {
                    update = mc.correct(&update);
                }

                // -- residual fold + Eq.2 rate + DGC warm-up --
                residual.fold_into(&mut update);
                let mut scale = match (dynamic, &mut rate) {
                    (true, Some(ctrl)) => ctrl.observe(round, mean_loss) / base_rate,
                    _ => {
                        if let Some(ctrl) = &mut rate {
                            ctrl.observe(round, mean_loss);
                        }
                        1.0
                    }
                };
                if warmup_rounds > 0 {
                    scale *= crate::sparse::momentum::warmup_rate(
                        base_rate, warmup_rounds, round,
                    ) / base_rate;
                }

                // -- sparsify + (secure) encode --
                let out = algorithm.sparsify(&update, &layer_spans, scale);
                if let Some(mc) = &mut momentum {
                    mc.mask_sent(&out.sparse); // DGC momentum factor masking
                }
                let nnz_rate = out.nnz as f64 / m as f64;
                let mut plain: Option<Vec<f32>> = None;
                let payload: SparseVec = if let Some(sec) = &secagg {
                    let keep: Vec<bool> = out.sparse.iter().map(|&v| v != 0.0).collect();
                    let peers: Vec<u32> =
                        selected_arc.iter().copied().filter(|&p| p != cid).collect();
                    let mu = sec.0[cid as usize].build_update_among(&update, &keep, round, &peers);
                    if audit {
                        // what ships minus the masks: exact in f32,
                        // since the residual is g or 0 positionwise
                        plain = Some(
                            update.iter().zip(&mu.residual).map(|(u, r)| u - r).collect(),
                        );
                    }
                    residual.store(&mu.residual);
                    mu.payload
                } else {
                    residual.store(&out.residual);
                    let sv = SparseVec::from_dense(&out.sparse);
                    // QSGD-style stochastic quantization (lossy; the
                    // server receives the dequantized values)
                    if let Some(bits) = quant_bits {
                        let mut qrng = Rng::new(
                            seed ^ 0x9a_17 ^ (cid as u64) << 16 ^ round,
                        );
                        let q = crate::sparse::quant::quantize(
                            &sv,
                            crate::sparse::quant::QuantConfig { bits },
                            &mut qrng,
                        );
                        crate::sparse::quant::dequantize(&q)
                    } else {
                        sv
                    }
                };
                let counted_nnz = if algorithm.is_sparse() || secure { payload.nnz() } else { m };
                let wire = payload.encode().len();
                Ok(ClientResult {
                    cid,
                    payload,
                    plain,
                    residual,
                    rate,
                    momentum,
                    mean_loss,
                    nnz: counted_nnz,
                    wire,
                    nnz_rate,
                })
            },
        );

        // ---- hand state back + aggregate ---------------------------
        let mut agg = vec![0f32; m];
        let mut plain_sum =
            (self.cfg.secure && self.cfg.audit_secure_sum).then(|| vec![0f64; m]);
        let mut nnz_list = Vec::with_capacity(selected.len());
        let mut wire_list = Vec::with_capacity(selected.len());
        let mut loss_sum = 0f64;
        let mut rate_sum = 0f64;

        for res in results {
            let r = res?;
            let cs = &mut self.clients[r.cid as usize];
            cs.residual = r.residual;
            cs.rate = r.rate;
            cs.momentum = r.momentum;
            cs.last_loss = r.mean_loss;
            cs.participation += 1;
            loss_sum += r.mean_loss;
            rate_sum += r.nnz_rate;
            nnz_list.push(r.nnz);
            wire_list.push(r.wire);
            if let (Some(ps), Some(p)) = (plain_sum.as_mut(), r.plain.as_ref()) {
                for (acc, &v) in ps.iter_mut().zip(p) {
                    *acc += v as f64;
                }
            }
            r.payload.add_into(&mut agg);
        }

        // FedAvg mean over the selected cohort
        self.global.apply_update(&agg, 1.0 / selected.len() as f32);

        // ---- eval + bookkeeping ------------------------------------
        let do_eval = round % cfg.eval_every == cfg.eval_every - 1 || round + 1 == cfg.rounds;
        let eval = if do_eval {
            Some(self.runner.evaluate(&self.global, &self.test_data, cfg.eval_samples)?)
        } else {
            None
        };
        let accuracy = eval.map(|(_, a)| a).unwrap_or(f64::NAN);

        let ups: Vec<u64> = nnz_list
            .iter()
            .map(|&n| cfg.algorithm.paper_cost_bytes(n, m, cfg.quant_bits))
            .collect();
        self.ledger
            .record_with_costs(round, &ups, &wire_list, accuracy);
        let rc = self.ledger.rounds.last().unwrap();
        let sim_time = self
            .network
            .round_time(crate::sparse::codec::dense_cost_bytes(m), &ups);

        self.recorder.push(RoundRecord {
            round,
            train_loss: loss_sum / selected.len() as f64,
            eval_loss: eval.map(|(l, _)| l).unwrap_or(f64::NAN),
            eval_accuracy: accuracy,
            up_bytes: rc.up_paper,
            wire_bytes: rc.up_wire,
            sim_time_s: sim_time,
            mean_rate: rate_sum / selected.len() as f64,
        });

        Ok(RoundOutcome {
            round,
            selected,
            mean_train_loss: loss_sum / nnz_list.len() as f64,
            nnz: nnz_list,
            wire_bytes: wire_list,
            eval,
            aggregate: agg,
            plain_sum,
        })
    }

    /// Evaluate the current global model on the test split.
    pub fn evaluate(&self) -> Result<(f64, f64)> {
        self.runner
            .evaluate(&self.global, &self.test_data, self.cfg.eval_samples)
    }

    pub fn model_params(&self) -> usize {
        self.global.len()
    }

    /// Which compute backend the run resolved to (`"native"`/`"pjrt"`).
    pub fn backend_name(&self) -> &'static str {
        self.runner.backend_name()
    }
}

/// The configured base sparsity rate (for Eq. 2 scaling).
fn base_rate_of(alg: &Algorithm) -> f64 {
    match alg {
        Algorithm::FedAvg | Algorithm::FedProx { .. } => 1.0,
        Algorithm::FlatSparse { s } => *s,
        Algorithm::Thgs(t) => t.s0,
        Algorithm::Stc { s } => *s,
    }
}
