//! The phased federated round engine.
//!
//! One round is an explicit pipeline of typed phases — each phase takes
//! the previous phase's output struct, so the data flow is inspectable
//! and individual phases can later run async or sharded:
//!
//! ```text
//! Select          C·K of N, seeded ([`super::selection`])        → Cohort
//! LocalTrain      E local SGD iterations per client (backend)    ┐ ClientPipeline,
//! Sparsify/Encode residual fold + Eq.2 rate + Top-k (+ masks)    ┘ parallel per client
//!                 + wire codec                                   → Vec<ClientResult>
//! Collect         transport (in-process / TCP / UDS): dropout,   → Collected
//!                 straggler + chaos injection, survivor filter,
//!                 wire metering
//! Unmask/Recover  [secure] Shamir-reconstruct dead clients'      → Aggregated
//!                 pair keys, cancel orphaned masks
//! Apply           commit survivor state, FedAvg mean over        → RoundScratch
//!                 survivors — or abort below `min_survivors`
//! Eval            test split + cost ledger + recorder            → RoundOutcome
//! ```
//!
//! The per-client path (LocalTrain through Encode) is owned by
//! [`ClientPipeline`]: an immutable, cheaply clonable context that each
//! worker runs one [`ClientJob`] through. Client mutable state moves
//! into the job and back out through [`super::client::ClientState`]'s
//! take/commit/restore protocol, so the hot path stays lock-free.
//!
//! Both sides of the engine run on reusable scratch: the per-worker
//! [`ClientWorkspace`] pool covers LocalTrain → Encode, and the
//! trainer-owned [`ServerWorkspace`] covers Collect → Unmask/Recover →
//! Apply (the global model is `Arc`'d, so the round snapshot is a
//! refcount bump and Apply is copy-on-write). Collect is *streaming*:
//! each delivered payload folds into the range-sharded accumulator on
//! arrival, so the coordinator never buffers the cohort's decoded
//! payloads; with `shards > 1` and a multi-worker pool the fold fans
//! out one task per shard, each range-walking the raw wire bytes with
//! the fused decode+fold kernels in ascending client id — bitwise
//! equal to the serial fold. Quantized uplinks ship raw codes and
//! dequantize on fold. In steady state neither side heap-allocates
//! anything model-sized — encoded wire buffers recycle through the
//! [`WorkspacePool`] (`tests/alloc_steady_state.rs`). Secure-mode pair-mask
//! generation — client masking and server dead-mask recovery — fans
//! out per pair over the worker pool under a pinned serial reduction
//! order, and the shards merge in ascending shard id, so results stay
//! bitwise identical to the serial path at any shard count (PERF.md).
//! Under a k-regular [`Neighborhood`] (`neighbors_k` > 0) each secure
//! client masks against only its seeded neighbors and dead-client
//! recovery walks one neighborhood, not the cohort.
//!
//! Failure semantics: a client the transport kills (crash or past-
//! deadline straggler) rolls back to its pre-round snapshot — from its
//! point of view the round never happened; the un-transmitted residual
//! mass stays put and is folded into its next participating round.
//! Snapshots are copy-on-write: the pre-round residual is shared by
//! `Arc` and the job writes the evolved state into a recycled spare
//! store, so failure-injection rounds take no model-sized copies or
//! allocations in steady state (see [`super::client`] and
//! `tests/alloc_steady_state.rs`).
//! When too few uploads arrive (`min_survivors`, or fewer than the
//! Shamir threshold while dead masks need recovery), the whole round
//! aborts: the global model and every selected client roll back, and
//! only the communication that actually happened is metered.

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::comm::transport::{Delivery, Uplink, UplinkFrame};
use crate::data::Dataset;
use crate::metrics::recorder::{PhaseTimings, RoundRecord};
use crate::models::params::ParamVector;
use crate::runtime::{ModelRunner, Workspace};
use crate::secagg::neighborhood::Neighborhood;
use crate::secagg::protocol::{recover_pair_keys_in, SecAggClient, SecAggServer};
use crate::secagg::rekey::recover_pair_keys_rekeyed;
use crate::secagg::sparse_mask::{MaskScratch, MaskedUpdate};
use crate::sparse::codec::SparseVec;
use crate::sparse::dynamic::DynamicRate;
use crate::sparse::quant::QuantizedSparse;
use crate::sparse::flat::SparsifyOut;
use crate::sparse::momentum::MomentumCorrector;
use crate::sparse::residual::ResidualStore;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::algorithms::Algorithm;
use super::client::ClientSnapshot;
use super::selection::select_clients;
use super::shard::ShardedAccumulator;
use super::trainer::Trainer;

/// Per-worker reusable scratch for the full client round path
/// (LocalTrain → Sparsify → Mask → Encode). Every model-sized buffer
/// the path touches lives here, sized on first use and reused for the
/// rest of the run, so the steady-state per-client path performs zero
/// model-sized heap allocations (pinned by
/// `tests/alloc_steady_state.rs`).
#[derive(Default)]
pub struct ClientWorkspace {
    /// Backend activation/delta scratch ([`Workspace`]).
    backend: Workspace,
    /// Flat gradient of one SGD step.
    grads: Vec<f32>,
    /// The client's local model, reset from the global snapshot.
    local: ParamVector,
    /// Δw = local − global after the E local iterations.
    update: Vec<f32>,
    /// Sampled batch indices / pixels / labels.
    batch_idx: Vec<usize>,
    x: Vec<f32>,
    y: Vec<i32>,
    /// Top-k magnitude-selection scratch.
    topk: Vec<f32>,
    /// Sparse/residual split output.
    sparsify: SparsifyOut,
    /// Secure mode: Top-k keep pattern, round peer ids, combined-mask
    /// scratch, and the masked-update output.
    keep: Vec<bool>,
    peers: Vec<u32>,
    mask: MaskScratch,
    masked: MaskedUpdate,
}

/// Shared pool of [`ClientWorkspace`]s, owned by the [`Trainer`] so
/// the warm buffers survive across rounds: a worker pops one per job
/// and returns it afterwards, so the pool grows to the worker pool's
/// concurrency during the first round and then every later round
/// reuses the same allocations.
///
/// The pool also recycles the **wire buffers**: an encoded payload has
/// to be moved (client → transport → Delivery → fold), so it cannot
/// live inside a [`ClientWorkspace`] — instead encode acquires a warm
/// byte buffer here and the Collect fold releases it after the payload
/// is consumed. Steady state: the same cohort-count of buffers cycles
/// every round and the encode path allocates nothing
/// (`tests/alloc_steady_state.rs`). A failure-injected client's buffer
/// dies inside the transport and is re-grown on a later acquire — a
/// k-sized, sub-model-sized cost only paid on failure rounds.
#[derive(Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<ClientWorkspace>>,
    wire: Mutex<Vec<Vec<u8>>>,
}

impl WorkspacePool {
    fn acquire(&self) -> ClientWorkspace {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    fn release(&self, ws: ClientWorkspace) {
        self.free.lock().unwrap().push(ws);
    }

    fn acquire_wire(&self) -> Vec<u8> {
        self.wire.lock().unwrap().pop().unwrap_or_default()
    }

    fn release_wire(&self, mut buf: Vec<u8>) {
        buf.clear();
        self.wire.lock().unwrap().push(buf);
    }
}

/// Coordinator-side reusable scratch — the server twin of
/// [`ClientWorkspace`], owned by the [`Trainer`] so the warm buffers
/// survive across rounds. Holds every model-sized buffer the Collect →
/// Unmask/Recover → Apply phases touch:
///
/// * `sharded` — the streaming Collect accumulator: each delivered
///   uplink folds into its range shard on arrival, so the coordinator
///   never buffers the cohort's decoded payloads (per-aggregator
///   memory O(model), not O(cohort × k_sparse));
/// * `decode` — one reusable [`SparseVec`] the wire codec decodes
///   into (k-sized, recycled per delivery);
/// * `agg` — the flat aggregate the shards merge into (ascending
///   shard id — the documented shard-merge order, bitwise identical
///   to a serial single-accumulator run at any shard count) after
///   dead masks are cancelled in place via the kept-entry reduction;
/// * `plain` — the `audit_secure_sum` f64 accumulator (only grown in
///   audit runs).
///
/// Apply needs no delta buffer: the global model is `Arc`'d and
/// updated copy-on-write through `Arc::make_mut`, which is in-place in
/// steady state. With [`crate::config::RunConfig::expose_aggregate`]
/// off (the default), the steady-state coordinator path performs zero
/// model-sized heap allocations per round — pinned by
/// `tests/alloc_steady_state.rs`.
#[derive(Default)]
pub struct ServerWorkspace {
    /// Streaming Collect accumulator (model-sized across its shards,
    /// reused).
    pub(crate) sharded: ShardedAccumulator,
    /// Wire-decode scratch (k-sized, reused).
    pub(crate) decode: SparseVec,
    /// Quantized-frame decode scratch (k-sized, reused; only touched
    /// when `quant_bits` is set).
    pub(crate) qdecode: QuantizedSparse,
    /// Post-merge flat aggregate (model-sized, reused).
    pub(crate) agg: Vec<f32>,
    /// Audit-mode plaintext f64 sum (model-sized, reused; empty unless
    /// `audit_secure_sum`).
    pub(crate) plain: Vec<f64>,
}

/// What one round produced (returned for tests/harnesses).
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub round: u64,
    pub selected: Vec<u32>,
    /// Selected clients whose upload arrived in time (== `selected`
    /// when failure injection is off).
    pub survivors: Vec<u32>,
    /// Selected clients that crashed mid-round (upload never sent).
    pub dropped: Vec<u32>,
    /// Selected clients whose upload landed after the collect deadline.
    pub stragglers: Vec<u32>,
    /// True when the round was discarded (fewer than `min_survivors`
    /// uploads, or dead masks unrecoverable): the global model and all
    /// client state rolled back; `aggregate` is empty and `eval` None.
    pub aborted: bool,
    /// (survivor, dead) pair masks the server Shamir-recovered and
    /// cancelled this round (secure mode).
    pub recovered_pairs: usize,
    /// Mean local train loss over *survivors*.
    pub mean_train_loss: f64,
    /// Per-survivor transmitted non-zeros.
    pub nnz: Vec<usize>,
    /// Per-survivor actual wire bytes.
    pub wire_bytes: Vec<usize>,
    pub eval: Option<(f64, f64)>, // (loss, accuracy)
    /// The server-side aggregate (the summed survivor payloads, masks
    /// recovered) before the `1/k` FedAvg scaling — what tests assert
    /// on. Only populated when
    /// [`crate::config::RunConfig::expose_aggregate`] is set (the copy
    /// out of the trainer-owned [`ServerWorkspace`] is a model-sized
    /// allocation); always empty when the round aborted.
    pub aggregate: Vec<f32>,
    /// [`crate::config::RunConfig::audit_secure_sum`] only: the f64 sum
    /// of the *survivors'* unmasked contributions, in the same order as
    /// `aggregate` (so tests can assert the pair masks cancelled).
    pub plain_sum: Option<Vec<f64>>,
    /// Real wall-clock spent per phase.
    pub timings: PhaseTimings,
}

/// Per-client mutable state moved into the parallel round pipeline.
/// The pre-round residual is shared (`Arc`), never mutated by the job;
/// the evolved residual is written into `fresh`, the client's recycled
/// double-buffer twin (see [`super::client::ClientState`]).
pub struct ClientJob {
    cid: u32,
    indices: Vec<usize>,
    residual: Arc<ResidualStore>,
    fresh: ResidualStore,
    rate: Option<DynamicRate>,
    /// Pre-round DGC momentum (shared with the rollback snapshot —
    /// read-only in the job; see [`super::client::ClientState`]).
    momentum: Option<Arc<MomentumCorrector>>,
    /// The recycled write target the evolved velocity lands in (the
    /// momentum twin of `fresh`).
    momentum_fresh: Option<MomentumCorrector>,
}

/// What each client job hands back.
pub struct ClientResult {
    cid: u32,
    /// Wire-encoded payload (moved into the transport at Collect).
    encoded: Vec<u8>,
    /// Encoded size in bytes (kept after `encoded` is shipped).
    wire: usize,
    /// Unmasked contribution (secure mode + audit only).
    plain: Option<Vec<f32>>,
    /// The evolved residual (committed on delivery; recycled into the
    /// client's spare on rollback).
    residual: ResidualStore,
    /// The untouched pre-round residual (becomes the next spare at
    /// commit; simply dropped on rollback — the snapshot holds it).
    residual_prev: Arc<ResidualStore>,
    rate: Option<DynamicRate>,
    /// The evolved momentum corrector (committed on delivery; recycled
    /// into the client's momentum spare on rollback).
    momentum: Option<MomentumCorrector>,
    /// The untouched pre-round corrector (the momentum twin of
    /// `residual_prev`).
    momentum_prev: Option<Arc<MomentumCorrector>>,
    mean_loss: f64,
    nnz: usize,
    nnz_rate: f64,
    /// CPU-seconds this client spent in local SGD.
    train_s: f64,
    /// CPU-seconds this client spent in sparsify+mask+encode.
    encode_s: f64,
    /// CPU-seconds of `encode_s` spent generating/applying pair masks
    /// (secure mode; 0 in plain runs).
    mask_s: f64,
}

/// Phase 1 output: the round's selected participant set plus its mask
/// topology (complete graph unless `neighbors_k` > 0 — see
/// [`Neighborhood`]).
pub struct Cohort {
    pub round: u64,
    pub selected: Vec<u32>,
    pub topology: Arc<Neighborhood>,
}

/// Phase 4 output: what survived the transport. Payloads are **not**
/// buffered here — Collect streams each delivered payload into the
/// sharded accumulator on arrival ([`ServerWorkspace`]).
struct Collected {
    /// Survivor results in selection order (their payloads already
    /// folded into the accumulator in this same order).
    survivors: Vec<ClientResult>,
    /// dropped ∪ stragglers — every selected client whose masks are now
    /// orphaned in secure mode.
    dead: Vec<u32>,
    dropped: Vec<u32>,
    stragglers: Vec<u32>,
    /// Failed clients' results (state discarded, snapshots restored).
    rolled_back: Vec<ClientResult>,
    /// Simulated communication wall-clock of the round barrier.
    round_time_s: f64,
    /// Framed socket bytes (payload + frame headers) the delivered
    /// uplinks put on the wire — metered identically on every
    /// transport (`up_framed` in the ledger).
    framed: u64,
}

/// Phase 5 output marker: the unmasked sum itself lives in the
/// trainer-owned [`ServerWorkspace`] (`agg` / `plain`); this carries
/// only the recovery metadata.
struct Aggregated {
    recovered_pairs: usize,
}

/// Phase 6 output: the per-survivor rows later phases report on.
#[derive(Default)]
struct RoundScratch {
    survivors: Vec<u32>,
    nnz: Vec<usize>,
    wire: Vec<usize>,
    /// Framed socket bytes for the round (see [`Collected::framed`]).
    framed: u64,
    loss_sum: f64,
    rate_sum: f64,
}

/// The per-client path (LocalTrain → Sparsify/Encode) as an immutable,
/// cheaply clonable context: every worker clones the pipeline and runs
/// one [`ClientJob`] through [`ClientPipeline::run`]. Owning this path
/// in one place (instead of a captured closure) is what lets the
/// engine's phases evolve independently.
#[derive(Clone)]
pub struct ClientPipeline {
    runner: ModelRunner,
    global: Arc<ParamVector>,
    data: Arc<Dataset>,
    layer_spans: Arc<Vec<(usize, usize)>>,
    secagg: Option<Arc<(Vec<SecAggClient>, SecAggServer)>>,
    /// The round's mask topology — each secure client masks against
    /// its neighbors (the full cohort when complete).
    topology: Arc<Neighborhood>,
    /// Trainer-owned workspace pool (warm buffers persist across
    /// rounds; see [`WorkspacePool`]).
    workspaces: Arc<WorkspacePool>,
    /// The trainer's client worker pool, shared back into the jobs so
    /// secure-mode pair-mask generation can fan out per peer
    /// (`ThreadPool::map_shared` is safe to call from inside the jobs
    /// running on this very pool).
    pool: Arc<ThreadPool>,
    round: u64,
    seed: u64,
    iters: usize,
    lr: f32,
    batch: usize,
    prox_mu: Option<f32>,
    algorithm: Algorithm,
    dynamic: bool,
    base_rate: f64,
    quant_bits: Option<u8>,
    warmup_rounds: u64,
    secure: bool,
    audit: bool,
    m: usize,
}

impl ClientPipeline {
    /// Snapshot the trainer's round-invariant context for one round.
    fn for_round(trainer: &Trainer, cohort: &Cohort) -> Self {
        let cfg = &trainer.cfg;
        Self {
            runner: trainer.runner.clone(),
            // refcount bump, NOT a model-sized copy: the global model
            // is Arc'd and only mutated copy-on-write at Apply
            global: Arc::clone(&trainer.global),
            data: Arc::clone(&trainer.train_data),
            layer_spans: Arc::new(trainer.layer_spans.clone()),
            secagg: trainer.secagg.clone(),
            topology: Arc::clone(&cohort.topology),
            workspaces: Arc::clone(&trainer.client_workspaces),
            pool: Arc::clone(&trainer.client_pool),
            round: cohort.round,
            seed: cfg.seed,
            iters: cfg.local_iters,
            lr: cfg.lr,
            batch: trainer.manifest.train_batch,
            prox_mu: cfg.algorithm.is_fedprox(),
            algorithm: cfg.algorithm,
            dynamic: cfg.dynamic_rate,
            base_rate: trainer.base_rate,
            quant_bits: cfg.quant_bits,
            warmup_rounds: cfg.warmup_rounds,
            secure: cfg.secure,
            audit: cfg.audit_secure_sum,
            m: trainer.global.len(),
        }
    }

    /// One client's full round path: local SGD (E iterations), DGC
    /// momentum correction, residual fold-in, Eq. 2 rate, sparsify,
    /// (secure) mask + encode. Pure in the job + context — no shared
    /// mutable state, so jobs parallelize freely; the model-sized
    /// scratch comes from the trainer's [`WorkspacePool`].
    pub fn run(&self, job: ClientJob) -> Result<ClientResult> {
        let mut ws = self.workspaces.acquire();
        let out = self.run_in(job, &mut ws);
        self.workspaces.release(ws);
        out
    }

    /// [`Self::run`] against explicit scratch. Every step writes into
    /// `ws` buffers and the wire payload encodes into a recycled
    /// [`WorkspacePool`] byte buffer, so the steady-state encode path
    /// allocates nothing beyond the k-sized sparse gather (and the
    /// audit vector when enabled).
    fn run_in(&self, job: ClientJob, ws: &mut ClientWorkspace) -> Result<ClientResult> {
        let ClientJob { cid, indices, residual, mut fresh, mut rate, momentum, mut momentum_fresh } =
            job;
        let round = self.round;

        // -- LocalTrain: E local SGD iterations --
        let sw = Stopwatch::start();
        ws.local.copy_from(&self.global);
        let mut rng = Rng::new(
            self.seed ^ (cid as u64) << 32 ^ round.wrapping_mul(0x2545_F491_4F6C_DD1D),
        );
        let mut loss_sum = 0f64;
        for _ in 0..self.iters {
            ws.batch_idx.clear();
            for _ in 0..self.batch {
                ws.batch_idx.push(indices[rng.below(indices.len() as u64) as usize]);
            }
            self.data.batch_into(&ws.batch_idx, &mut ws.x, &mut ws.y);
            let loss =
                self.runner.grad_into(&ws.local, &ws.x, &ws.y, &mut ws.backend, &mut ws.grads)?;
            if let Some(mu) = self.prox_mu {
                ws.local.add_prox_term(&mut ws.grads, &self.global, mu);
            }
            ws.local.sgd_step(&ws.grads, self.lr);
            loss_sum += loss as f64;
        }
        let mean_loss = loss_sum / self.iters as f64;
        ws.local.delta_into(&self.global, &mut ws.update);
        let train_s = sw.elapsed_secs();

        // -- Sparsify/Encode --
        let sw = Stopwatch::start();
        // DGC momentum correction (before residual fold). Double-
        // buffered like the residual: the shared pre-round corrector is
        // read-only (the rollback snapshot may hold it), the advanced
        // velocity lands in the recycled write target.
        if let Some(prev) = &momentum {
            momentum_fresh
                .as_mut()
                .expect("momentum write target paired with the corrector")
                .correct_from(prev, &mut ws.update);
        }

        // residual fold + Eq.2 rate + DGC warm-up
        residual.fold_into(&mut ws.update);
        let mut scale = match (self.dynamic, &mut rate) {
            (true, Some(ctrl)) => ctrl.observe(round, mean_loss) / self.base_rate,
            _ => {
                if let Some(ctrl) = &mut rate {
                    ctrl.observe(round, mean_loss);
                }
                1.0
            }
        };
        if self.warmup_rounds > 0 {
            scale *= crate::sparse::momentum::warmup_rate(
                self.base_rate,
                self.warmup_rounds,
                round,
            ) / self.base_rate;
        }

        // sparsify + (secure) encode
        self.algorithm.sparsify_into(
            &ws.update,
            &self.layer_spans,
            scale,
            &mut ws.topk,
            &mut ws.sparsify,
        );
        if let Some(mc) = &mut momentum_fresh {
            mc.mask_sent(&ws.sparsify.sparse); // DGC momentum factor masking
        }
        let nnz_rate = ws.sparsify.nnz as f64 / self.m as f64;
        let mut plain: Option<Vec<f32>> = None;
        let mut mask_s = 0f64;
        let (encoded, counted_nnz) = if let Some(sec) = &self.secagg {
            ws.keep.clear();
            ws.keep.extend(ws.sparsify.sparse.iter().map(|&v| v != 0.0));
            // this client's mask peers: its neighborhood under a
            // k-regular topology, the whole cohort when complete
            self.topology.neighbors_into(cid, &mut ws.peers);
            let sw_mask = Stopwatch::start();
            // fan the per-pair ChaCha streams out over the worker pool
            // when there is parallelism to gain; the pooled path is
            // bitwise identical to the serial one (pinned reduction
            // order — see PERF.md), so this gate is pure scheduling
            if self.pool.size() > 1 && ws.peers.len() >= 2 {
                sec.0[cid as usize].build_update_among_pooled_into(
                    &ws.update,
                    &ws.keep,
                    round,
                    &ws.peers,
                    &self.pool,
                    &mut ws.mask,
                    &mut ws.masked,
                );
            } else {
                sec.0[cid as usize].build_update_among_into(
                    &ws.update,
                    &ws.keep,
                    round,
                    &ws.peers,
                    &mut ws.mask,
                    &mut ws.masked,
                );
            }
            mask_s = sw_mask.elapsed_secs();
            if self.audit {
                // what ships minus the masks: exact in f32,
                // since the residual is g or 0 positionwise
                plain = Some(
                    ws.update.iter().zip(&ws.masked.residual).map(|(u, r)| u - r).collect(),
                );
            }
            // evolved residual into the recycled write target — the
            // shared pre-round store stays untouched for the rollback
            // snapshot (CoW; see `super::client`)
            fresh.store_from(&residual, &ws.masked.residual);
            // secure mode always ships f32 values: the pair masks are
            // f32 sums, so there is no code space to quantize in (the
            // config validator rejects secure + quant_bits)
            let mut wire = self.workspaces.acquire_wire();
            ws.masked.payload.encode_into(&mut wire);
            // secagg is only built in secure mode, where transmitted
            // positions are always counted sparsely
            (wire, ws.masked.payload.nnz())
        } else {
            fresh.store_from(&residual, &ws.sparsify.residual);
            let sv = SparseVec::from_dense(&ws.sparsify.sparse);
            let counted =
                if self.algorithm.is_sparse() || self.secure { sv.nnz() } else { self.m };
            let mut wire = self.workspaces.acquire_wire();
            if let Some(bits) = self.quant_bits {
                // QSGD-style stochastic quantization — the codes
                // themselves ship (bitpacked v1 frame) and the server
                // dequantizes on fold, bitwise identical to the old
                // client-side dequantize + f32 round-trip
                let mut qrng = Rng::new(self.seed ^ 0x9a_17 ^ (cid as u64) << 16 ^ round);
                let q = crate::sparse::quant::quantize(
                    &sv,
                    crate::sparse::quant::QuantConfig { bits },
                    &mut qrng,
                );
                q.encode_into(&mut wire);
            } else {
                sv.encode_into(&mut wire);
            }
            (wire, counted)
        };
        let encode_s = sw.elapsed_secs();
        Ok(ClientResult {
            cid,
            wire: encoded.len(),
            encoded,
            plain,
            residual: fresh,
            residual_prev: residual,
            rate,
            momentum: momentum_fresh,
            momentum_prev: momentum,
            mean_loss,
            nnz: counted_nnz,
            nnz_rate,
            train_s,
            encode_s,
            mask_s,
        })
    }
}

impl Trainer {
    /// One federated round through the phased engine. Never fails on
    /// injected client failures — those surface as `dropped` /
    /// `stragglers` / `aborted` in the [`RoundOutcome`].
    pub fn run_round(&mut self, round: u64) -> Result<RoundOutcome> {
        let mut timings = PhaseTimings::default();

        // ---- Select ------------------------------------------------
        let sw = Stopwatch::start();
        let cohort = self.phase_select(round);
        // Failure rollback needs pre-round state; skip the copies
        // entirely on the (default) failure-free path.
        let snapshots: HashMap<u32, ClientSnapshot> = if self.transport.failure_enabled() {
            cohort
                .selected
                .iter()
                .map(|&cid| (cid, self.clients[cid as usize].snapshot()))
                .collect()
        } else {
            HashMap::new()
        };
        timings.select_s = sw.elapsed_secs();

        // ---- LocalTrain + Sparsify/Encode (parallel per client) ----
        let sw = Stopwatch::start();
        let results = match self.phase_local_train(&cohort) {
            Ok(r) => r,
            Err(e) => {
                // the selected clients' state was moved into the jobs;
                // restore what the snapshots preserved before bubbling
                // the error (without failure injection there are no
                // snapshots and the moved state is lost — the error is
                // fatal to the run either way)
                self.restore_snapshots(snapshots);
                return Err(e);
            }
        };
        timings.train_s = sw.elapsed_secs();
        timings.client_train_cpu_s = results.iter().map(|r| r.train_s).sum();
        timings.client_encode_cpu_s = results.iter().map(|r| r.encode_s).sum();
        timings.mask_gen_s = results.iter().map(|r| r.mask_s).sum();

        // ---- Collect (transport + survivor filter) -----------------
        let sw = Stopwatch::start();
        let collected = match self.phase_collect(&cohort, results) {
            Ok(c) => c,
            Err(e) => {
                self.restore_snapshots(snapshots);
                return Err(e);
            }
        };
        timings.collect_s = sw.elapsed_secs();

        // ---- min-survivors guard -----------------------------------
        let mut required = self.cfg.min_survivors;
        if !collected.dead.is_empty() {
            if let Some(sec) = self.secagg.as_deref() {
                // recovering dead masks needs a Shamir quorum
                required = required.max(sec.1.share_threshold);
            }
        }
        if collected.survivors.len() < required {
            return Ok(self.abort_round(cohort, collected, snapshots, timings));
        }

        // ---- Unmask/Recover ----------------------------------------
        let sw = Stopwatch::start();
        let aggregated = match self.phase_unmask_recover(&cohort, &collected) {
            Some(a) => a,
            // no share material for the orphaned masks: the aggregate
            // is unusable — discard the round rather than corrupt the
            // model
            None => {
                timings.recover_s = sw.elapsed_secs();
                return Ok(self.abort_round(cohort, collected, snapshots, timings));
            }
        };
        timings.recover_s = sw.elapsed_secs();

        // ---- Apply -------------------------------------------------
        let sw = Stopwatch::start();
        let (scratch, dropped, stragglers, round_time_s) = self.phase_apply(collected, snapshots);
        timings.apply_s = sw.elapsed_secs();

        // ---- Eval + bookkeeping ------------------------------------
        let sw = Stopwatch::start();
        let cfg = &self.cfg;
        let do_eval = round % cfg.eval_every == cfg.eval_every - 1 || round + 1 == cfg.rounds;
        let eval = if do_eval {
            Some(self.runner.evaluate(&self.global, &self.test_data, cfg.eval_samples)?)
        } else {
            None
        };
        timings.eval_s = sw.elapsed_secs();
        let accuracy = eval.map(|(_, a)| a).unwrap_or(f64::NAN);

        let m = self.global.len();
        let ups: Vec<u64> = scratch
            .nnz
            .iter()
            .map(|&n| self.cfg.algorithm.paper_cost_bytes(n, m, self.cfg.quant_bits))
            .collect();
        self.ledger.record_with_costs(round, &ups, &scratch.wire, scratch.framed, accuracy);
        let rc = self.ledger.rounds.last().unwrap();

        let k = scratch.survivors.len();
        let mean_train_loss = scratch.loss_sum / k as f64;
        self.recorder.push(RoundRecord {
            round,
            train_loss: mean_train_loss,
            eval_loss: eval.map(|(l, _)| l).unwrap_or(f64::NAN),
            eval_accuracy: accuracy,
            up_bytes: rc.up_paper,
            wire_bytes: rc.up_wire,
            sim_time_s: round_time_s,
            mean_rate: scratch.rate_sum / k as f64,
            survivors: k,
            recovered: aggregated.recovered_pairs,
            timings,
        });

        // ---- Checkpoint (durable commit point) ---------------------
        // Only applied rounds commit: an aborted round rolls the model
        // and clients back, so checkpointing it would pin a next_round
        // whose state the uninterrupted twin never passes through.
        if self.ckpt.is_some() {
            let every = self.cfg.checkpoint_every.max(1);
            if (round + 1) % every == 0 || round + 1 == self.cfg.rounds {
                let ck = self.build_checkpoint(round + 1);
                let save_err = match &self.ckpt {
                    Some(store) => store.save(&ck).err(),
                    None => None,
                };
                if let Some(e) = save_err {
                    eprintln!(
                        "warning: checkpoint save failed ({e}); checkpointing disabled \
                         for the rest of the run"
                    );
                    self.ckpt = None;
                }
            }
        }

        Ok(RoundOutcome {
            round,
            selected: cohort.selected,
            survivors: scratch.survivors,
            dropped,
            stragglers,
            aborted: false,
            recovered_pairs: aggregated.recovered_pairs,
            mean_train_loss,
            nnz: scratch.nnz,
            wire_bytes: scratch.wire,
            eval,
            // observability copies out of the server workspace, gated:
            // with both flags off the steady-state coordinator path
            // allocates nothing model-sized
            aggregate: if self.cfg.expose_aggregate {
                self.server_ws.agg.clone()
            } else {
                Vec::new()
            },
            plain_sum: (self.cfg.secure && self.cfg.audit_secure_sum)
                .then(|| self.server_ws.plain.clone()),
            timings,
        })
    }

    /// Drive JUST the per-client path (Select → LocalTrain →
    /// Sparsify/Encode) for every selected client, inline on the
    /// caller thread, committing the evolved state — no transport,
    /// aggregation, apply, or eval. The perf/alloc harnesses use this
    /// to observe the per-client hot path in isolation; the full
    /// engine is exercised by [`Trainer::run_round`]. Returns the mean
    /// local train loss.
    pub fn run_client_phases(&mut self, round: u64) -> Result<f64> {
        let cohort = self.phase_select(round);
        let pipeline = ClientPipeline::for_round(self, &cohort);
        let mut loss_sum = 0f64;
        let k = cohort.selected.len();
        for &cid in &cohort.selected {
            let cs = &mut self.clients[cid as usize];
            let st = cs.take_round_state();
            let job = ClientJob {
                cid,
                indices: cs.data.clone(),
                residual: st.residual,
                fresh: st.fresh,
                rate: st.rate,
                momentum: st.momentum,
                momentum_fresh: st.momentum_fresh,
            };
            let r = pipeline.run(job)?;
            loss_sum += r.mean_loss;
            self.clients[cid as usize].commit_round(
                r.residual_prev,
                r.residual,
                r.rate,
                r.momentum_prev,
                r.momentum,
                r.mean_loss,
            );
        }
        Ok(loss_sum / k as f64)
    }

    /// Best-effort rollback after a mid-round error: restore whatever
    /// snapshots exist so a caller that catches the error does not
    /// continue with emptied client state. No-op when failure injection
    /// is off (no snapshots are taken on that zero-overhead path).
    fn restore_snapshots(&mut self, snapshots: HashMap<u32, ClientSnapshot>) {
        for (cid, snap) in snapshots {
            self.clients[cid as usize].restore(snap);
        }
    }

    /// Phase 1 — seeded cohort selection, the round's mask topology,
    /// and per-round cache hygiene.
    fn phase_select(&mut self, round: u64) -> Cohort {
        let selected =
            select_clients(self.cfg.clients, self.cfg.clients_per_round, self.cfg.seed, round);
        // deterministic per (seed, round), so any round replays exactly;
        // neighbors_k = 0 (the default) yields the complete graph and
        // the pre-neighborhood bitwise behavior
        let topology = Arc::new(Neighborhood::build(
            &selected,
            self.cfg.neighbors_k,
            self.cfg.seed,
            round,
        ));
        // previous round's pair streams are dead weight — drop them
        self.mask_cache.lock().unwrap().clear();
        // per-round neighborhood-local Shamir re-keying (k-regular
        // secure runs with failure injection): before any masks are
        // built, each cohort member's exponent shares move to exactly
        // its round neighbors; owners whose neighborhood is unchanged
        // carry their existing shares
        if let (Some(sec), Some(reg)) = (self.secagg.clone(), self.rekey.as_mut()) {
            reg.rekey_for(&sec.0, &topology, round, self.cfg.seed);
        }
        Cohort { round, selected, topology }
    }

    /// Phases 2+3 — fan the cohort out over the worker pool, one
    /// [`ClientPipeline::run`] per client. Results come back in
    /// selection order.
    fn phase_local_train(&mut self, cohort: &Cohort) -> Result<Vec<ClientResult>> {
        let jobs: Vec<ClientJob> = cohort
            .selected
            .iter()
            .map(|&cid| {
                let cs = &mut self.clients[cid as usize];
                let st = cs.take_round_state();
                ClientJob {
                    cid,
                    indices: cs.data.clone(),
                    residual: st.residual,
                    fresh: st.fresh,
                    rate: st.rate,
                    momentum: st.momentum,
                    momentum_fresh: st.momentum_fresh,
                }
            })
            .collect();
        let pipeline = ClientPipeline::for_round(self, cohort);
        let results: Vec<Result<ClientResult>> =
            self.client_pool.map(jobs, move |job: ClientJob| pipeline.run(job));
        results.into_iter().collect()
    }

    /// Phase 4 — move every encoded payload into the transport
    /// (in-process twin or a real socket, per `--transport`); the
    /// seeded failure plan decides who survives. Delivered frames are
    /// decoded server-side and **streamed** straight into the sharded
    /// accumulator: the transport's sink folds each payload on arrival
    /// and its decoded form is immediately recycled, so the coordinator
    /// holds O(model) accumulator memory instead of O(cohort ×
    /// k_sparse) buffered payloads. Every [`Uplink`] sinks in ascending
    /// client id (the socket path resequences to guarantee it) — the
    /// pinned fold order, so the streaming fold is bitwise identical to
    /// buffering all payloads and summing them afterwards, on any
    /// transport.
    fn phase_collect(
        &mut self,
        cohort: &Cohort,
        mut results: Vec<ClientResult>,
    ) -> Result<Collected> {
        let m = self.global.len();
        let frames: Vec<UplinkFrame> = results
            .iter_mut()
            .map(|r| UplinkFrame {
                cid: r.cid,
                bytes: std::mem::take(&mut r.encoded),
                paper_bytes: self.cfg.algorithm.paper_cost_bytes(r.nnz, m, self.cfg.quant_bits),
            })
            .collect();
        let down_bytes = crate::sparse::codec::dense_cost_bytes(m);
        let quant = self.cfg.quant_bits.is_some();
        // the pool-parallel fold is bitwise-equal to the serial one
        // (each position lives in exactly one shard and sees the same
        // ascending-cid op sequence), so this gate is pure scheduling;
        // it buffers the delivered payloads and fans out post-barrier
        let parallel = self.cfg.shards > 1 && self.client_pool.size() > 1;
        self.server_ws.sharded.reset(m, self.cfg.shards);

        let mut payloads: Vec<(u32, Vec<u8>)> = Vec::new();
        let mut fold_err: Option<anyhow::Error> = None;
        // the sink borrows server/client workspaces while the transport
        // holds `&mut self`'s transport field — disjoint by destructure
        let Trainer { transport, server_ws, client_workspaces, .. } = self;
        let mut sink = |d: Delivery| {
            if parallel {
                payloads.push((d.cid, d.bytes));
                return;
            }
            // serial streaming fold: decode into warm scratch, fold,
            // recycle the wire buffer. Quantized frames dequantize on
            // fold (`code·scale/levels` — the exact client-side
            // [`crate::sparse::quant::dequantize`] expression). First
            // decode error wins; later payloads still recycle.
            if fold_err.is_none() {
                let folded = if quant {
                    QuantizedSparse::decode_into(&d.bytes, &mut server_ws.qdecode)
                        .map(|_| server_ws.sharded.fold_quant(&server_ws.qdecode))
                } else {
                    SparseVec::decode_into(&d.bytes, &mut server_ws.decode)
                        .map(|_| server_ws.sharded.fold(&server_ws.decode))
                };
                if let Err(e) = folded {
                    fold_err = Some(anyhow!("client {} payload: {e}", d.cid));
                }
            }
            client_workspaces.release_wire(d.bytes);
        };
        let outcome = transport.collect_with(cohort.round, down_bytes, frames, &mut sink)?;
        if let Some(e) = fold_err {
            return Err(e);
        }
        // undelivered (and socket sender-side) wire buffers come back
        // through `spent` — recycle them so dropped clients don't cost
        // the pool its warm buffers
        for bytes in outcome.spent {
            self.client_workspaces.release_wire(bytes);
        }
        if parallel && !payloads.is_empty() {
            self.fold_payloads_parallel(m, payloads)?;
        }

        let delivered: HashSet<u32> = outcome.delivered.iter().map(|a| a.cid).collect();
        let framed: u64 = outcome.delivered.iter().map(|a| a.framed as u64).sum();
        let mut survivors = Vec::with_capacity(delivered.len());
        let mut rolled_back = Vec::new();
        for r in results {
            if delivered.contains(&r.cid) {
                survivors.push(r);
            } else {
                rolled_back.push(r);
            }
        }
        let mut dead = outcome.dropped.clone();
        dead.extend_from_slice(&outcome.timed_out);
        dead.sort_unstable();
        Ok(Collected {
            survivors,
            dead,
            dropped: outcome.dropped,
            stragglers: outcome.timed_out,
            rolled_back,
            round_time_s: outcome.round_time_s,
            framed,
        })
    }

    /// Pool-parallel Collect fold: one task per shard, each owning its
    /// moved-out shard buffer and walking every payload restricted to
    /// its coordinate range via the fused decode+fold kernels
    /// ([`crate::sparse::codec::fold_f32_range`] /
    /// [`crate::sparse::quant::fold_quant_range`]), in ascending
    /// client id. Bitwise-equal to the serial streaming sink fold: a
    /// position lives in exactly one shard, so its f32 op sequence is
    /// the serial one, and the shard merge stays a pure ascending-id
    /// concatenation (PERF.md shard-merge contract, extended to the
    /// parallel fold by `tests/neighborhood_secagg.rs`). Runs on
    /// [`ThreadPool::map_shared`], so it is safe at any pool size and
    /// the caller participates.
    fn fold_payloads_parallel(&mut self, m: usize, payloads: Vec<(u32, Vec<u8>)>) -> Result<()> {
        let shards = self.server_ws.sharded.shards();
        let tasks: Vec<Mutex<(u32, u32, Vec<f32>)>> = (0..shards)
            .map(|s| Mutex::new(self.server_ws.sharded.take_range_buf(s)))
            .collect();
        let quant = self.cfg.quant_bits.is_some();
        let payloads = Arc::new(payloads);
        let p = Arc::clone(&payloads);
        let outcomes = self.client_pool.map_shared(
            tasks,
            move |t: &Mutex<(u32, u32, Vec<f32>)>| {
                let t = &mut *t.lock().unwrap();
                let (start, end) = (t.0, t.1);
                let mut err: Option<String> = None;
                for (cid, bytes) in p.iter() {
                    let r = if quant {
                        crate::sparse::quant::fold_quant_range(bytes, start, end, &mut t.2)
                    } else {
                        crate::sparse::codec::fold_f32_range(bytes, start, end, &mut t.2)
                    };
                    match r {
                        Ok(n) if n as usize == m => {}
                        Ok(n) => {
                            err = Some(format!("client {cid} payload: dimension {n} != {m}"));
                            break;
                        }
                        Err(e) => {
                            err = Some(format!("client {cid} payload: {e}"));
                            break;
                        }
                    }
                }
                (std::mem::take(&mut t.2), err)
            },
        );
        let mut first_err = None;
        for (s, (buf, err)) in outcomes.into_iter().enumerate() {
            // buffers are moved back, never copied — the accumulator
            // stays warm for the next round
            self.server_ws.sharded.put_range_buf(s, buf);
            if first_err.is_none() {
                first_err = err;
            }
        }
        // best-effort wire-buffer recycle: a helper thread may still
        // hold the Arc for an instant after the last result lands, in
        // which case the buffers simply drop (k-sized, rare)
        if let Ok(payloads) = Arc::try_unwrap(payloads) {
            for (_, bytes) in payloads {
                self.client_workspaces.release_wire(bytes);
            }
        }
        match first_err {
            Some(e) => Err(anyhow!(e)),
            None => Ok(()),
        }
    }

    /// Phase 5 — the survivors' payloads are already folded into the
    /// sharded accumulator (streaming Collect); in secure mode, cancel
    /// the dead clients' orphaned pair masks using Shamir-recovered
    /// keys — recovery and cancellation walk only the dead clients'
    /// *neighborhoods* under a k-regular topology — then merge the
    /// shards (ascending shard id, pure concatenation) into the flat
    /// aggregate Apply consumes. The per-position f32 operation
    /// sequence is identical to the serial single-accumulator path, so
    /// the merged result is bitwise exact at any shard count (PERF.md
    /// shard-merge contract). `None` = recovery impossible → the
    /// caller aborts.
    fn phase_unmask_recover(
        &mut self,
        cohort: &Cohort,
        collected: &Collected,
    ) -> Option<Aggregated> {
        let m = self.global.len();
        let audit = self.cfg.secure && self.cfg.audit_secure_sum;
        {
            let ws = &mut self.server_ws;
            ws.plain.clear();
            if audit {
                ws.plain.resize(m, 0.0);
                for r in &collected.survivors {
                    if let Some(p) = r.plain.as_ref() {
                        for (acc, &v) in ws.plain.iter_mut().zip(p) {
                            *acc += v as f64;
                        }
                    }
                }
            }
        }

        let mut recovered_pairs = 0usize;
        if !collected.dead.is_empty() {
            // refcount bump so the secagg borrow does not pin `self`
            // across the mutable workspace destructure below
            if let Some(sec) = self.secagg.clone() {
                let survivor_ids: Vec<u32> =
                    collected.survivors.iter().map(|r| r.cid).collect();
                // a dead client only masked against its neighbors, so
                // both recovery and cancellation are restricted to its
                // neighborhood (complete topology → the full cohort,
                // the exact pre-neighborhood behavior)
                let topo = (!cohort.topology.is_complete()).then(|| &*cohort.topology);
                let recovered = if let Some(reg) = self.rekey.as_ref() {
                    // re-keyed material: a dead client's shares live
                    // only at its round neighbors; reconstruct its DH
                    // exponent and rederive the pair keys (the same
                    // bytes `pair_key_with` produces, so cancellation
                    // below is unchanged)
                    recover_pair_keys_rekeyed(
                        reg,
                        &sec.1,
                        &survivor_ids,
                        &collected.dead,
                        &cohort.topology,
                    )?
                } else {
                    recover_pair_keys_in(&sec.0, &sec.1, &survivor_ids, &collected.dead, topo)?
                };
                recovered_pairs = recovered.len();
                let Trainer { server_ws, client_pool, mask_cache, .. } = self;
                let sharded = &mut server_ws.sharded;
                sec.1.cancel_dead_masks_pooled_sink(
                    client_pool,
                    // the surviving endpoint of each pair usually built
                    // this stream already this round — recovery is
                    // mostly cache hits
                    Some(mask_cache),
                    m,
                    cohort.round,
                    &survivor_ids,
                    &collected.dead,
                    &recovered,
                    cohort.topology.participants(),
                    topo,
                    |i, x| sharded.sub_at(i, x),
                );
            }
        }
        // shard-merge: ascending shard id, pure concatenation — never
        // an f32 addition
        let ServerWorkspace { sharded, agg, .. } = &mut self.server_ws;
        sharded.merge_into(agg);
        Some(Aggregated { recovered_pairs })
    }

    /// Phase 6 — commit the survivors' evolved state, roll failed
    /// clients back to their snapshots, and take the FedAvg step over
    /// the survivor mean. Returns the per-survivor reporting rows plus
    /// the failure lists and barrier time moved out of `collected`.
    fn phase_apply(
        &mut self,
        collected: Collected,
        mut snapshots: HashMap<u32, ClientSnapshot>,
    ) -> (RoundScratch, Vec<u32>, Vec<u32>, f64) {
        let mut scratch = RoundScratch::default();
        scratch.framed = collected.framed;
        for r in collected.survivors {
            let cs = &mut self.clients[r.cid as usize];
            cs.commit_round(
                r.residual_prev,
                r.residual,
                r.rate,
                r.momentum_prev,
                r.momentum,
                r.mean_loss,
            );
            scratch.survivors.push(r.cid);
            scratch.loss_sum += r.mean_loss;
            scratch.rate_sum += r.nnz_rate;
            scratch.nnz.push(r.nnz);
            scratch.wire.push(r.wire);
        }
        for r in collected.rolled_back {
            let snap = snapshots.remove(&r.cid).expect("failed client has a snapshot");
            let cs = &mut self.clients[r.cid as usize];
            // the evolved residual/velocity are discarded, but their
            // buffers are recycled so the client's next round stays
            // allocation-free
            cs.reclaim_spare(r.residual, r.momentum);
            cs.restore(snap);
        }
        // FedAvg mean over the *surviving* cohort. Copy-on-write: the
        // round's pipeline clones of the global Arc are dropped by now,
        // so `make_mut` updates in place (no model-sized copy).
        let scale = 1.0 / scratch.survivors.len() as f32;
        let Trainer { global, server_ws, .. } = self;
        Arc::make_mut(global).apply_update(&server_ws.agg, scale);
        (scratch, collected.dropped, collected.stragglers, collected.round_time_s)
    }

    /// Abort path: fewer than `min_survivors` uploads (or orphaned
    /// masks without a Shamir quorum). Everything rolls back — global
    /// untouched, every selected client restored — but the bytes that
    /// did cross the wire are still metered, and the round is recorded
    /// (eval/accuracy NaN) so traces keep one row per round.
    fn abort_round(
        &mut self,
        cohort: Cohort,
        collected: Collected,
        mut snapshots: HashMap<u32, ClientSnapshot>,
        timings: PhaseTimings,
    ) -> RoundOutcome {
        let m = self.global.len();
        let mut survivors = Vec::new();
        let mut nnz = Vec::new();
        let mut wire = Vec::new();
        let mut loss_sum = 0f64;
        for r in collected.survivors {
            survivors.push(r.cid);
            nnz.push(r.nnz);
            wire.push(r.wire);
            loss_sum += r.mean_loss;
            // nothing commits on abort, but the evolved-residual (and
            // velocity) buffers are still recycled (allocation-free
            // next round)
            self.clients[r.cid as usize].reclaim_spare(r.residual, r.momentum);
        }
        for r in collected.rolled_back {
            self.clients[r.cid as usize].reclaim_spare(r.residual, r.momentum);
        }
        // every selected client — delivered or not — rolls back (aborts
        // only happen under failure injection, so snapshots exist)
        for &cid in &cohort.selected {
            let snap = snapshots.remove(&cid).expect("abort requires snapshots");
            self.clients[cid as usize].restore(snap);
        }
        let mean_train_loss =
            if survivors.is_empty() { f64::NAN } else { loss_sum / survivors.len() as f64 };

        let ups: Vec<u64> = nnz
            .iter()
            .map(|&n| self.cfg.algorithm.paper_cost_bytes(n, m, self.cfg.quant_bits))
            .collect();
        self.ledger.record_with_costs(cohort.round, &ups, &wire, collected.framed, f64::NAN);
        let rc = self.ledger.rounds.last().unwrap();
        self.recorder.push(RoundRecord {
            round: cohort.round,
            train_loss: mean_train_loss,
            eval_loss: f64::NAN,
            eval_accuracy: f64::NAN,
            up_bytes: rc.up_paper,
            wire_bytes: rc.up_wire,
            sim_time_s: collected.round_time_s,
            mean_rate: f64::NAN,
            survivors: survivors.len(),
            recovered: 0,
            timings,
        });

        RoundOutcome {
            round: cohort.round,
            selected: cohort.selected,
            survivors,
            dropped: collected.dropped,
            stragglers: collected.stragglers,
            aborted: true,
            recovered_pairs: 0,
            mean_train_loss,
            nnz,
            wire_bytes: wire,
            eval: None,
            aggregate: Vec::new(),
            plain_sum: None,
            timings,
        }
    }
}
