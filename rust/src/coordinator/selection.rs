//! Per-round client selection — `C·K` of `N` uniformly at random
//! (McMahan'17 setting the paper follows: C=0.1, K=100 → 10).
//!
//! Selection is a pure function of (seed, round) so any round of any
//! run can be replayed exactly.

use crate::util::rng::Rng;

/// Select `k` distinct client ids for `round`.
pub fn select_clients(n_clients: usize, k: usize, seed: u64, round: u64) -> Vec<u32> {
    assert!(k <= n_clients, "select {k} of {n_clients}");
    let mut rng = Rng::new(seed ^ 0x5e1e_c700u64 ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut ids = rng.sample_indices(n_clients, k);
    ids.sort_unstable();
    ids.into_iter().map(|i| i as u32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_round() {
        assert_eq!(select_clients(100, 10, 1, 5), select_clients(100, 10, 1, 5));
        assert_ne!(select_clients(100, 10, 1, 5), select_clients(100, 10, 1, 6));
    }

    #[test]
    fn distinct_and_in_range() {
        let sel = select_clients(100, 10, 2, 0);
        assert_eq!(sel.len(), 10);
        let mut s = sel.clone();
        s.dedup();
        assert_eq!(s.len(), 10);
        assert!(sel.iter().all(|&c| c < 100));
    }

    #[test]
    fn coverage_over_many_rounds() {
        // every client should get selected eventually
        let mut seen = vec![false; 20];
        for r in 0..200 {
            for c in select_clients(20, 4, 3, r) {
                seen[c as usize] = true;
            }
        }
        assert!(seen.iter().all(|&b| b), "{seen:?}");
    }

    #[test]
    fn full_selection() {
        let sel = select_clients(5, 5, 4, 1);
        assert_eq!(sel, vec![0, 1, 2, 3, 4]);
    }
}
