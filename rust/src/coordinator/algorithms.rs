//! The update-compression algorithms under test (§5's contenders).

use crate::sparse::flat::{flat_topk_sparsify_into, SparsifyOut};
use crate::sparse::thgs::{thgs_sparsify_into, ThgsConfig};

/// Which client-update algorithm a run uses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Algorithm {
    /// McMahan'17 — dense updates (the paper's main baseline).
    FedAvg,
    /// Li'20 — dense updates + proximal term μ (Table 2 baseline).
    FedProx { mu: f32 },
    /// Dryden'16 — single global Top-k over the flat update
    /// (the paper's "- spark" contender in Fig. 3).
    FlatSparse { s: f64 },
    /// The paper's contribution (Alg. 1): per-layer Top-k with
    /// layer-decaying rate ("- layerspares" in Fig. 3).
    Thgs(ThgsConfig),
    /// Sattler'19 sparse ternary compression (§2.1 contender; used by
    /// the ablation harness).
    Stc { s: f64 },
}

impl Algorithm {
    pub fn is_sparse(&self) -> bool {
        matches!(
            self,
            Algorithm::FlatSparse { .. } | Algorithm::Thgs(_) | Algorithm::Stc { .. }
        )
    }

    pub fn is_fedprox(&self) -> Option<f32> {
        match self {
            Algorithm::FedProx { mu } => Some(*mu),
            _ => None,
        }
    }

    /// Parse CLI form: `fedavg`, `fedprox:0.01`, `flat:0.01`,
    /// `thgs:0.1,0.8,0.01` (s0, α, s_min).
    pub fn parse(s: &str) -> Option<Self> {
        let (head, args) = match s.split_once(':') {
            Some((h, a)) => (h, a),
            None => (s, ""),
        };
        match head {
            "fedavg" => Some(Algorithm::FedAvg),
            "fedprox" => Some(Algorithm::FedProx {
                mu: if args.is_empty() { 0.01 } else { args.parse().ok()? },
            }),
            "flat" | "spark" => Some(Algorithm::FlatSparse {
                s: if args.is_empty() { 0.01 } else { args.parse().ok()? },
            }),
            "stc" => Some(Algorithm::Stc {
                s: if args.is_empty() { 0.01 } else { args.parse().ok()? },
            }),
            "thgs" | "layerspares" => {
                if args.is_empty() {
                    return Some(Algorithm::Thgs(ThgsConfig::default()));
                }
                let parts: Vec<&str> = args.split(',').collect();
                if parts.len() != 3 {
                    return None;
                }
                Some(Algorithm::Thgs(ThgsConfig {
                    s0: parts[0].parse().ok()?,
                    alpha: parts[1].parse().ok()?,
                    s_min: parts[2].parse().ok()?,
                }))
            }
            _ => None,
        }
    }

    pub fn label(&self) -> String {
        match self {
            Algorithm::FedAvg => "fedavg".into(),
            Algorithm::FedProx { mu } => format!("fedprox-mu{mu}"),
            Algorithm::FlatSparse { s } => format!("flat-s{s}"),
            Algorithm::Thgs(t) => format!("thgs-s{}-a{}", t.s0, t.alpha),
            Algorithm::Stc { s } => format!("stc-s{s}"),
        }
    }

    /// The CLI/config-file spec form — unlike [`Self::label`], this
    /// round-trips exactly through [`Self::parse`] (f32/f64 `Display`
    /// is shortest-round-trip in Rust).
    pub fn spec(&self) -> String {
        match self {
            Algorithm::FedAvg => "fedavg".into(),
            Algorithm::FedProx { mu } => format!("fedprox:{mu}"),
            Algorithm::FlatSparse { s } => format!("flat:{s}"),
            Algorithm::Thgs(t) => format!("thgs:{},{},{}", t.s0, t.alpha, t.s_min),
            Algorithm::Stc { s } => format!("stc:{s}"),
        }
    }

    /// Paper-model upload cost of one client's update under this
    /// algorithm (Eq. 6 / STC codebook form).
    pub fn paper_cost_bytes(&self, nnz: usize, m: usize, quant_bits: Option<u8>) -> u64 {
        use crate::sparse::{codec, quant, stc};
        match self {
            Algorithm::FedAvg | Algorithm::FedProx { .. } => codec::dense_cost_bytes(m),
            Algorithm::Stc { .. } => stc::stc_cost_bytes(nnz),
            _ => match quant_bits {
                Some(b) => quant::quant_cost_bytes(nnz, b),
                None => codec::sparse_cost_bytes(nnz),
            },
        }
    }

    /// Apply the algorithm's sparsifier to an update vector.
    /// `rate_scale` multiplies the configured rate (the Eq. 2 dynamic
    /// controller's output relative to the configured base; 1.0 when
    /// static). Dense algorithms return a trivial all-kept split.
    pub fn sparsify(
        &self,
        update: &[f32],
        layer_spans: &[(usize, usize)],
        rate_scale: f64,
    ) -> SparsifyOut {
        let mut out = SparsifyOut::default();
        self.sparsify_into(update, layer_spans, rate_scale, &mut Vec::new(), &mut out);
        out
    }

    /// [`Self::sparsify`] into caller-owned scratch + output — the
    /// round engine's zero-allocation path (`scratch` feeds the Top-k
    /// magnitude selection; every contender, STC included, reuses the
    /// caller's buffers).
    pub fn sparsify_into(
        &self,
        update: &[f32],
        layer_spans: &[(usize, usize)],
        rate_scale: f64,
        scratch: &mut Vec<f32>,
        out: &mut SparsifyOut,
    ) {
        match self {
            Algorithm::FedAvg | Algorithm::FedProx { .. } => {
                out.sparse.clear();
                out.sparse.extend_from_slice(update);
                out.residual.clear();
                out.residual.resize(update.len(), 0.0);
                out.nnz = update.len();
                out.thresholds.clear();
                out.thresholds.push(0.0);
            }
            Algorithm::FlatSparse { s } => {
                flat_topk_sparsify_into(update, (s * rate_scale).clamp(1e-9, 1.0), scratch, out)
            }
            Algorithm::Thgs(t) => {
                let cfg = ThgsConfig {
                    s0: (t.s0 * rate_scale).clamp(t.s_min.min(1e-9), 1.0),
                    ..*t
                };
                thgs_sparsify_into(update, layer_spans, &cfg, scratch, out)
            }
            Algorithm::Stc { s } => {
                // μ ships implicitly in the ternary values; the cost
                // model recovers it via `stc_cost_bytes`
                crate::sparse::stc::stc_sparsify_into(
                    update,
                    (s * rate_scale).clamp(1e-9, 1.0),
                    scratch,
                    out,
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_forms() {
        assert_eq!(Algorithm::parse("fedavg"), Some(Algorithm::FedAvg));
        assert_eq!(
            Algorithm::parse("fedprox:0.05"),
            Some(Algorithm::FedProx { mu: 0.05 })
        );
        assert_eq!(
            Algorithm::parse("flat:0.001"),
            Some(Algorithm::FlatSparse { s: 0.001 })
        );
        match Algorithm::parse("thgs:0.2,0.5,0.02") {
            Some(Algorithm::Thgs(t)) => {
                assert_eq!(t.s0, 0.2);
                assert_eq!(t.alpha, 0.5);
                assert_eq!(t.s_min, 0.02);
            }
            other => panic!("{other:?}"),
        }
        assert!(Algorithm::parse("nope").is_none());
        assert!(Algorithm::parse("thgs:1,2").is_none());
    }

    #[test]
    fn dense_passthrough() {
        let u = vec![1.0f32, -2.0, 0.5];
        let out = Algorithm::FedAvg.sparsify(&u, &[(0, 3)], 1.0);
        assert_eq!(out.sparse, u);
        assert_eq!(out.nnz, 3);
        assert!(out.residual.iter().all(|&r| r == 0.0));
    }

    #[test]
    fn sparse_split_exact() {
        let u: Vec<f32> = (0..1000).map(|i| ((i * 37) % 101) as f32 - 50.0).collect();
        for alg in [
            Algorithm::FlatSparse { s: 0.05 },
            Algorithm::Thgs(ThgsConfig { s0: 0.1, alpha: 0.5, s_min: 0.01 }),
        ] {
            let out = alg.sparsify(&u, &[(0, 600), (600, 400)], 1.0);
            for i in 0..u.len() {
                assert_eq!(out.sparse[i] + out.residual[i], u[i]);
            }
            assert!(out.nnz < u.len());
        }
    }

    #[test]
    fn rate_scale_shrinks_nnz() {
        let u: Vec<f32> = (0..10_000).map(|i| ((i * 7919) % 997) as f32 / 997.0 - 0.5).collect();
        let alg = Algorithm::FlatSparse { s: 0.1 };
        let full = alg.sparsify(&u, &[(0, u.len())], 1.0).nnz;
        let half = alg.sparsify(&u, &[(0, u.len())], 0.5).nnz;
        assert!(half < full, "half={half} full={full}");
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for alg in [
            Algorithm::FedAvg,
            Algorithm::FlatSparse { s: 0.01 },
            Algorithm::Thgs(ThgsConfig::default()),
        ] {
            assert!(alg.label().len() > 3);
        }
    }

    #[test]
    fn spec_round_trips_through_parse() {
        for alg in [
            Algorithm::FedAvg,
            Algorithm::FedProx { mu: 0.035 },
            Algorithm::FlatSparse { s: 0.001 },
            Algorithm::Thgs(ThgsConfig { s0: 0.2, alpha: 0.55, s_min: 0.015 }),
            Algorithm::Thgs(ThgsConfig::default()),
            Algorithm::Stc { s: 0.07 },
        ] {
            assert_eq!(Algorithm::parse(&alg.spec()), Some(alg), "spec {}", alg.spec());
        }
    }
}
