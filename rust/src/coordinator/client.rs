//! Per-client persistent state across rounds.
//!
//! The round engine moves the mutable pieces (residual store, Eq. 2
//! rate controller, DGC momentum) *into* the per-client pipeline job
//! and commits them back on success — [`ClientState::take_round_state`]
//! / [`ClientState::commit_round`]. When transport failure injection is
//! on, a [`ClientSnapshot`] taken before dispatch lets a dropped or
//! timed-out client roll back as if it had never been selected.

use crate::sparse::dynamic::DynamicRate;
use crate::sparse::momentum::MomentumCorrector;
use crate::sparse::residual::ResidualStore;

/// One simulated federated participant.
#[derive(Clone, Debug)]
pub struct ClientState {
    pub id: u32,
    /// Indices into the train split this client owns.
    pub data: Vec<usize>,
    /// Residual accumulation (Alg. 1 line 12).
    pub residual: ResidualStore,
    /// Eq. 2 controller (None when static rates are used).
    pub rate: Option<DynamicRate>,
    /// DGC momentum corrector (None when momentum = 0).
    pub momentum: Option<MomentumCorrector>,
    /// Mean local training loss of the last participating round.
    pub last_loss: f64,
    /// Rounds this client was selected AND delivered (diagnostics).
    pub participation: u64,
}

/// Pre-round copy of the mutable client state. Restored when the
/// transport reports the client failed mid-round: from the client's
/// point of view the round never happened (its update was lost in
/// flight, so neither the residual split nor the rate/momentum
/// controllers may advance).
#[derive(Clone, Debug)]
pub struct ClientSnapshot {
    residual: ResidualStore,
    rate: Option<DynamicRate>,
    momentum: Option<MomentumCorrector>,
}

impl ClientState {
    pub fn new(id: u32, data: Vec<usize>, model_params: usize) -> Self {
        Self {
            id,
            data,
            residual: ResidualStore::new(model_params),
            rate: None,
            momentum: None,
            last_loss: f64::NAN,
            participation: 0,
        }
    }

    /// Attach the Eq. 2 dynamic rate controller.
    pub fn with_dynamic_rate(mut self, r0: f64, alpha: f64, total_rounds: u64, r_min: f64) -> Self {
        self.rate = Some(DynamicRate::new(r0, alpha, total_rounds, r_min));
        self
    }

    /// Copy the mutable round state (call *before*
    /// [`Self::take_round_state`]; only needed under failure injection).
    pub fn snapshot(&self) -> ClientSnapshot {
        ClientSnapshot {
            residual: self.residual.clone(),
            rate: self.rate.clone(),
            momentum: self.momentum.clone(),
        }
    }

    /// Roll back to a pre-round snapshot (failed delivery / aborted
    /// round). Participation and loss history are untouched — they only
    /// ever advance in [`Self::commit_round`].
    pub fn restore(&mut self, snap: ClientSnapshot) {
        self.residual = snap.residual;
        self.rate = snap.rate;
        self.momentum = snap.momentum;
    }

    /// Move the mutable state into a round job (cheap: leaves empties
    /// behind; the state comes back via [`Self::commit_round`] or
    /// [`Self::restore`]).
    pub fn take_round_state(
        &mut self,
    ) -> (ResidualStore, Option<DynamicRate>, Option<MomentumCorrector>) {
        (
            std::mem::replace(&mut self.residual, ResidualStore::new(0)),
            self.rate.take(),
            self.momentum.take(),
        )
    }

    /// Commit a delivered round: hand the evolved state back and do the
    /// participation bookkeeping. This is the *single* owner of
    /// participation/loss accounting — nothing else increments it.
    pub fn commit_round(
        &mut self,
        residual: ResidualStore,
        rate: Option<DynamicRate>,
        momentum: Option<MomentumCorrector>,
        mean_loss: f64,
    ) {
        self.residual = residual;
        self.rate = rate;
        self.momentum = momentum;
        self.last_loss = mean_loss;
        self.participation += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_round_owns_participation() {
        let mut c = ClientState::new(0, vec![1, 2, 3], 10);
        let (residual, rate, momentum) = c.take_round_state();
        assert_eq!(c.residual.len(), 0, "state moved out");
        c.commit_round(residual, rate, momentum, 1.25);
        assert_eq!(c.participation, 1);
        assert_eq!(c.last_loss, 1.25);
        assert_eq!(c.residual.len(), 10, "state moved back");
    }

    #[test]
    fn restore_rolls_back_everything_but_history() {
        let mut c = ClientState::new(1, vec![], 4).with_dynamic_rate(0.1, 0.8, 100, 0.01);
        c.residual.store(&[1.0, 0.0, 2.0, 0.0]);
        c.last_loss = 3.0;
        c.participation = 5;
        let snap = c.snapshot();

        // a failed round: state moved out, evolved elsewhere, lost
        let (mut residual, _, _) = c.take_round_state();
        residual.store(&[0.0; 4]);
        c.restore(snap);

        assert_eq!(c.residual.as_slice().to_vec(), vec![1.0, 0.0, 2.0, 0.0]);
        assert!(c.rate.is_some(), "controller restored");
        // history only moves through commit_round
        assert_eq!(c.participation, 5);
        assert_eq!(c.last_loss, 3.0);
    }

    #[test]
    fn dynamic_rate_controller_survives_commit_cycle() {
        let mut c = ClientState::new(2, vec![], 8).with_dynamic_rate(0.1, 0.8, 100, 0.01);
        for t in 0..3 {
            let (residual, mut rate, momentum) = c.take_round_state();
            if let Some(ctrl) = &mut rate {
                ctrl.observe(t, 2.0);
            }
            c.commit_round(residual, rate, momentum, 2.0);
        }
        assert_eq!(c.participation, 3);
        assert!(c.rate.is_some());
    }

    #[test]
    fn residual_sized_to_model() {
        let c = ClientState::new(2, vec![], 123);
        assert_eq!(c.residual.len(), 123);
    }
}
