//! Per-client persistent state across rounds.
//!
//! The round engine moves the mutable pieces (residual store, Eq. 2
//! rate controller, DGC momentum) *into* the per-client pipeline job
//! and commits them back on success — [`ClientState::take_round_state`]
//! / [`ClientState::commit_round`]. When transport failure injection is
//! on, a [`ClientSnapshot`] taken before dispatch lets a dropped or
//! timed-out client roll back as if it had never been selected.
//!
//! ## Copy-on-write snapshots (double-buffered residuals)
//!
//! The residual store — the one model-sized piece of per-client state
//! — lives behind an `Arc`, so [`ClientState::snapshot`] is a refcount
//! bump, not a model-sized copy. The round job never mutates the
//! pre-round store: it *reads* it (fold-in, staleness counters) and
//! writes the evolved residual into a recycled spare store
//! ([`crate::sparse::residual::ResidualStore::store_from`]). At commit
//! the two stores swap roles — the spare becomes the live store and
//! the pre-round store is reclaimed as the next spare once the round's
//! snapshots release it (`retired` holds it for exactly that gap). Net
//! effect: failure-injection runs take per-cohort snapshots every
//! round without ever paying a model-sized copy or allocation in
//! steady state (pinned by `tests/alloc_steady_state.rs`), at the cost
//! of each client owning two model-sized stores instead of one.
//!
//! The DGC momentum corrector (optional, off by default) gets the
//! identical treatment: the velocity lives behind an `Arc`, the round
//! job advances it into a recycled spare corrector
//! ([`crate::sparse::momentum::MomentumCorrector::correct_from`]), and
//! commit swaps the buffers — so momentum + failure injection rounds
//! are also snapshot-copy-free. The rate controller is a few scalars
//! and is still cloned.

use std::sync::Arc;

use crate::sparse::dynamic::DynamicRate;
use crate::sparse::momentum::MomentumCorrector;
use crate::sparse::residual::ResidualStore;

/// One simulated federated participant.
#[derive(Clone, Debug)]
pub struct ClientState {
    pub id: u32,
    /// Indices into the train split this client owns.
    pub data: Vec<usize>,
    /// Residual accumulation (Alg. 1 line 12). `Arc`'d so rollback
    /// snapshots are refcount bumps (module docs); the round job reads
    /// it and writes the evolved state into the recycled spare.
    pub residual: Arc<ResidualStore>,
    /// The write target handed to the next round job (the double-buffer
    /// twin of `residual`, same size once warm).
    spare: Option<ResidualStore>,
    /// Pre-round store retired at the last commit while a rollback
    /// snapshot still referenced it; reclaimed as the next `spare` once
    /// its refcount drops back to one.
    retired: Option<Arc<ResidualStore>>,
    /// Eq. 2 controller (None when static rates are used).
    pub rate: Option<DynamicRate>,
    /// DGC momentum corrector (None when momentum = 0). `Arc`'d like
    /// the residual: snapshots share it, the round job reads it and
    /// writes the advanced velocity into the recycled spare.
    pub momentum: Option<Arc<MomentumCorrector>>,
    /// The momentum write target handed to the next round job (the
    /// double-buffer twin of `momentum`).
    momentum_spare: Option<MomentumCorrector>,
    /// Pre-round corrector retired at the last commit while a rollback
    /// snapshot still referenced it (see `retired`).
    momentum_retired: Option<Arc<MomentumCorrector>>,
    /// Mean local training loss of the last participating round.
    pub last_loss: f64,
    /// Rounds this client was selected AND delivered (diagnostics).
    pub participation: u64,
}

/// The mutable round inputs [`ClientState::take_round_state`] moves
/// into a round job: the shared pre-round stores (read-only from the
/// job's perspective) plus recycled write targets for the evolved
/// state.
pub struct RoundState {
    /// Pre-round residual (shared with snapshots; never mutated).
    pub residual: Arc<ResidualStore>,
    /// Recycled write target for the evolved residual.
    pub fresh: ResidualStore,
    pub rate: Option<DynamicRate>,
    /// Pre-round momentum corrector (shared with snapshots).
    pub momentum: Option<Arc<MomentumCorrector>>,
    /// Recycled write target for the advanced velocity; `Some` exactly
    /// when `momentum` is.
    pub momentum_fresh: Option<MomentumCorrector>,
}

/// Pre-round view of the mutable client state, restored when the
/// transport reports the client failed mid-round: from the client's
/// point of view the round never happened (its update was lost in
/// flight, so neither the residual split nor the rate/momentum
/// controllers may advance). Taking one is O(1) in the model size —
/// the residual is shared by `Arc`, never copied (module docs).
#[derive(Clone, Debug)]
pub struct ClientSnapshot {
    residual: Arc<ResidualStore>,
    rate: Option<DynamicRate>,
    momentum: Option<Arc<MomentumCorrector>>,
}

impl ClientState {
    pub fn new(id: u32, data: Vec<usize>, model_params: usize) -> Self {
        Self {
            id,
            data,
            residual: Arc::new(ResidualStore::new(model_params)),
            // pre-size the write target so a client first selected
            // mid-run does not allocate on the steady-state round path
            spare: Some(ResidualStore::new(model_params)),
            retired: None,
            rate: None,
            momentum: None,
            momentum_spare: None,
            momentum_retired: None,
            last_loss: f64::NAN,
            participation: 0,
        }
    }

    /// Attach the Eq. 2 dynamic rate controller.
    pub fn with_dynamic_rate(mut self, r0: f64, alpha: f64, total_rounds: u64, r_min: f64) -> Self {
        self.rate = Some(DynamicRate::new(r0, alpha, total_rounds, r_min));
        self
    }

    /// Attach the DGC momentum corrector, pre-sizing its double-buffer
    /// twin so the steady-state round path stays allocation-free.
    pub fn enable_momentum(&mut self, model_params: usize, coeff: f32) {
        self.momentum = Some(Arc::new(MomentumCorrector::new(model_params, coeff)));
        self.momentum_spare = Some(MomentumCorrector::new(model_params, coeff));
    }

    /// Capture the pre-round state (call *before*
    /// [`Self::take_round_state`]; only needed under failure
    /// injection). O(1) in the model size: the residual and the
    /// momentum corrector are shared by `Arc`, the rate controller is a
    /// few cloned scalars — no model-sized copies.
    pub fn snapshot(&self) -> ClientSnapshot {
        ClientSnapshot {
            residual: Arc::clone(&self.residual),
            rate: self.rate.clone(),
            momentum: self.momentum.clone(),
        }
    }

    /// Roll back to a pre-round snapshot (failed delivery / aborted
    /// round). Participation and loss history are untouched — they only
    /// ever advance in [`Self::commit_round`].
    pub fn restore(&mut self, snap: ClientSnapshot) {
        self.residual = snap.residual;
        self.rate = snap.rate;
        self.momentum = snap.momentum;
    }

    /// Recycle unused round write targets (the job of a rolled-back
    /// or aborted client evolved state that will never be committed)
    /// so the next selection of this client stays allocation-free.
    pub fn reclaim_spare(&mut self, store: ResidualStore, momentum: Option<MomentumCorrector>) {
        self.spare = Some(store);
        if momentum.is_some() {
            self.momentum_spare = momentum;
        }
    }

    /// Move the round inputs into a round job: the pre-round residual
    /// and momentum corrector (shared, read-only from the job's
    /// perspective), recycled write targets for the evolved state, and
    /// the rate controller (cheap: leaves empties behind; the state
    /// comes back via [`Self::commit_round`] or [`Self::restore`]).
    pub fn take_round_state(&mut self) -> RoundState {
        let residual = std::mem::replace(&mut self.residual, Arc::new(ResidualStore::new(0)));
        let fresh = match self.spare.take() {
            Some(s) => s,
            // reclaim the store retired at the last commit — by now the
            // snapshots that pinned it are gone (previous round ended)
            None => match self.retired.take() {
                Some(arc) => match Arc::try_unwrap(arc) {
                    Ok(s) => s,
                    Err(arc) => {
                        // still referenced (unusual — a caller kept a
                        // snapshot across rounds): leave it parked and
                        // pay a one-off grow in the job instead
                        self.retired = Some(arc);
                        ResidualStore::new(0)
                    }
                },
                None => ResidualStore::new(0),
            },
        };
        let momentum = self.momentum.take();
        // same spare → retired → fresh-alloc ladder for the velocity
        // (`correct_from` adapts the write target's size, so the rare
        // fallback is an empty corrector that grows once in the job)
        let momentum_fresh = momentum.as_ref().map(|prev| {
            self.momentum_spare
                .take()
                .or_else(|| {
                    self.momentum_retired.take().and_then(|arc| match Arc::try_unwrap(arc) {
                        Ok(mc) => Some(mc),
                        Err(arc) => {
                            self.momentum_retired = Some(arc);
                            None
                        }
                    })
                })
                .unwrap_or_else(|| MomentumCorrector::new(0, prev.momentum))
        });
        RoundState { residual, fresh, rate: self.rate.take(), momentum, momentum_fresh }
    }

    /// Commit a delivered round: the evolved stores (`residual`,
    /// `momentum`) become the live state, the pre-round stores are
    /// recycled as the next write targets — immediately when nothing
    /// else references them, or via the retired slots until the
    /// round's rollback snapshots drop. This is the *single* owner of
    /// participation/loss accounting — nothing else increments it.
    pub fn commit_round(
        &mut self,
        prev: Arc<ResidualStore>,
        residual: ResidualStore,
        rate: Option<DynamicRate>,
        momentum_prev: Option<Arc<MomentumCorrector>>,
        momentum: Option<MomentumCorrector>,
        mean_loss: f64,
    ) {
        self.residual = Arc::new(residual);
        match Arc::try_unwrap(prev) {
            Ok(s) => self.spare = Some(s),
            Err(arc) => self.retired = Some(arc),
        }
        self.rate = rate;
        self.momentum = momentum.map(Arc::new);
        if let Some(arc) = momentum_prev {
            match Arc::try_unwrap(arc) {
                Ok(mc) => self.momentum_spare = Some(mc),
                Err(arc) => self.momentum_retired = Some(arc),
            }
        }
        self.last_loss = mean_loss;
        self.participation += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commit_round_owns_participation() {
        let mut c = ClientState::new(0, vec![1, 2, 3], 10);
        let mut st = c.take_round_state();
        assert_eq!(c.residual.len(), 0, "state moved out");
        st.fresh.store_from(&st.residual, &[0.5; 10]);
        c.commit_round(st.residual, st.fresh, st.rate, st.momentum, st.momentum_fresh, 1.25);
        assert_eq!(c.participation, 1);
        assert_eq!(c.last_loss, 1.25);
        assert_eq!(c.residual.len(), 10, "state moved back");
        assert_eq!(c.residual.as_slice(), &[0.5f32; 10][..]);
    }

    #[test]
    fn snapshot_is_a_refcount_bump_and_restores() {
        let mut c = ClientState::new(1, vec![], 4).with_dynamic_rate(0.1, 0.8, 100, 0.01);
        Arc::make_mut(&mut c.residual).store(&[1.0, 0.0, 2.0, 0.0]);
        c.last_loss = 3.0;
        c.participation = 5;
        let snap = c.snapshot();
        assert!(
            Arc::ptr_eq(&snap.residual, &c.residual),
            "snapshot shares the store instead of copying it"
        );

        // a failed round: state moved out, evolved into the spare, lost
        let mut st = c.take_round_state();
        st.fresh.store_from(&st.residual, &[0.0; 4]);
        c.reclaim_spare(st.fresh, st.momentum_fresh);
        drop(st.residual);
        c.restore(snap);

        assert_eq!(c.residual.as_slice().to_vec(), vec![1.0, 0.0, 2.0, 0.0]);
        assert!(c.rate.is_some(), "controller restored");
        // history only moves through commit_round
        assert_eq!(c.participation, 5);
        assert_eq!(c.last_loss, 3.0);
    }

    #[test]
    fn double_buffer_recycles_without_snapshots() {
        let mut c = ClientState::new(2, vec![], 8);
        for t in 0..4 {
            let mut st = c.take_round_state();
            st.fresh.store_from(&st.residual, &[t as f32; 8]);
            c.commit_round(st.residual, st.fresh, st.rate, st.momentum, st.momentum_fresh, t as f64);
            assert!(c.spare.is_some(), "round {t}: prev recycled immediately");
            assert!(c.retired.is_none(), "round {t}: nothing parked");
            assert_eq!(c.residual.as_slice(), &[t as f32; 8][..]);
        }
    }

    #[test]
    fn double_buffer_parks_prev_while_snapshot_lives() {
        let mut c = ClientState::new(3, vec![], 8);
        // round A: snapshot held across commit (the engine holds the
        // cohort's snapshots until the round ends)
        let snap = c.snapshot();
        let mut st = c.take_round_state();
        st.fresh.store_from(&st.residual, &[1.0; 8]);
        c.commit_round(st.residual, st.fresh, st.rate, st.momentum, st.momentum_fresh, 0.0);
        assert!(c.spare.is_none(), "prev still pinned by the snapshot");
        assert!(c.retired.is_some(), "prev parked for later reclaim");
        // round ends: snapshots drop, round B reclaims the parked store
        drop(snap);
        let st = c.take_round_state();
        assert_eq!(st.fresh.len(), 8, "parked store reclaimed, not a fresh alloc");
        c.commit_round(st.residual, st.fresh, st.rate, st.momentum, st.momentum_fresh, 0.0);
    }

    #[test]
    fn dynamic_rate_controller_survives_commit_cycle() {
        let mut c = ClientState::new(2, vec![], 8).with_dynamic_rate(0.1, 0.8, 100, 0.01);
        for t in 0..3 {
            let mut st = c.take_round_state();
            if let Some(ctrl) = &mut st.rate {
                ctrl.observe(t, 2.0);
            }
            st.fresh.store_from(&st.residual, &[0.0; 8]);
            c.commit_round(st.residual, st.fresh, st.rate, st.momentum, st.momentum_fresh, 2.0);
        }
        assert_eq!(c.participation, 3);
        assert!(c.rate.is_some());
    }

    #[test]
    fn momentum_snapshot_is_a_refcount_bump_and_double_buffers() {
        let mut c = ClientState::new(4, vec![], 4);
        c.enable_momentum(4, 0.5);
        // failure-injection shape: the snapshot is held across commit
        let snap = c.snapshot();
        assert!(
            Arc::ptr_eq(snap.momentum.as_ref().unwrap(), c.momentum.as_ref().unwrap()),
            "snapshot shares the corrector instead of deep-copying it"
        );
        let mut st = c.take_round_state();
        let mut g = [1.0f32; 4];
        let mut fresh_mc = st.momentum_fresh.take().unwrap();
        fresh_mc.correct_from(st.momentum.as_ref().unwrap(), &mut g);
        assert_eq!(g, [1.0; 4], "first round: velocity == g");
        st.fresh.store_from(&st.residual, &[0.0; 4]);
        c.commit_round(st.residual, st.fresh, st.rate, st.momentum, Some(fresh_mc), 0.0);
        assert!(c.momentum_spare.is_none(), "prev corrector pinned by the snapshot");
        assert!(c.momentum_retired.is_some(), "prev corrector parked for later reclaim");
        // the snapshot drops at round end; the next take reclaims the
        // parked corrector instead of allocating
        drop(snap);
        let st = c.take_round_state();
        assert!(c.momentum_retired.is_none(), "parked corrector reclaimed");
        let mut g = [1.0f32; 4];
        let mut fresh_mc = st.momentum_fresh.unwrap();
        fresh_mc.correct_from(st.momentum.as_ref().unwrap(), &mut g);
        assert_eq!(g, [1.5; 4], "velocity advanced: 0.5·1 + 1");
        c.commit_round(st.residual, st.fresh, st.rate, st.momentum, Some(fresh_mc), 0.0);
        assert!(c.momentum_spare.is_some(), "no snapshot → prev recycled immediately");
    }

    #[test]
    fn momentum_restore_rolls_back_velocity() {
        let mut c = ClientState::new(5, vec![], 2);
        c.enable_momentum(2, 0.9);
        // round A commits velocity [1, 1]
        let mut st = c.take_round_state();
        let mut g = [1.0f32; 2];
        let mut mc = st.momentum_fresh.take().unwrap();
        mc.correct_from(st.momentum.as_ref().unwrap(), &mut g);
        st.fresh.store_from(&st.residual, &[0.0; 2]);
        c.commit_round(st.residual, st.fresh, st.rate, st.momentum, Some(mc), 0.0);
        let committed_norm = c.momentum.as_ref().unwrap().velocity_norm();
        assert!(committed_norm > 0.0);
        // round B fails: evolved velocity discarded, snapshot restored
        let snap = c.snapshot();
        let mut st = c.take_round_state();
        let mut g = [5.0f32; 2];
        let mut mc = st.momentum_fresh.take().unwrap();
        mc.correct_from(st.momentum.as_ref().unwrap(), &mut g);
        c.reclaim_spare(st.fresh, Some(mc));
        drop((st.residual, st.momentum));
        c.restore(snap);
        assert_eq!(c.momentum.as_ref().unwrap().velocity_norm(), committed_norm);
        assert!(c.momentum_spare.is_some(), "evolved corrector recycled on rollback");
    }

    #[test]
    fn residual_sized_to_model() {
        let c = ClientState::new(2, vec![], 123);
        assert_eq!(c.residual.len(), 123);
    }
}
