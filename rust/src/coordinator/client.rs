//! Per-client persistent state across rounds.

use crate::sparse::dynamic::DynamicRate;
use crate::sparse::residual::ResidualStore;

/// One simulated federated participant.
#[derive(Clone, Debug)]
pub struct ClientState {
    pub id: u32,
    /// Indices into the train split this client owns.
    pub data: Vec<usize>,
    /// Residual accumulation (Alg. 1 line 12).
    pub residual: ResidualStore,
    /// Eq. 2 controller (None when static rates are used).
    pub rate: Option<DynamicRate>,
    /// DGC momentum corrector (None when momentum = 0).
    pub momentum: Option<crate::sparse::momentum::MomentumCorrector>,
    /// Mean local training loss of the last participating round.
    pub last_loss: f64,
    /// Rounds this client was selected (diagnostics).
    pub participation: u64,
}

impl ClientState {
    pub fn new(id: u32, data: Vec<usize>, model_params: usize) -> Self {
        Self {
            id,
            data,
            residual: ResidualStore::new(model_params),
            rate: None,
            momentum: None,
            last_loss: f64::NAN,
            participation: 0,
        }
    }

    /// Attach the Eq. 2 dynamic rate controller.
    pub fn with_dynamic_rate(mut self, r0: f64, alpha: f64, total_rounds: u64, r_min: f64) -> Self {
        self.rate = Some(DynamicRate::new(r0, alpha, total_rounds, r_min));
        self
    }

    /// The rate *scale* for this round: dynamic-rate output relative
    /// to the base rate r0 (1.0 when the controller is off), after
    /// observing this round's loss.
    pub fn observe_loss(&mut self, round: u64, loss: f64, base_rate: f64) -> f64 {
        self.last_loss = loss;
        self.participation += 1;
        match &mut self.rate {
            Some(ctrl) => ctrl.observe(round, loss) / base_rate,
            None => 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_client_scale_is_one() {
        let mut c = ClientState::new(0, vec![1, 2, 3], 10);
        assert_eq!(c.observe_loss(0, 1.0, 0.1), 1.0);
        assert_eq!(c.participation, 1);
        assert_eq!(c.last_loss, 1.0);
    }

    #[test]
    fn dynamic_client_scale_tracks_controller() {
        let mut c = ClientState::new(1, vec![], 10).with_dynamic_rate(0.1, 0.8, 100, 0.01);
        let s0 = c.observe_loss(0, 2.0, 0.1);
        assert!(s0 > 0.0 && s0 <= 10.0);
        // constant loss + α<1 → scale decays
        let mut last = s0;
        for t in 1..20 {
            let s = c.observe_loss(t, 2.0, 0.1);
            assert!(s <= last + 1e-12);
            last = s;
        }
        assert!(last < s0);
    }

    #[test]
    fn residual_sized_to_model() {
        let c = ClientState::new(2, vec![], 123);
        assert_eq!(c.residual.len(), 123);
    }
}
