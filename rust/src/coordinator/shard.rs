//! Range-sharded aggregate accumulator — the coordinator's answer to
//! "one aggregator thread owns one model-sized buffer".
//!
//! The model's coordinate space `[0, n)` is split into `S` contiguous
//! spans (`starts[s] = s·n/S` fenceposts); shard `s` owns span
//! `[starts[s], starts[s+1])`. Every add/subtract routes to the one
//! shard owning that position, so per-shard memory is `O(n/S)` and the
//! shards could live on separate aggregator workers without any
//! cross-shard f32 traffic.
//!
//! **Bitwise-exactness argument** (the shard-merge reduction-order
//! contract, PERF.md): a position belongs to exactly one shard, so the
//! sequence of f32 operations applied to any single position is
//! *identical* to the serial single-accumulator path — sharding
//! partitions the coordinate space, never the operation stream of one
//! coordinate. The final merge is pure concatenation ascending shard id
//! (`starts` spans are contiguous and ascending), never an f32
//! addition. Therefore ANY shard count reproduces the serial result
//! bit-for-bit — pinned by the tests below and by
//! `tests/neighborhood_secagg.rs`.
//!
//! Buffers are retained across [`Self::reset`] calls (capacity reuse),
//! so the steady-state round path allocates nothing model-sized
//! (`tests/alloc_steady_state.rs`).

use crate::sparse::codec::SparseVec;
use crate::sparse::quant::{QuantConfig, QuantizedSparse};

/// A model-sized accumulator stored as `S` contiguous range shards.
#[derive(Default)]
pub struct ShardedAccumulator {
    n: usize,
    /// `S + 1` fenceposts: shard `s` owns `[starts[s], starts[s+1])`.
    starts: Vec<usize>,
    bufs: Vec<Vec<f32>>,
    /// Monotonic routing cursor for [`Self::fold`] (payload indices
    /// are ascending, so the common case is "same shard as last time").
    cursor: usize,
}

impl ShardedAccumulator {
    /// Zero the accumulator for an `n`-dimensional model over `shards`
    /// spans. Reuses existing buffer capacity.
    pub fn reset(&mut self, n: usize, shards: usize) {
        assert!(shards >= 1, "need at least one shard");
        self.n = n;
        self.starts.clear();
        self.starts.extend((0..=shards).map(|s| s * n / shards));
        self.bufs.resize_with(shards, Vec::new);
        for (s, buf) in self.bufs.iter_mut().enumerate() {
            buf.clear();
            buf.resize(self.starts[s + 1] - self.starts[s], 0.0);
        }
        self.cursor = 0;
    }

    pub fn shards(&self) -> usize {
        self.bufs.len()
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    fn shard_of(&self, i: usize) -> usize {
        debug_assert!(i < self.n, "position {i} out of range {}", self.n);
        self.starts.partition_point(|&st| st <= i) - 1
    }

    /// Fold one uplink payload in: `acc[i] += v` per entry, each entry
    /// routed to its owning shard. Entries stream in ascending-index
    /// order (the codec invariant), so routing is a monotonic cursor
    /// walk; out-of-order indices still route correctly, just slower.
    pub fn fold(&mut self, payload: &SparseVec) {
        assert_eq!(payload.n as usize, self.n, "accumulator size mismatch");
        let mut s = self.cursor.min(self.bufs.len() - 1);
        for (&i, &v) in payload.indices.iter().zip(&payload.values) {
            let i = i as usize;
            if i < self.starts[s] || i >= self.starts[s + 1] {
                s = self.shard_of(i);
            }
            self.bufs[s][i - self.starts[s]] += v;
        }
        self.cursor = 0;
    }

    /// [`Self::fold`] for a quantized uplink: `acc[i] += code·scale/levels`
    /// per entry — the exact expression [`crate::sparse::quant::dequantize`]
    /// evaluates client-side, so dequantize-on-fold is bitwise
    /// identical to folding a client-dequantized f32 payload.
    pub fn fold_quant(&mut self, q: &QuantizedSparse) {
        assert_eq!(q.n as usize, self.n, "accumulator size mismatch");
        let levels = QuantConfig { bits: q.bits }.levels() as f32;
        let mut s = self.cursor.min(self.bufs.len() - 1);
        for (&i, &c) in q.indices.iter().zip(&q.codes) {
            let i = i as usize;
            if i < self.starts[s] || i >= self.starts[s + 1] {
                s = self.shard_of(i);
            }
            self.bufs[s][i - self.starts[s]] += c as f32 / levels * q.scale;
        }
        self.cursor = 0;
    }

    /// Move shard `s`'s buffer out for a pool-parallel fold task,
    /// returning its `[start, end)` coordinate range with it. The task
    /// folds range-restricted payload walks into the buffer and hands
    /// it back through [`Self::put_range_buf`] — moved, never copied,
    /// so the parallel Collect stays allocation-free in steady state.
    pub(crate) fn take_range_buf(&mut self, s: usize) -> (u32, u32, Vec<f32>) {
        (self.starts[s] as u32, self.starts[s + 1] as u32, std::mem::take(&mut self.bufs[s]))
    }

    /// Restore shard `s`'s buffer after a parallel fold task.
    pub(crate) fn put_range_buf(&mut self, s: usize, buf: Vec<f32>) {
        debug_assert_eq!(buf.len(), self.starts[s + 1] - self.starts[s]);
        self.bufs[s] = buf;
    }

    /// `acc[i] -= x` — the dead-mask cancellation sink
    /// ([`crate::secagg::SecAggServer::cancel_dead_masks_pooled_sink`]).
    pub fn sub_at(&mut self, i: u32, x: f32) {
        let i = i as usize;
        let s = self.shard_of(i);
        self.bufs[s][i - self.starts[s]] -= x;
    }

    /// Concatenate the shards (ascending shard id) into `out` — the
    /// documented shard-merge order. Pure copy, no f32 arithmetic, so
    /// the merged vector is bitwise identical to a serial
    /// single-accumulator run regardless of shard count.
    pub fn merge_into(&self, out: &mut Vec<f32>) {
        out.clear();
        for buf in &self.bufs {
            out.extend_from_slice(buf);
        }
        debug_assert_eq!(out.len(), self.n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn payload(n: u32, seed: u64, frac: f64) -> SparseVec {
        let mut rng = Rng::new(seed);
        let dense: Vec<f32> = (0..n)
            .map(|_| if rng.next_f64() < frac { rng.normal_f32(1.0) } else { 0.0 })
            .collect();
        SparseVec::from_dense(&dense)
    }

    #[test]
    fn sharded_fold_is_bitwise_equal_to_serial() {
        let n = 997usize; // prime: uneven spans at every shard count
        let payloads: Vec<SparseVec> =
            (0..7).map(|i| payload(n as u32, 40 + i, 0.05)).collect();
        let mut serial = vec![0f32; n];
        for p in &payloads {
            p.add_into(&mut serial);
        }
        for shards in [1usize, 2, 3, 4, 8, 997, 1500] {
            let mut acc = ShardedAccumulator::default();
            acc.reset(n, shards);
            for p in &payloads {
                acc.fold(p);
            }
            let mut merged = Vec::new();
            acc.merge_into(&mut merged);
            assert_eq!(merged.len(), n);
            assert!(
                serial.iter().zip(&merged).all(|(a, b)| a.to_bits() == b.to_bits()),
                "shards={shards}: merge diverged from serial"
            );
        }
    }

    #[test]
    fn fold_quant_is_bitwise_equal_to_folding_dequantized() {
        use crate::sparse::quant::{dequantize, quantize};
        let n = 997usize;
        let mut rng = Rng::new(77);
        let quants: Vec<QuantizedSparse> = (0..5)
            .map(|i| {
                let p = payload(n as u32, 70 + i, 0.05);
                quantize(&p, QuantConfig { bits: 4 }, &mut rng)
            })
            .collect();
        // reference: the old client-side-dequantize path
        let mut reference = ShardedAccumulator::default();
        reference.reset(n, 1);
        for q in &quants {
            reference.fold(&dequantize(q));
        }
        let mut want = Vec::new();
        reference.merge_into(&mut want);
        for shards in [1usize, 2, 3, 8] {
            let mut acc = ShardedAccumulator::default();
            acc.reset(n, shards);
            for q in &quants {
                acc.fold_quant(q);
            }
            let mut got = Vec::new();
            acc.merge_into(&mut got);
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "shards={shards}: dequantize-on-fold diverged"
            );
        }
    }

    #[test]
    fn sub_at_matches_serial_subtraction() {
        let n = 256usize;
        let mut rng = Rng::new(9);
        let ops: Vec<(u32, f32)> =
            (0..300).map(|_| (rng.below(n as u64) as u32, rng.normal_f32(1.0))).collect();
        let mut serial = vec![0f32; n];
        for &(i, x) in &ops {
            serial[i as usize] -= x;
        }
        for shards in [1usize, 3, 5] {
            let mut acc = ShardedAccumulator::default();
            acc.reset(n, shards);
            for &(i, x) in &ops {
                acc.sub_at(i, x);
            }
            let mut merged = Vec::new();
            acc.merge_into(&mut merged);
            assert!(serial.iter().zip(&merged).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn reset_reuses_and_rezeroes() {
        let mut acc = ShardedAccumulator::default();
        acc.reset(100, 4);
        acc.sub_at(50, 1.0);
        acc.reset(100, 4);
        let mut merged = Vec::new();
        acc.merge_into(&mut merged);
        assert!(merged.iter().all(|&v| v == 0.0));
        // shrinking/growing the model dimension mid-run also works
        acc.reset(64, 4);
        let mut merged = Vec::new();
        acc.merge_into(&mut merged);
        assert_eq!(merged.len(), 64);
    }

    #[test]
    fn more_shards_than_positions_is_fine() {
        let mut acc = ShardedAccumulator::default();
        acc.reset(3, 8); // several empty spans
        acc.sub_at(0, 1.0);
        acc.sub_at(2, 2.0);
        let mut merged = Vec::new();
        acc.merge_into(&mut merged);
        assert_eq!(merged, vec![-1.0, 0.0, -2.0]);
    }
}
