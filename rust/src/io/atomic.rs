//! Atomic file commits: write-temp → fsync → rename.
//!
//! The contract: after [`commit_bytes`] returns `Ok`, the destination
//! path holds exactly the given bytes and survives a crash or power
//! loss at any later instant. If the process dies *during* the commit,
//! the destination either still holds its previous contents (or does
//! not exist yet) or already holds the complete new contents — never a
//! prefix. The only possible debris is a sibling `<name>.tmp`, which
//! every reader in this crate ignores.
//!
//! [`commit_bytes_torn`] is the same commit with a seeded crash
//! injection point, in the same spirit as `FailurePlan`/`ChaosPlan`:
//! tests drive the tear through every step of the commit and assert
//! that the last committed state stays loadable
//! (`tests/checkpoint_robustness.rs`).

use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::util::rng::Rng;

/// Where a simulated crash interrupts the commit sequence.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tear {
    /// Crash mid-write: only the first `keep` bytes reach the temp
    /// file. The destination is untouched.
    Partial { keep: usize },
    /// Crash after the temp file is fully written and synced but
    /// before the rename. The destination is untouched.
    BeforeRename,
}

/// Sibling temp path for `path` (`<name>.tmp` in the same directory,
/// so the final rename never crosses a filesystem boundary).
pub fn temp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().unwrap_or_default().to_os_string();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Atomically commit `bytes` to `path`.
pub fn commit_bytes(path: &Path, bytes: &[u8]) -> std::io::Result<()> {
    commit_bytes_torn(path, bytes, None).map(|_| ())
}

/// Atomically commit `bytes` to `path`, optionally crashing partway.
///
/// Returns `Ok(true)` when the commit completed and `Ok(false)` when a
/// simulated [`Tear`] stopped it early (the destination is untouched;
/// at most a `<name>.tmp` sibling is left behind, exactly like a real
/// crash).
pub fn commit_bytes_torn(path: &Path, bytes: &[u8], tear: Option<Tear>) -> std::io::Result<bool> {
    let tmp = temp_path(path);
    let mut f = OpenOptions::new().write(true).create(true).truncate(true).open(&tmp)?;
    match tear {
        Some(Tear::Partial { keep }) => {
            let keep = keep.min(bytes.len());
            f.write_all(&bytes[..keep])?;
            f.sync_all()?;
            return Ok(false);
        }
        Some(Tear::BeforeRename) => {
            f.write_all(bytes)?;
            f.sync_all()?;
            return Ok(false);
        }
        None => {
            f.write_all(bytes)?;
            f.sync_all()?;
        }
    }
    drop(f);
    fs::rename(&tmp, path)?;
    // Make the rename itself durable. Failure here is not a
    // correctness problem for readers (the rename is already visible),
    // so this is best-effort.
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(if dir.as_os_str().is_empty() { Path::new(".") } else { dir }) {
            let _ = d.sync_all();
        }
    }
    Ok(true)
}

/// Seeded torn-write injection plan: with probability `prob` per
/// commit, tear the write at a seeded step. Draws are pure in
/// `(seed, round)` — the same plan tears the same commits every run,
/// so a red test reproduces from its seed alone.
#[derive(Clone, Copy, Debug)]
pub struct TornWritePlan {
    pub prob: f64,
    pub seed: u64,
}

impl TornWritePlan {
    pub fn new(prob: f64, seed: u64) -> Self {
        Self { prob, seed }
    }

    /// The tear (if any) for the commit tagged `round`, writing `len`
    /// bytes. Pure in `(self.seed, round)`.
    pub fn tear_for(&self, round: u64, len: usize) -> Option<Tear> {
        if self.prob <= 0.0 {
            return None;
        }
        let mut rng = Rng::new(self.seed ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x7042);
        if rng.next_f64() >= self.prob {
            return None;
        }
        if rng.below(2) == 0 {
            Some(Tear::BeforeRename)
        } else {
            Some(Tear::Partial { keep: rng.below(len as u64 + 1) as usize })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fedsparse-atomic-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn commit_replaces_previous_contents() {
        let dir = tmp_dir("replace");
        let p = dir.join("state.bin");
        commit_bytes(&p, b"one").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"one");
        commit_bytes(&p, b"two-longer").unwrap();
        assert_eq!(fs::read(&p).unwrap(), b"two-longer");
        assert!(!temp_path(&p).exists(), "completed commit must not leave a temp file");
    }

    #[test]
    fn torn_commit_leaves_destination_untouched() {
        let dir = tmp_dir("torn");
        let p = dir.join("state.bin");
        commit_bytes(&p, b"committed").unwrap();
        for tear in [Tear::Partial { keep: 0 }, Tear::Partial { keep: 3 }, Tear::BeforeRename] {
            let committed = commit_bytes_torn(&p, b"never-lands", Some(tear)).unwrap();
            assert!(!committed);
            assert_eq!(fs::read(&p).unwrap(), b"committed", "tear {tear:?} touched the target");
        }
        // A later untorn commit still lands over the debris.
        assert!(commit_bytes_torn(&p, b"landed", None).unwrap());
        assert_eq!(fs::read(&p).unwrap(), b"landed");
    }

    #[test]
    fn torn_write_plan_is_pure_in_seed_and_round() {
        let plan = TornWritePlan::new(0.7, 99);
        for round in 0..64u64 {
            assert_eq!(plan.tear_for(round, 1000), plan.tear_for(round, 1000));
        }
        let torn = (0..64u64).filter(|&r| plan.tear_for(r, 1000).is_some()).count();
        assert!((20..=60).contains(&torn), "prob 0.7 of 64 commits tore {torn}");
        let never = TornWritePlan::new(0.0, 99);
        assert!((0..64u64).all(|r| never.tear_for(r, 1000).is_none()));
    }
}
