//! Durable-run I/O: atomic file commits, versioned + checksummed
//! training checkpoints, and schema-versioned run manifests.
//!
//! The three layers compose into one contract (PERF.md §Durable runs):
//!
//! * [`atomic`] — write-temp → fsync → rename commits, so a crash at
//!   any instant leaves either the old file, the new file, or a
//!   `*.tmp` leftover that every reader ignores — never a torn file
//!   under the final name. A seeded torn-write injection hook (same
//!   spirit as `FailurePlan`/`ChaosPlan`) lets tests crash the commit
//!   at every step.
//! * [`checkpoint`] — end-of-round snapshots of everything that
//!   carries state across rounds (global params, per-client
//!   residual/momentum/rate stores, metrics + cost cursors). Because
//!   every RNG stream in the repo is pure in `(seed, round, cid)`,
//!   restoring this snapshot and re-running the remaining rounds is
//!   bitwise-identical to never having been killed.
//! * [`manifest`] — `schema_version`'d, sha256-addressed run
//!   manifests (ROADMAP open item 2): what a run was, what it
//!   emitted, and a canonical `manifest_sha256` over the whole
//!   document so provenance is machine-checkable.

pub mod atomic;
pub mod checkpoint;
pub mod manifest;
