//! Versioned, checksummed end-of-round training checkpoints.
//!
//! A checkpoint captures **everything that carries state across
//! rounds**: the global parameter vector, every client's residual
//! store (values + ages), momentum velocity, dynamic-rate controller
//! state, loss/participation counters, plus the metrics rows and cost
//! ledger recorded so far. Everything else the round loop touches is
//! either pure in `(seed, round, cid)` (selection, dropout/chaos
//! draws, mask PRG streams, quantizer RNG) or rebuilt from the config
//! (thread pools, workspaces, transports, the secagg key setup) — so
//! restoring a checkpoint and re-running the remaining rounds is
//! bitwise-identical to the uninterrupted twin
//! (`tests/checkpoint_resume.rs`).
//!
//! Deliberately **not** checkpointed: the per-round Shamir re-keying
//! registry (`secagg/rekey.rs`). Its epoch-salted polynomials differ
//! between the original and resumed runs, but reconstruction recovers
//! each member's exact DH exponent bytes either way, so every derived
//! pair key — and therefore every mask and every aggregate — is
//! byte-identical. The resume tests pin this.
//!
//! ## On-disk format (`ckpt_<next_round:08>.fsckpt`, version 1)
//!
//! ```text
//! magic    b"FSCP"                      4 bytes
//! version  u32 LE (= 1)                 4 bytes
//! body_len u64 LE                       8 bytes
//! body_sha sha256(body)                32 bytes
//! body     little-endian fields        body_len bytes
//! ```
//!
//! All integers are little-endian; floats are stored as their IEEE-754
//! bit patterns, so values (including NaN payloads) round-trip
//! bitwise. Files are written via [`crate::io::atomic`], so a crash
//! mid-save never leaves a torn file under the committed name. The
//! loader is paranoid: magic/version/length/hash are validated before
//! the body is parsed, every read is truncation-checked, and invalid
//! files are quarantined (renamed `*.corrupt`, never deleted) while
//! the loader falls back to the newest valid snapshot.

use std::fs;
use std::path::{Path, PathBuf};

use sha2::{Digest, Sha256};

use crate::comm::cost::RoundCost;
use crate::config::RunConfig;
use crate::io::atomic::{self, Tear, TornWritePlan};
use crate::metrics::recorder::{PhaseTimings, RoundRecord};

pub const MAGIC: &[u8; 4] = b"FSCP";
pub const CHECKPOINT_VERSION: u32 = 1;
/// magic + version + body_len + sha256
const HEADER_LEN: usize = 4 + 4 + 8 + 32;

#[derive(Debug, thiserror::Error)]
pub enum CheckpointError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("truncated checkpoint ({0})")]
    Truncated(&'static str),
    #[error("bad magic — not a checkpoint file")]
    BadMagic,
    #[error("unsupported checkpoint version {0} (this build reads version {CHECKPOINT_VERSION})")]
    UnsupportedVersion(u32),
    #[error("checksum mismatch — checkpoint body is corrupt")]
    HashMismatch,
    #[error("malformed checkpoint ({0})")]
    Malformed(&'static str),
}

/// Cross-round state of one client.
#[derive(Clone, Debug, PartialEq)]
pub struct ClientCheckpoint {
    pub last_loss: f64,
    pub participation: u64,
    pub residual_buf: Vec<f32>,
    pub residual_age: Vec<u32>,
    /// `(current rate, previous observed loss)` when dynamic rate is on.
    pub rate: Option<(f64, Option<f64>)>,
    pub momentum_velocity: Option<Vec<f32>>,
}

/// One end-of-round snapshot. `next_round` is the first round the
/// resumed run executes; rows/costs cover rounds `0..next_round`
/// (minus any aborted rounds that were rolled back after this commit —
/// those replay deterministically).
#[derive(Clone, Debug, PartialEq)]
pub struct Checkpoint {
    pub label: String,
    pub seed: u64,
    pub config_digest: String,
    pub next_round: u64,
    pub global_tensors: Vec<(usize, usize)>,
    pub global_data: Vec<f32>,
    pub clients: Vec<ClientCheckpoint>,
    pub rows: Vec<RoundRecord>,
    pub costs: Vec<RoundCost>,
}

/// sha256 digest of the training-relevant config: the sorted
/// `key=value` lines from [`crate::config::file::to_map`] minus the
/// durability knobs (`checkpoint_dir`/`checkpoint_every`/`resume`),
/// which may legitimately differ between a run and its resume.
pub fn config_digest(cfg: &RunConfig) -> String {
    let mut text = String::new();
    for (k, v) in crate::config::file::to_map(cfg) {
        if matches!(k.as_str(), "checkpoint_dir" | "checkpoint_every" | "resume") {
            continue;
        }
        text.push_str(&k);
        text.push('=');
        text.push_str(&v);
        text.push('\n');
    }
    crate::io::manifest::sha256_hex(text.as_bytes())
}

// ---- encode ---------------------------------------------------------

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}
fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
fn put_f32(out: &mut Vec<u8>, v: f32) {
    put_u32(out, v.to_bits());
}
fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}
fn put_str(out: &mut Vec<u8>, v: &str) {
    put_u64(out, v.len() as u64);
    out.extend_from_slice(v.as_bytes());
}
fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u64(out, v.len() as u64);
    for &x in v {
        put_f32(out, x);
    }
}
fn put_u32s(out: &mut Vec<u8>, v: &[u32]) {
    put_u64(out, v.len() as u64);
    for &x in v {
        put_u32(out, x);
    }
}

/// Serialize a checkpoint to its complete file bytes (header + body).
pub fn encode(ck: &Checkpoint) -> Vec<u8> {
    let mut body = Vec::new();
    put_str(&mut body, &ck.label);
    put_u64(&mut body, ck.seed);
    put_str(&mut body, &ck.config_digest);
    put_u64(&mut body, ck.next_round);

    put_u64(&mut body, ck.global_tensors.len() as u64);
    for &(off, len) in &ck.global_tensors {
        put_u64(&mut body, off as u64);
        put_u64(&mut body, len as u64);
    }
    put_f32s(&mut body, &ck.global_data);

    put_u64(&mut body, ck.clients.len() as u64);
    for c in &ck.clients {
        put_f64(&mut body, c.last_loss);
        put_u64(&mut body, c.participation);
        put_f32s(&mut body, &c.residual_buf);
        put_u32s(&mut body, &c.residual_age);
        match c.rate {
            None => put_u8(&mut body, 0),
            Some((rate, loss_prev)) => {
                put_u8(&mut body, 1);
                put_f64(&mut body, rate);
                match loss_prev {
                    None => put_u8(&mut body, 0),
                    Some(lp) => {
                        put_u8(&mut body, 1);
                        put_f64(&mut body, lp);
                    }
                }
            }
        }
        match &c.momentum_velocity {
            None => put_u8(&mut body, 0),
            Some(v) => {
                put_u8(&mut body, 1);
                put_f32s(&mut body, v);
            }
        }
    }

    put_u64(&mut body, ck.rows.len() as u64);
    for r in &ck.rows {
        put_u64(&mut body, r.round);
        put_f64(&mut body, r.train_loss);
        put_f64(&mut body, r.eval_loss);
        put_f64(&mut body, r.eval_accuracy);
        put_u64(&mut body, r.up_bytes);
        put_u64(&mut body, r.wire_bytes);
        put_f64(&mut body, r.sim_time_s);
        put_f64(&mut body, r.mean_rate);
        put_u64(&mut body, r.survivors as u64);
        put_u64(&mut body, r.recovered as u64);
        let t = &r.timings;
        for v in [
            t.select_s,
            t.train_s,
            t.client_train_cpu_s,
            t.client_encode_cpu_s,
            t.mask_gen_s,
            t.collect_s,
            t.recover_s,
            t.apply_s,
            t.eval_s,
        ] {
            put_f64(&mut body, v);
        }
    }

    put_u64(&mut body, ck.costs.len() as u64);
    for c in &ck.costs {
        put_u64(&mut body, c.round);
        put_u64(&mut body, c.up_paper);
        put_u64(&mut body, c.up_wire);
        put_u64(&mut body, c.up_framed);
        put_u64(&mut body, c.down_paper);
        put_f64(&mut body, c.accuracy);
    }

    let mut out = Vec::with_capacity(HEADER_LEN + body.len());
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, CHECKPOINT_VERSION);
    put_u64(&mut out, body.len() as u64);
    let mut h = Sha256::new();
    h.update(&body);
    out.extend_from_slice(&h.finalize());
    out.extend_from_slice(&body);
    out
}

// ---- decode ---------------------------------------------------------

/// Truncation-checked little-endian cursor over the body bytes.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CheckpointError> {
        if self.remaining() < n {
            return Err(CheckpointError::Truncated("body field"));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CheckpointError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CheckpointError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, CheckpointError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32, CheckpointError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64, CheckpointError> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Element count for `elem_size`-byte items, guarded against
    /// counts that could not possibly fit in the remaining bytes (so a
    /// corrupt length can never trigger a huge allocation).
    fn count(&mut self, elem_size: usize) -> Result<usize, CheckpointError> {
        let n = self.u64()? as usize;
        match n.checked_mul(elem_size) {
            Some(bytes) if bytes <= self.remaining() => Ok(n),
            _ => Err(CheckpointError::Malformed("element count exceeds body size")),
        }
    }

    fn string(&mut self) -> Result<String, CheckpointError> {
        let n = self.count(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| CheckpointError::Malformed("non-UTF-8 string"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, CheckpointError> {
        let n = self.count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.f32()?);
        }
        Ok(v)
    }

    fn u32s(&mut self) -> Result<Vec<u32>, CheckpointError> {
        let n = self.count(4)?;
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.u32()?);
        }
        Ok(v)
    }

    fn opt(&mut self) -> Result<bool, CheckpointError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(CheckpointError::Malformed("option tag not 0/1")),
        }
    }
}

/// Parse and validate complete checkpoint file bytes.
pub fn decode(bytes: &[u8]) -> Result<Checkpoint, CheckpointError> {
    if bytes.len() < HEADER_LEN {
        return Err(CheckpointError::Truncated("header"));
    }
    if &bytes[..4] != MAGIC {
        return Err(CheckpointError::BadMagic);
    }
    let version = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if version != CHECKPOINT_VERSION {
        return Err(CheckpointError::UnsupportedVersion(version));
    }
    let mut len8 = [0u8; 8];
    len8.copy_from_slice(&bytes[8..16]);
    let body_len = u64::from_le_bytes(len8) as usize;
    let body = &bytes[HEADER_LEN..];
    if body.len() < body_len {
        return Err(CheckpointError::Truncated("body"));
    }
    if body.len() > body_len {
        return Err(CheckpointError::Malformed("trailing bytes after body"));
    }
    let mut h = Sha256::new();
    h.update(body);
    if h.finalize().as_slice() != &bytes[16..48] {
        return Err(CheckpointError::HashMismatch);
    }

    let mut r = Reader { buf: body, pos: 0 };
    let label = r.string()?;
    let seed = r.u64()?;
    let config_digest = r.string()?;
    let next_round = r.u64()?;

    let n_tensors = r.count(16)?;
    let mut global_tensors = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        let off = r.u64()? as usize;
        let len = r.u64()? as usize;
        global_tensors.push((off, len));
    }
    let global_data = r.f32s()?;

    let n_clients = r.count(1)?;
    let mut clients = Vec::with_capacity(n_clients);
    for _ in 0..n_clients {
        let last_loss = r.f64()?;
        let participation = r.u64()?;
        let residual_buf = r.f32s()?;
        let residual_age = r.u32s()?;
        if residual_buf.len() != residual_age.len() {
            return Err(CheckpointError::Malformed("residual value/age length mismatch"));
        }
        let rate = if r.opt()? {
            let rate = r.f64()?;
            let loss_prev = if r.opt()? { Some(r.f64()?) } else { None };
            Some((rate, loss_prev))
        } else {
            None
        };
        let momentum_velocity = if r.opt()? { Some(r.f32s()?) } else { None };
        clients.push(ClientCheckpoint {
            last_loss,
            participation,
            residual_buf,
            residual_age,
            rate,
            momentum_velocity,
        });
    }

    let n_rows = r.count(1)?;
    let mut rows = Vec::with_capacity(n_rows);
    for _ in 0..n_rows {
        let round = r.u64()?;
        let train_loss = r.f64()?;
        let eval_loss = r.f64()?;
        let eval_accuracy = r.f64()?;
        let up_bytes = r.u64()?;
        let wire_bytes = r.u64()?;
        let sim_time_s = r.f64()?;
        let mean_rate = r.f64()?;
        let survivors = r.u64()? as usize;
        let recovered = r.u64()? as usize;
        let timings = PhaseTimings {
            select_s: r.f64()?,
            train_s: r.f64()?,
            client_train_cpu_s: r.f64()?,
            client_encode_cpu_s: r.f64()?,
            mask_gen_s: r.f64()?,
            collect_s: r.f64()?,
            recover_s: r.f64()?,
            apply_s: r.f64()?,
            eval_s: r.f64()?,
        };
        rows.push(RoundRecord {
            round,
            train_loss,
            eval_loss,
            eval_accuracy,
            up_bytes,
            wire_bytes,
            sim_time_s,
            mean_rate,
            survivors,
            recovered,
            timings,
        });
    }

    let n_costs = r.count(1)?;
    let mut costs = Vec::with_capacity(n_costs);
    for _ in 0..n_costs {
        costs.push(RoundCost {
            round: r.u64()?,
            up_paper: r.u64()?,
            up_wire: r.u64()?,
            up_framed: r.u64()?,
            down_paper: r.u64()?,
            accuracy: r.f64()?,
        });
    }

    if r.remaining() != 0 {
        return Err(CheckpointError::Malformed("trailing bytes in body"));
    }
    Ok(Checkpoint {
        label,
        seed,
        config_digest,
        next_round,
        global_tensors,
        global_data,
        clients,
        rows,
        costs,
    })
}

// ---- store ----------------------------------------------------------

/// A directory of `ckpt_<next_round:08>.fsckpt` snapshots with
/// atomic saves and a paranoid loader.
#[derive(Debug)]
pub struct CheckpointStore {
    dir: PathBuf,
    /// Seeded torn-write injection for robustness tests.
    pub torn: Option<TornWritePlan>,
}

impl CheckpointStore {
    /// Open (creating if needed) a checkpoint directory.
    pub fn open(dir: &Path) -> std::io::Result<Self> {
        fs::create_dir_all(dir)?;
        Ok(Self { dir: dir.to_path_buf(), torn: None })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn path_for(&self, next_round: u64) -> PathBuf {
        self.dir.join(format!("ckpt_{next_round:08}.fsckpt"))
    }

    /// Atomically commit a snapshot. Returns `Ok(false)` when the
    /// store's [`TornWritePlan`] simulated a crash mid-commit (the
    /// previous snapshot set is untouched).
    pub fn save(&self, ck: &Checkpoint) -> std::io::Result<bool> {
        let bytes = encode(ck);
        let tear = self.torn.as_ref().and_then(|p| p.tear_for(ck.next_round, bytes.len()));
        atomic::commit_bytes_torn(&self.path_for(ck.next_round), &bytes, tear)
    }

    /// Like [`CheckpointStore::save`], but with an explicit tear — the
    /// robustness suite drives the crash through every commit step.
    pub fn save_with(&self, ck: &Checkpoint, tear: Option<Tear>) -> std::io::Result<bool> {
        let bytes = encode(ck);
        atomic::commit_bytes_torn(&self.path_for(ck.next_round), &bytes, tear)
    }

    /// Snapshot files present, newest (highest `next_round`) first.
    /// `*.tmp` debris and quarantined `*.corrupt` files are ignored.
    fn snapshots_newest_first(&self) -> Vec<(u64, PathBuf)> {
        let mut found = Vec::new();
        let entries = match fs::read_dir(&self.dir) {
            Ok(e) => e,
            Err(_) => return found,
        };
        for entry in entries.flatten() {
            let name = entry.file_name();
            let name = name.to_string_lossy();
            let round = name
                .strip_prefix("ckpt_")
                .and_then(|s| s.strip_suffix(".fsckpt"))
                .and_then(|s| s.parse::<u64>().ok());
            if let Some(round) = round {
                found.push((round, entry.path()));
            }
        }
        found.sort_by(|a, b| b.0.cmp(&a.0));
        found
    }

    /// Load the newest valid snapshot. Invalid files (torn, corrupt,
    /// wrong version) are quarantined — renamed to `<name>.corrupt`,
    /// never deleted — and the loader falls back to the next-newest
    /// snapshot. Returns `None` when no valid snapshot exists.
    pub fn load_latest(&self) -> Option<(Checkpoint, PathBuf)> {
        for (_, path) in self.snapshots_newest_first() {
            let parsed = fs::read(&path).map_err(CheckpointError::from).and_then(|b| decode(&b));
            match parsed {
                Ok(ck) => return Some((ck, path)),
                Err(e) => {
                    let mut quarantine = path.file_name().unwrap_or_default().to_os_string();
                    quarantine.push(".corrupt");
                    let qpath = path.with_file_name(quarantine);
                    eprintln!(
                        "warning: checkpoint {} is invalid ({e}); quarantining to {} and \
                         falling back to the previous snapshot",
                        path.display(),
                        qpath.display()
                    );
                    if let Err(re) = fs::rename(&path, &qpath) {
                        eprintln!("warning: could not quarantine {}: {re}", path.display());
                    }
                }
            }
        }
        None
    }
}
