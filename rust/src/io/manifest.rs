//! Schema-versioned, sha256-addressed run manifests (ROADMAP open
//! item 2: the contract layer that makes a fleet of runs auditable).
//!
//! A manifest is a canonical-JSON document:
//!
//! ```json
//! {
//!   "schema_version": "1.0.0",
//!   "kind": "train-run",
//!   "run_id": "...",
//!   "env": {"arch": "x86_64", "os": "linux"},
//!   "meta": {...},
//!   "artifacts": [{"bytes": 123, "path": "run.csv", "sha256": "..."}],
//!   "manifest_sha256": "..."
//! }
//! ```
//!
//! `manifest_sha256` is the sha256 of the manifest's own canonical
//! serialization **with that key removed** — `util::json::Value`
//! objects are `BTreeMap`s and `Display` emits sorted keys with no
//! whitespace, so the canonical form is the only form. Artifact
//! `path`s are resolved relative to the manifest file's directory at
//! validation time. The directory builder scans in sorted order and
//! reports unreadable files without aborting
//! (`src/bin/manifest_check.rs` is the CLI over both halves).

use std::fs;
use std::io::Read;
use std::path::{Path, PathBuf};

use sha2::{Digest, Sha256};

use crate::io::atomic;
use crate::util::json::{self, arr, num, obj, s, Value};

/// Bumped on breaking manifest-layout changes; validators accept any
/// `1.x.y`.
pub const SCHEMA_VERSION: &str = "1.0.0";

pub fn sha256_hex(bytes: &[u8]) -> String {
    let mut h = Sha256::new();
    h.update(bytes);
    let digest = h.finalize();
    let mut out = String::with_capacity(64);
    for b in digest {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

/// Streaming `(sha256_hex, byte_size)` of a file.
pub fn file_sha256(path: &Path) -> std::io::Result<(String, u64)> {
    let mut f = fs::File::open(path)?;
    let mut h = Sha256::new();
    let mut buf = [0u8; 64 * 1024];
    let mut total = 0u64;
    loop {
        let n = f.read(&mut buf)?;
        if n == 0 {
            break;
        }
        h.update(&buf[..n]);
        total += n as u64;
    }
    let digest = h.finalize();
    let mut out = String::with_capacity(64);
    for b in digest {
        out.push_str(&format!("{b:02x}"));
    }
    Ok((out, total))
}

/// Canonical hash of a manifest document: sha256 over its canonical
/// serialization with the `manifest_sha256` key removed.
pub fn canonical_sha256(manifest: &Value) -> String {
    let mut stripped = manifest.clone();
    if let Value::Object(map) = &mut stripped {
        map.remove("manifest_sha256");
    }
    sha256_hex(stripped.to_string().as_bytes())
}

/// Insert the canonical `manifest_sha256` into the document.
pub fn seal(mut manifest: Value) -> Value {
    let hash = canonical_sha256(&manifest);
    if let Value::Object(map) = &mut manifest {
        map.insert("manifest_sha256".to_string(), Value::Str(hash));
    }
    manifest
}

/// A built manifest plus the files the builder could not hash —
/// reported, not fatal (the run's own artifacts should never abort
/// the run).
pub struct BuiltManifest {
    pub manifest: Value,
    /// `(path as recorded, reason)` for every skipped artifact.
    pub invalid: Vec<(String, String)>,
}

/// Build a sealed manifest over an explicit artifact list. Each
/// artifact is `(path on disk, path to record)` — record paths
/// relative to wherever the manifest will live so validation can
/// resolve them.
pub fn build_manifest(
    kind: &str,
    run_id: &str,
    meta: Vec<(String, Value)>,
    artifacts: &[(PathBuf, String)],
) -> BuiltManifest {
    let mut invalid = Vec::new();
    let mut entries = Vec::new();
    for (disk, recorded) in artifacts {
        match file_sha256(disk) {
            Ok((hash, bytes)) => entries.push(obj(vec![
                ("path", s(recorded)),
                ("sha256", Value::Str(hash)),
                ("bytes", num(bytes as f64)),
            ])),
            Err(e) => invalid.push((recorded.clone(), e.to_string())),
        }
    }
    let manifest = obj(vec![
        ("schema_version", s(SCHEMA_VERSION)),
        ("kind", s(kind)),
        ("run_id", s(run_id)),
        (
            "env",
            obj(vec![("os", s(std::env::consts::OS)), ("arch", s(std::env::consts::ARCH))]),
        ),
        ("meta", Value::Object(meta.into_iter().collect())),
        ("artifacts", arr(entries)),
    ]);
    BuiltManifest { manifest: seal(manifest), invalid }
}

/// Build a sealed manifest over a directory: files are scanned in
/// sorted name order (deterministic on every platform), optionally
/// filtered by name prefix; `MANIFEST*.json`, `*.tmp`, and `*.corrupt`
/// are always skipped. Unreadable files land in
/// [`BuiltManifest::invalid`] instead of aborting the scan.
pub fn directory_manifest(
    dir: &Path,
    kind: &str,
    run_id: &str,
    prefix: &str,
    meta: Vec<(String, Value)>,
) -> std::io::Result<BuiltManifest> {
    let mut names = Vec::new();
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        if !entry.file_type().map(|t| t.is_file()).unwrap_or(false) {
            continue;
        }
        let name = entry.file_name().to_string_lossy().into_owned();
        let skip = (name.starts_with("MANIFEST") && name.ends_with(".json"))
            || name.ends_with(".tmp")
            || name.ends_with(".corrupt");
        if skip || (!prefix.is_empty() && !name.starts_with(prefix)) {
            continue;
        }
        names.push(name);
    }
    names.sort();
    let artifacts: Vec<(PathBuf, String)> =
        names.into_iter().map(|n| (dir.join(&n), n)).collect();
    Ok(build_manifest(kind, run_id, meta, &artifacts))
}

/// Atomically write a manifest document to `path`.
pub fn write_manifest(path: &Path, manifest: &Value) -> std::io::Result<()> {
    let mut text = manifest.to_string();
    text.push('\n');
    atomic::commit_bytes(path, text.as_bytes())
}

/// Validate a manifest file. Returns the list of problems found —
/// empty means the manifest is internally consistent (schema version
/// readable, canonical hash matches) and every artifact it names
/// exists with the recorded size and sha256 (resolved relative to the
/// manifest's directory).
pub fn validate_manifest_file(path: &Path) -> Vec<String> {
    let mut issues = Vec::new();
    let text = match fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => return vec![format!("unreadable: {e}")],
    };
    let doc = match json::parse(&text) {
        Ok(v) => v,
        Err(e) => return vec![format!("not valid JSON: {e}")],
    };
    if doc.as_object().is_none() {
        return vec!["top-level value is not an object".to_string()];
    }

    match doc.get("schema_version").and_then(|v| v.as_str()) {
        None => issues.push("missing schema_version".to_string()),
        Some(v) if v.split('.').next() == Some("1") => {}
        Some(v) => issues.push(format!("unsupported schema_version {v:?} (this build reads 1.x)")),
    }

    match doc.get("manifest_sha256").and_then(|v| v.as_str()) {
        None => issues.push("missing manifest_sha256".to_string()),
        Some(recorded) => {
            let actual = canonical_sha256(&doc);
            if recorded != actual {
                issues.push(format!(
                    "manifest_sha256 mismatch: recorded {recorded}, canonical form hashes to \
                     {actual}"
                ));
            }
        }
    }

    let base = path.parent().unwrap_or_else(|| Path::new("."));
    match doc.get("artifacts").and_then(|v| v.as_array()) {
        None => issues.push("missing artifacts array".to_string()),
        Some(items) => {
            for (i, item) in items.iter().enumerate() {
                let apath = item.get("path").and_then(|v| v.as_str());
                let ahash = item.get("sha256").and_then(|v| v.as_str());
                let abytes = item.get("bytes").and_then(|v| v.as_f64());
                let (Some(apath), Some(ahash), Some(abytes)) = (apath, ahash, abytes) else {
                    issues.push(format!("artifact #{i} is missing path/sha256/bytes"));
                    continue;
                };
                let disk = base.join(apath);
                match file_sha256(&disk) {
                    Err(e) => issues.push(format!("artifact {apath}: unreadable ({e})")),
                    Ok((hash, bytes)) => {
                        if bytes != abytes as u64 {
                            issues.push(format!(
                                "artifact {apath}: size changed ({bytes} bytes on disk, manifest \
                                 recorded {})",
                                abytes as u64
                            ));
                        }
                        if hash != ahash {
                            issues.push(format!(
                                "artifact {apath}: sha256 mismatch (disk {hash}, manifest \
                                 recorded {ahash})"
                            ));
                        }
                    }
                }
            }
        }
    }
    issues
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("fedsparse-manifest-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn sha256_hex_matches_known_vector() {
        // sha256("") — the canonical empty-input vector.
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
    }

    #[test]
    fn directory_manifest_round_trips_through_validation() {
        let dir = tmp_dir("roundtrip");
        fs::write(dir.join("b.csv"), "label,round\nx,0\n").unwrap();
        fs::write(dir.join("a.csv"), "label,round\nx,1\n").unwrap();
        fs::write(dir.join("skip.tmp"), "debris").unwrap();
        let built = directory_manifest(
            &dir,
            "test-run",
            "run-1",
            "",
            vec![("note".to_string(), s("unit test"))],
        )
        .unwrap();
        assert!(built.invalid.is_empty());
        let arts = built.manifest.get("artifacts").unwrap().as_array().unwrap();
        let names: Vec<&str> =
            arts.iter().map(|a| a.get("path").unwrap().as_str().unwrap()).collect();
        assert_eq!(names, ["a.csv", "b.csv"], "sorted order, debris skipped");
        let mpath = dir.join("MANIFEST.json");
        write_manifest(&mpath, &built.manifest).unwrap();
        assert_eq!(validate_manifest_file(&mpath), Vec::<String>::new());

        // Tampering with an artifact is caught.
        fs::write(dir.join("a.csv"), "label,round\nx,999\n").unwrap();
        let issues = validate_manifest_file(&mpath);
        assert!(
            issues.iter().any(|i| i.contains("a.csv") && i.contains("sha256")),
            "tamper not caught: {issues:?}"
        );
    }

    #[test]
    fn canonical_hash_ignores_its_own_key_and_pins_everything_else() {
        let m = seal(obj(vec![("schema_version", s(SCHEMA_VERSION)), ("kind", s("t"))]));
        assert_eq!(canonical_sha256(&m), m.get("manifest_sha256").unwrap().as_str().unwrap());
        // Any other field change moves the hash.
        let m2 = seal(obj(vec![("schema_version", s(SCHEMA_VERSION)), ("kind", s("u"))]));
        assert_ne!(
            m.get("manifest_sha256").unwrap().as_str().unwrap(),
            m2.get("manifest_sha256").unwrap().as_str().unwrap()
        );
    }

    #[test]
    fn unreadable_files_reported_not_fatal() {
        let dir = tmp_dir("invalid");
        fs::write(dir.join("ok.json"), "{}").unwrap();
        let built = build_manifest(
            "t",
            "r",
            Vec::new(),
            &[
                (dir.join("ok.json"), "ok.json".to_string()),
                (dir.join("missing.json"), "missing.json".to_string()),
            ],
        );
        assert_eq!(built.invalid.len(), 1);
        assert_eq!(built.invalid[0].0, "missing.json");
        let arts = built.manifest.get("artifacts").unwrap().as_array().unwrap();
        assert_eq!(arts.len(), 1, "valid artifact still recorded");
    }

    #[test]
    fn validator_flags_corrupted_manifest_hash() {
        let dir = tmp_dir("badhash");
        let mut m = seal(obj(vec![
            ("schema_version", s(SCHEMA_VERSION)),
            ("artifacts", arr(vec![])),
        ]));
        if let Value::Object(map) = &mut m {
            map.insert("manifest_sha256".to_string(), Value::Str("0".repeat(64)));
        }
        let mpath = dir.join("MANIFEST.json");
        write_manifest(&mpath, &m).unwrap();
        let issues = validate_manifest_file(&mpath);
        assert!(
            issues.iter().any(|i| i.contains("manifest_sha256 mismatch")),
            "bad hash not caught: {issues:?}"
        );
    }
}
