//! Run metrics: per-round records, CSV/JSON emission, run summaries.

pub mod recorder;

pub use recorder::{PhaseTimings, Recorder, RoundRecord, RunSummary};
