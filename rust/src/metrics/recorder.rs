//! Per-round metric recording + CSV/JSON writers for the experiment
//! harnesses (figures are regenerated from these files; see
//! DESIGN.md per-experiment index).

use std::io::Write;
use std::path::Path;

use crate::util::json::{arr, num, obj, s, Value};

/// Real wall-clock spent in each phase of one federated round (the
/// round engine's `Select → LocalTrain/Encode → Collect →
/// Unmask/Recover → Apply → Eval` decomposition). `train_s` is the
/// wall-clock of the parallel client fan-out; `client_train_cpu_s` /
/// `client_encode_cpu_s` are CPU-seconds *summed over clients* inside
/// it (local SGD vs sparsify+mask+encode), so the fan-out's
/// parallel efficiency is `(train_cpu + encode_cpu) / (workers ·
/// train_s)`. `mask_gen_s` is the slice of `client_encode_cpu_s`
/// spent generating/applying pair masks (secure mode; 0 otherwise) —
/// the mask-PRG trajectory the streaming σ-filter is judged on.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PhaseTimings {
    pub select_s: f64,
    pub train_s: f64,
    pub client_train_cpu_s: f64,
    pub client_encode_cpu_s: f64,
    pub mask_gen_s: f64,
    pub collect_s: f64,
    pub recover_s: f64,
    pub apply_s: f64,
    pub eval_s: f64,
}

impl PhaseTimings {
    /// Total measured wall-clock of the round.
    pub fn total_s(&self) -> f64 {
        self.select_s + self.train_s + self.collect_s + self.recover_s + self.apply_s + self.eval_s
    }

    /// Element-wise accumulate (bench averaging).
    pub fn accumulate(&mut self, o: &PhaseTimings) {
        self.select_s += o.select_s;
        self.train_s += o.train_s;
        self.client_train_cpu_s += o.client_train_cpu_s;
        self.client_encode_cpu_s += o.client_encode_cpu_s;
        self.mask_gen_s += o.mask_gen_s;
        self.collect_s += o.collect_s;
        self.recover_s += o.recover_s;
        self.apply_s += o.apply_s;
        self.eval_s += o.eval_s;
    }

    /// Element-wise scale (bench averaging: `sum.scaled(1.0 / n)`).
    pub fn scaled(&self, k: f64) -> PhaseTimings {
        PhaseTimings {
            select_s: self.select_s * k,
            train_s: self.train_s * k,
            client_train_cpu_s: self.client_train_cpu_s * k,
            client_encode_cpu_s: self.client_encode_cpu_s * k,
            mask_gen_s: self.mask_gen_s * k,
            collect_s: self.collect_s * k,
            recover_s: self.recover_s * k,
            apply_s: self.apply_s * k,
            eval_s: self.eval_s * k,
        }
    }

    /// JSON object (machine-readable bench output).
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("select_s", num(self.select_s)),
            ("train_s", num(self.train_s)),
            ("client_train_cpu_s", num(self.client_train_cpu_s)),
            ("client_encode_cpu_s", num(self.client_encode_cpu_s)),
            ("mask_gen_s", num(self.mask_gen_s)),
            ("collect_s", num(self.collect_s)),
            ("recover_s", num(self.recover_s)),
            ("apply_s", num(self.apply_s)),
            ("eval_s", num(self.eval_s)),
            ("total_s", num(self.total_s())),
        ])
    }
}

/// One row of a training-run trace.
///
/// `PartialEq` is field-wise (IEEE semantics: any NaN field — e.g.
/// `eval_loss` on non-eval rounds — makes rows compare unequal);
/// bitwise comparisons, as in the resume-determinism tests, compare
/// `to_bits()` per float field instead.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundRecord {
    pub round: u64,
    pub train_loss: f64,
    pub eval_loss: f64,
    pub eval_accuracy: f64,
    /// Paper-model upload bytes this round (summed over clients).
    pub up_bytes: u64,
    /// Actual wire bytes this round.
    pub wire_bytes: u64,
    /// Simulated round wall-clock (network model), seconds.
    pub sim_time_s: f64,
    /// Mean sparsity rate actually used by clients this round.
    pub mean_rate: f64,
    /// Selected clients whose upload arrived in time.
    pub survivors: usize,
    /// Shamir-recovered (survivor, dead) pair masks cancelled this
    /// round (secure mode; 0 when every client delivered).
    pub recovered: usize,
    /// Real wall-clock per phase.
    pub timings: PhaseTimings,
}

/// End-of-run summary.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub rounds: u64,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub total_up_bytes: u64,
    pub total_wire_bytes: u64,
    pub total_sim_time_s: f64,
}

/// Collects rows for one run and serializes them.
#[derive(Debug, Default)]
pub struct Recorder {
    pub label: String,
    pub rows: Vec<RoundRecord>,
    /// Live CSV stream (see [`Self::stream_to`]): when attached, every
    /// pushed row is appended and flushed immediately, so a crashed or
    /// killed run leaves a parseable CSV prefix on disk.
    sink: Option<std::io::BufWriter<std::fs::File>>,
}

impl Recorder {
    pub fn new(label: &str) -> Self {
        Self { label: label.to_string(), rows: Vec::new(), sink: None }
    }

    pub fn push(&mut self, row: RoundRecord) {
        if let Some(f) = &mut self.sink {
            let res = write_row(f, &self.label, &row).and_then(|_| f.flush());
            if let Err(e) = res {
                // losing the live trace must not kill the run; rows
                // stay in memory for the end-of-run writers
                eprintln!("warning: metrics stream lost ({e}); rows kept in memory only");
                self.sink = None;
            }
        }
        self.rows.push(row);
    }

    /// Attach a live CSV stream: opens `path` in append mode (creating
    /// it with a header when new, with the same schema check as
    /// [`Self::append_csv`]), writes out any already-recorded rows, and
    /// from then on each [`Self::push`] appends + flushes its row
    /// before returning — an interrupted run loses at most the round
    /// in flight.
    pub fn stream_to(&mut self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(open_csv_append(path.as_ref())?);
        for r in &self.rows {
            write_row(&mut f, &self.label, r)?;
        }
        f.flush()?;
        self.sink = Some(f);
        Ok(())
    }

    /// Re-attach a live CSV stream to the file a killed run left
    /// behind (`--resume`). The file is reconciled with the restored
    /// rows in `self.rows` before the sink attaches:
    ///
    /// * the existing header is kept (no duplicate header), after the
    ///   same schema check as [`Self::append_csv`];
    /// * complete, well-formed data lines are kept only while they
    ///   agree (position + round number) with the restored rows — a
    ///   torn trailing row, a malformed line, and rows from rounds
    ///   *after* the checkpoint (rolled back by the kill, about to be
    ///   re-run) are all truncated away;
    /// * restored rows the file is missing are appended.
    ///
    /// A missing file degrades to [`Self::stream_to`]. After this
    /// returns, file contents ≡ header + `self.rows`, and subsequent
    /// pushes append — so a resumed run's CSV is identical to the
    /// uninterrupted twin's (modulo wall-clock timing columns).
    pub fn resume_stream_to(&mut self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if !path.exists() {
            return self.stream_to(path);
        }
        let text = std::fs::read_to_string(path)?;
        let Some(header_end) = text.find('\n').map(|i| i + 1) else {
            // no complete header line (killed at creation): start over
            std::fs::remove_file(path)?;
            return self.stream_to(path);
        };
        if text[..header_end].trim_end() != Self::CSV_HEADER {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "refusing to resume into {path:?}: its header does not match the \
                     current schema (was it written by an older version?)"
                ),
            ));
        }
        let n_cols = Self::CSV_HEADER.split(',').count();
        let mut keep_bytes = header_end;
        let mut kept = 0usize;
        for line in text[header_end..].split_inclusive('\n') {
            if !line.ends_with('\n') || kept >= self.rows.len() {
                break; // torn trailing row / rolled-back rounds
            }
            let trimmed = line.trim_end();
            let round_field = trimmed.split(',').nth(1).and_then(|f| f.parse::<u64>().ok());
            if trimmed.split(',').count() != n_cols || round_field != Some(self.rows[kept].round) {
                break; // malformed or divergent: rewrite from here
            }
            keep_bytes += line.len();
            kept += 1;
        }
        let f = std::fs::OpenOptions::new().write(true).open(path)?;
        f.set_len(keep_bytes as u64)?;
        drop(f);
        let mut f = std::io::BufWriter::new(std::fs::OpenOptions::new().append(true).open(path)?);
        for r in &self.rows[kept..] {
            write_row(&mut f, &self.label, r)?;
        }
        f.flush()?;
        self.sink = Some(f);
        Ok(())
    }

    pub fn summary(&self) -> RunSummary {
        let finite_acc: Vec<f64> = self
            .rows
            .iter()
            .map(|r| r.eval_accuracy)
            .filter(|a| a.is_finite())
            .collect();
        RunSummary {
            rounds: self.rows.len() as u64,
            final_accuracy: finite_acc.last().copied().unwrap_or(f64::NAN),
            best_accuracy: finite_acc.iter().copied().fold(f64::NAN, f64::max),
            total_up_bytes: self.rows.iter().map(|r| r.up_bytes).sum(),
            total_wire_bytes: self.rows.iter().map(|r| r.wire_bytes).sum(),
            total_sim_time_s: self.rows.iter().map(|r| r.sim_time_s).sum(),
        }
    }

    /// CSV column header. New columns are appended at the end so
    /// positional readers of the original eight stay valid.
    const CSV_HEADER: &'static str = "label,round,train_loss,eval_loss,eval_accuracy,up_bytes,\
                                      wire_bytes,sim_time_s,mean_rate,survivors,recovered,\
                                      t_train_s,t_collect_s,t_recover_s,t_eval_s,t_mask_gen_s";

    /// CSV with a header; figures are plotted straight from this.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", Self::CSV_HEADER)?;
        for r in &self.rows {
            write_row(&mut f, &self.label, r)?;
        }
        Ok(())
    }

    /// Append rows to an existing CSV (multi-series figures). Refuses
    /// to append to a file whose header does not match the current
    /// schema (e.g. a trace written before a column was added) — mixed
    /// row widths would silently misalign downstream readers.
    pub fn append_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = open_csv_append(path)?;
        for r in &self.rows {
            write_row(&mut f, &self.label, r)?;
        }
        Ok(())
    }

    /// JSON dump (summary + rows).
    pub fn to_json(&self) -> Value {
        let summary = self.summary();
        obj(vec![
            ("label", s(&self.label)),
            (
                "summary",
                obj(vec![
                    ("rounds", num(summary.rounds as f64)),
                    ("final_accuracy", num(summary.final_accuracy)),
                    ("best_accuracy", num(summary.best_accuracy)),
                    ("total_up_bytes", num(summary.total_up_bytes as f64)),
                    ("total_wire_bytes", num(summary.total_wire_bytes as f64)),
                    ("total_sim_time_s", num(summary.total_sim_time_s)),
                ]),
            ),
            (
                "rows",
                arr(self
                    .rows
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("round", num(r.round as f64)),
                            ("train_loss", num(r.train_loss)),
                            ("eval_loss", num(r.eval_loss)),
                            ("eval_accuracy", num(r.eval_accuracy)),
                            ("up_bytes", num(r.up_bytes as f64)),
                            ("wire_bytes", num(r.wire_bytes as f64)),
                            ("sim_time_s", num(r.sim_time_s)),
                            ("mean_rate", num(r.mean_rate)),
                            ("survivors", num(r.survivors as f64)),
                            ("recovered", num(r.recovered as f64)),
                            ("timings", r.timings.to_json()),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

/// One CSV data row in [`Recorder::CSV_HEADER`] order. Free function
/// (not a method) so the streaming `push` can write through the sink
/// while the row is still outside `self.rows`.
fn write_row(f: &mut dyn Write, label: &str, r: &RoundRecord) -> std::io::Result<()> {
    writeln!(
        f,
        "{},{},{:.6},{:.6},{:.6},{},{},{:.6},{:.6},{},{},{:.6},{:.6},{:.6},{:.6},{:.6}",
        label,
        r.round,
        r.train_loss,
        r.eval_loss,
        r.eval_accuracy,
        r.up_bytes,
        r.wire_bytes,
        r.sim_time_s,
        r.mean_rate,
        r.survivors,
        r.recovered,
        r.timings.train_s,
        r.timings.collect_s,
        r.timings.recover_s,
        r.timings.eval_s,
        r.timings.mask_gen_s,
    )
}

/// Open `path` for row appends: creates parent dirs and writes the
/// header when the file is new; refuses a file whose header does not
/// match the current schema.
fn open_csv_append(path: &Path) -> std::io::Result<std::fs::File> {
    let exists = path.exists();
    if exists {
        let text = std::fs::read_to_string(path)?;
        let header = text.lines().next().unwrap_or("");
        if header != Recorder::CSV_HEADER {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "refusing to append to {path:?}: its header does not match the \
                     current schema (was it written by an older version?)"
                ),
            ));
        }
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    if !exists {
        writeln!(f, "{}", Recorder::CSV_HEADER)?;
    }
    Ok(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: u64, acc: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0 / (round + 1) as f64,
            eval_loss: 1.1,
            eval_accuracy: acc,
            up_bytes: 100,
            wire_bytes: 80,
            sim_time_s: 0.5,
            mean_rate: 0.01,
            survivors: 4,
            recovered: 0,
            timings: PhaseTimings::default(),
        }
    }

    #[test]
    fn summary_aggregates() {
        let mut r = Recorder::new("test");
        r.push(row(0, 0.5));
        r.push(row(1, f64::NAN));
        r.push(row(2, 0.8));
        let s = r.summary();
        assert_eq!(s.rounds, 3);
        assert_eq!(s.final_accuracy, 0.8);
        assert_eq!(s.best_accuracy, 0.8);
        assert_eq!(s.total_up_bytes, 300);
        assert!((s.total_sim_time_s - 1.5).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("fedsparse-metrics-{}", std::process::id()));
        let path = dir.join("run.csv");
        let _ = std::fs::remove_file(&path);
        let mut r = Recorder::new("a");
        r.push(row(0, 0.5));
        r.write_csv(&path).unwrap();
        let mut r2 = Recorder::new("b");
        r2.push(row(1, 0.6));
        r2.append_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3); // header + 2 rows
        assert!(lines[0].starts_with("label,round"));
        assert!(lines[1].starts_with("a,0,"));
        assert!(lines[2].starts_with("b,1,"));
    }

    #[test]
    fn stream_flushes_each_pushed_row() {
        let dir =
            std::env::temp_dir().join(format!("fedsparse-metrics-stream-{}", std::process::id()));
        let path = dir.join("stream.csv");
        let _ = std::fs::remove_file(&path);
        let mut r = Recorder::new("s");
        r.push(row(0, 0.1)); // recorded before the stream attaches
        r.stream_to(&path).unwrap();
        r.push(row(1, 0.2));
        // recorder still alive, no explicit flush call: the rows must
        // already be on disk (push flushes per row)
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "header + backlog row + streamed row");
        assert_eq!(lines[0], Recorder::CSV_HEADER);
        assert!(lines[1].starts_with("s,0,"));
        assert!(lines[2].starts_with("s,1,"));
        // a later run streams into the same file (multi-series append)
        let mut r2 = Recorder::new("t");
        r2.stream_to(&path).unwrap();
        r2.push(row(0, 0.3));
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4);
        assert!(text.lines().last().unwrap().starts_with("t,0,"));
    }

    #[test]
    fn append_refuses_stale_schema() {
        let dir = std::env::temp_dir().join(format!("fedsparse-metrics-old-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.csv");
        // a trace written by a pre-survivors version of the schema
        std::fs::write(&path, "label,round,train_loss\nx,0,1.0\n").unwrap();
        let mut r = Recorder::new("new");
        r.push(row(0, 0.5));
        let err = r.append_csv(&path).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        // the stale file is left untouched
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
    }

    #[test]
    fn phase_timings_accumulate_and_scale() {
        let mut sum = PhaseTimings::default();
        let one = PhaseTimings {
            select_s: 0.5,
            train_s: 2.0,
            client_train_cpu_s: 3.0,
            client_encode_cpu_s: 1.0,
            mask_gen_s: 0.5,
            collect_s: 0.25,
            recover_s: 0.125,
            apply_s: 0.0625,
            eval_s: 1.0,
        };
        sum.accumulate(&one);
        sum.accumulate(&one);
        let mean = sum.scaled(0.5);
        assert_eq!(mean, one);
        assert!((one.total_s() - (0.5 + 2.0 + 0.25 + 0.125 + 0.0625 + 1.0)).abs() < 1e-12);
        // the CPU sums are inside train_s, not added to the total
        assert!(one.total_s() < 8.0);
    }

    #[test]
    fn json_parses_back() {
        let mut r = Recorder::new("j");
        r.push(row(0, 0.9));
        let v = crate::util::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(v.path(&["summary", "rounds"]).unwrap().as_usize(), Some(1));
        assert_eq!(v.get("label").unwrap().as_str(), Some("j"));
    }
}
