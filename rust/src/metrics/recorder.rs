//! Per-round metric recording + CSV/JSON writers for the experiment
//! harnesses (figures are regenerated from these files; see
//! DESIGN.md per-experiment index).

use std::io::Write;
use std::path::Path;

use crate::util::json::{arr, num, obj, s, Value};

/// One row of a training-run trace.
#[derive(Clone, Debug)]
pub struct RoundRecord {
    pub round: u64,
    pub train_loss: f64,
    pub eval_loss: f64,
    pub eval_accuracy: f64,
    /// Paper-model upload bytes this round (summed over clients).
    pub up_bytes: u64,
    /// Actual wire bytes this round.
    pub wire_bytes: u64,
    /// Simulated round wall-clock (network model), seconds.
    pub sim_time_s: f64,
    /// Mean sparsity rate actually used by clients this round.
    pub mean_rate: f64,
}

/// End-of-run summary.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub rounds: u64,
    pub final_accuracy: f64,
    pub best_accuracy: f64,
    pub total_up_bytes: u64,
    pub total_wire_bytes: u64,
    pub total_sim_time_s: f64,
}

/// Collects rows for one run and serializes them.
#[derive(Debug, Default)]
pub struct Recorder {
    pub label: String,
    pub rows: Vec<RoundRecord>,
}

impl Recorder {
    pub fn new(label: &str) -> Self {
        Self { label: label.to_string(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: RoundRecord) {
        self.rows.push(row);
    }

    pub fn summary(&self) -> RunSummary {
        let finite_acc: Vec<f64> = self
            .rows
            .iter()
            .map(|r| r.eval_accuracy)
            .filter(|a| a.is_finite())
            .collect();
        RunSummary {
            rounds: self.rows.len() as u64,
            final_accuracy: finite_acc.last().copied().unwrap_or(f64::NAN),
            best_accuracy: finite_acc.iter().copied().fold(f64::NAN, f64::max),
            total_up_bytes: self.rows.iter().map(|r| r.up_bytes).sum(),
            total_wire_bytes: self.rows.iter().map(|r| r.wire_bytes).sum(),
            total_sim_time_s: self.rows.iter().map(|r| r.sim_time_s).sum(),
        }
    }

    /// CSV with a header; figures are plotted straight from this.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "label,round,train_loss,eval_loss,eval_accuracy,up_bytes,wire_bytes,sim_time_s,mean_rate"
        )?;
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{:.6},{:.6},{:.6},{},{},{:.6},{:.6}",
                self.label,
                r.round,
                r.train_loss,
                r.eval_loss,
                r.eval_accuracy,
                r.up_bytes,
                r.wire_bytes,
                r.sim_time_s,
                r.mean_rate
            )?;
        }
        Ok(())
    }

    /// Append rows to an existing CSV (multi-series figures).
    pub fn append_csv(&self, path: &Path) -> std::io::Result<()> {
        let exists = path.exists();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        if !exists {
            writeln!(
                f,
                "label,round,train_loss,eval_loss,eval_accuracy,up_bytes,wire_bytes,sim_time_s,mean_rate"
            )?;
        }
        for r in &self.rows {
            writeln!(
                f,
                "{},{},{:.6},{:.6},{:.6},{},{},{:.6},{:.6}",
                self.label,
                r.round,
                r.train_loss,
                r.eval_loss,
                r.eval_accuracy,
                r.up_bytes,
                r.wire_bytes,
                r.sim_time_s,
                r.mean_rate
            )?;
        }
        Ok(())
    }

    /// JSON dump (summary + rows).
    pub fn to_json(&self) -> Value {
        let summary = self.summary();
        obj(vec![
            ("label", s(&self.label)),
            (
                "summary",
                obj(vec![
                    ("rounds", num(summary.rounds as f64)),
                    ("final_accuracy", num(summary.final_accuracy)),
                    ("best_accuracy", num(summary.best_accuracy)),
                    ("total_up_bytes", num(summary.total_up_bytes as f64)),
                    ("total_wire_bytes", num(summary.total_wire_bytes as f64)),
                    ("total_sim_time_s", num(summary.total_sim_time_s)),
                ]),
            ),
            (
                "rows",
                arr(self
                    .rows
                    .iter()
                    .map(|r| {
                        obj(vec![
                            ("round", num(r.round as f64)),
                            ("train_loss", num(r.train_loss)),
                            ("eval_loss", num(r.eval_loss)),
                            ("eval_accuracy", num(r.eval_accuracy)),
                            ("up_bytes", num(r.up_bytes as f64)),
                            ("wire_bytes", num(r.wire_bytes as f64)),
                            ("sim_time_s", num(r.sim_time_s)),
                            ("mean_rate", num(r.mean_rate)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(round: u64, acc: f64) -> RoundRecord {
        RoundRecord {
            round,
            train_loss: 1.0 / (round + 1) as f64,
            eval_loss: 1.1,
            eval_accuracy: acc,
            up_bytes: 100,
            wire_bytes: 80,
            sim_time_s: 0.5,
            mean_rate: 0.01,
        }
    }

    #[test]
    fn summary_aggregates() {
        let mut r = Recorder::new("test");
        r.push(row(0, 0.5));
        r.push(row(1, f64::NAN));
        r.push(row(2, 0.8));
        let s = r.summary();
        assert_eq!(s.rounds, 3);
        assert_eq!(s.final_accuracy, 0.8);
        assert_eq!(s.best_accuracy, 0.8);
        assert_eq!(s.total_up_bytes, 300);
        assert!((s.total_sim_time_s - 1.5).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("fedsparse-metrics-{}", std::process::id()));
        let path = dir.join("run.csv");
        let _ = std::fs::remove_file(&path);
        let mut r = Recorder::new("a");
        r.push(row(0, 0.5));
        r.write_csv(&path).unwrap();
        let mut r2 = Recorder::new("b");
        r2.push(row(1, 0.6));
        r2.append_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3); // header + 2 rows
        assert!(lines[0].starts_with("label,round"));
        assert!(lines[1].starts_with("a,0,"));
        assert!(lines[2].starts_with("b,1,"));
    }

    #[test]
    fn json_parses_back() {
        let mut r = Recorder::new("j");
        r.push(row(0, 0.9));
        let v = crate::util::json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(v.path(&["summary", "rounds"]).unwrap().as_usize(), Some(1));
        assert_eq!(v.get("label").unwrap().as_str(), Some("j"));
    }
}
