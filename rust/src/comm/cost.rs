//! The paper's communication-cost model (§5.2) and the run ledger.
//!
//! Eq. 7/8, per aggregation round with C·K selected clients:
//!
//! ```text
//! c_up   = m·s·96 bit   (sparse)  |  m·64 bit  (dense)
//! c_down = m·64 bit                 (server → client, always dense)
//! c_total = n_rounds · C·K · (c_up + c_down)
//! ```
//!
//! The ledger records *both* the paper model (comparable to Table 2)
//! and the actual wire bytes our codec produced (strictly smaller),
//! plus per-round accuracy so the "cost to reach 95% of convergence
//! accuracy" query (Table 2's row definition) is answerable post-hoc.
//!
//! `up_wire` measures the bytes that actually crossed the transport:
//! with `quant_bits` set that is the bitpacked quantized v1 frame
//! (header + delta-varint indices + b-bit codes — see
//! [`crate::sparse::quant`]), not a dequantized f32 encoding; with
//! `quant_bits` unset it is the f32 [`crate::sparse::codec`] frame,
//! byte-identical to the pre-quantized-wire encoder. Secure rounds
//! always meter f32 frames (masks are f32 sums; see PERF.md).
//!
//! `up_framed` additionally counts the socket framing overhead: payload
//! bytes plus the fixed [`crate::comm::frame::HEADER_LEN`]-byte header
//! per delivered uplink. It is metered identically on every transport
//! (the in-process twin charges the same header it would put on a real
//! socket), so ledgers stay comparable across `--transport` choices
//! while `up_wire` stays pinned to the payload-only golden values.

use crate::sparse::codec;

/// One round's communication record.
///
/// `PartialEq` is field-wise with IEEE float semantics — a NaN
/// `accuracy` (non-eval round) compares unequal; checkpoint round-trip
/// tests compare `accuracy.to_bits()` instead.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RoundCost {
    pub round: u64,
    /// Paper-model upload bytes summed over selected clients.
    pub up_paper: u64,
    /// Actual encoded upload bytes.
    pub up_wire: u64,
    /// `up_wire` plus socket frame headers (0 when a path predates
    /// framed metering).
    pub up_framed: u64,
    /// Paper-model download bytes (dense model broadcast).
    pub down_paper: u64,
    /// Eval accuracy observed after this round (NaN when not evaled).
    pub accuracy: f64,
}

/// Whole-run ledger.
#[derive(Clone, Debug, Default)]
pub struct CostLedger {
    pub rounds: Vec<RoundCost>,
    /// Total parameter count m (for dense-baseline comparisons).
    pub model_params: usize,
}

impl CostLedger {
    pub fn new(model_params: usize) -> Self {
        Self { rounds: Vec::new(), model_params }
    }

    /// Record a round. `client_nnz` = per selected client, the number
    /// of non-zero update entries uploaded (dense ⇒ `m`); `wire_bytes`
    /// = actual encoded sizes.
    pub fn record(
        &mut self,
        round: u64,
        client_nnz: &[usize],
        wire_bytes: &[usize],
        dense_upload: bool,
        accuracy: f64,
    ) {
        let m = self.model_params;
        let up_paper: u64 = client_nnz
            .iter()
            .map(|&nnz| {
                if dense_upload {
                    codec::dense_cost_bytes(m)
                } else {
                    codec::sparse_cost_bytes(nnz)
                }
            })
            .sum();
        let up_wire: u64 = wire_bytes.iter().map(|&b| b as u64).sum();
        let down_paper = codec::dense_cost_bytes(m) * client_nnz.len() as u64;
        self.rounds.push(RoundCost {
            round,
            up_paper,
            up_wire,
            up_framed: 0,
            down_paper,
            accuracy,
        });
    }

    /// Record a round with per-client paper costs already computed
    /// (algorithm-specific wire formats: STC codebook, quantized, …).
    /// `framed` = actual framed socket bytes (payload + headers) for
    /// the round's delivered uplinks.
    pub fn record_with_costs(
        &mut self,
        round: u64,
        up_paper_per_client: &[u64],
        wire_bytes: &[usize],
        framed: u64,
        accuracy: f64,
    ) {
        let up_paper = up_paper_per_client.iter().sum();
        let up_wire = wire_bytes.iter().map(|&b| b as u64).sum();
        let down_paper =
            codec::dense_cost_bytes(self.model_params) * up_paper_per_client.len() as u64;
        self.rounds.push(RoundCost {
            round,
            up_paper,
            up_wire,
            up_framed: framed,
            down_paper,
            accuracy,
        });
    }

    pub fn total_up_paper(&self) -> u64 {
        self.rounds.iter().map(|r| r.up_paper).sum()
    }

    pub fn total_up_wire(&self) -> u64 {
        self.rounds.iter().map(|r| r.up_wire).sum()
    }

    /// Total framed socket bytes (payload + frame headers).
    pub fn total_up_framed(&self) -> u64 {
        self.rounds.iter().map(|r| r.up_framed).sum()
    }

    pub fn total_down_paper(&self) -> u64 {
        self.rounds.iter().map(|r| r.down_paper).sum()
    }

    /// Best accuracy seen over the run ("final average convergence
    /// accuracy" proxy; the paper averages the converged tail — we use
    /// the max of a trailing window, see [`Self::converged_accuracy`]).
    pub fn best_accuracy(&self) -> f64 {
        self.rounds
            .iter()
            .map(|r| r.accuracy)
            .filter(|a| a.is_finite())
            .fold(f64::NAN, f64::max)
    }

    /// Mean accuracy over the last `window` evaluated rounds — the
    /// paper's "final average convergence accuracy".
    pub fn converged_accuracy(&self, window: usize) -> f64 {
        let evaled: Vec<f64> = self
            .rounds
            .iter()
            .map(|r| r.accuracy)
            .filter(|a| a.is_finite())
            .collect();
        if evaled.is_empty() {
            return f64::NAN;
        }
        let tail = &evaled[evaled.len().saturating_sub(window)..];
        tail.iter().sum::<f64>() / tail.len() as f64
    }

    /// Table 2's row: cumulative paper-model upload bytes until the
    /// first evaluated round whose accuracy ≥ `target`. `None` if the
    /// run never got there.
    pub fn upload_to_reach(&self, target: f64) -> Option<u64> {
        let mut cum = 0u64;
        for r in &self.rounds {
            cum += r.up_paper;
            if r.accuracy.is_finite() && r.accuracy >= target {
                return Some(cum);
            }
        }
        None
    }

    /// Rounds until accuracy ≥ target (n_percent in Eq. 7).
    pub fn rounds_to_reach(&self, target: f64) -> Option<u64> {
        self.rounds
            .iter()
            .find(|r| r.accuracy.is_finite() && r.accuracy >= target)
            .map(|r| r.round + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ledger_with(accs: &[f64]) -> CostLedger {
        let mut l = CostLedger::new(1000);
        for (i, &a) in accs.iter().enumerate() {
            l.record(i as u64, &[100, 100], &[900, 900], false, a);
        }
        l
    }

    #[test]
    fn paper_model_sparse_vs_dense() {
        let mut l = CostLedger::new(1000);
        l.record(0, &[50, 50], &[0, 0], false, f64::NAN);
        // sparse: 2 clients × 50 nnz × 12 bytes
        assert_eq!(l.rounds[0].up_paper, 2 * 50 * 12);
        l.record(1, &[1000, 1000], &[0, 0], true, f64::NAN);
        // dense: 2 clients × 1000 × 8 bytes
        assert_eq!(l.rounds[1].up_paper, 2 * 8000);
        // download always dense per client
        assert_eq!(l.rounds[0].down_paper, 2 * 8000);
    }

    #[test]
    fn upload_to_reach_accumulates() {
        let l = ledger_with(&[0.2, 0.5, 0.8, 0.9]);
        let per_round = 2 * 100 * 12;
        assert_eq!(l.upload_to_reach(0.75), Some(3 * per_round));
        assert_eq!(l.upload_to_reach(0.95), None);
        assert_eq!(l.rounds_to_reach(0.5), Some(2));
    }

    #[test]
    fn converged_accuracy_tail_mean() {
        let l = ledger_with(&[0.1, 0.8, 0.9, 1.0]);
        assert!((l.converged_accuracy(2) - 0.95).abs() < 1e-12);
        assert!((l.best_accuracy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn skips_nan_accuracy() {
        let l = ledger_with(&[f64::NAN, 0.5, f64::NAN, 0.7]);
        assert!((l.converged_accuracy(10) - 0.6).abs() < 1e-12);
        assert_eq!(l.rounds_to_reach(0.6), Some(4));
    }

    #[test]
    fn framed_meter_accumulates() {
        let mut l = CostLedger::new(1000);
        l.record_with_costs(0, &[1200], &[900], 919, f64::NAN);
        assert_eq!(l.rounds[0].up_framed, 919);
        // plain record() predates framed metering
        l.record(1, &[100], &[900], false, f64::NAN);
        assert_eq!(l.rounds[1].up_framed, 0);
        assert_eq!(l.total_up_framed(), 919);
    }

    #[test]
    fn totals_sum() {
        let l = ledger_with(&[0.5, 0.6]);
        assert_eq!(l.total_up_paper(), 2 * 2 * 100 * 12);
        assert_eq!(l.total_up_wire(), 2 * 2 * 900);
        assert_eq!(l.total_down_paper(), 2 * 2 * 8000);
    }
}
