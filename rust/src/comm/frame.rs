//! The socket wire frame: length-delimited, versioned, round-tagged.
//!
//! Every uplink that crosses a real socket ([`crate::comm::socket`]) is
//! wrapped in one frame:
//!
//! ```text
//! offset  size  field
//! 0       1     magic    0xF5
//! 1       1     version  1
//! 2       1     kind     1 = Uplink
//! 3       8     round    u64 LE  (stale-round frames are discarded)
//! 11      4     cid      u32 LE
//! 15      4     len      u32 LE  payload byte length
//! 19      len   payload  opaque bytes — an f32 codec frame
//!                        ([`crate::sparse::codec`]), a bitpacked
//!                        quantized frame ([`crate::sparse::quant`]),
//!                        or a masked secure payload
//! ```
//!
//! The frame is transport-framing only: the payload stays byte-identical
//! to what the in-process transport carries, which is what lets the
//! conformance suite pin all transports to the same payload bytes. The
//! 19-byte header is the per-uplink wire overhead metered as
//! `up_framed` in [`crate::comm::cost`] (identically on every
//! transport, socket or not, so the ledgers stay comparable).
//!
//! Decoding is strict: wrong magic/version/kind, an oversized length
//! field, or a mid-frame EOF are errors — never a silent partial read.
//! A clean EOF *between* frames is the normal end-of-connection signal
//! (`Ok(None)`).

use std::io::{self, Read, Write};

/// First byte of every frame.
pub const MAGIC: u8 = 0xF5;
/// Wire-format version this build speaks.
pub const VERSION: u8 = 1;
/// Frame kind: one client upload.
pub const KIND_UPLINK: u8 = 1;
/// Fixed header size in bytes (see the module-level layout).
pub const HEADER_LEN: usize = 19;
/// Upper bound on the length field — a garbage header must not drive a
/// multi-gigabyte buffer reserve (same defense as the payload codecs).
pub const MAX_PAYLOAD: usize = 1 << 26; // 64 MiB

/// Decoded frame header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FrameHeader {
    pub kind: u8,
    pub round: u64,
    pub cid: u32,
    pub len: u32,
}

#[derive(Debug, thiserror::Error)]
pub enum FrameError {
    #[error("io: {0}")]
    Io(#[from] io::Error),
    #[error("bad magic byte 0x{0:02x}")]
    BadMagic(u8),
    #[error("unsupported frame version {0}")]
    BadVersion(u8),
    #[error("unknown frame kind {0}")]
    BadKind(u8),
    #[error("frame payload length {0} exceeds the {MAX_PAYLOAD}-byte cap")]
    TooLarge(u32),
    #[error("connection closed mid-frame")]
    Truncated,
}

/// Total on-the-wire size of a frame carrying `payload_len` bytes.
pub fn framed_len(payload_len: usize) -> usize {
    HEADER_LEN + payload_len
}

/// Serialize a header into its 19 wire bytes.
pub fn encode_header(round: u64, cid: u32, payload_len: u32) -> [u8; HEADER_LEN] {
    let mut h = [0u8; HEADER_LEN];
    h[0] = MAGIC;
    h[1] = VERSION;
    h[2] = KIND_UPLINK;
    h[3..11].copy_from_slice(&round.to_le_bytes());
    h[11..15].copy_from_slice(&cid.to_le_bytes());
    h[15..19].copy_from_slice(&payload_len.to_le_bytes());
    h
}

/// Write one complete frame; returns the bytes put on the wire
/// (`framed_len(payload.len())`).
pub fn write_frame<W: Write>(w: &mut W, round: u64, cid: u32, payload: &[u8]) -> io::Result<usize> {
    debug_assert!(payload.len() <= MAX_PAYLOAD);
    w.write_all(&encode_header(round, cid, payload.len() as u32))?;
    w.write_all(payload)?;
    Ok(framed_len(payload.len()))
}

/// Read one frame into `payload` (cleared first). `Ok(None)` on a clean
/// EOF at a frame boundary — the peer closed after its last frame; any
/// EOF inside a frame is [`FrameError::Truncated`].
pub fn read_frame<R: Read>(
    r: &mut R,
    payload: &mut Vec<u8>,
) -> Result<Option<FrameHeader>, FrameError> {
    payload.clear();
    let mut header = [0u8; HEADER_LEN];
    // the first byte distinguishes clean close from a truncated frame
    match r.read(&mut header[..1]) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) => return Err(e.into()),
    }
    read_exact_or_truncated(r, &mut header[1..])?;
    if header[0] != MAGIC {
        return Err(FrameError::BadMagic(header[0]));
    }
    if header[1] != VERSION {
        return Err(FrameError::BadVersion(header[1]));
    }
    if header[2] != KIND_UPLINK {
        return Err(FrameError::BadKind(header[2]));
    }
    let hdr = FrameHeader {
        kind: header[2],
        round: u64::from_le_bytes(header[3..11].try_into().unwrap()),
        cid: u32::from_le_bytes(header[11..15].try_into().unwrap()),
        len: u32::from_le_bytes(header[15..19].try_into().unwrap()),
    };
    if hdr.len as usize > MAX_PAYLOAD {
        return Err(FrameError::TooLarge(hdr.len));
    }
    payload.resize(hdr.len as usize, 0);
    read_exact_or_truncated(r, payload)?;
    Ok(Some(hdr))
}

fn read_exact_or_truncated<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<(), FrameError> {
    r.read_exact(buf).map_err(|e| {
        if e.kind() == io::ErrorKind::UnexpectedEof {
            FrameError::Truncated
        } else {
            FrameError::Io(e)
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame_bytes(round: u64, cid: u32, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, round, cid, payload).unwrap();
        out
    }

    #[test]
    fn round_trips() {
        let payload = b"sparse payload bytes".to_vec();
        let wire = frame_bytes(7, 42, &payload);
        assert_eq!(wire.len(), framed_len(payload.len()));
        let mut cursor = &wire[..];
        let mut got = Vec::new();
        let hdr = read_frame(&mut cursor, &mut got).unwrap().unwrap();
        assert_eq!(hdr, FrameHeader { kind: KIND_UPLINK, round: 7, cid: 42, len: 20 });
        assert_eq!(got, payload);
        // clean EOF after the frame
        assert!(read_frame(&mut cursor, &mut got).unwrap().is_none());
    }

    #[test]
    fn multiple_frames_on_one_stream() {
        let mut wire = frame_bytes(1, 0, b"aa");
        wire.extend(frame_bytes(1, 0, b"bbb")); // duplicate cid is legal framing
        let mut cursor = &wire[..];
        let mut got = Vec::new();
        assert_eq!(read_frame(&mut cursor, &mut got).unwrap().unwrap().len, 2);
        assert_eq!(read_frame(&mut cursor, &mut got).unwrap().unwrap().len, 3);
        assert!(read_frame(&mut cursor, &mut got).unwrap().is_none());
    }

    #[test]
    fn rejects_corrupt_headers() {
        let good = frame_bytes(3, 9, b"payload");
        let mut bad = good.clone();
        bad[0] = 0x00;
        let mut got = Vec::new();
        assert!(matches!(read_frame(&mut &bad[..], &mut got), Err(FrameError::BadMagic(0))));
        let mut bad = good.clone();
        bad[1] = 99;
        assert!(matches!(read_frame(&mut &bad[..], &mut got), Err(FrameError::BadVersion(99))));
        let mut bad = good.clone();
        bad[2] = 7;
        assert!(matches!(read_frame(&mut &bad[..], &mut got), Err(FrameError::BadKind(7))));
    }

    #[test]
    fn rejects_oversized_length() {
        let mut hdr = encode_header(0, 0, (MAX_PAYLOAD + 1) as u32).to_vec();
        hdr.extend_from_slice(&[0u8; 4]);
        let mut got = Vec::new();
        assert!(matches!(read_frame(&mut &hdr[..], &mut got), Err(FrameError::TooLarge(_))));
    }

    #[test]
    fn every_strict_prefix_is_truncated() {
        let wire = frame_bytes(5, 3, b"0123456789");
        let mut got = Vec::new();
        for cut in 1..wire.len() {
            let r = read_frame(&mut &wire[..cut], &mut got);
            assert!(matches!(r, Err(FrameError::Truncated)), "prefix {cut} must not parse");
        }
    }
}
