//! Seeded network-chaos injection: packet loss, duplication,
//! reordering, and slow links — the transport-level failure surface
//! beyond the crash/straggle fates of
//! [`crate::comm::transport::FailurePlan`].
//!
//! Every draw is a pure function of `(seed, round, cid)`, so a chaos
//! run replays bit-for-bit from its seed — the chaos-soak CI job
//! reprints exactly this seed on failure and the failing round can be
//! re-run locally with the same knobs. Each failure mode draws from its
//! *own* sub-stream (a per-mode label mixed into the seed), so turning
//! one knob never shifts another mode's draws: a run with
//! `loss_prob = 0.3` sees the same duplication pattern whether
//! reordering is on or off.
//!
//! Semantics (shared by the in-process and socket transports — both
//! evaluate the same [`LinkFate`], which is what makes their survivor
//! sets identical by construction):
//!
//! * **loss** — each transmission attempt is independently lost with
//!   `loss_prob`; the sender retries up to `max_retries` times. A frame
//!   whose every attempt is lost never reaches the server and the
//!   client is classified as dropped (the server cannot distinguish a
//!   black-holed link from a crashed client). Surviving retries cost
//!   simulated time: each lost attempt adds one full delivery duration.
//! * **duplication** — the frame is delivered twice; the server dedups
//!   by client id (first copy wins, the duplicate is discarded and not
//!   metered). On the socket transports the duplicate actually crosses
//!   the wire.
//! * **reordering** — the frame arrives out of send order (on the
//!   socket transports it is physically delayed behind later sends; the
//!   server's resequencing fold restores ascending-cid order, which is
//!   why reordering never changes the aggregate — see PERF.md).
//! * **slow link** — delivery time is multiplied by `slow_factor`,
//!   which can push a frame past a finite straggler deadline.

use crate::util::rng::Rng;

// Per-mode sub-stream labels (arbitrary constants).
const LABEL_LOSS: u64 = 0x6c_6f_73_73; // "loss"
const LABEL_DUP: u64 = 0x64_75_70; // "dup"
const LABEL_REORDER: u64 = 0x72_65_6f_72; // "reor"
const LABEL_SLOW: u64 = 0x73_6c_6f_77; // "slow"

/// Seeded chaos knobs. All probabilities are per `(round, cid)` frame.
#[derive(Clone, Copy, Debug)]
pub struct ChaosPlan {
    /// Per-attempt transmission-loss probability (0.0 = off).
    pub loss_prob: f64,
    /// Probability the frame is delivered twice.
    pub dup_prob: f64,
    /// Probability the frame arrives out of send order.
    pub reorder_prob: f64,
    /// Probability the link runs at `slow_factor`× delivery time.
    pub slow_prob: f64,
    /// Delivery-time multiplier for slow links (≥ 1).
    pub slow_factor: f64,
    /// Retransmission attempts after a lost one; a frame losing all
    /// `max_retries + 1` attempts never arrives.
    pub max_retries: u32,
    /// Chaos seed (independent of the [`FailurePlan`] seed).
    ///
    /// [`FailurePlan`]: crate::comm::transport::FailurePlan
    pub seed: u64,
}

/// What the chaos plan decided about one frame's link.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFate {
    /// Leading transmission attempts lost; the frame arrives on attempt
    /// `lost_attempts` unless that exceeds `max_retries`.
    pub lost_attempts: u32,
    /// Frame is delivered twice (server dedups).
    pub duplicate: bool,
    /// `Some(slots)` = frame is reordered: held back ~`slots` delivery
    /// slots behind later sends.
    pub reorder: Option<u32>,
    /// Delivery-time multiplier (1.0, or `slow_factor` on a slow link).
    pub slow_mult: f64,
}

impl LinkFate {
    /// A clear link: nothing lost, duplicated, reordered, or slowed.
    pub fn clear() -> Self {
        Self { lost_attempts: 0, duplicate: false, reorder: None, slow_mult: 1.0 }
    }
}

impl Default for ChaosPlan {
    fn default() -> Self {
        Self::none()
    }
}

impl ChaosPlan {
    /// No chaos: every link is clear. [`Self::link_fate`] takes a
    /// zero-cost path (no RNG work).
    pub fn none() -> Self {
        Self {
            loss_prob: 0.0,
            dup_prob: 0.0,
            reorder_prob: 0.0,
            slow_prob: 0.0,
            slow_factor: 4.0,
            max_retries: 3,
            seed: 0,
        }
    }

    /// Is any chaos mode live?
    pub fn enabled(&self) -> bool {
        self.loss_prob > 0.0
            || self.dup_prob > 0.0
            || self.reorder_prob > 0.0
            || self.slow_prob > 0.0
    }

    /// Can chaos alone make a frame vanish (loss exhausting every
    /// retry)? Rounds then need rollback snapshots even with the
    /// crash/straggle plan disabled.
    pub fn can_drop(&self) -> bool {
        self.loss_prob > 0.0
    }

    fn stream(&self, label: u64, round: u64, cid: u32) -> Rng {
        Rng::new(
            self.seed
                ^ label.wrapping_mul(0xA076_1D64_78BD_642F)
                ^ ((cid as u64) << 32)
                ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        )
    }

    /// Decide one frame's link fate. Pure in `(seed, round, cid)` —
    /// replayable, and independent per failure mode (per-mode
    /// sub-streams).
    pub fn link_fate(&self, round: u64, cid: u32) -> LinkFate {
        if !self.enabled() {
            return LinkFate::clear();
        }
        let mut lost_attempts = 0u32;
        if self.loss_prob > 0.0 {
            let mut r = self.stream(LABEL_LOSS, round, cid);
            while lost_attempts <= self.max_retries && r.next_f64() < self.loss_prob {
                lost_attempts += 1;
            }
        }
        let duplicate =
            self.dup_prob > 0.0 && self.stream(LABEL_DUP, round, cid).next_f64() < self.dup_prob;
        let reorder = if self.reorder_prob > 0.0 {
            let mut r = self.stream(LABEL_REORDER, round, cid);
            (r.next_f64() < self.reorder_prob).then(|| 1 + r.below(15) as u32)
        } else {
            None
        };
        let slow_mult = if self.slow_prob > 0.0
            && self.stream(LABEL_SLOW, round, cid).next_f64() < self.slow_prob
        {
            self.slow_factor
        } else {
            1.0
        };
        LinkFate { lost_attempts, duplicate, reorder, slow_mult }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_plan(seed: u64) -> ChaosPlan {
        ChaosPlan {
            loss_prob: 0.4,
            dup_prob: 0.4,
            reorder_prob: 0.4,
            slow_prob: 0.4,
            seed,
            ..ChaosPlan::none()
        }
    }

    #[test]
    fn disabled_plan_is_always_clear() {
        let p = ChaosPlan::none();
        assert!(!p.enabled() && !p.can_drop());
        for round in 0..4 {
            for cid in 0..8 {
                assert_eq!(p.link_fate(round, cid), LinkFate::clear());
            }
        }
    }

    #[test]
    fn fate_is_deterministic_and_varies() {
        let p = busy_plan(7);
        for round in 0..4 {
            for cid in 0..16 {
                assert_eq!(p.link_fate(round, cid), p.link_fate(round, cid));
            }
        }
        // every mode actually fires somewhere in a modest sweep
        let fates: Vec<LinkFate> =
            (0..64).flat_map(|r| (0..16).map(move |c| (r, c))).map(|(r, c)| p.link_fate(r, c)).collect();
        assert!(fates.iter().any(|f| f.lost_attempts > 0));
        assert!(fates.iter().any(|f| f.duplicate));
        assert!(fates.iter().any(|f| f.reorder.is_some()));
        assert!(fates.iter().any(|f| f.slow_mult > 1.0));
        assert!(fates.iter().any(|f| *f == LinkFate::clear()));
    }

    #[test]
    fn modes_draw_from_independent_streams() {
        // turning reordering on must not change the loss/dup/slow draws
        let without = ChaosPlan { reorder_prob: 0.0, ..busy_plan(11) };
        let with = ChaosPlan { reorder_prob: 0.9, ..busy_plan(11) };
        for round in 0..8 {
            for cid in 0..16 {
                let a = without.link_fate(round, cid);
                let b = with.link_fate(round, cid);
                assert_eq!(a.lost_attempts, b.lost_attempts);
                assert_eq!(a.duplicate, b.duplicate);
                assert_eq!(a.slow_mult, b.slow_mult);
            }
        }
    }

    #[test]
    fn certain_loss_exhausts_retries() {
        let p = ChaosPlan { loss_prob: 1.0, max_retries: 3, seed: 1, ..ChaosPlan::none() };
        let f = p.link_fate(0, 0);
        assert!(f.lost_attempts > p.max_retries, "all attempts lost");
    }

    #[test]
    fn reorder_slots_are_bounded_and_positive() {
        let p = ChaosPlan { reorder_prob: 1.0, seed: 3, ..ChaosPlan::none() };
        for cid in 0..64 {
            let slots = p.link_fate(0, cid).reorder.expect("certain reorder");
            assert!((1..=15).contains(&slots));
        }
    }
}
