//! Bandwidth/latency network model — turns the byte ledger into the
//! simulated wall-clock argument of §5.1 ("in the same network
//! environment, the time required to complete a round of sparse
//! updates is much smaller").
//!
//! Default profile mirrors the paper's asymmetric-uplink observation
//! ("upload bandwidth of the device is generally far less than the
//! download bandwidth"): 10 Mbps up / 50 Mbps down / 30 ms RTT.

/// Link profile for one client.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Uplink, bits per second.
    pub up_bps: f64,
    /// Downlink, bits per second.
    pub down_bps: f64,
    /// Per-message latency, seconds.
    pub rtt_s: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self { up_bps: 10e6, down_bps: 50e6, rtt_s: 0.030 }
    }
}

impl NetworkModel {
    /// Seconds to upload `bytes`.
    pub fn upload_time(&self, bytes: u64) -> f64 {
        self.rtt_s / 2.0 + bytes as f64 * 8.0 / self.up_bps
    }

    /// Seconds to download `bytes`.
    pub fn download_time(&self, bytes: u64) -> f64 {
        self.rtt_s / 2.0 + bytes as f64 * 8.0 / self.down_bps
    }

    /// Simulated duration of one synchronous round: every selected
    /// client downloads the model then uploads its update in parallel;
    /// the round ends when the **slowest** client finishes (barrier).
    pub fn round_time(&self, down_bytes_per_client: u64, up_bytes: &[u64]) -> f64 {
        up_bytes
            .iter()
            .map(|&u| self.download_time(down_bytes_per_client) + self.upload_time(u))
            .fold(0.0, f64::max)
    }

    /// §5.1's headline ratio: wall-clock speedup of sparse vs dense
    /// rounds with identical round counts.
    pub fn speedup(&self, dense_up: u64, sparse_up: u64, down: u64) -> f64 {
        self.round_time(down, &[dense_up]) / self.round_time(down, &[sparse_up])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asymmetric_links() {
        let n = NetworkModel::default();
        let b = 10_000_000u64; // 10 MB
        assert!(n.upload_time(b) > n.download_time(b));
    }

    #[test]
    fn round_time_is_slowest_client() {
        let n = NetworkModel::default();
        let t = n.round_time(1000, &[1_000, 1_000_000]);
        let slow = n.download_time(1000) + n.upload_time(1_000_000);
        assert!((t - slow).abs() < 1e-12);
    }

    #[test]
    fn sparse_speedup_positive() {
        let n = NetworkModel::default();
        // 159k params: dense 1.27MB vs 1% sparse ~19kB
        let s = n.speedup(1_272_080, 19_081, 1_272_080);
        assert!(s > 1.5, "speedup={s}");
    }

    #[test]
    fn latency_floor() {
        let n = NetworkModel::default();
        assert!(n.upload_time(0) >= n.rtt_s / 2.0);
    }
}
