//! Communication accounting + network layer (DESIGN.md S16).
//!
//! * [`cost`] — the paper's §5.2 analytic cost model (Eq. 6-8) and the
//!   per-round ledger behind Table 2
//! * [`channel`] — bandwidth/latency model turning bytes into simulated
//!   wall-clock round time (the §5.1 "from the perspective of time"
//!   argument)
//! * [`transport`] — the [`transport::Uplink`] trait every Collect
//!   barrier runs through, plus the in-process implementation with
//!   seeded dropout/straggler failure injection — the deterministic
//!   twin the golden tests pin
//! * [`chaos`] — seeded network chaos (loss, duplication, reordering,
//!   slow links), pure in `(seed, round, cid)` so runs replay
//! * [`frame`] — the length-delimited socket wire frame
//! * [`socket`] — real TCP / Unix-domain-socket uplink carrying the
//!   same payload bytes (conformance-pinned against the twin)

pub mod channel;
pub mod chaos;
pub mod cost;
pub mod frame;
pub mod socket;
pub mod transport;

pub use channel::NetworkModel;
pub use chaos::{ChaosPlan, LinkFate};
pub use cost::{CostLedger, RoundCost};
pub use socket::{SocketOptions, SocketTransport};
pub use transport::{
    effective_fate, Accepted, CollectResult, Delivery, EffectiveFate, FailurePlan, Fate, Transport,
    Uplink, UplinkFrame,
};
