//! Communication accounting + simulated network (DESIGN.md S16).
//!
//! * [`cost`] — the paper's §5.2 analytic cost model (Eq. 6-8) and the
//!   per-round ledger behind Table 2
//! * [`channel`] — bandwidth/latency model turning bytes into simulated
//!   wall-clock round time (the §5.1 "from the perspective of time"
//!   argument)
//! * [`transport`] — the in-process uplink actually carrying encoded
//!   payloads, with seeded dropout/straggler failure injection (the
//!   round engine's Collect phase)

pub mod channel;
pub mod cost;
pub mod transport;

pub use channel::NetworkModel;
pub use cost::{CostLedger, RoundCost};
pub use transport::{CollectResult, Delivery, FailurePlan, Fate, Transport, UplinkFrame};
