//! Real-wire uplink: the same payload bytes the in-process transport
//! carries, framed ([`crate::comm::frame`]) over TCP or Unix-domain
//! sockets.
//!
//! One [`SocketTransport`] owns a listening socket for its lifetime
//! (bound at construction, UDS path unlinked on drop). Each Collect
//! barrier is a full connection lifecycle: client sender threads
//! connect, write their frame(s), and close; an acceptor thread hands
//! every accepted connection to a reader that validates frame headers
//! (stale-round frames are discarded at the door) and pushes payloads
//! into a [`BoundedQueue`] — readers block when the consumer falls
//! behind, so a fast cohort cannot balloon server memory
//! (backpressure). The caller's thread consumes the queue under a
//! real timer deadline and folds payloads through the sink.
//!
//! **Determinism on a real wire.** Who survives, when they "arrive"
//! (simulated seconds), and what the round costs are all decided by the
//! same pure [`effective_fate`] the in-process twin evaluates — the
//! socket layer *enacts* those decisions (a crash/loss-fated sender
//! never transmits; a duplicate-fated sender writes its frame twice; a
//! reorder-fated sender is physically held back behind later sends)
//! rather than re-deciding them from racy wall-clock measurements. TCP
//! arrival order is nondeterministic, so the consumer **resequences**:
//! it knows the deliver-fated client ids (ascending), parks
//! out-of-order arrivals, and invokes the sink for the longest
//! contiguous prefix as frames land — the sink sees ascending client
//! id, the pinned fold order, making the folded aggregate bitwise
//! equal to the in-process run (PERF.md; pinned by
//! `tests/transport_conformance.rs`).
//!
//! The real timer ([`SocketOptions::accept_deadline`]) is a hang
//! backstop, not the straggler deadline — straggler classification is
//! plan time. Per the deadline boundary contract
//! ([`FailurePlan::on_time`]), the queue is always checked **before**
//! the timer ([`BoundedQueue::pop_until`]): a frame that landed at the
//! deadline is never discarded by the timer that noticed the time. A
//! deliver-fated frame still missing when the backstop expires (a
//! genuine hang — impossible under plan semantics) is classified as a
//! straggler so the round degrades or aborts cleanly instead of
//! wedging.

use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};
use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
#[cfg(unix)]
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
#[cfg(unix)]
use std::sync::atomic::AtomicU64;
use std::sync::{mpsc, Condvar, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::comm::chaos::ChaosPlan;
use crate::comm::channel::NetworkModel;
use crate::comm::frame;
use crate::comm::transport::{
    effective_fate, Accepted, CollectResult, Delivery, FailurePlan, Fate, Uplink, UplinkFrame,
};

/// Socket-layer knobs (all real time, not simulated time).
#[derive(Clone, Copy, Debug)]
pub struct SocketOptions {
    /// Hang backstop: how long the consumer waits for deliver-fated
    /// frames before classifying the missing ones as stragglers.
    pub accept_deadline: Duration,
    /// Uplink queue capacity in frames; readers block (backpressure)
    /// when the fold falls this far behind.
    pub queue_cap: usize,
    /// Physical hold-back per reorder slot when enacting a
    /// reorder-fated frame.
    pub reorder_slot_ms: u64,
}

impl Default for SocketOptions {
    fn default() -> Self {
        Self { accept_deadline: Duration::from_secs(5), queue_cap: 64, reorder_slot_ms: 3 }
    }
}

/// Blocking MPSC queue with a bounded capacity: `push` blocks when
/// full (backpressure into the socket readers), `pop_until` blocks
/// until an item, the deadline, or close — **checking the queue before
/// the timer**, so an item that made it in by the deadline is returned
/// even when the call happens after expiry (the off-by-frame deadline
/// fix; see the module docs).
pub struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

struct QueueState<T> {
    items: VecDeque<T>,
    closed: bool,
    cap: usize,
}

impl<T> BoundedQueue<T> {
    pub fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(QueueState { items: VecDeque::new(), closed: false, cap: cap.max(1) }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    /// Blocks while full; returns false (item discarded) once closed.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.items.len() >= st.cap && !st.closed {
            st = self.not_full.wait(st).unwrap();
        }
        if st.closed {
            return false;
        }
        st.items.push_back(item);
        self.not_empty.notify_one();
        true
    }

    /// Non-blocking pop.
    pub fn try_pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        let item = st.items.pop_front();
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Pop, waiting until `deadline`. Queue before timer: if an item is
    /// already queued this returns it even when `deadline` has passed;
    /// `None` only when the queue is empty *and* the deadline expired
    /// (or the queue was closed while empty).
    pub fn pop_until(&self, deadline: Instant) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            let (guard, _) = self.not_empty.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    /// Close: wakes every blocked producer (push → false) and consumer.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

/// Where senders connect.
#[derive(Clone, Debug)]
enum Endpoint {
    Tcp(SocketAddr),
    #[cfg(unix)]
    Uds(PathBuf),
}

impl Endpoint {
    /// Connect with a short bounded retry (the acceptor thread may not
    /// be polling yet). Write timeout bounds a wedged peer.
    fn connect(&self, write_timeout: Duration) -> io::Result<Conn> {
        let mut last = io::Error::new(io::ErrorKind::NotConnected, "no connect attempt");
        for _ in 0..40 {
            let attempt = match self {
                Endpoint::Tcp(addr) => TcpStream::connect(addr).map(Conn::Tcp),
                #[cfg(unix)]
                Endpoint::Uds(path) => UnixStream::connect(path).map(Conn::Uds),
            };
            match attempt {
                Ok(conn) => {
                    conn.set_write_timeout(write_timeout)?;
                    return Ok(conn);
                }
                Err(e) => last = e,
            }
            thread::sleep(Duration::from_millis(5));
        }
        Err(last)
    }
}

/// The server-side listening socket (the transport's lifetime-long
/// half of the connection lifecycle).
enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Uds(UnixListener, PathBuf),
}

impl Listener {
    fn accept(&self) -> io::Result<Conn> {
        match self {
            Listener::Tcp(l) => l.accept().map(|(s, _)| Conn::Tcp(s)),
            #[cfg(unix)]
            Listener::Uds(l, _) => l.accept().map(|(s, _)| Conn::Uds(s)),
        }
    }
}

impl Drop for Listener {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Listener::Uds(_, path) = self {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// One accepted or connected stream.
enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Uds(UnixStream),
}

impl Conn {
    fn set_blocking_with_read_timeout(&self, timeout: Duration) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(timeout))
            }
            #[cfg(unix)]
            Conn::Uds(s) => {
                s.set_nonblocking(false)?;
                s.set_read_timeout(Some(timeout))
            }
        }
    }

    fn set_write_timeout(&self, timeout: Duration) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.set_write_timeout(Some(timeout)),
            #[cfg(unix)]
            Conn::Uds(s) => s.set_write_timeout(Some(timeout)),
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Uds(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Uds(s) => s.flush(),
        }
    }
}

/// What one sender thread does with its frame, decided purely from the
/// frame's [`effective_fate`] before any thread spawns.
enum SendAction {
    /// Crash-, loss-exhausted-, or straggle-fated: never transmit (the
    /// server would not have accepted it; keeping it off the wire keeps
    /// the survivor set exactly the plan's).
    Skip,
    /// Deliver-fated: hold back `delay` (enacting reorder/slow/retry
    /// physics), then write `copies` copies of the frame.
    Send { delay: Duration, copies: u32 },
}

/// Framed uplink over a real socket — see the module docs.
pub struct SocketTransport {
    pub network: NetworkModel,
    plan: FailurePlan,
    chaos: ChaosPlan,
    opts: SocketOptions,
    listener: Listener,
    endpoint: Endpoint,
    kind: &'static str,
}

#[cfg(unix)]
static UDS_COUNTER: AtomicU64 = AtomicU64::new(0);

impl SocketTransport {
    /// TCP on a loopback ephemeral port.
    pub fn tcp(network: NetworkModel, plan: FailurePlan, chaos: ChaosPlan) -> Result<Self> {
        Self::tcp_with(network, plan, chaos, SocketOptions::default())
    }

    pub fn tcp_with(
        network: NetworkModel,
        plan: FailurePlan,
        chaos: ChaosPlan,
        opts: SocketOptions,
    ) -> Result<Self> {
        let listener =
            TcpListener::bind(("127.0.0.1", 0)).context("bind tcp uplink listener")?;
        listener.set_nonblocking(true).context("nonblocking tcp listener")?;
        let addr = listener.local_addr().context("tcp listener local addr")?;
        Ok(Self {
            network,
            plan,
            chaos,
            opts,
            listener: Listener::Tcp(listener),
            endpoint: Endpoint::Tcp(addr),
            kind: "tcp",
        })
    }

    /// Unix-domain socket on a fresh temp path (unlinked on drop).
    #[cfg(unix)]
    pub fn uds(network: NetworkModel, plan: FailurePlan, chaos: ChaosPlan) -> Result<Self> {
        Self::uds_with(network, plan, chaos, SocketOptions::default())
    }

    #[cfg(unix)]
    pub fn uds_with(
        network: NetworkModel,
        plan: FailurePlan,
        chaos: ChaosPlan,
        opts: SocketOptions,
    ) -> Result<Self> {
        let path = std::env::temp_dir().join(format!(
            "fedsparse-uds-{}-{}.sock",
            std::process::id(),
            UDS_COUNTER.fetch_add(1, Ordering::Relaxed),
        ));
        let _ = std::fs::remove_file(&path);
        let listener = UnixListener::bind(&path)
            .with_context(|| format!("bind uds uplink listener at {}", path.display()))?;
        listener.set_nonblocking(true).context("nonblocking uds listener")?;
        Ok(Self {
            network,
            plan,
            chaos,
            opts,
            listener: Listener::Uds(listener, path.clone()),
            endpoint: Endpoint::Uds(path),
            kind: "uds",
        })
    }
}

/// Reader half of one accepted connection: frames in, queue out.
/// Stale-round (or malformed) frames are discarded; a closed queue
/// (consumer done) ends the reader.
fn read_conn(mut conn: Conn, round: u64, q: &BoundedQueue<(u32, Vec<u8>)>, timeout: Duration) {
    if conn.set_blocking_with_read_timeout(timeout).is_err() {
        return;
    }
    let mut buf = Vec::new();
    loop {
        match frame::read_frame(&mut conn, &mut buf) {
            Ok(Some(hdr)) => {
                if hdr.round != round {
                    continue; // stale: a previous round's late duplicate
                }
                if !q.push((hdr.cid, std::mem::take(&mut buf))) {
                    break;
                }
            }
            Ok(None) | Err(_) => break,
        }
    }
}

impl Uplink for SocketTransport {
    fn collect_with(
        &mut self,
        round: u64,
        down_bytes: u64,
        frames: Vec<UplinkFrame>,
        sink: &mut dyn FnMut(Delivery),
    ) -> Result<CollectResult> {
        let mut out = CollectResult::default();
        let down_s = self.network.download_time(down_bytes);
        let slot = Duration::from_millis(self.opts.reorder_slot_ms);

        // ---- pure classification, identical to the in-process twin --
        // `expected` = deliver-fated cids ascending (frames arrive in
        // ascending submission order); `meta` = their plan arrival
        // times + framed sizes for the resequencing fold below.
        let mut expected: Vec<u32> = Vec::new();
        let mut meta: HashMap<u32, (f64, usize)> = HashMap::new();
        let mut senders: Vec<(u32, Vec<u8>, SendAction)> = Vec::with_capacity(frames.len());
        for f in frames {
            let base = down_s + self.network.upload_time(f.paper_bytes);
            let eff = effective_fate(&self.plan, &self.chaos, round, f.cid, base);
            match eff.fate {
                Fate::Deliver { at_s } => {
                    out.round_time_s = out.round_time_s.max(at_s);
                    if eff.link.duplicate {
                        out.duplicates += 1;
                    }
                    if eff.link.reorder.is_some() {
                        out.reordered += 1;
                    }
                    expected.push(f.cid);
                    meta.insert(f.cid, (at_s, frame::framed_len(f.bytes.len())));
                    // enact the chaos physically: reordered frames are
                    // held back behind later sends, lossy links pay a
                    // beat per lost attempt, slow links one extra slot
                    let mut delay = Duration::ZERO;
                    if let Some(slots) = eff.link.reorder {
                        delay += slot * slots;
                    }
                    delay += Duration::from_millis(2) * eff.link.lost_attempts;
                    if eff.link.slow_mult > 1.0 {
                        delay += slot;
                    }
                    let copies = if eff.link.duplicate { 2 } else { 1 };
                    senders.push((f.cid, f.bytes, SendAction::Send { delay, copies }));
                }
                Fate::Drop => {
                    if eff.chaos_lost {
                        out.chaos_lost += 1;
                    }
                    out.dropped.push(f.cid);
                    senders.push((f.cid, f.bytes, SendAction::Skip));
                }
                Fate::Timeout { .. } => {
                    out.timed_out.push(f.cid);
                    senders.push((f.cid, f.bytes, SendAction::Skip));
                }
            }
        }
        if (!out.timed_out.is_empty() || !out.dropped.is_empty())
            && self.plan.straggler_timeout_s.is_finite()
        {
            out.round_time_s = out.round_time_s.max(self.plan.straggler_timeout_s);
        }

        // ---- real wire: acceptor + readers + senders + consumer -----
        let queue = BoundedQueue::new(self.opts.queue_cap);
        let stop = AtomicBool::new(false);
        let (spent_tx, spent_rx) = mpsc::channel::<Vec<u8>>();
        let io_timeout = self.opts.accept_deadline;
        let endpoint = &self.endpoint;
        let listener = &self.listener;

        thread::scope(|s| {
            let q = &queue;
            let stop = &stop;
            // acceptor: nonblocking poll so it can wind down when the
            // barrier closes; each accepted connection gets a reader
            s.spawn(move || loop {
                if stop.load(Ordering::Acquire) {
                    break;
                }
                match listener.accept() {
                    Ok(conn) => {
                        s.spawn(move || read_conn(conn, round, q, io_timeout));
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(1));
                    }
                    Err(_) => break,
                }
            });
            // senders: one per selected client, enacting its fate; the
            // wire buffer comes back through `spent` either way so the
            // caller's pool stays warm
            for (cid, bytes, action) in senders {
                let tx = spent_tx.clone();
                s.spawn(move || {
                    if let SendAction::Send { delay, copies } = action {
                        if !delay.is_zero() {
                            thread::sleep(delay);
                        }
                        if let Ok(mut conn) = endpoint.connect(io_timeout) {
                            for _ in 0..copies {
                                if frame::write_frame(&mut conn, round, cid, &bytes).is_err() {
                                    break;
                                }
                            }
                            let _ = conn.flush();
                        }
                    }
                    let _ = tx.send(bytes);
                });
            }
            drop(spent_tx);

            // consumer (this thread): resequencing streaming fold —
            // park out-of-order arrivals, sink the longest contiguous
            // prefix of `expected`, so the sink sees ascending cid
            let deadline = Instant::now() + self.opts.accept_deadline;
            let mut pending: BTreeMap<u32, Vec<u8>> = BTreeMap::new();
            let mut arrived: HashSet<u32> = HashSet::new();
            let mut next = 0usize;
            let mut handle = |cid: u32,
                              payload: Vec<u8>,
                              pending: &mut BTreeMap<u32, Vec<u8>>,
                              arrived: &mut HashSet<u32>,
                              next: &mut usize| {
                // dedup by cid (first copy wins) and ignore unexpected
                // senders — both indistinguishable from replays
                if !meta.contains_key(&cid) || !arrived.insert(cid) {
                    return;
                }
                pending.insert(cid, payload);
                while *next < expected.len() {
                    let want = expected[*next];
                    match pending.remove(&want) {
                        Some(bytes) => {
                            let (at_s, framed) = meta[&want];
                            out.delivered.push(Accepted { cid: want, at_s, framed });
                            sink(Delivery { cid: want, bytes, at_s });
                            *next += 1;
                        }
                        None => break,
                    }
                }
            };
            while next < expected.len() {
                match queue.pop_until(deadline) {
                    Some((cid, payload)) => {
                        handle(cid, payload, &mut pending, &mut arrived, &mut next)
                    }
                    None => break,
                }
            }
            // drain-after-expiry: anything already queued when the
            // backstop fired still made it in time
            while next < expected.len() {
                match queue.try_pop() {
                    Some((cid, payload)) => {
                        handle(cid, payload, &mut pending, &mut arrived, &mut next)
                    }
                    None => break,
                }
            }
            // leftover parked frames: all are expected cids beyond the
            // contiguous prefix — BTreeMap iteration keeps the total
            // sink order ascending
            for (cid, bytes) in std::mem::take(&mut pending) {
                let (at_s, framed) = meta[&cid];
                out.delivered.push(Accepted { cid, at_s, framed });
                sink(Delivery { cid, bytes, at_s });
            }
            // backstop: a deliver-fated frame that never physically
            // arrived (hang/failure) degrades to a straggler
            for &cid in &expected {
                if !arrived.contains(&cid) {
                    out.timed_out.push(cid);
                }
            }
            out.timed_out.sort_unstable();

            // wind down: close the queue (unblocks readers mid-push),
            // stop the acceptor; scope joins every thread
            stop.store(true, Ordering::Release);
            queue.close();
        });

        out.spent = spent_rx.try_iter().collect();
        Ok(out)
    }

    fn plan(&self) -> &FailurePlan {
        &self.plan
    }

    fn chaos(&self) -> &ChaosPlan {
        &self.chaos
    }

    fn kind(&self) -> &'static str {
        self.kind
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn frames(n: u32) -> Vec<UplinkFrame> {
        (0..n)
            .map(|cid| UplinkFrame {
                cid,
                bytes: vec![cid as u8; 64 + cid as usize],
                paper_bytes: 100,
            })
            .collect()
    }

    fn run(
        t: &mut SocketTransport,
        round: u64,
        fr: Vec<UplinkFrame>,
    ) -> (CollectResult, Vec<Delivery>) {
        let mut got = Vec::new();
        let out = t.collect_with(round, 1_000, fr, &mut |d| got.push(d)).unwrap();
        (out, got)
    }

    #[test]
    fn bounded_queue_backpressure_blocks_push() {
        let q = BoundedQueue::new(1);
        assert!(q.push(1));
        let landed = AtomicUsize::new(0);
        thread::scope(|s| {
            s.spawn(|| {
                assert!(q.push(2)); // blocks until the pop below
                landed.store(1, Ordering::SeqCst);
            });
            thread::sleep(Duration::from_millis(30));
            assert_eq!(landed.load(Ordering::SeqCst), 0, "push must block while full");
            assert_eq!(q.try_pop(), Some(1));
        });
        assert_eq!(landed.load(Ordering::SeqCst), 1);
        assert_eq!(q.try_pop(), Some(2));
    }

    #[test]
    fn pop_until_checks_queue_before_timer() {
        // the off-by-frame deadline fix: an item queued by the deadline
        // is returned even when the call happens after expiry
        let q = BoundedQueue::new(4);
        assert!(q.push(7));
        let past = Instant::now() - Duration::from_millis(50);
        assert_eq!(q.pop_until(past), Some(7), "queue before timer");
        assert_eq!(q.pop_until(past), None, "then the expired timer rules");
    }

    #[test]
    fn queue_close_unblocks_producers_and_consumers() {
        let q = BoundedQueue::new(1);
        assert!(q.push(1));
        thread::scope(|s| {
            s.spawn(|| {
                assert!(!q.push(2), "push into a closed queue reports false");
            });
            thread::sleep(Duration::from_millis(20));
            q.close();
        });
        // close with an item still queued: consumer drains, then None
        assert_eq!(q.try_pop(), Some(1));
        assert_eq!(q.pop_until(Instant::now() + Duration::from_secs(1)), None);
    }

    #[test]
    fn tcp_delivers_ascending_with_payloads_intact() {
        let mut t =
            SocketTransport::tcp(NetworkModel::default(), FailurePlan::none(), ChaosPlan::none())
                .unwrap();
        for round in 0..2 {
            // two rounds on one transport: the listener persists
            let (out, got) = run(&mut t, round, frames(6));
            assert_eq!(got.len(), 6);
            for (i, d) in got.iter().enumerate() {
                assert_eq!(d.cid, i as u32, "sink order is ascending cid");
                assert_eq!(d.bytes, vec![i as u8; 64 + i], "payload bytes intact");
            }
            assert_eq!(out.delivered.len(), 6);
            for a in &out.delivered {
                assert_eq!(a.framed, 64 + a.cid as usize + frame::HEADER_LEN);
            }
            assert!(out.dropped.is_empty() && out.timed_out.is_empty());
            // all sender buffers recycle back
            assert_eq!(out.spent.len(), 6);
        }
    }

    #[test]
    fn tcp_duplicates_are_deduplicated() {
        let chaos = ChaosPlan { dup_prob: 1.0, seed: 3, ..ChaosPlan::none() };
        let mut t =
            SocketTransport::tcp(NetworkModel::default(), FailurePlan::none(), chaos).unwrap();
        let (out, got) = run(&mut t, 0, frames(5));
        assert_eq!(got.len(), 5, "each cid folded exactly once");
        assert_eq!(out.duplicates, 5);
        assert_eq!(out.delivered.len(), 5);
    }

    #[test]
    fn tcp_matches_inproc_classification_and_bytes() {
        use crate::comm::transport::Transport;
        let plan = FailurePlan { dropout_prob: 0.3, seed: 41, ..FailurePlan::none() };
        let chaos =
            ChaosPlan { loss_prob: 0.3, reorder_prob: 0.6, seed: 43, ..ChaosPlan::none() };
        let mut inproc = Transport::with_chaos(NetworkModel::default(), plan, chaos);
        let mut tcp =
            SocketTransport::tcp(NetworkModel::default(), plan, chaos).unwrap();
        let mut got_a = Vec::new();
        let a = inproc.collect_with(1, 1_000, frames(10), &mut |d| got_a.push(d)).unwrap();
        let mut got_b = Vec::new();
        let b = tcp.collect_with(1, 1_000, frames(10), &mut |d| got_b.push(d)).unwrap();
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.timed_out, b.timed_out);
        assert_eq!(a.chaos_lost, b.chaos_lost);
        assert_eq!(a.reordered, b.reordered);
        assert_eq!(a.round_time_s.to_bits(), b.round_time_s.to_bits());
        assert_eq!(got_a.len(), got_b.len());
        for (x, y) in got_a.iter().zip(&got_b) {
            assert_eq!(x.cid, y.cid);
            assert_eq!(x.bytes, y.bytes, "payload bytes identical across transports");
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
        }
    }

    #[cfg(unix)]
    #[test]
    fn uds_delivers_ascending_with_payloads_intact() {
        let mut t =
            SocketTransport::uds(NetworkModel::default(), FailurePlan::none(), ChaosPlan::none())
                .unwrap();
        let (out, got) = run(&mut t, 0, frames(4));
        assert_eq!(got.len(), 4);
        for (i, d) in got.iter().enumerate() {
            assert_eq!(d.cid, i as u32);
            assert_eq!(d.bytes, vec![i as u8; 64 + i]);
        }
        assert_eq!(out.delivered.len(), 4);
        assert_eq!(t.kind(), "uds");
    }

    #[cfg(unix)]
    #[test]
    fn uds_socket_path_is_unlinked_on_drop() {
        let t =
            SocketTransport::uds(NetworkModel::default(), FailurePlan::none(), ChaosPlan::none())
                .unwrap();
        let path = match &t.listener {
            Listener::Uds(_, p) => p.clone(),
            _ => unreachable!(),
        };
        assert!(path.exists());
        drop(t);
        assert!(!path.exists(), "drop unlinks the socket file");
    }
}
