//! In-process uplink transport with failure injection — the Collect
//! phase's substrate.
//!
//! [`crate::comm::channel::NetworkModel`] prices a byte; this module
//! actually *carries* the bytes: each selected client hands the
//! transport its encoded payload ([`UplinkFrame`]), and the transport
//! decides — deterministically, from a seeded [`FailurePlan`] — whether
//! that upload arrives, arrives late (straggler past the collect
//! deadline), or never arrives at all (client crashed mid-round). The
//! server side of the round only ever sees [`CollectResult::delivered`];
//! everything downstream (aggregation, secure-mask recovery, metrics)
//! operates on survivors.
//!
//! Fidelity notes:
//! * Delivery *time* uses the paper's §5.2 cost model bytes (so the
//!   simulated round time stays comparable to §5.1's argument), while
//!   the *metered* bytes handed to the [`crate::comm::cost::CostLedger`]
//!   are the actual wire bytes delivered.
//! * Failure draws are a pure function of `(plan seed, round, client)`,
//!   so any run — including which clients die where — replays exactly.
//! * The transport is payload-format-agnostic: a frame's bytes may be
//!   an f32 [`crate::sparse::codec`] encoding, a bitpacked quantized
//!   frame ([`crate::sparse::quant`]), or a masked secure payload —
//!   it carries and meters them identically. Delivered buffers are
//!   moved (never copied) from client encode through to the server
//!   fold, which recycles them; a dropped client's buffer dies here,
//!   which is the only round path that lets a wire buffer leave the
//!   reuse pool.

use crate::comm::channel::NetworkModel;
use crate::util::rng::Rng;

/// What the transport decided about one client's upload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fate {
    /// Arrived before the deadline, at simulated time `at_s`.
    Deliver { at_s: f64 },
    /// Client crashed before its upload left (never delivers).
    Drop,
    /// Upload exists but lands after the collect deadline; the server
    /// has already closed the round.
    Timeout { at_s: f64 },
}

/// Mean of the exponential straggler delay factor applied when a
/// finite collect deadline is configured (delivery time is scaled by
/// `1 + Exp(scale)` — heavy-tailed, like real mobile uplinks).
pub const DEFAULT_STRAGGLER_SCALE: f64 = 0.5;

/// Seeded per-round failure injection: which selected clients crash,
/// which straggle past the deadline.
#[derive(Clone, Copy, Debug)]
pub struct FailurePlan {
    /// Per-round probability a selected client crashes before its
    /// upload arrives (0.0 = off).
    pub dropout_prob: f64,
    /// Server-side collect deadline in simulated seconds;
    /// `f64::INFINITY` disables the deadline.
    pub straggler_timeout_s: f64,
    /// Mean of the exponential delay factor (0.0 = deliveries take
    /// exactly their modeled time).
    pub straggler_scale: f64,
    /// Plan seed (mixed with round and client id per draw).
    pub seed: u64,
}

impl FailurePlan {
    /// No failure injection: every upload arrives on time. The round
    /// engine takes a zero-overhead path (no state snapshots) when the
    /// plan is disabled.
    pub fn none() -> Self {
        Self {
            dropout_prob: 0.0,
            straggler_timeout_s: f64::INFINITY,
            straggler_scale: 0.0,
            seed: 0,
        }
    }

    /// Is any failure mode live?
    pub fn enabled(&self) -> bool {
        self.dropout_prob > 0.0 || self.straggler_timeout_s.is_finite()
    }

    /// Decide one client's fate this round. `base_time_s` is the
    /// failure-free delivery time (download + upload under the network
    /// model). Pure in `(seed, round, cid)` — replayable.
    pub fn fate(&self, round: u64, cid: u32, base_time_s: f64) -> Fate {
        if !self.enabled() {
            return Fate::Deliver { at_s: base_time_s };
        }
        let mut rng = Rng::new(
            self.seed ^ ((cid as u64) << 32) ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        if rng.next_f64() < self.dropout_prob {
            return Fate::Drop;
        }
        let jitter = if self.straggler_scale > 0.0 {
            -(1.0 - rng.next_f64()).ln() * self.straggler_scale
        } else {
            0.0
        };
        let at_s = base_time_s * (1.0 + jitter);
        if at_s > self.straggler_timeout_s {
            Fate::Timeout { at_s }
        } else {
            Fate::Deliver { at_s }
        }
    }
}

/// One client's upload as handed to the transport.
#[derive(Clone, Debug)]
pub struct UplinkFrame {
    pub cid: u32,
    /// Encoded payload ([`crate::sparse::codec::SparseVec::encode`]).
    pub bytes: Vec<u8>,
    /// Paper-model (§5.2) upload size, used for the simulated delivery
    /// time so round timing stays comparable to Eq. 7/8.
    pub paper_bytes: u64,
}

/// A frame that made it to the server before the deadline.
#[derive(Clone, Debug)]
pub struct Delivery {
    pub cid: u32,
    pub bytes: Vec<u8>,
    /// Simulated arrival time, seconds from round start.
    pub at_s: f64,
}

/// What one Collect phase yielded.
#[derive(Clone, Debug, Default)]
pub struct CollectResult {
    /// Frames that arrived in time, in send (selection) order. The
    /// caller meters these bytes into the cost ledger (failed uploads
    /// never reached the server, so they are not metered).
    pub delivered: Vec<Delivery>,
    /// Clients that crashed (no upload ever existed server-side).
    pub dropped: Vec<u32>,
    /// Clients whose upload landed after the deadline (excluded).
    pub timed_out: Vec<u32>,
    /// Simulated wall-clock of the round's communication barrier: the
    /// slowest accepted delivery — or the deadline itself when any
    /// upload was still missing at close (the server cannot know a
    /// crashed client will never send, so it waits the deadline out).
    pub round_time_s: f64,
}

/// The in-process uplink: prices deliveries with the [`NetworkModel`]
/// and filters them through the [`FailurePlan`].
#[derive(Clone, Copy, Debug)]
pub struct Transport {
    pub network: NetworkModel,
    pub plan: FailurePlan,
}

impl Transport {
    pub fn new(network: NetworkModel, plan: FailurePlan) -> Self {
        Self { network, plan }
    }

    /// Run one Collect barrier: every client first downloads the dense
    /// model (`down_bytes`), then uploads its frame; the plan decides
    /// who survives. Frames keep their submission order.
    pub fn collect(&self, round: u64, down_bytes: u64, frames: Vec<UplinkFrame>) -> CollectResult {
        let mut out = CollectResult::default();
        let down_s = self.network.download_time(down_bytes);
        for frame in frames {
            let base = down_s + self.network.upload_time(frame.paper_bytes);
            match self.plan.fate(round, frame.cid, base) {
                Fate::Deliver { at_s } => {
                    out.round_time_s = out.round_time_s.max(at_s);
                    out.delivered.push(Delivery { cid: frame.cid, bytes: frame.bytes, at_s });
                }
                Fate::Drop => out.dropped.push(frame.cid),
                Fate::Timeout { .. } => out.timed_out.push(frame.cid),
            }
        }
        // the server holds the barrier open until the deadline when any
        // upload — straggling or crashed — is still missing at close
        // (it cannot distinguish the two until the deadline passes)
        if (!out.timed_out.is_empty() || !out.dropped.is_empty())
            && self.plan.straggler_timeout_s.is_finite()
        {
            out.round_time_s = out.round_time_s.max(self.plan.straggler_timeout_s);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames(n: u32, bytes: usize) -> Vec<UplinkFrame> {
        (0..n)
            .map(|cid| UplinkFrame { cid, bytes: vec![0u8; bytes], paper_bytes: bytes as u64 })
            .collect()
    }

    #[test]
    fn disabled_plan_delivers_everything_at_model_time() {
        let t = Transport::new(NetworkModel::default(), FailurePlan::none());
        let out = t.collect(3, 1_000, frames(5, 2_000));
        assert_eq!(out.delivered.len(), 5);
        assert!(out.dropped.is_empty() && out.timed_out.is_empty());
        // identical to the pre-transport NetworkModel barrier formula
        let expect = NetworkModel::default().round_time(1_000, &[2_000; 5]);
        assert!((out.round_time_s - expect).abs() < 1e-12);
        let wire: usize = out.delivered.iter().map(|d| d.bytes.len()).sum();
        assert_eq!(wire, 5 * 2_000);
    }

    #[test]
    fn fate_is_deterministic_per_round_and_client() {
        let plan = FailurePlan { dropout_prob: 0.5, seed: 7, ..FailurePlan::none() };
        for round in 0..4 {
            for cid in 0..8 {
                assert_eq!(plan.fate(round, cid, 1.0), plan.fate(round, cid, 1.0));
            }
        }
        // and the draws differ across rounds for at least one client
        let fates: Vec<bool> =
            (0..32).map(|r| matches!(plan.fate(r, 0, 1.0), Fate::Drop)).collect();
        assert!(fates.iter().any(|&d| d) && fates.iter().any(|&d| !d), "{fates:?}");
    }

    #[test]
    fn certain_dropout_kills_all_uplinks() {
        let plan = FailurePlan { dropout_prob: 1.0, seed: 1, ..FailurePlan::none() };
        let t = Transport::new(NetworkModel::default(), plan);
        let out = t.collect(0, 100, frames(4, 100));
        assert!(out.delivered.is_empty());
        assert_eq!(out.dropped, vec![0, 1, 2, 3]);
    }

    #[test]
    fn crashed_client_holds_barrier_until_deadline() {
        // a crashed client never sends; with a finite deadline the
        // server still waits it out before closing the round
        let plan = FailurePlan {
            dropout_prob: 1.0,
            straggler_timeout_s: 10.0,
            seed: 2,
            ..FailurePlan::none()
        };
        let t = Transport::new(NetworkModel::default(), plan);
        let out = t.collect(0, 100, frames(2, 100));
        assert_eq!(out.dropped.len(), 2);
        assert!((out.round_time_s - 10.0).abs() < 1e-12, "{}", out.round_time_s);
        // with no deadline the simulation closes on the last delivery
        let t2 = Transport::new(
            NetworkModel::default(),
            FailurePlan { dropout_prob: 1.0, seed: 2, ..FailurePlan::none() },
        );
        assert_eq!(t2.collect(0, 100, frames(2, 100)).round_time_s, 0.0);
    }

    #[test]
    fn impossible_deadline_strands_every_upload() {
        // every delivery takes at least rtt/2 + download time, so a
        // microsecond deadline times everyone out regardless of seed
        let plan = FailurePlan {
            straggler_timeout_s: 1e-6,
            straggler_scale: DEFAULT_STRAGGLER_SCALE,
            seed: 9,
            ..FailurePlan::none()
        };
        let t = Transport::new(NetworkModel::default(), plan);
        let out = t.collect(1, 1_000, frames(3, 1_000));
        assert!(out.delivered.is_empty());
        assert_eq!(out.timed_out.len(), 3);
        // the server waited the deadline out
        assert!((out.round_time_s - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn generous_deadline_keeps_everyone() {
        // jitter is bounded by -ln(2^-53)·scale ≈ 18.4·scale, so a huge
        // deadline can never be crossed
        let plan = FailurePlan {
            straggler_timeout_s: 1e6,
            straggler_scale: DEFAULT_STRAGGLER_SCALE,
            seed: 11,
            ..FailurePlan::none()
        };
        let t = Transport::new(NetworkModel::default(), plan);
        let out = t.collect(2, 1_000, frames(6, 10_000));
        assert_eq!(out.delivered.len(), 6);
        // stragglers are slower than the failure-free barrier
        let base = NetworkModel::default().round_time(1_000, &[10_000; 6]);
        assert!(out.round_time_s >= base);
    }

    #[test]
    fn delivery_order_is_submission_order() {
        let plan = FailurePlan { dropout_prob: 0.4, seed: 3, ..FailurePlan::none() };
        let t = Transport::new(NetworkModel::default(), plan);
        let out = t.collect(5, 100, frames(10, 100));
        let cids: Vec<u32> = out.delivered.iter().map(|d| d.cid).collect();
        let mut sorted = cids.clone();
        sorted.sort_unstable();
        assert_eq!(cids, sorted, "survivor order must stay deterministic");
        assert_eq!(out.delivered.len() + out.dropped.len(), 10);
    }
}
