//! The uplink transport abstraction and its in-process implementation
//! — the Collect phase's substrate.
//!
//! [`crate::comm::channel::NetworkModel`] prices a byte; this module
//! actually *carries* the bytes: each selected client hands the
//! transport its encoded payload ([`UplinkFrame`]), and the transport
//! decides — deterministically, from a seeded [`FailurePlan`] plus a
//! seeded [`ChaosPlan`] — whether that upload arrives, arrives late
//! (straggler past the collect deadline), or never arrives at all
//! (client crashed mid-round, or packet loss exhausted every retry).
//! Delivered payloads stream through the caller's sink in **ascending
//! client id**; the server side of the round only ever sees what the
//! sink received plus [`CollectResult`]'s survivor metadata —
//! everything downstream (aggregation, secure-mask recovery, metrics)
//! operates on survivors.
//!
//! Two implementations share the [`Uplink`] trait:
//!
//! * [`Transport`] here — the in-process deterministic-test twin: no
//!   sockets, simulated time only. Every golden test pins against it.
//! * [`crate::comm::socket::SocketTransport`] — the same payload bytes
//!   framed over real TCP / Unix-domain sockets
//!   ([`crate::comm::frame`]), with a resequencing streaming fold that
//!   restores ascending-cid sink order.
//!
//! Both evaluate the same pure [`effective_fate`] per `(round, cid)`,
//! which is what makes their survivor sets, arrival times, and folded
//! aggregates identical by construction (pinned by
//! `tests/transport_conformance.rs`).
//!
//! Fidelity notes:
//! * Delivery *time* uses the paper's §5.2 cost model bytes (so the
//!   simulated round time stays comparable to §5.1's argument), while
//!   the *metered* bytes handed to the [`crate::comm::cost::CostLedger`]
//!   are the actual wire bytes delivered (`up_wire` = payload bytes,
//!   `up_framed` = payload + socket frame header — metered identically
//!   on every transport).
//! * Failure draws are a pure function of `(plan seed, round, client)`,
//!   so any run — including which clients die where — replays exactly.
//! * The deadline boundary contract: an upload landing **exactly at**
//!   the straggler deadline is delivered ([`FailurePlan::on_time`] is
//!   `at_s <= deadline`); only strictly-later arrivals time out. The
//!   socket transport's timer layer drains its queue before honoring
//!   deadline expiry for the same reason — a frame that made it in
//!   time is never discarded by the timer that noticed the time.
//! * The transport is payload-format-agnostic: a frame's bytes may be
//!   an f32 [`crate::sparse::codec`] encoding, a bitpacked quantized
//!   frame ([`crate::sparse::quant`]), or a masked secure payload —
//!   it carries and meters them identically. Delivered buffers are
//!   moved (never copied) from client encode through the sink to the
//!   server fold, which recycles them; an undelivered client's buffer
//!   comes back via [`CollectResult::spent`] so the reuse pool keeps
//!   it warm.

use anyhow::Result;

use crate::comm::chaos::{ChaosPlan, LinkFate};
use crate::comm::channel::NetworkModel;
use crate::comm::frame;
use crate::util::rng::Rng;

/// What the transport decided about one client's upload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fate {
    /// Arrived at or before the deadline, at simulated time `at_s`.
    Deliver { at_s: f64 },
    /// Client crashed before its upload left — or chaos loss ate every
    /// transmission attempt (never delivers either way).
    Drop,
    /// Upload exists but lands strictly after the collect deadline;
    /// the server has already closed the round.
    Timeout { at_s: f64 },
}

/// Mean of the exponential straggler delay factor applied when a
/// finite collect deadline is configured (delivery time is scaled by
/// `1 + Exp(scale)` — heavy-tailed, like real mobile uplinks).
pub const DEFAULT_STRAGGLER_SCALE: f64 = 0.5;

/// Seeded per-round failure injection: which selected clients crash,
/// which straggle past the deadline.
#[derive(Clone, Copy, Debug)]
pub struct FailurePlan {
    /// Per-round probability a selected client crashes before its
    /// upload arrives (0.0 = off).
    pub dropout_prob: f64,
    /// Server-side collect deadline in simulated seconds;
    /// `f64::INFINITY` disables the deadline.
    pub straggler_timeout_s: f64,
    /// Mean of the exponential delay factor (0.0 = deliveries take
    /// exactly their modeled time).
    pub straggler_scale: f64,
    /// Plan seed (mixed with round and client id per draw).
    pub seed: u64,
}

impl FailurePlan {
    /// No failure injection: every upload arrives on time. The round
    /// engine takes a zero-overhead path (no state snapshots) when the
    /// plan is disabled.
    pub fn none() -> Self {
        Self {
            dropout_prob: 0.0,
            straggler_timeout_s: f64::INFINITY,
            straggler_scale: 0.0,
            seed: 0,
        }
    }

    /// Is any failure mode live?
    pub fn enabled(&self) -> bool {
        self.dropout_prob > 0.0 || self.straggler_timeout_s.is_finite()
    }

    /// The deadline boundary contract, in one place so the simulated
    /// and timer-driven paths cannot disagree: an arrival **at** the
    /// deadline is on time; only strictly-later arrivals straggle.
    pub fn on_time(&self, at_s: f64) -> bool {
        at_s <= self.straggler_timeout_s
    }

    /// The raw (pre-deadline-classification) delivery time for one
    /// client this round: `None` = the client crashed, `Some(at_s)` =
    /// its upload would land at `at_s` (base delivery time times the
    /// seeded straggler jitter). Pure in `(seed, round, cid)`.
    pub fn raw_time(&self, round: u64, cid: u32, base_time_s: f64) -> Option<f64> {
        if !self.enabled() {
            return Some(base_time_s);
        }
        let mut rng = Rng::new(
            self.seed ^ ((cid as u64) << 32) ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        if rng.next_f64() < self.dropout_prob {
            return None;
        }
        let jitter = if self.straggler_scale > 0.0 {
            -(1.0 - rng.next_f64()).ln() * self.straggler_scale
        } else {
            0.0
        };
        Some(base_time_s * (1.0 + jitter))
    }

    /// Decide one client's fate this round. `base_time_s` is the
    /// failure-free delivery time (download + upload under the network
    /// model). Pure in `(seed, round, cid)` — replayable.
    pub fn fate(&self, round: u64, cid: u32, base_time_s: f64) -> Fate {
        match self.raw_time(round, cid, base_time_s) {
            None => Fate::Drop,
            Some(at_s) if self.on_time(at_s) => Fate::Deliver { at_s },
            Some(at_s) => Fate::Timeout { at_s },
        }
    }
}

/// One client's `(round, cid)` outcome with the crash/straggle plan
/// and the chaos plan composed — the single classification both
/// transports evaluate, so they cannot diverge.
#[derive(Clone, Copy, Debug)]
pub struct EffectiveFate {
    pub fate: Fate,
    /// The chaos draw behind the fate (duplication/reorder enactment).
    pub link: LinkFate,
    /// True when `fate` is [`Fate::Drop`] *because* chaos loss ate
    /// every transmission attempt (as opposed to a client crash).
    pub chaos_lost: bool,
}

/// Compose the crash/straggle [`FailurePlan`] with the [`ChaosPlan`]
/// for one frame. Pure in `(seeds, round, cid)`:
///
/// * plan says crash → `Drop` (chaos never resurrects a dead client);
/// * chaos loss exhausts `max_retries` → `Drop` (`chaos_lost`);
/// * otherwise the raw delivery time is stretched by the slow-link
///   multiplier and one extra full delivery per lost attempt, then
///   classified against the deadline via [`FailurePlan::on_time`] —
///   so a slow link or lossy retries can turn a would-be delivery
///   into a straggler.
///
/// With chaos disabled the time math is skipped entirely, keeping the
/// plan-only path bitwise identical to the pre-chaos transport.
pub fn effective_fate(
    plan: &FailurePlan,
    chaos: &ChaosPlan,
    round: u64,
    cid: u32,
    base_time_s: f64,
) -> EffectiveFate {
    let link = chaos.link_fate(round, cid);
    let Some(mut at_s) = plan.raw_time(round, cid, base_time_s) else {
        return EffectiveFate { fate: Fate::Drop, link, chaos_lost: false };
    };
    if link.lost_attempts > chaos.max_retries {
        return EffectiveFate { fate: Fate::Drop, link, chaos_lost: true };
    }
    if chaos.enabled() {
        at_s = at_s * link.slow_mult * (1.0 + link.lost_attempts as f64);
    }
    let fate =
        if plan.on_time(at_s) { Fate::Deliver { at_s } } else { Fate::Timeout { at_s } };
    EffectiveFate { fate, link, chaos_lost: false }
}

/// One client's upload as handed to the transport.
#[derive(Clone, Debug)]
pub struct UplinkFrame {
    pub cid: u32,
    /// Encoded payload ([`crate::sparse::codec::SparseVec::encode`]).
    pub bytes: Vec<u8>,
    /// Paper-model (§5.2) upload size, used for the simulated delivery
    /// time so round timing stays comparable to Eq. 7/8.
    pub paper_bytes: u64,
}

/// A frame the sink receives: one payload that made it to the server
/// in time. Sinks are invoked in ascending client id on every
/// transport (the socket path resequences arrivals to guarantee it).
#[derive(Clone, Debug)]
pub struct Delivery {
    pub cid: u32,
    pub bytes: Vec<u8>,
    /// Simulated arrival time, seconds from round start.
    pub at_s: f64,
}

/// Survivor metadata for one delivered upload (the payload itself went
/// through the sink).
#[derive(Clone, Copy, Debug)]
pub struct Accepted {
    pub cid: u32,
    /// Simulated arrival time, seconds from round start.
    pub at_s: f64,
    /// Framed wire size: payload + the socket frame header
    /// ([`crate::comm::frame::HEADER_LEN`]), metered identically on
    /// every transport as `up_framed`.
    pub framed: usize,
}

/// What one Collect phase yielded. Payloads are not here — they
/// streamed through the sink; this is the classification record.
#[derive(Clone, Debug, Default)]
pub struct CollectResult {
    /// Uploads that arrived in time, ascending client id (their
    /// payloads went through the sink in this same order). The caller
    /// meters these into the cost ledger (failed uploads never reached
    /// the server, so they are not metered).
    pub delivered: Vec<Accepted>,
    /// Clients that crashed — or whose frame chaos loss black-holed
    /// (no upload ever arrived server-side; indistinguishable there).
    pub dropped: Vec<u32>,
    /// Clients whose upload landed after the deadline (excluded).
    pub timed_out: Vec<u32>,
    /// Simulated wall-clock of the round's communication barrier: the
    /// slowest accepted delivery — or the deadline itself when any
    /// upload was still missing at close (the server cannot know a
    /// crashed client will never send, so it waits the deadline out).
    pub round_time_s: f64,
    /// Delivered frames that arrived twice (chaos duplication; the
    /// extra copy was deduplicated and not metered).
    pub duplicates: usize,
    /// Delivered frames that arrived out of send order (chaos
    /// reordering; resequenced before folding, so the aggregate is
    /// unaffected).
    pub reordered: usize,
    /// How many of `dropped` were chaos loss (every retry lost) rather
    /// than client crashes.
    pub chaos_lost: usize,
    /// Sender-side wire buffers the transport is done with (undelivered
    /// frames here; socket senders also return transmitted buffers).
    /// The caller recycles them into its pool.
    pub spent: Vec<Vec<u8>>,
}

/// The uplink carrying one round's Collect barrier. Implementations
/// must invoke `sink` once per surviving upload in **ascending client
/// id** (the pinned fold order — PERF.md), classify failures from the
/// same pure [`effective_fate`], and meter identically; the
/// conformance suite holds every implementation to all three.
pub trait Uplink: Send {
    /// Run one Collect barrier: every client first downloads the dense
    /// model (`down_bytes`), then uploads its frame; the plans decide
    /// who survives. `frames` arrive in ascending-cid submission order.
    fn collect_with(
        &mut self,
        round: u64,
        down_bytes: u64,
        frames: Vec<UplinkFrame>,
        sink: &mut dyn FnMut(Delivery),
    ) -> Result<CollectResult>;

    fn plan(&self) -> &FailurePlan;

    fn chaos(&self) -> &ChaosPlan;

    /// Can this transport fail to deliver an upload? (Gates the round
    /// engine's snapshot/rollback machinery.)
    fn failure_enabled(&self) -> bool {
        self.plan().enabled() || self.chaos().can_drop()
    }

    /// `"inproc"` / `"tcp"` / `"uds"` — for logs and labels.
    fn kind(&self) -> &'static str;
}

/// The in-process uplink: prices deliveries with the [`NetworkModel`]
/// and filters them through [`effective_fate`]. No sockets, no real
/// time — the deterministic-test twin every golden test pins against.
#[derive(Clone, Copy, Debug)]
pub struct Transport {
    pub network: NetworkModel,
    pub plan: FailurePlan,
    pub chaos: ChaosPlan,
}

impl Transport {
    pub fn new(network: NetworkModel, plan: FailurePlan) -> Self {
        Self { network, plan, chaos: ChaosPlan::none() }
    }

    pub fn with_chaos(network: NetworkModel, plan: FailurePlan, chaos: ChaosPlan) -> Self {
        Self { network, plan, chaos }
    }
}

impl Uplink for Transport {
    fn collect_with(
        &mut self,
        round: u64,
        down_bytes: u64,
        frames: Vec<UplinkFrame>,
        sink: &mut dyn FnMut(Delivery),
    ) -> Result<CollectResult> {
        let mut out = CollectResult::default();
        let down_s = self.network.download_time(down_bytes);
        for f in frames {
            let base = down_s + self.network.upload_time(f.paper_bytes);
            let eff = effective_fate(&self.plan, &self.chaos, round, f.cid, base);
            match eff.fate {
                Fate::Deliver { at_s } => {
                    out.round_time_s = out.round_time_s.max(at_s);
                    if eff.link.duplicate {
                        out.duplicates += 1;
                    }
                    if eff.link.reorder.is_some() {
                        out.reordered += 1;
                    }
                    out.delivered.push(Accepted {
                        cid: f.cid,
                        at_s,
                        framed: frame::framed_len(f.bytes.len()),
                    });
                    sink(Delivery { cid: f.cid, bytes: f.bytes, at_s });
                }
                Fate::Drop => {
                    if eff.chaos_lost {
                        out.chaos_lost += 1;
                    }
                    out.dropped.push(f.cid);
                    out.spent.push(f.bytes);
                }
                Fate::Timeout { .. } => {
                    out.timed_out.push(f.cid);
                    out.spent.push(f.bytes);
                }
            }
        }
        // the server holds the barrier open until the deadline when any
        // upload — straggling or crashed — is still missing at close
        // (it cannot distinguish the two until the deadline passes)
        if (!out.timed_out.is_empty() || !out.dropped.is_empty())
            && self.plan.straggler_timeout_s.is_finite()
        {
            out.round_time_s = out.round_time_s.max(self.plan.straggler_timeout_s);
        }
        Ok(out)
    }

    fn plan(&self) -> &FailurePlan {
        &self.plan
    }

    fn chaos(&self) -> &ChaosPlan {
        &self.chaos
    }

    fn kind(&self) -> &'static str {
        "inproc"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames(n: u32, bytes: usize) -> Vec<UplinkFrame> {
        (0..n)
            .map(|cid| UplinkFrame { cid, bytes: vec![0u8; bytes], paper_bytes: bytes as u64 })
            .collect()
    }

    fn run(
        t: &mut Transport,
        round: u64,
        down: u64,
        frames: Vec<UplinkFrame>,
    ) -> (CollectResult, Vec<Delivery>) {
        let mut got = Vec::new();
        let out = t.collect_with(round, down, frames, &mut |d| got.push(d)).unwrap();
        (out, got)
    }

    #[test]
    fn disabled_plan_delivers_everything_at_model_time() {
        let mut t = Transport::new(NetworkModel::default(), FailurePlan::none());
        let (out, got) = run(&mut t, 3, 1_000, frames(5, 2_000));
        assert_eq!(out.delivered.len(), 5);
        assert!(out.dropped.is_empty() && out.timed_out.is_empty());
        assert_eq!(out.duplicates + out.reordered + out.chaos_lost, 0);
        // identical to the pre-transport NetworkModel barrier formula
        let expect = NetworkModel::default().round_time(1_000, &[2_000; 5]);
        assert!((out.round_time_s - expect).abs() < 1e-12);
        let wire: usize = got.iter().map(|d| d.bytes.len()).sum();
        assert_eq!(wire, 5 * 2_000);
        // framed metering = payload + header, per delivery
        for a in &out.delivered {
            assert_eq!(a.framed, 2_000 + frame::HEADER_LEN);
        }
    }

    #[test]
    fn fate_is_deterministic_per_round_and_client() {
        let plan = FailurePlan { dropout_prob: 0.5, seed: 7, ..FailurePlan::none() };
        for round in 0..4 {
            for cid in 0..8 {
                assert_eq!(plan.fate(round, cid, 1.0), plan.fate(round, cid, 1.0));
            }
        }
        // and the draws differ across rounds for at least one client
        let fates: Vec<bool> =
            (0..32).map(|r| matches!(plan.fate(r, 0, 1.0), Fate::Drop)).collect();
        assert!(fates.iter().any(|&d| d) && fates.iter().any(|&d| !d), "{fates:?}");
    }

    #[test]
    fn certain_dropout_kills_all_uplinks() {
        let plan = FailurePlan { dropout_prob: 1.0, seed: 1, ..FailurePlan::none() };
        let mut t = Transport::new(NetworkModel::default(), plan);
        let (out, got) = run(&mut t, 0, 100, frames(4, 100));
        assert!(out.delivered.is_empty() && got.is_empty());
        assert_eq!(out.dropped, vec![0, 1, 2, 3]);
        // undelivered buffers come back for pool recycling
        assert_eq!(out.spent.len(), 4);
    }

    #[test]
    fn crashed_client_holds_barrier_until_deadline() {
        // a crashed client never sends; with a finite deadline the
        // server still waits it out before closing the round
        let plan = FailurePlan {
            dropout_prob: 1.0,
            straggler_timeout_s: 10.0,
            seed: 2,
            ..FailurePlan::none()
        };
        let mut t = Transport::new(NetworkModel::default(), plan);
        let (out, _) = run(&mut t, 0, 100, frames(2, 100));
        assert_eq!(out.dropped.len(), 2);
        assert!((out.round_time_s - 10.0).abs() < 1e-12, "{}", out.round_time_s);
        // with no deadline the simulation closes on the last delivery
        let mut t2 = Transport::new(
            NetworkModel::default(),
            FailurePlan { dropout_prob: 1.0, seed: 2, ..FailurePlan::none() },
        );
        assert_eq!(run(&mut t2, 0, 100, frames(2, 100)).0.round_time_s, 0.0);
    }

    #[test]
    fn impossible_deadline_strands_every_upload() {
        // every delivery takes at least rtt/2 + download time, so a
        // microsecond deadline times everyone out regardless of seed
        let plan = FailurePlan {
            straggler_timeout_s: 1e-6,
            straggler_scale: DEFAULT_STRAGGLER_SCALE,
            seed: 9,
            ..FailurePlan::none()
        };
        let mut t = Transport::new(NetworkModel::default(), plan);
        let (out, _) = run(&mut t, 1, 1_000, frames(3, 1_000));
        assert!(out.delivered.is_empty());
        assert_eq!(out.timed_out.len(), 3);
        // the server waited the deadline out
        assert!((out.round_time_s - 1e-6).abs() < 1e-15);
    }

    #[test]
    fn generous_deadline_keeps_everyone() {
        // jitter is bounded by -ln(2^-53)·scale ≈ 18.4·scale, so a huge
        // deadline can never be crossed
        let plan = FailurePlan {
            straggler_timeout_s: 1e6,
            straggler_scale: DEFAULT_STRAGGLER_SCALE,
            seed: 11,
            ..FailurePlan::none()
        };
        let mut t = Transport::new(NetworkModel::default(), plan);
        let (out, _) = run(&mut t, 2, 1_000, frames(6, 10_000));
        assert_eq!(out.delivered.len(), 6);
        // stragglers are slower than the failure-free barrier
        let base = NetworkModel::default().round_time(1_000, &[10_000; 6]);
        assert!(out.round_time_s >= base);
    }

    #[test]
    fn delivery_order_is_submission_order() {
        let plan = FailurePlan { dropout_prob: 0.4, seed: 3, ..FailurePlan::none() };
        let mut t = Transport::new(NetworkModel::default(), plan);
        let (out, got) = run(&mut t, 5, 100, frames(10, 100));
        let cids: Vec<u32> = got.iter().map(|d| d.cid).collect();
        let mut sorted = cids.clone();
        sorted.sort_unstable();
        assert_eq!(cids, sorted, "sink order must stay ascending cid");
        assert_eq!(
            cids,
            out.delivered.iter().map(|a| a.cid).collect::<Vec<_>>(),
            "metadata order matches sink order"
        );
        assert_eq!(out.delivered.len() + out.dropped.len(), 10);
    }

    #[test]
    fn exact_deadline_arrival_is_delivered() {
        // straggler_scale = 0 → at_s is exactly the modeled time, so a
        // deadline set to that instant hits the boundary case: AT the
        // deadline delivers, one ulp past does not
        let n = NetworkModel::default();
        let at = n.download_time(1_000) + n.upload_time(2_000);
        let exactly = FailurePlan {
            straggler_timeout_s: at,
            straggler_scale: 0.0,
            seed: 5,
            ..FailurePlan::none()
        };
        assert!(exactly.on_time(at));
        let mut t = Transport::new(n, exactly);
        let (out, _) = run(&mut t, 0, 1_000, frames(1, 2_000));
        assert_eq!(out.delivered.len(), 1, "frame AT the deadline is on time");
        assert!(out.timed_out.is_empty());

        let one_ulp_short = FailurePlan {
            straggler_timeout_s: f64::from_bits(at.to_bits() - 1),
            ..exactly
        };
        assert!(!one_ulp_short.on_time(at));
        let mut t = Transport::new(n, one_ulp_short);
        let (out, _) = run(&mut t, 0, 1_000, frames(1, 2_000));
        assert!(out.delivered.is_empty());
        assert_eq!(out.timed_out, vec![0], "one ulp past the deadline straggles");
    }

    #[test]
    fn chaos_dup_and_reorder_never_change_survivors() {
        let plan = FailurePlan { dropout_prob: 0.3, seed: 13, ..FailurePlan::none() };
        let chaos = ChaosPlan { dup_prob: 1.0, reorder_prob: 1.0, seed: 17, ..ChaosPlan::none() };
        let mut plain = Transport::new(NetworkModel::default(), plan);
        let mut noisy = Transport::with_chaos(NetworkModel::default(), plan, chaos);
        let (a, got_a) = run(&mut plain, 2, 100, frames(8, 100));
        let (b, got_b) = run(&mut noisy, 2, 100, frames(8, 100));
        let ids = |g: &[Delivery]| g.iter().map(|d| d.cid).collect::<Vec<_>>();
        assert_eq!(ids(&got_a), ids(&got_b), "dup/reorder are delivery-neutral");
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(b.duplicates, b.delivered.len(), "every delivery arrived twice");
        assert_eq!(b.reordered, b.delivered.len());
        assert_eq!(a.duplicates + a.reordered, 0);
        // times are also untouched (dup/reorder don't slow the link)
        for (x, y) in a.delivered.iter().zip(&b.delivered) {
            assert_eq!(x.at_s.to_bits(), y.at_s.to_bits());
        }
    }

    #[test]
    fn chaos_loss_exhaustion_classifies_as_dropped() {
        let chaos = ChaosPlan { loss_prob: 0.6, max_retries: 1, seed: 23, ..ChaosPlan::none() };
        let mut t =
            Transport::with_chaos(NetworkModel::default(), FailurePlan::none(), chaos);
        let (out, got) = run(&mut t, 0, 100, frames(32, 100));
        assert!(out.chaos_lost > 0, "p=0.6 over 32 clients loses someone");
        assert_eq!(out.chaos_lost, out.dropped.len(), "no crashes configured");
        assert_eq!(got.len() + out.dropped.len(), 32);
        assert_eq!(out.spent.len(), out.dropped.len());
        // surviving retries cost time: some delivery is slower than base
        let base = NetworkModel::default().download_time(100)
            + NetworkModel::default().upload_time(100);
        assert!(out.delivered.iter().any(|a| a.at_s > base * 1.5));
    }

    #[test]
    fn slow_links_can_cross_the_deadline() {
        let n = NetworkModel::default();
        let base = n.download_time(100) + n.upload_time(100);
        // deadline admits every on-model delivery but no 4× slow link
        let plan = FailurePlan {
            straggler_timeout_s: base * 2.0,
            straggler_scale: 0.0,
            seed: 29,
            ..FailurePlan::none()
        };
        let chaos = ChaosPlan { slow_prob: 0.5, slow_factor: 4.0, seed: 31, ..ChaosPlan::none() };
        let mut t = Transport::with_chaos(n, plan, chaos);
        let (out, _) = run(&mut t, 0, 100, frames(32, 100));
        assert!(!out.timed_out.is_empty(), "some slow link crossed the deadline");
        assert!(!out.delivered.is_empty(), "p=0.5 leaves clear links too");
        // exactly the slow ones straggled
        for &cid in &out.timed_out {
            assert!(chaos.link_fate(0, cid).slow_mult > 1.0);
        }
    }

    #[test]
    fn failure_enabled_accounts_for_chaos_loss() {
        let t = Transport::new(NetworkModel::default(), FailurePlan::none());
        assert!(!t.failure_enabled());
        let t = Transport::with_chaos(
            NetworkModel::default(),
            FailurePlan::none(),
            ChaosPlan { dup_prob: 0.5, ..ChaosPlan::none() },
        );
        assert!(!t.failure_enabled(), "dup alone cannot lose an upload");
        let t = Transport::with_chaos(
            NetworkModel::default(),
            FailurePlan::none(),
            ChaosPlan { loss_prob: 0.1, ..ChaosPlan::none() },
        );
        assert!(t.failure_enabled(), "loss can black-hole an upload");
    }
}
