//! Deterministic synthetic image datasets (MNIST/FMNIST/CIFAR-shaped).
//!
//! Each class has a smooth archetype built from a seeded mixture of 2-D
//! cosine harmonics (distinct frequency/phase signature per class).
//! A sample is its class archetype under a random ±2px translation,
//! intensity scale, and additive Gaussian pixel noise — enough
//! variation that the models must genuinely generalize, while keeping
//! the task learnable at MNIST-like difficulty (an MLP reaches >95%,
//! mirroring the paper's setting; see EXPERIMENTS.md §Data).
//!
//! Every pixel is a pure function of (dataset seed, class, sample id),
//! so the 60k-sample corpus is generated lazily per batch and never
//! materialized — CIFAR-sized data would otherwise cost ~700 MB.

use crate::util::rng::Rng;

/// Number of cosine harmonics per archetype.
const N_HARMONICS: usize = 6;
/// Max |translation| in pixels.
const MAX_SHIFT: i64 = 2;
/// Additive pixel noise std.
const NOISE_STD: f32 = 0.10;

/// Per-class archetype generator parameters.
#[derive(Clone, Debug)]
pub struct Archetype {
    /// (fy, fx, phase, amplitude) per harmonic per channel.
    harmonics: Vec<(f32, f32, f32, f32)>,
    channels: usize,
    h: usize,
    w: usize,
    /// Cached rendered pattern, padded by MAX_SHIFT on each side.
    padded: Vec<f32>,
}

impl Archetype {
    /// Build the archetype for `(dataset_seed, class)`.
    pub fn new(dataset_seed: u64, class: u8, h: usize, w: usize, channels: usize) -> Self {
        let mut rng = Rng::new(dataset_seed ^ (0xA5C3_0000 + class as u64));
        let mut harmonics = Vec::with_capacity(N_HARMONICS * channels);
        for h in 0..N_HARMONICS * channels {
            if h % N_HARMONICS == 0 {
                // dominant harmonic with a structured per-class
                // frequency signature → classes provably distinct
                let fy = 0.8 + 0.55 * (class % 5) as f32;
                let fx = 0.8 + 0.75 * (class / 5) as f32;
                let phase = rng.next_f32() * std::f32::consts::TAU;
                harmonics.push((fy, fx, phase, 2.0));
            } else {
                // low-amplitude random texture on top
                let fy = 0.5 + rng.next_f32() * 3.0; // low freq → smooth
                let fx = 0.5 + rng.next_f32() * 3.0;
                let phase = rng.next_f32() * std::f32::consts::TAU;
                let amp = 0.2 + rng.next_f32() * 0.3;
                harmonics.push((fy, fx, phase, amp));
            }
        }
        let mut a = Self { harmonics, channels, h, w, padded: Vec::new() };
        a.render();
        a
    }

    /// Render the padded pattern once; samples crop shifted windows.
    fn render(&mut self) {
        let ph = self.h + 2 * MAX_SHIFT as usize;
        let pw = self.w + 2 * MAX_SHIFT as usize;
        let mut img = vec![0f32; ph * pw * self.channels];
        for c in 0..self.channels {
            let hs = &self.harmonics[c * N_HARMONICS..(c + 1) * N_HARMONICS];
            for y in 0..ph {
                for x in 0..pw {
                    let mut v = 0f32;
                    for &(fy, fx, phase, amp) in hs {
                        let arg = std::f32::consts::TAU
                            * (fy * y as f32 / ph as f32 + fx * x as f32 / pw as f32)
                            + phase;
                        v += amp * arg.cos();
                    }
                    // squash to [0,1]
                    let norm = v / N_HARMONICS as f32; // ~[-1,1]
                    img[(y * pw + x) * self.channels + c] = 0.5 + 0.5 * norm;
                }
            }
        }
        self.padded = img;
    }

    /// Render sample `sample_id` into `out` (len h·w·channels, NHWC
    /// pixel order). Pure function of the inputs.
    pub fn fill_sample(&self, dataset_seed: u64, sample_id: u64, out: &mut [f32]) {
        assert_eq!(out.len(), self.h * self.w * self.channels, "sample buffer size");
        let mut rng = Rng::new(dataset_seed ^ sample_id.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        // shift ∈ [-MAX_SHIFT, MAX_SHIFT] expressed as a padded-window
        // offset ∈ [0, 2·MAX_SHIFT]
        let dy = rng.below((2 * MAX_SHIFT + 1) as u64) as usize;
        let dx = rng.below((2 * MAX_SHIFT + 1) as u64) as usize;
        let scale = 0.9 + rng.next_f32() * 0.2;
        let pw = self.w + 2 * MAX_SHIFT as usize;
        for y in 0..self.h {
            for x in 0..self.w {
                for c in 0..self.channels {
                    let src = ((y + dy) * pw + (x + dx)) * self.channels + c;
                    let noise = rng.normal_f32(NOISE_STD);
                    let v = self.padded[src] * scale + noise;
                    out[(y * self.w + x) * self.channels + c] = v.clamp(0.0, 1.0);
                }
            }
        }
    }
}

/// A full synthetic split: archetypes for all classes + label table.
pub struct SynthSource {
    pub seed: u64,
    pub archetypes: Vec<Archetype>,
    pub labels: Vec<u8>,
}

impl SynthSource {
    /// Labels are a seeded shuffle of a balanced class assignment, so
    /// every class has exactly `n/10` samples (paper's splits are
    /// balanced too).
    pub fn new(seed: u64, n: usize, n_classes: usize, h: usize, w: usize, ch: usize) -> Self {
        let archetypes = (0..n_classes)
            .map(|c| Archetype::new(seed, c as u8, h, w, ch))
            .collect();
        let mut labels: Vec<u8> = (0..n).map(|i| (i % n_classes) as u8).collect();
        let mut rng = Rng::new(seed ^ 0x1abe1);
        rng.shuffle(&mut labels);
        Self { seed, archetypes, labels }
    }

    pub fn fill(&self, idx: usize, out: &mut [f32]) {
        let class = self.labels[idx] as usize;
        self.archetypes[class].fill_sample(self.seed, idx as u64, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn samples_deterministic() {
        let a = Archetype::new(7, 3, 28, 28, 1);
        let mut s1 = vec![0f32; 28 * 28];
        let mut s2 = vec![0f32; 28 * 28];
        a.fill_sample(7, 42, &mut s1);
        a.fill_sample(7, 42, &mut s2);
        assert_eq!(s1, s2);
    }

    #[test]
    fn different_samples_differ() {
        let a = Archetype::new(7, 3, 28, 28, 1);
        let mut s1 = vec![0f32; 28 * 28];
        let mut s2 = vec![0f32; 28 * 28];
        a.fill_sample(7, 1, &mut s1);
        a.fill_sample(7, 2, &mut s2);
        assert_ne!(s1, s2);
    }

    #[test]
    fn classes_are_separable() {
        // nearest-archetype-mean classification on noisy samples must
        // beat chance by a wide margin — the learnability smoke test
        let n_classes = 10;
        let arch: Vec<Archetype> = (0..n_classes)
            .map(|c| Archetype::new(11, c as u8, 28, 28, 1))
            .collect();
        // class means over a few clean-ish samples
        let mut means = vec![vec![0f32; 28 * 28]; n_classes];
        let mut buf = vec![0f32; 28 * 28];
        for c in 0..n_classes {
            for s in 0..10u64 {
                arch[c].fill_sample(11, s, &mut buf);
                for (m, &v) in means[c].iter_mut().zip(&buf) {
                    *m += v / 10.0;
                }
            }
        }
        let mut correct = 0;
        let total = 200;
        for trial in 0..total {
            let c = trial % n_classes;
            arch[c].fill_sample(11, 1000 + trial as u64, &mut buf);
            let best = (0..n_classes)
                .min_by(|&a, &b| {
                    let da: f32 = means[a].iter().zip(&buf).map(|(m, v)| (m - v).powi(2)).sum();
                    let db: f32 = means[b].iter().zip(&buf).map(|(m, v)| (m - v).powi(2)).sum();
                    da.partial_cmp(&db).unwrap()
                })
                .unwrap();
            if best == c {
                correct += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        assert!(acc > 0.75, "nearest-mean acc {acc} too low — not learnable");
    }

    #[test]
    fn pixels_in_unit_range() {
        let a = Archetype::new(3, 0, 32, 32, 3);
        let mut s = vec![0f32; 32 * 32 * 3];
        a.fill_sample(3, 5, &mut s);
        assert!(s.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn labels_balanced() {
        let src = SynthSource::new(1, 1000, 10, 8, 8, 1);
        let mut counts = [0usize; 10];
        for &l in &src.labels {
            counts[l as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100), "{counts:?}");
    }

    #[test]
    fn source_fill_uses_label_class() {
        let src = SynthSource::new(2, 100, 10, 8, 8, 1);
        let mut a = vec![0f32; 64];
        src.fill(0, &mut a);
        // same sample twice → identical
        let mut b = vec![0f32; 64];
        src.fill(0, &mut b);
        assert_eq!(a, b);
    }
}
