//! Loaders for the *real* datasets when present on disk:
//!
//! * IDX format (MNIST / Fashion-MNIST: `train-images-idx3-ubyte`,
//!   `train-labels-idx1-ubyte`, `t10k-…`)
//! * CIFAR-10 binary format (`data_batch_1.bin` … `data_batch_5.bin`,
//!   `test_batch.bin`; 1 label byte + 3072 CHW pixel bytes per record)
//!
//! [`crate::data::dataset::Dataset`] probes these paths and falls back
//! to the synthetic source when absent (DESIGN.md §Substitutions).

use std::fs;
use std::path::Path;

#[derive(Debug, thiserror::Error)]
pub enum IdxError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("bad idx magic {0:#010x}")]
    BadMagic(u32),
    #[error("idx payload truncated")]
    Truncated,
    #[error("cifar file size {0} not a multiple of record size")]
    BadCifarSize(usize),
}

/// In-memory images + labels, pixels already scaled to [0, 1] f32,
/// layout NHWC.
pub struct RawData {
    pub images: Vec<f32>,
    pub labels: Vec<u8>,
    pub n: usize,
    pub shape: [usize; 3], // H, W, C
}

fn be_u32(b: &[u8], off: usize) -> Result<u32, IdxError> {
    b.get(off..off + 4)
        .map(|s| u32::from_be_bytes(s.try_into().unwrap()))
        .ok_or(IdxError::Truncated)
}

/// Parse an IDX image file (magic 0x00000803, dims [n, h, w]).
pub fn load_idx_images(path: &Path) -> Result<RawData, IdxError> {
    let bytes = fs::read(path)?;
    let magic = be_u32(&bytes, 0)?;
    if magic != 0x0000_0803 {
        return Err(IdxError::BadMagic(magic));
    }
    let n = be_u32(&bytes, 4)? as usize;
    let h = be_u32(&bytes, 8)? as usize;
    let w = be_u32(&bytes, 12)? as usize;
    let need = 16 + n * h * w;
    if bytes.len() < need {
        return Err(IdxError::Truncated);
    }
    let images = bytes[16..need].iter().map(|&b| b as f32 / 255.0).collect();
    Ok(RawData { images, labels: Vec::new(), n, shape: [h, w, 1] })
}

/// Parse an IDX label file (magic 0x00000801).
pub fn load_idx_labels(path: &Path) -> Result<Vec<u8>, IdxError> {
    let bytes = fs::read(path)?;
    let magic = be_u32(&bytes, 0)?;
    if magic != 0x0000_0801 {
        return Err(IdxError::BadMagic(magic));
    }
    let n = be_u32(&bytes, 4)? as usize;
    if bytes.len() < 8 + n {
        return Err(IdxError::Truncated);
    }
    Ok(bytes[8..8 + n].to_vec())
}

/// Parse one CIFAR-10 binary batch file. Records are
/// `label u8 + 3072 bytes CHW`; we convert to NHWC.
pub fn load_cifar_bin(path: &Path) -> Result<RawData, IdxError> {
    const REC: usize = 1 + 3072;
    let bytes = fs::read(path)?;
    if bytes.len() % REC != 0 {
        return Err(IdxError::BadCifarSize(bytes.len()));
    }
    let n = bytes.len() / REC;
    let mut images = vec![0f32; n * 3072];
    let mut labels = Vec::with_capacity(n);
    for r in 0..n {
        let rec = &bytes[r * REC..(r + 1) * REC];
        labels.push(rec[0]);
        // CHW → HWC
        for c in 0..3 {
            for y in 0..32 {
                for x in 0..32 {
                    let src = 1 + c * 1024 + y * 32 + x;
                    let dst = r * 3072 + (y * 32 + x) * 3 + c;
                    images[dst] = rec[src] as f32 / 255.0;
                }
            }
        }
    }
    Ok(RawData { images, labels, n, shape: [32, 32, 3] })
}

/// Probe for MNIST-style IDX files under `dir` with the given prefix
/// ("train" or "t10k"). Returns images+labels when both parse.
pub fn try_load_idx_split(dir: &Path, prefix: &str) -> Option<RawData> {
    let img = dir.join(format!("{prefix}-images-idx3-ubyte"));
    let lbl = dir.join(format!("{prefix}-labels-idx1-ubyte"));
    let mut data = load_idx_images(&img).ok()?;
    let labels = load_idx_labels(&lbl).ok()?;
    if labels.len() != data.n {
        return None;
    }
    data.labels = labels;
    Some(data)
}

/// Probe for the CIFAR-10 binary split under `dir`.
pub fn try_load_cifar_split(dir: &Path, train: bool) -> Option<RawData> {
    if train {
        let mut all: Option<RawData> = None;
        for i in 1..=5 {
            let batch = load_cifar_bin(&dir.join(format!("data_batch_{i}.bin"))).ok()?;
            match &mut all {
                None => all = Some(batch),
                Some(acc) => {
                    acc.images.extend_from_slice(&batch.images);
                    acc.labels.extend_from_slice(&batch.labels);
                    acc.n += batch.n;
                }
            }
        }
        all
    } else {
        load_cifar_bin(&dir.join("test_batch.bin")).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fedsparse-idx-{}", std::process::id()));
        let _ = fs::create_dir_all(&d);
        d
    }

    fn write_idx_images(path: &Path, n: u32, h: u32, w: u32) {
        let mut f = fs::File::create(path).unwrap();
        f.write_all(&0x0000_0803u32.to_be_bytes()).unwrap();
        f.write_all(&n.to_be_bytes()).unwrap();
        f.write_all(&h.to_be_bytes()).unwrap();
        f.write_all(&w.to_be_bytes()).unwrap();
        let body: Vec<u8> = (0..(n * h * w)).map(|i| (i % 256) as u8).collect();
        f.write_all(&body).unwrap();
    }

    fn write_idx_labels(path: &Path, labels: &[u8]) {
        let mut f = fs::File::create(path).unwrap();
        f.write_all(&0x0000_0801u32.to_be_bytes()).unwrap();
        f.write_all(&(labels.len() as u32).to_be_bytes()).unwrap();
        f.write_all(labels).unwrap();
    }

    #[test]
    fn idx_roundtrip() {
        let dir = tmpdir();
        write_idx_images(&dir.join("train-images-idx3-ubyte"), 4, 5, 6);
        write_idx_labels(&dir.join("train-labels-idx1-ubyte"), &[0, 1, 2, 3]);
        let data = try_load_idx_split(&dir, "train").unwrap();
        assert_eq!(data.n, 4);
        assert_eq!(data.shape, [5, 6, 1]);
        assert_eq!(data.labels, vec![0, 1, 2, 3]);
        assert_eq!(data.images.len(), 4 * 5 * 6);
        assert!((data.images[1] - 1.0 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn idx_bad_magic_rejected() {
        let dir = tmpdir();
        let p = dir.join("bad-images-idx3-ubyte");
        fs::write(&p, 0xdeadbeefu32.to_be_bytes()).unwrap();
        assert!(matches!(load_idx_images(&p), Err(IdxError::BadMagic(_))));
    }

    #[test]
    fn idx_label_count_mismatch_is_none() {
        let dir = tmpdir();
        write_idx_images(&dir.join("t10k-images-idx3-ubyte"), 3, 2, 2);
        write_idx_labels(&dir.join("t10k-labels-idx1-ubyte"), &[0, 1]);
        assert!(try_load_idx_split(&dir, "t10k").is_none());
    }

    #[test]
    fn cifar_bin_roundtrip() {
        let dir = tmpdir();
        let p = dir.join("test_batch.bin");
        let mut bytes = Vec::new();
        for r in 0..2u8 {
            bytes.push(r); // label
            bytes.extend((0..3072).map(|i| ((i + r as usize) % 256) as u8));
        }
        fs::write(&p, &bytes).unwrap();
        let data = try_load_cifar_split(&dir, false).unwrap();
        assert_eq!(data.n, 2);
        assert_eq!(data.shape, [32, 32, 3]);
        assert_eq!(data.labels, vec![0, 1]);
        // CHW→HWC: pixel (y=0,x=0) channel 1 comes from offset 1024
        assert!((data.images[1] - (1024 % 256) as f32 / 255.0).abs() < 1e-6);
    }

    #[test]
    fn cifar_bad_size_rejected() {
        let dir = tmpdir();
        let p = dir.join("data_batch_1.bin");
        fs::write(&p, [0u8; 100]).unwrap();
        assert!(matches!(load_cifar_bin(&p), Err(IdxError::BadCifarSize(_))));
    }

    #[test]
    fn missing_files_probe_none() {
        let dir = tmpdir().join("nonexistent");
        assert!(try_load_idx_split(&dir, "train").is_none());
        assert!(try_load_cifar_split(&dir, true).is_none());
    }
}
