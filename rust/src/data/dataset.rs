//! The dataset abstraction the coordinator trains on.
//!
//! Prefers real on-disk data (see [`crate::data::idx`]); falls back to
//! the deterministic synthetic source. Sample access is by index so the
//! partitioner can hand each simulated client an index set and batches
//! are materialized lazily (synthetic pixels are pure functions).

use std::path::Path;

use super::idx;
use super::synth::SynthSource;

/// Which corpus (shapes + split sizes follow the paper's Table 1 setup).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    Mnist,
    FashionMnist,
    Cifar10,
}

impl DatasetKind {
    pub fn from_name(name: &str) -> Option<Self> {
        match name {
            "mnist" => Some(Self::Mnist),
            "fmnist" | "fashion_mnist" | "fashion-mnist" => Some(Self::FashionMnist),
            "cifar10" | "cifar" => Some(Self::Cifar10),
            _ => None,
        }
    }

    pub fn shape(&self) -> [usize; 3] {
        match self {
            Self::Mnist | Self::FashionMnist => [28, 28, 1],
            Self::Cifar10 => [32, 32, 3],
        }
    }

    pub fn train_size(&self) -> usize {
        match self {
            Self::Mnist | Self::FashionMnist => 60_000,
            Self::Cifar10 => 50_000,
        }
    }

    pub fn test_size(&self) -> usize {
        10_000
    }

    /// Seed namespace so MNIST ≠ FMNIST synthetic patterns.
    fn seed_tag(&self) -> u64 {
        match self {
            Self::Mnist => 0x1,
            Self::FashionMnist => 0x2,
            Self::Cifar10 => 0x3,
        }
    }

    /// Subdirectory probed for real files.
    fn dir_name(&self) -> &'static str {
        match self {
            Self::Mnist => "mnist",
            Self::FashionMnist => "fashion-mnist",
            Self::Cifar10 => "cifar-10",
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

enum Source {
    Synth(SynthSource),
    Real(idx::RawData),
}

/// A dataset split with index-addressable samples.
pub struct Dataset {
    pub kind: DatasetKind,
    pub split: Split,
    source: Source,
    n: usize,
}

impl Dataset {
    /// Load `split`, probing `data_dir/<kind>/` for real files first.
    /// `seed` parameterizes the synthetic fallback (and is ignored for
    /// real data).
    pub fn load(kind: DatasetKind, split: Split, data_dir: Option<&Path>, seed: u64) -> Self {
        if let Some(dir) = data_dir {
            let sub = dir.join(kind.dir_name());
            let real = match (kind, split) {
                (DatasetKind::Cifar10, Split::Train) => idx::try_load_cifar_split(&sub, true),
                (DatasetKind::Cifar10, Split::Test) => idx::try_load_cifar_split(&sub, false),
                (_, Split::Train) => idx::try_load_idx_split(&sub, "train"),
                (_, Split::Test) => idx::try_load_idx_split(&sub, "t10k"),
            };
            if let Some(data) = real {
                let n = data.n;
                return Self { kind, split, source: Source::Real(data), n };
            }
        }
        let [h, w, c] = kind.shape();
        let n = match split {
            Split::Train => kind.train_size(),
            Split::Test => kind.test_size(),
        };
        // different split → different sample stream
        let split_tag = match split {
            Split::Train => 0x7_000,
            Split::Test => 0x8_000,
        };
        let src = SynthSource::new(seed ^ kind.seed_tag() ^ split_tag, n, 10, h, w, c);
        Self { kind, split, source: Source::Synth(src), n }
    }

    /// Smaller synthetic split for tests/CI (same pipeline, fewer rows).
    pub fn synthetic_small(kind: DatasetKind, split: Split, n: usize, seed: u64) -> Self {
        let [h, w, c] = kind.shape();
        let split_tag = match split {
            Split::Train => 0x7_000,
            Split::Test => 0x8_000,
        };
        let src = SynthSource::new(seed ^ kind.seed_tag() ^ split_tag, n, 10, h, w, c);
        Self { kind, split, source: Source::Synth(src), n }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    pub fn sample_len(&self) -> usize {
        let [h, w, c] = self.kind.shape();
        h * w * c
    }

    pub fn is_synthetic(&self) -> bool {
        matches!(self.source, Source::Synth(_))
    }

    pub fn label(&self, idx: usize) -> u8 {
        match &self.source {
            Source::Synth(s) => s.labels[idx],
            Source::Real(r) => r.labels[idx],
        }
    }

    /// All labels (partitioner input).
    pub fn labels(&self) -> Vec<u8> {
        (0..self.n).map(|i| self.label(i)).collect()
    }

    pub fn fill_sample(&self, idx: usize, out: &mut [f32]) {
        match &self.source {
            Source::Synth(s) => s.fill(idx, out),
            Source::Real(r) => {
                let m = self.sample_len();
                out.copy_from_slice(&r.images[idx * m..(idx + 1) * m]);
            }
        }
    }

    /// Materialize a batch: NHWC f32 pixels + i32 labels, in the order
    /// of `indices`.
    pub fn batch(&self, indices: &[usize]) -> (Vec<f32>, Vec<i32>) {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        self.batch_into(indices, &mut xs, &mut ys);
        (xs, ys)
    }

    /// [`Self::batch`] into caller-owned buffers (resized to fit) —
    /// the round engine's per-worker workspaces reuse these across
    /// every local SGD iteration, so the steady-state training loop
    /// allocates no batch buffers.
    pub fn batch_into(&self, indices: &[usize], xs: &mut Vec<f32>, ys: &mut Vec<i32>) {
        let m = self.sample_len();
        xs.clear();
        xs.resize(indices.len() * m, 0.0);
        ys.clear();
        for (row, &idx) in indices.iter().enumerate() {
            self.fill_sample(idx, &mut xs[row * m..(row + 1) * m]);
            ys.push(self.label(idx) as i32);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_split_sizes() {
        let d = Dataset::synthetic_small(DatasetKind::Mnist, Split::Train, 500, 1);
        assert_eq!(d.len(), 500);
        assert_eq!(d.sample_len(), 784);
        assert!(d.is_synthetic());
    }

    #[test]
    fn batch_shapes_and_labels() {
        let d = Dataset::synthetic_small(DatasetKind::Cifar10, Split::Train, 100, 2);
        let (xs, ys) = d.batch(&[0, 5, 9]);
        assert_eq!(xs.len(), 3 * 3072);
        assert_eq!(ys.len(), 3);
        assert_eq!(ys[1], d.label(5) as i32);
        assert!(xs.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn train_and_test_streams_differ() {
        let tr = Dataset::synthetic_small(DatasetKind::Mnist, Split::Train, 10, 3);
        let te = Dataset::synthetic_small(DatasetKind::Mnist, Split::Test, 10, 3);
        let mut a = vec![0f32; 784];
        let mut b = vec![0f32; 784];
        tr.fill_sample(0, &mut a);
        te.fill_sample(0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn kinds_have_distinct_patterns() {
        let m = Dataset::synthetic_small(DatasetKind::Mnist, Split::Train, 10, 3);
        let f = Dataset::synthetic_small(DatasetKind::FashionMnist, Split::Train, 10, 3);
        let mut a = vec![0f32; 784];
        let mut b = vec![0f32; 784];
        m.fill_sample(0, &mut a);
        f.fill_sample(0, &mut b);
        assert_ne!(a, b);
    }

    #[test]
    fn from_name_parses() {
        assert_eq!(DatasetKind::from_name("mnist"), Some(DatasetKind::Mnist));
        assert_eq!(DatasetKind::from_name("fmnist"), Some(DatasetKind::FashionMnist));
        assert_eq!(DatasetKind::from_name("cifar10"), Some(DatasetKind::Cifar10));
        assert_eq!(DatasetKind::from_name("imagenet"), None);
    }

    #[test]
    fn full_split_sizes_match_paper() {
        assert_eq!(DatasetKind::Mnist.train_size(), 60_000);
        assert_eq!(DatasetKind::Cifar10.train_size(), 50_000);
        assert_eq!(DatasetKind::Mnist.test_size(), 10_000);
    }
}
