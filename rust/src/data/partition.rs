//! Client data partitioning — the §5 "sample allocation matrix".
//!
//! * [`iid_partition`] — shuffle + equal chunks.
//! * [`noniid_partition`] — Non-IID-n: every client holds samples from
//!   exactly `n` label classes (the paper's Non-IID-4/6/8 settings),
//!   with balanced per-client sample counts.

use crate::util::rng::Rng;

/// IID: shuffle all indices, deal equal contiguous chunks.
/// Remainder samples go one-each to the first clients.
pub fn iid_partition(n_samples: usize, n_clients: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(n_clients > 0 && n_samples >= n_clients, "bad partition request");
    let mut idx: Vec<usize> = (0..n_samples).collect();
    rng.shuffle(&mut idx);
    let base = n_samples / n_clients;
    let extra = n_samples % n_clients;
    let mut out = Vec::with_capacity(n_clients);
    let mut pos = 0;
    for c in 0..n_clients {
        let take = base + usize::from(c < extra);
        out.push(idx[pos..pos + take].to_vec());
        pos += take;
    }
    out
}

/// Non-IID-n: client `i` draws from classes
/// `{(i·step + j) mod n_classes : j < classes_per_client}` with a
/// stride chosen so class usage is balanced, then each class's sample
/// pool is sliced evenly among the clients that use it.
///
/// Follows the shard construction of McMahan'17 (sort by label, deal
/// shards) generalized to n classes per client.
pub fn noniid_partition(
    labels: &[u8],
    n_clients: usize,
    classes_per_client: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let n_classes = (*labels.iter().max().expect("empty labels") as usize) + 1;
    assert!(
        classes_per_client >= 1 && classes_per_client <= n_classes,
        "classes_per_client {classes_per_client} outside [1, {n_classes}]"
    );
    // pool per class, shuffled
    let mut pools: Vec<Vec<usize>> = vec![Vec::new(); n_classes];
    for (i, &l) in labels.iter().enumerate() {
        pools[l as usize].push(i);
    }
    for p in pools.iter_mut() {
        rng.shuffle(p);
    }

    // class assignment: client i gets classes (i + j·offset) rotating
    // through the class ring so every class is used by the same number
    // of clients (when n_clients·cpc % n_classes == 0, exactly).
    let mut users_per_class = vec![0usize; n_classes];
    let mut assignment: Vec<Vec<usize>> = Vec::with_capacity(n_clients);
    for i in 0..n_clients {
        let mut classes = Vec::with_capacity(classes_per_client);
        let start = (i * classes_per_client) % n_classes;
        for j in 0..classes_per_client {
            let c = (start + j) % n_classes;
            classes.push(c);
            users_per_class[c] += 1;
        }
        assignment.push(classes);
    }

    // slice each class pool among its users
    let mut cursor = vec![0usize; n_classes];
    let mut out: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    for (i, classes) in assignment.iter().enumerate() {
        for &c in classes {
            let share = pools[c].len() / users_per_class[c].max(1);
            let start = cursor[c];
            let end = (start + share).min(pools[c].len());
            out[i].extend_from_slice(&pools[c][start..end]);
            cursor[c] = end;
        }
    }
    // distribute leftovers (rounding) to keep every sample owned
    for c in 0..n_classes {
        let mut i = 0usize;
        while cursor[c] < pools[c].len() {
            // give to clients that use class c, round-robin
            if assignment[i % n_clients].contains(&c) {
                out[i % n_clients].push(pools[c][cursor[c]]);
                cursor[c] += 1;
            }
            i += 1;
            if i > n_clients * (pools[c].len() + 1) {
                break; // no user of this class (can't happen with ring)
            }
        }
    }
    out
}

/// Count distinct label classes per client (diagnostics / tests).
pub fn classes_held(partition: &[Vec<usize>], labels: &[u8]) -> Vec<usize> {
    partition
        .iter()
        .map(|idxs| {
            let mut seen = [false; 256];
            let mut count = 0;
            for &i in idxs {
                let l = labels[i] as usize;
                if !seen[l] {
                    seen[l] = true;
                    count += 1;
                }
            }
            count
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn balanced_labels(n: usize) -> Vec<u8> {
        (0..n).map(|i| (i % 10) as u8).collect()
    }

    #[test]
    fn iid_covers_all_samples_once() {
        let mut rng = Rng::new(1);
        let parts = iid_partition(1003, 10, &mut rng);
        assert_eq!(parts.len(), 10);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        assert_eq!(all, (0..1003).collect::<Vec<_>>());
        // sizes balanced within 1
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn noniid_n_classes_exact() {
        let labels = balanced_labels(10_000);
        let mut rng = Rng::new(2);
        for n in [1usize, 2, 4, 6, 8] {
            let parts = noniid_partition(&labels, 100, n, &mut rng);
            let held = classes_held(&parts, &labels);
            assert!(
                held.iter().all(|&h| h == n),
                "Non-IID-{n}: classes held {held:?}"
            );
        }
    }

    #[test]
    fn noniid_covers_all_samples_once() {
        let labels = balanced_labels(10_000);
        let mut rng = Rng::new(3);
        let parts = noniid_partition(&labels, 100, 4, &mut rng);
        let mut all: Vec<usize> = parts.concat();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 10_000, "samples lost or duplicated");
    }

    #[test]
    fn noniid_sizes_roughly_balanced() {
        let labels = balanced_labels(10_000);
        let mut rng = Rng::new(4);
        let parts = noniid_partition(&labels, 100, 4, &mut rng);
        let sizes: Vec<usize> = parts.iter().map(|p| p.len()).collect();
        let min = *sizes.iter().min().unwrap();
        let max = *sizes.iter().max().unwrap();
        assert!(max - min <= 20, "sizes {min}..{max}");
    }

    #[test]
    fn noniid_full_classes_is_iid_like() {
        let labels = balanced_labels(1000);
        let mut rng = Rng::new(5);
        let parts = noniid_partition(&labels, 10, 10, &mut rng);
        let held = classes_held(&parts, &labels);
        assert!(held.iter().all(|&h| h == 10));
    }

    #[test]
    fn deterministic_per_seed() {
        let labels = balanced_labels(1000);
        let a = noniid_partition(&labels, 10, 4, &mut Rng::new(7));
        let b = noniid_partition(&labels, 10, 4, &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn rejects_zero_classes() {
        noniid_partition(&balanced_labels(100), 10, 0, &mut Rng::new(8));
    }
}
