//! Dataset substrate (DESIGN.md S12-S13).
//!
//! The paper evaluates on MNIST, Fashion-MNIST and CIFAR-10. This
//! environment has no network access, so [`synth`] provides
//! deterministic, learnable synthetic stand-ins with identical tensor
//! shapes and class counts; [`idx`] auto-loads the *real* datasets
//! (IDX / CIFAR-10 binary format) whenever the files are present under
//! `data/`, making the substitution transparent (DESIGN.md
//! §Substitutions). [`partition`] implements the §5 sample-allocation
//! matrix: IID and Non-IID-n client splits.

pub mod dataset;
pub mod idx;
pub mod partition;
pub mod synth;

pub use dataset::{Dataset, DatasetKind, Split};
pub use partition::{iid_partition, noniid_partition};
