//! Tolerance-banded comparison of `BENCH_*.json` reports against
//! committed baselines — the logic behind the `bench_diff` binary and
//! CI's `perf-smoke` regression gate (see PERF.md §bench-history).
//!
//! Cases are matched by their `name` field; the gate judges the **p50**
//! per-iteration latency (p95 is reported alongside for context but
//! does not gate — it is too noisy on shared CI runners). A case above
//! `fail_pct` p50 regression fails the gate, above `warn_pct` warns;
//! new cases (no baseline) and vanished cases are reported but never
//! fail, so adding/renaming benches does not wedge CI.

use super::json::Value;

/// Gate outcome, ordered by severity (`Pass < Warn < Fail`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Verdict {
    Pass,
    Warn,
    Fail,
}

impl Verdict {
    pub fn label(&self) -> &'static str {
        match self {
            Verdict::Pass => "ok",
            Verdict::Warn => "WARN",
            Verdict::Fail => "FAIL",
        }
    }
}

/// Regression tolerance bands, in percent of the baseline p50.
#[derive(Clone, Copy, Debug)]
pub struct Tolerance {
    pub warn_pct: f64,
    pub fail_pct: f64,
}

impl Default for Tolerance {
    fn default() -> Self {
        // generous bands: GitHub-hosted runners vary run to run, and
        // the quick-mode benches sample for only ~200 ms per case
        Self { warn_pct: 15.0, fail_pct: 30.0 }
    }
}

impl Tolerance {
    /// Judge one p50 delta (percent; negative = faster than baseline).
    pub fn verdict(&self, p50_delta_pct: f64) -> Verdict {
        if p50_delta_pct > self.fail_pct {
            Verdict::Fail
        } else if p50_delta_pct > self.warn_pct {
            Verdict::Warn
        } else {
            Verdict::Pass
        }
    }
}

/// One matched case: baseline vs current latency plus the verdict.
#[derive(Clone, Debug)]
pub struct CaseDelta {
    pub name: String,
    pub base_p50_s: f64,
    pub cur_p50_s: f64,
    /// `(cur − base) / base · 100`; negative = faster.
    pub p50_delta_pct: f64,
    pub base_p95_s: f64,
    pub cur_p95_s: f64,
    pub p95_delta_pct: f64,
    pub verdict: Verdict,
}

/// Comparison of one bench report file against its baseline.
#[derive(Clone, Debug, Default)]
pub struct BenchComparison {
    /// Report file stem, e.g. `BENCH_round`.
    pub bench: String,
    pub cases: Vec<CaseDelta>,
    /// Cases present only in the current report (no baseline yet).
    pub new_cases: Vec<String>,
    /// Baseline cases that vanished from the current report.
    pub missing_cases: Vec<String>,
}

impl BenchComparison {
    pub fn worst(&self) -> Verdict {
        self.cases.iter().map(|c| c.verdict).max().unwrap_or(Verdict::Pass)
    }
}

/// (name, p50_s, p95_s) rows of a report; cases without the shared
/// numeric fields are skipped (they cannot be compared).
fn case_rows(report: &Value) -> Vec<(String, f64, f64)> {
    let mut rows = Vec::new();
    if let Some(cases) = report.get("cases").and_then(|c| c.as_array()) {
        for case in cases {
            let name = case.get("name").and_then(|v| v.as_str());
            let p50 = case.get("p50_s").and_then(|v| v.as_f64());
            let p95 = case.get("p95_s").and_then(|v| v.as_f64());
            if let (Some(name), Some(p50), Some(p95)) = (name, p50, p95) {
                rows.push((name.to_string(), p50, p95));
            }
        }
    }
    rows
}

fn pct(base: f64, cur: f64) -> f64 {
    (cur - base) / base * 100.0
}

/// Compare a current report against its committed baseline.
pub fn compare(bench: &str, baseline: &Value, current: &Value, tol: Tolerance) -> BenchComparison {
    let base_rows = case_rows(baseline);
    let cur_rows = case_rows(current);
    let mut out = BenchComparison { bench: bench.to_string(), ..Default::default() };
    for (name, cur_p50, cur_p95) in &cur_rows {
        match base_rows.iter().find(|(b, _, _)| b == name) {
            // a zero baseline p50 cannot be banded (division by zero);
            // treat the case as new rather than inventing a verdict
            Some((_, base_p50, base_p95)) if *base_p50 > 0.0 => {
                let p50_delta_pct = pct(*base_p50, *cur_p50);
                out.cases.push(CaseDelta {
                    name: name.clone(),
                    base_p50_s: *base_p50,
                    cur_p50_s: *cur_p50,
                    p50_delta_pct,
                    base_p95_s: *base_p95,
                    cur_p95_s: *cur_p95,
                    p95_delta_pct: if *base_p95 > 0.0 { pct(*base_p95, *cur_p95) } else { 0.0 },
                    verdict: tol.verdict(p50_delta_pct),
                });
            }
            _ => out.new_cases.push(name.clone()),
        }
    }
    for (name, _, _) in &base_rows {
        if !cur_rows.iter().any(|(c, _, _)| c == name) {
            out.missing_cases.push(name.clone());
        }
    }
    out
}

/// Worst verdict across a set of report comparisons.
pub fn worst(cmps: &[BenchComparison]) -> Verdict {
    cmps.iter().map(|c| c.worst()).max().unwrap_or(Verdict::Pass)
}

/// Scale every case's p50/p95 up by `pct` percent — the synthetic-
/// regression aid behind `bench_diff --inflate-current`, which CI uses
/// to prove the gate actually trips on a >fail_pct regression.
pub fn inflate_report(report: &Value, pct: f64) -> Value {
    let factor = 1.0 + pct / 100.0;
    let mut out = report.clone();
    if let Value::Object(obj) = &mut out {
        if let Some(Value::Array(cases)) = obj.get_mut("cases") {
            for case in cases {
                if let Value::Object(fields) = case {
                    for key in ["p50_s", "p95_s"] {
                        if let Some(Value::Num(x)) = fields.get_mut(key) {
                            *x *= factor;
                        }
                    }
                }
            }
        }
    }
    out
}

fn fmt_s(secs: f64) -> String {
    super::timer::fmt_duration(std::time::Duration::from_secs_f64(secs.max(0.0)))
}

fn fmt_pct(p: f64) -> String {
    format!("{p:+.1}%")
}

/// Markdown summary of the gate run — one table per compared report —
/// sized for `$GITHUB_STEP_SUMMARY` so regressions are readable
/// without downloading artifacts. `verdict` is the caller's FINAL
/// gate outcome (it may be worse than `worst(cmps)`, e.g. when a
/// whole baseline report vanished), so the headline never contradicts
/// the exit code.
pub fn markdown(cmps: &[BenchComparison], tol: Tolerance, verdict: Verdict) -> String {
    let mut md = format!(
        "## perf gate: {} (fail >{:.0}% p50, warn >{:.0}%)\n\n",
        verdict.label(),
        tol.fail_pct,
        tol.warn_pct
    );
    for cmp in cmps {
        md.push_str(&format!("### {}\n\n", cmp.bench));
        if !cmp.cases.is_empty() {
            md.push_str("| case | base p50 | cur p50 | Δp50 | base p95 | cur p95 | Δp95 | verdict |\n");
            md.push_str("|---|---|---|---|---|---|---|---|\n");
            for c in &cmp.cases {
                md.push_str(&format!(
                    "| {} | {} | {} | {} | {} | {} | {} | {} |\n",
                    c.name,
                    fmt_s(c.base_p50_s),
                    fmt_s(c.cur_p50_s),
                    fmt_pct(c.p50_delta_pct),
                    fmt_s(c.base_p95_s),
                    fmt_s(c.cur_p95_s),
                    fmt_pct(c.p95_delta_pct),
                    c.verdict.label(),
                ));
            }
            md.push('\n');
        }
        if !cmp.new_cases.is_empty() {
            md.push_str(&format!("new cases (no baseline): {}\n\n", cmp.new_cases.join(", ")));
        }
        if !cmp.missing_cases.is_empty() {
            md.push_str(&format!(
                "baseline cases missing from this run: {}\n\n",
                cmp.missing_cases.join(", ")
            ));
        }
    }
    md
}

/// Headline for a gate run that compared **nothing** because no
/// committed baselines matched (the bootstrap state): say
/// "reporting-only" explicitly instead of a vacuous "perf gate: ok"
/// over zero cases, which read as a passing comparison when nothing
/// was compared at all.
pub fn markdown_reporting_only(n_reports: usize, baseline_dir: &str) -> String {
    format!(
        "## perf gate: reporting-only — no committed baselines under `{}` \
         ({} report(s) listed, 0 compared)\n\nThe gate arms once the first \
         BENCH_*.json files are committed (see bench-history/README.md).\n\n",
        baseline_dir, n_reports
    )
}

/// Markdown p50/p95 table for a report with **no** committed baseline
/// (the bootstrap state — see bench-history/README.md): current
/// numbers only, so the step summary is still informative.
pub fn markdown_current_only(bench: &str, current: &Value) -> String {
    let mut md = format!("### {} (no committed baseline — reporting only)\n\n", bench);
    md.push_str("| case | p50 | p95 |\n|---|---|---|\n");
    for (name, p50, p95) in case_rows(current) {
        md.push_str(&format!("| {} | {} | {} |\n", name, fmt_s(p50), fmt_s(p95)));
    }
    md.push('\n');
    md
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{arr, num, obj, s};

    fn report(cases: &[(&str, f64, f64)]) -> Value {
        obj(vec![
            ("bench", s("round")),
            (
                "cases",
                arr(cases
                    .iter()
                    .map(|(name, p50, p95)| {
                        obj(vec![
                            ("name", s(name)),
                            ("n", num(100.0)),
                            ("p50_s", num(*p50)),
                            ("p95_s", num(*p95)),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    #[test]
    fn bands_classify_deltas() {
        let tol = Tolerance::default();
        assert_eq!(tol.verdict(-40.0), Verdict::Pass); // improvement
        assert_eq!(tol.verdict(0.0), Verdict::Pass);
        assert_eq!(tol.verdict(14.9), Verdict::Pass);
        assert_eq!(tol.verdict(15.1), Verdict::Warn);
        assert_eq!(tol.verdict(30.1), Verdict::Fail);
    }

    #[test]
    fn compare_matches_by_name_and_judges_p50() {
        let base = report(&[("a", 1.0, 2.0), ("b", 1.0, 2.0), ("c", 1.0, 2.0)]);
        let cur = report(&[("a", 1.05, 2.2), ("b", 1.2, 2.0), ("c", 1.5, 2.0)]);
        let cmp = compare("BENCH_x", &base, &cur, Tolerance::default());
        assert_eq!(cmp.cases.len(), 3);
        assert_eq!(cmp.cases[0].verdict, Verdict::Pass);
        assert_eq!(cmp.cases[1].verdict, Verdict::Warn);
        assert_eq!(cmp.cases[2].verdict, Verdict::Fail);
        assert_eq!(cmp.worst(), Verdict::Fail);
        assert!((cmp.cases[2].p50_delta_pct - 50.0).abs() < 1e-9);
    }

    #[test]
    fn p95_reports_but_never_gates() {
        let base = report(&[("a", 1.0, 1.0)]);
        let cur = report(&[("a", 1.0, 9.0)]); // p95 ×9, p50 flat
        let cmp = compare("BENCH_x", &base, &cur, Tolerance::default());
        assert_eq!(cmp.worst(), Verdict::Pass);
        assert!(cmp.cases[0].p95_delta_pct > 700.0);
    }

    #[test]
    fn new_and_missing_cases_never_fail() {
        let base = report(&[("gone", 1.0, 1.0)]);
        let cur = report(&[("fresh", 1.0, 1.0)]);
        let cmp = compare("BENCH_x", &base, &cur, Tolerance::default());
        assert!(cmp.cases.is_empty());
        assert_eq!(cmp.new_cases, vec!["fresh"]);
        assert_eq!(cmp.missing_cases, vec!["gone"]);
        assert_eq!(cmp.worst(), Verdict::Pass);
    }

    #[test]
    fn zero_baseline_is_not_a_division() {
        let base = report(&[("a", 0.0, 0.0)]);
        let cur = report(&[("a", 1.0, 1.0)]);
        let cmp = compare("BENCH_x", &base, &cur, Tolerance::default());
        assert!(cmp.cases.is_empty());
        assert_eq!(cmp.new_cases, vec!["a"]);
    }

    #[test]
    fn inflation_trips_the_gate() {
        // the CI self-test contract: a report inflated by 50% must
        // FAIL against itself under the default 30% band
        let base = report(&[("a", 0.010, 0.012), ("b", 0.5, 0.6)]);
        let cur = inflate_report(&base, 50.0);
        let cmp = compare("BENCH_x", &base, &cur, Tolerance::default());
        assert_eq!(cmp.worst(), Verdict::Fail);
        assert!(cmp.cases.iter().all(|c| (c.p50_delta_pct - 50.0).abs() < 1e-6));
        // and un-inflated passes against itself
        let clean = compare("BENCH_x", &base, &base, Tolerance::default());
        assert_eq!(clean.worst(), Verdict::Pass);
    }

    #[test]
    fn reporting_only_headline_is_explicit() {
        let md = markdown_reporting_only(3, "../bench-history");
        assert!(md.contains("reporting-only"));
        assert!(md.contains("../bench-history"));
        assert!(md.contains("0 compared"));
        assert!(md.contains("bench-history/README.md"), "arming recipe pointer");
        assert!(
            !md.contains("perf gate: ok"),
            "reporting-only must not read as a passing comparison"
        );
    }

    #[test]
    fn markdown_lists_every_case_and_verdict() {
        let base = report(&[("alpha/case", 1.0, 2.0)]);
        let cur = report(&[("alpha/case", 1.4, 2.0), ("beta/new", 1.0, 1.0)]);
        let cmps = vec![compare("BENCH_x", &base, &cur, Tolerance::default())];
        let md = markdown(&cmps, Tolerance::default(), worst(&cmps));
        assert!(md.contains("alpha/case"));
        assert!(md.contains("FAIL"));
        assert!(md.contains("beta/new"));
        let solo = markdown_current_only("BENCH_y", &cur);
        assert!(solo.contains("beta/new") && solo.contains("no committed baseline"));
    }
}
