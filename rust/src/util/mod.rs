//! Infrastructure substrates built in-repo (the offline vendor set only
//! carries the `xla` crate's closure — see DESIGN.md §Substitutions).
//!
//! * [`rng`] — splitmix64 / xoshiro256** PRNGs + normal sampling
//! * [`chacha`] — ChaCha20 stream cipher used as the secagg mask PRG
//! * [`json`] — minimal JSON parser/serializer (manifest, metrics)
//! * [`cli`] — declarative command-line argument parser
//! * [`pool`] — fixed thread pool + `parallel_map`
//! * [`simd`] — portable f32x8/u32x8/u32x4 lane types (SSE2/AVX2 with
//!   scalar fallback) behind the bitwise-determinism contract
//! * [`bench`] — criterion-style micro-benchmark harness
//! * [`benchcmp`] — tolerance-banded BENCH_*.json comparison (the CI
//!   perf-regression gate behind the `bench_diff` binary)
//! * [`prop`] — seeded property-testing helper with shrinking
//! * [`timer`] — stopwatch / duration formatting

pub mod bench;
pub mod benchcmp;
pub mod chacha;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod timer;
