//! Minimal JSON parser + serializer.
//!
//! Consumes `artifacts/manifest.json` (written by the python AOT
//! exporter) and emits metric/result files. Built in-repo because the
//! offline vendor set carries no serde facade (DESIGN.md S20).
//!
//! Supports the full JSON grammar except `\u` surrogate pairs are
//! combined but lone surrogates are replaced (we never emit them).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (the manifest only uses
/// integers ≤ 2^31 and small floats, well inside f64's exact range).
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// `obj["a"]["b"]` chains without panics.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let cp = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() == Some(b'\\') && self.bump() == Some(b'u') {
                                let lo = self.hex4()?;
                                0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                            } else {
                                return Err(self.err("lone surrogate"));
                            }
                        } else {
                            hi
                        };
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // re-assemble UTF-8 multibyte sequences
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        match std::str::from_utf8(&self.bytes[start..end]) {
                            Ok(s) => {
                                out.push_str(s);
                                self.pos = end;
                            }
                            Err(_) => return Err(self.err("invalid utf-8")),
                        }
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

// ------------------------------------------------------------ serialize

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Array(items) => {
                write!(f, "[")?;
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{it}")?;
                }
                write!(f, "]")
            }
            Value::Object(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

/// Builder helpers for emitting metric files.
pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
    Value::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Value {
    Value::Num(x)
}

pub fn s(text: &str) -> Value {
    Value::Str(text.to_string())
}

pub fn arr(items: Vec<Value>) -> Value {
    Value::Array(items)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let text = r#"{
          "version": 1,
          "train_batch": 50,
          "models": {
            "mnist_mlp": {
              "params": [{"name": "layer0/w", "shape": [784, 200],
                          "init": {"kind": "normal", "std": 0.0505}, "layer": 0}],
              "param_count": 159010
            }
          }
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.get("version").unwrap().as_usize(), Some(1));
        let p = v.path(&["models", "mnist_mlp", "param_count"]).unwrap();
        assert_eq!(p.as_usize(), Some(159_010));
        let shape = v
            .path(&["models", "mnist_mlp", "params"]).unwrap()
            .as_array().unwrap()[0]
            .get("shape").unwrap()
            .as_array().unwrap();
        assert_eq!(shape[0].as_usize(), Some(784));
    }

    #[test]
    fn roundtrip() {
        let v = obj(vec![
            ("a", num(1.5)),
            ("b", arr(vec![num(1.0), Value::Bool(true), Value::Null])),
            ("c", s("hi \"there\"\n")),
        ]);
        let text = v.to_string();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = parse(r#""a\tbé€""#).unwrap();
        assert_eq!(v.as_str(), Some("a\tbé€"));
        // surrogate pair: 😀
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn parses_numbers() {
        assert_eq!(parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(parse("0.25").unwrap().as_f64(), Some(0.25));
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(num(42.0).to_string(), "42");
        assert_eq!(num(0.5).to_string(), "0.5");
    }
}
