//! Fixed-size thread pool (tokio substitute for this workload —
//! DESIGN.md S19). The FL round loop fans client-local work out to the
//! pool and joins at the round barrier, which is exactly a scoped
//! parallel map; no async runtime needed.
//!
//! PJRT executors are **not** `Send`, so under the `pjrt` feature
//! compute jobs do not run here — they run on the dedicated executor
//! threads owned by `crate::runtime::ExecutorPool`. This pool handles
//! the pure-rust work: native-backend local training, sparsification,
//! masking, encoding, data synthesis.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    rx_shared: Arc<Mutex<mpsc::Receiver<Msg>>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to ≥1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx_shared = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx_shared);
                thread::Builder::new()
                    .name(format!("fedsparse-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx, rx_shared, workers }
    }

    /// Default-sized pool: available parallelism − 1, min 1.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    /// Apply `f` to every item, in parallel, preserving order.
    ///
    /// `f` is cloned per item on the caller's thread (so `F` needs
    /// `Clone + Send` but not `Sync` — closures may capture e.g.
    /// channel senders); items and results cross threads.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Clone + 'static,
    {
        let n = items.len();
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = f.clone();
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("pool worker died");
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // Drain handles; a worker that already exited returns Err which
        // we ignore — shutdown is best-effort.
        let _ = &self.rx_shared;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect(), |x: usize| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn submit_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..50 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn map_on_single_worker() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![3, 1, 2], |x: i32| x + 10);
        assert_eq!(out, vec![13, 11, 12]);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        let _ = pool.map((0..10).collect(), |x: usize| x);
        drop(pool); // must not hang
    }

    #[test]
    fn empty_map() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }
}
