//! Fixed-size thread pool (tokio substitute for this workload —
//! DESIGN.md S19). The FL round loop fans client-local work out to the
//! pool and joins at the round barrier, which is exactly a scoped
//! parallel map; no async runtime needed.
//!
//! PJRT executors are **not** `Send`, so under the `pjrt` feature
//! compute jobs do not run here — they run on the dedicated executor
//! threads owned by `crate::runtime::ExecutorPool`. This pool handles
//! the pure-rust work: native-backend local training, sparsification,
//! masking, encoding, data synthesis.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

enum Msg {
    Run(Job),
    Shutdown,
}

/// A fixed pool of worker threads.
pub struct ThreadPool {
    tx: mpsc::Sender<Msg>,
    rx_shared: Arc<Mutex<mpsc::Receiver<Msg>>>,
    workers: Vec<thread::JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `n` workers (clamped to ≥1).
    pub fn new(n: usize) -> Self {
        let n = n.max(1);
        let (tx, rx) = mpsc::channel::<Msg>();
        let rx_shared = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx_shared);
                thread::Builder::new()
                    .name(format!("fedsparse-pool-{i}"))
                    .spawn(move || loop {
                        let msg = { rx.lock().unwrap().recv() };
                        match msg {
                            Ok(Msg::Run(job)) => job(),
                            Ok(Msg::Shutdown) | Err(_) => break,
                        }
                    })
                    .expect("spawn pool worker")
            })
            .collect();
        Self { tx, rx_shared, workers }
    }

    /// Default-sized pool: available parallelism − 1, min 1.
    pub fn with_default_size() -> Self {
        let n = thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
        Self::new(n.saturating_sub(1).max(1))
    }

    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a fire-and-forget job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.tx.send(Msg::Run(Box::new(f))).expect("pool closed");
    }

    /// Apply `f` to every item, in parallel, preserving order.
    ///
    /// `f` is cloned per item on the caller's thread (so `F` needs
    /// `Clone + Send` but not `Sync` — closures may capture e.g.
    /// channel senders); items and results cross threads.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Clone + 'static,
    {
        let n = items.len();
        let (rtx, rrx) = mpsc::channel::<(usize, R)>();
        for (i, item) in items.into_iter().enumerate() {
            let f = f.clone();
            let rtx = rtx.clone();
            self.submit(move || {
                let r = f(item);
                let _ = rtx.send((i, r));
            });
        }
        drop(rtx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for _ in 0..n {
            let (i, r) = rrx.recv().expect("pool worker died");
            slots[i] = Some(r);
        }
        slots.into_iter().map(|s| s.unwrap()).collect()
    }
}

/// Claim-based work state for [`ThreadPool::map_shared`]: tasks are
/// immutable, indices are claimed from an atomic counter, results land
/// in per-index slots, and a (count, condvar) pair signals completion.
struct Shared<T, R, F> {
    tasks: Vec<T>,
    f: F,
    next: AtomicUsize,
    slots: Mutex<Vec<Option<R>>>,
    done: (Mutex<usize>, Condvar),
}

/// Signals one task's completion on drop — including during unwind,
/// so a panicking task leaves its slot empty but still wakes the
/// waiting caller, which then panics on the missing result instead of
/// wedging on the condvar forever.
struct DoneGuard<'a> {
    done: &'a Mutex<usize>,
    cv: &'a Condvar,
    n: usize,
}

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let mut done = self.done.lock().unwrap();
        *done += 1;
        if *done == self.n {
            self.cv.notify_all();
        }
    }
}

/// Claim and run tasks until the counter runs past the end. Called by
/// the `map_shared` caller *and* by its best-effort pool helpers: each
/// index is claimed exactly once, whoever gets there first.
fn drain_shared<T, R, F: Fn(&T) -> R>(st: &Shared<T, R, F>) {
    loop {
        let i = st.next.fetch_add(1, Ordering::Relaxed);
        if i >= st.tasks.len() {
            return;
        }
        let guard = DoneGuard { done: &st.done.0, cv: &st.done.1, n: st.tasks.len() };
        let r = (st.f)(&st.tasks[i]);
        st.slots.lock().unwrap()[i] = Some(r);
        drop(guard);
    }
}

impl ThreadPool {
    /// Parallel map where the **caller participates**: task indices are
    /// claimed from a shared counter by the caller and by best-effort
    /// helper jobs, so the call always makes progress even when every
    /// pool worker is busy. That makes it safe to call from *inside* a
    /// pool job (nested fan-out) — unlike [`Self::map`], which parks
    /// the caller until workers drain the queue and therefore
    /// deadlocks when all workers are themselves waiting on nested
    /// maps. Worst case (no worker ever frees up) the caller simply
    /// runs every task itself.
    ///
    /// Results come back in input order; `f` runs exactly once per
    /// item. A panicking task does NOT wedge the caller: completion is
    /// signalled by a drop guard, so the panic surfaces here as a
    /// missing-result panic (the worker that ran it is lost, as with
    /// [`Self::map`]).
    pub fn map_shared<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + Sync + 'static,
        R: Send + 'static,
        F: Fn(&T) -> R + Send + Sync + 'static,
    {
        let n = items.len();
        if n == 0 {
            return Vec::new();
        }
        let st = Arc::new(Shared {
            tasks: items,
            f,
            next: AtomicUsize::new(0),
            slots: Mutex::new((0..n).map(|_| None).collect()),
            done: (Mutex::new(0), Condvar::new()),
        });
        // Helpers are opportunistic: each claims whatever the caller
        // has not reached yet and exits as soon as nothing is left. At
        // most n−1 of them can ever hold work (the caller takes one).
        for _ in 0..self.size().min(n.saturating_sub(1)) {
            let st = Arc::clone(&st);
            self.submit(move || drain_shared(&st));
        }
        drain_shared(&st);
        // the caller ran out of claimable tasks; wait for in-flight
        // helper claims to finish
        let mut done = st.done.0.lock().unwrap();
        while *done < n {
            done = st.done.1.wait(done).unwrap();
        }
        drop(done);
        let slots = std::mem::take(&mut *st.slots.lock().unwrap());
        slots.into_iter().map(|s| s.expect("map_shared task panicked")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Msg::Shutdown);
        }
        // Drain handles; a worker that already exited returns Err which
        // we ignore — shutdown is best-effort.
        let _ = &self.rx_shared;
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map((0..100).collect(), |x: usize| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn submit_runs_jobs() {
        let pool = ThreadPool::new(2);
        let counter = Arc::new(AtomicUsize::new(0));
        let (tx, rx) = mpsc::channel();
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            let tx = tx.clone();
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                let _ = tx.send(());
            });
        }
        for _ in 0..50 {
            rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }

    #[test]
    fn map_on_single_worker() {
        let pool = ThreadPool::new(1);
        let out = pool.map(vec![3, 1, 2], |x: i32| x + 10);
        assert_eq!(out, vec![13, 11, 12]);
    }

    #[test]
    fn drop_joins_cleanly() {
        let pool = ThreadPool::new(3);
        let _ = pool.map((0..10).collect(), |x: usize| x);
        drop(pool); // must not hang
    }

    #[test]
    fn empty_map() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map(Vec::<usize>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn map_shared_preserves_order() {
        let pool = ThreadPool::new(4);
        let out = pool.map_shared((0..200).collect(), |&x: &usize| x * 3);
        assert_eq!(out, (0..200).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn map_shared_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<usize> = pool.map_shared(Vec::<usize>::new(), |&x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn map_shared_runs_each_task_once() {
        let pool = ThreadPool::new(3);
        let counter = Arc::new(AtomicUsize::new(0));
        let c = Arc::clone(&counter);
        let out = pool.map_shared((0..64).collect(), move |&x: &usize| {
            c.fetch_add(1, Ordering::SeqCst);
            x
        });
        assert_eq!(out.len(), 64);
        assert_eq!(counter.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn map_shared_panicking_task_panics_not_hangs() {
        // whichever thread claims the poisoned index — caller (panic
        // propagates directly) or helper (caller panics on the empty
        // slot) — the call must end in a panic, never a hang
        let pool = ThreadPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.map_shared((0..8).collect(), |&i: &usize| {
                if i == 3 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(result.is_err());
    }

    #[test]
    fn map_shared_nested_inside_workers_does_not_deadlock() {
        // the round engine's shape: an outer `map` of client jobs, each
        // fanning pair-mask generation out with `map_shared` on the
        // SAME pool. With `map` this would deadlock (all workers block
        // waiting for queued inner jobs); `map_shared` callers claim
        // their own tasks, so every nesting level makes progress.
        for workers in [1usize, 2, 4] {
            let pool = Arc::new(ThreadPool::new(workers));
            let p = Arc::clone(&pool);
            let out = pool.map((0..6).collect(), move |outer: usize| {
                let inner = p.map_shared((0..9).collect(), |&i: &usize| i + 1);
                outer * inner.iter().sum::<usize>()
            });
            assert_eq!(out, (0..6).map(|o| o * 45).collect::<Vec<_>>());
        }
    }
}
