//! Seeded property-testing helper (proptest substitute, DESIGN.md S23).
//!
//! A property runs over `cases` generated inputs; on failure the input
//! is shrunk (for the built-in generators) and the failing seed is
//! reported so the case can be replayed deterministically:
//!
//! ```no_run
//! use fedsparse::util::prop::{forall, vec_f32};
//! forall("sparse+residual==g", 200, vec_f32(1..=4096, 10.0), |g| {
//!     // property body returning bool
//!     !g.is_empty() || g.is_empty()
//! });
//! ```

use super::rng::Rng;

/// A generator produces a value from an RNG.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate smaller inputs (for shrinking); default: none.
    fn shrink(&self, _v: &Self::Value) -> Vec<Self::Value> {
        Vec::new()
    }
}

/// Run `prop` over `cases` generated inputs. Panics (with seed and
/// shrunk input) on the first failure — mirroring proptest's behavior
/// so `cargo test` reports it.
pub fn forall<G: Gen>(name: &str, cases: u64, gen: G, prop: impl Fn(&G::Value) -> bool) {
    // Base seed is fixed for reproducibility; override with env var.
    let base = std::env::var("FEDSPARSE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xfed5_9a12_u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut rng = Rng::new(seed);
        let input = gen.generate(&mut rng);
        if prop(&input) {
            continue;
        }
        // shrink
        let mut failing = input;
        'outer: loop {
            for cand in gen.shrink(&failing) {
                if !prop(&cand) {
                    failing = cand;
                    continue 'outer;
                }
            }
            break;
        }
        panic!(
            "property '{name}' failed (case {case}, seed {seed}).\n  shrunk input: {failing:?}"
        );
    }
}

// --------------------------------------------------------- generators

/// Uniform f32 vectors with length in `range`, values in ±`scale`.
pub struct VecF32 {
    pub min_len: usize,
    pub max_len: usize,
    pub scale: f32,
}

pub fn vec_f32(range: std::ops::RangeInclusive<usize>, scale: f32) -> VecF32 {
    VecF32 { min_len: *range.start(), max_len: *range.end(), scale }
}

impl Gen for VecF32 {
    type Value = Vec<f32>;

    fn generate(&self, rng: &mut Rng) -> Vec<f32> {
        let len = self.min_len + rng.below((self.max_len - self.min_len + 1) as u64) as usize;
        (0..len)
            .map(|_| (rng.next_f32() * 2.0 - 1.0) * self.scale)
            .collect()
    }

    fn shrink(&self, v: &Vec<f32>) -> Vec<Vec<f32>> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            // halve the tail
            let keep = (v.len() / 2).max(self.min_len);
            out.push(v[..keep].to_vec());
            // drop the head half
            if v.len() - keep >= self.min_len {
                out.push(v[keep..].to_vec());
            }
        }
        // zero out values (simpler values often still fail)
        if v.iter().any(|&x| x != 0.0) {
            out.push(v.iter().map(|&x| if x.abs() > 1.0 { x.signum() } else { 0.0 }).collect());
        }
        out
    }
}

/// Pairs of independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for Pair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> = self
            .0
            .shrink(&v.0)
            .into_iter()
            .map(|a| (a, v.1.clone()))
            .collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Uniform usize in an inclusive range.
pub struct USize {
    pub min: usize,
    pub max: usize,
}

pub fn usize_in(range: std::ops::RangeInclusive<usize>) -> USize {
    USize { min: *range.start(), max: *range.end() }
}

impl Gen for USize {
    type Value = usize;

    fn generate(&self, rng: &mut Rng) -> usize {
        self.min + rng.below((self.max - self.min + 1) as u64) as usize
    }

    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.min {
            out.push(self.min);
            out.push(self.min + (v - self.min) / 2);
        }
        out.dedup();
        out
    }
}

/// Uniform f32 in a range.
pub struct F32In {
    pub min: f32,
    pub max: f32,
}

pub fn f32_in(min: f32, max: f32) -> F32In {
    F32In { min, max }
}

impl Gen for F32In {
    type Value = f32;

    fn generate(&self, rng: &mut Rng) -> f32 {
        self.min + rng.next_f32() * (self.max - self.min)
    }

    fn shrink(&self, v: &f32) -> Vec<f32> {
        if *v != self.min {
            vec![self.min, self.min + (v - self.min) / 2.0]
        } else {
            Vec::new()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_completes() {
        forall("len in range", 100, vec_f32(1..=64, 1.0), |v| {
            (1..=64).contains(&v.len())
        });
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_name() {
        forall("always false", 5, usize_in(0..=10), |_| false);
    }

    #[test]
    fn shrinking_reaches_small_input() {
        // capture the panic message and check the shrunk witness is minimal
        let result = std::panic::catch_unwind(|| {
            forall("len<=8", 50, vec_f32(1..=256, 1.0), |v| v.len() <= 8);
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // the shrunk witness should be 9..=16 long (halving stops there)
        assert!(msg.contains("shrunk input"), "{msg}");
    }

    #[test]
    fn pair_generates_both() {
        forall(
            "pair",
            50,
            Pair(usize_in(1..=4), f32_in(0.0, 1.0)),
            |(n, x)| *n >= 1 && *n <= 4 && (0.0..=1.0).contains(x),
        );
    }
}
