//! Criterion-style micro-benchmark harness (DESIGN.md S22).
//!
//! The offline vendor set carries no criterion, so `cargo bench` runs
//! `harness = false` targets built on this module. It reproduces the
//! parts the experiment suite needs: warm-up, automatic iteration
//! scaling to a target measurement time, and mean/σ/p50/p95 reporting.
//!
//! ```no_run
//! use fedsparse::util::bench::Bench;
//! let mut b = Bench::new("sparsify");
//! let data = vec![0.5f32; 1 << 20];
//! b.bench("topk/1M", || {
//!     // measured body
//!     std::hint::black_box(&data);
//! });
//! b.finish();
//! ```

use std::time::{Duration, Instant};

use super::json::{arr, num, obj, s, Value};
use super::timer::fmt_duration;

/// Result statistics for one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    /// Elements processed per iteration (0 when the case has no
    /// natural element count).
    pub n: u64,
    pub iters: u64,
    pub mean: Duration,
    pub std_dev: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub min: Duration,
}

impl Stats {
    /// Throughput helper: elements/second given per-iter element count.
    pub fn throughput(&self, elems_per_iter: u64) -> f64 {
        elems_per_iter as f64 / self.mean.as_secs_f64()
    }
}

/// A named group of benchmark cases.
pub struct Bench {
    group: String,
    /// Target cumulative measurement time per case.
    pub measure_time: Duration,
    /// Warm-up time per case.
    pub warmup_time: Duration,
    /// Number of sample batches.
    pub samples: usize,
    results: Vec<Stats>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // honor a quick mode for CI: FEDSPARSE_BENCH_QUICK=1
        let quick = std::env::var("FEDSPARSE_BENCH_QUICK").is_ok();
        Self {
            group: group.to_string(),
            measure_time: if quick { Duration::from_millis(200) } else { Duration::from_secs(2) },
            warmup_time: if quick { Duration::from_millis(50) } else { Duration::from_millis(500) },
            samples: if quick { 10 } else { 30 },
            results: Vec::new(),
        }
    }

    /// Measure `f`, auto-scaling iterations per sample batch.
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> Stats {
        self.bench_case(name, 0, f)
    }

    fn bench_case<F: FnMut()>(&mut self, name: &str, n: u64, mut f: F) -> Stats {
        // warm-up + per-iteration estimate
        let warm_start = Instant::now();
        let mut warm_iters = 0u64;
        while warm_start.elapsed() < self.warmup_time || warm_iters < 3 {
            f();
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let per_iter = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

        // choose iters per sample so that samples fill measure_time
        let total_iters = (self.measure_time.as_secs_f64() / per_iter.max(1e-9)) as u64;
        let iters_per_sample = (total_iters / self.samples as u64).max(1);

        let mut sample_means: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            sample_means.push(t0.elapsed().as_secs_f64() / iters_per_sample as f64);
        }
        sample_means.sort_by(|a, b| a.partial_cmp(b).unwrap());

        let n_samples = sample_means.len();
        let mean = sample_means.iter().sum::<f64>() / n_samples as f64;
        let var =
            sample_means.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n_samples as f64;
        let stats = Stats {
            name: format!("{}/{}", self.group, name),
            n,
            iters: iters_per_sample * n_samples as u64,
            mean: Duration::from_secs_f64(mean),
            std_dev: Duration::from_secs_f64(var.sqrt()),
            p50: Duration::from_secs_f64(sample_means[n_samples / 2]),
            p95: Duration::from_secs_f64(sample_means[(n_samples * 95 / 100).min(n_samples - 1)]),
            min: Duration::from_secs_f64(sample_means[0]),
        };
        println!(
            "{:<44} time: [{}  ±{}]  p50 {}  p95 {}  ({} iters)",
            stats.name,
            fmt_duration(stats.mean),
            fmt_duration(stats.std_dev),
            fmt_duration(stats.p50),
            fmt_duration(stats.p95),
            stats.iters,
        );
        self.results.push(stats.clone());
        stats
    }

    /// Like [`bench`](Self::bench) but also prints element throughput.
    pub fn bench_throughput<F: FnMut()>(&mut self, name: &str, elems: u64, f: F) -> Stats {
        let stats = self.bench_case(name, elems, f);
        let tput = stats.throughput(elems);
        println!(
            "{:<44} thrpt: {:.2} Melem/s",
            format!("{}/{}", self.group, name),
            tput / 1e6
        );
        stats
    }

    /// Machine-readable report of every case so far — the shared
    /// `BENCH_<group>.json` schema (name, n, iters, mean/σ/p50/p95/min
    /// seconds) that tracks the perf trajectory across PRs.
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("bench", s(&self.group)),
            (
                "cases",
                arr(self
                    .results
                    .iter()
                    .map(|st| {
                        obj(vec![
                            ("name", s(&st.name)),
                            ("n", num(st.n as f64)),
                            ("iters", num(st.iters as f64)),
                            ("mean_s", num(st.mean.as_secs_f64())),
                            ("std_dev_s", num(st.std_dev.as_secs_f64())),
                            ("p50_s", num(st.p50.as_secs_f64())),
                            ("p95_s", num(st.p95.as_secs_f64())),
                            ("min_s", num(st.min.as_secs_f64())),
                        ])
                    })
                    .collect()),
            ),
        ])
    }

    /// Print the summary table and write `BENCH_<group>.json` (cwd);
    /// call once at the end of the bench bin. Bins that want a richer
    /// report (e.g. `bench_round`'s per-phase timings) overwrite the
    /// file afterwards.
    pub fn finish(self) -> Vec<Stats> {
        println!("\n== {} summary ==", self.group);
        for s in &self.results {
            println!("{:<44} {}", s.name, fmt_duration(s.mean));
        }
        let path = format!("BENCH_{}.json", self.group);
        match std::fs::write(&path, self.to_json().to_string()) {
            Ok(()) => println!("machine-readable report: {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
        self.results
    }
}

/// Prevent the optimizer from eliding a value (std::hint::black_box
/// passthrough, kept here so bench bins only import this module).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        std::env::set_var("FEDSPARSE_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        b.measure_time = Duration::from_millis(30);
        b.warmup_time = Duration::from_millis(5);
        b.samples = 5;
        let mut acc = 0u64;
        let s = b.bench("noop-ish", || {
            acc = acc.wrapping_add(black_box(1));
        });
        assert!(s.iters > 0);
        assert!(s.mean.as_nanos() > 0);
        assert!(s.p95 >= s.p50 || s.std_dev.as_nanos() == 0);
    }

    #[test]
    fn throughput_positive() {
        std::env::set_var("FEDSPARSE_BENCH_QUICK", "1");
        let mut b = Bench::new("test");
        b.measure_time = Duration::from_millis(20);
        b.warmup_time = Duration::from_millis(5);
        b.samples = 4;
        let v = vec![1f32; 1024];
        let s = b.bench_throughput("sum", 1024, || {
            black_box(v.iter().sum::<f32>());
        });
        assert!(s.throughput(1024) > 0.0);
    }
}
