//! Dependency-free portable SIMD lane types for the hot kernels.
//!
//! Three fixed-width lane types over `core::arch` with a scalar
//! fallback, selected by `cfg` at compile time:
//!
//! * [`F32x8`] — eight f32 lanes (AVX2 `__m256`, else two SSE2
//!   `__m128`s, else two NEON `float32x4_t`s on aarch64, else a
//!   `[f32; 8]` loop) — the blocked-matmul axpy sweeps
//!   (`runtime::native`), the backward-input gather dot
//!   ([`F32x8::gather`], AVX2 only), and the Top-k abs-scan
//!   (`sparse::topk`);
//! * [`U32x8`] — eight u32 lanes, used through [`LaneFilter`] for the
//!   σ-filter's integer compare + compress (`secagg::mask`);
//! * [`U32x4`] — four u32 lanes (128-bit on x86_64 and aarch64), the
//!   four-blocks-per-dispatch ChaCha core (`util::chacha`).
//!
//! ## The bitwise contract (PERF.md)
//!
//! Every consumer vectorizes **across independent accumulators/lanes**
//! only: each f32 accumulator still receives exactly the same
//! `mul`-then-`add` op sequence as the scalar code (no FMA — a fused
//! multiply-add rounds once where the scalar code rounds twice, which
//! would break every golden test), integer compares are exact, and the
//! ChaCha blocks a quad dispatch produces are the same independent
//! expansions the scalar loop produces one at a time. SIMD on/off is
//! therefore bitwise invisible; the property tests in this module and
//! in each consumer pin that at lane-remainder widths.
//!
//! ## Runtime escape hatch
//!
//! `FEDSPARSE_NO_SIMD=1` (any value other than empty or `0`) makes
//! [`enabled`] return false; kernels read it once per call and take
//! their scalar branch. The lane types themselves stay compiled — the
//! switch selects code paths, not types — so CI runs the whole suite
//! both ways (see the test matrix in ci.yml) and the scalar fallback
//! cannot rot.

use std::sync::OnceLock;

/// Whether the vectorized kernel branches should run: true unless the
/// `FEDSPARSE_NO_SIMD` environment variable is set to something other
/// than `""` or `"0"`. Read once per process and cached (kernels
/// consult this per kernel call, not per lane).
pub fn enabled() -> bool {
    static ENABLED: OnceLock<bool> = OnceLock::new();
    *ENABLED.get_or_init(|| match std::env::var_os("FEDSPARSE_NO_SIMD") {
        None => true,
        Some(v) => v.is_empty() || v == "0",
    })
}

// Intrinsic safety varies by toolchain (newer rustc makes the
// non-memory x86 intrinsics safe when the target feature is enabled,
// making the `unsafe` blocks below redundant-but-harmless); keep the
// blocks for older toolchains and silence the newer ones' lint.
#[allow(unused_unsafe)]
#[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
mod lanes {
    use core::arch::x86_64::*;

    /// Eight f32 lanes (AVX2 `__m256`).
    #[derive(Clone, Copy)]
    pub struct F32x8(__m256);

    impl F32x8 {
        #[inline]
        pub fn splat(v: f32) -> Self {
            unsafe { Self(_mm256_set1_ps(v)) }
        }

        /// Load eight lanes from the head of `s` (`s.len() >= 8`).
        #[inline]
        pub fn load(s: &[f32]) -> Self {
            debug_assert!(s.len() >= 8);
            unsafe { Self(_mm256_loadu_ps(s.as_ptr())) }
        }

        /// Store the eight lanes to the head of `s` (`s.len() >= 8`).
        #[inline]
        pub fn store(self, s: &mut [f32]) {
            debug_assert!(s.len() >= 8);
            unsafe { _mm256_storeu_ps(s.as_mut_ptr(), self.0) }
        }

        #[inline]
        pub fn add(self, o: Self) -> Self {
            unsafe { Self(_mm256_add_ps(self.0, o.0)) }
        }

        #[inline]
        pub fn mul(self, o: Self) -> Self {
            unsafe { Self(_mm256_mul_ps(self.0, o.0)) }
        }

        /// Per-lane |x| (sign-bit clear — bitwise `f32::abs`).
        #[inline]
        pub fn abs(self) -> Self {
            unsafe {
                let mask = _mm256_set1_ps(f32::from_bits(0x7fff_ffff));
                Self(_mm256_and_ps(self.0, mask))
            }
        }

        /// This build has a hardware strided gather (`vgatherdps`);
        /// kernels gate their gather branch on this so non-AVX2
        /// targets keep the scalar sweep (see [`Self::gather`]).
        pub const HAS_GATHER: bool = true;

        /// Lanes `s[0], s[stride], …, s[7·stride]` in one `vgatherdps`
        /// (`s.len() > 7·stride`). A gather is eight independent
        /// loads, so this is bitwise-exact like [`Self::load`].
        #[inline]
        pub fn gather(s: &[f32], idx: GatherIdx) -> Self {
            debug_assert!(s.len() > idx.1);
            unsafe { Self(_mm256_i32gather_ps::<4>(s.as_ptr(), idx.0)) }
        }
    }

    /// Prebuilt index vector for [`F32x8::gather`]: lane l reads
    /// element `l·stride` (built once per kernel call, reused per
    /// gather).
    #[derive(Clone, Copy)]
    pub struct GatherIdx(__m256i, usize);

    impl GatherIdx {
        /// Indices `[0, stride, …, 7·stride]`; `7·stride` must fit in
        /// i32 (model dims are far below that).
        #[inline]
        pub fn stride(stride: usize) -> Self {
            let s = stride as i32;
            unsafe {
                Self(
                    _mm256_setr_epi32(0, s, 2 * s, 3 * s, 4 * s, 5 * s, 6 * s, 7 * s),
                    7 * stride,
                )
            }
        }
    }

    /// Eight u32 lanes (AVX2 `__m256i`).
    #[derive(Clone, Copy)]
    pub struct U32x8(__m256i);

    impl U32x8 {
        #[inline]
        pub fn splat(v: u32) -> Self {
            unsafe { Self(_mm256_set1_epi32(v as i32)) }
        }

        /// Load eight little-endian u32 lanes from 32 bytes.
        #[inline]
        pub fn load_le(bytes: &[u8]) -> Self {
            debug_assert!(bytes.len() >= 32);
            unsafe { Self(_mm256_loadu_si256(bytes.as_ptr() as *const __m256i)) }
        }

        #[inline]
        pub fn xor(self, o: Self) -> Self {
            unsafe { Self(_mm256_xor_si256(self.0, o.0)) }
        }

        /// Bitmask (bit l ⟺ lane l) of `self > o` as signed i32 lanes.
        #[inline]
        pub fn gt_i32_mask(self, o: Self) -> u32 {
            unsafe {
                let gt = _mm256_cmpgt_epi32(self.0, o.0);
                _mm256_movemask_ps(_mm256_castsi256_ps(gt)) as u32 & 0xff
            }
        }

        #[inline]
        pub fn from_array(a: [u32; 8]) -> Self {
            unsafe { Self(_mm256_loadu_si256(a.as_ptr() as *const __m256i)) }
        }

        #[inline]
        pub fn to_array(self) -> [u32; 8] {
            let mut a = [0u32; 8];
            unsafe { _mm256_storeu_si256(a.as_mut_ptr() as *mut __m256i, self.0) };
            a
        }

        #[inline]
        pub fn and(self, o: Self) -> Self {
            unsafe { Self(_mm256_and_si256(self.0, o.0)) }
        }

        #[inline]
        pub fn or(self, o: Self) -> Self {
            unsafe { Self(_mm256_or_si256(self.0, o.0)) }
        }

        /// Lane-wise logical shift left by a runtime count (`n < 32`) —
        /// the bitpack kernel's field placement shift.
        #[inline]
        pub fn shl(self, n: u32) -> Self {
            debug_assert!(n < 32);
            unsafe { Self(_mm256_sll_epi32(self.0, _mm_cvtsi32_si128(n as i32))) }
        }

        /// Lane-wise logical shift right by a runtime count (`n < 32`).
        #[inline]
        pub fn shr(self, n: u32) -> Self {
            debug_assert!(n < 32);
            unsafe { Self(_mm256_srl_epi32(self.0, _mm_cvtsi32_si128(n as i32))) }
        }
    }

    pub use super::sse_u32x4::U32x4;
}

#[allow(unused_unsafe)]
#[cfg(all(target_arch = "x86_64", not(target_feature = "avx2")))]
mod lanes {
    use core::arch::x86_64::*;

    /// Eight f32 lanes (two SSE2 `__m128` halves — the x86-64
    /// baseline; build with `-C target-cpu=native` for the AVX2
    /// variant).
    #[derive(Clone, Copy)]
    pub struct F32x8(__m128, __m128);

    impl F32x8 {
        #[inline]
        pub fn splat(v: f32) -> Self {
            unsafe {
                let h = _mm_set1_ps(v);
                Self(h, h)
            }
        }

        /// Load eight lanes from the head of `s` (`s.len() >= 8`).
        #[inline]
        pub fn load(s: &[f32]) -> Self {
            debug_assert!(s.len() >= 8);
            unsafe { Self(_mm_loadu_ps(s.as_ptr()), _mm_loadu_ps(s.as_ptr().add(4))) }
        }

        /// Store the eight lanes to the head of `s` (`s.len() >= 8`).
        #[inline]
        pub fn store(self, s: &mut [f32]) {
            debug_assert!(s.len() >= 8);
            unsafe {
                _mm_storeu_ps(s.as_mut_ptr(), self.0);
                _mm_storeu_ps(s.as_mut_ptr().add(4), self.1);
            }
        }

        #[inline]
        pub fn add(self, o: Self) -> Self {
            unsafe { Self(_mm_add_ps(self.0, o.0), _mm_add_ps(self.1, o.1)) }
        }

        #[inline]
        pub fn mul(self, o: Self) -> Self {
            unsafe { Self(_mm_mul_ps(self.0, o.0), _mm_mul_ps(self.1, o.1)) }
        }

        /// Per-lane |x| (sign-bit clear — bitwise `f32::abs`).
        #[inline]
        pub fn abs(self) -> Self {
            unsafe {
                let mask = _mm_set1_ps(f32::from_bits(0x7fff_ffff));
                Self(_mm_and_ps(self.0, mask), _mm_and_ps(self.1, mask))
            }
        }

        /// SSE2 has no strided gather; kernels gating on this take
        /// their scalar branch (the lane-by-lane fallback below only
        /// serves the parity tests).
        pub const HAS_GATHER: bool = false;

        /// Lanes `s[0], s[stride], …, s[7·stride]` loaded one by one
        /// (`s.len() > 7·stride`).
        #[inline]
        pub fn gather(s: &[f32], idx: GatherIdx) -> Self {
            let st = idx.0;
            let a = [
                s[0],
                s[st],
                s[2 * st],
                s[3 * st],
                s[4 * st],
                s[5 * st],
                s[6 * st],
                s[7 * st],
            ];
            Self::load(&a)
        }
    }

    /// Stride handle for [`F32x8::gather`] (no hardware gather on this
    /// target — the fallback indexes lane by lane).
    #[derive(Clone, Copy)]
    pub struct GatherIdx(usize);

    impl GatherIdx {
        /// Indices `[0, stride, …, 7·stride]`.
        #[inline]
        pub fn stride(stride: usize) -> Self {
            Self(stride)
        }
    }

    /// Eight u32 lanes (two SSE2 `__m128i` halves).
    #[derive(Clone, Copy)]
    pub struct U32x8(__m128i, __m128i);

    impl U32x8 {
        #[inline]
        pub fn splat(v: u32) -> Self {
            unsafe {
                let h = _mm_set1_epi32(v as i32);
                Self(h, h)
            }
        }

        /// Load eight little-endian u32 lanes from 32 bytes.
        #[inline]
        pub fn load_le(bytes: &[u8]) -> Self {
            debug_assert!(bytes.len() >= 32);
            unsafe {
                Self(
                    _mm_loadu_si128(bytes.as_ptr() as *const __m128i),
                    _mm_loadu_si128(bytes.as_ptr().add(16) as *const __m128i),
                )
            }
        }

        #[inline]
        pub fn xor(self, o: Self) -> Self {
            unsafe { Self(_mm_xor_si128(self.0, o.0), _mm_xor_si128(self.1, o.1)) }
        }

        /// Bitmask (bit l ⟺ lane l) of `self > o` as signed i32 lanes.
        #[inline]
        pub fn gt_i32_mask(self, o: Self) -> u32 {
            unsafe {
                let lo = _mm_movemask_ps(_mm_castsi128_ps(_mm_cmpgt_epi32(self.0, o.0))) as u32;
                let hi = _mm_movemask_ps(_mm_castsi128_ps(_mm_cmpgt_epi32(self.1, o.1))) as u32;
                (lo & 0xf) | ((hi & 0xf) << 4)
            }
        }

        #[inline]
        pub fn from_array(a: [u32; 8]) -> Self {
            unsafe {
                Self(
                    _mm_loadu_si128(a.as_ptr() as *const __m128i),
                    _mm_loadu_si128(a.as_ptr().add(4) as *const __m128i),
                )
            }
        }

        #[inline]
        pub fn to_array(self) -> [u32; 8] {
            let mut a = [0u32; 8];
            unsafe {
                _mm_storeu_si128(a.as_mut_ptr() as *mut __m128i, self.0);
                _mm_storeu_si128(a.as_mut_ptr().add(4) as *mut __m128i, self.1);
            }
            a
        }

        #[inline]
        pub fn and(self, o: Self) -> Self {
            unsafe { Self(_mm_and_si128(self.0, o.0), _mm_and_si128(self.1, o.1)) }
        }

        #[inline]
        pub fn or(self, o: Self) -> Self {
            unsafe { Self(_mm_or_si128(self.0, o.0), _mm_or_si128(self.1, o.1)) }
        }

        /// Lane-wise logical shift left by a runtime count (`n < 32`) —
        /// the bitpack kernel's field placement shift.
        #[inline]
        pub fn shl(self, n: u32) -> Self {
            debug_assert!(n < 32);
            unsafe {
                let c = _mm_cvtsi32_si128(n as i32);
                Self(_mm_sll_epi32(self.0, c), _mm_sll_epi32(self.1, c))
            }
        }

        /// Lane-wise logical shift right by a runtime count (`n < 32`).
        #[inline]
        pub fn shr(self, n: u32) -> Self {
            debug_assert!(n < 32);
            unsafe {
                let c = _mm_cvtsi32_si128(n as i32);
                Self(_mm_srl_epi32(self.0, c), _mm_srl_epi32(self.1, c))
            }
        }
    }

    pub use super::sse_u32x4::U32x4;
}

/// The 128-bit u32 quad used by the ChaCha multi-block core — shared
/// by both x86_64 variants (four blocks per dispatch is a 128-bit
/// problem regardless of AVX2).
#[allow(unused_unsafe)]
#[cfg(target_arch = "x86_64")]
mod sse_u32x4 {
    use core::arch::x86_64::*;

    /// Four u32 lanes (SSE2 `__m128i`).
    #[derive(Clone, Copy)]
    pub struct U32x4(__m128i);

    impl U32x4 {
        #[inline]
        pub fn splat(v: u32) -> Self {
            unsafe { Self(_mm_set1_epi32(v as i32)) }
        }

        #[inline]
        pub fn from_array(a: [u32; 4]) -> Self {
            unsafe { Self(_mm_loadu_si128(a.as_ptr() as *const __m128i)) }
        }

        #[inline]
        pub fn to_array(self) -> [u32; 4] {
            let mut out = [0u32; 4];
            unsafe { _mm_storeu_si128(out.as_mut_ptr() as *mut __m128i, self.0) };
            out
        }

        #[inline]
        pub fn wrapping_add(self, o: Self) -> Self {
            unsafe { Self(_mm_add_epi32(self.0, o.0)) }
        }

        #[inline]
        pub fn xor(self, o: Self) -> Self {
            unsafe { Self(_mm_xor_si128(self.0, o.0)) }
        }

        /// Per-lane rotate-left by `n` bits (`0 < n < 32`).
        #[inline]
        pub fn rotl(self, n: u32) -> Self {
            debug_assert!(n > 0 && n < 32);
            unsafe {
                let l = _mm_sll_epi32(self.0, _mm_cvtsi32_si128(n as i32));
                let r = _mm_srl_epi32(self.0, _mm_cvtsi32_si128(32 - n as i32));
                Self(_mm_or_si128(l, r))
            }
        }
    }
}

/// aarch64 NEON variant: the same eight-lane API over paired 128-bit
/// quads (`float32x4_t`/`uint32x4_t`), mirroring the SSE2 twin. NEON
/// is baseline on aarch64, so no feature gate is needed; `vmulq_f32` /
/// `vaddq_f32` are the plain (non-fused) ops, preserving the
/// mul-then-add rounding contract. Kept compiling by the CI
/// `cargo check --target aarch64-unknown-linux-gnu` leg.
#[allow(unused_unsafe)]
#[cfg(target_arch = "aarch64")]
mod lanes {
    use core::arch::aarch64::*;

    /// Eight f32 lanes (two NEON `float32x4_t` halves).
    #[derive(Clone, Copy)]
    pub struct F32x8(float32x4_t, float32x4_t);

    impl F32x8 {
        #[inline]
        pub fn splat(v: f32) -> Self {
            unsafe {
                let h = vdupq_n_f32(v);
                Self(h, h)
            }
        }

        /// Load eight lanes from the head of `s` (`s.len() >= 8`).
        #[inline]
        pub fn load(s: &[f32]) -> Self {
            debug_assert!(s.len() >= 8);
            unsafe { Self(vld1q_f32(s.as_ptr()), vld1q_f32(s.as_ptr().add(4))) }
        }

        /// Store the eight lanes to the head of `s` (`s.len() >= 8`).
        #[inline]
        pub fn store(self, s: &mut [f32]) {
            debug_assert!(s.len() >= 8);
            unsafe {
                vst1q_f32(s.as_mut_ptr(), self.0);
                vst1q_f32(s.as_mut_ptr().add(4), self.1);
            }
        }

        #[inline]
        pub fn add(self, o: Self) -> Self {
            unsafe { Self(vaddq_f32(self.0, o.0), vaddq_f32(self.1, o.1)) }
        }

        #[inline]
        pub fn mul(self, o: Self) -> Self {
            unsafe { Self(vmulq_f32(self.0, o.0), vmulq_f32(self.1, o.1)) }
        }

        /// Per-lane |x| (sign-bit clear — bitwise `f32::abs`; done in
        /// the integer domain so NaN payloads survive like on x86).
        #[inline]
        pub fn abs(self) -> Self {
            unsafe {
                let mask = vdupq_n_u32(0x7fff_ffff);
                Self(
                    vreinterpretq_f32_u32(vandq_u32(vreinterpretq_u32_f32(self.0), mask)),
                    vreinterpretq_f32_u32(vandq_u32(vreinterpretq_u32_f32(self.1), mask)),
                )
            }
        }

        /// NEON has no strided gather; kernels gating on this take
        /// their scalar branch (the lane-by-lane fallback below only
        /// serves the parity tests).
        pub const HAS_GATHER: bool = false;

        /// Lanes `s[0], s[stride], …, s[7·stride]` loaded one by one
        /// (`s.len() > 7·stride`).
        #[inline]
        pub fn gather(s: &[f32], idx: GatherIdx) -> Self {
            let st = idx.0;
            let a = [
                s[0],
                s[st],
                s[2 * st],
                s[3 * st],
                s[4 * st],
                s[5 * st],
                s[6 * st],
                s[7 * st],
            ];
            Self::load(&a)
        }
    }

    /// Stride handle for [`F32x8::gather`] (no hardware gather on this
    /// target — the fallback indexes lane by lane).
    #[derive(Clone, Copy)]
    pub struct GatherIdx(usize);

    impl GatherIdx {
        /// Indices `[0, stride, …, 7·stride]`.
        #[inline]
        pub fn stride(stride: usize) -> Self {
            Self(stride)
        }
    }

    /// Eight u32 lanes (two NEON `uint32x4_t` halves).
    #[derive(Clone, Copy)]
    pub struct U32x8(uint32x4_t, uint32x4_t);

    impl U32x8 {
        #[inline]
        pub fn splat(v: u32) -> Self {
            unsafe {
                let h = vdupq_n_u32(v);
                Self(h, h)
            }
        }

        /// Load eight little-endian u32 lanes from 32 bytes (byte
        /// loads + reinterpret, so no u32 alignment is assumed;
        /// aarch64-unknown-linux-gnu is little-endian).
        #[inline]
        pub fn load_le(bytes: &[u8]) -> Self {
            debug_assert!(bytes.len() >= 32);
            unsafe {
                Self(
                    vreinterpretq_u32_u8(vld1q_u8(bytes.as_ptr())),
                    vreinterpretq_u32_u8(vld1q_u8(bytes.as_ptr().add(16))),
                )
            }
        }

        #[inline]
        pub fn xor(self, o: Self) -> Self {
            unsafe { Self(veorq_u32(self.0, o.0), veorq_u32(self.1, o.1)) }
        }

        /// Bitmask (bit l ⟺ lane l) of `self > o` as signed i32 lanes.
        /// NEON has no movemask: weight the all-ones compare lanes by
        /// `[1, 2, 4, 8]` and horizontal-add each half into a nibble.
        #[inline]
        pub fn gt_i32_mask(self, o: Self) -> u32 {
            unsafe {
                let w = [1u32, 2, 4, 8];
                let wv = vld1q_u32(w.as_ptr());
                let lo = vcgtq_s32(vreinterpretq_s32_u32(self.0), vreinterpretq_s32_u32(o.0));
                let hi = vcgtq_s32(vreinterpretq_s32_u32(self.1), vreinterpretq_s32_u32(o.1));
                let lo = vaddvq_u32(vandq_u32(lo, wv));
                let hi = vaddvq_u32(vandq_u32(hi, wv));
                lo | (hi << 4)
            }
        }

        #[inline]
        pub fn from_array(a: [u32; 8]) -> Self {
            unsafe { Self(vld1q_u32(a.as_ptr()), vld1q_u32(a.as_ptr().add(4))) }
        }

        #[inline]
        pub fn to_array(self) -> [u32; 8] {
            let mut a = [0u32; 8];
            unsafe {
                vst1q_u32(a.as_mut_ptr(), self.0);
                vst1q_u32(a.as_mut_ptr().add(4), self.1);
            }
            a
        }

        #[inline]
        pub fn and(self, o: Self) -> Self {
            unsafe { Self(vandq_u32(self.0, o.0), vandq_u32(self.1, o.1)) }
        }

        #[inline]
        pub fn or(self, o: Self) -> Self {
            unsafe { Self(vorrq_u32(self.0, o.0), vorrq_u32(self.1, o.1)) }
        }

        /// Lane-wise logical shift left by a runtime count (`n < 32`) —
        /// the bitpack kernel's field placement shift. NEON shifts by a
        /// signed per-lane count vector (negative = right).
        #[inline]
        pub fn shl(self, n: u32) -> Self {
            debug_assert!(n < 32);
            unsafe {
                let c = vdupq_n_s32(n as i32);
                Self(vshlq_u32(self.0, c), vshlq_u32(self.1, c))
            }
        }

        /// Lane-wise logical shift right by a runtime count (`n < 32`).
        #[inline]
        pub fn shr(self, n: u32) -> Self {
            debug_assert!(n < 32);
            unsafe {
                let c = vdupq_n_s32(-(n as i32));
                Self(vshlq_u32(self.0, c), vshlq_u32(self.1, c))
            }
        }
    }

    /// Four u32 lanes (NEON `uint32x4_t`).
    #[derive(Clone, Copy)]
    pub struct U32x4(uint32x4_t);

    impl U32x4 {
        #[inline]
        pub fn splat(v: u32) -> Self {
            unsafe { Self(vdupq_n_u32(v)) }
        }

        #[inline]
        pub fn from_array(a: [u32; 4]) -> Self {
            unsafe { Self(vld1q_u32(a.as_ptr())) }
        }

        #[inline]
        pub fn to_array(self) -> [u32; 4] {
            let mut out = [0u32; 4];
            unsafe { vst1q_u32(out.as_mut_ptr(), self.0) };
            out
        }

        #[inline]
        pub fn wrapping_add(self, o: Self) -> Self {
            unsafe { Self(vaddq_u32(self.0, o.0)) }
        }

        #[inline]
        pub fn xor(self, o: Self) -> Self {
            unsafe { Self(veorq_u32(self.0, o.0)) }
        }

        /// Per-lane rotate-left by `n` bits (`0 < n < 32`). `USHL`
        /// with a negative per-lane shift count is a logical right
        /// shift, giving the two halves of the rotate.
        #[inline]
        pub fn rotl(self, n: u32) -> Self {
            debug_assert!(n > 0 && n < 32);
            unsafe {
                let l = vshlq_u32(self.0, vdupq_n_s32(n as i32));
                let r = vshlq_u32(self.0, vdupq_n_s32(n as i32 - 32));
                Self(vorrq_u32(l, r))
            }
        }
    }
}

/// Scalar fallback for targets without a lane module (neither x86_64
/// nor aarch64): same API, plain loops. The kernels built on these
/// types stay bitwise identical by the same argument (per-lane ops in
/// the same order), just without the hardware parallelism.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod lanes {
    /// Eight f32 lanes (portable array fallback).
    #[derive(Clone, Copy)]
    pub struct F32x8([f32; 8]);

    impl F32x8 {
        #[inline]
        pub fn splat(v: f32) -> Self {
            Self([v; 8])
        }

        /// Load eight lanes from the head of `s` (`s.len() >= 8`).
        #[inline]
        pub fn load(s: &[f32]) -> Self {
            let mut a = [0f32; 8];
            a.copy_from_slice(&s[..8]);
            Self(a)
        }

        /// Store the eight lanes to the head of `s` (`s.len() >= 8`).
        #[inline]
        pub fn store(self, s: &mut [f32]) {
            s[..8].copy_from_slice(&self.0);
        }

        #[inline]
        pub fn add(self, o: Self) -> Self {
            let mut a = self.0;
            for (x, y) in a.iter_mut().zip(&o.0) {
                *x += *y;
            }
            Self(a)
        }

        #[inline]
        pub fn mul(self, o: Self) -> Self {
            let mut a = self.0;
            for (x, y) in a.iter_mut().zip(&o.0) {
                *x *= *y;
            }
            Self(a)
        }

        /// Per-lane |x| (sign-bit clear — bitwise `f32::abs`).
        #[inline]
        pub fn abs(self) -> Self {
            let mut a = self.0;
            for x in a.iter_mut() {
                *x = f32::from_bits(x.to_bits() & 0x7fff_ffff);
            }
            Self(a)
        }

        /// No hardware gather on the portable fallback; kernels gating
        /// on this take their scalar branch.
        pub const HAS_GATHER: bool = false;

        /// Lanes `s[0], s[stride], …, s[7·stride]` loaded one by one
        /// (`s.len() > 7·stride`).
        #[inline]
        pub fn gather(s: &[f32], idx: GatherIdx) -> Self {
            let st = idx.0;
            Self([
                s[0],
                s[st],
                s[2 * st],
                s[3 * st],
                s[4 * st],
                s[5 * st],
                s[6 * st],
                s[7 * st],
            ])
        }
    }

    /// Stride handle for [`F32x8::gather`] (no hardware gather on this
    /// target — the fallback indexes lane by lane).
    #[derive(Clone, Copy)]
    pub struct GatherIdx(usize);

    impl GatherIdx {
        /// Indices `[0, stride, …, 7·stride]`.
        #[inline]
        pub fn stride(stride: usize) -> Self {
            Self(stride)
        }
    }

    /// Eight u32 lanes (portable array fallback).
    #[derive(Clone, Copy)]
    pub struct U32x8([u32; 8]);

    impl U32x8 {
        #[inline]
        pub fn splat(v: u32) -> Self {
            Self([v; 8])
        }

        /// Load eight little-endian u32 lanes from 32 bytes.
        #[inline]
        pub fn load_le(bytes: &[u8]) -> Self {
            let mut a = [0u32; 8];
            for (l, ch) in a.iter_mut().zip(bytes[..32].chunks_exact(4)) {
                *l = u32::from_le_bytes(ch.try_into().unwrap());
            }
            Self(a)
        }

        #[inline]
        pub fn xor(self, o: Self) -> Self {
            let mut a = self.0;
            for (x, y) in a.iter_mut().zip(&o.0) {
                *x ^= *y;
            }
            Self(a)
        }

        /// Bitmask (bit l ⟺ lane l) of `self > o` as signed i32 lanes.
        #[inline]
        pub fn gt_i32_mask(self, o: Self) -> u32 {
            let mut m = 0u32;
            for l in 0..8 {
                if (self.0[l] as i32) > (o.0[l] as i32) {
                    m |= 1 << l;
                }
            }
            m
        }

        #[inline]
        pub fn from_array(a: [u32; 8]) -> Self {
            Self(a)
        }

        #[inline]
        pub fn to_array(self) -> [u32; 8] {
            self.0
        }

        #[inline]
        pub fn and(self, o: Self) -> Self {
            let mut a = self.0;
            for (x, y) in a.iter_mut().zip(&o.0) {
                *x &= *y;
            }
            Self(a)
        }

        #[inline]
        pub fn or(self, o: Self) -> Self {
            let mut a = self.0;
            for (x, y) in a.iter_mut().zip(&o.0) {
                *x |= *y;
            }
            Self(a)
        }

        /// Lane-wise logical shift left by a runtime count (`n < 32`) —
        /// the bitpack kernel's field placement shift.
        #[inline]
        pub fn shl(self, n: u32) -> Self {
            debug_assert!(n < 32);
            let mut a = self.0;
            for x in a.iter_mut() {
                *x <<= n;
            }
            Self(a)
        }

        /// Lane-wise logical shift right by a runtime count (`n < 32`).
        #[inline]
        pub fn shr(self, n: u32) -> Self {
            debug_assert!(n < 32);
            let mut a = self.0;
            for x in a.iter_mut() {
                *x >>= n;
            }
            Self(a)
        }
    }

    /// Four u32 lanes (portable array fallback).
    #[derive(Clone, Copy)]
    pub struct U32x4([u32; 4]);

    impl U32x4 {
        #[inline]
        pub fn splat(v: u32) -> Self {
            Self([v; 4])
        }

        #[inline]
        pub fn from_array(a: [u32; 4]) -> Self {
            Self(a)
        }

        #[inline]
        pub fn to_array(self) -> [u32; 4] {
            self.0
        }

        #[inline]
        pub fn wrapping_add(self, o: Self) -> Self {
            let mut a = self.0;
            for (x, y) in a.iter_mut().zip(&o.0) {
                *x = x.wrapping_add(*y);
            }
            Self(a)
        }

        #[inline]
        pub fn xor(self, o: Self) -> Self {
            let mut a = self.0;
            for (x, y) in a.iter_mut().zip(&o.0) {
                *x ^= *y;
            }
            Self(a)
        }

        /// Per-lane rotate-left by `n` bits (`0 < n < 32`).
        #[inline]
        pub fn rotl(self, n: u32) -> Self {
            let mut a = self.0;
            for x in a.iter_mut() {
                *x = x.rotate_left(n);
            }
            Self(a)
        }
    }
}

pub use lanes::{F32x8, GatherIdx, U32x4, U32x8};

/// `acc[i] += c · x[i]` over equal-length slices — the axpy inner loop
/// of the blocked matmul kernels, eight accumulators per step with a
/// scalar tail. Each accumulator receives exactly one `mul` + one
/// `add` per call in both branches (no FMA), so the two paths are
/// bitwise identical; `use_simd` is therefore pure scheduling
/// (kernels pass [`enabled`], tests force both).
#[inline]
pub fn axpy_with(acc: &mut [f32], c: f32, x: &[f32], use_simd: bool) {
    assert_eq!(acc.len(), x.len(), "axpy length mismatch");
    let n = acc.len();
    let mut i = 0;
    if use_simd && n >= 8 {
        let cv = F32x8::splat(c);
        while i + 8 <= n {
            let a = F32x8::load(&acc[i..]);
            let w = F32x8::load(&x[i..]);
            a.add(w.mul(cv)).store(&mut acc[i..]);
            i += 8;
        }
    }
    while i < n {
        acc[i] += c * x[i];
        i += 1;
    }
}

/// [`axpy_with`] at the process-wide SIMD setting.
#[inline]
pub fn axpy(acc: &mut [f32], c: f32, x: &[f32]) {
    axpy_with(acc, c, x, enabled());
}

/// `dst[i] = |src[i]|` over equal-length slices — the Top-k magnitude
/// scan. |x| clears the sign bit in both branches, so the paths are
/// bitwise identical for every input including `-0.0` and NaNs.
#[inline]
pub fn abs_into_with(src: &[f32], dst: &mut [f32], use_simd: bool) {
    assert_eq!(src.len(), dst.len(), "abs_into length mismatch");
    let n = src.len();
    let mut i = 0;
    if use_simd && n >= 8 {
        while i + 8 <= n {
            F32x8::load(&src[i..]).abs().store(&mut dst[i..]);
            i += 8;
        }
    }
    while i < n {
        dst[i] = src[i].abs();
        i += 1;
    }
}

/// [`abs_into_with`] at the process-wide SIMD setting.
#[inline]
pub fn abs_into(src: &[f32], dst: &mut [f32]) {
    abs_into_with(src, dst, enabled());
}

/// The σ-filter's vectorized integer compare: a prepared
/// `lane < bound` (unsigned) test over eight LE u32 lanes at a time.
/// The signed-compare bias trick (`a <u b ⟺ a⊕2³¹ <s b⊕2³¹`) is
/// precomputed once; [`Self::keep_mask`] returns the kept-lane
/// bitmask for the caller's compress step (`secagg::mask` pushes only
/// the set bits' entries). Exactness: the compare is integer, so the
/// kept set matches the scalar `(lane as u64) < bound` test bit for
/// bit — callers handle the `bound == 2³²` keep-everything case
/// before constructing a filter.
pub struct LaneFilter {
    bias: U32x8,
    bound_biased: U32x8,
}

impl LaneFilter {
    pub fn new(bound: u32) -> Self {
        Self {
            bias: U32x8::splat(0x8000_0000),
            bound_biased: U32x8::splat(bound ^ 0x8000_0000),
        }
    }

    /// Bitmask (bit l ⟺ lane l kept) of `lane < bound` over the eight
    /// LE u32 lanes at the head of `lanes_le` (`len >= 32`).
    #[inline]
    pub fn keep_mask(&self, lanes_le: &[u8]) -> u32 {
        self.bound_biased.gt_i32_mask(U32x8::load_le(lanes_le).xor(self.bias))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The lane-remainder widths every vectorized kernel is pinned at:
    /// below/at/above one vector, a ragged middle, and a full tile ±1.
    const REMAINDER_WIDTHS: [usize; 7] = [1, 7, 8, 9, 17, 64, 65];

    #[test]
    fn f32x8_roundtrip_and_ops() {
        let a: Vec<f32> = (0..8).map(|i| i as f32 - 3.5).collect();
        let b: Vec<f32> = (0..8).map(|i| 0.25 * i as f32 + 1.0).collect();
        let mut out = vec![0f32; 8];
        F32x8::load(&a).add(F32x8::load(&b)).store(&mut out);
        for i in 0..8 {
            assert_eq!(out[i].to_bits(), (a[i] + b[i]).to_bits());
        }
        F32x8::load(&a).mul(F32x8::load(&b)).store(&mut out);
        for i in 0..8 {
            assert_eq!(out[i].to_bits(), (a[i] * b[i]).to_bits());
        }
        F32x8::splat(2.5).store(&mut out);
        assert!(out.iter().all(|&v| v == 2.5));
    }

    #[test]
    fn f32x8_abs_is_sign_bit_clear() {
        let vals = [-1.5f32, 0.0, -0.0, 3.25, f32::NEG_INFINITY, -f32::NAN, 1e-38, -1e38];
        let mut out = vec![0f32; 8];
        F32x8::load(&vals).abs().store(&mut out);
        for i in 0..8 {
            assert_eq!(out[i].to_bits(), vals[i].to_bits() & 0x7fff_ffff, "lane {i}");
        }
    }

    #[test]
    fn gather_matches_scalar_strided_indexing() {
        // On AVX2 this exercises the real vgatherdps; elsewhere the
        // lane-by-lane fallback. Either way a gather is eight plain
        // loads, so lanes must be bit-identical to direct indexing.
        let mut rng = Rng::new(0x6a7);
        for stride in [1usize, 3, 9, 64] {
            let data: Vec<f32> = (0..stride * 8 + 5).map(|_| rng.normal_f32(1.0)).collect();
            let idx = GatherIdx::stride(stride);
            for base in [0usize, 2, 5] {
                let s = &data[base..];
                let mut out = [0f32; 8];
                F32x8::gather(s, idx).store(&mut out);
                for (l, &got) in out.iter().enumerate() {
                    assert_eq!(
                        got.to_bits(),
                        s[l * stride].to_bits(),
                        "stride={stride} base={base} lane={l}"
                    );
                }
            }
        }
    }

    #[test]
    fn u32x4_ops_match_scalar() {
        let a = [1u32, u32::MAX, 0x8000_0000, 0x1234_5678];
        let b = [2u32, 1, 0x8000_0000, 0x0fed_cba9];
        let add = U32x4::from_array(a).wrapping_add(U32x4::from_array(b)).to_array();
        let xor = U32x4::from_array(a).xor(U32x4::from_array(b)).to_array();
        for i in 0..4 {
            assert_eq!(add[i], a[i].wrapping_add(b[i]));
            assert_eq!(xor[i], a[i] ^ b[i]);
        }
        for n in [7u32, 8, 12, 16] {
            let rot = U32x4::from_array(a).rotl(n).to_array();
            for i in 0..4 {
                assert_eq!(rot[i], a[i].rotate_left(n), "rotl {n} lane {i}");
            }
        }
        assert_eq!(U32x4::splat(9).to_array(), [9; 4]);
    }

    #[test]
    fn lane_filter_matches_scalar_compare() {
        let mut rng = Rng::new(0x51f7);
        for bound in [0u32, 1, 77, 0x7fff_ffff, 0x8000_0000, 0x8000_0001, u32::MAX] {
            let filter = LaneFilter::new(bound);
            for _ in 0..50 {
                let lanes: Vec<u32> = (0..8)
                    .map(|_| {
                        // mix uniform draws with values clustered at the
                        // boundary so off-by-one compares get exercised
                        let r = rng.next_u64() as u32;
                        match r % 4 {
                            0 => bound.wrapping_add((r >> 8) % 3),
                            1 => bound.wrapping_sub((r >> 8) % 3),
                            _ => r,
                        }
                    })
                    .collect();
                let mut bytes = Vec::with_capacity(32);
                for l in &lanes {
                    bytes.extend_from_slice(&l.to_le_bytes());
                }
                let mask = filter.keep_mask(&bytes);
                for (l, &lane) in lanes.iter().enumerate() {
                    assert_eq!(
                        (mask >> l) & 1 == 1,
                        lane < bound,
                        "bound {bound} lane {lane}"
                    );
                }
            }
        }
    }

    #[test]
    fn u32x8_bit_ops_match_scalar() {
        let mut rng = Rng::new(0x0b17);
        for _ in 0..50 {
            let a: [u32; 8] = std::array::from_fn(|_| rng.next_u64() as u32);
            let b: [u32; 8] = std::array::from_fn(|_| rng.next_u64() as u32);
            let av = U32x8::from_array(a);
            let bv = U32x8::from_array(b);
            assert_eq!(av.to_array(), a);
            let and = av.and(bv).to_array();
            let or = av.or(bv).to_array();
            for i in 0..8 {
                assert_eq!(and[i], a[i] & b[i]);
                assert_eq!(or[i], a[i] | b[i]);
            }
            for n in [0u32, 1, 2, 7, 8, 15, 31] {
                let shl = av.shl(n).to_array();
                let shr = av.shr(n).to_array();
                for i in 0..8 {
                    assert_eq!(shl[i], a[i] << n, "shl {n} lane {i}");
                    assert_eq!(shr[i], a[i] >> n, "shr {n} lane {i}");
                }
            }
        }
    }

    #[test]
    fn axpy_simd_bitwise_matches_scalar_at_remainder_widths() {
        let mut rng = Rng::new(0xa1);
        for &n in &REMAINDER_WIDTHS {
            for case in 0..10 {
                let x: Vec<f32> = (0..n).map(|_| rng.normal_f32(1.0)).collect();
                let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(2.0)).collect();
                let c = rng.normal_f32(1.0);
                let mut a_simd = base.clone();
                let mut a_scalar = base.clone();
                axpy_with(&mut a_simd, c, &x, true);
                axpy_with(&mut a_scalar, c, &x, false);
                for i in 0..n {
                    assert_eq!(
                        a_simd[i].to_bits(),
                        a_scalar[i].to_bits(),
                        "n={n} case={case} i={i}"
                    );
                }
            }
        }
    }

    #[test]
    fn abs_into_simd_bitwise_matches_scalar_at_remainder_widths() {
        let mut rng = Rng::new(0xab5);
        for &n in &REMAINDER_WIDTHS {
            let mut src: Vec<f32> = (0..n).map(|_| rng.normal_f32(3.0)).collect();
            if n >= 2 {
                src[0] = -0.0;
                src[1] = f32::NEG_INFINITY;
            }
            let mut d_simd = vec![9.9f32; n];
            let mut d_scalar = vec![-7.0f32; n];
            abs_into_with(&src, &mut d_simd, true);
            abs_into_with(&src, &mut d_scalar, false);
            for i in 0..n {
                assert_eq!(d_simd[i].to_bits(), d_scalar[i].to_bits(), "n={n} i={i}");
                assert_eq!(d_scalar[i].to_bits(), src[i].abs().to_bits(), "n={n} i={i}");
            }
        }
    }
}
