//! Stopwatch + human-readable duration formatting.

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// `1.23ms` / `45.6µs` / `2.34s` — criterion-style unit picking.
pub fn fmt_duration(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1e3 {
        format!("{ns:.0}ns")
    } else if ns < 1e6 {
        format!("{:.2}µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2}ms", ns / 1e6)
    } else {
        format!("{:.2}s", ns / 1e9)
    }
}

/// Bytes with binary units.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b}B")
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duration_units() {
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_duration(Duration::from_micros(1500)), "1.50ms");
        assert_eq!(fmt_duration(Duration::from_millis(2500)), "2.50s");
        assert!(fmt_duration(Duration::from_micros(12)).ends_with("µs"));
    }

    #[test]
    fn byte_units() {
        assert_eq!(fmt_bytes(512), "512B");
        assert_eq!(fmt_bytes(2048), "2.00KiB");
        assert_eq!(fmt_bytes(3 * 1024 * 1024), "3.00MiB");
    }

    #[test]
    fn stopwatch_monotonic() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_secs() >= 0.004);
        let e = sw.restart();
        assert!(e.as_millis() >= 4);
        assert!(sw.elapsed_secs() < 0.1);
    }
}
