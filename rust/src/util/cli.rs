//! Declarative command-line argument parser (clap substitute, DESIGN.md S18).
//!
//! ```no_run
//! use fedsparse::util::cli::{ArgSpec, Args};
//! let spec = &[
//!     ArgSpec::opt("model", "m", "mnist_mlp", "model name from the zoo"),
//!     ArgSpec::opt("rounds", "r", "100", "number of federated rounds"),
//!     ArgSpec::flag("secure", "", "enable secure aggregation"),
//! ];
//! let args = Args::parse_spec("fedsparse train", spec,
//!                             std::env::args().skip(2)).unwrap();
//! let rounds: usize = args.get_parsed("rounds").unwrap();
//! ```

use std::collections::BTreeMap;

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown argument '{0}' (try --help)")]
    Unknown(String),
    #[error("missing value for --{0}")]
    MissingValue(String),
    #[error("missing required argument --{0}")]
    MissingRequired(String),
    #[error("invalid value '{value}' for --{name}: {msg}")]
    Invalid { name: String, value: String, msg: String },
    #[error("help requested")]
    Help,
}

/// Specification of one argument.
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: &'static str,
    pub short: &'static str,
    pub default: Option<&'static str>,
    pub help: &'static str,
    pub is_flag: bool,
    pub required: bool,
}

impl ArgSpec {
    /// Optional `--name value` with a default.
    pub const fn opt(
        name: &'static str,
        short: &'static str,
        default: &'static str,
        help: &'static str,
    ) -> Self {
        Self { name, short, default: Some(default), help, is_flag: false, required: false }
    }

    /// Required `--name value`.
    pub const fn req(name: &'static str, short: &'static str, help: &'static str) -> Self {
        Self { name, short, default: None, help, is_flag: false, required: true }
    }

    /// Boolean `--name` flag (default false).
    pub const fn flag(name: &'static str, short: &'static str, help: &'static str) -> Self {
        Self { name, short, default: None, help, is_flag: true, required: false }
    }
}

/// Parsed arguments.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Args {
    /// Parse `argv` (not including the program/subcommand tokens)
    /// against `spec`. `--help`/`-h` prints usage and returns
    /// [`CliError::Help`].
    pub fn parse_spec<I: Iterator<Item = String>>(
        prog: &str,
        spec: &[ArgSpec],
        argv: I,
    ) -> Result<Args, CliError> {
        let mut args = Args::default();
        for s in spec {
            if s.is_flag {
                args.flags.insert(s.name.to_string(), false);
            } else if let Some(d) = s.default {
                args.values.insert(s.name.to_string(), d.to_string());
            }
        }

        let find = |token: &str| -> Option<&ArgSpec> {
            spec.iter().find(|s| {
                token == format!("--{}", s.name) || (!s.short.is_empty() && token == format!("-{}", s.short))
            })
        };

        let mut it = argv.peekable();
        while let Some(tok) = it.next() {
            if tok == "--help" || tok == "-h" {
                eprintln!("{}", usage(prog, spec));
                return Err(CliError::Help);
            }
            // --name=value form
            if let Some((head, val)) = tok.split_once('=') {
                if let Some(s) = find(head) {
                    if s.is_flag {
                        args.flags.insert(
                            s.name.to_string(),
                            matches!(val, "true" | "1" | "yes"),
                        );
                    } else {
                        args.values.insert(s.name.to_string(), val.to_string());
                    }
                    continue;
                }
                return Err(CliError::Unknown(tok));
            }
            match find(&tok) {
                Some(s) if s.is_flag => {
                    args.flags.insert(s.name.to_string(), true);
                }
                Some(s) => {
                    let val = it.next().ok_or_else(|| CliError::MissingValue(s.name.into()))?;
                    args.values.insert(s.name.to_string(), val);
                }
                None => return Err(CliError::Unknown(tok)),
            }
        }

        for s in spec {
            if s.required && !args.values.contains_key(s.name) {
                return Err(CliError::MissingRequired(s.name.into()));
            }
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    /// Parse a value with FromStr, with a useful error.
    pub fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.get(name).ok_or_else(|| CliError::MissingRequired(name.into()))?;
        raw.parse().map_err(|e: T::Err| CliError::Invalid {
            name: name.into(),
            value: raw.into(),
            msg: e.to_string(),
        })
    }
}

/// Render the usage/help text.
pub fn usage(prog: &str, spec: &[ArgSpec]) -> String {
    let mut out = format!("usage: {prog} [options]\n\noptions:\n");
    for s in spec {
        let short = if s.short.is_empty() {
            "    ".to_string()
        } else {
            format!("-{}, ", s.short)
        };
        let head = if s.is_flag {
            format!("  {short}--{}", s.name)
        } else {
            format!("  {short}--{} <v>", s.name)
        };
        let default = match (s.is_flag, s.default) {
            (true, _) => String::new(),
            (false, Some(d)) => format!(" [default: {d}]"),
            (false, None) => " (required)".to_string(),
        };
        out.push_str(&format!("{head:<28} {}{default}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SPEC: &[ArgSpec] = &[
        ArgSpec::opt("model", "m", "mnist_mlp", "model"),
        ArgSpec::opt("rounds", "r", "100", "rounds"),
        ArgSpec::flag("secure", "s", "secure agg"),
        ArgSpec::req("out", "", "output path"),
    ];

    fn parse(argv: &[&str]) -> Result<Args, CliError> {
        Args::parse_spec("test", SPEC, argv.iter().map(|s| s.to_string()))
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse(&["--rounds", "5", "--out", "x.csv"]).unwrap();
        assert_eq!(a.get("model"), Some("mnist_mlp"));
        assert_eq!(a.get_parsed::<usize>("rounds").unwrap(), 5);
        assert!(!a.get_flag("secure"));
    }

    #[test]
    fn short_and_equals_forms() {
        let a = parse(&["-m", "cifar_cnn", "--rounds=7", "-s", "--out=o"]).unwrap();
        assert_eq!(a.get("model"), Some("cifar_cnn"));
        assert_eq!(a.get_parsed::<usize>("rounds").unwrap(), 7);
        assert!(a.get_flag("secure"));
    }

    #[test]
    fn missing_required_rejected() {
        assert!(matches!(parse(&[]), Err(CliError::MissingRequired(_))));
    }

    #[test]
    fn unknown_rejected() {
        assert!(matches!(
            parse(&["--nope", "1", "--out", "o"]),
            Err(CliError::Unknown(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            parse(&["--out"]),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_parse_has_context() {
        let a = parse(&["--rounds", "abc", "--out", "o"]).unwrap();
        let e = a.get_parsed::<usize>("rounds").unwrap_err();
        assert!(matches!(e, CliError::Invalid { .. }));
    }

    #[test]
    fn usage_mentions_all() {
        let u = usage("test", SPEC);
        for s in SPEC {
            assert!(u.contains(s.name));
        }
    }
}
