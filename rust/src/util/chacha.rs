//! ChaCha20 (RFC 8439) — the cryptographic PRG that expands a
//! Diffie-Hellman shared secret into the pairwise mask stream of the
//! secure-aggregation protocol (paper §3.2).
//!
//! Implemented from the RFC rather than pulled in as a crate so the
//! whole mask path is auditable in-repo (and the offline vendor set has
//! no chacha crate anyway). Verified against the RFC 8439 §2.3.2 test
//! vector below.
//!
//! ## Multi-block dispatch
//!
//! ChaCha blocks are independent expansions of (state, counter), so
//! the hot mask-PRG path generates **four blocks per dispatch** with
//! the 4-lane integer vectors from [`crate::util::simd`] (lane b =
//! counter + b): every round operation runs on all four blocks at
//! once, and the serialized 256-byte buffer is keystream-identical to
//! four sequential single-block refills **by construction** — the
//! per-block math is untouched, only scheduled side by side. The
//! scalar single-block path stays as the `FEDSPARSE_NO_SIMD` fallback
//! and the reference the parity tests pin against.

use crate::util::simd::{self, U32x4};

/// ChaCha20 keystream generator.
pub struct ChaCha20 {
    state: [u32; 16],
    /// Buffered keystream (one block scalar, four per quad dispatch)
    /// and its read window: `offset..filled` is unread.
    block: [u8; 256],
    filled: usize,
    offset: usize,
    /// Four-blocks-per-dispatch mode (the SIMD default; both modes
    /// produce the identical keystream).
    quad: bool,
}

const SIGMA: [u32; 4] = [0x6170_7865, 0x3320_646e, 0x7962_2d32, 0x6b20_6574];

impl ChaCha20 {
    /// Keystream from a 32-byte key and a 12-byte nonce, counter = 0.
    pub fn new(key: &[u8; 32], nonce: &[u8; 12]) -> Self {
        let mut state = [0u32; 16];
        state[..4].copy_from_slice(&SIGMA);
        for i in 0..8 {
            state[4 + i] = u32::from_le_bytes(key[4 * i..4 * i + 4].try_into().unwrap());
        }
        state[12] = 0; // block counter
        for i in 0..3 {
            state[13 + i] = u32::from_le_bytes(nonce[4 * i..4 * i + 4].try_into().unwrap());
        }
        Self { state, block: [0u8; 256], filled: 0, offset: 0, quad: simd::enabled() }
    }

    /// Convenience: derive nonce from a u64 label (e.g. round number).
    pub fn from_seed(key: &[u8; 32], label: u64) -> Self {
        let mut nonce = [0u8; 12];
        nonce[..8].copy_from_slice(&label.to_le_bytes());
        Self::new(key, &nonce)
    }

    #[inline]
    fn quarter_round(s: &mut [u32; 16], a: usize, b: usize, c: usize, d: usize) {
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = (s[d] ^ s[a]).rotate_left(16);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = (s[b] ^ s[c]).rotate_left(12);
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = (s[d] ^ s[a]).rotate_left(8);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = (s[b] ^ s[c]).rotate_left(7);
    }

    /// Force the block dispatch width: `true` = four blocks per
    /// dispatch, `false` = the scalar single-block path. Testing/bench
    /// hook — the two modes are keystream-identical by construction
    /// (pinned by `quad_dispatch_matches_scalar_blocks`); callers
    /// normally keep the [`simd::enabled`] default.
    pub fn set_quad_blocks(&mut self, quad: bool) {
        self.quad = quad;
    }

    fn refill(&mut self) {
        let mut w = self.state;
        for _ in 0..10 {
            // column rounds
            Self::quarter_round(&mut w, 0, 4, 8, 12);
            Self::quarter_round(&mut w, 1, 5, 9, 13);
            Self::quarter_round(&mut w, 2, 6, 10, 14);
            Self::quarter_round(&mut w, 3, 7, 11, 15);
            // diagonal rounds
            Self::quarter_round(&mut w, 0, 5, 10, 15);
            Self::quarter_round(&mut w, 1, 6, 11, 12);
            Self::quarter_round(&mut w, 2, 7, 8, 13);
            Self::quarter_round(&mut w, 3, 4, 9, 14);
        }
        for i in 0..16 {
            let v = w[i].wrapping_add(self.state[i]);
            self.block[4 * i..4 * i + 4].copy_from_slice(&v.to_le_bytes());
        }
        self.state[12] = self.state[12].wrapping_add(1);
        self.filled = 64;
        self.offset = 0;
    }

    #[inline]
    fn quarter_round4(s: &mut [U32x4; 16], a: usize, b: usize, c: usize, d: usize) {
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = s[d].xor(s[a]).rotl(16);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = s[b].xor(s[c]).rotl(12);
        s[a] = s[a].wrapping_add(s[b]);
        s[d] = s[d].xor(s[a]).rotl(8);
        s[c] = s[c].wrapping_add(s[d]);
        s[b] = s[b].xor(s[c]).rotl(7);
    }

    /// Four independent blocks per dispatch: lane b of every state
    /// vector carries block `counter + b`, the 20 rounds run on all
    /// four at once, and the 256-byte buffer serializes them in
    /// counter order — the identical keystream [`Self::refill`]
    /// produces one block at a time.
    fn refill4(&mut self) {
        let ctr = self.state[12];
        let mut init = [U32x4::splat(0); 16];
        for (v, &s) in init.iter_mut().zip(&self.state) {
            *v = U32x4::splat(s);
        }
        init[12] = U32x4::from_array([
            ctr,
            ctr.wrapping_add(1),
            ctr.wrapping_add(2),
            ctr.wrapping_add(3),
        ]);
        let mut w = init;
        for _ in 0..10 {
            // column rounds
            Self::quarter_round4(&mut w, 0, 4, 8, 12);
            Self::quarter_round4(&mut w, 1, 5, 9, 13);
            Self::quarter_round4(&mut w, 2, 6, 10, 14);
            Self::quarter_round4(&mut w, 3, 7, 11, 15);
            // diagonal rounds
            Self::quarter_round4(&mut w, 0, 5, 10, 15);
            Self::quarter_round4(&mut w, 1, 6, 11, 12);
            Self::quarter_round4(&mut w, 2, 7, 8, 13);
            Self::quarter_round4(&mut w, 3, 4, 9, 14);
        }
        for i in 0..16 {
            let sum = w[i].wrapping_add(init[i]).to_array();
            for (b, v) in sum.iter().enumerate() {
                let off = 64 * b + 4 * i;
                self.block[off..off + 4].copy_from_slice(&v.to_le_bytes());
            }
        }
        self.state[12] = ctr.wrapping_add(4);
        self.filled = 256;
        self.offset = 0;
    }

    /// Refill the exhausted buffer at the configured dispatch width.
    #[inline]
    fn refill_buffer(&mut self) {
        if self.quad {
            self.refill4();
        } else {
            self.refill();
        }
    }

    /// Fill `out` with keystream bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut i = 0;
        while i < out.len() {
            if self.offset == self.filled {
                self.refill_buffer();
            }
            let take = (out.len() - i).min(self.filled - self.offset);
            out[i..i + take].copy_from_slice(&self.block[self.offset..self.offset + take]);
            self.offset += take;
            i += take;
        }
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let mut b = [0u8; 4];
        self.fill_bytes(&mut b);
        u32::from_le_bytes(b)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut b = [0u8; 8];
        self.fill_bytes(&mut b);
        u64::from_le_bytes(b)
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa of a u64 draw).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)` — the paper's mask element
    /// distribution `mask_r ∈ [p, p+q)` (§3.2).
    #[inline]
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (self.next_f64() as f32) * (hi - lo)
    }

    /// Map one raw keystream lane to a uniform f32 in `[lo, hi)`.
    ///
    /// This is THE lane→value function of the mask PRG: both the dense
    /// fill and the streaming σ-filter path go through it, so filtering
    /// on the raw u32 lane (see `secagg::mask`) and converting only the
    /// kept lanes produces bit-identical values. The map is monotone
    /// non-decreasing in `lane` (every factor is positive and each f32
    /// rounding step preserves order), which is what makes an exact
    /// integer filter threshold possible.
    #[inline]
    pub fn lane_to_f32(lane: u32, lo: f32, hi: f32) -> f32 {
        const SCALE: f32 = 1.0 / 4_294_967_296.0; // 2^-32
        lo + lane as f32 * SCALE * (hi - lo)
    }

    /// Visit the next `n` keystream u32 lanes as contiguous
    /// little-endian byte runs straight out of the block buffer:
    /// `f(start_lane, bytes)` with `bytes.len()` a non-zero multiple
    /// of 4 (up to 256 — one quad dispatch). Consumes the keystream
    /// exactly like [`Self::fill_uniform_f32`] (one u32 per lane).
    ///
    /// This is the SIMD seam of the mask PRG: `secagg::mask` runs the
    /// vectorized σ-compare straight over these byte runs, and
    /// [`Self::for_each_uniform_f32`] decodes them lane-wise — both on
    /// the same buffered bytes, so the two views are the same stream.
    pub fn for_each_lane_chunk<F: FnMut(usize, &[u8])>(&mut self, n: usize, mut f: F) {
        let mut i = 0;
        while i < n {
            if self.offset == self.filled {
                self.refill_buffer();
            }
            // whole u32 lanes available in the buffered keystream
            let lanes = (self.filled - self.offset) / 4;
            if lanes == 0 {
                // realign: consume the (post-`fill_bytes`) tail bytes
                let mut b = [0u8; 4];
                self.fill_bytes(&mut b);
                f(i, &b);
                i += 1;
                continue;
            }
            let take = lanes.min(n - i);
            let start = self.offset;
            self.offset += 4 * take;
            f(i, &self.block[start..start + 4 * take]);
            i += take;
        }
    }

    /// Stream `n` keystream lanes block-wise: `f(index, raw_lane)` for
    /// each, straight out of the block buffer — no dense allocation.
    ///
    /// Hot path of the secure-aggregation round (one call per pair per
    /// round over the full parameter vector): the σ-filtered mask build
    /// streams lanes through this and materializes only the kept
    /// entries (~k/x of n), instead of a dense n-float vector.
    pub fn for_each_uniform_f32<F: FnMut(usize, u32)>(&mut self, n: usize, mut f: F) {
        self.for_each_lane_chunk(n, |base, bytes| {
            for (l, ch) in bytes.chunks_exact(4).enumerate() {
                f(base + l, u32::from_le_bytes(ch.try_into().unwrap()));
            }
        });
    }

    /// Fill a mask vector with uniform `[lo, hi)` values (one u32 lane
    /// per element; see [`Self::for_each_uniform_f32`], §Perf L3
    /// iteration 2 — ~3× over the per-element `next_u64` path).
    pub fn fill_uniform_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        let n = out.len();
        self.for_each_uniform_f32(n, |i, lane| out[i] = Self::lane_to_f32(lane, lo, hi));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 8439 §2.3.2 test vector: key 00..1f, nonce 00:00:00:09:00:00:00:4a:00:00:00:00,
    /// counter 1 → first block keystream known. We start at counter 0, so
    /// compare the SECOND 64-byte block.
    #[test]
    fn rfc8439_block_vector() {
        let mut key = [0u8; 32];
        for (i, k) in key.iter_mut().enumerate() {
            *k = i as u8;
        }
        let nonce: [u8; 12] = [0, 0, 0, 9, 0, 0, 0, 0x4a, 0, 0, 0, 0];
        let mut c = ChaCha20::new(&key, &nonce);
        let mut buf = [0u8; 128];
        c.fill_bytes(&mut buf);
        let expected_block1: [u8; 16] = [
            0x10, 0xf1, 0xe7, 0xe4, 0xd1, 0x3b, 0x59, 0x15,
            0x50, 0x0f, 0xdd, 0x1f, 0xa3, 0x20, 0x71, 0xc4,
        ];
        assert_eq!(&buf[64..80], &expected_block1);
    }

    #[test]
    fn deterministic_stream() {
        let key = [7u8; 32];
        let mut a = ChaCha20::from_seed(&key, 3);
        let mut b = ChaCha20::from_seed(&key, 3);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_labels_differ() {
        let key = [9u8; 32];
        let mut a = ChaCha20::from_seed(&key, 1);
        let mut b = ChaCha20::from_seed(&key, 2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn uniform_range_respected() {
        let key = [1u8; 32];
        let mut c = ChaCha20::from_seed(&key, 0);
        let mut v = vec![0f32; 10_000];
        c.fill_uniform_f32(&mut v, -5.0, 5.0);
        assert!(v.iter().all(|&x| (-5.0..5.0).contains(&x)));
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.2, "mean={mean}");
    }

    #[test]
    fn streamed_lanes_match_dense_fill() {
        let key = [5u8; 32];
        // n = 1000 is not a multiple of 16, so the final block is
        // consumed partially on both paths
        let n = 1000;
        let mut dense = vec![0f32; n];
        ChaCha20::from_seed(&key, 9).fill_uniform_f32(&mut dense, -10.0, 10.0);
        let mut streamed = vec![0f32; n];
        let mut seen = 0usize;
        ChaCha20::from_seed(&key, 9).for_each_uniform_f32(n, |i, lane| {
            streamed[i] = ChaCha20::lane_to_f32(lane, -10.0, 10.0);
            seen += 1;
        });
        assert_eq!(seen, n);
        // bitwise: the two paths must be the SAME stream
        for i in 0..n {
            assert_eq!(dense[i].to_bits(), streamed[i].to_bits(), "lane {i}");
        }
    }

    #[test]
    fn lane_map_is_monotone() {
        // order-preservation is what the integer σ-threshold relies on
        let (lo, hi) = (-10.0f32, 10.0);
        let mut prev = ChaCha20::lane_to_f32(0, lo, hi);
        for lane in (0u64..=u32::MAX as u64).step_by(65_537) {
            let v = ChaCha20::lane_to_f32(lane as u32, lo, hi);
            assert!(v >= prev, "lane {lane}: {v} < {prev}");
            prev = v;
        }
        assert_eq!(ChaCha20::lane_to_f32(0, lo, hi), lo);
        assert!(ChaCha20::lane_to_f32(u32::MAX, lo, hi) <= hi);
    }

    #[test]
    fn quad_dispatch_matches_scalar_blocks() {
        // the four-blocks-per-dispatch path must be keystream-identical
        // to the single-block path, for byte reads and lane streams
        // alike, at widths that land inside, at, and across the 64-byte
        // block and 256-byte quad boundaries
        let key = [0x2au8; 32];
        for n_lanes in [1usize, 7, 8, 9, 15, 16, 17, 63, 64, 65, 100, 1000] {
            let mut quad = ChaCha20::from_seed(&key, 11);
            quad.set_quad_blocks(true);
            let mut scalar = ChaCha20::from_seed(&key, 11);
            scalar.set_quad_blocks(false);
            let mut lanes_q = Vec::new();
            quad.for_each_uniform_f32(n_lanes, |i, lane| lanes_q.push((i, lane)));
            let mut lanes_s = Vec::new();
            scalar.for_each_uniform_f32(n_lanes, |i, lane| lanes_s.push((i, lane)));
            assert_eq!(lanes_q, lanes_s, "n={n_lanes}");
        }
        for n_bytes in [1usize, 63, 64, 65, 255, 256, 257, 777] {
            let mut quad = ChaCha20::from_seed(&key, 12);
            quad.set_quad_blocks(true);
            let mut scalar = ChaCha20::from_seed(&key, 12);
            scalar.set_quad_blocks(false);
            let mut bq = vec![0u8; n_bytes];
            let mut bs = vec![0u8; n_bytes];
            quad.fill_bytes(&mut bq);
            scalar.fill_bytes(&mut bs);
            assert_eq!(bq, bs, "n={n_bytes}");
        }
    }

    #[test]
    fn quad_dispatch_survives_mode_and_alignment_mixes() {
        // reading bytes (including a misaligning 3-byte read) and then
        // lanes from one stream must match a pure byte stream
        let key = [0x3bu8; 32];
        for quad in [false, true] {
            let mut a = ChaCha20::from_seed(&key, 4);
            a.set_quad_blocks(quad);
            let mut reference = vec![0u8; 3 + 4 * 100];
            a.fill_bytes(&mut reference);

            let mut b = ChaCha20::from_seed(&key, 4);
            b.set_quad_blocks(quad);
            let mut head = [0u8; 3];
            b.fill_bytes(&mut head);
            assert_eq!(head[..], reference[..3]);
            let mut lanes = Vec::new();
            b.for_each_uniform_f32(100, |i, lane| lanes.push((i, lane)));
            for (i, lane) in lanes {
                let off = 3 + 4 * i;
                let want = u32::from_le_bytes(reference[off..off + 4].try_into().unwrap());
                assert_eq!(lane, want, "quad={quad} lane {i}");
            }
        }
    }

    #[test]
    fn unaligned_reads_match_aligned() {
        let key = [3u8; 32];
        let mut a = ChaCha20::from_seed(&key, 5);
        let mut b = ChaCha20::from_seed(&key, 5);
        let mut big = [0u8; 100];
        a.fill_bytes(&mut big);
        let mut parts = Vec::new();
        for chunk in [7usize, 13, 64, 16] {
            let mut buf = vec![0u8; chunk];
            b.fill_bytes(&mut buf);
            parts.extend_from_slice(&buf);
        }
        assert_eq!(&big[..], &parts[..100]);
    }
}
