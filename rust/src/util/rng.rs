//! Deterministic PRNGs for everything that is *not* cryptographic:
//! model init, client selection, data synthesis, property tests.
//! (Secure-aggregation masks use [`crate::util::chacha`] instead.)
//!
//! `SplitMix64` seeds `Xoshiro256**` per the reference construction;
//! both are exactly reproducible across platforms, which is what makes
//! the experiment harnesses rerunnable bit-for-bit.

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller sample.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream for a labelled subcomponent.
    /// Streams for different labels are decorrelated by hashing.
    pub fn fork(&mut self, label: u64) -> Rng {
        let mut sm = SplitMix64::new(self.next_u64() ^ label.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in `[0, n)` (Lemire-style rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let (hi, lo) = mul_hi_lo(r, n);
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_spare = Some(r * s);
            return r * c;
        }
    }

    /// Normal with std `std` as f32 (model init).
    pub fn normal_f32(&mut self, std: f32) -> f32 {
        (self.normal() * std as f64) as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[inline]
fn mul_hi_lo(a: u64, b: u64) -> (u64, u64) {
    let wide = (a as u128) * (b as u128);
    ((wide >> 64) as u64, wide as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiasedish_and_in_range() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            // each bucket ~10k; loose 3-sigma-ish bound
            assert!((9_000..11_000).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Rng::new(3);
        let s = r.sample_indices(100, 10);
        assert_eq!(s.len(), 10);
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 10);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut r = Rng::new(11);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }
}
