//! Per-round neighborhood-local Shamir re-keying.
//!
//! The original recovery design distributed Shamir shares of every
//! pair key across **all** pairs at setup (`full_setup` with
//! `share_keys: true`) — O(n³) share material, every client holding
//! material for every pair, forever. This module replaces that for
//! k-regular runs: each round, every cohort member's DH private
//! exponent is re-shared among exactly its current neighbors
//! `N_r(u)` (one share per neighbor, evaluated at `x = neighbor_id +
//! 1`), so
//!
//! * setup and re-key are O(n·k) — Σ_u |N_r(u)| shares per round, not
//!   n·(n−1);
//! * a client's secret is only ever held by its *current* neighbors —
//!   leaving a neighborhood revokes access, because the next re-share
//!   draws a fresh polynomial the old shares don't lie on;
//! * churn (join/leave between re-key calls) re-shares only the
//!   neighborhoods whose holder set actually changed — the
//!   consistent-hash ring ([`super::neighborhood`]) keeps those local
//!   to the churned member.
//!
//! Sharing the *exponent* rather than each pair key keeps the material
//! per owner O(k) instead of O(k²) and still recovers exactly the same
//! pair-key bytes: reconstructing `x_u` lets the server recompute
//! `pub_v^{x_u} mod p` and run it through the same HKDF both endpoints
//! use ([`protocol::pair_key`]), so cancellation is bit-identical to
//! the shared-pair-key path. `neighbors_k = 0` runs never construct a
//! registry and keep the one-off all-pairs setup byte-identical.

use std::collections::HashMap;

use crate::util::rng::Rng;

use super::dh::DhKeyPair;
use super::neighborhood::Neighborhood;
use super::protocol::{pair_key, SecAggClient, SecAggServer};
use super::shamir::{self, Share};

/// Domain constant mixed into each owner's re-share polynomial seed
/// (distinct from the selection/transport/keygen/neighborhood
/// constants).
const REKEY_SALT: u64 = 0x7265_6b65_79;

/// What one [`RekeyRegistry::rekey_for`] call did — the counting
/// surface the O(n·k) acceptance tests and benches pin.
#[derive(Clone, Copy, Debug, Default)]
pub struct RekeyStats {
    /// Owners whose secret was (re-)shared this call.
    pub reshared_owners: usize,
    /// Shares distributed this call (Σ over reshared owners of
    /// |N_r(owner)|).
    pub shares_distributed: usize,
    /// Owners dropped because they left the cohort.
    pub dropped_owners: usize,
    /// Owners whose holder set was unchanged — their existing shares
    /// stay valid (the secret is round-independent), so nothing moves.
    pub carried_owners: usize,
}

/// One owner's live share material: who holds a share, and the shares
/// themselves (in the simulation the registry plays the wire; holders
/// are recorded so tests can assert the secret exists *only* at
/// `N_r(owner)`).
struct RekeyEntry {
    /// Holder ids, ascending ([`Neighborhood::neighbors_into`] order).
    holders: Vec<u32>,
    /// Per-holder share vector, aligned with `holders`; inner Vec is
    /// one [`Share`] per 16-bit limb of the exponent.
    shares: Vec<Vec<Share>>,
    /// Reconstruction threshold this entry was split with (the
    /// configured threshold, capped by the neighbor count for
    /// degenerate tiny cohorts).
    t: usize,
}

/// Server-side registry of the current round's share placement.
///
/// Owned by the coordinator (`Trainer`) for k-regular secure runs with
/// failure injection; [`Self::rekey_for`] runs in the Select phase
/// after the round's topology is built, and
/// [`recover_pair_keys_rekeyed`] replaces
/// [`super::protocol::recover_pair_keys_in`] in Unmask/Recover.
pub struct RekeyRegistry {
    threshold: usize,
    /// Bumped every re-key call and mixed into the polynomial seed, so
    /// a churn re-share within the same round never reuses a
    /// polynomial with new evaluation points.
    epoch: u64,
    entries: HashMap<u32, RekeyEntry>,
}

impl RekeyRegistry {
    pub fn new(threshold: usize) -> Self {
        assert!(threshold >= 1, "threshold must be ≥ 1");
        Self { threshold, epoch: 0, entries: HashMap::new() }
    }

    pub fn threshold(&self) -> usize {
        self.threshold
    }

    /// Current holder set for `owner`'s secret (ascending), if shared.
    pub fn holders_of(&self, owner: u32) -> Option<&[u32]> {
        self.entries.get(&owner).map(|e| e.holders.as_slice())
    }

    /// Owners with live share material, ascending.
    pub fn owners(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.entries.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Re-key the registry against `topo` (the round's topology over
    /// its cohort): drop owners that left the cohort, keep owners
    /// whose neighbor set is unchanged (their shares remain valid),
    /// and re-share everyone else among exactly their current
    /// neighbors — fresh polynomial per owner per call.
    ///
    /// O(n·k): Σ_u |N_r(u)| shares move per full re-key, and a churn
    /// call touches only the affected neighborhoods.
    pub fn rekey_for(
        &mut self,
        clients: &[SecAggClient],
        topo: &Neighborhood,
        round: u64,
        seed: u64,
    ) -> RekeyStats {
        self.epoch += 1;
        let members = topo.members();
        let before = self.entries.len();
        self.entries.retain(|owner, _| members.binary_search(owner).is_ok());
        let mut stats =
            RekeyStats { dropped_owners: before - self.entries.len(), ..Default::default() };
        let mut neighbors = Vec::new();
        for &owner in members {
            topo.neighbors_into(owner, &mut neighbors);
            if let Some(e) = self.entries.get(&owner) {
                if e.holders == neighbors {
                    stats.carried_owners += 1;
                    continue;
                }
            }
            let secret = clients[owner as usize].private_share_bytes();
            let xs: Vec<u64> = neighbors.iter().map(|&v| v as u64 + 1).collect();
            let t = self.threshold.min(xs.len());
            let mut rng = Rng::new(
                seed ^ REKEY_SALT
                    ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                    ^ (owner as u64).wrapping_mul(0xD6E8_FEB8_6659_FD93)
                    ^ self.epoch.wrapping_mul(0xA24B_AED4_963E_E407),
            );
            let limb_shares = shamir::split_bytes_at(&secret, &xs, t, &mut rng);
            // transpose limb-major → holder-major, the shape a holder
            // would receive on the wire
            let shares: Vec<Vec<Share>> = (0..xs.len())
                .map(|h| limb_shares.iter().map(|l| l[h]).collect())
                .collect();
            stats.reshared_owners += 1;
            stats.shares_distributed += xs.len();
            self.entries.insert(owner, RekeyEntry { holders: neighbors.clone(), shares, t });
        }
        stats
    }
}

/// Dropout recovery against a re-keyed registry: for each dead client
/// `u`, gather ≥ `t` shares from its *surviving holders* (which are
/// exactly its round neighbors), reconstruct the DH exponent, and
/// rederive the pair key for every surviving neighbor `v` — the same
/// bytes [`SecAggClient::pair_key_with`] produces, so mask
/// cancellation is unchanged.
///
/// Returns `None` when some dead client has fewer than `t` surviving
/// holders — the caller must abort the round rather than apply a
/// mask-corrupted aggregate. (Shares live only in `u`'s neighborhood
/// now, so the quorum is over |N_r(u) ∩ survivors|, not all
/// survivors.)
pub fn recover_pair_keys_rekeyed(
    registry: &RekeyRegistry,
    server: &SecAggServer,
    survivors: &[u32],
    dead: &[u32],
    topo: &Neighborhood,
) -> Option<HashMap<(u32, u32), [u8; 32]>> {
    let mut recovered = HashMap::new();
    for &u in dead {
        let entry = registry.entries.get(&u)?;
        let contributing: Vec<&Vec<Share>> = entry
            .holders
            .iter()
            .zip(&entry.shares)
            .filter(|(h, _)| survivors.contains(h))
            .map(|(_, s)| s)
            .take(entry.t)
            .collect();
        if contributing.len() < entry.t {
            return None;
        }
        let n_limbs = contributing[0].len();
        // transpose holder-major → limb-major for reconstruction
        let limbs: Vec<Vec<Share>> = (0..n_limbs)
            .map(|l| contributing.iter().map(|s| s[l]).collect())
            .collect();
        let exponent = shamir::reconstruct_bytes(&limbs);
        let kp = DhKeyPair::from_private_bytes_be(&server.params, &exponent);
        for &v in survivors {
            if topo.are_neighbors(u, v) {
                let secret = kp.shared_secret(&server.params, &server.publics[v as usize]);
                recovered.insert((v, u), pair_key(&secret));
            }
        }
    }
    Some(recovered)
}
