//! Finite-field Diffie-Hellman key agreement (§2.2 / §3.2: "the secure
//! aggregation framework completes the key exchange through the DH
//! protocol").
//!
//! Group: RFC 3526 1536-bit MODP (id 5), generator 2. Each pair of
//! federated participants derives one shared secret; [`crate::secagg::kdf`]
//! turns it into per-round mask seeds. The DH *exchange* still runs
//! once per training job (the paper's §6 notes redoing the modpow
//! handshake per round would dominate), but the Shamir *shares* of
//! each exponent are re-keyed every round against the round's
//! k-regular neighborhood ([`crate::secagg::rekey`]), so a client's
//! secret is only ever held by its current neighbors. `neighbors_k =
//! 0` bypasses re-keying and keeps the original one-off all-pairs
//! setup byte-identical.

use super::bignum::BigUint;
use crate::util::rng::Rng;

/// RFC 3526 group 5 prime (1536-bit), generator 2.
pub const MODP_1536_HEX: &str = "
FFFFFFFF FFFFFFFF C90FDAA2 2168C234 C4C6628B 80DC1CD1
29024E08 8A67CC74 020BBEA6 3B139B22 514A0879 8E3404DD
EF9519B3 CD3A431B 302B0A6D F25F1437 4FE1356D 6D51C245
E485B576 625E7EC6 F44C42E9 A637ED6B 0BFF5CB6 F406B7ED
EE386BFB 5A899FA5 AE9F2411 7C4B1FE6 49286651 ECE45B3D
C2007CB8 A163BF05 98DA4836 1C55D39A 69163FA8 FD24CF5F
83655D23 DCA3AD96 1C62F356 208552BB 9ED52907 7096966D
670C354E 4ABC9804 F1746C08 CA237327 FFFFFFFF FFFFFFFF";

/// A small toy group for fast unit tests (NOT secure): p = 2^61-1
/// is prime (Mersenne), g = 3.
pub const TOY_P: u64 = (1u64 << 61) - 1;
pub const TOY_G: u64 = 3;

/// Diffie-Hellman group parameters.
#[derive(Clone, Debug)]
pub struct DhParams {
    pub p: BigUint,
    pub g: BigUint,
    /// Private-key bit length to sample.
    pub priv_bits: usize,
}

impl DhParams {
    /// RFC 3526 1536-bit MODP group.
    pub fn rfc3526_1536() -> Self {
        Self {
            p: BigUint::from_hex(MODP_1536_HEX).expect("constant"),
            g: BigUint::from_u64(2),
            priv_bits: 256,
        }
    }

    /// Toy group for tests — 61-bit Mersenne prime.
    pub fn toy() -> Self {
        Self {
            p: BigUint::from_u64(TOY_P),
            g: BigUint::from_u64(TOY_G),
            priv_bits: 48,
        }
    }
}

/// One participant's DH key pair.
#[derive(Clone, Debug)]
pub struct DhKeyPair {
    pub public: BigUint,
    private: BigUint,
}

impl DhKeyPair {
    /// Sample a private exponent from `rng` and compute `g^x mod p`.
    pub fn generate(params: &DhParams, rng: &mut Rng) -> Self {
        // sample priv_bits of randomness, force the top bit so the
        // exponent has full length, and avoid 0/1
        let n_limbs = params.priv_bits.div_ceil(64);
        let mut bytes = Vec::with_capacity(n_limbs * 8);
        for _ in 0..n_limbs {
            bytes.extend_from_slice(&rng.next_u64().to_be_bytes());
        }
        let mut x = BigUint::from_bytes_be(&bytes);
        // clamp to priv_bits and set the high bit
        x = x.rem(&shl_one(params.priv_bits));
        x = x.add(&shl_one(params.priv_bits - 1));
        let public = params.g.modpow(&x, &params.p);
        Self { public, private: x }
    }

    /// Shared secret `other_pub ^ my_priv mod p`, as big-endian bytes.
    pub fn shared_secret(&self, params: &DhParams, other_pub: &BigUint) -> Vec<u8> {
        other_pub.modpow(&self.private, &params.p).to_bytes_be()
    }

    /// The private exponent as fixed-width big-endian bytes
    /// (left-padded with zeros to `len`) — the secret material the
    /// per-round re-keying path Shamir-shares limb-wise. `len` must
    /// cover `priv_bits + 1` bits: [`Self::generate`]'s high-bit force
    /// can carry one bit past `priv_bits`.
    pub fn private_bytes_be(&self, len: usize) -> Vec<u8> {
        let raw = self.private.to_bytes_be();
        assert!(raw.len() <= len, "exponent wider than {len} bytes");
        let mut out = vec![0u8; len - raw.len()];
        out.extend_from_slice(&raw);
        out
    }

    /// Rebuild a keypair from a serialized private exponent
    /// (recomputing `g^x mod p`) — the recovery side of re-keying:
    /// reconstructing a dead client's exponent lets the server rederive
    /// every pair secret that client would have computed.
    pub fn from_private_bytes_be(params: &DhParams, bytes: &[u8]) -> Self {
        let x = BigUint::from_bytes_be(bytes);
        let public = params.g.modpow(&x, &params.p);
        Self { public, private: x }
    }
}

fn shl_one(bits: usize) -> BigUint {
    // 2^bits
    let mut bytes = vec![0u8; bits / 8 + 1];
    bytes[0] = 1 << (bits % 8);
    BigUint::from_bytes_be(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toy_group_agreement() {
        let params = DhParams::toy();
        let mut rng = Rng::new(1);
        let a = DhKeyPair::generate(&params, &mut rng);
        let b = DhKeyPair::generate(&params, &mut rng);
        let sa = a.shared_secret(&params, &b.public);
        let sb = b.shared_secret(&params, &a.public);
        assert_eq!(sa, sb);
        assert!(!sa.is_empty());
    }

    #[test]
    fn toy_group_distinct_pairs_distinct_secrets() {
        let params = DhParams::toy();
        let mut rng = Rng::new(2);
        let a = DhKeyPair::generate(&params, &mut rng);
        let b = DhKeyPair::generate(&params, &mut rng);
        let c = DhKeyPair::generate(&params, &mut rng);
        let sab = a.shared_secret(&params, &b.public);
        let sac = a.shared_secret(&params, &c.public);
        assert_ne!(sab, sac);
    }

    #[test]
    fn rfc_group_agreement() {
        // full 1536-bit group; one exchange (~4 modpows) is fast enough
        let params = DhParams::rfc3526_1536();
        assert_eq!(params.p.bit_len(), 1536);
        let mut rng = Rng::new(3);
        let a = DhKeyPair::generate(&params, &mut rng);
        let b = DhKeyPair::generate(&params, &mut rng);
        assert_eq!(
            a.shared_secret(&params, &b.public),
            b.shared_secret(&params, &a.public)
        );
    }

    #[test]
    fn public_key_in_range() {
        let params = DhParams::toy();
        let mut rng = Rng::new(4);
        for _ in 0..10 {
            let kp = DhKeyPair::generate(&params, &mut rng);
            assert!(kp.public.cmp_big(&params.p) == std::cmp::Ordering::Less);
            assert!(!kp.public.is_zero());
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let params = DhParams::toy();
        let a1 = DhKeyPair::generate(&params, &mut Rng::new(42));
        let a2 = DhKeyPair::generate(&params, &mut Rng::new(42));
        assert_eq!(a1.public, a2.public);
    }

    #[test]
    fn private_bytes_roundtrip_rederives_all_pair_secrets() {
        for params in [DhParams::toy(), DhParams::rfc3526_1536()] {
            let mut rng = Rng::new(5);
            let a = DhKeyPair::generate(&params, &mut rng);
            let b = DhKeyPair::generate(&params, &mut rng);
            // minimal width covering priv_bits + 1 bits (the
            // generate() carry); the re-keying registry additionally
            // rounds up to whole 16-bit limbs (exponent_share_width)
            let len = (params.priv_bits + 1).div_ceil(8);
            let bytes = a.private_bytes_be(len);
            assert_eq!(bytes.len(), len);
            let a2 = DhKeyPair::from_private_bytes_be(&params, &bytes);
            assert_eq!(a2.public, a.public);
            assert_eq!(
                a2.shared_secret(&params, &b.public),
                a.shared_secret(&params, &b.public)
            );
        }
    }

    #[test]
    #[should_panic(expected = "exponent wider")]
    fn too_narrow_private_width_rejected() {
        let params = DhParams::toy();
        let kp = DhKeyPair::generate(&params, &mut Rng::new(6));
        // toy exponents always have the 2^47 bit set → > 4 bytes
        kp.private_bytes_be(4);
    }
}
