//! Pairwise additive masks (Bonawitz et al. 2017 construction, §2.2).
//!
//! For every client pair (u, v) with DH shared secret `s_uv`, both
//! sides expand the same uniform stream `mask_r ∈ [p, p+q)` (paper
//! §3.2) via ChaCha20 keyed by `HKDF(s_uv, pair, round)`. The lower-id
//! client *adds* the mask, the higher-id client *subtracts* it, so the
//! server-side sum over all participants cancels exactly.
//!
//! The DH exchange itself runs once per job; per-round keys come from
//! the KDF (see [`crate::secagg::kdf::mask_seed`]), reproducing the
//! paper's "DH only executed once" setting without mask reuse.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::chacha::ChaCha20;
use crate::util::pool::ThreadPool;
use crate::util::simd::{self, LaneFilter};

use super::kdf::mask_seed;

/// A σ-filtered pair stream: only the kept (mask_r < σ) entries.
#[derive(Debug)]
pub struct FilteredStream {
    pub sigma: f32,
    pub n: usize,
    /// (position, value) of kept entries, ascending positions.
    pub entries: Vec<(u32, f32)>,
}

/// Exclusive raw-lane bound equivalent to the σ-filter: a keystream
/// lane `u` is kept iff `(u as u64) < bound`, which holds iff
/// `ChaCha20::lane_to_f32(u, lo, hi) < sigma`.
///
/// The lane→value map is monotone non-decreasing (see
/// [`ChaCha20::lane_to_f32`]), so the kept set is exactly `[0, bound)`
/// and a 32-step binary search recovers the boundary *exactly* — the
/// streaming filter is bitwise identical to materialize-then-compare,
/// while the ~(1 − k/x) discarded lanes skip the int→float conversion
/// entirely. `u64` so that "keep everything" (σ above the range top)
/// is representable as 2³².
fn sigma_lane_bound(lo: f32, hi: f32, sigma: f32) -> u64 {
    let val = |u: u32| ChaCha20::lane_to_f32(u, lo, hi);
    if val(0) >= sigma {
        return 0; // nothing kept (σ at/below the range bottom)
    }
    if val(u32::MAX) < sigma {
        return 1 << 32; // everything kept
    }
    // invariant: val(a) < sigma ≤ val(b)
    let (mut a, mut b) = (0u32, u32::MAX);
    while b - a > 1 {
        let mid = a + (b - a) / 2;
        if val(mid) < sigma {
            a = mid;
        } else {
            b = mid;
        }
    }
    b as u64
}

/// Stream `n` keystream lanes of `prg` against the exclusive integer
/// σ-bound, pushing `(position, value)` for every kept lane in
/// ascending position order — the compress half of the σ-filter.
///
/// With `use_simd`, eight raw u32 lanes at a time are compared
/// straight out of the PRG's buffered block bytes
/// ([`LaneFilter::keep_mask`]); only the kept lanes (~k/x of n) are
/// decoded and converted to f32, and an all-discarded group — the
/// overwhelmingly common case at round keep-ratios — costs one
/// compare + one branch. The integer compare is exact and the kept
/// lanes decode through the same [`ChaCha20::lane_to_f32`] map, so
/// both branches emit bit-identical entries (pinned by
/// `filter_compress_bitwise_matches_scalar`); the scalar branch is
/// also taken for the `bound == 2³²` keep-everything edge, where a
/// compare-and-compress step has nothing to discard.
fn filter_lanes_into(
    prg: &mut ChaCha20,
    n: usize,
    bound: u64,
    lo: f32,
    hi: f32,
    entries: &mut Vec<(u32, f32)>,
    use_simd: bool,
) {
    if bound == 0 {
        return; // nothing kept — no entry the PRG could contribute
    }
    if !use_simd || bound >= 1 << 32 {
        prg.for_each_uniform_f32(n, |i, lane| {
            if (lane as u64) < bound {
                entries.push((i as u32, ChaCha20::lane_to_f32(lane, lo, hi)));
            }
        });
        return;
    }
    let filter = LaneFilter::new(bound as u32);
    prg.for_each_lane_chunk(n, |base, bytes| {
        let lanes = bytes.len() / 4;
        let mut l = 0;
        while l + 8 <= lanes {
            let mut mask = filter.keep_mask(&bytes[4 * l..]);
            // compress: emit kept lanes only, low bit first (ascending
            // positions — the scalar emission order)
            while mask != 0 {
                let bit = mask.trailing_zeros() as usize;
                mask &= mask - 1;
                let off = 4 * (l + bit);
                let lane = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
                entries.push(((base + l + bit) as u32, ChaCha20::lane_to_f32(lane, lo, hi)));
            }
            l += 8;
        }
        while l < lanes {
            let off = 4 * l;
            let lane = u32::from_le_bytes(bytes[off..off + 4].try_into().unwrap());
            if (lane as u64) < bound {
                entries.push(((base + l) as u32, ChaCha20::lane_to_f32(lane, lo, hi)));
            }
            l += 1;
        }
    });
}

/// Build (or fetch from `cache`) the σ-filtered stream of pair
/// (id, peer) from the pair secret. Standalone (not a
/// [`PairwiseMasker`] method) so the parallel fan-out paths — the
/// client-side pooled combined mask and the server's dead-mask
/// recovery — can run it from worker tasks that own only the pair's
/// key material. The PRG is streamed block-wise against the integer
/// σ-bound exactly as documented on
/// `PairwiseMasker::filtered_pair_mask`.
pub(crate) fn filtered_stream_for_pair(
    id: u32,
    peer: u32,
    secret: &[u8],
    range: MaskRange,
    cache: Option<&MaskCache>,
    round: u64,
    n: usize,
    sigma: f32,
) -> Arc<FilteredStream> {
    let cache_key = {
        let (lo, hi) = if id < peer { (id, peer) } else { (peer, id) };
        (lo, hi, round)
    };
    if let Some(cache) = cache {
        if let Some(hit) = cache.lock().unwrap().get(&cache_key) {
            if hit.n == n && hit.sigma == sigma {
                return Arc::clone(hit);
            }
        }
    }
    let (lo, hi) = (range.lo(), range.hi());
    let bound = sigma_lane_bound(lo, hi, sigma);
    // expected keep count = (bound / 2³²) · n, plus slack so the
    // binomial tail rarely reallocates
    let expect = (bound as f64 / 4_294_967_296.0 * n as f64) as usize;
    let mut entries: Vec<(u32, f32)> = Vec::with_capacity(expect + expect / 8 + 16);
    let key = mask_seed(secret, id, peer, round);
    let mut prg = ChaCha20::from_seed(&key, round);
    filter_lanes_into(&mut prg, n, bound, lo, hi, &mut entries, simd::enabled());
    let out = Arc::new(FilteredStream { sigma, n, entries });
    if let Some(cache) = cache {
        cache.lock().unwrap().insert(cache_key, Arc::clone(&out));
    }
    out
}

/// One (id, peer) stream-generation task for the pooled fan-out: owns
/// a copy of the pair's key material so it can cross into pool workers
/// (small — the secret is 32 bytes, never model-sized).
struct PairGenTask {
    id: u32,
    peer: u32,
    secret: Vec<u8>,
    range: MaskRange,
    cache: Option<MaskCache>,
    round: u64,
    n: usize,
    sigma: f32,
}

/// Shared per-round cache of σ-filtered pair streams. In the
/// in-process simulation each pair's stream is needed by BOTH
/// endpoints within a round; caching halves ChaCha work AND shrinks
/// the accumulate sweep to the kept entries only (§Perf L3
/// iterations 4-5). Key: (lo-id, hi-id, round).
pub type MaskCache = Arc<Mutex<HashMap<(u32, u32, u64), Arc<FilteredStream>>>>;

/// Mask distribution bounds: `mask_r ∈ [p, p+q)` (§3.2).
#[derive(Clone, Copy, Debug)]
pub struct MaskRange {
    pub p: f32,
    pub q: f32,
}

impl Default for MaskRange {
    fn default() -> Self {
        // symmetric around zero, wide enough to drown typical gradient
        // magnitudes (|g| ~ 1e-2 after local training)
        Self { p: -10.0, q: 20.0 }
    }
}

impl MaskRange {
    pub fn lo(&self) -> f32 {
        self.p
    }

    pub fn hi(&self) -> f32 {
        self.p + self.q
    }

    /// The paper's Eq. 4 filter threshold `σ = p + (k/x)·q`, where `k`
    /// is the mask keep-ratio and `x` the number of participants.
    pub fn sigma(&self, k: f64, x: usize) -> f32 {
        assert!(x > 0, "sigma with zero participants");
        self.p + ((k / x as f64) as f32) * self.q
    }
}

/// One client's view of the pairwise masking state.
#[derive(Clone)]
pub struct PairwiseMasker {
    pub id: u32,
    /// (peer id, DH shared secret bytes) for every *other* participant.
    peers: Vec<(u32, Vec<u8>)>,
    pub range: MaskRange,
    /// Optional shared stream cache (simulation-only optimization; the
    /// per-client communication/computation model is unchanged).
    cache: Option<MaskCache>,
}

impl std::fmt::Debug for PairwiseMasker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PairwiseMasker")
            .field("id", &self.id)
            .field("n_peers", &self.peers.len())
            .field("range", &self.range)
            .finish()
    }
}

impl PairwiseMasker {
    pub fn new(id: u32, peers: Vec<(u32, Vec<u8>)>, range: MaskRange) -> Self {
        assert!(
            peers.iter().all(|(pid, _)| *pid != id),
            "peer list contains self"
        );
        Self { id, peers, range, cache: None }
    }

    /// Attach a shared per-round stream cache.
    pub fn set_cache(&mut self, cache: MaskCache) {
        self.cache = Some(cache);
    }

    pub fn n_peers(&self) -> usize {
        self.peers.len()
    }

    /// Restrict to a subset of peers — the per-round participant set
    /// (masks only form among the round's selected clients; the DH
    /// pair keys are reused, matching §3.2's one-time key exchange).
    /// `keep` is sorted once so membership is a binary search, not an
    /// O(selected) scan per peer.
    pub fn restrict(&self, keep: &[u32]) -> PairwiseMasker {
        let mut sorted = keep.to_vec();
        sorted.sort_unstable();
        PairwiseMasker {
            id: self.id,
            peers: self
                .peers
                .iter()
                .filter(|(pid, _)| sorted.binary_search(pid).is_ok())
                .cloned()
                .collect(),
            range: self.range,
            cache: self.cache.clone(),
        }
    }

    /// This pair's per-round ChaCha stream, positioned at lane 0.
    fn pair_prg(&self, secret: &[u8], peer: u32, round: u64) -> ChaCha20 {
        let key = mask_seed(secret, self.id, peer, round);
        ChaCha20::from_seed(&key, round)
    }

    fn peer_secret(&self, peer: u32) -> &[u8] {
        let (_, secret) = self
            .peers
            .iter()
            .find(|(pid, _)| *pid == peer)
            .expect("unknown peer");
        secret
    }

    /// The raw uniform stream for one pair at one round: identical on
    /// both sides of the pair (keyed by normalized pair + round).
    pub fn raw_pair_mask(&self, peer: u32, round: u64, n: usize) -> Vec<f32> {
        let mut prg = self.pair_prg(self.peer_secret(peer), peer, round);
        let mut out = vec![0f32; n];
        prg.fill_uniform_f32(&mut out, self.range.lo(), self.range.hi());
        out
    }

    /// σ-filtered pair stream, cache-aware. The PRG is streamed
    /// block-wise: each raw u32 lane is compared against the
    /// precomputed integer σ-bound and only the kept lanes (~k/x of n)
    /// are converted to f32 and pushed — the dense n-float stream is
    /// never materialized. Bitwise identical to generating the dense
    /// stream and filtering `v < σ` (see [`sigma_lane_bound`]).
    fn filtered_pair_mask(&self, peer: u32, round: u64, n: usize, sigma: f32) -> Arc<FilteredStream> {
        filtered_stream_for_pair(
            self.id,
            peer,
            self.peer_secret(peer),
            self.range,
            self.cache.as_ref(),
            round,
            n,
            sigma,
        )
    }

    /// The per-peer stream-generation tasks for the pooled fan-out
    /// (each owns a copy of its pair's 32-byte key material).
    fn pair_gen_tasks(&self, round: u64, n: usize, sigma: f32) -> Vec<PairGenTask> {
        self.peers
            .iter()
            .map(|(peer, secret)| PairGenTask {
                id: self.id,
                peer: *peer,
                secret: secret.clone(),
                range: self.range,
                cache: self.cache.clone(),
                round,
                n,
                sigma,
            })
            .collect()
    }

    /// Sign convention: +1 if this client has the smaller id of the
    /// pair (it adds), −1 otherwise (it subtracts).
    pub fn sign_for(&self, peer: u32) -> f32 {
        if self.id < peer {
            1.0
        } else {
            -1.0
        }
    }

    /// Dense combined mask `Σ_pairs sign · mask_r` (original secure
    /// aggregation, no sparsification). Each pair stream accumulates
    /// block-wise straight out of the PRG — no per-pair dense buffer.
    pub fn combined_mask(&self, round: u64, n: usize) -> Vec<f32> {
        let mut acc = vec![0f32; n];
        self.accumulate_combined_mask(round, &mut acc);
        acc
    }

    /// [`Self::combined_mask`] into a caller-owned (zeroed) buffer.
    pub fn accumulate_combined_mask(&self, round: u64, acc: &mut [f32]) {
        let (lo, hi) = (self.range.lo(), self.range.hi());
        for (peer, secret) in &self.peers {
            let mut prg = self.pair_prg(secret, *peer, round);
            let sign = self.sign_for(*peer);
            prg.for_each_uniform_f32(acc.len(), |i, lane| {
                acc[i] += sign * ChaCha20::lane_to_f32(lane, lo, hi);
            });
        }
    }

    /// Sparse combined mask: the paper's zero-local-value rule
    /// (Alg. 2 line 14): keep `mask_r[j]` only when `mask_r[j] < σ`.
    /// Both sides of a pair keep the same positions (same stream), so
    /// cancellation is preserved. Returns the signed combined sparse
    /// mask; `nonzero[j]` is true where ANY pair kept a mask value
    /// (needed for the transmission mask `mask_t`).
    pub fn sparse_combined_mask(&self, round: u64, n: usize, sigma: f32) -> (Vec<f32>, Vec<bool>) {
        let mut acc = Vec::new();
        let mut nonzero = Vec::new();
        self.sparse_combined_mask_into(round, n, sigma, &mut acc, &mut nonzero);
        (acc, nonzero)
    }

    /// [`Self::sparse_combined_mask`] into caller-owned scratch (the
    /// per-worker `ClientWorkspace` holds these, so the steady-state
    /// round path allocates nothing model-sized). The accumulate sweep
    /// only touches the σ-kept entries of each pair stream (~k/x of
    /// n), via the shared [`FilteredStream`] cache when attached
    /// (§Perf L3 iteration 5).
    pub fn sparse_combined_mask_into(
        &self,
        round: u64,
        n: usize,
        sigma: f32,
        acc: &mut Vec<f32>,
        nonzero: &mut Vec<bool>,
    ) {
        acc.clear();
        acc.resize(n, 0.0);
        nonzero.clear();
        nonzero.resize(n, false);
        for (peer, _) in &self.peers {
            let filtered = self.filtered_pair_mask(*peer, round, n, sigma);
            let sign = self.sign_for(*peer);
            for &(i, v) in &filtered.entries {
                acc[i as usize] += sign * v;
                nonzero[i as usize] = true;
            }
        }
    }

    /// [`Self::sparse_combined_mask_into`] with the per-pair stream
    /// *generation* fanned out over `pool` — each pair's ChaCha
    /// expansion is independent, so large cohorts spread the PRG work
    /// across workers (via [`ThreadPool::map_shared`], which is safe
    /// to call from inside a pool job: the round engine's client jobs
    /// already run on this pool).
    ///
    /// **Reduction-order contract** (PERF.md): the reduce into `acc`
    /// stays strictly serial — peers in construction order, positions
    /// ascending within each stream. That is exactly the
    /// per-accumulator f32 op order of the serial path, so the result
    /// is **bitwise identical** to [`Self::sparse_combined_mask_into`]
    /// (pinned by `parallel_fanout_bitwise_matches_serial`).
    pub fn sparse_combined_mask_pooled_into(
        &self,
        pool: &ThreadPool,
        round: u64,
        n: usize,
        sigma: f32,
        acc: &mut Vec<f32>,
        nonzero: &mut Vec<bool>,
    ) {
        let streams = pool.map_shared(self.pair_gen_tasks(round, n, sigma), |t: &PairGenTask| {
            filtered_stream_for_pair(
                t.id,
                t.peer,
                &t.secret,
                t.range,
                t.cache.as_ref(),
                t.round,
                t.n,
                t.sigma,
            )
        });
        acc.clear();
        acc.resize(n, 0.0);
        nonzero.clear();
        nonzero.resize(n, false);
        for ((peer, _), filtered) in self.peers.iter().zip(&streams) {
            let sign = self.sign_for(*peer);
            for &(i, v) in &filtered.entries {
                acc[i as usize] += sign * v;
                nonzero[i as usize] = true;
            }
        }
    }

    /// [`Self::accumulate_combined_mask`] with per-pair generation
    /// fanned out over `pool`: each pair expands its full dense stream
    /// into its own buffer in parallel, then the buffers reduce into
    /// `acc` serially in peer order — every `acc[i]` receives the same
    /// additions in the same order as the serial path
    /// (`fill_uniform_f32` is keystream-identical to the lane
    /// callback), so the result is bitwise identical. The per-pair
    /// dense buffers make this a large-cohort / bench path, not a
    /// steady-state zero-allocation one; the round engine's secure
    /// path uses the σ-filtered variant.
    pub fn accumulate_combined_mask_pooled(&self, pool: &ThreadPool, round: u64, acc: &mut [f32]) {
        let n = acc.len();
        let bufs = pool.map_shared(self.pair_gen_tasks(round, n, 0.0), |t: &PairGenTask| {
            let key = mask_seed(&t.secret, t.id, t.peer, t.round);
            let mut prg = ChaCha20::from_seed(&key, t.round);
            let mut out = vec![0f32; t.n];
            prg.fill_uniform_f32(&mut out, t.range.lo(), t.range.hi());
            out
        });
        for ((peer, _), buf) in self.peers.iter().zip(&bufs) {
            let sign = self.sign_for(*peer);
            for (a, &v) in acc.iter_mut().zip(buf) {
                *a += sign * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: u32) -> Vec<PairwiseMasker> {
        // all-pairs shared secrets derived deterministically for tests
        let secret = |a: u32, b: u32| -> Vec<u8> {
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            format!("secret-{lo}-{hi}").into_bytes()
        };
        (0..n)
            .map(|id| {
                let peers = (0..n)
                    .filter(|&p| p != id)
                    .map(|p| (p, secret(id, p)))
                    .collect();
                PairwiseMasker::new(id, peers, MaskRange::default())
            })
            .collect()
    }

    #[test]
    fn pair_streams_symmetric() {
        let f = fleet(3);
        let m01 = f[0].raw_pair_mask(1, 7, 100);
        let m10 = f[1].raw_pair_mask(0, 7, 100);
        assert_eq!(m01, m10);
    }

    #[test]
    fn dense_masks_cancel_over_fleet() {
        let f = fleet(5);
        let n = 1000;
        let mut sum = vec![0f32; n];
        for c in &f {
            let m = c.combined_mask(3, n);
            for i in 0..n {
                sum[i] += m[i];
            }
        }
        for (i, &s) in sum.iter().enumerate() {
            assert!(s.abs() < 1e-3, "position {i} residue {s}");
        }
    }

    #[test]
    fn sparse_masks_cancel_over_fleet() {
        let f = fleet(4);
        let n = 2000;
        let sigma = f[0].range.sigma(1.0, 4); // keep 25% of mask entries
        let mut sum = vec![0f32; n];
        let mut any_nonzero = 0usize;
        for c in &f {
            let (m, nz) = c.sparse_combined_mask(9, n, sigma);
            any_nonzero += nz.iter().filter(|&&b| b).count();
            for i in 0..n {
                sum[i] += m[i];
            }
        }
        assert!(any_nonzero > 0, "sigma filtered everything");
        for (i, &s) in sum.iter().enumerate() {
            assert!(s.abs() < 1e-3, "position {i} residue {s}");
        }
    }

    #[test]
    fn sigma_controls_keep_fraction() {
        let f = fleet(2);
        let n = 50_000;
        let x = 10;
        for k in [0.5f64, 1.0, 3.0] {
            let sigma = f[0].range.sigma(k, x);
            let (_, nz) = f[0].sparse_combined_mask(1, n, sigma);
            let frac = nz.iter().filter(|&&b| b).count() as f64 / n as f64;
            let expect = k / x as f64;
            assert!(
                (frac - expect).abs() < 0.02,
                "k={k}: frac={frac:.3} expect={expect:.3}"
            );
        }
    }

    #[test]
    fn streamed_filter_matches_materialized_reference() {
        // property: for every (σ, n, round), the block-streamed
        // integer-threshold filter keeps EXACTLY the entries a
        // materialize-then-compare reference keeps, with bit-identical
        // values — the constraint that lets the golden secagg tests
        // survive the streaming rewrite unchanged.
        let f = fleet(3);
        let cases = [
            (1u64, 5000usize, 1.0f64, 10usize),
            (2, 777, 0.5, 4),
            (3, 4096, 3.0, 10),
            (9, 100, 0.0, 2),
        ];
        for (round, n, k, x) in cases {
            let sigma = f[0].range.sigma(k, x);
            let streamed = f[0].filtered_pair_mask(1, round, n, sigma);
            let raw = f[0].raw_pair_mask(1, round, n);
            let reference: Vec<(u32, f32)> = raw
                .iter()
                .enumerate()
                .filter(|(_, &v)| v < sigma)
                .map(|(i, &v)| (i as u32, v))
                .collect();
            assert_eq!(streamed.entries.len(), reference.len(), "k={k} x={x}");
            for (a, b) in streamed.entries.iter().zip(&reference) {
                assert_eq!(a.0, b.0);
                assert_eq!(a.1.to_bits(), b.1.to_bits());
            }
        }
    }

    #[test]
    fn filter_compress_bitwise_matches_scalar() {
        // property: the SIMD compare+compress and the scalar filter
        // emit identical entry lists for every combination of block
        // dispatch width (quad/scalar ChaCha) and filter branch, at
        // lane counts exercising the 8-lane group remainders and the
        // 64/256-byte block boundaries, across keep fractions from
        // "almost nothing" to "everything".
        let key = [0x7cu8; 32];
        let (lo, hi) = (-10.0f32, 10.0);
        // from "nothing kept" through ~0.4% and half up to "everything"
        let bounds: [u64; 6] = [0, 1, 1 << 24, 1 << 31, u32::MAX as u64, 1 << 32];
        for &n in &[1usize, 7, 8, 9, 17, 64, 65, 100, 1000] {
            for &bound in &bounds {
                let run = |quad: bool, use_simd: bool| -> Vec<(u32, f32)> {
                    let mut prg = ChaCha20::from_seed(&key, 21);
                    prg.set_quad_blocks(quad);
                    let mut entries = Vec::new();
                    filter_lanes_into(&mut prg, n, bound, lo, hi, &mut entries, use_simd);
                    entries
                };
                let reference = run(false, false);
                for (quad, use_simd) in [(false, true), (true, false), (true, true)] {
                    let got = run(quad, use_simd);
                    assert_eq!(
                        got.len(),
                        reference.len(),
                        "n={n} bound={bound} quad={quad} simd={use_simd}"
                    );
                    for (a, b) in got.iter().zip(&reference) {
                        assert_eq!(a.0, b.0, "n={n} bound={bound}");
                        assert_eq!(a.1.to_bits(), b.1.to_bits(), "n={n} bound={bound}");
                    }
                }
                // and the scalar reference itself matches the dense map
                let mut prg = ChaCha20::from_seed(&key, 21);
                prg.set_quad_blocks(false);
                let mut want = Vec::new();
                prg.for_each_uniform_f32(n, |i, lane| {
                    if (lane as u64) < bound {
                        want.push((i as u32, ChaCha20::lane_to_f32(lane, lo, hi)));
                    }
                });
                assert_eq!(reference, want, "n={n} bound={bound}");
            }
        }
    }

    #[test]
    fn sigma_lane_bound_edges() {
        let r = MaskRange::default();
        // σ at/below the bottom keeps nothing; above the top keeps all
        assert_eq!(sigma_lane_bound(r.lo(), r.hi(), r.lo()), 0);
        assert_eq!(sigma_lane_bound(r.lo(), r.hi(), r.lo() - 1.0), 0);
        assert_eq!(sigma_lane_bound(r.lo(), r.hi(), r.hi() + 1.0), 1 << 32);
        // boundary exactness: lanes straddling the bound agree with
        // the f32 comparison on either side
        let sigma = r.sigma(1.0, 10);
        let bound = sigma_lane_bound(r.lo(), r.hi(), sigma);
        assert!(bound > 0 && bound < 1 << 32);
        for d in 0..64u64 {
            let below = (bound - 1).saturating_sub(d) as u32;
            let at = (bound + d).min(u32::MAX as u64) as u32;
            assert!(crate::util::chacha::ChaCha20::lane_to_f32(below, r.lo(), r.hi()) < sigma);
            assert!(crate::util::chacha::ChaCha20::lane_to_f32(at, r.lo(), r.hi()) >= sigma);
        }
    }

    #[test]
    fn restrict_filters_and_preserves_order() {
        let f = fleet(6);
        // unsorted keep list — restrict must sort internally
        let r = f[2].restrict(&[5, 0, 3]);
        assert_eq!(r.n_peers(), 3);
        let kept: Vec<u32> = r.peers.iter().map(|(p, _)| *p).collect();
        assert_eq!(kept, vec![0, 3, 5], "peer construction order preserved");
        // restricted masker still produces the same pair stream
        assert_eq!(r.raw_pair_mask(5, 1, 32), f[2].raw_pair_mask(5, 1, 32));
    }

    #[test]
    fn into_variant_matches_allocating_path() {
        let f = fleet(4);
        let n = 1500;
        let sigma = f[1].range.sigma(1.0, 4);
        let (acc, nz) = f[1].sparse_combined_mask(5, n, sigma);
        // pre-dirtied, differently-sized scratch must come out identical
        let mut acc2 = vec![9.9f32; 3];
        let mut nz2 = vec![true; 7];
        f[1].sparse_combined_mask_into(5, n, sigma, &mut acc2, &mut nz2);
        assert_eq!(acc, acc2);
        assert_eq!(nz, nz2);
    }

    #[test]
    fn parallel_fanout_bitwise_matches_serial() {
        // The reduction-order contract (PERF.md): pooled generation +
        // serial peer-order reduction must be BITWISE equal to the
        // serial path, for dense and σ-filtered masks, across cohort
        // sizes spanning the block remainders and sign mixes.
        let pool = ThreadPool::new(3);
        for &x in &[2u32, 3, 8, 17] {
            let f = fleet(x);
            let n = 3000;
            let sigma = f[0].range.sigma(1.0, x as usize);
            // a low, a middle, and the highest id — covers both sign
            // directions without running all 17 clients
            for &ci in &[0usize, (x / 2) as usize, (x - 1) as usize] {
                let c = &f[ci];
                // σ-filtered
                let (acc_s, nz_s) = c.sparse_combined_mask(5, n, sigma);
                let mut acc_p = vec![7.0f32; 1]; // dirty, wrong-sized
                let mut nz_p = vec![true; 3];
                c.sparse_combined_mask_pooled_into(&pool, 5, n, sigma, &mut acc_p, &mut nz_p);
                assert_eq!(nz_s, nz_p, "x={x} client={ci}");
                assert!(
                    acc_s.iter().zip(&acc_p).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "x={x} client={ci}: σ-filtered pooled mask diverged"
                );
                // dense
                let dense_s = c.combined_mask(6, n);
                let mut dense_p = vec![0f32; n];
                c.accumulate_combined_mask_pooled(&pool, 6, &mut dense_p);
                assert!(
                    dense_s.iter().zip(&dense_p).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "x={x} client={ci}: dense pooled mask diverged"
                );
            }
        }
    }

    #[test]
    fn pooled_fanout_uses_and_fills_the_cache() {
        let mut f = fleet(4);
        let cache: MaskCache = Default::default();
        for c in f.iter_mut() {
            c.set_cache(Arc::clone(&cache));
        }
        let pool = ThreadPool::new(2);
        let n = 1200;
        let sigma = f[0].range.sigma(1.0, 4);
        let (mut a1, mut z1) = (Vec::new(), Vec::new());
        f[0].sparse_combined_mask_pooled_into(&pool, 3, n, sigma, &mut a1, &mut z1);
        // all three pair streams of client 0 are now cached
        assert_eq!(cache.lock().unwrap().len(), 3);
        // a second pooled build (other endpoint of pair (0,1)) hits the
        // cache and stays bitwise-consistent with the serial path
        let (mut a2, mut z2) = (Vec::new(), Vec::new());
        f[1].sparse_combined_mask_pooled_into(&pool, 3, n, sigma, &mut a2, &mut z2);
        let (a2s, z2s) = f[1].sparse_combined_mask(3, n, sigma);
        assert_eq!(z2, z2s);
        assert!(a2.iter().zip(&a2s).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn rounds_decorrelate_masks() {
        let f = fleet(2);
        let a = f[0].raw_pair_mask(1, 0, 64);
        let b = f[0].raw_pair_mask(1, 1, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn masks_within_declared_range() {
        let f = fleet(2);
        let m = f[0].raw_pair_mask(1, 2, 10_000);
        let r = f[0].range;
        assert!(m.iter().all(|&x| x >= r.lo() && x < r.hi()));
    }

    #[test]
    #[should_panic(expected = "peer list contains self")]
    fn self_peer_rejected() {
        PairwiseMasker::new(1, vec![(1, vec![0])], MaskRange::default());
    }
}
