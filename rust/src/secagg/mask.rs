//! Pairwise additive masks (Bonawitz et al. 2017 construction, §2.2).
//!
//! For every client pair (u, v) with DH shared secret `s_uv`, both
//! sides expand the same uniform stream `mask_r ∈ [p, p+q)` (paper
//! §3.2) via ChaCha20 keyed by `HKDF(s_uv, pair, round)`. The lower-id
//! client *adds* the mask, the higher-id client *subtracts* it, so the
//! server-side sum over all participants cancels exactly.
//!
//! The DH exchange itself runs once per job; per-round keys come from
//! the KDF (see [`crate::secagg::kdf::mask_seed`]), reproducing the
//! paper's "DH only executed once" setting without mask reuse.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::util::chacha::ChaCha20;

use super::kdf::mask_seed;

/// A σ-filtered pair stream: only the kept (mask_r < σ) entries.
#[derive(Debug)]
pub struct FilteredStream {
    pub sigma: f32,
    pub n: usize,
    /// (position, value) of kept entries, ascending positions.
    pub entries: Vec<(u32, f32)>,
}

/// Shared per-round cache of σ-filtered pair streams. In the
/// in-process simulation each pair's stream is needed by BOTH
/// endpoints within a round; caching halves ChaCha work AND shrinks
/// the accumulate sweep to the kept entries only (§Perf L3
/// iterations 4-5). Key: (lo-id, hi-id, round).
pub type MaskCache = Arc<Mutex<HashMap<(u32, u32, u64), Arc<FilteredStream>>>>;

/// Mask distribution bounds: `mask_r ∈ [p, p+q)` (§3.2).
#[derive(Clone, Copy, Debug)]
pub struct MaskRange {
    pub p: f32,
    pub q: f32,
}

impl Default for MaskRange {
    fn default() -> Self {
        // symmetric around zero, wide enough to drown typical gradient
        // magnitudes (|g| ~ 1e-2 after local training)
        Self { p: -10.0, q: 20.0 }
    }
}

impl MaskRange {
    pub fn lo(&self) -> f32 {
        self.p
    }

    pub fn hi(&self) -> f32 {
        self.p + self.q
    }

    /// The paper's Eq. 4 filter threshold `σ = p + (k/x)·q`, where `k`
    /// is the mask keep-ratio and `x` the number of participants.
    pub fn sigma(&self, k: f64, x: usize) -> f32 {
        assert!(x > 0, "sigma with zero participants");
        self.p + ((k / x as f64) as f32) * self.q
    }
}

/// One client's view of the pairwise masking state.
#[derive(Clone)]
pub struct PairwiseMasker {
    pub id: u32,
    /// (peer id, DH shared secret bytes) for every *other* participant.
    peers: Vec<(u32, Vec<u8>)>,
    pub range: MaskRange,
    /// Optional shared stream cache (simulation-only optimization; the
    /// per-client communication/computation model is unchanged).
    cache: Option<MaskCache>,
}

impl std::fmt::Debug for PairwiseMasker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PairwiseMasker")
            .field("id", &self.id)
            .field("n_peers", &self.peers.len())
            .field("range", &self.range)
            .finish()
    }
}

impl PairwiseMasker {
    pub fn new(id: u32, peers: Vec<(u32, Vec<u8>)>, range: MaskRange) -> Self {
        assert!(
            peers.iter().all(|(pid, _)| *pid != id),
            "peer list contains self"
        );
        Self { id, peers, range, cache: None }
    }

    /// Attach a shared per-round stream cache.
    pub fn set_cache(&mut self, cache: MaskCache) {
        self.cache = Some(cache);
    }

    pub fn n_peers(&self) -> usize {
        self.peers.len()
    }

    /// Restrict to a subset of peers — the per-round participant set
    /// (masks only form among the round's selected clients; the DH
    /// pair keys are reused, matching §3.2's one-time key exchange).
    pub fn restrict(&self, keep: &[u32]) -> PairwiseMasker {
        PairwiseMasker {
            id: self.id,
            peers: self
                .peers
                .iter()
                .filter(|(pid, _)| keep.contains(pid))
                .cloned()
                .collect(),
            range: self.range,
            cache: self.cache.clone(),
        }
    }

    /// The raw uniform stream for one pair at one round: identical on
    /// both sides of the pair (keyed by normalized pair + round).
    pub fn raw_pair_mask(&self, peer: u32, round: u64, n: usize) -> Vec<f32> {
        let (_, secret) = self
            .peers
            .iter()
            .find(|(pid, _)| *pid == peer)
            .expect("unknown peer");
        let key = mask_seed(secret, self.id, peer, round);
        let mut prg = ChaCha20::from_seed(&key, round);
        let mut out = vec![0f32; n];
        prg.fill_uniform_f32(&mut out, self.range.lo(), self.range.hi());
        out
    }

    /// σ-filtered pair stream, cache-aware: generate the raw stream
    /// once per (pair, round) and keep only the entries below σ.
    fn filtered_pair_mask(&self, peer: u32, round: u64, n: usize, sigma: f32) -> Arc<FilteredStream> {
        let cache_key = {
            let (lo, hi) = if self.id < peer { (self.id, peer) } else { (peer, self.id) };
            (lo, hi, round)
        };
        if let Some(cache) = &self.cache {
            if let Some(hit) = cache.lock().unwrap().get(&cache_key) {
                if hit.n == n && hit.sigma == sigma {
                    return Arc::clone(hit);
                }
            }
        }
        let raw = self.raw_pair_mask(peer, round, n);
        let entries: Vec<(u32, f32)> = raw
            .iter()
            .enumerate()
            .filter(|(_, &v)| v < sigma)
            .map(|(i, &v)| (i as u32, v))
            .collect();
        let out = Arc::new(FilteredStream { sigma, n, entries });
        if let Some(cache) = &self.cache {
            cache.lock().unwrap().insert(cache_key, Arc::clone(&out));
        }
        out
    }

    /// Sign convention: +1 if this client has the smaller id of the
    /// pair (it adds), −1 otherwise (it subtracts).
    pub fn sign_for(&self, peer: u32) -> f32 {
        if self.id < peer {
            1.0
        } else {
            -1.0
        }
    }

    /// Dense combined mask `Σ_pairs sign · mask_r` (original secure
    /// aggregation, no sparsification).
    pub fn combined_mask(&self, round: u64, n: usize) -> Vec<f32> {
        let mut acc = vec![0f32; n];
        for (peer, _) in self.peers.clone() {
            let raw = self.raw_pair_mask(peer, round, n);
            let sign = self.sign_for(peer);
            for i in 0..n {
                acc[i] += sign * raw[i];
            }
        }
        acc
    }

    /// Sparse combined mask: the paper's zero-local-value rule
    /// (Alg. 2 line 14): keep `mask_r[j]` only when `mask_r[j] < σ`.
    /// Both sides of a pair keep the same positions (same stream), so
    /// cancellation is preserved. Returns the signed combined sparse
    /// mask; `nonzero[j]` is true where ANY pair kept a mask value
    /// (needed for the transmission mask `mask_t`).
    ///
    /// The accumulate sweep only touches the σ-kept entries of each
    /// pair stream (~k/x of n), via the shared [`FilteredStream`]
    /// cache when attached (§Perf L3 iteration 5).
    pub fn sparse_combined_mask(&self, round: u64, n: usize, sigma: f32) -> (Vec<f32>, Vec<bool>) {
        let mut acc = vec![0f32; n];
        let mut nonzero = vec![false; n];
        for (peer, _) in self.peers.clone() {
            let filtered = self.filtered_pair_mask(peer, round, n, sigma);
            let sign = self.sign_for(peer);
            for &(i, v) in &filtered.entries {
                acc[i as usize] += sign * v;
                nonzero[i as usize] = true;
            }
        }
        (acc, nonzero)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: u32) -> Vec<PairwiseMasker> {
        // all-pairs shared secrets derived deterministically for tests
        let secret = |a: u32, b: u32| -> Vec<u8> {
            let (lo, hi) = if a < b { (a, b) } else { (b, a) };
            format!("secret-{lo}-{hi}").into_bytes()
        };
        (0..n)
            .map(|id| {
                let peers = (0..n)
                    .filter(|&p| p != id)
                    .map(|p| (p, secret(id, p)))
                    .collect();
                PairwiseMasker::new(id, peers, MaskRange::default())
            })
            .collect()
    }

    #[test]
    fn pair_streams_symmetric() {
        let f = fleet(3);
        let m01 = f[0].raw_pair_mask(1, 7, 100);
        let m10 = f[1].raw_pair_mask(0, 7, 100);
        assert_eq!(m01, m10);
    }

    #[test]
    fn dense_masks_cancel_over_fleet() {
        let f = fleet(5);
        let n = 1000;
        let mut sum = vec![0f32; n];
        for c in &f {
            let m = c.combined_mask(3, n);
            for i in 0..n {
                sum[i] += m[i];
            }
        }
        for (i, &s) in sum.iter().enumerate() {
            assert!(s.abs() < 1e-3, "position {i} residue {s}");
        }
    }

    #[test]
    fn sparse_masks_cancel_over_fleet() {
        let f = fleet(4);
        let n = 2000;
        let sigma = f[0].range.sigma(1.0, 4); // keep 25% of mask entries
        let mut sum = vec![0f32; n];
        let mut any_nonzero = 0usize;
        for c in &f {
            let (m, nz) = c.sparse_combined_mask(9, n, sigma);
            any_nonzero += nz.iter().filter(|&&b| b).count();
            for i in 0..n {
                sum[i] += m[i];
            }
        }
        assert!(any_nonzero > 0, "sigma filtered everything");
        for (i, &s) in sum.iter().enumerate() {
            assert!(s.abs() < 1e-3, "position {i} residue {s}");
        }
    }

    #[test]
    fn sigma_controls_keep_fraction() {
        let f = fleet(2);
        let n = 50_000;
        let x = 10;
        for k in [0.5f64, 1.0, 3.0] {
            let sigma = f[0].range.sigma(k, x);
            let (_, nz) = f[0].sparse_combined_mask(1, n, sigma);
            let frac = nz.iter().filter(|&&b| b).count() as f64 / n as f64;
            let expect = k / x as f64;
            assert!(
                (frac - expect).abs() < 0.02,
                "k={k}: frac={frac:.3} expect={expect:.3}"
            );
        }
    }

    #[test]
    fn rounds_decorrelate_masks() {
        let f = fleet(2);
        let a = f[0].raw_pair_mask(1, 0, 64);
        let b = f[0].raw_pair_mask(1, 1, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn masks_within_declared_range() {
        let f = fleet(2);
        let m = f[0].raw_pair_mask(1, 2, 10_000);
        let r = f[0].range;
        assert!(m.iter().all(|&x| x >= r.lo() && x < r.hi()));
    }

    #[test]
    #[should_panic(expected = "peer list contains self")]
    fn self_peer_rejected() {
        PairwiseMasker::new(1, vec![(1, vec![0])], MaskRange::default());
    }
}
