//! HKDF-SHA256 (RFC 5869) — derives per-pair, per-round mask seeds from
//! a Diffie-Hellman shared secret.
//!
//! Seed layout: `HKDF(secret, salt="fedsparse-secagg", info=pair||round)`
//! so one DH exchange (run once per job, §3.2) yields an independent
//! ChaCha20 key for every round without re-keying.

use hmac::{Hmac, Mac};
use sha2::Sha256;

type HmacSha256 = Hmac<Sha256>;

/// RFC 5869 extract step.
pub fn hkdf_extract(salt: &[u8], ikm: &[u8]) -> [u8; 32] {
    let mut mac = <HmacSha256 as Mac>::new_from_slice(salt).expect("hmac key");
    mac.update(ikm);
    mac.finalize().into_bytes().into()
}

/// RFC 5869 expand step (okm up to 255*32 bytes; we only need 32).
pub fn hkdf_expand(prk: &[u8; 32], info: &[u8], okm: &mut [u8]) {
    assert!(okm.len() <= 255 * 32, "hkdf expand too long");
    let mut t: Vec<u8> = Vec::new();
    let mut done = 0usize;
    let mut counter = 1u8;
    while done < okm.len() {
        let mut mac = <HmacSha256 as Mac>::new_from_slice(prk).expect("hmac key");
        mac.update(&t);
        mac.update(info);
        mac.update(&[counter]);
        t = mac.finalize().into_bytes().to_vec();
        let take = (okm.len() - done).min(32);
        okm[done..done + take].copy_from_slice(&t[..take]);
        done += take;
        counter += 1;
    }
}

/// Full HKDF: 32-byte output key.
pub fn hkdf32(salt: &[u8], ikm: &[u8], info: &[u8]) -> [u8; 32] {
    let prk = hkdf_extract(salt, ikm);
    let mut okm = [0u8; 32];
    hkdf_expand(&prk, info, &mut okm);
    okm
}

const SALT: &[u8] = b"fedsparse-secagg";

/// ChaCha20 key for the (u,v) pair at `round`. Pair order is
/// normalized so both sides derive the same key.
pub fn mask_seed(shared_secret: &[u8], u: u32, v: u32, round: u64) -> [u8; 32] {
    let (lo, hi) = if u <= v { (u, v) } else { (v, u) };
    let mut info = Vec::with_capacity(20);
    info.extend_from_slice(b"mask");
    info.extend_from_slice(&lo.to_le_bytes());
    info.extend_from_slice(&hi.to_le_bytes());
    info.extend_from_slice(&round.to_le_bytes());
    hkdf32(SALT, shared_secret, &info)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// RFC 5869 Test Case 1 (A.1).
    #[test]
    fn rfc5869_case1() {
        let ikm = [0x0b; 22];
        let salt: Vec<u8> = (0x00..=0x0c).collect();
        let info: Vec<u8> = (0xf0..=0xf9).collect();
        let prk = hkdf_extract(&salt, &ikm);
        let expected_prk = [
            0x07, 0x77, 0x09, 0x36, 0x2c, 0x2e, 0x32, 0xdf, 0x0d, 0xdc, 0x3f, 0x0d, 0xc4, 0x7b,
            0xba, 0x63, 0x90, 0xb6, 0xc7, 0x3b, 0xb5, 0x0f, 0x9c, 0x31, 0x22, 0xec, 0x84, 0x4a,
            0xd7, 0xc2, 0xb3, 0xe5,
        ];
        assert_eq!(prk, expected_prk);
        let mut okm = [0u8; 42];
        hkdf_expand(&prk, &info, &mut okm);
        let expected_okm = [
            0x3c, 0xb2, 0x5f, 0x25, 0xfa, 0xac, 0xd5, 0x7a, 0x90, 0x43, 0x4f, 0x64, 0xd0, 0x36,
            0x2f, 0x2a, 0x2d, 0x2d, 0x0a, 0x90, 0xcf, 0x1a, 0x5a, 0x4c, 0x5d, 0xb0, 0x2d, 0x56,
            0xec, 0xc4, 0xc5, 0xbf, 0x34, 0x00, 0x72, 0x08, 0xd5, 0xb8, 0x87, 0x18, 0x58, 0x65,
        ];
        assert_eq!(okm, expected_okm);
    }

    #[test]
    fn mask_seed_symmetric_in_pair() {
        let secret = b"shared";
        assert_eq!(mask_seed(secret, 3, 7, 5), mask_seed(secret, 7, 3, 5));
    }

    #[test]
    fn mask_seed_varies_with_round_and_pair() {
        let secret = b"shared";
        let s1 = mask_seed(secret, 1, 2, 0);
        assert_ne!(s1, mask_seed(secret, 1, 2, 1));
        assert_ne!(s1, mask_seed(secret, 1, 3, 0));
        assert_ne!(s1, mask_seed(b"other", 1, 2, 0));
    }
}
