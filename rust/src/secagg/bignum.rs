//! Big unsigned integers for Diffie-Hellman — schoolbook limbs with
//! modular exponentiation. Sized for 1536/2048-bit MODP groups; built
//! in-repo because the offline vendor set has no bignum crate
//! (DESIGN.md S8).
//!
//! Not constant-time. That is acceptable here: the DH exchange runs
//! between *simulated* federated clients inside one process; the
//! security analysis the paper makes (§4) is about what the
//! *aggregation server* learns from masked updates, not about
//! side-channels on the key exchange.

use std::cmp::Ordering;

/// Arbitrary-precision unsigned integer, little-endian u64 limbs.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BigUint {
    /// Little-endian limbs; no trailing zero limbs (canonical form).
    limbs: Vec<u64>,
}

impl BigUint {
    pub fn zero() -> Self {
        Self { limbs: vec![] }
    }

    pub fn one() -> Self {
        Self { limbs: vec![1] }
    }

    pub fn from_u64(v: u64) -> Self {
        if v == 0 {
            Self::zero()
        } else {
            Self { limbs: vec![v] }
        }
    }

    /// Parse big-endian hex (whitespace tolerated — RFC constants).
    pub fn from_hex(s: &str) -> Result<Self, String> {
        let clean: String = s.chars().filter(|c| !c.is_whitespace()).collect();
        if clean.is_empty() {
            return Err("empty hex".into());
        }
        let mut limbs = Vec::new();
        let bytes = clean.as_bytes();
        let mut pos = bytes.len();
        while pos > 0 {
            let start = pos.saturating_sub(16);
            let chunk = std::str::from_utf8(&bytes[start..pos]).unwrap();
            let limb = u64::from_str_radix(chunk, 16).map_err(|e| e.to_string())?;
            limbs.push(limb);
            pos = start;
        }
        let mut out = Self { limbs };
        out.normalize();
        Ok(out)
    }

    /// Big-endian bytes (no leading zeros, empty for zero).
    pub fn to_bytes_be(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(self.limbs.len() * 8);
        for limb in self.limbs.iter().rev() {
            out.extend_from_slice(&limb.to_be_bytes());
        }
        while out.first() == Some(&0) {
            out.remove(0);
        }
        out
    }

    /// Interpret big-endian bytes.
    pub fn from_bytes_be(bytes: &[u8]) -> Self {
        let mut limbs = Vec::new();
        let mut pos = bytes.len();
        while pos > 0 {
            let start = pos.saturating_sub(8);
            let mut limb = 0u64;
            for &b in &bytes[start..pos] {
                limb = (limb << 8) | b as u64;
            }
            limbs.push(limb);
            pos = start;
        }
        let mut out = Self { limbs };
        out.normalize();
        out
    }

    fn normalize(&mut self) {
        while self.limbs.last() == Some(&0) {
            self.limbs.pop();
        }
    }

    pub fn is_zero(&self) -> bool {
        self.limbs.is_empty()
    }

    pub fn bit_len(&self) -> usize {
        match self.limbs.last() {
            None => 0,
            Some(&top) => (self.limbs.len() - 1) * 64 + (64 - top.leading_zeros() as usize),
        }
    }

    pub fn bit(&self, i: usize) -> bool {
        let (limb, off) = (i / 64, i % 64);
        self.limbs.get(limb).map_or(false, |&l| (l >> off) & 1 == 1)
    }

    pub fn cmp_big(&self, other: &Self) -> Ordering {
        if self.limbs.len() != other.limbs.len() {
            return self.limbs.len().cmp(&other.limbs.len());
        }
        for (a, b) in self.limbs.iter().rev().zip(other.limbs.iter().rev()) {
            match a.cmp(b) {
                Ordering::Equal => continue,
                ord => return ord,
            }
        }
        Ordering::Equal
    }

    pub fn add(&self, other: &Self) -> Self {
        let n = self.limbs.len().max(other.limbs.len());
        let mut out = Vec::with_capacity(n + 1);
        let mut carry = 0u64;
        for i in 0..n {
            let a = self.limbs.get(i).copied().unwrap_or(0);
            let b = other.limbs.get(i).copied().unwrap_or(0);
            let (s1, c1) = a.overflowing_add(b);
            let (s2, c2) = s1.overflowing_add(carry);
            out.push(s2);
            carry = (c1 as u64) + (c2 as u64);
        }
        out.push(carry);
        let mut r = Self { limbs: out };
        r.normalize();
        r
    }

    /// `self - other`; panics if other > self.
    pub fn sub(&self, other: &Self) -> Self {
        assert!(self.cmp_big(other) != Ordering::Less, "bignum underflow");
        let mut out = Vec::with_capacity(self.limbs.len());
        let mut borrow = 0u64;
        for i in 0..self.limbs.len() {
            let a = self.limbs[i];
            let b = other.limbs.get(i).copied().unwrap_or(0);
            let (d1, b1) = a.overflowing_sub(b);
            let (d2, b2) = d1.overflowing_sub(borrow);
            out.push(d2);
            borrow = (b1 as u64) + (b2 as u64);
        }
        let mut r = Self { limbs: out };
        r.normalize();
        r
    }

    /// Schoolbook multiply.
    pub fn mul(&self, other: &Self) -> Self {
        if self.is_zero() || other.is_zero() {
            return Self::zero();
        }
        let mut out = vec![0u64; self.limbs.len() + other.limbs.len()];
        for (i, &a) in self.limbs.iter().enumerate() {
            let mut carry = 0u128;
            for (j, &b) in other.limbs.iter().enumerate() {
                let cur = out[i + j] as u128 + (a as u128) * (b as u128) + carry;
                out[i + j] = cur as u64;
                carry = cur >> 64;
            }
            let mut k = i + other.limbs.len();
            while carry > 0 {
                let cur = out[k] as u128 + carry;
                out[k] = cur as u64;
                carry = cur >> 64;
                k += 1;
            }
        }
        let mut r = Self { limbs: out };
        r.normalize();
        r
    }

    fn shl_bits(&self, bits: usize) -> Self {
        if self.is_zero() {
            return Self::zero();
        }
        let (limb_shift, bit_shift) = (bits / 64, bits % 64);
        let mut out = vec![0u64; self.limbs.len() + limb_shift + 1];
        for (i, &l) in self.limbs.iter().enumerate() {
            out[i + limb_shift] |= if bit_shift == 0 { l } else { l << bit_shift };
            if bit_shift > 0 {
                out[i + limb_shift + 1] |= l >> (64 - bit_shift);
            }
        }
        let mut r = Self { limbs: out };
        r.normalize();
        r
    }

    /// `self mod m` by binary long division (shift-subtract).
    pub fn rem(&self, m: &Self) -> Self {
        assert!(!m.is_zero(), "mod by zero");
        if self.cmp_big(m) == Ordering::Less {
            return self.clone();
        }
        let mut r = Self::zero();
        for i in (0..self.bit_len()).rev() {
            r = r.shl_bits(1);
            if self.bit(i) {
                r = r.add(&Self::one());
            }
            if r.cmp_big(m) != Ordering::Less {
                r = r.sub(m);
            }
        }
        r
    }

    /// `self * other mod m`.
    pub fn mulmod(&self, other: &Self, m: &Self) -> Self {
        self.mul(other).rem(m)
    }

    /// `self ^ exp mod m` — left-to-right square-and-multiply.
    pub fn modpow(&self, exp: &Self, m: &Self) -> Self {
        assert!(!m.is_zero(), "mod by zero");
        if m == &Self::one() {
            return Self::zero();
        }
        let base = self.rem(m);
        let mut acc = Self::one();
        for i in (0..exp.bit_len()).rev() {
            acc = acc.mulmod(&acc, m);
            if exp.bit(i) {
                acc = acc.mulmod(&base, m);
            }
        }
        acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn n(v: u64) -> BigUint {
        BigUint::from_u64(v)
    }

    #[test]
    fn hex_roundtrip() {
        let x = BigUint::from_hex("FFFFFFFFFFFFFFFFC90FDAA22168C234").unwrap();
        assert_eq!(x.bit_len(), 128);
        let bytes = x.to_bytes_be();
        assert_eq!(BigUint::from_bytes_be(&bytes), x);
    }

    #[test]
    fn add_sub_roundtrip() {
        let a = BigUint::from_hex("123456789ABCDEF0123456789ABCDEF0").unwrap();
        let b = BigUint::from_hex("FEDCBA9876543210").unwrap();
        assert_eq!(a.add(&b).sub(&b), a);
    }

    #[test]
    fn add_carries_across_limbs() {
        let a = BigUint::from_hex("FFFFFFFFFFFFFFFF").unwrap();
        let s = a.add(&BigUint::one());
        assert_eq!(s, BigUint::from_hex("10000000000000000").unwrap());
    }

    #[test]
    fn mul_small_matches_u128() {
        for (a, b) in [(3u64, 5u64), (u64::MAX, 2), (12345, 67890), (u64::MAX, u64::MAX)] {
            let big = n(a).mul(&n(b));
            let expect = (a as u128) * (b as u128);
            let lo = expect as u64;
            let hi = (expect >> 64) as u64;
            let want = if hi == 0 {
                n(lo)
            } else {
                BigUint { limbs: vec![lo, hi] }
            };
            assert_eq!(big, want, "{a} * {b}");
        }
    }

    #[test]
    fn rem_matches_u128() {
        let a = BigUint::from_hex("123456789ABCDEF0FEDCBA9876543210").unwrap();
        let m = n(1_000_000_007);
        let got = a.rem(&m);
        let a128 = 0x1234_5678_9ABC_DEF0_FEDC_BA98_7654_3210u128;
        assert_eq!(got, n((a128 % 1_000_000_007u128) as u64));
    }

    #[test]
    fn modpow_small_cases() {
        // 3^10 mod 1000 = 59049 mod 1000 = 49
        assert_eq!(n(3).modpow(&n(10), &n(1000)), n(49));
        // Fermat: 2^(p-1) mod p = 1 for prime p
        let p = n(1_000_000_007);
        assert_eq!(n(2).modpow(&n(1_000_000_006), &p), BigUint::one());
        // x^0 = 1
        assert_eq!(n(42).modpow(&BigUint::zero(), &p), BigUint::one());
        // mod 1 → 0
        assert_eq!(n(42).modpow(&n(3), &BigUint::one()), BigUint::zero());
    }

    #[test]
    fn modpow_matches_naive_on_random() {
        use crate::util::rng::Rng;
        let mut rng = Rng::new(8);
        let m = n(0xFFFF_FFFB); // prime 2^32-5
        for _ in 0..20 {
            let base = n(rng.next_u64() % 0xFFFF_FFFB);
            let e = rng.next_u64() % 1000;
            let mut want = 1u128;
            for _ in 0..e {
                want = want * (base.limbs.first().copied().unwrap_or(0) as u128) % 0xFFFF_FFFBu128;
            }
            assert_eq!(base.modpow(&n(e), &m), n(want as u64));
        }
    }

    #[test]
    fn dh_commutativity_toy_group() {
        // g^a^b == g^b^a mod p for toy p
        let p = n(0xFFFF_FFFB);
        let g = n(5);
        let a = n(123_456_789);
        let b = n(987_654_321);
        let ga = g.modpow(&a, &p);
        let gb = g.modpow(&b, &p);
        assert_eq!(ga.modpow(&b, &p), gb.modpow(&a, &p));
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn sub_underflow_panics() {
        n(1).sub(&n(2));
    }

    #[test]
    fn zero_canonical() {
        let z = n(5).sub(&n(5));
        assert!(z.is_zero());
        assert_eq!(z.bit_len(), 0);
        assert_eq!(z.to_bytes_be(), Vec::<u8>::new());
    }
}
