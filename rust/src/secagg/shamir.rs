//! Shamir secret sharing over GF(p), p = 2^61 − 1 (Mersenne prime).
//!
//! Substrate for the Bonawitz'17 dropout-recovery path (DESIGN.md S11):
//! each client secret-shares its per-pair seed material so the server
//! can reconstruct the masks of clients that drop mid-round from any
//! `threshold` surviving shares. The paper's protocol assumes no
//! dropout; we implement the recovery path as the documented extension
//! and exercise it in `rust/tests/secagg_e2e.rs`.

/// Field modulus 2^61 − 1 (prime).
pub const P: u64 = (1u64 << 61) - 1;

#[inline]
fn add(a: u64, b: u64) -> u64 {
    let s = a + b; // < 2^62, no overflow
    if s >= P {
        s - P
    } else {
        s
    }
}

#[inline]
fn sub(a: u64, b: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + P - b
    }
}

#[inline]
fn mul(a: u64, b: u64) -> u64 {
    ((a as u128 * b as u128) % P as u128) as u64
}

/// Modular inverse via Fermat (p prime).
fn inv(a: u64) -> u64 {
    assert!(a % P != 0, "inverse of zero");
    pow(a, P - 2)
}

fn pow(mut base: u64, mut e: u64) -> u64 {
    base %= P;
    let mut acc = 1u64;
    while e > 0 {
        if e & 1 == 1 {
            acc = mul(acc, base);
        }
        base = mul(base, base);
        e >>= 1;
    }
    acc
}

/// One share: the polynomial evaluated at x (x ≠ 0).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Share {
    pub x: u64,
    pub y: u64,
}

/// Split `secret` (< P) into `n` shares with reconstruction
/// threshold `t` (any t shares suffice; t−1 reveal nothing).
pub fn split(secret: u64, n: usize, t: usize, rng: &mut crate::util::rng::Rng) -> Vec<Share> {
    assert!(secret < P, "secret out of field");
    assert!(t >= 1 && t <= n, "bad threshold t={t} n={n}");
    // random polynomial of degree t-1 with a_0 = secret
    let coeffs: Vec<u64> = std::iter::once(secret)
        .chain((1..t).map(|_| rng.below(P)))
        .collect();
    (1..=n as u64)
        .map(|x| {
            // Horner evaluation
            let mut y = 0u64;
            for &c in coeffs.iter().rev() {
                y = add(mul(y, x), c);
            }
            Share { x, y }
        })
        .collect()
}

/// Split `secret` (< P) into shares at the explicit evaluation points
/// `xs` (distinct, nonzero mod P) with threshold `t` — the per-round
/// re-keying path ([`crate::secagg::rekey`]), where the share holders
/// are a round's neighbor ids rather than `1..=n`. A fresh
/// degree-(t−1) polynomial is drawn from `rng` on every call, so
/// re-sharing the same secret at new points never reuses a polynomial.
pub fn split_at(secret: u64, xs: &[u64], t: usize, rng: &mut crate::util::rng::Rng) -> Vec<Share> {
    assert!(secret < P, "secret out of field");
    assert!(t >= 1 && t <= xs.len(), "bad threshold t={t} n={}", xs.len());
    let coeffs: Vec<u64> = std::iter::once(secret)
        .chain((1..t).map(|_| rng.below(P)))
        .collect();
    xs.iter()
        .map(|&x| {
            assert!(x % P != 0, "evaluation point must be nonzero");
            let mut y = 0u64;
            for &c in coeffs.iter().rev() {
                y = add(mul(y, x % P), c);
            }
            Share { x, y }
        })
        .collect()
}

/// Share an even-length byte string limb-wise at explicit evaluation
/// points: 16-bit LE limbs (trivially < P), each split independently
/// with its own polynomial. Outer Vec is per-limb, inner per point —
/// [`split_seed`]'s shape generalized to arbitrary widths and holder
/// sets (re-keying shares DH exponents, whose width depends on the
/// group's `priv_bits`).
pub fn split_bytes_at(
    secret: &[u8],
    xs: &[u64],
    t: usize,
    rng: &mut crate::util::rng::Rng,
) -> Vec<Vec<Share>> {
    assert!(secret.len() % 2 == 0, "secret must be an even number of bytes");
    (0..secret.len() / 2)
        .map(|i| {
            let limb = u16::from_le_bytes([secret[2 * i], secret[2 * i + 1]]) as u64;
            split_at(limb, xs, t, rng)
        })
        .collect()
}

/// Reconstruct the byte string shared by [`split_bytes_at`] (≥ t
/// shares per limb, same holder subset across limbs).
pub fn reconstruct_bytes(limbs: &[Vec<Share>]) -> Vec<u8> {
    let mut out = Vec::with_capacity(limbs.len() * 2);
    for shares in limbs {
        let v = reconstruct(shares) as u16;
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Reconstruct the secret from ≥ t shares (Lagrange at x=0).
/// Shares must have distinct x; extra shares beyond t are fine.
pub fn reconstruct(shares: &[Share]) -> u64 {
    assert!(!shares.is_empty(), "no shares");
    let mut secret = 0u64;
    for (i, si) in shares.iter().enumerate() {
        // L_i(0) = Π_{j≠i} x_j / (x_j − x_i)
        let mut num = 1u64;
        let mut den = 1u64;
        for (j, sj) in shares.iter().enumerate() {
            if i == j {
                continue;
            }
            assert_ne!(si.x, sj.x, "duplicate share x");
            num = mul(num, sj.x % P);
            den = mul(den, sub(sj.x % P, si.x % P));
        }
        secret = add(secret, mul(si.y, mul(num, inv(den))));
    }
    secret
}

/// Split a 32-byte seed into shares (chunked into 4 field elements of
/// ≤61 bits each plus remainder handling via 16-bit limbs for
/// simplicity: 16 × 16-bit limbs, each shared independently).
pub fn split_seed(seed: &[u8; 32], n: usize, t: usize, rng: &mut crate::util::rng::Rng) -> Vec<Vec<Share>> {
    // 16-bit limbs guarantee < P trivially
    (0..16)
        .map(|i| {
            let limb = u16::from_le_bytes([seed[2 * i], seed[2 * i + 1]]) as u64;
            split(limb, n, t, rng)
        })
        .collect()
}

/// Reconstruct a 32-byte seed from per-limb share sets.
pub fn reconstruct_seed(limbs: &[Vec<Share>]) -> [u8; 32] {
    assert_eq!(limbs.len(), 16, "expect 16 limbs");
    let mut out = [0u8; 32];
    for (i, shares) in limbs.iter().enumerate() {
        let v = reconstruct(shares) as u16;
        out[2 * i..2 * i + 2].copy_from_slice(&v.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_exact_threshold() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let secret = rng.below(P);
            let shares = split(secret, 5, 3, &mut rng);
            assert_eq!(reconstruct(&shares[..3]), secret);
            assert_eq!(reconstruct(&shares[1..4]), secret);
            assert_eq!(reconstruct(&shares), secret); // extras fine
        }
    }

    #[test]
    fn below_threshold_is_not_secret() {
        // t-1 shares interpolate to a (almost surely) different value
        let mut rng = Rng::new(2);
        let secret = 123_456_789u64;
        let shares = split(secret, 5, 3, &mut rng);
        let wrong = reconstruct(&shares[..2]);
        assert_ne!(wrong, secret);
    }

    #[test]
    fn single_share_threshold_one() {
        let mut rng = Rng::new(3);
        let shares = split(42, 4, 1, &mut rng);
        // t=1: constant polynomial; every share IS the secret
        for s in &shares {
            assert_eq!(reconstruct(&[*s]), 42);
        }
    }

    #[test]
    fn seed_roundtrip() {
        let mut rng = Rng::new(4);
        let mut seed = [0u8; 32];
        for (i, b) in seed.iter_mut().enumerate() {
            *b = (i * 7 + 3) as u8;
        }
        let shares = split_seed(&seed, 6, 4, &mut rng);
        let subset: Vec<Vec<Share>> = shares.iter().map(|l| l[1..5].to_vec()).collect();
        assert_eq!(reconstruct_seed(&subset), seed);
    }

    #[test]
    fn split_at_roundtrips_at_sparse_points() {
        // neighbor-id-shaped evaluation points: sparse, unordered gaps
        let mut rng = Rng::new(6);
        let xs = [3u64, 17, 4, 901, 12];
        for _ in 0..20 {
            let secret = rng.below(P);
            let shares = split_at(secret, &xs, 3, &mut rng);
            assert_eq!(shares.len(), xs.len());
            for (s, &x) in shares.iter().zip(&xs) {
                assert_eq!(s.x, x);
            }
            assert_eq!(reconstruct(&shares[..3]), secret);
            assert_eq!(reconstruct(&shares[2..]), secret);
        }
    }

    #[test]
    fn split_at_matches_split_on_contiguous_points() {
        // same polynomial (same rng stream) evaluated at 1..=n must
        // give exactly split()'s shares
        let xs: Vec<u64> = (1..=5).collect();
        let a = split(777, 5, 3, &mut Rng::new(7));
        let b = split_at(777, &xs, 3, &mut Rng::new(7));
        assert_eq!(a, b);
    }

    #[test]
    fn bytes_roundtrip_at_explicit_points() {
        let mut rng = Rng::new(8);
        let secret: Vec<u8> = (0..8).map(|i| (i * 31 + 5) as u8).collect();
        let xs = [9u64, 2, 40, 11];
        let limbs = split_bytes_at(&secret, &xs, 2, &mut rng);
        assert_eq!(limbs.len(), 4);
        let subset: Vec<Vec<Share>> = limbs.iter().map(|l| l[1..3].to_vec()).collect();
        assert_eq!(reconstruct_bytes(&subset), secret);
    }

    #[test]
    #[should_panic(expected = "even number of bytes")]
    fn odd_byte_widths_rejected() {
        split_bytes_at(&[1, 2, 3], &[1, 2], 2, &mut Rng::new(9));
    }

    #[test]
    #[should_panic(expected = "evaluation point must be nonzero")]
    fn zero_evaluation_point_rejected() {
        split_at(1, &[0], 1, &mut Rng::new(10));
    }

    #[test]
    fn field_ops_sane() {
        assert_eq!(add(P - 1, 2), 1);
        assert_eq!(sub(1, 2), P - 1);
        assert_eq!(mul(P - 1, P - 1), 1); // (-1)^2
        assert_eq!(mul(inv(7), 7), 1);
        assert_eq!(pow(2, 61), 1); // 2^61 ≡ 1 (mod 2^61 − 1)
    }

    #[test]
    #[should_panic(expected = "bad threshold")]
    fn threshold_above_n_rejected() {
        split(1, 3, 4, &mut Rng::new(5));
    }

    #[test]
    #[should_panic(expected = "duplicate share x")]
    fn duplicate_shares_rejected() {
        let s = Share { x: 1, y: 2 };
        reconstruct(&[s, s]);
    }
}
