//! Shamir secret sharing over GF(p), p = 2^61 − 1 (Mersenne prime).
//!
//! Substrate for the Bonawitz'17 dropout-recovery path (DESIGN.md S11):
//! each client secret-shares its per-pair seed material so the server
//! can reconstruct the masks of clients that drop mid-round from any
//! `threshold` surviving shares. The paper's protocol assumes no
//! dropout; we implement the recovery path as the documented extension
//! and exercise it in `rust/tests/secagg_e2e.rs`.

/// Field modulus 2^61 − 1 (prime).
pub const P: u64 = (1u64 << 61) - 1;

#[inline]
fn add(a: u64, b: u64) -> u64 {
    let s = a + b; // < 2^62, no overflow
    if s >= P {
        s - P
    } else {
        s
    }
}

#[inline]
fn sub(a: u64, b: u64) -> u64 {
    if a >= b {
        a - b
    } else {
        a + P - b
    }
}

#[inline]
fn mul(a: u64, b: u64) -> u64 {
    ((a as u128 * b as u128) % P as u128) as u64
}

/// Modular inverse via Fermat (p prime).
fn inv(a: u64) -> u64 {
    assert!(a % P != 0, "inverse of zero");
    pow(a, P - 2)
}

fn pow(mut base: u64, mut e: u64) -> u64 {
    base %= P;
    let mut acc = 1u64;
    while e > 0 {
        if e & 1 == 1 {
            acc = mul(acc, base);
        }
        base = mul(base, base);
        e >>= 1;
    }
    acc
}

/// One share: the polynomial evaluated at x (x ≠ 0).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Share {
    pub x: u64,
    pub y: u64,
}

/// Split `secret` (< P) into `n` shares with reconstruction
/// threshold `t` (any t shares suffice; t−1 reveal nothing).
pub fn split(secret: u64, n: usize, t: usize, rng: &mut crate::util::rng::Rng) -> Vec<Share> {
    assert!(secret < P, "secret out of field");
    assert!(t >= 1 && t <= n, "bad threshold t={t} n={n}");
    // random polynomial of degree t-1 with a_0 = secret
    let coeffs: Vec<u64> = std::iter::once(secret)
        .chain((1..t).map(|_| rng.below(P)))
        .collect();
    (1..=n as u64)
        .map(|x| {
            // Horner evaluation
            let mut y = 0u64;
            for &c in coeffs.iter().rev() {
                y = add(mul(y, x), c);
            }
            Share { x, y }
        })
        .collect()
}

/// Reconstruct the secret from ≥ t shares (Lagrange at x=0).
/// Shares must have distinct x; extra shares beyond t are fine.
pub fn reconstruct(shares: &[Share]) -> u64 {
    assert!(!shares.is_empty(), "no shares");
    let mut secret = 0u64;
    for (i, si) in shares.iter().enumerate() {
        // L_i(0) = Π_{j≠i} x_j / (x_j − x_i)
        let mut num = 1u64;
        let mut den = 1u64;
        for (j, sj) in shares.iter().enumerate() {
            if i == j {
                continue;
            }
            assert_ne!(si.x, sj.x, "duplicate share x");
            num = mul(num, sj.x % P);
            den = mul(den, sub(sj.x % P, si.x % P));
        }
        secret = add(secret, mul(si.y, mul(num, inv(den))));
    }
    secret
}

/// Split a 32-byte seed into shares (chunked into 4 field elements of
/// ≤61 bits each plus remainder handling via 16-bit limbs for
/// simplicity: 16 × 16-bit limbs, each shared independently).
pub fn split_seed(seed: &[u8; 32], n: usize, t: usize, rng: &mut crate::util::rng::Rng) -> Vec<Vec<Share>> {
    // 16-bit limbs guarantee < P trivially
    (0..16)
        .map(|i| {
            let limb = u16::from_le_bytes([seed[2 * i], seed[2 * i + 1]]) as u64;
            split(limb, n, t, rng)
        })
        .collect()
}

/// Reconstruct a 32-byte seed from per-limb share sets.
pub fn reconstruct_seed(limbs: &[Vec<Share>]) -> [u8; 32] {
    assert_eq!(limbs.len(), 16, "expect 16 limbs");
    let mut out = [0u8; 32];
    for (i, shares) in limbs.iter().enumerate() {
        let v = reconstruct(shares) as u16;
        out[2 * i..2 * i + 2].copy_from_slice(&v.to_le_bytes());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_exact_threshold() {
        let mut rng = Rng::new(1);
        for _ in 0..20 {
            let secret = rng.below(P);
            let shares = split(secret, 5, 3, &mut rng);
            assert_eq!(reconstruct(&shares[..3]), secret);
            assert_eq!(reconstruct(&shares[1..4]), secret);
            assert_eq!(reconstruct(&shares), secret); // extras fine
        }
    }

    #[test]
    fn below_threshold_is_not_secret() {
        // t-1 shares interpolate to a (almost surely) different value
        let mut rng = Rng::new(2);
        let secret = 123_456_789u64;
        let shares = split(secret, 5, 3, &mut rng);
        let wrong = reconstruct(&shares[..2]);
        assert_ne!(wrong, secret);
    }

    #[test]
    fn single_share_threshold_one() {
        let mut rng = Rng::new(3);
        let shares = split(42, 4, 1, &mut rng);
        // t=1: constant polynomial; every share IS the secret
        for s in &shares {
            assert_eq!(reconstruct(&[*s]), 42);
        }
    }

    #[test]
    fn seed_roundtrip() {
        let mut rng = Rng::new(4);
        let mut seed = [0u8; 32];
        for (i, b) in seed.iter_mut().enumerate() {
            *b = (i * 7 + 3) as u8;
        }
        let shares = split_seed(&seed, 6, 4, &mut rng);
        let subset: Vec<Vec<Share>> = shares.iter().map(|l| l[1..5].to_vec()).collect();
        assert_eq!(reconstruct_seed(&subset), seed);
    }

    #[test]
    fn field_ops_sane() {
        assert_eq!(add(P - 1, 2), 1);
        assert_eq!(sub(1, 2), P - 1);
        assert_eq!(mul(P - 1, P - 1), 1); // (-1)^2
        assert_eq!(mul(inv(7), 7), 1);
        assert_eq!(pow(2, 61), 1); // 2^61 ≡ 1 (mod 2^61 − 1)
    }

    #[test]
    #[should_panic(expected = "bad threshold")]
    fn threshold_above_n_rejected() {
        split(1, 3, 4, &mut Rng::new(5));
    }

    #[test]
    #[should_panic(expected = "duplicate share x")]
    fn duplicate_shares_rejected() {
        let s = Share { x: 1, y: 2 };
        reconstruct(&[s, s]);
    }
}
