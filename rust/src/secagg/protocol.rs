//! The secure-aggregation round protocol: DH setup → pairwise masks →
//! mask-sparsified updates → server aggregation, with Shamir-based
//! dropout recovery (Bonawitz'17 path) as the documented extension.
//!
//! All participants live in one process (the paper simulates too), but
//! the information flow is strictly message-shaped: clients only ever
//! hand the server *payloads* ([`crate::sparse::codec::SparseVec`]) and
//! *shares*; the server never touches a client's raw update or masker.

use std::collections::HashMap;
use std::sync::Arc;

use crate::sparse::codec::SparseVec;
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;

use super::bignum::BigUint;
use super::dh::{DhKeyPair, DhParams};
use super::mask::{filtered_stream_for_pair, MaskCache, MaskRange, PairwiseMasker};
use super::neighborhood::Neighborhood;
use super::shamir::{self, Share};
use super::sparse_mask::{
    mask_sparsify, mask_sparsify_into, mask_sparsify_pooled_into, MaskScratch, MaskSparsifyConfig,
    MaskedUpdate,
};

/// Protocol configuration.
#[derive(Clone, Debug)]
pub struct SecAggConfig {
    /// Use the RFC 3526 1536-bit group (false → toy group for tests).
    pub full_dh: bool,
    pub range: MaskRange,
    /// Eq. 4 mask keep-ratio numerator `k`.
    pub mask_ratio_k: f64,
    /// Shamir reconstruction threshold for dropout recovery.
    pub share_threshold: usize,
    /// Distribute Shamir shares of every pair key at setup — the
    /// original one-off all-pairs walk (O(n³) share material), kept
    /// byte-identical for `neighbors_k = 0` runs. k-regular runs turn
    /// this off and use per-round neighborhood-local re-keying instead
    /// ([`crate::secagg::rekey`]: O(n·k) shares per round, secrets
    /// only at current neighbors).
    pub share_keys: bool,
}

impl Default for SecAggConfig {
    fn default() -> Self {
        Self {
            full_dh: false,
            range: MaskRange::default(),
            mask_ratio_k: 1.0,
            share_threshold: 2,
            share_keys: true,
        }
    }
}

/// Pair key = 32-byte symmetric seed both ends derive from the DH
/// shared secret; what gets Shamir-shared for dropout recovery
/// (crate-visible: the re-keying recovery path derives the same bytes
/// from a reconstructed exponent).
pub(crate) fn pair_key(shared_secret: &[u8]) -> [u8; 32] {
    super::kdf::hkdf32(b"fedsparse-pairkey", shared_secret, b"")
}

/// Fixed Shamir width (bytes) for a group's private exponents: the
/// high-bit force in [`DhKeyPair::generate`] can carry into bit
/// `priv_bits`, so cover `priv_bits + 1` bits, rounded up to whole
/// 16-bit limbs (toy 48-bit group → 8 bytes, RFC 3526 → 34).
pub(crate) fn exponent_share_width(params: &DhParams) -> usize {
    let w = (params.priv_bits + 1).div_ceil(8);
    w + (w & 1)
}

/// One federated participant's secagg state.
///
/// Pair keys are derived **lazily**: the client holds only its own DH
/// keypair plus the fleet's public keys (`Arc`-shared), and derives
/// the symmetric pair key for a peer on demand. Setup is therefore
/// O(n) in the fleet size, and a round under a k-regular
/// [`Neighborhood`] derives exactly the k keys it masks with — the
/// eager all-pairs key table the complete-graph design materialized is
/// gone (derivation is deterministic, so lazy ≡ eager key-for-key).
pub struct SecAggClient {
    pub id: u32,
    params: Arc<DhParams>,
    keypair: DhKeyPair,
    /// Every participant's DH public key (index = client id).
    publics: Arc<Vec<BigUint>>,
    range: MaskRange,
    cache: Option<MaskCache>,
    /// Shares this client holds of (owner, peer) pair keys.
    held_shares: HashMap<(u32, u32), Vec<Share>>,
    /// Eq. 4 mask keep-ratio numerator `k` (from [`SecAggConfig`]).
    mask_ratio_k: f64,
}

impl SecAggClient {
    /// Derive the symmetric pair key shared with `peer` (both ends
    /// compute the same value from the DH agreement).
    ///
    /// In a real deployment this secret never leaves the two
    /// endpoints; it is `pub` here because the simulation's benches and
    /// property tests play both sides of the wire.
    pub fn pair_key_with(&self, peer: u32) -> [u8; 32] {
        assert_ne!(peer, self.id, "no pair key with self");
        let secret =
            self.keypair.shared_secret(&self.params, &self.publics[peer as usize]);
        pair_key(&secret)
    }

    /// Build this round's masked sparse update (all-peers graph).
    pub fn build_update(
        &self,
        g: &[f32],
        grad_keep: &[bool],
        round: u64,
        participants: usize,
    ) -> MaskedUpdate {
        let cfg = MaskSparsifyConfig {
            range: self.range,
            mask_ratio_k: self.mask_ratio_for(participants),
            participants,
        };
        mask_sparsify(g, grad_keep, &self.masker_all(), round, &cfg)
    }

    fn mask_ratio_for(&self, _participants: usize) -> f64 {
        self.mask_ratio_k
    }

    pub fn n_peers(&self) -> usize {
        self.publics.len() - 1
    }

    /// Masker over the complete fleet (exclusive of self).
    fn masker_all(&self) -> PairwiseMasker {
        let all: Vec<u32> = (0..self.publics.len() as u32).collect();
        self.masker_for(&all)
    }

    /// Masker over the round's participant set (exclusive of self) —
    /// the full cohort, or this client's [`Neighborhood`] under a
    /// k-regular topology. Masks only cancel among clients that mask
    /// against each other, so the caller must hand every member of a
    /// pair the same edge set. Peers are keyed lazily and ordered
    /// ascending by id — the pinned masker construction order.
    pub fn masker_for(&self, selected: &[u32]) -> PairwiseMasker {
        let mut ids: Vec<u32> =
            selected.iter().copied().filter(|&p| p != self.id).collect();
        ids.sort_unstable();
        ids.dedup();
        let peers: Vec<(u32, Vec<u8>)> =
            ids.into_iter().map(|p| (p, self.pair_key_with(p).to_vec())).collect();
        let mut masker = PairwiseMasker::new(self.id, peers, self.range);
        if let Some(cache) = &self.cache {
            masker.set_cache(cache.clone());
        }
        masker
    }

    /// Build an update against an explicit participant subset.
    pub fn build_update_among(
        &self,
        g: &[f32],
        grad_keep: &[bool],
        round: u64,
        selected: &[u32],
    ) -> MaskedUpdate {
        let mut scratch = MaskScratch::default();
        let mut out = MaskedUpdate::default();
        self.build_update_among_into(g, grad_keep, round, selected, &mut scratch, &mut out);
        out
    }

    /// [`Self::build_update_among`] into caller-owned scratch + output
    /// — the round engine's zero-allocation path (the per-worker
    /// workspace holds both, so masking a 159k-param update allocates
    /// nothing model-sized in steady state).
    pub fn build_update_among_into(
        &self,
        g: &[f32],
        grad_keep: &[bool],
        round: u64,
        selected: &[u32],
        scratch: &mut MaskScratch,
        out: &mut MaskedUpdate,
    ) {
        let masker = self.masker_for(selected);
        let cfg = MaskSparsifyConfig {
            range: masker.range,
            mask_ratio_k: self.mask_ratio_k,
            participants: masker.n_peers() + 1,
        };
        mask_sparsify_into(g, grad_keep, &masker, round, &cfg, scratch, out);
    }

    /// [`Self::build_update_among_into`] with the pair-mask stream
    /// generation fanned out over `pool` — bitwise identical to the
    /// serial path (the reduction order is pinned; see PERF.md and
    /// [`PairwiseMasker::sparse_combined_mask_pooled_into`]). The
    /// round engine uses this from inside its client jobs:
    /// [`ThreadPool::map_shared`] is nesting-safe.
    pub fn build_update_among_pooled_into(
        &self,
        g: &[f32],
        grad_keep: &[bool],
        round: u64,
        selected: &[u32],
        pool: &ThreadPool,
        scratch: &mut MaskScratch,
        out: &mut MaskedUpdate,
    ) {
        let masker = self.masker_for(selected);
        let cfg = MaskSparsifyConfig {
            range: masker.range,
            mask_ratio_k: self.mask_ratio_k,
            participants: masker.n_peers() + 1,
        };
        mask_sparsify_pooled_into(g, grad_keep, &masker, round, &cfg, pool, scratch, out);
    }

    /// Surrender held shares for a dropped client (server request).
    pub fn shares_for(&self, owner: u32, peer: u32) -> Option<&Vec<Share>> {
        self.held_shares.get(&(owner, peer))
    }

    /// This client's DH private exponent as fixed-width bytes — the
    /// secret material the per-round re-keying registry
    /// ([`crate::secagg::rekey`]) Shamir-shares among the round's
    /// neighbors. Crate-internal: the raw exponent never crosses the
    /// public API.
    pub(crate) fn private_share_bytes(&self) -> Vec<u8> {
        self.keypair.private_bytes_be(exponent_share_width(&self.params))
    }

    /// Attach a shared per-round mask-stream cache (simulation-only
    /// speedup; see [`crate::secagg::mask::MaskCache`]). Every masker
    /// subsequently built by [`Self::masker_for`] carries it.
    pub fn attach_cache(&mut self, cache: crate::secagg::mask::MaskCache) {
        self.cache = Some(cache);
    }
}

/// Server-side aggregation state.
pub struct SecAggServer {
    pub n_clients: u32,
    pub range: MaskRange,
    pub mask_ratio_k: f64,
    pub share_threshold: usize,
    /// DH group parameters — needed to recompute pair keys from a
    /// re-keying-recovered private exponent.
    pub(crate) params: Arc<DhParams>,
    /// Every participant's DH public key (index = client id; the same
    /// `Arc` the clients share).
    pub(crate) publics: Arc<Vec<BigUint>>,
}

impl SecAggServer {
    /// Sum the received payloads. `survivors` are the clients whose
    /// payloads arrived; `dropped` are selected clients that vanished
    /// AFTER the others built their masks (their pair masks now sit
    /// uncancelled in the sum). `recovered_keys` maps each
    /// (survivor, dropped) pair to its reconstructed pair key.
    pub fn aggregate(
        &self,
        n: usize,
        round: u64,
        payloads: &[(u32, SparseVec)],
        dropped: &[u32],
        recovered_keys: &HashMap<(u32, u32), [u8; 32]>,
    ) -> Vec<f32> {
        let mut acc = vec![0f32; n];
        for (_, p) in payloads {
            p.add_into(&mut acc);
        }
        let survivors: Vec<u32> = payloads.iter().map(|(v, _)| *v).collect();
        let participants = payloads.len() + dropped.len();
        self.cancel_dead_masks(&mut acc, round, &survivors, dropped, recovered_keys, participants);
        acc
    }

    /// Remove the uncancelled mask halves left by `dead` clients: for
    /// each survivor v and dead u, regenerate the (v, u) sparse pair
    /// mask from the reconstructed pair key and subtract v's signed
    /// contribution from `acc`. `participants` is the full round cohort
    /// size (survivors + dead) — the σ the clients used when masking
    /// (Eq. 4), which must match or cancellation misses positions.
    pub fn cancel_dead_masks(
        &self,
        acc: &mut [f32],
        round: u64,
        survivors: &[u32],
        dead: &[u32],
        recovered_keys: &HashMap<(u32, u32), [u8; 32]>,
        participants: usize,
    ) {
        if dead.is_empty() {
            return;
        }
        let n = acc.len();
        let sigma = self.range.sigma(self.mask_ratio_k, participants);
        for &v in survivors {
            for &u in dead {
                let key = recovered_keys
                    .get(&(v, u))
                    .or_else(|| recovered_keys.get(&(u, v)))
                    .expect("missing recovered pair key");
                let masker = PairwiseMasker::new(v, vec![(u, key.to_vec())], self.range);
                let (mask, _) = masker.sparse_combined_mask(round, n, sigma);
                for i in 0..n {
                    acc[i] -= mask[i];
                }
            }
        }
    }

    /// [`Self::cancel_dead_masks`] with the per-pair mask regeneration
    /// fanned out over `pool` and **no model-sized scratch**: instead
    /// of materializing each pair's dense mask and subtracting all `n`
    /// positions, only the σ-kept entries of each stream are
    /// subtracted directly from `acc` (subtracting the zero positions
    /// is the f32 identity `x − 0 == x`, so skipping them is bitwise
    /// exact; entries that are themselves `+0.0` are skipped for the
    /// same reason — `sign · 0.0` may be `−0.0`, and `x − (−0.0)`
    /// flushes a `−0.0` accumulator to `+0.0` where the dense path
    /// would not).
    ///
    /// **Reduction-order contract** (PERF.md): generation is
    /// order-free (independent ChaCha streams), the reduce into `acc`
    /// is strictly serial — survivors in the given order (outer), dead
    /// clients in the given order (inner), positions ascending within
    /// each pair stream — matching the serial path per accumulator, so
    /// the result is bitwise identical
    /// (`pooled_cancel_matches_serial_reference`).
    ///
    /// `cache`: the in-process simulation's shared per-round stream
    /// cache. A dead client's (survivor, dead) stream was usually
    /// already generated by the surviving endpoint while masking this
    /// round, so recovery is mostly cache hits.
    pub fn cancel_dead_masks_pooled(
        &self,
        pool: &ThreadPool,
        cache: Option<&MaskCache>,
        acc: &mut [f32],
        round: u64,
        survivors: &[u32],
        dead: &[u32],
        recovered_keys: &HashMap<(u32, u32), [u8; 32]>,
        participants: usize,
    ) {
        let n = acc.len();
        self.cancel_dead_masks_pooled_sink(
            pool,
            cache,
            n,
            round,
            survivors,
            dead,
            recovered_keys,
            participants,
            None,
            |i, x| acc[i as usize] -= x,
        );
    }

    /// [`Self::cancel_dead_masks_pooled`] generalized two ways:
    ///
    /// * the subtraction goes through `sub(i, x)` (contract:
    ///   `acc[i] -= x`), so a sharded accumulator can route each
    ///   position to its owning shard without this method knowing the
    ///   storage layout — `x` is the already-signed entry, so the f32
    ///   op per position is identical to the slice path;
    /// * an optional [`Neighborhood`] restricts the pair walk to the
    ///   dead clients' edges: a dead client only ever masked against
    ///   its neighbors, so recovery work is O(|dead| · degree), not
    ///   O(|dead| · |survivors|). `None` (or a complete topology) is
    ///   the exact pre-neighborhood behavior — every skipped pair is a
    ///   pair with no mask to cancel, and every kept pair must have a
    ///   recovered key (missing ⇒ panic, as before).
    ///
    /// The reduction order is unchanged: survivors-outer, dead-inner
    /// (non-edges skipped), positions ascending within each stream.
    #[allow(clippy::too_many_arguments)]
    pub fn cancel_dead_masks_pooled_sink<F: FnMut(u32, f32)>(
        &self,
        pool: &ThreadPool,
        cache: Option<&MaskCache>,
        n: usize,
        round: u64,
        survivors: &[u32],
        dead: &[u32],
        recovered_keys: &HashMap<(u32, u32), [u8; 32]>,
        participants: usize,
        topology: Option<&Neighborhood>,
        mut sub: F,
    ) {
        if dead.is_empty() {
            return;
        }
        let sigma = self.range.sigma(self.mask_ratio_k, participants);
        // generation fan-out: one task per (survivor, dead) edge
        let mut pairs: Vec<(u32, u32)> = Vec::with_capacity(survivors.len() * dead.len());
        let mut tasks: Vec<(u32, u32, Vec<u8>)> =
            Vec::with_capacity(survivors.len() * dead.len());
        for &v in survivors {
            for &u in dead {
                if let Some(t) = topology {
                    if !t.are_neighbors(v, u) {
                        continue;
                    }
                }
                let key = recovered_keys
                    .get(&(v, u))
                    .or_else(|| recovered_keys.get(&(u, v)))
                    .expect("missing recovered pair key");
                pairs.push((v, u));
                tasks.push((v, u, key.to_vec()));
            }
        }
        let range = self.range;
        let cache = cache.cloned();
        let streams = pool.map_shared(tasks, move |(v, u, key): &(u32, u32, Vec<u8>)| {
            filtered_stream_for_pair(*v, *u, key, range, cache.as_ref(), round, n, sigma)
        });
        // fixed serial reduction: same (survivor, dead) nesting as the
        // dense reference, ascending positions within each stream
        for (&(v, u), stream) in pairs.iter().zip(&streams) {
            let sign = if v < u { 1.0f32 } else { -1.0 };
            for &(i, val) in &stream.entries {
                if val != 0.0 {
                    sub(i, sign * val);
                }
            }
        }
    }

    /// Reconstruct the (owner, peer) pair key from survivors' shares.
    pub fn reconstruct_pair_key(
        &self,
        share_sets: &[Vec<Share>], // one Vec<Share> (16 limbs) per contributing client
    ) -> [u8; 32] {
        assert!(
            share_sets.len() >= self.share_threshold,
            "not enough shares: {} < {}",
            share_sets.len(),
            self.share_threshold
        );
        // transpose: limb i gets one Share from each contributor
        let limbs: Vec<Vec<Share>> = (0..16)
            .map(|i| share_sets.iter().map(|s| s[i]).collect())
            .collect();
        shamir::reconstruct_seed(&limbs)
    }
}

/// Server-side dropout recovery (Bonawitz'17 unmasking round): gather
/// ≥ `share_threshold` Shamir shares of every (survivor, dead) pair key
/// from the *surviving* clients and reconstruct the keys the server
/// needs to cancel the dead clients' orphaned masks.
///
/// Returns `None` when the survivors cannot muster the threshold for
/// some pair (setup ran with `share_keys: false`, or too few clients
/// remain) — the caller must abort the round rather than apply a
/// mask-corrupted aggregate.
pub fn recover_pair_keys(
    clients: &[SecAggClient],
    server: &SecAggServer,
    survivors: &[u32],
    dead: &[u32],
) -> Option<HashMap<(u32, u32), [u8; 32]>> {
    recover_pair_keys_in(clients, server, survivors, dead, None)
}

/// [`recover_pair_keys`] restricted to a [`Neighborhood`]: a dead
/// client under a k-regular topology only ever masked against its
/// neighbors, so only the `(survivor, dead)` pairs that are *edges*
/// need their keys reconstructed — recovery work proportional to one
/// neighborhood, not the whole cohort. `None` topology (or a complete
/// one) is the exact all-pairs behavior.
pub fn recover_pair_keys_in(
    clients: &[SecAggClient],
    server: &SecAggServer,
    survivors: &[u32],
    dead: &[u32],
    topology: Option<&Neighborhood>,
) -> Option<HashMap<(u32, u32), [u8; 32]>> {
    let mut recovered = HashMap::new();
    for &u in dead {
        for &v in survivors {
            if let Some(t) = topology {
                if !t.are_neighbors(u, v) {
                    continue;
                }
            }
            let pair = if v < u { (v, u) } else { (u, v) };
            let share_sets: Vec<Vec<Share>> = survivors
                .iter()
                .filter_map(|&w| clients[w as usize].shares_for(pair.0, pair.1).cloned())
                .take(server.share_threshold)
                .collect();
            if share_sets.len() < server.share_threshold {
                return None;
            }
            recovered.insert((v, u), server.reconstruct_pair_key(&share_sets));
        }
    }
    Some(recovered)
}

/// Run the full setup phase: DH key generation + (optionally) Shamir
/// sharing of pair keys. Returns the client fleet and server.
///
/// Pair keys themselves are **not** materialized here — clients derive
/// them lazily from the shared public-key vector ([`SecAggClient`]),
/// so with `share_keys: false` setup is O(n). The Shamir loop below is
/// the original one-off all-pairs walk (O(n³) share material): it now
/// runs only for `neighbors_k = 0` runs under failure injection, where
/// it stays byte-identical to the pre-re-keying design (keypairs draw
/// from `rng` before the loop, so skipping it never perturbs the key
/// streams). k-regular runs skip it and re-share per round through
/// [`crate::secagg::rekey::RekeyRegistry`] instead — O(n·k) share
/// material scoped to each round's neighborhoods.
pub fn full_setup(n: u32, seed: u64, cfg: &SecAggConfig) -> (Vec<SecAggClient>, SecAggServer) {
    assert!(n >= 2, "secagg needs ≥2 participants");
    let params = Arc::new(if cfg.full_dh {
        DhParams::rfc3526_1536()
    } else {
        DhParams::toy()
    });
    let mut rng = Rng::new(seed);
    let keypairs: Vec<DhKeyPair> = (0..n).map(|_| DhKeyPair::generate(&params, &mut rng)).collect();
    let publics: Arc<Vec<BigUint>> =
        Arc::new(keypairs.iter().map(|kp| kp.public.clone()).collect());

    // Shamir-share every pair key among all OTHER clients: share j of
    // pair (u,v) goes to client j (j ≠ u, j ≠ v gets a share too —
    // Bonawitz shares to everyone; reconstruction needs `threshold`).
    let t = cfg.share_threshold;
    let mut held: Vec<HashMap<(u32, u32), Vec<Share>>> = (0..n).map(|_| HashMap::new()).collect();
    if cfg.share_keys {
        for u in 0..n {
            for v in (u + 1)..n {
                let secret =
                    keypairs[u as usize].shared_secret(&params, &publics[v as usize]);
                let k = pair_key(&secret);
                let limb_shares = shamir::split_seed(&k, n as usize, t, &mut rng);
                // client j's share vector = j-th share of each limb
                for j in 0..n as usize {
                    let mine: Vec<Share> = limb_shares.iter().map(|l| l[j]).collect();
                    held[j].insert((u, v), mine);
                }
            }
        }
    }

    let clients = keypairs
        .into_iter()
        .enumerate()
        .map(|(id, keypair)| SecAggClient {
            id: id as u32,
            params: Arc::clone(&params),
            keypair,
            publics: Arc::clone(&publics),
            range: cfg.range,
            cache: None,
            held_shares: std::mem::take(&mut held[id]),
            mask_ratio_k: cfg.mask_ratio_k,
        })
        .collect();

    let server = SecAggServer {
        n_clients: n,
        range: cfg.range,
        mask_ratio_k: cfg.mask_ratio_k,
        share_threshold: t,
        params,
        publics,
    };
    (clients, server)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::topk::threshold_for_topk_abs;

    fn keep_top(g: &[f32], frac: f64) -> Vec<bool> {
        let k = ((g.len() as f64 * frac).ceil() as usize).max(1);
        let d = threshold_for_topk_abs(g, k);
        g.iter().map(|v| v.abs() > d).collect()
    }

    #[test]
    fn full_round_no_dropout() {
        let cfg = SecAggConfig::default();
        let (clients, server) = full_setup(4, 7, &cfg);
        let n = 3000;
        let mut rng = Rng::new(8);
        let mut expect = vec![0f64; n];
        let mut payloads = Vec::new();
        for c in &clients {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.1)).collect();
            let keep = keep_top(&g, 0.02);
            let out = c.build_update(&g, &keep, 1, clients.len());
            for j in 0..n {
                expect[j] += (g[j] - out.residual[j]) as f64;
            }
            payloads.push((c.id, out.payload));
        }
        let agg = server.aggregate(n, 1, &payloads, &[], &HashMap::new());
        for j in 0..n {
            assert!((agg[j] as f64 - expect[j]).abs() < 2e-3, "at {j}");
        }
    }

    #[test]
    fn dropout_recovery_cancels_orphan_masks() {
        let cfg = SecAggConfig { share_threshold: 2, ..Default::default() };
        let (clients, server) = full_setup(4, 9, &cfg);
        let n = 2000;
        let mut rng = Rng::new(10);

        // all four build updates (so masks reference all pairs)...
        let mut updates = Vec::new();
        for c in &clients {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.1)).collect();
            let keep = keep_top(&g, 0.02);
            let upd = c.build_update(&g, &keep, 2, clients.len());
            updates.push((c.id, g, upd));
        }
        // ...but client 3 drops before sending
        let dropped = 3u32;
        let mut payloads = Vec::new();
        let mut expect = vec![0f64; n];
        for (id, g, out) in &updates {
            if *id == dropped {
                continue;
            }
            for j in 0..n {
                expect[j] += (g[j] - out.residual[j]) as f64;
            }
            payloads.push((*id, out.payload.clone()));
        }

        // server reconstructs pair keys (survivor, dropped) from the
        // survivors' held shares
        let mut recovered = HashMap::new();
        for (v, _, _) in updates.iter().filter(|(id, _, _)| *id != dropped) {
            let pair = if *v < dropped { (*v, dropped) } else { (dropped, *v) };
            let share_sets: Vec<Vec<Share>> = clients
                .iter()
                .filter(|c| c.id != dropped)
                .filter_map(|c| c.shares_for(pair.0, pair.1).cloned())
                .take(cfg.share_threshold)
                .collect();
            recovered.insert((*v, dropped), server.reconstruct_pair_key(&share_sets));
        }

        let agg = server.aggregate(n, 2, &payloads, &[dropped], &recovered);
        for j in 0..n {
            assert!(
                (agg[j] as f64 - expect[j]).abs() < 2e-3,
                "orphan mask at {j}: {} vs {}",
                agg[j],
                expect[j]
            );
        }
    }

    #[test]
    fn pooled_cancel_matches_serial_reference() {
        // the parallel recovery path (fan-out generation + kept-entry
        // serial-order reduction) must be BITWISE equal to the dense
        // serial reference, with and without the shared stream cache
        let cfg = SecAggConfig { share_threshold: 2, ..Default::default() };
        for (fleet_n, dead) in [(4u32, vec![3u32]), (6, vec![1, 4])] {
            let (clients, server) = full_setup(fleet_n, 31 + fleet_n as u64, &cfg);
            let n = 2000;
            let mut rng = Rng::new(fleet_n as u64);
            let survivors: Vec<u32> =
                (0..fleet_n).filter(|id| !dead.contains(id)).collect();
            let mut payloads = Vec::new();
            for c in &clients {
                let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.1)).collect();
                let keep = keep_top(&g, 0.02);
                let out = c.build_update(&g, &keep, 6, clients.len());
                if survivors.contains(&c.id) {
                    payloads.push(out.payload);
                }
            }
            let recovered = recover_pair_keys(&clients, &server, &survivors, &dead)
                .expect("quorum met");

            let mut base = vec![0f32; n];
            for p in &payloads {
                p.add_into(&mut base);
            }
            let mut serial = base.clone();
            server.cancel_dead_masks(
                &mut serial,
                6,
                &survivors,
                &dead,
                &recovered,
                fleet_n as usize,
            );
            let pool = ThreadPool::new(3);
            for cache in [None, Some(crate::secagg::mask::MaskCache::default())] {
                let mut pooled = base.clone();
                server.cancel_dead_masks_pooled(
                    &pool,
                    cache.as_ref(),
                    &mut pooled,
                    6,
                    &survivors,
                    &dead,
                    &recovered,
                    fleet_n as usize,
                );
                assert!(
                    serial.iter().zip(&pooled).all(|(a, b)| a.to_bits() == b.to_bits()),
                    "fleet={fleet_n} dead={dead:?} cache={}: pooled cancel diverged",
                    cache.is_some()
                );
            }
        }
    }

    #[test]
    fn recover_pair_keys_matches_manual_reconstruction() {
        let cfg = SecAggConfig { share_threshold: 2, ..Default::default() };
        let (clients, server) = full_setup(5, 21, &cfg);
        let survivors = [0u32, 1, 3];
        let dead = [2u32, 4];
        let rec = recover_pair_keys(&clients, &server, &survivors, &dead)
            .expect("threshold met: 3 survivors hold shares");
        // every (survivor, dead) pair recovered, and each key matches a
        // by-hand reconstruction from the same share sets
        assert_eq!(rec.len(), survivors.len() * dead.len());
        for &u in &dead {
            for &v in &survivors {
                let pair = if v < u { (v, u) } else { (u, v) };
                let share_sets: Vec<Vec<Share>> = survivors
                    .iter()
                    .filter_map(|&w| clients[w as usize].shares_for(pair.0, pair.1).cloned())
                    .take(2)
                    .collect();
                assert_eq!(rec[&(v, u)], server.reconstruct_pair_key(&share_sets));
            }
        }
    }

    #[test]
    fn recover_pair_keys_fails_without_share_material() {
        let cfg = SecAggConfig { share_keys: false, ..Default::default() };
        let (clients, server) = full_setup(4, 23, &cfg);
        assert!(recover_pair_keys(&clients, &server, &[0, 1, 2], &[3]).is_none());
    }

    #[test]
    fn without_recovery_orphan_masks_corrupt_sum() {
        // negative control: dropping a client WITHOUT recovery leaves
        // large mask residues (this is why recovery exists)
        let cfg = SecAggConfig::default();
        let (clients, server) = full_setup(3, 11, &cfg);
        let n = 1000;
        let mut rng = Rng::new(12);
        let mut payloads = Vec::new();
        let mut expect = vec![0f64; n];
        for c in &clients {
            let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.1)).collect();
            let keep = keep_top(&g, 0.02);
            let out = c.build_update(&g, &keep, 3, clients.len());
            if c.id == 2 {
                continue; // drop, no recovery
            }
            for j in 0..n {
                expect[j] += (g[j] - out.residual[j]) as f64;
            }
            payloads.push((c.id, out.payload));
        }
        let agg = server.aggregate(n, 3, &payloads, &[], &HashMap::new());
        let max_err = (0..n)
            .map(|j| (agg[j] as f64 - expect[j]).abs())
            .fold(0.0f64, f64::max);
        assert!(max_err > 0.5, "expected visible mask residue, got {max_err}");
    }

    #[test]
    fn setup_is_deterministic_per_seed() {
        let cfg = SecAggConfig::default();
        let (c1, _) = full_setup(3, 42, &cfg);
        let (c2, _) = full_setup(3, 42, &cfg);
        let m1 = c1[0].masker_for(&[0, 1, 2]).raw_pair_mask(1, 0, 16);
        let m2 = c2[0].masker_for(&[0, 1, 2]).raw_pair_mask(1, 0, 16);
        assert_eq!(m1, m2);
    }

    #[test]
    fn lazy_pair_keys_agree_across_endpoints() {
        // both ends of every pair must derive the same key on demand —
        // the property the deleted eager all-pairs table guaranteed by
        // construction, now guaranteed by DH agreement
        let cfg = SecAggConfig { share_keys: false, ..Default::default() };
        let (clients, _) = full_setup(5, 77, &cfg);
        for u in 0..5u32 {
            for v in (u + 1)..5 {
                assert_eq!(
                    clients[u as usize].pair_key_with(v),
                    clients[v as usize].pair_key_with(u),
                    "pair ({u},{v})"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "not enough shares")]
    fn reconstruction_requires_threshold() {
        let cfg = SecAggConfig { share_threshold: 3, ..Default::default() };
        let (clients, server) = full_setup(4, 13, &cfg);
        let shares = vec![clients[0].shares_for(1, 2).unwrap().clone()];
        server.reconstruct_pair_key(&shares);
    }
}
