//! Seeded k-regular mask neighborhoods — the sparsified-secagg graph
//! (Ergün et al., Beguier et al.: the complete pair graph can be
//! replaced by sparse neighborhoods without losing cancellation).
//!
//! The complete pair-mask graph costs O(cohort²) pair streams per
//! round; at 10k+ clients that wall dominates everything. This module
//! replaces it with a **circulant ring**: each member's ring position
//! is its rank under a per-`(run_seed, round, member)` hash
//! (consistent-hash ordering), and every client masks against the
//! `half` positions on each side — a uniform-degree (`2·half`-regular)
//! symmetric graph, deterministic per `(seed, round)` so any round
//! replays bit-for-bit. Hashing members *independently* (rather than
//! shuffling the cohort, which permutes everything when one member
//! changes) makes churn local: a join/leave moves only the ring window
//! around the changed member, so per-round Shamir re-keying
//! ([`crate::secagg::rekey`]) re-shares only the affected
//! neighborhoods.
//!
//! Uniform degree is load-bearing: Eq. 4's σ depends on the
//! participant count `x`, and both endpoints of a pair *and* the
//! server's dead-mask cancellation must use the same σ. With every
//! vertex at degree `d`, all three agree on `x = d + 1`.
//!
//! `k = 0` (the config default) or any `k` whose ring covers the whole
//! cohort short-circuits to the **complete graph** — bitwise identical
//! to the pre-neighborhood behavior, which is what keeps the golden
//! secagg tests pinned.

/// Domain constant mixed into the neighborhood ring hash (distinct
/// from the selection/transport/keygen constants).
const NEIGHBORHOOD_SALT: u64 = 0x6e65_6967;

/// A member's ring rank: the SplitMix64 finalizer over the
/// `(seed, round, member)` mix. Each member hashes independently of
/// the rest of the cohort, which is what makes the ring order a
/// consistent hash — one member joining or leaving shifts only the
/// ring window around its own position.
fn ring_rank(base: u64, cid: u32) -> u64 {
    let mut z = base.wrapping_add((cid as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// One round's mask topology over the selected cohort.
#[derive(Clone, Debug)]
pub struct Neighborhood {
    /// The cohort, in selection (ascending id) order.
    members: Vec<u32>,
    /// Ring order (members sorted by consistent hash); empty when
    /// complete.
    ring: Vec<u32>,
    /// Ring position per member, aligned with `members`.
    pos: Vec<usize>,
    /// Neighbors per side on the ring (0 when complete).
    half: usize,
}

impl Neighborhood {
    /// The complete graph over `selected` — every pair masks.
    pub fn complete(selected: &[u32]) -> Self {
        Self { members: selected.to_vec(), ring: Vec::new(), pos: Vec::new(), half: 0 }
    }

    /// Seeded `k`-regular topology over `selected` for `round`.
    ///
    /// `k` is the target degree; the ring construction uses
    /// `half = ⌈k/2⌉` neighbors per side, so the realized degree is
    /// `min(2·half, n−1)`. `k = 0`, cohorts of ≤ 3, and any `k` whose
    /// ring already covers the cohort all collapse to the complete
    /// graph (same masks, same σ — the zero-cost bypass).
    pub fn build(selected: &[u32], k: usize, seed: u64, round: u64) -> Self {
        let n = selected.len();
        let half = k.div_ceil(2);
        if k == 0 || n < 2 || 2 * half >= n - 1 {
            return Self::complete(selected);
        }
        // consistent-hash ring order: sort by per-member hash (id
        // tie-break for the negligible collision case); round is mixed
        // into the hash base so the ring still varies per round
        let base = seed ^ NEIGHBORHOOD_SALT ^ round.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut ring = selected.to_vec();
        ring.sort_unstable_by_key(|&cid| (ring_rank(base, cid), cid));
        // members is sorted (selection order); map each to its ring slot
        let members = selected.to_vec();
        let mut pos = vec![0usize; n];
        for (slot, &cid) in ring.iter().enumerate() {
            let i = members.binary_search(&cid).expect("ring is a permutation of members");
            pos[i] = slot;
        }
        Self { members, ring, pos, half }
    }

    pub fn is_complete(&self) -> bool {
        self.ring.is_empty()
    }

    /// Uniform per-vertex degree (circulant graphs are regular).
    pub fn degree(&self) -> usize {
        if self.is_complete() {
            self.members.len().saturating_sub(1)
        } else {
            2 * self.half
        }
    }

    /// Eq. 4's `x` as seen by every endpoint and the server:
    /// degree + 1.
    pub fn participants(&self) -> usize {
        self.degree() + 1
    }

    pub fn members(&self) -> &[u32] {
        &self.members
    }

    /// Fill `out` with `cid`'s neighbors, ascending by id (the pinned
    /// masker construction order — PERF.md reduction-order contract).
    pub fn neighbors_into(&self, cid: u32, out: &mut Vec<u32>) {
        out.clear();
        let i = self
            .members
            .binary_search(&cid)
            .unwrap_or_else(|_| panic!("client {cid} not in cohort"));
        if self.is_complete() {
            out.extend(self.members.iter().copied().filter(|&p| p != cid));
            return;
        }
        let n = self.members.len();
        let p = self.pos[i];
        for d in 1..=self.half {
            out.push(self.ring[(p + d) % n]);
            out.push(self.ring[(p + n - d) % n]);
        }
        out.sort_unstable();
    }

    /// `cid`'s neighbors, ascending (allocating twin of
    /// [`Self::neighbors_into`]).
    pub fn neighbors_of(&self, cid: u32) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.degree());
        self.neighbors_into(cid, &mut out);
        out
    }

    /// Whether `(u, v)` is an edge (symmetric; false for self-pairs
    /// and non-members).
    pub fn are_neighbors(&self, u: u32, v: u32) -> bool {
        if u == v {
            return false;
        }
        let (Ok(i), Ok(j)) = (self.members.binary_search(&u), self.members.binary_search(&v))
        else {
            return false;
        };
        if self.is_complete() {
            return true;
        }
        let n = self.members.len();
        let d = (self.pos[i] + n - self.pos[j]) % n;
        d.min(n - d) <= self.half
    }
}

/// The paper-suggested degree target for a cohort of `n`:
/// `⌈log₂ n⌉ + c` (connectivity with overwhelming probability needs
/// Ω(log n); the slack `c` buys dropout tolerance).
pub fn log_degree(n: usize, c: usize) -> usize {
    (usize::BITS - n.max(1).leading_zeros()) as usize + c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cohort(n: u32) -> Vec<u32> {
        (0..n).collect()
    }

    #[test]
    fn complete_bypass_matches_all_pairs() {
        for k in [0usize, 9, 10, 100] {
            let sel = cohort(10);
            // k ≥ n−1 (or 0) must yield the complete graph
            if k == 0 || 2 * k.div_ceil(2) >= 9 {
                let nb = Neighborhood::build(&sel, k, 7, 3);
                assert!(nb.is_complete(), "k={k}");
                assert_eq!(nb.degree(), 9);
                assert_eq!(nb.participants(), 10);
                assert_eq!(nb.neighbors_of(4), vec![0, 1, 2, 3, 5, 6, 7, 8, 9]);
            }
        }
    }

    #[test]
    fn ring_degree_is_uniform_and_symmetric() {
        let sel = cohort(17);
        let nb = Neighborhood::build(&sel, 4, 11, 2);
        assert!(!nb.is_complete());
        assert_eq!(nb.degree(), 4);
        assert_eq!(nb.participants(), 5);
        for &c in &sel {
            let peers = nb.neighbors_of(c);
            assert_eq!(peers.len(), 4, "client {c}");
            assert!(peers.windows(2).all(|w| w[0] < w[1]), "sorted ascending");
            assert!(!peers.contains(&c), "no self edge");
            for &p in &peers {
                assert!(nb.are_neighbors(c, p));
                assert!(nb.are_neighbors(p, c), "symmetric");
                assert!(nb.neighbors_of(p).contains(&c), "edge listed both ends");
            }
        }
    }

    #[test]
    fn deterministic_per_seed_round_and_varies_by_round() {
        let sel = cohort(64);
        let a = Neighborhood::build(&sel, 8, 5, 1);
        let b = Neighborhood::build(&sel, 8, 5, 1);
        let c = Neighborhood::build(&sel, 8, 5, 2);
        for &id in &sel {
            assert_eq!(a.neighbors_of(id), b.neighbors_of(id));
        }
        assert!(
            sel.iter().any(|&id| a.neighbors_of(id) != c.neighbors_of(id)),
            "round must reshuffle the ring"
        );
    }

    #[test]
    fn churn_shifts_only_the_local_ring_window() {
        // consistent-hash ordering: removing one member may change the
        // neighbor sets of only the members whose ±half ring window
        // spanned the removed slot — 2·half of them — not the whole
        // cohort (a shuffled ring would re-pair nearly everyone)
        let sel = cohort(64);
        let a = Neighborhood::build(&sel, 8, 5, 3);
        let without: Vec<u32> = sel.iter().copied().filter(|&c| c != 20).collect();
        let b = Neighborhood::build(&without, 8, 5, 3);
        let changed = without
            .iter()
            .filter(|&&c| a.neighbors_of(c) != b.neighbors_of(c))
            .count();
        assert!(changed >= 1, "the departed member's neighbors must re-pair");
        assert!(
            changed <= a.degree(),
            "churn changed {changed} neighborhoods (degree {})",
            a.degree()
        );
        // joins are the same mechanism in reverse
        let rejoin = Neighborhood::build(&sel, 8, 5, 3);
        for &c in &sel {
            assert_eq!(a.neighbors_of(c), rejoin.neighbors_of(c));
        }
    }

    #[test]
    fn works_on_non_contiguous_cohorts() {
        let sel = vec![2u32, 5, 11, 12, 40, 41, 77, 90, 91];
        let nb = Neighborhood::build(&sel, 4, 9, 0);
        for &c in &sel {
            let peers = nb.neighbors_of(c);
            assert_eq!(peers.len(), 4);
            assert!(peers.iter().all(|p| sel.contains(p)));
        }
        assert!(!nb.are_neighbors(2, 3), "non-member is never a neighbor");
    }

    #[test]
    fn odd_k_rounds_up_to_even_degree() {
        let nb = Neighborhood::build(&cohort(32), 5, 3, 0);
        assert_eq!(nb.degree(), 6); // half = 3
    }

    #[test]
    fn tiny_cohorts_are_complete() {
        for n in [2u32, 3] {
            let nb = Neighborhood::build(&cohort(n), 2, 1, 0);
            assert!(nb.is_complete());
        }
    }

    #[test]
    fn log_degree_grows_with_n() {
        assert_eq!(log_degree(1024, 2), 13);
        assert!(log_degree(10_000, 2) >= 15);
        assert!(log_degree(2, 0) >= 1);
    }
}
